"""Fault-tolerant elastic training (paper Fig. 5 in miniature):
start with 4 nodes, join 3 more, crash one, lose one gracefully —
training never stops. Also demonstrates P2P checkpoint onboarding.

    PYTHONPATH=src python examples/fault_tolerant_training.py
"""
import tempfile

import jax

from repro.checkpointing import CheckpointServer, fetch_checkpoint
from repro.configs import get_config
from repro.core.diloco import DiLoCoConfig
from repro.core.fault_tolerance import (ClusterSimulator, EventKind,
                                        NodeEvent)
from repro.data.pipeline import DataConfig
from repro.models.registry import get_model
from repro.train.loop import ElasticTrainer, TrainerConfig

cfg = get_config("mamba2-130m").reduced()
model = get_model(cfg)
params, _ = model.init(jax.random.PRNGKey(0))

events = [
    NodeEvent(1, EventKind.JOIN, 4),      # new sponsor joins
    NodeEvent(2, EventKind.JOIN, 5),
    NodeEvent(3, EventKind.CRASH, 0),     # node 0 dies silently ->
    NodeEvent(4, EventKind.JOIN, 6),      #   heartbeat eviction
    NodeEvent(5, EventKind.LEAVE, 1),     # node 1 sends deathrattle
    NodeEvent(6, EventKind.STRAGGLE, 2),  # node 2 too slow one round
]
with tempfile.TemporaryDirectory() as ckpt_dir:
    trainer = ElasticTrainer(
        model,
        TrainerConfig(diloco=DiLoCoConfig(inner_steps=4, quant="int8"),
                      inner_lr=3e-3, max_workers=8, ckpt_dir=ckpt_dir),
        DataConfig(vocab=cfg.vocab, seq_len=48, batch_per_worker=4,
                   total_steps=100),
        params,
        ClusterSimulator([0, 1, 2, 3], events=events),
    )
    hist = trainer.run(8)
    for h in hist:
        tag = ""
        if h["joined"]:
            tag += f" +join{h['joined']}"
        if h["left"]:
            tag += f" -left{h['left']}"
        print(f"outer={h['outer_step']} n={len(h['live'])} "
              f"loss={h['loss']:.4f}{tag}")

    # peer-to-peer checkpoint transfer (paper §2.4.2): a joiner
    # downloads the latest checkpoint straight from an active peer
    import time
    for _ in range(100):
        from repro.checkpointing import latest_step
        if latest_step(ckpt_dir) is not None:
            break
        time.sleep(0.1)
    server = CheckpointServer(ckpt_dir)
    with tempfile.TemporaryDirectory() as joiner_dir:
        path = fetch_checkpoint(("127.0.0.1", server.port), joiner_dir)
        print(f"\nP2P checkpoint fetched by joiner: {path.name} "
              f"(sha256-verified frames over TCP)")
    server.close()
print("survived crash, deathrattle, straggler and 3 joins")
