"""Fault-tolerant elastic training (paper Fig. 5 in miniature):
start with 4 nodes, join 3 more, crash one, lose one gracefully —
training never stops. Also demonstrates live checkpoint recovery: the
trainer writes int8 delta checkpoints into a content-addressed chunk
store, three peers serve it, and a joiner swarm-fetches the state even
though one peer crashes mid-transfer.

    PYTHONPATH=src python examples/fault_tolerant_training.py
"""
import tempfile

import jax
import numpy as np

from repro.checkpointing import (CheckpointServer, ChunkPeer,
                                 fetch_checkpoint, recover)
from repro.configs import get_config
from repro.core.diloco import DiLoCoConfig
from repro.core.fault_tolerance import (ClusterSimulator, EventKind,
                                        NodeEvent)
from repro.data.pipeline import DataConfig
from repro.models.registry import get_model
from repro.train.loop import ElasticTrainer, TrainerConfig

cfg = get_config("mamba2-130m").reduced()
model = get_model(cfg)
params, _ = model.init(jax.random.PRNGKey(0))

events = [
    NodeEvent(1, EventKind.JOIN, 4),      # new sponsor joins
    NodeEvent(2, EventKind.JOIN, 5),
    NodeEvent(3, EventKind.CRASH, 0),     # node 0 dies silently ->
    NodeEvent(4, EventKind.JOIN, 6),      #   heartbeat eviction
    NodeEvent(5, EventKind.LEAVE, 1),     # node 1 sends deathrattle
    NodeEvent(6, EventKind.STRAGGLE, 2),  # node 2 too slow one round
]
with tempfile.TemporaryDirectory() as ckpt_dir:
    trainer = ElasticTrainer(
        model,
        TrainerConfig(diloco=DiLoCoConfig(inner_steps=4, quant="int8"),
                      inner_lr=3e-3, max_workers=8, ckpt_dir=ckpt_dir,
                      ckpt_engine="delta", ckpt_delta_base_every=4),
        DataConfig(vocab=cfg.vocab, seq_len=48, batch_per_worker=4,
                   total_steps=100),
        params,
        ClusterSimulator([0, 1, 2, 3], events=events),
    )
    hist = trainer.run(8)
    for h in hist:
        tag = ""
        if h["joined"]:
            tag += f" +join{h['joined']}"
        if h["left"]:
            tag += f" -left{h['left']}"
        print(f"outer={h['outer_step']} n={len(h['live'])} "
              f"loss={h['loss']:.4f}{tag}")

    store = trainer.ckpt_store
    latest = store.load_manifest(store.latest_step())
    full = latest["stats"]["logical_bytes"]
    new = max(1, latest["stats"]["new_bytes"])
    print(f"\ndelta checkpoint: kind={latest['kind']} "
          f"{full} logical B -> {new} stored B "
          f"({full / new:.1f}x smaller than a flat fp32 dump)")

    # swarm recovery (paper §2.4.2 + SWARM striping): three peers
    # serve the store; one crashes mid-fetch; the joiner still
    # completes, bit-exact against the writer's reference chain
    peers = [ChunkPeer(store),
             ChunkPeer(store, crash_after=2),   # dies after 2 chunks
             ChunkPeer(store)]
    with tempfile.TemporaryDirectory() as joiner_dir:
        tree, meta, stats = recover([p.addr for p in peers],
                                    joiner_dir,
                                    trainer.checkpoint_like())
        np.testing.assert_allclose(
            np.asarray(tree["anchor"]["embed"], np.float32),
            np.asarray(trainer.outer.anchor["embed"], np.float32),
            atol=1e-2)   # within one delta-quantization step
        print(f"swarm fetch: step {stats['step']} "
              f"{stats['chunks_fetched']} chunks from "
              f"{len(stats['per_peer'])} peers, "
              f"dead={stats['dead_peers']}, "
              f"reassigned={stats['reassigned_ranges']} -> joiner "
              f"enters at outer step {meta['outer_step']}")
    for p in peers:
        p.close()

    # the seed's single-peer flat protocol still works for flat dirs
    with tempfile.TemporaryDirectory() as flat_dir:
        from repro.checkpointing import save
        save(flat_dir, 1, {"w": np.zeros(4, np.float32)})
        server = CheckpointServer(flat_dir)
        with tempfile.TemporaryDirectory() as joiner_dir:
            path = fetch_checkpoint(("127.0.0.1", server.port),
                                    joiner_dir)
            print(f"single-peer flat fetch still works: {path.name}")
        server.close()
print("survived crash, deathrattle, straggler, 3 joins and a "
      "mid-fetch peer death")
