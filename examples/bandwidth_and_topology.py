"""The communication stack: int8 ring all-reduce + bandwidth-aware ring
ordering (paper §2.2/§2.5).

    PYTHONPATH=src python examples/bandwidth_and_topology.py
"""
import jax.numpy as jnp
import numpy as np

from repro.core import topology
from repro.core.diloco import DiLoCoConfig, bandwidth_reduction_factor
from repro.core.ring_reduce import (RingConfig, ring_wire_bytes,
                                    simulate_ring_all_reduce)

rng = np.random.default_rng(0)

# 1. int8 ring all-reduce: 6 workers, fp32-exactness vs int8 wire format
xs = jnp.asarray(rng.normal(size=(6, 100_000)) * 1e-3, jnp.float32)
exact = simulate_ring_all_reduce(xs, cfg=RingConfig(quant="fp32"))
q8 = simulate_ring_all_reduce(xs, cfg=RingConfig(quant="int8"))
err = float(jnp.max(jnp.abs(q8[0] - exact[0])))
print(f"int8 ring vs exact mean: max err {err:.2e} "
      f"(pseudo-grad sigma {float(xs.std()):.2e})")
print(f"wire bytes per worker: int8 "
      f"{ring_wire_bytes(100_000, 6, 'int8'):,} vs fp32 "
      f"{ring_wire_bytes(100_000, 6, 'fp32'):,}")
for h, q in [(100, "int8"), (500, "int8"), (100, "int4")]:
    f = bandwidth_reduction_factor(DiLoCoConfig(inner_steps=h, quant=q))
    print(f"  H={h} {q}: {f:.0f}x less traffic than per-step fp32 DP")

# 2. bandwidth-aware ring order (max-min bottleneck Hamiltonian cycle)
n = 10
w = rng.uniform(0.3, 4.0, size=(n, n))
w = (w + w.T) / 2
np.fill_diagonal(w, 0)
naive = tuple(range(n))
best = topology.optimize_ring_order(w)
print(f"\nring bottleneck bandwidth: naive order "
      f"{topology.cycle_bottleneck(w, naive):.2f} Gb/s -> optimized "
      f"{topology.cycle_bottleneck(w, best):.2f} Gb/s")
print(f"optimized ring: {best}")

# 3. the monitor only reorders (=> recompiles) when links drift
mon = topology.BandwidthMonitor(n)
mon.observe_matrix(w)
changed, order = mon.maybe_reorder()
print(f"monitor adopted order (recompile needed): {changed}")
changed, _ = mon.maybe_reorder()
print(f"stable network, second check reorders: {changed}")
