"""Quickstart: train a small LM with DiLoCo in 60 seconds on CPU.

    PYTHONPATH=src python examples/quickstart.py
"""
import jax

from repro.configs import get_config
from repro.core.diloco import DiLoCoConfig
from repro.core.fault_tolerance import ClusterSimulator
from repro.data.pipeline import DataConfig
from repro.models.registry import get_model
from repro.train.loop import ElasticTrainer, TrainerConfig

# a reduced-size sibling of the paper's own 10B config
cfg = get_config("intellect-1").reduced()
model = get_model(cfg)
params, _ = model.init(jax.random.PRNGKey(0))

# 4 DiLoCo workers, H=5 inner steps, int8 ring (the paper's recipe)
trainer = ElasticTrainer(
    model,
    TrainerConfig(diloco=DiLoCoConfig(inner_steps=5, quant="int8"),
                  inner_lr=3e-3, max_workers=4),
    DataConfig(vocab=cfg.vocab, seq_len=64, batch_per_worker=4,
               total_steps=100),
    params,
    ClusterSimulator([0, 1, 2, 3]),
)
history = trainer.run(6)
for h in history:
    print(f"outer={h['outer_step']} loss={h['loss']:.4f} "
          f"live={h['live']} wire_bytes/sync={h['wire_bytes']:,}")
print(f"\nloss {history[0]['loss']:.3f} -> {history[-1]['loss']:.3f} "
      f"with 400x less communication than per-step DP at H=100")
