"""Continuous-batching serving over the model zoo (here: the
attention-free Mamba2, whose decode state is O(1) per token): per-slot
admission/retirement, bucketed exact prefill, and an on-device decode
loop sampling with per-request temperatures.

    PYTHONPATH=src python examples/serve_batched.py
"""
import jax
import numpy as np

from repro.configs import get_config
from repro.models.registry import get_model
from repro.serving.engine import ContinuousEngine, Request

cfg = get_config("mamba2-130m").reduced()
model = get_model(cfg)
params, _ = model.init(jax.random.PRNGKey(0))

engine = ContinuousEngine(model, params, batch_slots=4, max_len=128,
                          decode_chunk=8, top_k=8)
rng = np.random.default_rng(0)
reqs = [Request(i, rng.integers(2, cfg.vocab, size=rng.integers(4, 12))
                .astype(np.int32), max_new_tokens=12,
                temperature=0.0 if i % 2 == 0 else 0.8)
        for i in range(10)]
for r in reqs:
    engine.submit(r)

engine.run_until_drained()
for r in reqs[:3]:
    mode = "greedy" if r.temperature == 0 else f"T={r.temperature}"
    print(f"req {r.rid} ({mode}): prompt[{len(r.prompt)}] -> "
          f"{r.out_tokens}")
s = engine.perf_summary()
print(f"\n{s['requests']} requests, {s['decode_steps']} decode steps "
      f"in {s['host_syncs']} host syncs, {s['tokens_per_s']:.1f} tok/s "
      f"on CPU, p95 latency {s['latency_p95_s'] * 1e3:.0f} ms, "
      f"occupancy {s['slot_occupancy']:.2f}")
