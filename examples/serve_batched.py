"""Batched serving with the wave engine: prefill + lockstep decode over
the model zoo (here: the attention-free Mamba2, whose decode state is
O(1) per token).

    PYTHONPATH=src python examples/serve_batched.py
"""
import time

import jax
import numpy as np

from repro.configs import get_config
from repro.models.registry import get_model
from repro.serving.engine import Request, ServeEngine

cfg = get_config("mamba2-130m").reduced()
model = get_model(cfg)
params, _ = model.init(jax.random.PRNGKey(0))

engine = ServeEngine(model, params, batch_slots=4, max_len=128)
rng = np.random.default_rng(0)
reqs = [Request(i, rng.integers(2, cfg.vocab, size=rng.integers(4, 12))
                .astype(np.int32), max_new_tokens=12)
        for i in range(10)]
for r in reqs:
    engine.submit(r)

t0 = time.time()
engine.run_until_drained()
dt = time.time() - t0
for r in reqs[:3]:
    print(f"req {r.rid}: prompt[{len(r.prompt)}] -> {r.out_tokens}")
s = engine.stats
print(f"\n{len(reqs)} requests in {s['waves']} waves, "
      f"{s['decode_steps']} decode steps, "
      f"{s['tokens_out'] / dt:.1f} tok/s on CPU")
