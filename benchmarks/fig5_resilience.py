"""Paper Fig. 5: dynamic node participation — the run starts at 4 nodes,
scales toward 14 with churn (joins, crashes, graceful leaves), and
training stays stable. Executed for real with the elastic trainer on a
reduced model; reports the membership trajectory and loss trend."""
from __future__ import annotations

import time

import jax
import numpy as np

from benchmarks import common
from repro.configs import CONFIGS
from repro.core.diloco import DiLoCoConfig
from repro.core.fault_tolerance import (ClusterSimulator, EventKind,
                                        NodeEvent)
from repro.data.pipeline import DataConfig
from repro.models.registry import get_model
from repro.train.loop import ElasticTrainer, TrainerConfig


def run(seed: int = 0) -> list[str]:
    cfg = CONFIGS["mamba2-130m"].reduced()
    model = get_model(cfg)
    params, _ = model.init(jax.random.PRNGKey(seed))
    events = [NodeEvent(1, EventKind.JOIN, 4),
              NodeEvent(2, EventKind.JOIN, 5),
              NodeEvent(3, EventKind.JOIN, 6),
              NodeEvent(4, EventKind.CRASH, 2),
              NodeEvent(5, EventKind.JOIN, 7),
              NodeEvent(6, EventKind.LEAVE, 0),
              NodeEvent(7, EventKind.JOIN, 8),
              NodeEvent(7, EventKind.STRAGGLE, 5)]
    sim = ClusterSimulator([0, 1, 2, 3], events=events)
    dcfg = DataConfig(vocab=cfg.vocab, seq_len=48, batch_per_worker=4,
                      total_steps=400)
    tcfg = TrainerConfig(diloco=DiLoCoConfig(inner_steps=3,
                                             quant="int8"),
                         inner_lr=3e-3, max_workers=10)
    tr = ElasticTrainer(model, tcfg, dcfg, params, sim)
    t0 = time.time()
    hist = tr.run(9)
    dt = (time.time() - t0) / 9 * 1e6
    sizes = [len(h["live"]) for h in hist]
    losses = [h["loss"] for h in hist]
    return [common.csv_row(
        "fig5/resilience", dt,
        f"members={'-'.join(map(str, sizes))};"
        f"loss_first={losses[0]:.3f};loss_last={losses[-1]:.3f};"
        f"stable={int(losses[-1] < losses[0])};"
        f"retry_attempts_max={max(h['attempts'] for h in hist)}")]
