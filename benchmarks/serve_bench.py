"""Continuous vs wave serving benchmark on a mixed-length trace.

The trace mixes short-prompt/short-generation requests with
long-generation stragglers — the workload where wave scheduling
strands slots (a drained request idles until the whole wave finishes)
and per-token host syncs dominate. Both engines run the SAME requests
greedily; outputs must be bit-identical (asserted into the payload), so
the speedup is pure scheduling + sync amortization.

``python -m benchmarks.run serve --json`` writes ``BENCH_serve.json``
(tokens/sec, p50/p95 request latency, slot occupancy, speedups) — the
serving perf-trajectory file future PRs diff against. ``--smoke``
shrinks the trace for CI. Each engine does one warmup pass (compiles)
and is re-timed on a fresh copy of the trace.
"""
from __future__ import annotations

import jax
import numpy as np

JSON_PATH = "BENCH_serve.json"

ARCH = "internlm2-1.8b"      # dense GQA reduced: exercises bucketing
SLOTS = 4
MAX_LEN = 256
DECODE_CHUNK = 8


def _trace(n_requests: int, vocab: int, long_new: int):
    """70% short prompt+gen, 30% long-gen stragglers (mixed lengths)."""
    rng = np.random.default_rng(0)
    reqs = []
    for i in range(n_requests):
        straggler = i % 3 == 2
        plen = int(rng.integers(24, 90)) if straggler else \
            int(rng.integers(4, 24))
        reqs.append((i, rng.integers(2, vocab, size=plen).astype(
            np.int32), long_new if straggler else 5))
    return reqs


def _run_engine(kind, model, params, trace):
    from repro.serving.engine import Request, make_engine
    engine = make_engine(kind, model, params, batch_slots=SLOTS,
                         max_len=MAX_LEN, decode_chunk=DECODE_CHUNK)

    def submit_all():
        reqs = [Request(rid, prompt, max_new_tokens=mnew)
                for rid, prompt, mnew in trace]
        for r in reqs:
            engine.submit(r)
        return reqs

    warm = submit_all()                  # warmup: pays all compiles
    engine.run_until_drained()
    engine.reset_metrics()
    timed = submit_all()
    engine.run_until_drained()
    assert all(r.done for r in timed)
    return engine.perf_summary(), [r.out_tokens for r in warm]


def _run_swarm(cfg, params, trace, cont_out, smoke):
    """Swarm-serving leg: a K-stage x 2-replica fleet serves a subset
    of the trace through a ``SwarmRouter``; a mid-chain stage holder is
    crashed partway through the timed pass, so the numbers include one
    real failover + re-prefill recovery. Outputs must stay
    bit-identical to the continuous engine's."""
    import tempfile
    import time
    from pathlib import Path

    from repro.checkpointing import (ChunkGossip, ChunkStore,
                                     PeerConnPool)
    from repro.models import registry
    from repro.serving import StageServer, SwarmRouter, publish_stages

    k = 2
    # short-prompt subset: keeps the per-bucket stage compiles cheap
    subset = [(i, prompt, mnew) for i, (rid, prompt, mnew)
              in enumerate(trace) if len(prompt) < 24]
    subset = subset[:4 if smoke else 8]
    stages = registry.make_stages(cfg, k)
    servers, pool, gossip = {}, None, None
    with tempfile.TemporaryDirectory() as td:
        root = Path(td)
        seed_store = ChunkStore(root / "seed")
        publish_stages(seed_store, cfg, params, k)
        try:
            for sid in range(k):
                sp = stages[sid].slice_params(params)
                for r in range(2):
                    srv = StageServer(
                        cfg, ChunkStore(root / f"srv_{sid}_{r}"),
                        k_stages=k, max_len=MAX_LEN)
                    srv.serve_stage(sid, sp)
                    servers[(sid, r)] = srv
            pool = PeerConnPool(timeout=10.0)
            gossip = ChunkGossip([s.addr for s in servers.values()],
                                 timeout=10.0, pool=pool)
            gossip.poll_once()

            def pass_over(tag):
                router = SwarmRouter(k, gossip, timeout=10.0,
                                     pool=pool, max_len=MAX_LEN)
                t0 = time.perf_counter()
                outs = [router.generate(p.tolist(), mnew,
                                        rid=f"{tag}{i}")
                        for i, p, mnew in subset]
                return outs, time.perf_counter() - t0, router.stats

            pass_over("warm")               # pays the stage compiles
            # crash the stage-1 holder the router will pick (lowest
            # address wins), so the timed pass hits a real failover
            picked = min(servers[(1, r)].addr for r in range(2))
            victim = next(s for s in servers.values()
                          if s.addr == picked)
            victim.crash_after = victim.served_chunks + 3
            outs, wall, st = pass_over("t")  # ...during the timed pass
            identical = outs == [cont_out[i] for i, _, _ in subset]
            assert identical, \
                "swarm vs continuous greedy outputs diverged"
            ntok = sum(len(o) for o in outs)
            return {
                "k_stages": k, "replicas": 2,
                "requests": len(subset), "tokens_out": ntok,
                "tokens_per_s": ntok / max(wall, 1e-9),
                "failovers": st["failovers"],
                "recoveries": st["recoveries"],
                "replayed_tokens": st["replayed_tokens"],
                "recovery_latency_s": st["recovery_s"]
                / max(1, st["recoveries"]),
                "pool_reused": pool.stats["reused"],
                "greedy_bit_identical": identical,
            }
        finally:
            if gossip is not None:
                gossip.stop()
            if pool is not None:
                pool.close()
            for s in servers.values():
                s.close()


def _run_paged(cfg, model, params, trace, cont_out, smoke):
    """Paged-KV leg. Two measurements:

    1. the SAME mixed trace on a pool sized to exactly the dense
       engine's per-slot KV budget — outputs must be bit-identical to
       the continuous engine (the paged tier is a layout change, not a
       numerics change);
    2. max concurrent streams at FIXED KV memory: the dense engine's
       budget is ``SLOTS`` slots x ``MAX_LEN`` cells = ``SLOTS``
       streams, period. The paged engine pools the same cell count;
       with a shared system prompt each stream only pins its private
       suffix/decode blocks, so the same bytes hold many more live
       streams (plus a nonzero prefix-hit rate from the shared
       prefix)."""
    import time

    from repro.serving.engine import Request
    from repro.serving.paging import PagedEngine

    paged, paged_out = _run_engine("paged", model, params, trace)
    identical = paged_out == cont_out
    assert identical, "paged vs continuous greedy outputs diverged"

    blk = 16
    budget_blocks = SLOTS * MAX_LEN // blk     # dense KV budget, in blocks
    slots = 32
    n_req = 36 if smoke else 48
    rng = np.random.default_rng(3)
    sys_prompt = rng.integers(2, cfg.vocab, size=48).astype(np.int32)
    eng = PagedEngine(model, params, batch_slots=slots,
                      max_len=MAX_LEN, decode_chunk=DECODE_CHUNK,
                      block_size=blk, pool_blocks=budget_blocks + 1)
    reqs = [Request(i, np.concatenate(
                [sys_prompt,
                 rng.integers(2, cfg.vocab, size=8).astype(np.int32)]),
                max_new_tokens=8) for i in range(n_req)]
    for r in reqs:
        eng.submit(r)
    # peak concurrency is visible between admission and the decode
    # chunk (step() returns the POST-retire count, which is 0 whenever
    # a whole wave finishes within one chunk) — probe the seam
    peak = 0
    seam = eng._before_chunk

    def probe():
        nonlocal peak
        peak = max(peak, sum(r is not None for r in eng.active))
        seam()

    eng._before_chunk = probe
    t0 = time.perf_counter()
    eng.run_until_drained()
    wall = time.perf_counter() - t0
    assert all(r.done for r in reqs)
    s = eng.perf_summary()
    stream_ratio = peak / SLOTS
    # acceptance guardrails: the paged pool must hold >= 4x the dense
    # stream count at the same memory, with real prefix sharing
    assert stream_ratio >= 4.0, \
        f"paged streams {peak} < 4x dense {SLOTS} at equal KV memory"
    assert s["prefix_hit_rate"] > 0.0, "prefix sharing never hit"
    return {
        "trace": paged,
        "greedy_bit_identical": identical,
        "block_size": blk,
        "kv_budget_blocks": budget_blocks,
        "dense_max_streams": SLOTS,
        "max_concurrent_streams": peak,
        "stream_ratio_vs_dense": stream_ratio,
        "shared_prompt_requests": n_req,
        "shared_prompt_tokens_per_s": sum(
            len(r.out_tokens) for r in reqs) / max(wall, 1e-9),
        "prefix_hit_rate": s["prefix_hit_rate"],
        "prefix_hits": s["prefix_hits"],
        "cow_forks": s["cow_forks"],
        "blocks_peak": s["blocks_peak"],
    }


def run_json(smoke: bool = False):
    from repro.configs import CONFIGS
    from repro.models.registry import get_model

    cfg = CONFIGS[ARCH].reduced()
    model = get_model(cfg)
    params, _ = model.init(jax.random.PRNGKey(0))
    trace = _trace(10 if smoke else 30, cfg.vocab,
                   long_new=24 if smoke else 56)

    wave, wave_out = _run_engine("wave", model, params, trace)
    cont, cont_out = _run_engine("continuous", model, params, trace)
    identical = wave_out == cont_out
    # acceptance guardrail, not just a recorded field: a broken
    # equivalence must fail the CI smoke step, not ship green
    assert identical, "wave vs continuous greedy outputs diverged"

    swarm = _run_swarm(cfg, params, trace, cont_out, smoke)
    paged = _run_paged(cfg, model, params, trace, cont_out, smoke)

    speedup = cont["tokens_per_s"] / wave["tokens_per_s"]
    p95_speedup = wave["latency_p95_s"] / cont["latency_p95_s"]
    payload = {"serve": {
        "arch": ARCH, "slots": SLOTS, "max_len": MAX_LEN,
        "decode_chunk": DECODE_CHUNK, "requests": len(trace),
        "smoke": smoke,
        "wave": wave, "continuous": cont,
        "swarm": swarm,
        "paged": paged,
        "tokens_per_s_speedup": speedup,
        "p95_latency_speedup": p95_speedup,
        "greedy_bit_identical": identical,
    }}
    rows = []
    for s in (wave, cont):
        us_per_tok = s["wall_s"] / max(1, s["tokens_out"]) * 1e6
        rows.append(
            f"serve_{s['engine']},{us_per_tok:.1f},"
            f"tok/s={s['tokens_per_s']:.1f} "
            f"p95={s['latency_p95_s'] * 1e3:.0f}ms "
            f"occ={s['slot_occupancy']:.2f}")
    rows.append(f"serve_speedup,0,{speedup:.2f}x_tok/s "
                f"{p95_speedup:.2f}x_p95 bit_identical={identical}")
    rows.append(
        f"serve_swarm,{swarm['recovery_latency_s'] * 1e6:.1f},"
        f"tok/s={swarm['tokens_per_s']:.1f} "
        f"failovers={swarm['failovers']} "
        f"recovery={swarm['recovery_latency_s'] * 1e3:.0f}ms "
        f"bit_identical={swarm['greedy_bit_identical']}")
    pt = paged["trace"]
    rows.append(
        f"serve_paged,{pt['wall_s'] / max(1, pt['tokens_out']) * 1e6:.1f},"
        f"tok/s={pt['tokens_per_s']:.1f} "
        f"streams={paged['max_concurrent_streams']}x"
        f"{paged['dense_max_streams']}dense "
        f"({paged['stream_ratio_vs_dense']:.1f}x) "
        f"prefix_hit={paged['prefix_hit_rate']:.2f} "
        f"cow_forks={paged['cow_forks']} "
        f"bit_identical={paged['greedy_bit_identical']}")
    return rows, payload


def run(smoke: bool = False):
    rows, _ = run_json(smoke=smoke)
    return rows


if __name__ == "__main__":
    for row in run():
        print(row)
