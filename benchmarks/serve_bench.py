"""Continuous vs wave serving benchmark on a mixed-length trace.

The trace mixes short-prompt/short-generation requests with
long-generation stragglers — the workload where wave scheduling
strands slots (a drained request idles until the whole wave finishes)
and per-token host syncs dominate. Both engines run the SAME requests
greedily; outputs must be bit-identical (asserted into the payload), so
the speedup is pure scheduling + sync amortization.

``python -m benchmarks.run serve --json`` writes ``BENCH_serve.json``
(tokens/sec, p50/p95 request latency, slot occupancy, speedups) — the
serving perf-trajectory file future PRs diff against. ``--smoke``
shrinks the trace for CI. Each engine does one warmup pass (compiles)
and is re-timed on a fresh copy of the trace.
"""
from __future__ import annotations

import jax
import numpy as np

JSON_PATH = "BENCH_serve.json"

ARCH = "internlm2-1.8b"      # dense GQA reduced: exercises bucketing
SLOTS = 4
MAX_LEN = 256
DECODE_CHUNK = 8


def _trace(n_requests: int, vocab: int, long_new: int):
    """70% short prompt+gen, 30% long-gen stragglers (mixed lengths)."""
    rng = np.random.default_rng(0)
    reqs = []
    for i in range(n_requests):
        straggler = i % 3 == 2
        plen = int(rng.integers(24, 90)) if straggler else \
            int(rng.integers(4, 24))
        reqs.append((i, rng.integers(2, vocab, size=plen).astype(
            np.int32), long_new if straggler else 5))
    return reqs


def _run_engine(kind, model, params, trace):
    from repro.serving.engine import Request, make_engine
    engine = make_engine(kind, model, params, batch_slots=SLOTS,
                         max_len=MAX_LEN, decode_chunk=DECODE_CHUNK)

    def submit_all():
        reqs = [Request(rid, prompt, max_new_tokens=mnew)
                for rid, prompt, mnew in trace]
        for r in reqs:
            engine.submit(r)
        return reqs

    warm = submit_all()                  # warmup: pays all compiles
    engine.run_until_drained()
    engine.reset_metrics()
    timed = submit_all()
    engine.run_until_drained()
    assert all(r.done for r in timed)
    return engine.perf_summary(), [r.out_tokens for r in warm]


def run_json(smoke: bool = False):
    from repro.configs import CONFIGS
    from repro.models.registry import get_model

    cfg = CONFIGS[ARCH].reduced()
    model = get_model(cfg)
    params, _ = model.init(jax.random.PRNGKey(0))
    trace = _trace(10 if smoke else 30, cfg.vocab,
                   long_new=24 if smoke else 56)

    wave, wave_out = _run_engine("wave", model, params, trace)
    cont, cont_out = _run_engine("continuous", model, params, trace)
    identical = wave_out == cont_out
    # acceptance guardrail, not just a recorded field: a broken
    # equivalence must fail the CI smoke step, not ship green
    assert identical, "wave vs continuous greedy outputs diverged"

    speedup = cont["tokens_per_s"] / wave["tokens_per_s"]
    p95_speedup = wave["latency_p95_s"] / cont["latency_p95_s"]
    payload = {"serve": {
        "arch": ARCH, "slots": SLOTS, "max_len": MAX_LEN,
        "decode_chunk": DECODE_CHUNK, "requests": len(trace),
        "smoke": smoke,
        "wave": wave, "continuous": cont,
        "tokens_per_s_speedup": speedup,
        "p95_latency_speedup": p95_speedup,
        "greedy_bit_identical": identical,
    }}
    rows = []
    for s in (wave, cont):
        us_per_tok = s["wall_s"] / max(1, s["tokens_out"]) * 1e6
        rows.append(
            f"serve_{s['engine']},{us_per_tok:.1f},"
            f"tok/s={s['tokens_per_s']:.1f} "
            f"p95={s['latency_p95_s'] * 1e3:.0f}ms "
            f"occ={s['slot_occupancy']:.2f}")
    rows.append(f"serve_speedup,0,{speedup:.2f}x_tok/s "
                f"{p95_speedup:.2f}x_p95 bit_identical={identical}")
    return rows, payload


def run(smoke: bool = False):
    rows, _ = run_json(smoke=smoke)
    return rows


if __name__ == "__main__":
    for row in run():
        print(row)
