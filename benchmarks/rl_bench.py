"""Asynchronous RL tier benchmark (INTELLECT-2-style rollout loop).

Runs the full fleet — DiLoCo trainer + PolicyPublisher + staggered
rollout workers with one mid-run crash/rejoin — on the toy
verifiable-reward task and records:

  * rollout throughput (tok/s through the logprob-capturing engine),
  * policy propagation (adoption latency, bytes over the delta chain,
    mean accepted staleness in outer steps),
  * the staleness ledger (drop fraction under max_policy_lag),
  * the reward trend, asserted improving in full mode.

``python -m benchmarks.run rl --json`` writes ``BENCH_rl.json``;
``--smoke`` shrinks the run for CI. Bit-exact adoption (every adopted
policy sha == the published anchor's) is an acceptance guardrail in
BOTH modes — a divergence fails the run, it never ships green.
"""
from __future__ import annotations

import tempfile

import numpy as np

JSON_PATH = "BENCH_rl.json"


def _config(smoke: bool):
    from repro.rl import RLConfig
    if smoke:
        return RLConfig(outer_steps=5, inner_steps=2, n_groups=4,
                        group_size=4, max_new=8, inner_lr=2e-2,
                        max_policy_lag=1, adopt_strides=(1, 3),
                        kill_at=1, rejoin_at=2)
    return RLConfig(outer_steps=10, inner_steps=3, n_groups=8,
                    group_size=4, max_new=12, inner_lr=2e-2,
                    max_policy_lag=1, adopt_strides=(1, 3),
                    kill_at=3, rejoin_at=5)


def run_json(smoke: bool = False):
    from repro.rl import RLDriver

    cfg = _config(smoke)
    with tempfile.TemporaryDirectory() as td:
        drv = RLDriver(cfg, td)
        try:
            s = drv.run()
        finally:
            drv.close()

    led = s["ledger"]
    # exact accounting: every generated rollout is accounted for
    assert led["generated"] == led["accepted"] + led["dropped_stale"] \
        + led["evicted_capacity"] + len(drv.buffer), led
    assert led["max_accepted_lag"] <= cfg.max_policy_lag, led
    # acceptance guardrails, not just recorded fields: broken
    # bit-exactness or a non-learning loop must fail the CI step
    assert s["bit_exact"], "adopted policy diverged from published"
    if not smoke:
        r = s["reward_trend"]
        early, late = np.mean(r[:3]), np.mean(r[-3:])
        assert late > early + 0.02, \
            f"reward not improving: {early:.3f} -> {late:.3f} ({r})"

    payload = {"rl": {
        "smoke": smoke,
        "outer_steps": cfg.outer_steps,
        "workers": cfg.n_workers,
        "max_policy_lag": cfg.max_policy_lag,
        "adopt_strides": list(cfg.adopt_strides),
        "kill_at": cfg.kill_at, "rejoin_at": cfg.rejoin_at,
        **{k: s[k] for k in (
            "reward_trend", "reward_first", "reward_last",
            "rollout_tok_s", "rollout_tokens", "ledger",
            "stale_drop_fraction", "mean_accepted_lag", "adoptions",
            "mean_adopt_s", "adopt_bytes", "bit_exact",
            "versions_published", "live_versions")},
    }}
    us_per_tok = 1e6 / max(s["rollout_tok_s"], 1e-9)
    rows = [
        f"rl_rollout,{us_per_tok:.1f},"
        f"tok/s={s['rollout_tok_s']:.1f} "
        f"reward={s['reward_first']:.3f}->{s['reward_last']:.3f} "
        f"bit_exact={s['bit_exact']}",
        f"rl_staleness,0,"
        f"drop_frac={s['stale_drop_fraction']:.2f} "
        f"mean_lag={s['mean_accepted_lag']:.2f} "
        f"max_lag={led['max_accepted_lag']} "
        f"accepted={led['accepted']}/{led['generated']}",
        f"rl_propagation,{s['mean_adopt_s'] * 1e6:.1f},"
        f"adoptions={s['adoptions']} "
        f"bytes={s['adopt_bytes']} "
        f"versions={s['versions_published']}",
    ]
    return rows, payload


def run(smoke: bool = False):
    rows, _ = run_json(smoke=smoke)
    return rows


if __name__ == "__main__":
    for row in run():
        print(row)
