"""Paper Table 2: MFU and compute utilization across geographies.

Wall-clock MFU cannot be measured in this CPU container, so the table is
reconstructed from the paper's own measured anchors + our network model:

  compute_util = inner_phase / (inner_phase + allreduce + outer_cpu)
  MFU          = baseline_MFU x compute_util

The all-reduce time is simulated with the int8 ring over sampled
pairwise bandwidths (per-scenario lognormal), using the
bandwidth-optimized ring order — the same code path the trainer uses.
Verified against the paper's reported 95.7 / 85.6 / 83.0 % utilization.
"""
from __future__ import annotations

import numpy as np

from benchmarks import common
from repro.core import topology
from repro.core.ring_reduce import ring_wire_bytes


def run(seed: int = 0) -> list[str]:
    rng = np.random.default_rng(seed)
    rows = []
    n_params = 10_205_262_848          # INTELLECT-1 10B
    rows.append(common.csv_row(
        "table2/baseline_no_comm_mfu", 0.0,
        f"mfu={common.BASELINE_MFU:.3f};util=1.000"))
    for name, sc in common.SCENARIOS.items():
        times = []
        for _ in range(200):
            w = common.sample_bandwidth_matrix(sc, rng)
            order = topology.optimize_ring_order(w)
            payload = ring_wire_bytes(n_params, sc.n_nodes, "int8")
            times.append(common.ring_allreduce_time_s(
                payload, w, order, sc.latency_ms))
        med = float(np.median(times))
        util = common.INNER_PHASE_S / (
            common.INNER_PHASE_S + med + common.OUTER_CPU_OVERHEAD_S)
        mfu = common.BASELINE_MFU * util
        paper_med = common.ALLREDUCE_MEDIAN_S[name]
        paper_util = common.INNER_PHASE_S / (
            common.INNER_PHASE_S + paper_med
            + common.OUTER_CPU_OVERHEAD_S)
        rows.append(common.csv_row(
            f"table2/{name}", med * 1e6,
            f"allreduce_med_s={med:.0f};util={util:.3f};"
            f"mfu={mfu:.3f};paper_med_s={paper_med:.0f};"
            f"paper_util={paper_util:.3f}"))
    return rows
