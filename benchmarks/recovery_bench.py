"""Checkpoint recovery benchmark (paper §2.4.2 live recovery).

Measures the three things the recovery subsystem exists for:

  * **wire bytes** — flat fp32 snapshot vs chunk-store full snapshot
    (dedup: post-sync ``params`` == ``anchor``) vs int8 and int4 delta
    checkpoints, over a chain of outer steps with heavy-tailed updates;
  * **fetch time** — a joiner recovering the chain over real localhost
    TCP from 1 peer, striped over 4 peers, and striped over 4 peers
    with one peer crashing mid-transfer (reassignment on the live
    path);
  * **overlap** — the tentpole claim: a joiner STREAMS the checkpoint
    (gossip + background chunk streaming + incremental chain replay)
    while the cluster keeps running inner phases, and is admitted at
    the next outer boundary. Reports time-to-ready, the fraction of
    fetch wall-time hidden under compute (``overlap_ratio``), and
    bit-exactness of the streamed restore vs the serving store.

``python -m benchmarks.run recovery --json`` writes
``BENCH_recovery.json`` (the recovery perf-trajectory file future PRs
diff against); ``--smoke`` shrinks the model for CI.
"""
from __future__ import annotations

import pathlib
import tempfile
import time

import numpy as np

from benchmarks import common
from repro.checkpointing import (ChunkPeer, ChunkStore,
                                 DeltaCheckpointer, DeltaConfig,
                                 swarm_fetch)
from repro.checkpointing import delta as delta_mod

N_ELEMS = 1 << 21          # 2M params per component (~8 MiB fp32)
N_ELEMS_SMOKE = 1 << 16
CHAIN = 5                  # base + 4 deltas
CHUNK = 1 << 18


def _chain(rng, n):
    """Post-sync checkpoint trees: params == anchor, heavy-tailed
    outer updates (95% small + 5% spike components)."""
    params = rng.standard_normal(n).astype(np.float32) * 0.02
    mom = np.zeros(n, np.float32)
    for t in range(CHAIN):
        yield {"params": {"w": params.copy()},
               "anchor": {"w": params.copy()},
               "outer_momentum": {"w": mom.copy()},
               "step": np.int32(t)}
        upd = rng.standard_normal(n).astype(np.float32) * 1e-3
        upd += ((rng.random(n) < 0.05)
                * rng.standard_normal(n)).astype(np.float32) * 0.03
        params = params + upd
        mom = 0.9 * mom + upd


def _flat_bytes(tree) -> int:
    from repro.checkpointing.checkpoint import _flatten, leaf_to_bytes
    return sum(len(leaf_to_bytes(a)[0])
               for a in _flatten(tree).values())


def _save_chain(root, trees, codec: str | None):
    """Persist the chain; returns (per-step new_bytes, store)."""
    store = ChunkStore(root, chunk_bytes=CHUNK)
    if codec is None:
        sizes = [store.save_tree(t, tree)["stats"]["new_bytes"]
                 for t, tree in enumerate(trees)]
    else:
        ck = DeltaCheckpointer(store, DeltaConfig(base_every=CHAIN + 1,
                                                  codec=codec))
        sizes = [ck.save(t, tree)["stats"]["new_bytes"]
                 for t, tree in enumerate(trees)]
    return sizes, store


def _timed_fetch(src_root, n_peers: int, crash: bool) -> dict:
    peers = [ChunkPeer(ChunkStore(src_root)) for _ in range(n_peers)]
    if crash:
        peers[0].crash_after = 2
    with tempfile.TemporaryDirectory() as dst:
        t0 = time.perf_counter()
        stats = swarm_fetch([p.addr for p in peers], dst,
                            range_chunks=4)
        dt = time.perf_counter() - t0
    for p in peers:
        p.close()
    return {"seconds": dt, "chunks": stats["chunks_fetched"],
            "bytes": stats["bytes_fetched"],
            "dead_peers": len(stats["dead_peers"]),
            "reassigned_ranges": stats["reassigned_ranges"]}


def _overlap_scenario(smoke: bool = False) -> dict:
    """A joiner streams the checkpoint DURING the cluster's inner
    phases (throttled serving links so the fetch has real wall time)
    and is admitted at the next outer boundary; measures how much of
    the fetch hid under compute."""
    import jax

    from repro.configs import CONFIGS
    from repro.core.diloco import DiLoCoConfig
    from repro.core.fault_tolerance import ClusterSimulator
    from repro.data.pipeline import DataConfig
    from repro.models.registry import get_model
    from repro.train.loop import ElasticTrainer, TrainerConfig

    cfg = CONFIGS["mamba2-130m"].reduced()
    model = get_model(cfg)
    params, _ = model.init(jax.random.PRNGKey(0))
    inner = 2 if smoke else 4
    dcfg = DataConfig(vocab=cfg.vocab, seq_len=32, batch_per_worker=2,
                      total_steps=inner * 16)
    with tempfile.TemporaryDirectory() as td:
        td = pathlib.Path(td)
        tcfg = TrainerConfig(
            diloco=DiLoCoConfig(inner_steps=inner, quant="fp32"),
            inner_lr=1e-3, max_workers=4,
            ckpt_dir=str(td / "cluster"), ckpt_engine="delta",
            ckpt_delta_base_every=2, ckpt_chunk_bytes=1 << 14)
        tr = ElasticTrainer(model, tcfg, dcfg, params,
                            ClusterSimulator([0, 1]))
        tr.run(2)                       # builds base + delta chain
        tr.snapshotter.flush()

        # two serving peers on throttled links (~0.5 ms/chunk), so the
        # fetch takes non-trivial wall time to hide
        peers = [ChunkPeer(tr.ckpt_store, stall_chunks=0,
                           stall_s=0.0005) for _ in range(2)]
        try:
            fetcher = tr.begin_stream_join(
                [p.addr for p in peers], store_root=td / "joiner",
                range_chunks=4)
            t_run0 = time.perf_counter()
            hist = tr.run(5 if smoke else 6)   # cluster keeps training
            t_run1 = time.perf_counter()
            stats = fetcher.wait_ready(timeout=120)
        finally:
            for p in peers:
                p.close()

        joins = [h["stream_join"] for h in hist if "stream_join" in h]
        admitted = bool(joins and joins[0]["admitted"])
        # fraction of the fetch window that ran under the compute
        # window (the paper's overlap claim)
        f0, f1 = stats["t_start"], stats["t_ready"]
        hidden = max(0.0, min(f1, t_run1) - max(f0, t_run0))
        overlap_ratio = hidden / max(f1 - f0, 1e-9)

        # bit-exact: streamed restore == direct restore of that step
        tree, _, _ = fetcher.result()
        truth, _ = delta_mod.restore(tr.ckpt_store,
                                     tr.checkpoint_like(),
                                     step=stats["step"])
        bit_exact = all(
            np.array_equal(np.asarray(a), np.asarray(b))
            for a, b in zip(jax.tree.leaves(tree),
                            jax.tree.leaves(truth)))
        if tr.snapshotter is not None:
            tr.snapshotter.flush()
        return {
            "time_to_ready_s": stats["fetch_seconds"],
            "overlap_ratio": overlap_ratio,
            "hidden_s": hidden,
            "train_window_s": t_run1 - t_run0,
            "chunks": stats["chunks_fetched"],
            "bytes": stats["bytes_fetched"],
            "replayed_on_stream": stats["replayed_on_stream"],
            "rounds": stats["rounds"],
            "admitted_at_boundary": admitted,
            "bit_exact": bit_exact,
        }


def _measure(seed: int = 0, smoke: bool = False) -> dict:
    rng = np.random.default_rng(seed)
    n = N_ELEMS_SMOKE if smoke else N_ELEMS
    trees = list(_chain(rng, n))
    flat_per_step = _flat_bytes(trees[0])

    with tempfile.TemporaryDirectory() as td:
        td = pathlib.Path(td)
        full_sizes, _ = _save_chain(td / "full", trees, None)
        int8_sizes, store8 = _save_chain(td / "d8", trees, "int8")
        int4_sizes, _ = _save_chain(td / "d4", trees, "int4")

        # verify the chain restores before timing fetches of it
        like = trees[-1]
        restored, _ = delta_mod.restore(store8, like)
        fetch = {
            "peers1": _timed_fetch(td / "d8", 1, crash=False),
            "peers4": _timed_fetch(td / "d8", 4, crash=False),
            "peers4_crash1": _timed_fetch(td / "d8", 4, crash=True),
        }

    steady8 = int8_sizes[-1]
    steady4 = int4_sizes[-1]
    return {
        "elements": int(3 * n),
        "chain_len": CHAIN,
        "flat_fp32_bytes_per_step": flat_per_step,
        "store_full_bytes_per_step": full_sizes[-1],
        "delta_int8_bytes_per_step": steady8,
        "delta_int4_bytes_per_step": steady4,
        "reduction_store_full": flat_per_step / max(1, full_sizes[-1]),
        "reduction_delta_int8": flat_per_step / max(1, steady8),
        "reduction_delta_int4": flat_per_step / max(1, steady4),
        "fetch": fetch,
        "overlap": _overlap_scenario(smoke=smoke),
    }


def _rows(m: dict) -> list[str]:
    f = m["fetch"]
    return [
        common.csv_row(
            "recovery/wire_delta_int8", 0.0,
            f"bytes={m['delta_int8_bytes_per_step']};"
            f"vs_flat_fp32={m['reduction_delta_int8']:.1f}x"),
        common.csv_row(
            "recovery/wire_delta_int4", 0.0,
            f"bytes={m['delta_int4_bytes_per_step']};"
            f"vs_flat_fp32={m['reduction_delta_int4']:.1f}x"),
        common.csv_row(
            "recovery/fetch_1peer", f["peers1"]["seconds"] * 1e6,
            f"chunks={f['peers1']['chunks']}"),
        common.csv_row(
            "recovery/fetch_4peers", f["peers4"]["seconds"] * 1e6,
            f"speedup={f['peers1']['seconds'] / f['peers4']['seconds']:.2f}x"),
        common.csv_row(
            "recovery/fetch_4peers_crash1",
            f["peers4_crash1"]["seconds"] * 1e6,
            f"reassigned={f['peers4_crash1']['reassigned_ranges']};"
            f"dead={f['peers4_crash1']['dead_peers']}"),
        common.csv_row(
            "recovery/overlapped_join",
            m["overlap"]["time_to_ready_s"] * 1e6,
            f"overlap_ratio={m['overlap']['overlap_ratio']:.2f};"
            f"hidden_s={m['overlap']['hidden_s']:.3f};"
            f"bit_exact={m['overlap']['bit_exact']};"
            f"admitted={m['overlap']['admitted_at_boundary']}"),
    ]


def run(seed: int = 0, smoke: bool = False) -> list[str]:
    return _rows(_measure(seed, smoke=smoke))


def run_json(seed: int = 0, smoke: bool = False):
    m = _measure(seed, smoke=smoke)
    return _rows(m), {"recovery": m}


JSON_PATH = "BENCH_recovery.json"
