"""Paper Fig. 3/4: distribution of all-reduce completion times across
geographies — variance and right-skew grow with distance — plus the
benefit of bandwidth-aware ring ordering (§2.5) over a fixed ring."""
from __future__ import annotations

import numpy as np

from benchmarks import common
from repro.core import topology
from repro.core.ring_reduce import ring_wire_bytes


def run(seed: int = 0) -> list[str]:
    rng = np.random.default_rng(seed)
    n_params = 10_205_262_848
    rows = []
    for name, sc in common.SCENARIOS.items():
        t_opt, t_fixed = [], []
        payload = ring_wire_bytes(n_params, sc.n_nodes, "int8")
        fixed = tuple(range(sc.n_nodes))
        for _ in range(300):
            w = common.sample_bandwidth_matrix(sc, rng)
            order = topology.optimize_ring_order(w)
            t_opt.append(common.ring_allreduce_time_s(
                payload, w, order, sc.latency_ms))
            t_fixed.append(common.ring_allreduce_time_s(
                payload, w, fixed, sc.latency_ms))
        t_opt, t_fixed = np.array(t_opt), np.array(t_fixed)
        med, p95 = np.median(t_opt), np.percentile(t_opt, 95)
        skew = float((np.mean(t_opt) - med) / np.std(t_opt))
        rows.append(common.csv_row(
            f"fig3/{name}", med * 1e6,
            f"median_s={med:.0f};p95_s={p95:.0f};"
            f"p95_over_median={p95 / med:.2f};right_skew={skew:.2f};"
            f"topo_speedup_vs_fixed="
            f"{np.median(t_fixed) / med:.2f}x"))
    return rows
