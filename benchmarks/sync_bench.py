"""Outer-sync engine benchmark: fused/bucketed SyncEngine vs the seed's
flatten -> quantize -> ring -> unflatten monolith, plus the two PR 5
scenarios:

* ``buckets`` — ``sync_buckets > 1`` changes the wire format (one
  codebook sideband PER sub-bucket) but also gives each sub-bucket its
  own codebook: quality-vs-sideband sweep at a realistic per-worker
  element count, reporting per-worker wire bytes, sideband bytes and
  cosine similarity of the int8-reduced result against the fp32 ring;
* ``overlap`` — the overlapped outer sync end-to-end on the elastic
  trainer: hidden-comm fraction of the ring under the chunked inner
  phase (CommOverlapLedger logical time), delayed-application loss
  trajectory vs the synchronous run, and a worker dying mid-overlap
  recovering through the synchronous fallback bit-consistently
  (two identical runs produce bit-identical anchors);
* ``robust_agg`` — the untrusted-contributor defense (PR 10
  acceptance): an 8-worker cluster with two persistent attackers
  (node 6 alternates nan/signflip, node 7 ships 1e6x updates) run
  with the admission layer lands an anchor BIT-IDENTICAL to a clean
  6-worker cluster's, while the undefended foil is destroyed; the
  clean run records zero false quarantines, and the distributed
  shard_map backend reaches the same admission decisions and the
  same anchor bit-for-bit (subprocess with 8 forced host devices).

The seed path (reproduced verbatim below as ``_seed_*``) re-flattened
the anchor pytree once per worker inside a vmap (plus once more in the
outer apply), materialized the pseudo-gradient before quantizing, ran
the ring simulation as O(k^2) per-hop Python loops over ``jnp.stack``
copies of the full stacked accumulator, and dequantized + accumulated
in two passes. The SyncEngine path keeps a persistent flat fp32 anchor,
quantizes the first hop straight off (anchor, theta), accumulates with
the fused decode+add, and runs workers under ``vmap`` / hops under
``fori_loop``.

``python -m benchmarks.run sync --json`` writes ``BENCH_sync.json``
(the perf trajectory future PRs diff against); ``--smoke`` shrinks the
element counts and trainer runs for CI.
"""
from __future__ import annotations

import time

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks import common
from repro.core import diloco as dl
from repro.core import ring_reduce as rr
from repro.kernels import ops as qops
from repro.kernels.ref import NUM_BUCKETS
from repro.optim.nesterov import NesterovState

N_ELEMS = 1 << 24           # 16.8M params (~64 MiB fp32)
N_ELEMS_SMOKE = 1 << 18
N_BUCKET_ELEMS = 1 << 22    # ring-only sweep: 4.2M params
N_WORKERS = 4


# -- seed path, reproduced verbatim (pre-SyncEngine) -------------------------


def _seed_flatten_pytree(tree):
    leaves, treedef = jax.tree.flatten(tree)
    shapes = [l.shape for l in leaves]
    sizes = [l.size for l in leaves]
    flat = jnp.concatenate(
        [l.reshape(-1).astype(jnp.float32) for l in leaves]) \
        if leaves else jnp.zeros((0,), jnp.float32)

    def unflatten(vec, like=None):
        out, off = [], 0
        ref_leaves = jax.tree.leaves(like) if like is not None else leaves
        for s, shp, ref in zip(sizes, shapes, ref_leaves):
            out.append(vec[off:off + s].reshape(shp).astype(ref.dtype))
            off += s
        return jax.tree.unflatten(treedef, out)

    return flat, unflatten


def _seed_pad_to_chunks(x, n):
    size = x.shape[-1]
    chunk = -(-size // n)
    pad = n * chunk - size
    if pad:
        x = jnp.pad(x, [(0, 0)] * (x.ndim - 1) + [(0, pad)])
    return x, chunk


def _seed_get_chunk(acc, idx, chunk):
    return jax.lax.dynamic_slice_in_dim(acc, idx * chunk, chunk, axis=-1)


def _seed_set_chunk(acc, idx, val, chunk):
    return jax.lax.dynamic_update_slice_in_dim(acc, val, idx * chunk,
                                               axis=-1)


def _seed_tx_quant(val):
    q = qops.quantize(val, impl="jnp")
    return tuple(q), lambda p: qops.dequantize(qops.Quantized(*p),
                                               impl="jnp")


def _seed_simulate_ring(xs):
    """Seed ``simulate_ring_all_reduce`` (int8, identity order)."""
    k, orig_size = xs.shape
    xs = xs.astype(jnp.float32)
    weights = jnp.ones((k,), jnp.float32)
    total_w = jnp.sum(weights)
    accs = jnp.stack([xs[p] * weights[p] for p in range(k)])
    accs, chunk = _seed_pad_to_chunks(accs, k)

    def quant_chunks(vals):
        payloads, deqs = [], []
        for p in range(k):
            pay, deq = _seed_tx_quant(vals[p])
            payloads.append(pay)
            deqs.append(deq)
        return payloads, deqs

    for s in range(k - 1):
        sends = [_seed_get_chunk(accs[p], (p - s) % k, chunk)
                 for p in range(k)]
        payloads, deqs = quant_chunks(sends)
        new = []
        for p in range(k):
            src = (p - 1) % k
            recv_idx = (p - s - 1) % k
            val = _seed_get_chunk(accs[p], recv_idx, chunk) + deqs[src](
                payloads[src])
            new.append(_seed_set_chunk(accs[p], recv_idx, val, chunk))
        accs = jnp.stack(new)

    sends = [_seed_get_chunk(accs[p], (p + 1) % k, chunk)
             for p in range(k)]
    payloads, deqs = quant_chunks(sends)
    accs = jnp.stack([
        _seed_set_chunk(accs[p], (p + 1) % k, deqs[p](payloads[p]), chunk)
        for p in range(k)])
    bufs, buf_deqs = payloads, deqs
    for s in range(k - 1):
        nbufs = [bufs[(p - 1) % k] for p in range(k)]
        ndeqs = [buf_deqs[(p - 1) % k] for p in range(k)]
        accs = jnp.stack([
            _seed_set_chunk(accs[p], (p - s) % k, ndeqs[p](nbufs[p]),
                            chunk) for p in range(k)])
        bufs, buf_deqs = nbufs, ndeqs
    return accs[..., :orig_size] / jnp.maximum(total_w, 1e-20)


def _seed_outer_sync_sim(stacked_params, state, cfg):
    k = jax.tree.leaves(stacked_params)[0].shape[0]

    def per_worker(params_i):
        p_flat, _ = _seed_flatten_pytree(params_i)
        a_flat, _ = _seed_flatten_pytree(state.anchor)
        return a_flat - p_flat

    pgs = jax.vmap(per_worker)(stacked_params)
    reduced = _seed_simulate_ring(pgs)
    any_params = jax.tree.map(lambda p: p[0], stacked_params)
    delta = _seed_flatten_pytree(state.anchor)[1](
        reduced[0], like=state.anchor)
    new_anchor, new_opt = cfg.outer_opt.update(delta, state.opt,
                                               state.anchor)
    new_params = jax.tree.map(
        lambda a, p: a.astype(p.dtype), new_anchor, any_params)
    stacked_new = jax.tree.map(
        lambda p: jnp.broadcast_to(p[None], (k,) + p.shape), new_params)
    return stacked_new, state._replace(anchor=new_anchor, opt=new_opt)


# -- harness -----------------------------------------------------------------


def _model(rng, n=N_ELEMS):
    """8-leaf pytree totalling n elements (flatten is part of the cost)."""
    per = n // 8
    return {f"w{i}": jnp.asarray(rng.normal(size=(per,)) * 0.02,
                                 jnp.float32) for i in range(8)}


def _drift(params, k):
    return jax.tree.map(
        lambda a: jnp.stack([a * (1 + 0.01 * i) for i in range(k)]),
        params)


def _time(fn, iters=2):
    jax.block_until_ready(fn())  # warmup / op-cache fill
    t0 = time.perf_counter()
    for _ in range(iters):
        jax.block_until_ready(fn())
    return (time.perf_counter() - t0) / iters


def _bucket_quality(seed: int, smoke: bool) -> list[dict]:
    """Quality-vs-sideband sweep over ``sync_buckets`` (PR 1 follow-up):
    per-bucket codebooks cost 4*256 B of sideband per sub-bucket per
    chunk-hop but quantize each sub-chunk against its OWN histogram, so
    heavy-tailed pseudo-gradients lose less to clipping."""
    n = N_ELEMS_SMOKE if smoke else N_BUCKET_ELEMS
    k = N_WORKERS
    rng = np.random.default_rng(seed)
    # heavy-tailed pseudo-gradients (95% small + 5% spikes), the same
    # shape the recovery bench uses for outer updates
    pgs = rng.standard_normal((k, n)).astype(np.float32) * 1e-3
    pgs += ((rng.random((k, n)) < 0.05)
            * rng.standard_normal((k, n))).astype(np.float32) * 0.03
    pgs = jnp.asarray(pgs)
    ref = rr.simulate_ring_all_reduce(
        pgs, cfg=rr.RingConfig(quant="fp32"))[0]
    ref = np.asarray(ref, np.float64)
    out = []
    for buckets in (1, 2, 4, 8):
        got = rr.simulate_ring_all_reduce(
            pgs, cfg=rr.RingConfig(quant="int8", buckets=buckets))[0]
        got = np.asarray(got, np.float64)
        cos = float(np.dot(ref, got)
                    / max(np.linalg.norm(ref) * np.linalg.norm(got),
                          1e-30))
        wire = rr.ring_wire_bytes(n, k, "int8", buckets=buckets)
        out.append({
            "buckets": buckets,
            "wire_bytes_per_worker": wire,
            "sideband_bytes_per_worker":
                2 * (k - 1) * 4 * NUM_BUCKETS * buckets,
            "sideband_frac": 2 * (k - 1) * 4 * NUM_BUCKETS * buckets
                / wire,
            "cosine_vs_fp32": cos,
            "rmse_vs_fp32": float(np.sqrt(np.mean((ref - got) ** 2))),
        })
    return out


def _make_trainer(overlap: str, chunks: int, inner: int, events=(),
                  workers: int = 3, max_workers: int = 4,
                  validation=None):
    import jax as _jax

    from repro.configs import CONFIGS
    from repro.core.fault_tolerance import ClusterSimulator
    from repro.data.pipeline import DataConfig
    from repro.models.registry import get_model
    from repro.train.loop import ElasticTrainer, TrainerConfig

    cfg = CONFIGS["mamba2-130m"].reduced()
    model = get_model(cfg)
    params, _ = model.init(_jax.random.PRNGKey(0))
    dcfg = DataConfig(vocab=cfg.vocab, seq_len=32, batch_per_worker=2,
                      total_steps=inner * 32)
    tcfg = TrainerConfig(
        diloco=dl.DiLoCoConfig(inner_steps=inner, quant="int8",
                               overlap=overlap),
        inner_lr=3e-3, max_workers=max_workers, inner_chunks=chunks,
        validation=validation)
    return ElasticTrainer(model, tcfg, dcfg, params,
                          ClusterSimulator(list(range(workers)),
                                           events=list(events)))


def _overlap_scenario(seed: int, smoke: bool) -> dict:
    """End-to-end overlapped outer sync on the elastic trainer (the
    acceptance scenario): hops of the in-flight ring dispatched between
    inner scan chunks, reduced pseudo-gradient applied one phase late,
    a worker dying mid-overlap recovered via the synchronous fallback."""
    from repro.core.fault_tolerance import EventKind, NodeEvent

    # the sim rings over all max_workers slots: hops = 2*(slots-1).
    # inner_chunks >= hops + 1 dispatches the whole ring before the
    # boundary, so steady-state windows hide ~100% of the ring.
    if smoke:
        workers, slots, inner, chunks, steps = 2, 3, 5, 5, 4
    else:
        workers, slots, inner, chunks, steps = 3, 4, 8, 8, 8

    def losses(tr):
        return [h["loss"] for h in tr.history]

    def anchor_eval(tr):
        """Loss of the FINAL anchor on a fixed held-out batch: after
        the end-of-run drain both schedules have applied the same
        number of outer updates, so this is the apples-to-apples
        trajectory endpoint (the per-phase loss traces are offset by
        one boundary by construction)."""
        import jax as _jax
        # a held-out FUTURE batch from the same token pipeline (both
        # trainers share data config + slot): same distribution, never
        # trained on by either run
        batch = tr._pipeline(0).batch_at(10_000)
        anchor = _jax.tree.map(
            lambda a: a.astype(jnp.float32), tr.outer.anchor)
        loss, _ = tr.model.loss(anchor, batch)
        return float(loss)

    t0 = time.perf_counter()
    tr_sync = _make_trainer("none", 1, inner, workers=workers,
                            max_workers=slots)
    tr_sync.run(steps)
    t_sync = time.perf_counter() - t0

    t0 = time.perf_counter()
    tr_del = _make_trainer("delayed", chunks, inner, workers=workers,
                           max_workers=slots)
    tr_del.run(steps)
    t_del = time.perf_counter() - t0

    led = tr_del.comm_ledger
    # the last record is the end-of-run drain (no next phase to hide
    # under); steady-state windows are the paper's operating regime
    steady = led.records[:-1] if len(led.records) > 1 else led.records
    s_total = sum(r["comm_total_s"] for r in steady)
    s_hidden = sum(r["comm_hidden_s"] for r in steady)
    ls, ld = losses(tr_sync), losses(tr_del)
    # delayed applies each reduction one phase late: compare the
    # trajectory shifted by one boundary, plus the anchor endpoints
    # (same number of applied updates once the drain lands)
    shifted = [abs(d - s) / max(abs(s), 1e-9)
               for d, s in zip(ld[1:], ls[:-1])]
    ev_sync, ev_del = anchor_eval(tr_sync), anchor_eval(tr_del)

    # worker death mid-overlap: node 1 crashes at step 2 while the
    # step-1 boundary's reduction is on the wire -> torn -> synchronous
    # re-reduction over the survivors. Bit-consistency: two identical
    # runs land bit-identical anchors.
    ev = [NodeEvent(2, EventKind.CRASH, 1)]
    tr_c1 = _make_trainer("delayed", chunks, inner, events=ev,
                          workers=workers, max_workers=slots)
    h_c1 = tr_c1.run(steps)
    tr_c2 = _make_trainer("delayed", chunks, inner, events=ev,
                          workers=workers, max_workers=slots)
    tr_c2.run(steps)
    fallbacks = [h["sync_fallback"] for h in h_c1
                 if "sync_fallback" in h]
    bit_consistent = bool(jnp.array_equal(tr_c1.outer.anchor_flat,
                                          tr_c2.outer.anchor_flat))

    return {
        "workers": workers, "slots": slots, "inner_steps": inner,
        "inner_chunks": chunks, "outer_steps": steps,
        "ring_hops": 2 * (slots - 1),
        "hidden_frac_steady": s_hidden / s_total if s_total else 1.0,
        "hidden_frac_with_drain": led.hidden_fraction,
        "comm_windows": len(led.records),
        "loss_sync": ls, "loss_delayed": ld,
        "loss_shifted_reldiff_max": max(shifted) if shifted else 0.0,
        "final_loss_sync": ls[-1], "final_loss_delayed": ld[-1],
        "anchor_eval_sync": ev_sync, "anchor_eval_delayed": ev_del,
        "anchor_eval_reldiff": abs(ev_del - ev_sync)
            / max(abs(ev_sync), 1e-9),
        "loss_decreased": ld[-1] < ld[0],
        "wall_s_sync": t_sync, "wall_s_delayed": t_del,
        "death_mid_overlap": {
            "fallbacks": fallbacks,
            "recovered": bool(fallbacks
                              and np.isfinite(h_c1[-1]["loss"])),
            "bit_consistent": bit_consistent,
        },
    }


def _overlap_distributed(seed: int, smoke: bool) -> dict:
    """The DISTRIBUTED overlap acceptance scenario, in a subprocess
    with 8 forced host devices (this process already initialized jax
    with one): an ElasticTrainer synced through DistSyncBackend's
    per-hop shard_map collectives under a stable NON-uniform bandwidth
    matrix. Checks: steady-state hidden fraction, exactly one justified
    ring reorder (+ recompile) off the slow link, bit-identity to the
    simulator trainer, and ZERO spurious reorders on a fully-observed
    uniform matrix."""
    import json as _json
    import pathlib
    import subprocess
    import sys
    import textwrap

    src = str(pathlib.Path(__file__).resolve().parents[1] / "src")
    if smoke:
        k, inner, chunks, steps = 4, 5, 7, 4
    else:
        k, inner, chunks, steps = 4, 8, 8, 6
    prog = textwrap.dedent("""
        import os
        os.environ["XLA_FLAGS"] = \
            "--xla_force_host_platform_device_count=8"
        import sys, json
        sys.path.insert(0, {src!r})
        import jax, numpy as np, jax.numpy as jnp
        from repro import compat
        from repro.configs import CONFIGS
        from repro.core import diloco as dl
        from repro.core.fault_tolerance import ClusterSimulator
        from repro.data.pipeline import DataConfig
        from repro.models.registry import get_model
        from repro.train import step as ts
        from repro.train.loop import ElasticTrainer, TrainerConfig

        K, INNER, CHUNKS, STEPS = {k}, {inner}, {chunks}, {steps}

        def make_trainer(backend=None):
            cfg = CONFIGS["mamba2-130m"].reduced()
            model = get_model(cfg)
            params, _ = model.init(jax.random.PRNGKey(0))
            dcfg = DataConfig(vocab=cfg.vocab, seq_len=32,
                              batch_per_worker=2,
                              total_steps=INNER * 32)
            tcfg = TrainerConfig(
                diloco=dl.DiLoCoConfig(inner_steps=INNER,
                                       quant="int8",
                                       overlap="delayed"),
                inner_lr=3e-3, max_workers=K, inner_chunks=CHUNKS)
            return ElasticTrainer(model, tcfg, dcfg, params,
                                  ClusterSimulator(list(range(K))),
                                  sync_backend=backend)

        # stable, fully observed, NON-uniform links (Gb/s): the
        # identity ring crosses the slow 0-1 edge; the max-min
        # solver routes around it -> exactly one justified reorder
        m = np.full((K, K), 4.0)
        np.fill_diagonal(m, 0.0)
        m[0, 1] = m[1, 0] = 0.25
        sampler = lambda t: m

        mesh = compat.make_mesh(
            (K,), ("data",), devices=np.asarray(jax.devices())[:K])
        backend = ts.DistSyncBackend(mesh, "data")
        tr = make_trainer(backend=backend)
        tr.run(STEPS, bandwidth_sampler=sampler)
        led = tr.comm_ledger
        steady = (led.records[:-1] if len(led.records) > 1
                  else led.records)
        s_total = sum(r["comm_total_s"] for r in steady)
        s_hidden = sum(r["comm_hidden_s"] for r in steady)

        tr_sim = make_trainer()
        tr_sim.run(STEPS, bandwidth_sampler=sampler)
        bit = bool(jnp.array_equal(tr.outer.anchor_flat,
                                   tr_sim.outer.anchor_flat))

        # fully observed UNIFORM matrix: the identity ring already
        # achieves the max-min bottleneck -> zero reorders allowed
        m2 = np.full((K, K), 4.0)
        np.fill_diagonal(m2, 0.0)
        tr2 = make_trainer(backend=ts.DistSyncBackend(mesh, "data"))
        tr2.run(STEPS, bandwidth_sampler=lambda t: m2)

        slow = set(zip(tr.ring_order,
                       tr.ring_order[1:] + tr.ring_order[:1]))
        print(json.dumps({{
            "workers": K, "inner_chunks": CHUNKS,
            "outer_steps": STEPS,
            "hidden_frac_steady":
                s_hidden / s_total if s_total else 1.0,
            "hidden_frac_with_drain": led.hidden_fraction,
            "reorders": tr.reorders,
            "recompiles": backend.recompiles,
            "ring_order": list(tr.ring_order),
            "slow_link_avoided": (0, 1) not in slow
                and (1, 0) not in slow,
            "bit_identical_to_sim": bit,
            "spurious_reorders_stable": tr2.reorders,
        }}))
    """).format(src=src, k=k, inner=inner, chunks=chunks, steps=steps)
    out = subprocess.run([sys.executable, "-c", prog],
                         capture_output=True, text=True, timeout=900)
    assert out.returncode == 0, out.stderr[-3000:]
    return _json.loads(out.stdout.strip().splitlines()[-1])


def _robust_poison_events(steps: int):
    from repro.core.fault_tolerance import EventKind, NodeEvent

    mode = ["nan", "signflip"]
    return [NodeEvent(t, EventKind.POISON, 6, arg=mode[t % 2])
            for t in range(steps)] + \
           [NodeEvent(t, EventKind.POISON, 7, arg="huge")
            for t in range(steps)]


def _robust_agg_scenario(seed: int, smoke: bool) -> dict:
    """Untrusted-contributor defense end-to-end: 2-of-8 workers ship
    poisoned pseudo-gradients every boundary (node 6 alternates
    nan/signflip, node 7 sends 1e6x-norm updates). Defended run vs
    clean 6-worker run (must be bit-identical — quarantined slots are
    indistinguishable from never-filled slots), vs undefended foil
    (must diverge). Clean run doubles as the false-positive probe."""
    from repro.core import validation as vd

    inner, steps = (2, 3) if smoke else (3, 4)
    ev = _robust_poison_events(steps)

    t0 = time.perf_counter()
    defended = _make_trainer("none", 1, inner, events=ev, workers=8,
                             max_workers=8,
                             validation=vd.ValidationConfig())
    defended.run(steps)
    t_def = time.perf_counter() - t0

    t0 = time.perf_counter()
    clean = _make_trainer("none", 1, inner, workers=6, max_workers=8,
                          validation=vd.ValidationConfig())
    clean.run(steps)
    t_clean = time.perf_counter() - t0

    t0 = time.perf_counter()
    undefended = _make_trainer("none", 1, inner, events=ev, workers=8,
                               max_workers=8)
    undefended.run(steps)
    t_undef = time.perf_counter() - t0

    ad = np.asarray(defended.outer.anchor_flat)
    ac = np.asarray(clean.outer.anchor_flat)
    au = np.asarray(undefended.outer.anchor_flat)
    first = (defended.quarantine_events[0]
             if defended.quarantine_events else None)
    return {
        "workers": 8, "poisoned_nodes": [6, 7], "inner_steps": inner,
        "outer_steps": steps,
        "defended_matches_clean_bitwise": bool(np.array_equal(ad, ac)),
        "defended_anchor_finite": bool(np.isfinite(ad).all()),
        "undefended_anchor_finite": bool(np.isfinite(au).all()),
        "false_quarantines_clean": len(clean.quarantine_events),
        "false_violations_clean": len(clean.sim.violations),
        "first_catch_step": first["outer_step"] if first else None,
        "first_catch_nodes": sorted(first["quarantined"])
            if first else [],
        "violating_nodes": sorted({v[1]
                                   for v in defended.sim.violations}),
        "requarantines_node6":
            int(defended.sim.hb.nodes[6].quarantines),
        # admission overhead: defended wall over the undefended same-
        # size run (gates + one extra restart-reduce per rejection)
        "wall_s_defended": t_def, "wall_s_undefended": t_undef,
        "wall_s_clean": t_clean,
        "admission_overhead_frac": (t_def - t_undef)
            / max(t_undef, 1e-9),
        "distributed": _robust_distributed(seed, smoke),
    }


def _robust_distributed(seed: int, smoke: bool) -> dict:
    """The DISTRIBUTED half of the robust_agg acceptance, in a
    subprocess with 8 forced host devices: the same poisoned schedule
    through DistSyncBackend's per-hop shard_map collectives. The
    admission gates judge host-side float64 copies of the staged rows
    plus the chunk-norm sideband, so the backend must reach the SAME
    quarantine decisions and the SAME anchor, bit-for-bit, as the
    single-device simulator trainer."""
    import json as _json
    import pathlib
    import subprocess
    import sys
    import textwrap

    src = str(pathlib.Path(__file__).resolve().parents[1] / "src")
    inner, steps = (2, 3) if smoke else (3, 4)
    prog = textwrap.dedent("""
        import os
        os.environ["XLA_FLAGS"] = \
            "--xla_force_host_platform_device_count=8"
        import sys, json
        sys.path.insert(0, {src!r})
        import jax, numpy as np, jax.numpy as jnp
        from repro import compat
        from repro.configs import CONFIGS
        from repro.core import diloco as dl
        from repro.core import validation as vd
        from repro.core.fault_tolerance import (ClusterSimulator,
                                                EventKind, NodeEvent)
        from repro.data.pipeline import DataConfig
        from repro.models.registry import get_model
        from repro.train import step as ts
        from repro.train.loop import ElasticTrainer, TrainerConfig

        K, INNER, STEPS = 8, {inner}, {steps}
        MODE = ["nan", "signflip"]

        def events():
            return ([NodeEvent(t, EventKind.POISON, 6,
                               arg=MODE[t % 2]) for t in range(STEPS)]
                    + [NodeEvent(t, EventKind.POISON, 7, arg="huge")
                       for t in range(STEPS)])

        def make_trainer(backend=None):
            cfg = CONFIGS["mamba2-130m"].reduced()
            model = get_model(cfg)
            params, _ = model.init(jax.random.PRNGKey(0))
            dcfg = DataConfig(vocab=cfg.vocab, seq_len=32,
                              batch_per_worker=2,
                              total_steps=INNER * 32)
            tcfg = TrainerConfig(
                diloco=dl.DiLoCoConfig(inner_steps=INNER,
                                       quant="int8"),
                inner_lr=3e-3, max_workers=K,
                validation=vd.ValidationConfig())
            return ElasticTrainer(model, tcfg, dcfg, params,
                                  ClusterSimulator(list(range(K)),
                                                   events=events()),
                                  sync_backend=backend)

        mesh = compat.make_mesh(
            (K,), ("data",), devices=np.asarray(jax.devices())[:K])
        tr = make_trainer(backend=ts.DistSyncBackend(mesh, "data"))
        tr.run(STEPS)
        tr_sim = make_trainer()
        tr_sim.run(STEPS)

        def decisions(t):
            return [[e["outer_step"], sorted(e["quarantined"]),
                     sorted((s, sorted(r))
                            for s, r in e["flagged"].items())]
                    for e in t.quarantine_events]

        print(json.dumps({{
            "bit_identical_to_sim": bool(jnp.array_equal(
                tr.outer.anchor_flat, tr_sim.outer.anchor_flat)),
            "decisions_identical":
                decisions(tr) == decisions(tr_sim)
                and tr.sim.violations == tr_sim.sim.violations,
            "anchor_finite": bool(
                jnp.isfinite(tr.outer.anchor_flat).all()),
            "quarantined_nodes":
                sorted({{v[1] for v in tr.sim.violations}}),
        }}))
    """).format(src=src, inner=inner, steps=steps)
    out = subprocess.run([sys.executable, "-c", prog],
                         capture_output=True, text=True, timeout=900)
    assert out.returncode == 0, out.stderr[-3000:]
    return _json.loads(out.stdout.strip().splitlines()[-1])


def _measure(seed: int = 0, smoke: bool = False) -> dict:
    rng = np.random.default_rng(seed)
    params = _model(rng, N_ELEMS_SMOKE if smoke else N_ELEMS)
    stacked = _drift(params, N_WORKERS)
    cfg = dl.DiLoCoConfig(quant="int8", sync_buckets=2)
    st = dl.init_outer_state_sim(params, cfg, N_WORKERS)

    t_fused = _time(lambda: dl.outer_sync_sim(stacked, st, cfg)[1]
                    .anchor_flat)
    t_seed = _time(lambda: _seed_outer_sync_sim(stacked, st, cfg)[1]
                   .anchor["w0"])

    n = sum(l.size for l in jax.tree.leaves(params))
    # analytic full-model HBM round-trips around the ring (per outer
    # step, per worker; the ring's chunk traffic itself is identical):
    #   seed : anchor flatten inside vmap (k reads + k writes of the
    #          anchor) + theta flatten + pg materialize + anchor
    #          re-flatten in apply + delta unflatten + tree-map outer
    #   fused: theta flatten + pg subtract off the persistent buffer +
    #          momentum flatten + 3 unflattens (anchor/momentum/params)
    hbm = {"seed_anchor_flattens_per_step": N_WORKERS + 1,
           "fused_anchor_flattens_per_step": 0,
           "seed_ring_stack_copies": 2 * (N_WORKERS - 1) + 2,
           "fused_ring_stack_copies": 0}
    return {
        "elements": int(n),
        "workers": N_WORKERS,
        "quant": cfg.quant,
        "sync_buckets": cfg.sync_buckets,
        "fused_outer_sync_s": t_fused,
        "seed_outer_sync_s": t_seed,
        "speedup": t_seed / t_fused,
        "wire_bytes_per_worker": dl.sync_wire_bytes(
            params, N_WORKERS, cfg),
        "hbm_passes": hbm,
        "buckets": _bucket_quality(seed, smoke),
        "overlap": _overlap_scenario(seed, smoke),
        "overlap_distributed": _overlap_distributed(seed, smoke),
        "robust_agg": _robust_agg_scenario(seed, smoke),
    }


def _rows(m: dict) -> list[str]:
    ov = m["overlap"]
    od = m["overlap_distributed"]
    ra = m["robust_agg"]
    rd = ra["distributed"]
    best = max(m["buckets"], key=lambda b: b["cosine_vs_fp32"])
    return [
        common.csv_row("sync/outer_sync_fused", m["fused_outer_sync_s"]
                       * 1e6, f"elems={m['elements']};k={m['workers']};"
                       f"buckets={m['sync_buckets']}"),
        common.csv_row("sync/outer_sync_seed_path",
                       m["seed_outer_sync_s"] * 1e6,
                       f"speedup_fused={m['speedup']:.2f}x"),
        common.csv_row("sync/wire_bytes", 0.0,
                       f"per_worker_bytes={m['wire_bytes_per_worker']}"),
        common.csv_row(
            "sync/buckets_quality", 0.0,
            ";".join(f"B={b['buckets']}:cos={b['cosine_vs_fp32']:.6f}"
                     f":side={b['sideband_bytes_per_worker']}"
                     for b in m["buckets"])
            + f";best=B={best['buckets']}"),
        common.csv_row(
            "sync/overlap_hidden", 0.0,
            f"hidden_steady={ov['hidden_frac_steady']:.2f};"
            f"hidden_with_drain={ov['hidden_frac_with_drain']:.2f};"
            f"hops={ov['ring_hops']};chunks={ov['inner_chunks']}"),
        common.csv_row(
            "sync/overlap_delayed_loss", 0.0,
            f"anchor_eval_sync={ov['anchor_eval_sync']:.4f};"
            f"anchor_eval_delayed={ov['anchor_eval_delayed']:.4f};"
            f"reldiff={ov['anchor_eval_reldiff']:.3f};"
            f"shifted_traj_reldiff_max="
            f"{ov['loss_shifted_reldiff_max']:.3f}"),
        common.csv_row(
            "sync/overlap_death_fallback", 0.0,
            f"recovered={ov['death_mid_overlap']['recovered']};"
            f"bit_consistent="
            f"{ov['death_mid_overlap']['bit_consistent']}"),
        common.csv_row(
            "sync/overlap_distributed", 0.0,
            f"hidden_steady={od['hidden_frac_steady']:.2f};"
            f"reorders={od['reorders']};"
            f"recompiles={od['recompiles']};"
            f"spurious_stable={od['spurious_reorders_stable']};"
            f"bit_identical={od['bit_identical_to_sim']}"),
        common.csv_row(
            "sync/robust_agg", 0.0,
            f"defended_matches_clean="
            f"{ra['defended_matches_clean_bitwise']};"
            f"undefended_finite={ra['undefended_anchor_finite']};"
            f"false_quarantines={ra['false_quarantines_clean']};"
            f"first_catch_step={ra['first_catch_step']};"
            f"caught={ra['first_catch_nodes']};"
            f"overhead_frac={ra['admission_overhead_frac']:.2f}"),
        common.csv_row(
            "sync/robust_agg_distributed", 0.0,
            f"bit_identical={rd['bit_identical_to_sim']};"
            f"decisions_identical={rd['decisions_identical']};"
            f"quarantined={rd['quarantined_nodes']}"),
    ]


def run(seed: int = 0, smoke: bool = False) -> list[str]:
    return _rows(_measure(seed, smoke=smoke))


def run_json(seed: int = 0, smoke: bool = False):
    m = _measure(seed, smoke=smoke)
    return _rows(m), {"sync": m}
