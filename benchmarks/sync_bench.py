"""Outer-sync engine benchmark: fused/bucketed SyncEngine vs the seed's
flatten -> quantize -> ring -> unflatten monolith.

The seed path (reproduced verbatim below as ``_seed_*``) re-flattened
the anchor pytree once per worker inside a vmap (plus once more in the
outer apply), materialized the pseudo-gradient before quantizing, ran
the ring simulation as O(k^2) per-hop Python loops over ``jnp.stack``
copies of the full stacked accumulator, and dequantized + accumulated
in two passes. The SyncEngine path keeps a persistent flat fp32 anchor,
quantizes the first hop straight off (anchor, theta), accumulates with
the fused decode+add, and runs workers under ``vmap`` / hops under
``fori_loop``.

Reports XLA:CPU wall time for a >=16M-element model, per-worker wire
bytes, and the analytic count of full-model HBM round-trips on each
path. ``python -m benchmarks.run sync --json`` additionally writes
``BENCH_sync.json`` so future PRs have a perf trajectory.
"""
from __future__ import annotations

import time

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks import common
from repro.core import diloco as dl
from repro.kernels import ops as qops
from repro.optim.nesterov import NesterovState

N_ELEMS = 1 << 24           # 16.8M params (~64 MiB fp32)
N_WORKERS = 4


# -- seed path, reproduced verbatim (pre-SyncEngine) -------------------------


def _seed_flatten_pytree(tree):
    leaves, treedef = jax.tree.flatten(tree)
    shapes = [l.shape for l in leaves]
    sizes = [l.size for l in leaves]
    flat = jnp.concatenate(
        [l.reshape(-1).astype(jnp.float32) for l in leaves]) \
        if leaves else jnp.zeros((0,), jnp.float32)

    def unflatten(vec, like=None):
        out, off = [], 0
        ref_leaves = jax.tree.leaves(like) if like is not None else leaves
        for s, shp, ref in zip(sizes, shapes, ref_leaves):
            out.append(vec[off:off + s].reshape(shp).astype(ref.dtype))
            off += s
        return jax.tree.unflatten(treedef, out)

    return flat, unflatten


def _seed_pad_to_chunks(x, n):
    size = x.shape[-1]
    chunk = -(-size // n)
    pad = n * chunk - size
    if pad:
        x = jnp.pad(x, [(0, 0)] * (x.ndim - 1) + [(0, pad)])
    return x, chunk


def _seed_get_chunk(acc, idx, chunk):
    return jax.lax.dynamic_slice_in_dim(acc, idx * chunk, chunk, axis=-1)


def _seed_set_chunk(acc, idx, val, chunk):
    return jax.lax.dynamic_update_slice_in_dim(acc, val, idx * chunk,
                                               axis=-1)


def _seed_tx_quant(val):
    q = qops.quantize(val, impl="jnp")
    return tuple(q), lambda p: qops.dequantize(qops.Quantized(*p),
                                               impl="jnp")


def _seed_simulate_ring(xs):
    """Seed ``simulate_ring_all_reduce`` (int8, identity order)."""
    k, orig_size = xs.shape
    xs = xs.astype(jnp.float32)
    weights = jnp.ones((k,), jnp.float32)
    total_w = jnp.sum(weights)
    accs = jnp.stack([xs[p] * weights[p] for p in range(k)])
    accs, chunk = _seed_pad_to_chunks(accs, k)

    def quant_chunks(vals):
        payloads, deqs = [], []
        for p in range(k):
            pay, deq = _seed_tx_quant(vals[p])
            payloads.append(pay)
            deqs.append(deq)
        return payloads, deqs

    for s in range(k - 1):
        sends = [_seed_get_chunk(accs[p], (p - s) % k, chunk)
                 for p in range(k)]
        payloads, deqs = quant_chunks(sends)
        new = []
        for p in range(k):
            src = (p - 1) % k
            recv_idx = (p - s - 1) % k
            val = _seed_get_chunk(accs[p], recv_idx, chunk) + deqs[src](
                payloads[src])
            new.append(_seed_set_chunk(accs[p], recv_idx, val, chunk))
        accs = jnp.stack(new)

    sends = [_seed_get_chunk(accs[p], (p + 1) % k, chunk)
             for p in range(k)]
    payloads, deqs = quant_chunks(sends)
    accs = jnp.stack([
        _seed_set_chunk(accs[p], (p + 1) % k, deqs[p](payloads[p]), chunk)
        for p in range(k)])
    bufs, buf_deqs = payloads, deqs
    for s in range(k - 1):
        nbufs = [bufs[(p - 1) % k] for p in range(k)]
        ndeqs = [buf_deqs[(p - 1) % k] for p in range(k)]
        accs = jnp.stack([
            _seed_set_chunk(accs[p], (p - s) % k, ndeqs[p](nbufs[p]),
                            chunk) for p in range(k)])
        bufs, buf_deqs = nbufs, ndeqs
    return accs[..., :orig_size] / jnp.maximum(total_w, 1e-20)


def _seed_outer_sync_sim(stacked_params, state, cfg):
    k = jax.tree.leaves(stacked_params)[0].shape[0]

    def per_worker(params_i):
        p_flat, _ = _seed_flatten_pytree(params_i)
        a_flat, _ = _seed_flatten_pytree(state.anchor)
        return a_flat - p_flat

    pgs = jax.vmap(per_worker)(stacked_params)
    reduced = _seed_simulate_ring(pgs)
    any_params = jax.tree.map(lambda p: p[0], stacked_params)
    delta = _seed_flatten_pytree(state.anchor)[1](
        reduced[0], like=state.anchor)
    new_anchor, new_opt = cfg.outer_opt.update(delta, state.opt,
                                               state.anchor)
    new_params = jax.tree.map(
        lambda a, p: a.astype(p.dtype), new_anchor, any_params)
    stacked_new = jax.tree.map(
        lambda p: jnp.broadcast_to(p[None], (k,) + p.shape), new_params)
    return stacked_new, state._replace(anchor=new_anchor, opt=new_opt)


# -- harness -----------------------------------------------------------------


def _model(rng, n=N_ELEMS):
    """8-leaf pytree totalling n elements (flatten is part of the cost)."""
    per = n // 8
    return {f"w{i}": jnp.asarray(rng.normal(size=(per,)) * 0.02,
                                 jnp.float32) for i in range(8)}


def _drift(params, k):
    return jax.tree.map(
        lambda a: jnp.stack([a * (1 + 0.01 * i) for i in range(k)]),
        params)


def _time(fn, iters=2):
    jax.block_until_ready(fn())  # warmup / op-cache fill
    t0 = time.perf_counter()
    for _ in range(iters):
        jax.block_until_ready(fn())
    return (time.perf_counter() - t0) / iters


def _measure(seed: int = 0) -> dict:
    rng = np.random.default_rng(seed)
    params = _model(rng)
    stacked = _drift(params, N_WORKERS)
    cfg = dl.DiLoCoConfig(quant="int8", sync_buckets=2)
    st = dl.init_outer_state_sim(params, cfg, N_WORKERS)

    t_fused = _time(lambda: dl.outer_sync_sim(stacked, st, cfg)[1]
                    .anchor_flat)
    t_seed = _time(lambda: _seed_outer_sync_sim(stacked, st, cfg)[1]
                   .anchor["w0"])

    n = sum(l.size for l in jax.tree.leaves(params))
    # analytic full-model HBM round-trips around the ring (per outer
    # step, per worker; the ring's chunk traffic itself is identical):
    #   seed : anchor flatten inside vmap (k reads + k writes of the
    #          anchor) + theta flatten + pg materialize + anchor
    #          re-flatten in apply + delta unflatten + tree-map outer
    #   fused: theta flatten + pg subtract off the persistent buffer +
    #          momentum flatten + 3 unflattens (anchor/momentum/params)
    hbm = {"seed_anchor_flattens_per_step": N_WORKERS + 1,
           "fused_anchor_flattens_per_step": 0,
           "seed_ring_stack_copies": 2 * (N_WORKERS - 1) + 2,
           "fused_ring_stack_copies": 0}
    return {
        "elements": int(n),
        "workers": N_WORKERS,
        "quant": cfg.quant,
        "sync_buckets": cfg.sync_buckets,
        "fused_outer_sync_s": t_fused,
        "seed_outer_sync_s": t_seed,
        "speedup": t_seed / t_fused,
        "wire_bytes_per_worker": dl.sync_wire_bytes(
            params, N_WORKERS, cfg),
        "hbm_passes": hbm,
    }


def _rows(m: dict) -> list[str]:
    return [
        common.csv_row("sync/outer_sync_fused", m["fused_outer_sync_s"]
                       * 1e6, f"elems={m['elements']};k={m['workers']};"
                       f"buckets={m['sync_buckets']}"),
        common.csv_row("sync/outer_sync_seed_path",
                       m["seed_outer_sync_s"] * 1e6,
                       f"speedup_fused={m['speedup']:.2f}x"),
        common.csv_row("sync/wire_bytes", 0.0,
                       f"per_worker_bytes={m['wire_bytes_per_worker']}"),
    ]


def run(seed: int = 0) -> list[str]:
    return _rows(_measure(seed))


def run_json(seed: int = 0):
    m = _measure(seed)
    return _rows(m), {"sync": m}
