"""Benchmark harness — one module per paper table/figure.
Prints ``name,us_per_call,derived`` CSV (plus a header comment).

    PYTHONPATH=src python -m benchmarks.run            # all
    PYTHONPATH=src python -m benchmarks.run table2 fig5
    PYTHONPATH=src python -m benchmarks.run sync --json

``--json``: modules exposing ``run_json()`` additionally contribute a
machine-readable payload, merged into ``BENCH_sync.json`` (the perf
trajectory file future PRs diff against).
"""
from __future__ import annotations

import json
import sys
import traceback

MODULES = [
    ("table2", "benchmarks.table2_compute_util"),
    ("bandwidth", "benchmarks.bandwidth_reduction"),
    ("fig3", "benchmarks.fig3_allreduce_dist"),
    ("fig5", "benchmarks.fig5_resilience"),
    ("convergence", "benchmarks.convergence_diloco_vs_dp"),
    ("quant", "benchmarks.quant_quality"),
    ("kernels", "benchmarks.kernel_bench"),
    ("sync", "benchmarks.sync_bench"),
]

JSON_PATH = "BENCH_sync.json"


def main() -> None:
    args = sys.argv[1:]
    json_mode = "--json" in args
    want = {a for a in args if not a.startswith("-")}
    print("# name,us_per_call,derived")
    failed = []
    payload: dict = {}
    for key, modname in MODULES:
        if want and key not in want:
            continue
        try:
            mod = __import__(modname, fromlist=["run"])
            if json_mode and hasattr(mod, "run_json"):
                rows, part = mod.run_json()
                payload.update(part)
            else:
                rows = mod.run()
            for row in rows:
                print(row, flush=True)
        except Exception:
            failed.append(key)
            traceback.print_exc()
    if json_mode and payload:
        with open(JSON_PATH, "w") as f:
            json.dump(payload, f, indent=2, sort_keys=True)
        print(f"# wrote {JSON_PATH}", file=sys.stderr)
    if failed:
        print(f"# FAILED: {failed}", file=sys.stderr)
        sys.exit(1)


if __name__ == "__main__":
    main()
