"""Benchmark harness — one module per paper table/figure.
Prints ``name,us_per_call,derived`` CSV (plus a header comment).

    PYTHONPATH=src python -m benchmarks.run            # all
    PYTHONPATH=src python -m benchmarks.run table2 fig5
    PYTHONPATH=src python -m benchmarks.run sync --json
    PYTHONPATH=src python -m benchmarks.run recovery --json --smoke

``--json``: modules exposing ``run_json()`` additionally contribute a
machine-readable payload, written to the module's ``JSON_PATH``
(default ``BENCH_sync.json``) — the perf trajectory files future PRs
diff against. ``--smoke``: modules whose ``run``/``run_json`` accept a
``smoke`` kwarg run at CI-sized scale.
"""
from __future__ import annotations

import inspect
import json
import sys
import traceback

MODULES = [
    ("table2", "benchmarks.table2_compute_util"),
    ("bandwidth", "benchmarks.bandwidth_reduction"),
    ("fig3", "benchmarks.fig3_allreduce_dist"),
    ("fig5", "benchmarks.fig5_resilience"),
    ("convergence", "benchmarks.convergence_diloco_vs_dp"),
    ("quant", "benchmarks.quant_quality"),
    ("kernels", "benchmarks.kernel_bench"),
    ("sync", "benchmarks.sync_bench"),
    ("recovery", "benchmarks.recovery_bench"),
    ("serve", "benchmarks.serve_bench"),
    ("rl", "benchmarks.rl_bench"),
]

JSON_PATH = "BENCH_sync.json"


def _call(fn, smoke: bool):
    if smoke and "smoke" in inspect.signature(fn).parameters:
        return fn(smoke=True)
    return fn()


def main() -> None:
    args = sys.argv[1:]
    json_mode = "--json" in args
    smoke = "--smoke" in args
    want = {a for a in args if not a.startswith("-")}
    print("# name,us_per_call,derived")
    failed = []
    payloads: dict[str, dict] = {}
    for key, modname in MODULES:
        if want and key not in want:
            continue
        try:
            mod = __import__(modname, fromlist=["run"])
            if json_mode and hasattr(mod, "run_json"):
                rows, part = _call(mod.run_json, smoke)
                path = getattr(mod, "JSON_PATH", JSON_PATH)
                payloads.setdefault(path, {}).update(part)
            else:
                rows = _call(mod.run, smoke)
            for row in rows:
                print(row, flush=True)
        except Exception:
            failed.append(key)
            traceback.print_exc()
    for path, payload in payloads.items():
        with open(path, "w") as f:
            json.dump(payload, f, indent=2, sort_keys=True)
        print(f"# wrote {path}", file=sys.stderr)
    if failed:
        print(f"# FAILED: {failed}", file=sys.stderr)
        sys.exit(1)


if __name__ == "__main__":
    main()
