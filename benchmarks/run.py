"""Benchmark harness — one module per paper table/figure.
Prints ``name,us_per_call,derived`` CSV (plus a header comment).

    PYTHONPATH=src python -m benchmarks.run            # all
    PYTHONPATH=src python -m benchmarks.run table2 fig5
"""
from __future__ import annotations

import sys
import traceback

MODULES = [
    ("table2", "benchmarks.table2_compute_util"),
    ("bandwidth", "benchmarks.bandwidth_reduction"),
    ("fig3", "benchmarks.fig3_allreduce_dist"),
    ("fig5", "benchmarks.fig5_resilience"),
    ("convergence", "benchmarks.convergence_diloco_vs_dp"),
    ("quant", "benchmarks.quant_quality"),
    ("kernels", "benchmarks.kernel_bench"),
]


def main() -> None:
    want = set(sys.argv[1:])
    print("# name,us_per_call,derived")
    failed = []
    for key, modname in MODULES:
        if want and key not in want:
            continue
        try:
            mod = __import__(modname, fromlist=["run"])
            for row in mod.run():
                print(row, flush=True)
        except Exception:
            failed.append(key)
            traceback.print_exc()
    if failed:
        print(f"# FAILED: {failed}", file=sys.stderr)
        sys.exit(1)


if __name__ == "__main__":
    main()
