"""Table 3 proxy: the paper's claim that DiLoCo training matches
centralized training quality ("comparable performance ... effectively
scales"). We cannot run MMLU in this container; the measurable proxy is
loss-match on the same token budget: k DiLoCo workers (H=8, int8 ring)
vs fully-synchronous data parallel (H=1, fp32)."""
from __future__ import annotations

import time

import jax

from benchmarks import common
from repro.configs import CONFIGS
from repro.core.diloco import DiLoCoConfig
from repro.core.fault_tolerance import ClusterSimulator
from repro.data.pipeline import DataConfig
from repro.models.registry import get_model
from repro.train.loop import ElasticTrainer, TrainerConfig


def _run(quant: str, h: int, outer: int, seed: int = 0) -> list[float]:
    cfg = CONFIGS["internlm2-1.8b"].reduced()
    model = get_model(cfg)
    params, _ = model.init(jax.random.PRNGKey(seed))
    dcfg = DataConfig(vocab=cfg.vocab, seq_len=64, batch_per_worker=4,
                      total_steps=400)
    tcfg = TrainerConfig(diloco=DiLoCoConfig(inner_steps=h,
                                             quant=quant),
                         inner_lr=3e-3, max_workers=4)
    tr = ElasticTrainer(model, tcfg, dcfg, params,
                        ClusterSimulator([0, 1, 2, 3]))
    return [x["loss"] for x in tr.run(outer)]


def run(seed: int = 0) -> list[str]:
    t0 = time.time()
    diloco = _run("int8", h=8, outer=5, seed=seed)
    dp = _run("fp32", h=1, outer=40, seed=seed)
    dt = (time.time() - t0) * 1e6
    gap = (diloco[-1] - dp[-1]) / dp[-1]
    return [common.csv_row(
        "convergence/diloco_vs_dp", dt,
        f"diloco_final={diloco[-1]:.4f};dp_final={dp[-1]:.4f};"
        f"rel_gap={gap:+.3f};same_token_budget=1")]
