"""Shared benchmark utilities: the WAN/DCI network model used to
reproduce the paper's timing tables on CPU (no real multi-continent
links here), with the paper's own measured anchors.

Paper anchors (Table 2): inner phase 38 min (H=100 on 8xH100 nodes);
median all-reduce 103 s (USA), 382 s (transatlantic), 469 s (global);
checkpoint save 60 s; CPU pseudo-grad + outer step 5-10 s.
"""
from __future__ import annotations

import dataclasses

import numpy as np

# Paper Table 2 anchors
INNER_PHASE_S = 38 * 60.0
ALLREDUCE_MEDIAN_S = {"usa": 103.0, "transatlantic": 382.0,
                      "global": 469.0}
BASELINE_MFU = 0.433          # "no comm" MFU
CKPT_SAVE_S = 60.0
OUTER_CPU_OVERHEAD_S = 7.5    # 5-10 s


@dataclasses.dataclass(frozen=True)
class GeoScenario:
    name: str
    n_nodes: int
    # pairwise bandwidth distribution (Gbit/s), lognormal-ish jitter
    bw_mean_gbps: float
    bw_sigma: float           # lognormal sigma: higher = less reliable
    latency_ms: float


# Bandwidth means back-calibrated from the paper's measured medians
# (17.9-19 GB int8 payload for 10B params over 103/382/469 s implies
# ~1.4 / 0.39 / 0.32 Gbit/s effective bottleneck links — inside the
# paper's stated 500 Mb - 4 Gb/s envelope). Sigma grows with distance
# (Fig. 3: variance increases toward global).
SCENARIOS = {
    "usa": GeoScenario("usa", 8, 1.3, 0.25, 40.0),
    "transatlantic": GeoScenario("transatlantic", 10, 0.36, 0.45, 90.0),
    "global": GeoScenario("global", 14, 0.28, 0.60, 150.0),
}


def sample_bandwidth_matrix(sc: GeoScenario, rng: np.random.Generator
                            ) -> np.ndarray:
    """Symmetric pairwise bandwidth (Gbit/s) with heavy-ish tails."""
    n = sc.n_nodes
    w = sc.bw_mean_gbps * rng.lognormal(0.0, sc.bw_sigma, size=(n, n))
    w = (w + w.T) / 2
    np.fill_diagonal(w, 0.0)
    return w


def ring_allreduce_time_s(payload_bytes_per_worker: float,
                          ring_bw_gbps: np.ndarray,
                          order, latency_ms: float) -> float:
    """Time of one ring all-reduce: 2(n-1) hops, each hop paced by the
    slowest active link (synchronous ring), plus per-hop latency."""
    n = len(order)
    if n <= 1:
        return 0.0
    hop_payload = payload_bytes_per_worker / (2 * (n - 1))
    edges = [(order[i], order[(i + 1) % n]) for i in range(n)]
    bws = np.array([ring_bw_gbps[a, b] for a, b in edges])
    bottleneck = bws.min() * 1e9 / 8      # bytes/s
    per_hop = hop_payload / bottleneck + latency_ms / 1e3
    return 2 * (n - 1) * per_hop


def csv_row(name: str, us_per_call: float, derived: str) -> str:
    return f"{name},{us_per_call:.3f},{derived}"
