"""Paper abstract/§2.2: communication-volume reduction vs per-step fp32
data-parallel training — 400x at H=100/int8, up to 2000x at H=500, plus
the beyond-paper int4 (+EF) mode. Exact byte accounting from the ring
implementation (payload + codebook sidebands), not an estimate."""
from __future__ import annotations

import jax

from benchmarks import common
from repro.configs import get_config
from repro.core.diloco import DiLoCoConfig, sync_wire_bytes
from repro.models import common as mcommon
from repro.models.registry import get_model


def run(seed: int = 0) -> list[str]:
    cfg = get_config("intellect-1")
    model = get_model(cfg)
    shapes, _ = mcommon.eval_axes(model.init, jax.random.PRNGKey(0))
    n = sum(l.size for l in jax.tree.leaves(shapes))
    k = 8
    dp_per_step = 2 * (k - 1) * (n / k) * 4      # fp32 ring gradients
    rows = []
    for h, quant in [(100, "int8"), (500, "int8"), (100, "fp32"),
                     (100, "int4"), (500, "int4")]:
        dcfg = DiLoCoConfig(inner_steps=h, quant=quant)
        diloco = sync_wire_bytes(shapes, k, dcfg)  # once per H steps
        reduction = (dp_per_step * h) / diloco
        rows.append(common.csv_row(
            f"bandwidth_reduction/H{h}_{quant}", 0.0,
            f"reduction={reduction:.0f}x;"
            f"diloco_bytes_per_sync={diloco:.3e};"
            f"dp_bytes_per_{h}_steps={dp_per_step * h:.3e}"))
    return rows
