"""§2.2 kernel microbenchmark: quantization throughput.

The paper's C++ uint8 ops had to beat 4 Gb/s link speed (60x over
torch). Here the Pallas kernels target TPU; on this CPU container we
time the jnp reference (compiled by XLA:CPU) and the interpret-mode
kernels per-call, and — the deployable number — derive the bytes/s each
path must sustain so quantization never becomes the ring bottleneck
(paper's criterion)."""
from __future__ import annotations

import time

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks import common
from repro.kernels import ops, ref


def _time(fn, *args, iters: int = 5) -> float:
    fn(*args)  # compile
    t0 = time.perf_counter()
    for _ in range(iters):
        jax.block_until_ready(fn(*args))
    return (time.perf_counter() - t0) / iters


def run(seed: int = 0) -> list[str]:
    rng = np.random.default_rng(seed)
    rows = []
    n = 1 << 22              # 16 MiB fp32 chunk (one ring hop payload)
    x = jnp.asarray(rng.normal(size=(n,)), jnp.float32)

    t_ref = _time(jax.jit(ref.quantize), x)
    gbps_ref = n * 4 / t_ref / 1e9
    rows.append(common.csv_row(
        "kernel/quantize_jnp_xla_cpu", t_ref * 1e6,
        f"throughput_GBps={gbps_ref:.2f};"
        f"sustains_4Gbit_link={int(gbps_ref * 8 > 4)}"))

    q = ref.quantize(x)
    t_deq = _time(jax.jit(ref.dequantize), q)
    rows.append(common.csv_row(
        "kernel/dequantize_jnp_xla_cpu", t_deq * 1e6,
        f"throughput_GBps={n * 4 / t_deq / 1e9:.2f}"))

    # interpret-mode Pallas (correctness vehicle; real target is TPU —
    # use a small block so the python interpreter finishes quickly)
    xs = x[: 1 << 16]
    t_pal = _time(lambda v: ops.quantize(v, impl="pallas"), xs,
                  iters=2)
    rows.append(common.csv_row(
        "kernel/quantize_pallas_interpret", t_pal * 1e6,
        f"elems={xs.size};note=interpret-mode-correctness-only"))

    # fused pseudo-grad path: ops.quantize_pseudograd is ONE jit program
    # (stats fused over anchor/theta, pg never materialized) vs a
    # two-program pipeline that materializes pg in HBM between jits —
    # both sides compiled, so the delta is the extra round-trip only
    a = jnp.asarray(rng.normal(size=(n,)), jnp.float32)
    t_fused = _time(lambda aa, xx: ops.quantize_pseudograd(
        aa, xx, impl="jnp"), a, x)

    j_sub = jax.jit(lambda aa, xx: aa - xx)
    j_quant = jax.jit(ref.quantize)
    t_unfused = _time(lambda aa, xx: j_quant(j_sub(aa, xx)), a, x)
    rows.append(common.csv_row(
        "kernel/pseudograd_fusion", t_fused * 1e6,
        f"unfused_us={t_unfused * 1e6:.1f};"
        f"speedup={t_unfused / t_fused:.2f}x;"
        f"note=cpu-parity-expected-tpu-saves-hbm-pass"))
    return rows
