"""§2.2 quality claim: int8 pseudo-gradient quantization maintains model
quality. Same run with fp32 / int8 / int4 / int4+EF rings; report final
losses and the roundtrip quantization error on real pseudo-gradients."""
from __future__ import annotations

import time

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks import common
from repro.configs import CONFIGS
from repro.core.diloco import DiLoCoConfig
from repro.core.fault_tolerance import ClusterSimulator
from repro.data.pipeline import DataConfig
from repro.kernels import ref
from repro.models.registry import get_model
from repro.train.loop import ElasticTrainer, TrainerConfig


def _train(quant: str, ef: bool = False, seed: int = 0) -> float:
    cfg = CONFIGS["internlm2-1.8b"].reduced()
    model = get_model(cfg)
    params, _ = model.init(jax.random.PRNGKey(seed))
    dcfg = DataConfig(vocab=cfg.vocab, seq_len=64, batch_per_worker=4,
                      total_steps=300)
    tcfg = TrainerConfig(
        diloco=DiLoCoConfig(inner_steps=5, quant=quant,
                            error_feedback=ef),
        inner_lr=3e-3, max_workers=4)
    tr = ElasticTrainer(model, tcfg, dcfg, params,
                        ClusterSimulator([0, 1, 2, 3]))
    return tr.run(5)[-1]["loss"]


def run(seed: int = 0) -> list[str]:
    rows = []
    t0 = time.time()
    base = _train("fp32", seed=seed)
    for quant, ef in [("int8", False), ("int4", False), ("int4", True)]:
        loss = _train(quant, ef, seed=seed)
        rows.append(common.csv_row(
            f"quant_quality/{quant}{'_ef' if ef else ''}",
            (time.time() - t0) * 1e6,
            f"final_loss={loss:.4f};fp32_loss={base:.4f};"
            f"rel_gap={(loss - base) / base:+.4f}"))
    # roundtrip error of the paper's scheme on a gaussian pseudo-grad
    rng = np.random.default_rng(seed)
    pg = jnp.asarray(rng.normal(0, 1e-3, size=(1 << 20,)), jnp.float32)
    q = ref.quantize(pg)
    err = float(jnp.max(jnp.abs(ref.dequantize(q) - pg)))
    rel = err / float(jnp.std(pg))
    rows.append(common.csv_row(
        "quant_quality/roundtrip", 0.0,
        f"max_abs_err={err:.3e};err_over_sigma={rel:.4f};"
        f"bucket_width_sigma={12 / 256:.4f}"))
    return rows
