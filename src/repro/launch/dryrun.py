import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

"""Multi-pod dry-run: lower + compile every (arch x shape x mesh) cell.

For each cell:
  * build the production mesh (16x16 single-pod / 2x16x16 multi-pod),
  * eval_shape the model init -> parameter ShapeDtypeStructs (+ logical
    axes -> PartitionSpecs via the plan),
  * lower the hot-path step for the shape kind:
      - train:   inner train step (fwd+bwd+AdamW)    [+ DiLoCo sync step]
      - prefill: prefill (full prompt -> cache)
      - decode:  one serve_step token against a seq_len cache
  * ``.lower().compile()`` and record memory_analysis / cost_analysis /
    per-collective wire bytes -> JSON under experiments/dryrun/.

Run a single cell:
    PYTHONPATH=src python -m repro.launch.dryrun --arch mamba2-130m \
        --shape train_4k --mesh single
Run everything (spawns one subprocess per cell for memory isolation):
    PYTHONPATH=src python -m repro.launch.dryrun --all
"""
import argparse
import json
import pathlib
import subprocess
import sys
import time

OUT_DIR = pathlib.Path(__file__).resolve().parents[3] / "experiments" \
    / "dryrun"


def run_cell(arch: str, shape_name: str, mesh_kind: str,
             sync_too: bool = True, quant: str = "int8",
             out_dir: pathlib.Path = OUT_DIR) -> dict:
    import jax
    import jax.numpy as jnp
    from jax.sharding import NamedSharding, PartitionSpec as P

    from repro.analysis import roofline
    from repro.configs import SHAPES, get_config
    from repro.core.diloco import DiLoCoConfig
    from repro.launch.mesh import make_production_mesh
    from repro.models import common
    from repro.models.registry import get_model
    from repro.optim.adamw import AdamW
    from repro.sharding import make_plan, partition
    from repro.train import step as step_lib
    from repro.train.state import TrainState
    from repro.optim.adamw import AdamWState

    t0 = time.time()
    cfg = get_config(arch)
    shape = SHAPES[shape_name]
    if not cfg.supports(shape):
        result = {"arch": arch, "shape": shape_name, "mesh": mesh_kind,
                  "skipped": "no sub-quadratic path for 500k dense attn"}
        out = out_dir / mesh_kind / arch
        out.mkdir(parents=True, exist_ok=True)
        (out / f"{shape_name}.json").write_text(
            json.dumps(result, indent=1))
        return result

    mesh = make_production_mesh(multi_pod=(mesh_kind == "multi"))
    axes_map = step_lib.mesh_axes(mesh)
    n_chips = int(mesh.devices.size)
    plan = make_plan(cfg, shape, axes_map)
    model = get_model(cfg)
    pshapes, paxes = common.eval_axes(model.init, jax.random.PRNGKey(0))
    pspecs = partition.param_pspecs(paxes, pshapes, plan, axes_map)

    def named(tree):
        return partition.to_named(tree, mesh)

    result = {"arch": arch, "shape": shape_name, "mesh": mesh_kind,
              "plan": {"diloco_axis": plan.diloco_axis,
                       "n_workers": plan.n_workers,
                       "batch_axes": plan.batch_axes,
                       "remat": plan.remat,
                       "seq_axis": plan.seq_axis},
              "n_chips": n_chips}

    def record(tag, lowered, model_flops):
        compiled = lowered.compile()
        ma = compiled.memory_analysis()
        hlo = compiled.as_text()
        rl = roofline.analyze(compiled, n_chips=n_chips,
                              model_flops=model_flops, hlo=hlo)
        from repro.analysis.hlo_cost import analyze_hlo
        coll_by_kind = analyze_hlo(hlo).collective_bytes
        xla_ca = compiled.cost_analysis() or {}
        mem = {}
        if ma is not None:
            mem = {
                "argument_bytes": ma.argument_size_in_bytes,
                "output_bytes": ma.output_size_in_bytes,
                "temp_bytes": ma.temp_size_in_bytes,
                "alias_bytes": ma.alias_size_in_bytes,
                "peak_device_bytes": (ma.argument_size_in_bytes
                                      + ma.output_size_in_bytes
                                      + ma.temp_size_in_bytes
                                      - ma.alias_size_in_bytes),
            }
        result[tag] = {"roofline": rl.as_dict(),
                       "collectives": coll_by_kind,
                       "memory": mem,
                       "xla_cost": {
                           "flops_1iter": float(
                               xla_ca.get("flops", 0.0)),
                           "bytes_1iter": float(
                               xla_ca.get("bytes accessed", 0.0))}}
        print(f"[{arch}/{shape_name}/{mesh_kind}/{tag}] "
              f"flops/dev={rl.flops:.3e} hbm/dev={rl.hbm_bytes:.3e} "
              f"wire/dev={rl.wire_bytes:.3e} bottleneck={rl.bottleneck} "
              f"mfu_bound={rl.mfu:.3f} "
              f"peakmem={mem.get('peak_device_bytes', 0)/2**30:.2f}GiB",
              flush=True)

    with mesh:
        if shape.kind == "train":
            train_step, state_specs = step_lib.build_train_step(
                model, plan, mesh, AdamW(lr=7.5e-5))
            k = plan.n_workers
            stack = (lambda t: jax.tree.map(
                lambda s: jax.ShapeDtypeStruct((k,) + s.shape, s.dtype),
                t)) if plan.diloco_axis else (lambda t: t)
            params_s = stack(pshapes)
            f32 = lambda t: jax.tree.map(
                lambda s: jax.ShapeDtypeStruct(s.shape, jnp.float32), t)
            opt_s = AdamWState(
                jax.ShapeDtypeStruct((k,) if plan.diloco_axis else (),
                                     jnp.int32),
                f32(params_s), f32(params_s))
            state_s = TrainState(params_s, opt_s)
            ispecs = model.input_specs(shape)
            bsp = step_lib.batch_pspecs(model, shape, plan, mesh,
                                        stacked=True)
            if plan.diloco_axis:
                per_w = {kk: jax.ShapeDtypeStruct(
                    (k, v.shape[0] // k) + v.shape[1:], v.dtype)
                    for kk, v in ispecs.items()}
            else:
                per_w = ispecs
            lowered = jax.jit(
                train_step,
                in_shardings=(named(state_specs), named(bsp)),
                out_shardings=(named(state_specs), None),
                donate_argnums=0,
            ).lower(state_s, per_w)
            record("train_step", lowered,
                   roofline.model_flops_for(cfg, shape))

            if sync_too:
                dcfg = DiLoCoConfig(quant=quant, quant_impl="jnp")
                sync, outer_specs = step_lib.build_outer_sync(
                    model, plan, mesh, dcfg)
                anchor_s = f32(pshapes)
                from repro.optim.nesterov import NesterovState
                from repro.core.diloco import OuterState
                outer_s = OuterState(
                    anchor_s, NesterovState(f32(pshapes)),
                    jax.ShapeDtypeStruct(
                        (k, 0) if plan.diloco_axis else (0,),
                        jnp.float32),
                    jax.ShapeDtypeStruct((), jnp.int32))
                if outer_specs.anchor_flat is not None:
                    # every DiLoCo plan threads the persistent flat
                    # fp32 anchor through the sync step: replicated
                    # plans the full flatten, sharded plans the
                    # per-shard concat view (device-major, opaque)
                    nflat = step_lib.flat_anchor_len(model, plan,
                                                     mesh)
                    outer_s = outer_s._replace(
                        anchor_flat=jax.ShapeDtypeStruct(
                            (nflat,), jnp.float32))
                w_s = jax.ShapeDtypeStruct((k,), jnp.float32)
                wspec = NamedSharding(
                    mesh, P(plan.diloco_axis) if plan.diloco_axis
                    else P())
                lowered2 = jax.jit(
                    sync,
                    in_shardings=(named(partition.with_leading(
                        pspecs, plan.diloco_axis)),
                        named(outer_specs), wspec),
                    donate_argnums=(0, 1),
                ).lower(params_s, outer_s, w_s)
                # sync moves 1 byte/param int8 over the ring; "useful
                # flops" isn't meaningful here -> use param count
                record("sync_step", lowered2,
                       float(cfg.param_count()))
        else:
            kind = "prefill" if shape.kind == "prefill" else "decode"
            fn, _ = step_lib.build_serve_step(model, plan, mesh, kind)
            cache_s = jax.eval_shape(
                lambda: model.init_cache(shape.global_batch, shape))
            cache_specs = model.cache_pspecs(cache_s, plan, axes_map)
            ispecs = model.input_specs(shape)
            bsp = step_lib.batch_pspecs(model, shape, plan, mesh,
                                        stacked=False)
            if kind == "prefill":
                lowered = jax.jit(
                    fn, in_shardings=(named(pspecs), named(bsp),
                                      named(cache_specs)),
                    out_shardings=(None, named(cache_specs)),
                    donate_argnums=2,
                ).lower(pshapes, ispecs, cache_s)
            else:
                # decode: cache length reflects seq_len tokens present
                lowered = jax.jit(
                    fn, in_shardings=(named(pspecs),
                                      named(bsp["token"]),
                                      named(cache_specs)),
                    out_shardings=(None, named(cache_specs)),
                    donate_argnums=2,
                ).lower(pshapes, ispecs["token"], cache_s)
            record("serve_step", lowered,
                   roofline.model_flops_for(cfg, shape))

    result["elapsed_s"] = round(time.time() - t0, 1)
    out = out_dir / mesh_kind / arch
    out.mkdir(parents=True, exist_ok=True)
    (out / f"{shape_name}.json").write_text(json.dumps(result, indent=1))
    return result


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch")
    ap.add_argument("--shape")
    ap.add_argument("--mesh", choices=["single", "multi"],
                    default="single")
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--skip-existing", action="store_true")
    ap.add_argument("--no-sync", action="store_true")
    ap.add_argument("--quant", default="int8")
    args = ap.parse_args()

    if not args.all:
        res = run_cell(args.arch, args.shape, args.mesh,
                       sync_too=not args.no_sync, quant=args.quant)
        print(json.dumps(
            {k: v for k, v in res.items() if k != "plan"} | {
                "plan": res.get("plan")}, default=str)[:2000])
        return

    from repro.configs import ASSIGNED, SHAPES
    failures = []
    for mesh_kind in ("single", "multi"):
        for arch in ASSIGNED:
            for shape_name in SHAPES:
                tgt = OUT_DIR / mesh_kind / arch / f"{shape_name}.json"
                if args.skip_existing and tgt.exists():
                    print(f"skip {tgt}")
                    continue
                cmd = [sys.executable, "-m", "repro.launch.dryrun",
                       "--arch", arch, "--shape", shape_name,
                       "--mesh", mesh_kind]
                if args.no_sync:
                    cmd.append("--no-sync")
                print(">>", " ".join(cmd), flush=True)
                p = subprocess.run(cmd, timeout=3600)
                if p.returncode != 0:
                    failures.append((mesh_kind, arch, shape_name))
    print("FAILURES:", failures)
    sys.exit(1 if failures else 0)


if __name__ == "__main__":
    main()
