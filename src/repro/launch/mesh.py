"""Production mesh builders.

``make_production_mesh`` is a FUNCTION (never a module-level constant)
so importing this module never touches jax device state. The dry-run
sets ``XLA_FLAGS=--xla_force_host_platform_device_count=512`` before any
jax import to get placeholder devices.

Single pod: (16, 16) = (data, model) — 256 chips.
Multi-pod:  (2, 16, 16) = (pod, data, model) — 512 chips; the 'pod'
axis is the DiLoCo axis (slow inter-pod fabric).
"""
from __future__ import annotations

from repro import compat


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return compat.make_mesh(shape, axes)


def make_host_mesh(n: int = 1, axis: str = "data"):
    """Small local mesh for tests/examples on CPU devices."""
    return compat.make_mesh((n,), (axis,))
