"""Serving launcher: batched generation with the wave engine.

  PYTHONPATH=src python -m repro.launch.serve --arch mamba2-130m \
      --reduced --requests 8 --max-new 16
"""
from __future__ import annotations

import argparse
import time


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="mamba2-130m")
    ap.add_argument("--reduced", action="store_true")
    ap.add_argument("--requests", type=int, default=8)
    ap.add_argument("--slots", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=16)
    ap.add_argument("--max-new", type=int, default=16)
    ap.add_argument("--max-len", type=int, default=256)
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args()

    import jax
    import numpy as np

    from repro.configs import get_config
    from repro.models.registry import get_model
    from repro.serving.engine import Request, ServeEngine

    cfg = get_config(args.arch)
    if args.reduced:
        cfg = cfg.reduced()
    model = get_model(cfg)
    params, _ = model.init(jax.random.PRNGKey(args.seed))
    engine = ServeEngine(model, params, batch_slots=args.slots,
                         max_len=args.max_len)
    rng = np.random.default_rng(args.seed)
    for i in range(args.requests):
        engine.submit(Request(
            rid=i,
            prompt=rng.integers(
                2, cfg.vocab, size=args.prompt_len).astype(np.int32),
            max_new_tokens=args.max_new))
    t0 = time.time()
    engine.run_until_drained()
    dt = time.time() - t0
    print(f"requests={args.requests} waves={engine.stats['waves']} "
          f"decode_steps={engine.stats['decode_steps']} "
          f"tokens={engine.stats['tokens_out']} "
          f"tok/s={engine.stats['tokens_out']/dt:.1f}")


if __name__ == "__main__":
    main()
