"""Serving launcher: batched generation with the wave or continuous
engine, with tokens/sec and request-latency percentiles at exit.

  PYTHONPATH=src python -m repro.launch.serve --arch mamba2-130m \
      --reduced --requests 8 --max-new 16 --engine continuous

``--engine wave`` keeps the legacy static batcher for A/B runs;
``--attn-impl pallas`` routes decode attention through the Pallas
flash-decode kernel (interpret mode off-TPU).
"""
from __future__ import annotations

import argparse
import dataclasses


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="mamba2-130m")
    ap.add_argument("--reduced", action="store_true")
    ap.add_argument("--engine", default="continuous",
                    choices=["wave", "continuous"])
    ap.add_argument("--requests", type=int, default=8)
    ap.add_argument("--slots", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=16)
    ap.add_argument("--max-new", type=int, default=16)
    ap.add_argument("--max-len", type=int, default=256)
    ap.add_argument("--decode-chunk", type=int, default=8)
    ap.add_argument("--temperature", type=float, default=0.0)
    ap.add_argument("--top-k", type=int, default=0)
    ap.add_argument("--attn-impl", default="jnp",
                    choices=["jnp", "pallas"])
    ap.add_argument("--no-bucket", action="store_true",
                    help="disable power-of-two prompt pad bucketing")
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args()
    if args.engine == "wave" and args.temperature > 0:
        ap.error("--engine wave is greedy-only; use --engine "
                 "continuous for --temperature > 0")

    import jax
    import numpy as np

    from repro.configs import get_config
    from repro.models.registry import get_model
    from repro.serving.engine import Request, make_engine

    cfg = get_config(args.arch)
    if args.reduced:
        cfg = cfg.reduced()
    if args.attn_impl != cfg.decode_attn_impl:
        cfg = dataclasses.replace(cfg, decode_attn_impl=args.attn_impl)
    model = get_model(cfg)
    params, _ = model.init(jax.random.PRNGKey(args.seed))
    engine = make_engine(args.engine, model, params,
                         batch_slots=args.slots, max_len=args.max_len,
                         bucket_prompts=not args.no_bucket,
                         decode_chunk=args.decode_chunk,
                         top_k=args.top_k, seed=args.seed)
    rng = np.random.default_rng(args.seed)
    for i in range(args.requests):
        plen = max(1, int(rng.integers(args.prompt_len // 2,
                                       args.prompt_len + 1)))
        engine.submit(Request(
            rid=i,
            prompt=rng.integers(2, cfg.vocab, size=plen).astype(
                np.int32),
            max_new_tokens=args.max_new,
            temperature=args.temperature))
    engine.run_until_drained()
    s = engine.perf_summary()
    print(f"engine={s['engine']} requests={s['requests']} "
          f"tokens={s['tokens_out']} decode_steps={s['decode_steps']}")
    print(f"tok/s={s['tokens_per_s']:.1f} "
          f"p50_latency={s['latency_p50_s'] * 1e3:.1f}ms "
          f"p95_latency={s['latency_p95_s'] * 1e3:.1f}ms "
          f"occupancy={s['slot_occupancy']:.2f} "
          f"host_syncs={s['host_syncs']} "
          f"prefill_widths={s['prefill_widths']}")


if __name__ == "__main__":
    main()
