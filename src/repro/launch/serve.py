"""Serving launcher: batched generation with the wave or continuous
engine, with tokens/sec and request-latency percentiles at exit.

  PYTHONPATH=src python -m repro.launch.serve --arch mamba2-130m \
      --reduced --requests 8 --max-new 16 --engine continuous

``--engine wave`` keeps the legacy static batcher for A/B runs;
``--attn-impl pallas`` routes decode attention through the Pallas
flash-decode kernel (interpret mode off-TPU).

``--swarm`` demos fault-tolerant swarm inference instead: it brings up
an in-process fleet of ``--stages x --replicas`` StageServers (weight
distribution via the chunk swarm), routes the same requests through a
``SwarmRouter``, crashes one stage holder mid-run, and checks the
emitted tokens stay bit-identical to the single-host engine:

  PYTHONPATH=src python -m repro.launch.serve --arch internlm2-1.8b \
      --reduced --swarm --stages 2 --replicas 2 --requests 4
"""
from __future__ import annotations

import argparse
import dataclasses


def _run_swarm(args, cfg, model, params):
    import tempfile
    import time
    from pathlib import Path

    import numpy as np

    from repro.checkpointing import (ChunkGossip, ChunkPeer, ChunkStore,
                                     PeerConnPool)
    from repro.serving import (StageServer, SwarmRouter, publish_stages)
    from repro.serving.engine import ContinuousEngine, Request
    from repro.models import registry

    rng = np.random.default_rng(args.seed)
    prompts = [rng.integers(2, cfg.vocab,
                            size=max(1, int(rng.integers(
                                args.prompt_len // 2,
                                args.prompt_len + 1)))).tolist()
               for _ in range(args.requests)]

    # single-host greedy reference
    engine = ContinuousEngine(model, params, batch_slots=args.slots,
                              max_len=args.max_len)
    reqs = [Request(rid=i, prompt=np.asarray(p, np.int32),
                    max_new_tokens=args.max_new)
            for i, p in enumerate(prompts)]
    for r in reqs:
        engine.submit(r)
    engine.run_until_drained()
    reference = [list(r.out_tokens) for r in reqs]

    stages = registry.make_stages(cfg, args.stages)
    servers, pool, gossip = {}, None, None
    with tempfile.TemporaryDirectory() as td:
        root = Path(td)
        seed_store = ChunkStore(root / "seed")
        publish_stages(seed_store, cfg, params, args.stages)
        seed_peer = ChunkPeer(seed_store)
        try:
            for sid in range(args.stages):
                sp = stages[sid].slice_params(params)
                for r in range(args.replicas):
                    srv = StageServer(
                        cfg, ChunkStore(root / f"srv_{sid}_{r}"),
                        k_stages=args.stages, max_len=args.max_len)
                    srv.serve_stage(sid, sp)
                    servers[(sid, r)] = srv
            pool = PeerConnPool(timeout=args.timeout)
            gossip = ChunkGossip([s.addr for s in servers.values()],
                                 timeout=args.timeout, pool=pool)
            gossip.poll_once()
            router = SwarmRouter(args.stages, gossip,
                                 timeout=args.timeout, pool=pool,
                                 max_len=args.max_len)
            if args.replicas > 1 and args.requests > 1:
                # crash a mid-chain holder a few responses into the
                # run: the router must fail over and re-prefill
                victim = servers[(args.stages // 2, 0)]
                victim.crash_after = victim.served_chunks + 3
            t0 = time.perf_counter()
            outs = [router.generate(p, args.max_new, rid=f"req{i}",
                                    eos_id=engine.eos_id)
                    for i, p in enumerate(prompts)]
            wall = time.perf_counter() - t0
            st = router.stats
            ntok = sum(len(o) for o in outs)
            identical = outs == reference
            print(f"swarm stages={args.stages} replicas={args.replicas} "
                  f"requests={len(outs)} tokens={ntok} "
                  f"tok/s={ntok / max(wall, 1e-9):.1f}")
            print(f"failovers={st['failovers']} "
                  f"recoveries={st['recoveries']} "
                  f"replayed_tokens={st['replayed_tokens']} "
                  f"recovery_s={st['recovery_s']:.3f} "
                  f"pool_reused={pool.stats['reused']}")
            print(f"bit_identical_to_engine={identical}")
            if not identical:
                raise SystemExit("swarm outputs diverged from engine")
        finally:
            if gossip is not None:
                gossip.stop()
            if pool is not None:
                pool.close()
            for s in servers.values():
                s.close()
            seed_peer.close()


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="mamba2-130m")
    ap.add_argument("--reduced", action="store_true")
    ap.add_argument("--engine", default="continuous",
                    choices=["wave", "continuous", "paged"])
    ap.add_argument("--block-size", type=int, default=16,
                    help="paged engine: KV cells per physical block")
    ap.add_argument("--pool-blocks", type=int, default=None,
                    help="paged engine: physical pool size (default "
                         "matches the dense per-slot budget)")
    ap.add_argument("--top-p", type=float, default=0.0,
                    help="nucleus sampling threshold (0 = off)")
    ap.add_argument("--requests", type=int, default=8)
    ap.add_argument("--slots", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=16)
    ap.add_argument("--max-new", type=int, default=16)
    ap.add_argument("--max-len", type=int, default=256)
    ap.add_argument("--decode-chunk", type=int, default=8)
    ap.add_argument("--temperature", type=float, default=0.0)
    ap.add_argument("--top-k", type=int, default=0)
    ap.add_argument("--attn-impl", default="jnp",
                    choices=["jnp", "pallas"])
    ap.add_argument("--no-bucket", action="store_true",
                    help="disable power-of-two prompt pad bucketing")
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--swarm", action="store_true",
                    help="fault-tolerant swarm-inference demo")
    ap.add_argument("--stages", type=int, default=2)
    ap.add_argument("--replicas", type=int, default=2)
    ap.add_argument("--timeout", type=float, default=5.0)
    args = ap.parse_args()
    if args.engine == "wave" and args.temperature > 0:
        ap.error("--engine wave is greedy-only; use --engine "
                 "continuous for --temperature > 0")

    import jax
    import numpy as np

    from repro.configs import get_config
    from repro.models.registry import get_model
    from repro.serving.engine import Request, make_engine

    cfg = get_config(args.arch)
    if args.reduced:
        cfg = cfg.reduced()
    if args.attn_impl != cfg.decode_attn_impl:
        cfg = dataclasses.replace(cfg, decode_attn_impl=args.attn_impl)
    model = get_model(cfg)
    params, _ = model.init(jax.random.PRNGKey(args.seed))
    if args.swarm:
        _run_swarm(args, cfg, model, params)
        return
    engine_kw = dict(batch_slots=args.slots, max_len=args.max_len,
                     bucket_prompts=not args.no_bucket,
                     decode_chunk=args.decode_chunk,
                     top_k=args.top_k, top_p=args.top_p,
                     seed=args.seed)
    if args.engine == "paged":
        engine_kw.update(block_size=args.block_size,
                         pool_blocks=args.pool_blocks)
    engine = make_engine(args.engine, model, params, **engine_kw)
    rng = np.random.default_rng(args.seed)
    for i in range(args.requests):
        plen = max(1, int(rng.integers(args.prompt_len // 2,
                                       args.prompt_len + 1)))
        engine.submit(Request(
            rid=i,
            prompt=rng.integers(2, cfg.vocab, size=plen).astype(
                np.int32),
            max_new_tokens=args.max_new,
            temperature=args.temperature))
    engine.run_until_drained()
    s = engine.perf_summary()
    print(f"engine={s['engine']} requests={s['requests']} "
          f"tokens={s['tokens_out']} decode_steps={s['decode_steps']}")
    print(f"tok/s={s['tokens_per_s']:.1f} "
          f"p50_latency={s['latency_p50_s'] * 1e3:.1f}ms "
          f"p95_latency={s['latency_p95_s'] * 1e3:.1f}ms "
          f"occupancy={s['slot_occupancy']:.2f} "
          f"host_syncs={s['host_syncs']} "
          f"prefill_widths={s['prefill_widths']}")
    if args.engine == "paged":
        print(f"block_size={s['block_size']} "
              f"blocks_peak={s['blocks_peak']}/{s['pool_blocks']} "
              f"prefix_hit_rate={s['prefix_hit_rate']:.2f} "
              f"cow_forks={s['cow_forks']} "
              f"paged_extends={s['paged_extends']}")


if __name__ == "__main__":
    main()
