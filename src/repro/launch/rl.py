"""Async decentralized RL launcher: trainer + publisher + rollout
fleet in one process, with the reward trend, staleness ledger and
adoption bit-exactness printed at exit.

  PYTHONPATH=src python -m repro.launch.rl --outer-steps 8 \
      --workers 2 --groups 6 --kill-at 2 --rejoin-at 4

Workers re-adopt on staggered strides (``--adopt-strides``), so the
fleet genuinely spans policy versions; ``--kill-at``/``--rejoin-at``
crash and rejoin one worker mid-run; ``--force-retire-at`` tombstones
an old version and exercises the typed retired-version fallback.
"""
from __future__ import annotations

import argparse
import tempfile


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="internlm2-1.8b",
                    help="preset name; the launcher runs its reduced()")
    ap.add_argument("--workers", type=int, default=2)
    ap.add_argument("--outer-steps", type=int, default=8)
    ap.add_argument("--inner-steps", type=int, default=3)
    ap.add_argument("--groups", type=int, default=6)
    ap.add_argument("--group-size", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=6)
    ap.add_argument("--max-new", type=int, default=10)
    ap.add_argument("--temperature", type=float, default=1.0)
    ap.add_argument("--lr", type=float, default=2e-2)
    ap.add_argument("--max-policy-lag", type=int, default=1)
    ap.add_argument("--stale-mode", default="drop",
                    choices=["drop", "downweight"])
    ap.add_argument("--codec", default="int8", choices=["int8", "int4"])
    ap.add_argument("--base-every", type=int, default=4)
    ap.add_argument("--adopt-strides", type=int, nargs="+",
                    default=[1, 3])
    ap.add_argument("--kill-at", type=int, default=None)
    ap.add_argument("--rejoin-at", type=int, default=None)
    ap.add_argument("--force-retire-at", type=int, default=None)
    ap.add_argument("--root", default=None,
                    help="fleet store root (default: a temp dir)")
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args()

    from repro.rl import RLConfig, RLDriver

    cfg = RLConfig(
        arch=args.arch, n_workers=args.workers,
        outer_steps=args.outer_steps, inner_steps=args.inner_steps,
        n_groups=args.groups, group_size=args.group_size,
        prompt_len=args.prompt_len, max_new=args.max_new,
        seq_len=args.prompt_len + args.max_new,
        temperature=args.temperature, inner_lr=args.lr,
        max_policy_lag=args.max_policy_lag, stale_mode=args.stale_mode,
        codec=args.codec, base_every=args.base_every,
        adopt_strides=tuple(args.adopt_strides),
        kill_at=args.kill_at, rejoin_at=args.rejoin_at,
        force_retire_at=args.force_retire_at, seed=args.seed)

    def run(root):
        drv = RLDriver(cfg, root)
        try:
            return drv.run()
        finally:
            drv.close()

    if args.root:
        s = run(args.root)
    else:
        with tempfile.TemporaryDirectory() as td:
            s = run(td)

    led = s["ledger"]
    print(f"rl workers={args.workers} outer_steps={s['outer_steps']} "
          f"versions={s['versions_published']} "
          f"rollout_tokens={s['rollout_tokens']} "
          f"tok/s={s['rollout_tok_s']:.1f}")
    print(f"reward {s['reward_first']:.3f}->{s['reward_last']:.3f} "
          f"trend={['%.3f' % r for r in s['reward_trend']]}")
    print(f"staleness generated={led['generated']} "
          f"accepted={led['accepted']} "
          f"dropped_stale={led['dropped_stale']} "
          f"drop_frac={s['stale_drop_fraction']:.2f} "
          f"max_lag={led['max_accepted_lag']} "
          f"mean_lag={s['mean_accepted_lag']:.2f}")
    print(f"adoptions={s['adoptions']} "
          f"mean_adopt_s={s['mean_adopt_s']:.3f} "
          f"adopt_bytes={s['adopt_bytes']} "
          f"retired_fallbacks={s['retired_fallbacks']} "
          f"live_versions={s['live_versions']}")
    print(f"bit_identical_to_publisher={s['bit_exact']}")
    if not s["bit_exact"]:
        raise SystemExit("adopted policy diverged from published anchor")


if __name__ == "__main__":
    main()
