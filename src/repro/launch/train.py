"""Training launcher.

Two modes:
  * ``--cluster-sim`` (default on CPU): the full elastic PRIME protocol
    with k stacked DiLoCo workers in one process — join/leave/crash
    schedules, int8 ring, bandwidth-aware reordering, checkpointing.
  * ``--distributed``: pjit/shard_map path against the production mesh
    (requires real or forced devices; the dry-run proves these programs
    compile for 256/512 chips).

Examples:
  PYTHONPATH=src python -m repro.launch.train --arch mamba2-130m \
      --reduced --outer-steps 5 --inner-steps 10 --workers 4
"""
from __future__ import annotations

import argparse
import json


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="intellect-1")
    ap.add_argument("--reduced", action="store_true",
                    help="use the smoke-scale sibling config")
    ap.add_argument("--workers", type=int, default=4)
    ap.add_argument("--outer-steps", type=int, default=4)
    ap.add_argument("--inner-steps", type=int, default=None,
                    help="H (default: DiLoCo config, paper=100)")
    ap.add_argument("--seq-len", type=int, default=128)
    ap.add_argument("--batch-per-worker", type=int, default=4)
    ap.add_argument("--quant", default="int8",
                    choices=["int8", "int4", "fp32"])
    ap.add_argument("--overlap", default="none",
                    choices=["none", "delayed"],
                    help="none: outer sync is a barrier between inner "
                         "phases; delayed: the quantized ring runs "
                         "under the next inner phase (hops dispatched "
                         "between scan chunks) and the reduced pseudo-"
                         "gradient is applied one phase late (paper "
                         "§2.2 overlapped sync)")
    ap.add_argument("--inner-chunks", type=int, default=1,
                    help="jitted scan chunks per inner phase; the gaps "
                         "are where in-flight ring hops are dispatched "
                         "(>= ring hops + 1 hides the whole ring)")
    ap.add_argument("--sync-buckets", type=int, default=1,
                    help="sub-buckets per ring chunk-hop (independent "
                         "codebooks; pipelines compress/transmit)")
    ap.add_argument("--error-feedback", action="store_true")
    ap.add_argument("--inner-lr", type=float, default=3e-4)
    ap.add_argument("--outer-lr", type=float, default=0.7)
    ap.add_argument("--ckpt-dir", default=None)
    ap.add_argument("--ckpt-engine", default="flat",
                    choices=["flat", "store", "delta"],
                    help="flat npy dirs | content-addressed chunk "
                         "store | chunk store + int8/int4 delta chain")
    ap.add_argument("--ckpt-base-every", type=int, default=8,
                    help="delta engine: full re-anchor every N saves")
    ap.add_argument("--ckpt-codec", default="int8",
                    choices=["int8", "int4"])
    ap.add_argument("--serve-ckpt-port", type=int, default=None,
                    help="serve the chunk store to joiners on this "
                         "port after training (0 = ephemeral)")
    ap.add_argument("--join-from", default=None,
                    help="comma-separated host:port peers; swarm-fetch "
                         "the latest checkpoint into --ckpt-dir and "
                         "start from it")
    ap.add_argument("--join-mode", default="blocking",
                    choices=["blocking", "stream"],
                    help="blocking: fetch completes before step 0 (the "
                         "paper's production mode); stream: gossip + "
                         "background chunk streaming overlapped with "
                         "the inner phases, adopted at the first outer "
                         "boundary where the chain is fully assembled")
    ap.add_argument("--events", default=None,
                    help='JSON list like [[2,"join",5],[3,"crash",1]]')
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args()

    import jax

    from repro.configs import get_config
    from repro.core.diloco import DiLoCoConfig
    from repro.core.fault_tolerance import (ClusterSimulator, EventKind,
                                            NodeEvent)
    from repro.data.pipeline import DataConfig
    from repro.models.registry import get_model
    from repro.train.loop import ElasticTrainer, TrainerConfig

    cfg = get_config(args.arch)
    if args.reduced:
        cfg = cfg.reduced()
    model = get_model(cfg)
    params, _ = model.init(jax.random.PRNGKey(args.seed))

    events = []
    if args.events:
        for step, kind, nid in json.loads(args.events):
            events.append(NodeEvent(step, EventKind(kind), nid))
    sim = ClusterSimulator(list(range(args.workers)), events=events)

    dcfg = DataConfig(vocab=cfg.vocab, seq_len=args.seq_len,
                      batch_per_worker=args.batch_per_worker,
                      total_steps=args.outer_steps * (
                          args.inner_steps or 100))
    tcfg = TrainerConfig(
        diloco=DiLoCoConfig(
            inner_steps=args.inner_steps or 100, quant=args.quant,
            outer_lr=args.outer_lr, overlap=args.overlap,
            sync_buckets=args.sync_buckets,
            error_feedback=args.error_feedback),
        inner_lr=args.inner_lr, ckpt_dir=args.ckpt_dir,
        ckpt_engine=args.ckpt_engine,
        ckpt_delta_base_every=args.ckpt_base_every,
        ckpt_codec=args.ckpt_codec,
        inner_chunks=args.inner_chunks,
        max_workers=max(args.workers * 2, args.workers + 2))
    trainer = ElasticTrainer(model, tcfg, dcfg, params, sim)

    if args.join_from:
        peers = []
        for hp in args.join_from.split(","):
            host, _, port = hp.rpartition(":")
            peers.append((host or "127.0.0.1", int(port)))
        assert args.ckpt_dir, "--join-from needs --ckpt-dir"
        assert args.ckpt_engine != "flat", \
            "--join-from fetches into a chunk store; use " \
            "--ckpt-engine store|delta"
        if args.join_mode == "stream":
            # overlapped onboarding: chunks stream + assemble in the
            # background while the inner phases run; the trainer
            # adopts at the first ready outer boundary
            trainer.begin_stream_join(peers)
            print(f"streaming join from {len(peers)} peers "
                  f"(gossip + background chunk streaming)")
        else:
            from repro.checkpointing import recover
            tree, meta, stats = recover(peers, args.ckpt_dir,
                                        trainer.checkpoint_like())
            trainer.adopt_checkpoint(tree, meta)
            print(f"joined via swarm: step {stats['step']}, "
                  f"{stats['chunks_fetched']} chunks "
                  f"({stats['bytes_fetched']} B) from "
                  f"{len(stats['per_peer'])} peers "
                  f"(reassigned={stats['reassigned_ranges']})")

    hist = trainer.run(args.outer_steps,
                       inner_steps=args.inner_steps)
    joins = [h["stream_join"] for h in hist if "stream_join" in h]
    for j in joins:
        st = j.get("stats", {})
        print(f"stream join: admitted={j['admitted']} "
              f"step={j.get('step')} "
              f"fetch={st.get('fetch_seconds', 0):.3f}s "
              f"chunks={st.get('chunks_fetched', 0)} "
              f"replayed_on_stream={st.get('replayed_on_stream', 0)}")
    if args.serve_ckpt_port is not None:
        assert args.ckpt_dir, "--serve-ckpt-port needs --ckpt-dir"
        if args.ckpt_engine == "flat":
            from repro.checkpointing import CheckpointServer
            peer = CheckpointServer(args.ckpt_dir,
                                    port=args.serve_ckpt_port)
            print(f"serving flat checkpoints on 127.0.0.1:{peer.port} "
                  f"(ctrl-C to stop)")
        else:
            peer = trainer.serve_checkpoints(port=args.serve_ckpt_port)
            print(f"serving chunk store on 127.0.0.1:{peer.port} "
                  f"(ctrl-C to stop)")
        try:
            import time
            while True:
                time.sleep(1)
        except KeyboardInterrupt:
            peer.close()
    for h in hist:
        print(json.dumps({k: v for k, v in h.items()
                          if k != "ring_order"}, default=str))
    if args.overlap == "delayed":
        led = trainer.comm_ledger
        falls = sum(1 for h in hist if "sync_fallback" in h)
        print(f"overlapped sync: {led.hidden_fraction:.0%} of ring "
              f"comm hidden under the chunked inner phase "
              f"({len(led.records)} windows, {falls} torn fallbacks)")
    print(f"final loss: {hist[-1]['loss']:.4f}  "
          f"bandwidth reduction vs fp32 DP: "
          f"{tcfg.diloco.inner_steps * 4 / (0.5 if args.quant=='int4' else (1 if args.quant=='int8' else 4)):.0f}x")


if __name__ == "__main__":
    main()
