"""Rollout workers: the serving tier feeding the RL trainer.

A :class:`RolloutWorker` is a node that (1) **adopts** a published
policy version over the swarm chunk protocol — fetch the delta chain
into its own ``ChunkStore``, replay it bit-exactly, verify the tree sha
against the publisher's record — and (2) **generates** rollouts with a
``ContinuousEngine`` in ``capture_logprobs`` mode, so every sampled
token carries its behavior-policy log-prob for the GRPO loss.

Adoption is asynchronous by design: each worker re-adopts on its own
cadence, so at any instant the fleet spans several policy versions.
Rollouts are tagged with the version that generated them; the staleness
window in :class:`repro.rl.buffer.RolloutBuffer` is what keeps that
spread bounded on the training side.

Failure model: a killed worker just stops producing (its buffer
contributions age out of the staleness window); a rejoiner re-adopts
from whatever peers are alive — its local store dedups the chain prefix
it already holds, so a rejoin fetches only the deltas it missed. A
worker that requests a force-retired version gets the typed
:class:`PolicyRetiredError` and re-adopts the latest.
"""
from __future__ import annotations

import time
from typing import Sequence

import jax
import numpy as np

from repro.checkpointing import ChunkStore, StepRetiredError, swarm_fetch
from repro.checkpointing import delta as _delta
from repro.checkpointing.p2p import PeerConn
from repro.rl.buffer import Rollout
from repro.rl.policy_pub import PolicyRetiredError, tree_sha
from repro.serving.engine import ContinuousEngine, Request


class AdoptionShaMismatch(RuntimeError):
    """The restored policy does not reproduce the publisher's recorded
    reconstruction sha — the chain replay is NOT bit-exact."""


class RolloutWorker:
    """One inference node of the asynchronous rollout fleet.

    ``like`` is a concrete params pytree (shapes/dtypes template for
    the chain restore — e.g. the same init params the trainer started
    from). The engine is built on first adoption and kept across
    re-adoptions (params swap in place, so the compiled decode program
    is reused)."""

    def __init__(self, wid: int, model, like, store_root, *,
                 batch_slots: int = 4, max_len: int = 256,
                 decode_chunk: int = 8, seed: int = 0, eos_id: int = 1,
                 engine: str = "continuous", **engine_kw):
        self.wid = int(wid)
        self.model = model
        self.like = like
        self.store = ChunkStore(store_root)
        # engine="paged" serves GRPO groups off the paged KV tier: the
        # k samples of a group share their question prompt, so the
        # content-addressed prefix index maps all k to the same
        # physical blocks and k-1 prefills are skipped outright
        self.engine_kind = engine
        self.engine_kw = dict(batch_slots=batch_slots, max_len=max_len,
                              decode_chunk=decode_chunk, eos_id=eos_id,
                              seed=seed * 1009 + wid, **engine_kw)
        self.engine: ContinuousEngine | None = None
        self.version: int | None = None     # adopted policy version
        self.adopted_sha: str | None = None
        self.adoptions: list[dict] = []
        self.alive = True
        self._rid = 0

    # -- policy adoption ------------------------------------------------------

    def adopt(self, peers: Sequence[tuple], *,
              version: int | None = None, timeout: float = 20.0) -> dict:
        """Fetch + restore policy ``version`` (None = the peers'
        newest) and swap it into the engine. Returns the adoption
        record; raises :class:`PolicyRetiredError` when the version was
        force-retired and :class:`AdoptionShaMismatch` when the restore
        is not bit-exact vs the publisher."""
        t0 = time.perf_counter()
        try:
            stats = swarm_fetch(peers, self.store, step=version,
                                timeout=timeout)
        except PolicyRetiredError:
            raise
        except StepRetiredError as e:
            raise PolicyRetiredError(str(e), e.failures) from e
        v = stats["step"]
        manifest = self.store.load_manifest(v)
        like = {"params": self.like}
        if manifest["kind"] == "delta":
            tree, meta = _delta.restore(self.store, like, step=v)
        else:
            tree, meta = self.store.restore_tree(like, step=v)
        sha = tree_sha(tree)
        pub_sha = self._publisher_sha(peers, v, timeout)
        if pub_sha is not None and pub_sha != sha:
            raise AdoptionShaMismatch(
                f"worker {self.wid}: adopted v{v} sha {sha[:12]} != "
                f"published {pub_sha[:12]}")
        params = jax.tree.map(jax.numpy.asarray, tree["params"])
        if self.engine is None:
            if self.engine_kind == "paged":
                from repro.serving.paging import PagedEngine
                self.engine = PagedEngine(
                    self.model, params, capture_logprobs=True,
                    **self.engine_kw)
            else:
                self.engine = ContinuousEngine(
                    self.model, params, capture_logprobs=True,
                    **self.engine_kw)
        else:
            self.engine.params = params
            # cached prefix KV / logits were computed under the OLD
            # policy — a params swap must invalidate the sharing index
            flush = getattr(self.engine, "flush_prefix_cache", None)
            if flush is not None:
                flush()
        prev = self.version
        self.version = int(meta.get("policy_version", v))
        self.adopted_sha = sha
        rec = {"worker": self.wid, "version": self.version,
               "from_version": prev, "sha": sha,
               "sha_verified": pub_sha is not None,
               "chunks_fetched": stats["chunks_fetched"],
               "bytes_fetched": stats["bytes_fetched"],
               "adopt_s": time.perf_counter() - t0}
        self.adoptions.append(rec)
        return rec

    def _publisher_sha(self, peers, version: int,
                       timeout: float) -> str | None:
        """Ask any peer for the publisher-recorded sha of ``version``
        (None when no peer speaks the policy_sha op — plain ChunkPeers
        serving a checkpoint store)."""
        for addr in peers:
            try:
                conn = PeerConn(tuple(addr), timeout)
                try:
                    body = conn.request_json(
                        {"op": "policy_sha", "version": int(version)})
                finally:
                    conn.close()
                if body.get("sha"):
                    return body["sha"]
            except Exception:
                continue
        return None

    # -- generation -----------------------------------------------------------

    def generate(self, prompts: Sequence[np.ndarray], *,
                 groups: Sequence[int] | None = None,
                 max_new: int = 16,
                 temperature: float = 1.0) -> tuple[list[Rollout], dict]:
        """Sample one completion per prompt (prompts sharing a group id
        form one GRPO group). Returns (rollouts tagged with the adopted
        version, worker-side stats)."""
        assert self.engine is not None and self.version is not None, \
            f"worker {self.wid} has not adopted a policy yet"
        assert self.alive, f"worker {self.wid} is dead"
        if groups is None:
            groups = list(range(len(prompts)))
        reqs = []
        for p in prompts:
            self._rid += 1
            reqs.append(Request(
                rid=self.wid * 1_000_000 + self._rid,
                prompt=np.asarray(p, np.int32),
                max_new_tokens=max_new, temperature=temperature))
        t0 = time.perf_counter()
        for r in reqs:
            self.engine.submit(r)
        self.engine.run_until_drained()
        wall = time.perf_counter() - t0
        rollouts = []
        for r, g in zip(reqs, groups):
            assert len(r.out_logprobs) == len(r.out_tokens), \
                "logprob capture out of sync with emitted tokens"
            rollouts.append(Rollout(
                rid=r.rid, prompt=np.asarray(r.prompt, np.int32),
                tokens=list(r.out_tokens),
                logprobs=list(r.out_logprobs),
                version=self.version, group=int(g), worker=self.wid))
        n_tok = sum(len(r.out_tokens) for r in reqs)
        stats = {"worker": self.wid, "version": self.version,
                 "requests": len(reqs), "tokens": n_tok,
                 "wall_s": wall,
                 "tokens_per_s": n_tok / wall if wall > 0 else 0.0}
        return rollouts, stats

    # -- fault injection ------------------------------------------------------

    def kill(self) -> None:
        """Simulated crash: the worker stops producing until rejoin."""
        self.alive = False

    def rejoin(self, peers, *, timeout: float = 20.0) -> dict:
        """Come back from a crash: re-adopt the latest policy (the
        local store dedups whatever chain prefix survived)."""
        self.alive = True
        return self.adopt(peers, timeout=timeout)
