"""Asynchronous rollout buffer with an outer-boundary staleness window.

Rollout workers append trajectories tagged with the policy version that
generated them; the trainer drains the buffer at each outer boundary.
Because workers adopt policy versions asynchronously (INTELLECT-2's
async RL), a drained rollout may be up to several versions behind the
trainer. The staleness window bounds the off-policy gap:

    lag = trainer_version - rollout.version     (>= 0)
    lag <= max_policy_lag  -> accepted  (weight 1, or gamma**lag when
                              mode == 'downweight' and lag > 0)
    lag >  max_policy_lag  -> dropped, never enters a training batch

Every decision is counted in a :class:`StalenessLedger` — the
accounting is exact (generated == accepted + dropped + still-buffered +
capacity-evicted at all times) and tested, because silent drops would
make reward trends unreadable.
"""
from __future__ import annotations

import dataclasses
import threading
from typing import Iterable

import numpy as np


@dataclasses.dataclass
class Rollout:
    """One sampled trajectory from a rollout worker."""
    rid: int
    prompt: np.ndarray          # (S,) int32
    tokens: list                # sampled completion token ids
    logprobs: list              # behavior-policy logprob per token
    version: int                # policy version that generated it
    group: int                  # GRPO group id (same prompt -> same group)
    worker: int = -1
    reward: float | None = None


@dataclasses.dataclass
class StalenessLedger:
    """Exact accounting of every rollout's fate at the staleness gate."""
    generated: int = 0          # appended to the buffer
    accepted: int = 0           # entered a training batch (weight > 0)
    dropped_stale: int = 0      # lag > max_policy_lag
    downweighted: int = 0       # accepted with weight < 1
    evicted_capacity: int = 0   # pushed out by the capacity bound
    max_accepted_lag: int = 0

    def as_dict(self) -> dict:
        return dataclasses.asdict(self)


class RolloutBuffer:
    """Thread-safe FIFO of rollouts between workers and the trainer.

    Workers ``add()`` from their own threads / call sites; the trainer
    ``drain()``s at outer boundaries with its CURRENT policy version,
    which is where the staleness window is enforced (the buffer itself
    never inspects versions on the way in — a rollout fresh at add time
    can be stale by the time it is consumed, and that is exactly the
    case the ledger must count).
    """

    def __init__(self, capacity: int = 4096):
        self.capacity = int(capacity)
        self._items: list[Rollout] = []
        self._lock = threading.Lock()
        self.ledger = StalenessLedger()

    def __len__(self) -> int:
        with self._lock:
            return len(self._items)

    @property
    def occupancy(self) -> float:
        return len(self) / max(1, self.capacity)

    def add(self, rollouts: Iterable[Rollout]) -> int:
        """Append rollouts (FIFO). Returns how many were evicted to
        honor the capacity bound (oldest first)."""
        rollouts = list(rollouts)
        with self._lock:
            self._items.extend(rollouts)
            self.ledger.generated += len(rollouts)
            evict = max(0, len(self._items) - self.capacity)
            if evict:
                del self._items[:evict]
                self.ledger.evicted_capacity += evict
        return evict

    def drain(self, current_version: int, max_policy_lag: int,
              mode: str = "drop", stale_gamma: float = 0.5
              ) -> list[tuple[Rollout, float]]:
        """Remove everything buffered and apply the staleness window.

        Returns ``[(rollout, weight), ...]`` for the accepted rollouts:
        weight 1.0 when on-window; ``stale_gamma ** lag`` for lagged
        rollouts under ``mode='downweight'``. Rollouts with
        ``lag > max_policy_lag`` are dropped (counted, not returned) —
        under 'downweight' too: the window is a hard boundary, the mode
        only shapes weights inside it.
        """
        if mode not in ("drop", "downweight"):
            raise ValueError(f"unknown staleness mode {mode!r}")
        with self._lock:
            items, self._items = self._items, []
        out: list[tuple[Rollout, float]] = []
        led = self.ledger
        for r in items:
            lag = int(current_version) - int(r.version)
            if lag < 0:
                raise ValueError(
                    f"rollout from FUTURE version {r.version} vs "
                    f"trainer {current_version} — version bookkeeping "
                    "is broken")
            if lag > max_policy_lag:
                led.dropped_stale += 1
                continue
            w = 1.0
            if mode == "downweight" and lag > 0:
                w = float(stale_gamma) ** lag
                led.downweighted += 1
            led.accepted += 1
            led.max_accepted_lag = max(led.max_accepted_lag, lag)
            out.append((r, w))
        return out
