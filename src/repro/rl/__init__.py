"""Asynchronous decentralized RL tier: rollout workers feed a DiLoCo
trainer through a staleness-windowed buffer; the trainer publishes each
outer-step anchor as a policy version over the swarm chunk protocol
(see docs/rl_rollout.md)."""
from repro.rl.buffer import Rollout, RolloutBuffer, StalenessLedger
from repro.rl.driver import RLConfig, RLDriver
from repro.rl.grpo import (GRPOBatcher, GRPOModel, group_advantages,
                           render_example, toy_low_token_reward)
from repro.rl.policy_pub import (PolicyPeer, PolicyPublisher,
                                 PolicyRetiredError, tree_sha)
from repro.rl.rollout import AdoptionShaMismatch, RolloutWorker

__all__ = [
    "Rollout", "RolloutBuffer", "StalenessLedger",
    "GRPOBatcher", "GRPOModel", "group_advantages", "render_example",
    "toy_low_token_reward",
    "PolicyPeer", "PolicyPublisher", "PolicyRetiredError", "tree_sha",
    "RolloutWorker", "AdoptionShaMismatch",
    "RLConfig", "RLDriver",
]
