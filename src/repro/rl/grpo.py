"""GRPO-style advantage estimation and the RL loss fed to the trainer.

Group Relative Policy Optimization (the INTELLECT-2 recipe): sample G
completions per prompt, normalize each completion's scalar reward
against its own group —

    A_i = (r_i - mean(r_group)) / std(r_group)

— no value network. Zero-variance groups (all completions scored the
same) carry no learning signal and are filtered rather than divided by
zero. The policy-gradient loss is token-level REINFORCE on the
completion span:

    L = - sum_t( A * w * mask_t * log pi(y_t | y_<t) ) / max(sum mask, 1)

where ``w`` is the staleness weight from the rollout buffer (1.0 under
mode='drop'). The loss plugs into :class:`ElasticTrainer` unchanged —
it has the same ``loss(params, batch) -> (loss, metrics)`` shape as the
pretraining cross-entropy, just over a different batch pytree.
"""
from __future__ import annotations

import dataclasses
from typing import Callable, Sequence

import jax
import jax.numpy as jnp
import numpy as np

from repro.models.registry import ModelDef
from repro.rl.buffer import Rollout


# -- rewards ------------------------------------------------------------------


def toy_low_token_reward(tokens: Sequence[int], vocab: int) -> float:
    """Toy verifiable reward: fraction of completion tokens drawn from
    the 'good' band [2, vocab//4). Band starts at 2 so eos (1) and pad
    (0) never score — otherwise the degenerate 'emit eos immediately'
    policy is optimal and the reward trend is unlearnable."""
    if not tokens:
        return 0.0
    lo, hi = 2, max(3, vocab // 4)
    good = sum(1 for t in tokens if lo <= int(t) < hi)
    return good / len(tokens)


def group_advantages(rewards: Sequence[float], groups: Sequence[int]
                     ) -> np.ndarray:
    """Per-group (r - mean) / std advantages; zero-variance groups map
    to all-zero advantages (filtered from the gradient, not div-by-0)."""
    rewards = np.asarray(rewards, np.float64)
    groups = np.asarray(groups)
    adv = np.zeros_like(rewards)
    for g in np.unique(groups):
        sel = groups == g
        r = rewards[sel]
        std = r.std()
        if std > 1e-8:
            adv[sel] = (r - r.mean()) / std
    return adv.astype(np.float32)


# -- loss ---------------------------------------------------------------------


class GRPOModel:
    """ModelDef-shaped wrapper whose ``loss`` is the GRPO REINFORCE
    objective over {"tokens", "targets", "mask", "adv"} batches.

    ``mask`` is 1.0 on completion positions (normalizer); ``adv`` is the
    per-token advantage*staleness-weight (signal). Prompt and padding
    positions are 0 in both, so the model is never trained to imitate
    the prompt."""

    def __init__(self, model: ModelDef):
        if model.logits is None:
            raise TypeError(
                f"family {model.cfg.family!r} exposes no bare logits "
                "forward — GRPO needs ModelDef.logits (dense / moe / "
                "vlm / ssm / hybrid)")
        self.inner = model
        self.cfg = model.cfg
        self.init = model.init

    def loss(self, params, batch, remat: bool = False):
        logits = self.inner.logits(params, batch["tokens"], remat=remat)
        logp = jax.nn.log_softmax(logits.astype(jnp.float32), axis=-1)
        chosen = jnp.take_along_axis(
            logp, batch["targets"][..., None].astype(jnp.int32),
            axis=-1)[..., 0]
        mask = batch["mask"]
        denom = jnp.maximum(mask.sum(), 1.0)
        loss = -(batch["adv"] * mask * chosen).sum() / denom
        metrics = {"loss": loss,
                   "mean_logp": (mask * chosen).sum() / denom,
                   "tokens": mask.sum()}
        return loss, metrics


# -- batching -----------------------------------------------------------------


@dataclasses.dataclass
class GRPOExample:
    """One rollout rendered into trainer arrays (all length L)."""
    inp: np.ndarray     # (L,) int32: full[:-1] padded
    tgt: np.ndarray     # (L,) int32: full[1:] padded
    mask: np.ndarray    # (L,) f32: 1 on completion targets
    adv: np.ndarray     # (L,) f32: advantage * weight on completion


def render_example(r: Rollout, advantage: float, weight: float,
                   seq_len: int, pad_id: int = 0) -> GRPOExample:
    """prompt+completion -> next-token arrays. Completion targets sit
    at positions [len(prompt)-1, len(prompt)-1+len(tokens)) of the
    shifted sequence; anything past seq_len is truncated."""
    full = np.concatenate([np.asarray(r.prompt, np.int32),
                           np.asarray(r.tokens, np.int32)])
    inp, tgt = full[:-1], full[1:]
    n = min(len(inp), seq_len)
    out_i = np.full(seq_len, pad_id, np.int32)
    out_t = np.full(seq_len, pad_id, np.int32)
    out_i[:n], out_t[:n] = inp[:n], tgt[:n]
    mask = np.zeros(seq_len, np.float32)
    lo = len(r.prompt) - 1
    hi = min(lo + len(r.tokens), seq_len)
    if hi > lo >= 0:
        mask[lo:hi] = 1.0
    return GRPOExample(out_i, out_t, mask,
                       mask * np.float32(advantage * weight))


class GRPOBatcher:
    """``ElasticTrainer.batch_provider`` backed by a pool of rendered
    rollouts.

    ``ingest()`` replaces the pool with the latest drained-and-scored
    rollouts; the provider cycles the pool deterministically (cursor
    mod pool size) to fill (H, k, b, L) stacks. When no rollouts have
    arrived yet (starved), it reuses the previous pool rather than
    stalling the trainer — with an all-zero fallback example before the
    first ingest, which contributes zero gradient."""

    def __init__(self, seq_len: int, batch_per_worker: int,
                 pad_id: int = 0):
        self.seq_len = int(seq_len)
        self.b = int(batch_per_worker)
        self.pad_id = pad_id
        z = np.zeros(self.seq_len, np.float32)
        zi = np.full(self.seq_len, pad_id, np.int32)
        self._pool: list[GRPOExample] = [GRPOExample(zi, zi, z, z)]
        self._cursor = 0
        self.starved_phases = 0
        self.ingested = 0

    def ingest(self, scored: Sequence[tuple[Rollout, float, float]]
               ) -> int:
        """Replace the pool. ``scored`` is (rollout, advantage, weight)
        triples; zero-advantage examples still enter the pool (they
        hold the normalizer honest) unless the whole batch is empty."""
        pool = [render_example(r, a, w, self.seq_len, self.pad_id)
                for r, a, w in scored]
        if pool:
            self._pool = pool
            self._cursor = 0
            self.ingested += len(pool)
        return len(pool)

    def __call__(self, global_step: int, h: int, k: int):
        if self.ingested == 0:
            self.starved_phases += 1
        need = h * k * self.b
        exs = []
        for _ in range(need):
            exs.append(self._pool[self._cursor % len(self._pool)])
            self._cursor += 1
        shape = (h, k, self.b, self.seq_len)
        stackf = lambda key: jnp.asarray(
            np.stack([getattr(e, key) for e in exs]).reshape(shape))
        return {"tokens": stackf("inp"), "targets": stackf("tgt"),
                "mask": stackf("mask"), "adv": stackf("adv")}
