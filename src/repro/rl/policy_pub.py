"""Trainer-side policy publication for the async RL tier.

At each outer boundary the trainer's fresh anchor becomes a **policy
version**: the :class:`PolicyPublisher` ships it as one link of a
quantized delta-checkpoint chain (``DeltaCheckpointer`` over a
``ChunkStore``) and serves it to rollout workers through a
:class:`PolicyPeer` (the swarm chunk protocol plus a ``policy_sha``
op). Versions are consecutive integers, reused as the chain's step
numbers.

Bit-exactness contract: the published policy IS the writer's
reconstruction (``DeltaCheckpointer.reference`` at publish time, which
for base versions equals the raw anchor exactly). Its tree sha is
recorded at publish; a worker that adopts version v must reproduce that
sha bit-for-bit — the delta chain guarantees it, and the driver/tests
assert it on every adoption.

Retention vs the lagging consumer (the race this module closes): the
publisher pins each live version's chain at publish time, so
``retire()``'s gc can never collect a version a slow worker may still
request — and a worker *mid-stream* on a retiring version is protected
a second time by the peer's per-session chain pin. Only a **forced**
retire tombstones the version (``ChunkStore.retire_step``), after
which a fetch fails with the typed :class:`PolicyRetiredError` (via
``StepRetiredError``) instead of hanging or serving a truncated chain —
the worker's signal to re-adopt the latest version.
"""
from __future__ import annotations

import hashlib
import json
from typing import Any

import numpy as np

from repro.checkpointing import checkpoint as _ckpt
from repro.checkpointing import (ChunkStore, DeltaCheckpointer,
                                 DeltaConfig, StepRetiredError)
from repro.checkpointing.swarm import ChunkPeer, _send_frame


class PolicyRetiredError(StepRetiredError):
    """The requested policy version was force-retired by the trainer:
    terminal for that version — re-adopt the latest instead."""


def tree_sha(tree: Any) -> str:
    """Order-stable sha256 over a pytree's leaves (key, shape, dtype,
    raw bytes) — the adoption bit-exactness witness."""
    h = hashlib.sha256()
    for key in sorted(flat := _ckpt._flatten(tree)):
        arr = np.ascontiguousarray(flat[key])
        h.update(key.encode())
        h.update(str(arr.shape).encode())
        h.update(str(arr.dtype).encode())
        h.update(arr.tobytes())
    return h.hexdigest()


class PolicyPeer(ChunkPeer):
    """ChunkPeer + ``{"op": "policy_sha", "version": v}`` -> the
    publisher-recorded reconstruction sha (or ``{"error":
    "unknown-version"}``), so workers verify adoption end-to-end over
    the wire rather than via in-process back-channels."""

    def __init__(self, store: ChunkStore, publisher: "PolicyPublisher",
                 **kw):
        self.publisher = publisher
        super().__init__(store, **kw)

    def _handle_op(self, conn, req, pins) -> bool:
        if req.get("op") == "policy_sha":
            sha = self.publisher.shas.get(int(req["version"]))
            body = {"sha": sha} if sha else \
                {"error": "unknown-version", "version": req["version"]}
            _send_frame(conn, json.dumps(body).encode())
            return True
        return super()._handle_op(conn, req, pins)


class PolicyPublisher:
    """Publishes trainer anchors as a delta chain of policy versions.

    ``keep_live`` bounds how many versions stay fetchable: publishing
    version v auto-retires (unforced) versions <= v - keep_live. An
    unforced retire only unpins + gcs — the chain-keeping gc and any
    consumer-session pins decide what physically survives. Forced
    retire additionally tombstones the version.
    """

    def __init__(self, store: ChunkStore | str, *, codec: str = "int8",
                 base_every: int = 8, keep_live: int = 4):
        self.store = store if isinstance(store, ChunkStore) \
            else ChunkStore(store)
        self.writer = DeltaCheckpointer(
            self.store, DeltaConfig(base_every=base_every, codec=codec))
        self.keep_live = int(keep_live)
        self.shas: dict[int, str] = {}      # version -> reconstruction sha
        self._pins: dict[int, dict] = {}    # version -> gc pin token
        self.latest: int | None = None
        self.retired: list[int] = []

    @property
    def live_versions(self) -> list[int]:
        return sorted(self._pins)

    def publish(self, version: int, tree: Any,
                meta: dict | None = None) -> dict:
        version = int(version)
        assert self.latest is None or version > self.latest, \
            f"versions must be monotone: {version} after {self.latest}"
        manifest = self.writer.save(
            version, tree, {"policy_version": version, **(meta or {})})
        # the publish-time reconstruction is the contract: what every
        # adopter must reproduce (== tree exactly for base versions)
        self.shas[version] = tree_sha(self.writer.reference(tree))
        self._pins[version] = self.store.pin_chain(version)
        self.latest = version
        rec = {"version": version, "kind": manifest["kind"],
               "sha": self.shas[version],
               "new_bytes": manifest["stats"]["new_bytes"],
               "logical_bytes": manifest["stats"]["logical_bytes"]}
        floor = version - self.keep_live
        for old in [v for v in self.live_versions if v <= floor]:
            self.retire(old)
        rec["live"] = self.live_versions
        return rec

    def safe_to_retire(self, version: int) -> bool:
        """True unless ``version`` is a chain link of a DIFFERENT live
        version: tombstoning a live chain's base/prev would make every
        dependent version unrestorable even though it is still pinned
        (the chain walk hits the tombstone mid-fetch)."""
        from repro.checkpointing.delta import chain_steps
        return not any(version in chain_steps(self.store, v)
                       for v in self.live_versions if v != version)

    def retire(self, version: int, *, force: bool = False) -> dict:
        """Withdraw ``version`` from retention. Unforced: drop its pin
        and gc — chunks shared with kept chains and chunks pinned by an
        in-flight consumer session all survive. Forced: also tombstone
        it so future fetches fail typed (PolicyRetiredError at the
        worker) instead of racing the gc; refused when the version is a
        chain dependency of a live one."""
        version = int(version)
        if force and not self.safe_to_retire(version):
            raise ValueError(
                f"version {version} is a chain link of live versions "
                f"{self.live_versions} — tombstoning it would sever "
                "their delta chains")
        token = self._pins.pop(version, None)
        if token is not None:
            self.store.unpin(token)
        if force:
            self.store.retire_step(version)
        self.retired.append(version)
        stats = self.store.gc(keep_steps=tuple(self._pins))
        return {"version": version, "forced": force, "gc": stats}

    def serve(self, port: int = 0) -> PolicyPeer:
        return PolicyPeer(self.store, self, port=port)
