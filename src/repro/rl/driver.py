"""End-to-end asynchronous decentralized RL driver (INTELLECT-2 shape).

One process plays every role so the whole protocol is testable on CPU,
mirroring how ``ElasticTrainer`` simulates the DiLoCo cluster:

    trainer (ElasticTrainer + GRPO loss)
        └─ boundary_hook ──> PolicyPublisher ──> delta chain v0,v1,...
                                   │ PolicyPeer (swarm protocol)
    rollout workers (ContinuousEngine, capture_logprobs)
        └─ adopt(v) on their own cadence ──> generate ──> RolloutBuffer
                                   │
    outer boundary: drain(staleness window) -> rewards -> GRPO
    advantages -> GRPOBatcher -> next inner phase's batches

Per outer step t: (churn) -> workers adopt on their stride -> generate
one round of grouped completions -> drain the buffer against the
CURRENT version (staleness ledger) -> score + group-normalize -> ingest
into the batcher -> ``trainer.run(1)`` (whose boundary hook publishes
version t+1). Version t is therefore always one boundary ahead of the
freshest rollout that can train on it — the async lag is structural,
not an artifact.
"""
from __future__ import annotations

import dataclasses
import pathlib

import jax
import numpy as np

from repro.configs import CONFIGS
from repro.core import diloco as dl
from repro.core.fault_tolerance import ClusterSimulator
from repro.data.pipeline import DataConfig
from repro.models.registry import get_model
from repro.rl.buffer import RolloutBuffer
from repro.rl.grpo import (GRPOBatcher, GRPOModel, group_advantages,
                           toy_low_token_reward)
from repro.rl.policy_pub import PolicyPublisher, PolicyRetiredError
from repro.rl.rollout import RolloutWorker
from repro.train.loop import ElasticTrainer, TrainerConfig


@dataclasses.dataclass
class RLConfig:
    arch: str = "internlm2-1.8b"   # reduced() of this preset
    n_workers: int = 2
    outer_steps: int = 6
    inner_steps: int = 2
    trainer_workers: int = 2       # DiLoCo slots in the stacked sim
    batch_per_worker: int = 2
    seq_len: int = 32
    n_groups: int = 4              # GRPO groups per outer step
    group_size: int = 4            # completions per group
    prompt_len: int = 6
    max_new: int = 10
    temperature: float = 1.0
    inner_lr: float = 5e-3
    max_policy_lag: int = 1
    stale_mode: str = "drop"       # 'drop' | 'downweight'
    stale_gamma: float = 0.5
    codec: str = "int8"            # policy delta chain codec
    base_every: int = 4
    keep_live: int = 4
    # worker i re-adopts every adopt_strides[i % len] outer steps; a
    # stride above max_policy_lag+1 makes that worker's tail rollouts
    # provably stale (the ledger must show the drops)
    adopt_strides: tuple = (1, 3)
    kill_at: int | None = None     # outer step to crash kill_worker
    rejoin_at: int | None = None
    kill_worker: int = 1
    force_retire_at: int | None = None  # tombstone the oldest version
    seed: int = 0


class RLDriver:
    """Builds the fleet under ``root`` (publisher store + one store per
    worker) and runs the async RL loop. ``run()`` returns a summary the
    benchmark/launcher serialize directly."""

    def __init__(self, cfg: RLConfig, root: str | pathlib.Path):
        assert cfg.prompt_len + cfg.max_new <= cfg.seq_len + 1, \
            "rollouts longer than the training seq_len would truncate"
        self.cfg = cfg
        self.root = pathlib.Path(root)
        arch = CONFIGS[cfg.arch].reduced()
        self.arch = arch
        self.model = get_model(arch)
        params, _ = self.model.init(jax.random.PRNGKey(cfg.seed))
        self.publisher = PolicyPublisher(
            str(self.root / "pub"), codec=cfg.codec,
            base_every=cfg.base_every, keep_live=cfg.keep_live)
        self.peer = self.publisher.serve()
        self.peers = [self.peer.addr]
        self.workers = [
            RolloutWorker(i, self.model, params,
                          str(self.root / f"worker{i}"),
                          max_len=cfg.prompt_len + cfg.max_new + 2,
                          seed=cfg.seed)
            for i in range(cfg.n_workers)]
        self.buffer = RolloutBuffer()
        self.batcher = GRPOBatcher(cfg.seq_len, cfg.batch_per_worker)
        dcfg = DataConfig(vocab=arch.vocab, seq_len=cfg.seq_len,
                          batch_per_worker=cfg.batch_per_worker,
                          total_steps=cfg.outer_steps * cfg.inner_steps)
        tcfg = TrainerConfig(
            diloco=dl.DiLoCoConfig(inner_steps=cfg.inner_steps,
                                   quant="int8"),
            inner_lr=cfg.inner_lr, max_workers=cfg.trainer_workers)
        self.trainer = ElasticTrainer(
            GRPOModel(self.model), tcfg, dcfg, params,
            ClusterSimulator(list(range(cfg.trainer_workers))),
            batch_provider=self.batcher,
            boundary_hook=self._publish_hook)
        # v0: the initial anchor, published before any rollout so the
        # fleet never samples from an unpublished policy
        self._published = 0
        self.publisher.publish(0, {"params": self.trainer.outer.anchor})
        self.step_recs: list[dict] = []
        self.retired_fallbacks = 0
        self.sha_failures = 0

    # -- trainer boundary -> policy version -----------------------------------

    def _publish_hook(self, t: int, trainer) -> dict:
        self._published += 1
        return self.publisher.publish(
            self._published, {"params": trainer.outer.anchor},
            meta={"outer_step": t})

    # -- rollout round --------------------------------------------------------

    def _prompts(self, t: int) -> list[tuple[np.ndarray, int]]:
        """(prompt, group) pairs for step t: each group shares ONE
        prompt (GRPO's baseline is within-group), drawn from [2, vocab)
        so pad/eos never appear mid-prompt. Deterministic in (seed, t)."""
        out = []
        for g in range(self.cfg.n_groups):
            rng = np.random.default_rng(
                (self.cfg.seed * 100003 + t * 131 + g) % (2**31))
            p = rng.integers(2, self.arch.vocab, size=self.cfg.prompt_len,
                             dtype=np.int64).astype(np.int32)
            out.extend([(p, g)] * self.cfg.group_size)
        return out

    def _rollout_round(self, t: int) -> dict:
        alive = [w for w in self.workers if w.alive]
        assert alive, "entire rollout fleet is dead"
        work = self._prompts(t)
        shares = {w.wid: [] for w in alive}
        for i, item in enumerate(work):
            shares[alive[i % len(alive)].wid].append(item)
        stats = []
        for w in alive:
            if not shares[w.wid]:
                continue
            prompts = [p for p, _ in shares[w.wid]]
            groups = [g for _, g in shares[w.wid]]
            rollouts, st = w.generate(
                prompts, groups=groups, max_new=self.cfg.max_new,
                temperature=self.cfg.temperature)
            self.buffer.add(rollouts)
            stats.append(st)
        return {"workers": stats,
                "tokens": sum(s["tokens"] for s in stats),
                "wall_s": sum(s["wall_s"] for s in stats)}

    # -- one outer step -------------------------------------------------------

    def _adopt_round(self, t: int) -> list[dict]:
        recs = []
        strides = self.cfg.adopt_strides
        for i, w in enumerate(self.workers):
            if not w.alive:
                continue
            stride = max(1, strides[i % len(strides)])
            if t % stride == 0 or w.version is None:
                rec = w.adopt(self.peers)
                if not rec["sha_verified"]:
                    self.sha_failures += 1
                recs.append(rec)
        return recs

    def _maybe_churn(self, t: int) -> dict:
        c, rec = self.cfg, {}
        if c.kill_at is not None and t == c.kill_at:
            self.workers[c.kill_worker].kill()
            rec["killed"] = c.kill_worker
        if c.rejoin_at is not None and t == c.rejoin_at and \
                not self.workers[c.kill_worker].alive:
            self.workers[c.kill_worker].rejoin(self.peers)
            rec["rejoined"] = c.kill_worker
        if c.force_retire_at is not None and t == c.force_retire_at:
            # oldest live version that is NOT a chain link of a newer
            # one (the publisher refuses to tombstone chain links)
            safe = [v for v in self.publisher.live_versions[:-1]
                    if self.publisher.safe_to_retire(v)]
            if not safe:
                rec["force_retired"] = None
                return rec
            old = safe[0]
            self.publisher.retire(old, force=True)
            rec["force_retired"] = old
            # a lagging consumer asking for the tombstoned version must
            # get the typed terminal error, then recover on the latest
            try:
                self.workers[0].adopt(self.peers, version=old)
            except PolicyRetiredError:
                self.retired_fallbacks += 1
                self.workers[0].adopt(self.peers)
            else:
                raise AssertionError(
                    f"adopting retired v{old} did not raise")
        return rec

    def step(self, t: int) -> dict:
        rec = {"outer_step": t, "churn": self._maybe_churn(t)}
        rec["adoptions"] = self._adopt_round(t)
        rec["rollout"] = self._rollout_round(t)
        current = self.publisher.latest
        drained = self.buffer.drain(
            current, self.cfg.max_policy_lag, mode=self.cfg.stale_mode,
            stale_gamma=self.cfg.stale_gamma)
        rewards = [toy_low_token_reward(r.tokens, self.arch.vocab)
                   for r, _ in drained]
        for (r, _), rew in zip(drained, rewards):
            r.reward = rew
        advs = group_advantages(rewards, [r.group for r, _ in drained])
        self.batcher.ingest(
            [(r, float(a), w) for (r, w), a in zip(drained, advs)])
        lags = [current - r.version for r, _ in drained]
        rec["train"] = self.trainer.run(1)[-1]
        rec.update(
            version=current,
            mean_reward=float(np.mean(rewards)) if rewards else 0.0,
            accepted=len(drained),
            mean_accepted_lag=float(np.mean(lags)) if lags else 0.0,
            loss=rec["train"]["loss"])
        self.step_recs.append(rec)
        return rec

    # -- full run -------------------------------------------------------------

    def run(self) -> dict:
        for t in range(self.cfg.outer_steps):
            self.step(t)
        return self.summary()

    def summary(self) -> dict:
        led = self.buffer.ledger.as_dict()
        rounds = [r["rollout"] for r in self.step_recs]
        tok = sum(r["tokens"] for r in rounds)
        wall = sum(r["wall_s"] for r in rounds)
        adopts = [a for r in self.step_recs for a in r["adoptions"]]
        rewards = [r["mean_reward"] for r in self.step_recs]
        return {
            "outer_steps": len(self.step_recs),
            "versions_published": self._published + 1,
            "reward_trend": rewards,
            "reward_first": rewards[0] if rewards else None,
            "reward_last": rewards[-1] if rewards else None,
            "loss_trend": [r["loss"] for r in self.step_recs],
            "ledger": led,
            "stale_drop_fraction":
                led["dropped_stale"] / max(1, led["generated"]),
            "mean_accepted_lag": float(np.mean(
                [r["mean_accepted_lag"] for r in self.step_recs]))
                if self.step_recs else 0.0,
            "rollout_tok_s": tok / wall if wall > 0 else 0.0,
            "rollout_tokens": tok,
            "adoptions": len(adopts),
            "mean_adopt_s": float(np.mean(
                [a["adopt_s"] for a in adopts])) if adopts else 0.0,
            "adopt_bytes": sum(a["bytes_fetched"] for a in adopts),
            "bit_exact": self.sha_failures == 0 and
                all(a["sha_verified"] for a in adopts),
            "retired_fallbacks": self.retired_fallbacks,
            "live_versions": self.publisher.live_versions,
            "starved_phases": self.batcher.starved_phases,
        }

    def close(self) -> None:
        self.peer.close()
