from repro.serving.engine import (ContinuousEngine, Request, ServeEngine,
                                  WaveEngine, make_engine)
from repro.serving.swarm_serve import (ReplayBudgetError, StageRPCError,
                                       StageServer, StageUnservableError,
                                       SwarmRouter, publish_stages,
                                       restore_stage_params,
                                       stage_chunk_id)

__all__ = ["Request", "ServeEngine", "WaveEngine", "ContinuousEngine",
           "make_engine",
           "StageServer", "SwarmRouter", "publish_stages",
           "restore_stage_params", "stage_chunk_id",
           "StageUnservableError", "ReplayBudgetError", "StageRPCError"]
