from repro.serving.engine import (ContinuousEngine, Request, ServeEngine,
                                  WaveEngine, make_engine)

__all__ = ["Request", "ServeEngine", "WaveEngine", "ContinuousEngine",
           "make_engine"]
