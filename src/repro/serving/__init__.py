from repro.serving.engine import Request, ServeEngine

__all__ = ["Request", "ServeEngine"]
