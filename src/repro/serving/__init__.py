from repro.serving.engine import (ContinuousEngine, Request, ServeEngine,
                                  WaveEngine, make_engine)
from repro.serving.paging import (BlockPool, BlockPoolExhaustedError,
                                  PagedEngine, PrefixIndex,
                                  build_paged_cache, chain_digests)
from repro.serving.swarm_serve import (ReplayBudgetError, StageRPCError,
                                       StageServer, StageUnservableError,
                                       SwarmRouter, publish_stages,
                                       restore_stage_params,
                                       stage_chunk_id)

__all__ = ["Request", "ServeEngine", "WaveEngine", "ContinuousEngine",
           "make_engine",
           "PagedEngine", "BlockPool", "BlockPoolExhaustedError",
           "PrefixIndex", "build_paged_cache", "chain_digests",
           "StageServer", "SwarmRouter", "publish_stages",
           "restore_stage_params", "stage_chunk_id",
           "StageUnservableError", "ReplayBudgetError", "StageRPCError"]
