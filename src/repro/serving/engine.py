"""Batched serving engine over the model zoo's prefill/decode steps.

Wave-scheduled static batching: when all slots are free, up to
``batch_slots`` queued requests are admitted together — prompts are
padded to a common length and prefilled in one batched call — then the
wave decodes in lockstep, one token per engine step, retiring requests
on EOS/max-tokens and finishing when the whole wave is done. (The KV/SSM
cache tracks a single sequence length per layer, so admission happens at
wave boundaries; per-slot continuous batching would need per-slot length
bookkeeping — noted as future work.)

Serving is not a PRIME contribution — the paper trains — but the
assigned decode/long shapes require a real serve_step; this engine is
the production wrapper around it.
"""
from __future__ import annotations

import dataclasses
from collections import deque

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ShapeConfig


@dataclasses.dataclass
class Request:
    rid: int
    prompt: np.ndarray            # (S,) int32
    max_new_tokens: int = 32
    out_tokens: list = dataclasses.field(default_factory=list)
    done: bool = False


class ServeEngine:
    def __init__(self, model, params, *, batch_slots: int = 4,
                 max_len: int = 512, eos_id: int = 1,
                 pad_id: int = 0):
        self.model = model
        self.params = params
        self.slots = batch_slots
        self.max_len = max_len
        self.eos_id = eos_id
        self.pad_id = pad_id
        self.queue: deque[Request] = deque()
        self.active: list[Request | None] = [None] * batch_slots
        self.cache = None
        self.tokens = None
        self.remaining = np.zeros((batch_slots,), np.int64)
        self._decode = jax.jit(lambda p, t, c: model.decode(p, t, c))
        self._prefill = jax.jit(lambda p, b, c: model.prefill(p, b, c))
        self.stats = {"waves": 0, "decode_steps": 0, "tokens_out": 0}

    def submit(self, req: Request) -> None:
        self.queue.append(req)

    def _admit_wave(self) -> bool:
        if not self.queue:
            return False
        wave: list[Request] = []
        while self.queue and len(wave) < self.slots:
            wave.append(self.queue.popleft())
        # left-pad prompts to a common length (causal => pads attend
        # nothing useful but are masked out of the loss-free decode)
        plen = max(len(w.prompt) for w in wave)
        tokens = np.full((self.slots, plen), self.pad_id, np.int32)
        for i, w in enumerate(wave):
            tokens[i, plen - len(w.prompt):] = w.prompt
        shape = ShapeConfig("serve", "decode", self.max_len, self.slots)
        self.cache = self.model.init_cache(self.slots, shape)
        logits, self.cache = self._prefill(
            self.params, {"tokens": jnp.asarray(tokens)}, self.cache)
        first = jnp.argmax(logits, axis=-1)
        self.tokens = first[:, None].astype(jnp.int32)
        for i in range(self.slots):
            if i < len(wave):
                self.active[i] = wave[i]
                wave[i].out_tokens.append(int(first[i]))
                self.remaining[i] = wave[i].max_new_tokens - 1
            else:
                self.active[i] = None
                self.remaining[i] = 0
        self.stats["waves"] += 1
        return True

    def step(self) -> int:
        """One engine iteration; returns number of active slots."""
        if not any(r is not None for r in self.active):
            if not self._admit_wave():
                return 0
        logits, self.cache = self._decode(self.params, self.tokens,
                                          self.cache)
        next_tok = jnp.argmax(logits, axis=-1)
        self.stats["decode_steps"] += 1
        for slot, req in enumerate(self.active):
            if req is None:
                continue
            tok = int(next_tok[slot])
            req.out_tokens.append(tok)
            self.stats["tokens_out"] += 1
            self.remaining[slot] -= 1
            if tok == self.eos_id or self.remaining[slot] <= 0:
                req.done = True
                self.active[slot] = None
        self.tokens = next_tok[:, None].astype(jnp.int32)
        return sum(r is not None for r in self.active)

    def run_until_drained(self, max_steps: int = 10_000) -> None:
        for _ in range(max_steps):
            if self.step() == 0 and not self.queue:
                return
