"""Serving engines over the model zoo's prefill/decode steps.

Two schedulers share one protocol (submit / step / run_until_drained):

* ``WaveEngine`` — the legacy static batcher, kept as the A/B foil:
  admission only at wave boundaries (a finished request's slot idles
  until the WHOLE wave drains) and one host round-trip per slot per
  decoded token (``int(next_tok[slot])``).

* ``ContinuousEngine`` — slot-level continuous batching with the decode
  loop kept on device:
    - the (B-slot) cache is allocated ONCE; per-slot cache lengths
      (``KVCache.length`` is (B,)) let a new request prefill into a
      free slot while the other slots keep decoding — no wave barrier;
    - admission prefills ONE request (batch 1, prompt right-padded to a
      power-of-two bucket so prefill recompiles are capped at
      O(log max_len); exact per-slot semantics via ``prompt_len``) and
      inserts the filled sub-cache into its slot with a jitted
      tree-wide dynamic_update_slice;
    - decoding runs N steps as one jitted ``lax.scan`` with ON-DEVICE
      sampling (greedy + temperature/top-k), per-slot EOS/budget done
      flags, and a single device->host transfer of the (N, B) token
      block — the per-token sync cost is amortized N-fold.

Both engines produce BIT-IDENTICAL greedy outputs (right-padded exact
prefill everywhere; tests assert it), so the A/B benchmark in
``benchmarks/serve_bench.py`` measures pure scheduling + sync overhead.
MoE capacity is forced to no-drop on the serving paths so expert
contention never couples slots (see moe.apply_moe).
"""
from __future__ import annotations

import dataclasses
import time
from collections import deque

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ShapeConfig

MIN_BUCKET = 8        # smallest prompt pad bucket
NEG_INF = -1e30


@dataclasses.dataclass
class Request:
    rid: int
    prompt: np.ndarray            # (S,) int32
    max_new_tokens: int = 32
    temperature: float = 0.0      # 0 = greedy (wave engine is greedy-only)
    out_tokens: list = dataclasses.field(default_factory=list)
    # log-prob of each emitted token under the SAMPLING distribution
    # (logits/temperature, pre-top-k; temp==0 scores the unscaled
    # softmax). Aligned 1:1 with out_tokens; filled only when the
    # engine runs with capture_logprobs=True (the RL rollout path).
    out_logprobs: list = dataclasses.field(default_factory=list)
    done: bool = False
    t_submit: float | None = None
    t_first: float | None = None  # first token available
    t_done: float | None = None


def bucket_len(n: int) -> int:
    """Next power of two >= n (floor MIN_BUCKET)."""
    b = MIN_BUCKET
    while b < n:
        b *= 2
    return b


def nucleus_mask(scaled: jnp.ndarray, top_p: float) -> jnp.ndarray:
    """(B, V) temperature-scaled logits -> bool keep-mask of the
    smallest token set whose probability mass reaches ``top_p``.

    On-device sorted-cumsum: sort descending, softmax, keep tokens
    while the mass BEFORE them is < top_p (so the top-1 token always
    survives and the set is minimal); the kept set maps back to vocab
    order via a per-row logit threshold (ties at the threshold are all
    kept)."""
    srt = jnp.sort(scaled, axis=-1)[..., ::-1]
    probs = jax.nn.softmax(srt, axis=-1)
    cum = jnp.cumsum(probs, axis=-1)
    keep = (cum - probs) < top_p
    thresh = jnp.min(jnp.where(keep, srt, jnp.inf), axis=-1,
                     keepdims=True)
    return scaled >= thresh


def sample_tokens(logits: jnp.ndarray, key, temps: jnp.ndarray,
                  top_k: int = 0, top_p: float = 0.0) -> jnp.ndarray:
    """On-device per-slot sampling. logits (B, V), temps (B,).

    temp == 0 -> greedy (bitwise argmax, matching the wave engine);
    temp > 0 -> categorical over logits/temp, optionally top-k- and/or
    nucleus (top-p)-masked (nucleus applies first, on the scaled
    distribution; top-k then picks from the surviving set).
    ``key`` is either one key for the whole batch (legacy: categorical
    draws independent gumbels per row, but the draw depends on the
    slot's NEIGHBORS) or a (B, 2) stack of PER-SLOT keys — each slot
    then consumes its own deterministic key stream, so a request's
    sampled tokens are reproducible regardless of slot placement or
    co-batched traffic."""
    lg = logits.astype(jnp.float32)
    greedy = jnp.argmax(lg, axis=-1).astype(jnp.int32)
    safe = jnp.where(temps > 0, temps, 1.0)[:, None]
    if top_p and top_p > 0.0:
        lg = jnp.where(nucleus_mask(lg / safe, top_p), lg, NEG_INF)
    per_slot = key.ndim == 2
    if top_k and top_k > 0:
        vals, idx = jax.lax.top_k(lg, top_k)
        scaled = vals / safe
        if per_slot:
            choice = jax.vmap(jax.random.categorical)(key, scaled)
        else:
            choice = jax.random.categorical(key, scaled)
        sampled = jnp.take_along_axis(idx, choice[:, None], axis=-1)[:, 0]
    elif per_slot:
        sampled = jax.vmap(jax.random.categorical)(key, lg / safe)
    else:
        sampled = jax.random.categorical(key, lg / safe)
    return jnp.where(temps > 0, sampled.astype(jnp.int32), greedy)


def chosen_logprob(logits: jnp.ndarray, toks: jnp.ndarray,
                   temps: jnp.ndarray) -> jnp.ndarray:
    """Log-prob of ``toks`` (B,) under softmax(logits/temp) per slot —
    the behavior-policy score an RL trainer needs next to each sampled
    token. temp==0 slots score the unscaled distribution (greedy picks
    the argmax, so this is its actual, finite log-mass)."""
    lg = logits.astype(jnp.float32)
    safe = jnp.where(temps > 0, temps, 1.0)[:, None]
    logp = jax.nn.log_softmax(lg / safe, axis=-1)
    return jnp.take_along_axis(
        logp, toks[:, None].astype(jnp.int32), axis=-1)[:, 0]


def bucket_batch(n: int) -> int:
    """Next power of two >= n (floor 1) — admission prefill batch
    buckets, so batched admission adds O(log slots) compiles, not one
    per occupancy pattern."""
    b = 1
    while b < n:
        b *= 2
    return b


def tree_take_slot(big, like1, idx, batch: int):
    """Extract row ``idx`` of a B-batch cache pytree as a batch-1
    pytree (the inverse of ``tree_insert_slot``): per leaf, a
    dynamic_slice along the batch axis, statically inferred as the
    unique axis where the big leaf has B and the batch-1 template leaf
    has 1."""
    def leaf(bl, ll):
        if batch == 1 and bl.shape == ll.shape:
            return bl
        for a in range(bl.ndim):
            if (bl.shape[a] == batch and ll.shape[a] == 1
                    and bl.shape[:a] == ll.shape[:a]
                    and bl.shape[a + 1:] == ll.shape[a + 1:]):
                return jax.lax.dynamic_slice_in_dim(bl, idx, 1, axis=a)
        raise ValueError(
            f"no batch axis: big {bl.shape} vs template {ll.shape}")
    return jax.tree.map(leaf, big, like1)


def tree_insert_slot(big, sub, slot, batch: int):
    """Insert a batch-1 cache pytree into slot ``slot`` of a B-slot
    cache: per leaf, a dynamic_update_slice along the (statically
    inferred) batch axis. Works across families — stacked KV (L, B, S,
    Hk, dh), per-slot lengths (L, B)/(B,), SSM states (L, B, H, P, N),
    conv rings, cross caches — because the batch axis is the unique
    axis where the big leaf has B and the sub leaf has 1."""
    def leaf(bl, sl):
        if batch == 1 and bl.shape == sl.shape:
            return sl.astype(bl.dtype)
        for a in range(bl.ndim):
            if (bl.shape[a] == batch and sl.shape[a] == 1
                    and bl.shape[:a] == sl.shape[:a]
                    and bl.shape[a + 1:] == sl.shape[a + 1:]):
                return jax.lax.dynamic_update_slice_in_dim(
                    bl, sl.astype(bl.dtype), slot, axis=a)
        raise ValueError(
            f"no batch axis: big {bl.shape} vs sub {sl.shape}")
    return jax.tree.map(leaf, big, sub)


class _EngineBase:
    kind = ""

    def __init__(self, model, params, *, batch_slots: int = 4,
                 max_len: int = 512, eos_id: int = 1, pad_id: int = 0,
                 bucket_prompts: bool = True):
        self.model = model
        self.params = params
        self.slots = batch_slots
        self.max_len = max_len
        self.eos_id = eos_id
        self.pad_id = pad_id
        self.bucket_prompts = bucket_prompts
        self.cfg = getattr(model, "cfg", None)
        self.shape = ShapeConfig("serve", "decode", max_len, batch_slots)
        self.queue: deque[Request] = deque()
        self.active: list[Request | None] = [None] * batch_slots
        self.latencies: list[float] = []
        self.wall: float = 0.0
        self.stats = {"decode_steps": 0, "tokens_out": 0,
                      "host_syncs": 0, "admitted": 0,
                      "busy_slot_steps": 0, "total_slot_steps": 0,
                      "prefill_widths": set()}

    def submit(self, req: Request) -> None:
        req.t_submit = time.perf_counter()
        self.queue.append(req)

    def reset_metrics(self) -> None:
        """Zero counters/latencies (keeps compiled functions and device
        state) — lets benchmarks time a post-warmup run."""
        for k, v in self.stats.items():
            self.stats[k] = set() if isinstance(v, set) else 0
        self.latencies = []
        self.wall = 0.0

    # -- admission helpers ----------------------------------------------------

    def _padded_len(self, n: int) -> int:
        """Pad width for an n-token prompt: power-of-two bucket so the
        prefill jit cache stays O(log max_len) entries. Safe for SWA
        rings at any width — the rolling prefill write gathers each
        slot's newest in-window positions (see transformer.prefill)."""
        if not self.bucket_prompts:
            return n
        return max(min(bucket_len(n), self.max_len), n)

    def _budget(self, req: Request) -> int:
        """Total tokens this request may emit (cache-capacity-clamped
        for non-rolling attention caches; SSM state and SWA rings are
        O(1)/wrapping, so no cap there)."""
        cfg = self.cfg
        capless = (getattr(cfg, "sliding_window", None) is not None
                   or (getattr(cfg, "family", "") in ("ssm", "hybrid")
                       and not getattr(cfg, "attn_every", None)))
        if capless:
            return max(1, req.max_new_tokens)
        return max(1, min(req.max_new_tokens,
                          self.max_len - len(req.prompt)))

    def _retire(self, req: Request) -> None:
        req.done = True
        req.t_done = time.perf_counter()
        self.latencies.append(req.t_done - req.t_submit)

    # -- protocol -------------------------------------------------------------

    def step(self) -> int:
        raise NotImplementedError

    def run_until_drained(self, max_steps: int = 10_000) -> None:
        t0 = time.perf_counter()
        for _ in range(max_steps):
            if self.step() == 0 and not self.queue:
                break
        self.wall += time.perf_counter() - t0

    def perf_summary(self) -> dict:
        lat = sorted(self.latencies)
        pct = (lambda p: lat[min(len(lat) - 1,
                                 int(p / 100 * len(lat)))]) if lat \
            else (lambda p: float("nan"))
        occ = (self.stats["busy_slot_steps"]
               / max(1, self.stats["total_slot_steps"]))
        return {
            "engine": self.kind,
            "requests": len(lat),
            "tokens_out": self.stats["tokens_out"],
            "decode_steps": self.stats["decode_steps"],
            "wall_s": self.wall,
            "tokens_per_s": self.stats["tokens_out"] / self.wall
            if self.wall else float("nan"),
            "latency_p50_s": pct(50),
            "latency_p95_s": pct(95),
            "slot_occupancy": occ,
            "host_syncs": self.stats["host_syncs"],
            "prefill_widths": sorted(self.stats["prefill_widths"]),
        }


# -- wave (static) batching ---------------------------------------------------


class WaveEngine(_EngineBase):
    """Wave-scheduled static batching (the seed engine, modernized to
    the per-slot cache): all-free admission, lockstep decode, one host
    sync per slot per token. Greedy-only."""
    kind = "wave"

    def __init__(self, model, params, **kw):
        super().__init__(model, params, **kw)
        self.cache = None
        self.tokens = None
        self.remaining = np.zeros((self.slots,), np.int64)
        self._decode = jax.jit(lambda p, t, c: model.decode(p, t, c))
        self._prefill = jax.jit(lambda p, b, c: model.prefill(p, b, c))
        self._cache0 = model.init_cache(self.slots, self.shape)
        self.stats["waves"] = 0

    def submit(self, req: Request) -> None:
        if req.temperature > 0:
            raise ValueError(
                "WaveEngine is greedy-only (it exists as the A/B "
                "foil); use ContinuousEngine for sampled requests")
        super().submit(req)

    def _admit_wave(self) -> bool:
        if not self.queue:
            return False
        wave: list[Request] = []
        while self.queue and len(wave) < self.slots:
            wave.append(self.queue.popleft())
        for w in wave:
            assert 1 <= len(w.prompt) <= self.max_len, \
                f"prompt length {len(w.prompt)} vs max_len {self.max_len}"
        padded = self._padded_len(max(len(w.prompt) for w in wave))
        tokens = np.full((self.slots, padded), self.pad_id, np.int32)
        plen = np.ones((self.slots,), np.int32)
        for i, w in enumerate(wave):
            tokens[i, :len(w.prompt)] = w.prompt        # RIGHT-pad
            plen[i] = len(w.prompt)
        self.stats["prefill_widths"].add(padded)
        logits, self.cache = self._prefill(
            self.params,
            {"tokens": jnp.asarray(tokens),
             "prompt_len": jnp.asarray(plen)},
            self._cache0)
        first = jnp.argmax(logits, axis=-1)
        self.tokens = first[:, None].astype(jnp.int32)
        now = time.perf_counter()
        for i in range(self.slots):
            req = wave[i] if i < len(wave) else None
            self.active[i] = req
            self.remaining[i] = 0
            if req is None:
                continue
            tok = int(first[i])
            req.out_tokens.append(tok)
            req.t_first = now
            self.stats["tokens_out"] += 1
            budget = self._budget(req)
            self.remaining[i] = budget - 1
            if tok == self.eos_id or budget <= 1:
                self._retire(req)
                self.active[i] = None
        self.stats["waves"] += 1
        self.stats["admitted"] += len(wave)
        return True

    def step(self) -> int:
        """One engine iteration; returns number of active slots."""
        if not any(r is not None for r in self.active):
            if not self._admit_wave():
                return 0
        logits, self.cache = self._decode(self.params, self.tokens,
                                          self.cache)
        next_tok = jnp.argmax(logits, axis=-1)
        self.stats["decode_steps"] += 1
        self.stats["total_slot_steps"] += self.slots
        for slot, req in enumerate(self.active):
            if req is None:
                continue
            tok = int(next_tok[slot])           # host sync PER TOKEN
            self.stats["host_syncs"] += 1
            self.stats["busy_slot_steps"] += 1
            req.out_tokens.append(tok)
            self.stats["tokens_out"] += 1
            self.remaining[slot] -= 1
            if tok == self.eos_id or self.remaining[slot] <= 0:
                self._retire(req)
                self.active[slot] = None        # idles until wave drains
        self.tokens = next_tok[:, None].astype(jnp.int32)
        return sum(r is not None for r in self.active)


# -- continuous (per-slot) batching -------------------------------------------


class ContinuousEngine(_EngineBase):
    """Slot-level continuous batching with a device-resident decode
    loop. ``decode_chunk`` is the scheduling quantum: admissions and
    retirements happen between chunks; within a chunk the device runs
    ``lax.scan`` over decode+sample steps and ships one (N, B) token
    block to the host."""
    kind = "continuous"

    def __init__(self, model, params, *, decode_chunk: int = 8,
                 top_k: int = 0, top_p: float = 0.0, seed: int = 0,
                 batch_admit: bool = True, overlap_admission: bool = False,
                 capture_logprobs: bool = False, **kw):
        super().__init__(model, params, **kw)
        self.decode_chunk = decode_chunk
        self.top_k = top_k
        self.top_p = top_p
        self.batch_admit = batch_admit
        # overlap admission prefill with the in-flight decode chunk:
        # after the chunk is DISPATCHED (before its blocking host
        # read), queued requests prefill into B=1 sub-caches the device
        # can overlap with the running scan; they splice at the next
        # chunk boundary. Bit-identical to serial admission — per-rid
        # PRNG streams and exact right-padded prefill are placement-
        # and timing-independent.
        self.overlap_admission = overlap_admission
        self._prepped: deque = deque()
        # RL rollout mode: the decode scan additionally emits each
        # sampled token's log-prob (one extra (N, B) row in the same
        # host transfer). Off by default — the serving path's compiled
        # program is unchanged when disabled.
        self.capture_logprobs = capture_logprobs
        self.cache = model.init_cache(self.slots, self.shape)
        self._pcache0 = model.init_cache(1, self.shape)  # prefill template
        self._pcaches = {1: self._pcache0}   # per-batch-bucket templates
        self.tokens = jnp.full((self.slots, 1), self.pad_id, jnp.int32)
        self.done = jnp.ones((self.slots,), bool)
        self.remaining = jnp.zeros((self.slots,), jnp.int32)
        self.temps = jnp.zeros((self.slots,), jnp.float32)
        # per-slot PRNG streams: each request's stream is seeded from
        # (engine seed, request id) at admission, so its temperature /
        # top-k draws are reproducible REGARDLESS of which slot it
        # lands in or what else is co-batched
        self.base_key = jax.random.PRNGKey(seed)
        self.slot_keys = jnp.zeros((self.slots, 2), jnp.uint32)
        self._pending_first: list = [None] * self.slots
        self._prefill = jax.jit(lambda p, b, c: model.prefill(p, b, c))
        self._admit_jit = jax.jit(self._admit_fn)
        self._chunk_jit = jax.jit(self._chunk_fn,
                                  static_argnames=("n",))
        self.stats["decode_chunks"] = 0
        self.stats["prefills"] = 0
        self.stats["admit_batch_max"] = 0

    # -- device-side pieces ---------------------------------------------------

    def _admit_fn(self, cache, tokens, done, remaining, temps,
                  slot_keys, sub_cache, logits, slot, budget, temp,
                  rid):
        """Insert a freshly prefilled request into ``slot``: cache
        splice + first-token sample + per-slot state reset, one jit.
        The request's PRNG stream is derived from (engine seed, rid) —
        slot placement never enters the key chain."""
        cache = tree_insert_slot(cache, sub_cache, slot, self.slots)
        return self._admit_state(cache, tokens, done, remaining, temps,
                                 slot_keys, logits, slot, budget, temp,
                                 rid)

    def _admit_state(self, cache, tokens, done, remaining, temps,
                     slot_keys, logits, slot, budget, temp, rid):
        """Post-splice half of admission: first-token sample + per-slot
        scheduler state reset (shared by the dense splice and the paged
        engine's block-table paths)."""
        req_key = jax.random.fold_in(self.base_key, rid)
        k_first, k_stream = jax.random.split(req_key)
        first = sample_tokens(logits, k_first[None, :],
                              jnp.reshape(temp, (1,)).astype(jnp.float32),
                              self.top_k, self.top_p)     # (1,)
        tokens = jax.lax.dynamic_update_slice(
            tokens, first.reshape(1, 1).astype(jnp.int32), (slot, 0))
        budget = jnp.reshape(budget, (1,)).astype(jnp.int32)
        first_done = (first == self.eos_id) | (budget <= 0)
        done = jax.lax.dynamic_update_slice(done, first_done, (slot,))
        remaining = jax.lax.dynamic_update_slice(remaining, budget,
                                                 (slot,))
        temps = jax.lax.dynamic_update_slice(
            temps, jnp.reshape(temp, (1,)).astype(jnp.float32), (slot,))
        slot_keys = jax.lax.dynamic_update_slice(
            slot_keys, k_stream[None, :].astype(slot_keys.dtype),
            (slot, 0))
        out = (cache, tokens, done, remaining, temps, slot_keys,
               first[0])
        if self.capture_logprobs:
            lp = chosen_logprob(
                logits, first,
                jnp.reshape(temp, (1,)).astype(jnp.float32))
            out = out + (lp[0],)
        return out

    def _chunk_fn(self, params, cache, tokens, done, remaining, temps,
                  slot_keys, *, n: int):
        """N decode+sample steps as one lax.scan; emits the (N, B)
        sampled-token block (-1 for slots already done at step start).
        Every slot advances its OWN key chain one split per step, so a
        request's draw sequence depends only on (engine seed, rid,
        token index) — never on chunk boundaries or sibling slots."""
        def body(carry, _):
            tokens, cache, done, remaining, keys = carry
            logits, cache = self.model.decode(params, tokens, cache)
            nk = jax.vmap(jax.random.split)(keys)        # (B, 2, 2)
            step_keys, keys = nk[:, 0], nk[:, 1]
            nxt = sample_tokens(logits, step_keys, temps, self.top_k,
                                self.top_p)
            remaining = remaining - jnp.where(done, 0, 1)
            newly = (~done) & ((nxt == self.eos_id) | (remaining <= 0))
            emit = jnp.where(done, -1, nxt)
            if self.capture_logprobs:
                lp = chosen_logprob(logits, nxt, temps)
                emit = (emit, jnp.where(done, 0.0, lp))
            done = done | newly
            return (nxt[:, None].astype(jnp.int32), cache, done,
                    remaining, keys), emit

        (tokens, cache, done, remaining, slot_keys), toks = jax.lax.scan(
            body, (tokens, cache, done, remaining, slot_keys), None,
            length=n)
        return cache, tokens, done, remaining, slot_keys, toks

    # -- host-side scheduler --------------------------------------------------

    def _pcache(self, nb: int):
        c = self._pcaches.get(nb)
        if c is None:
            c = self._pcaches[nb] = self.model.init_cache(
                nb, self.shape)
        return c

    def _admit(self) -> None:
        """Fill every free slot from the queue. With ``batch_admit``
        the waiting requests are prefilled in ONE bucketed call
        (batch padded to a power of two with throwaway rows, prompts
        right-padded to the longest bucket) and each row's batch-1
        sub-cache is spliced into its slot — a burst of B admissions
        costs one prefill instead of B. Per-row outputs are identical
        to the B=1 path (rows never interact: causal attention +
        no-drop MoE capacity on serving paths), which the equivalence
        test asserts bitwise."""
        free = [s for s in range(self.slots)
                if self.active[s] is None]
        while free and self._prepped:       # overlap-prefilled splice
            req, sub, logits = self._prepped.popleft()
            self._install(req, free.pop(0), sub, logits)
        n = min(len(free), len(self.queue))
        if n == 0:
            return
        reqs = [self.queue.popleft() for _ in range(n)]
        groups = [reqs] if self.batch_admit else [[r] for r in reqs]
        taken = 0
        for grp in groups:
            slots = free[taken:taken + len(grp)]
            taken += len(grp)
            self._admit_group(grp, slots)

    def _prep_admissions(self) -> None:
        """Dispatch B=1 admission prefills for queued requests while
        the decode chunk is still running on device (called between
        chunk dispatch and its blocking host read). The results wait in
        ``_prepped`` and splice at the next chunk boundary."""
        while self.queue and len(self._prepped) < self.slots:
            req = self.queue.popleft()
            assert 1 <= len(req.prompt) <= self.max_len, \
                f"prompt length {len(req.prompt)} vs {self.max_len}"
            padded = self._padded_len(len(req.prompt))
            tokens = np.full((1, padded), self.pad_id, np.int32)
            tokens[0, :len(req.prompt)] = req.prompt
            self.stats["prefill_widths"].add(padded)
            self.stats["prefills"] += 1
            logits, sub = self._prefill(
                self.params,
                {"tokens": jnp.asarray(tokens),
                 "prompt_len": jnp.asarray([len(req.prompt)], np.int32)},
                self._pcache0)
            self._prepped.append((req, sub, logits))

    def _admit_group(self, reqs: list, slots: list) -> None:
        for req in reqs:
            assert 1 <= len(req.prompt) <= self.max_len, \
                f"prompt length {len(req.prompt)} vs {self.max_len}"
        nb = bucket_batch(len(reqs))
        padded = self._padded_len(max(len(r.prompt) for r in reqs))
        tokens = np.full((nb, padded), self.pad_id, np.int32)
        plen = np.ones((nb,), np.int32)    # dummy rows: 1-token pads
        for i, r in enumerate(reqs):
            tokens[i, :len(r.prompt)] = r.prompt         # RIGHT-pad
            plen[i] = len(r.prompt)
        self.stats["prefill_widths"].add(padded)
        self.stats["prefills"] += 1
        self.stats["admit_batch_max"] = max(
            self.stats["admit_batch_max"], len(reqs))
        logits, sub = self._prefill(
            self.params,
            {"tokens": jnp.asarray(tokens),
             "prompt_len": jnp.asarray(plen)},
            self._pcache(nb))
        for i, (req, slot) in enumerate(zip(reqs, slots)):
            sub_i = sub if nb == 1 else tree_take_slot(
                sub, self._pcache0, i, nb)
            self._install(req, slot, sub_i, logits[i:i + 1])

    def _install(self, req: Request, slot: int, sub_cache,
                 logits) -> None:
        """Splice one prefilled request (batch-1 sub-cache + last-token
        logits row) into ``slot`` via the jitted admit step."""
        out = self._admit_jit(
            self.cache, self.tokens, self.done, self.remaining,
            self.temps, self.slot_keys, sub_cache, logits,
            jnp.int32(slot), self._budget(req) - 1,
            float(req.temperature), jnp.int32(req.rid))
        self._finish_install(req, slot, out)

    def _finish_install(self, req: Request, slot: int, out) -> None:
        (self.cache, self.tokens, self.done, self.remaining,
         self.temps, self.slot_keys) = out[:6]
        # (first token, logprob-or-None): fetched at drain
        self._pending_first[slot] = (
            out[6], out[7] if self.capture_logprobs else None)
        self.active[slot] = req
        self.stats["admitted"] += 1

    def _drain(self, toks_np: np.ndarray,
               lps_np: np.ndarray | None = None) -> None:
        n = toks_np.shape[0]
        now = time.perf_counter()
        for slot, req in enumerate(self.active):
            if req is None:
                continue
            budget = self._budget(req)
            if self._pending_first[slot] is not None:
                first_dev, lp_dev = self._pending_first[slot]
                first = int(np.asarray(first_dev))
                self._pending_first[slot] = None
                req.out_tokens.append(first)
                if lp_dev is not None:
                    req.out_logprobs.append(float(np.asarray(lp_dev)))
                req.t_first = now
                self.stats["tokens_out"] += 1
                if first == self.eos_id or len(req.out_tokens) >= budget:
                    self._retire(req)
                    self.active[slot] = None
                    self._release_slot(slot)
                    continue
            for t in range(n):
                tok = int(toks_np[t, slot])
                if tok < 0:      # slot was done before this step
                    break
                req.out_tokens.append(tok)
                if lps_np is not None:
                    req.out_logprobs.append(float(lps_np[t, slot]))
                self.stats["tokens_out"] += 1
                if tok == self.eos_id or len(req.out_tokens) >= budget:
                    self._retire(req)
                    self.active[slot] = None
                    self._release_slot(slot)
                    break

    # -- scheduler seams (paged engine overrides) -----------------------------

    def _release_slot(self, slot: int) -> None:
        """Called when ``slot`` retires — the paged engine releases its
        block refs here."""

    def _before_chunk(self) -> None:
        """Called after admission, before the decode chunk is
        dispatched — the paged engine's copy-on-write fork point."""

    def _after_chunk(self, n: int) -> None:
        """Called after the chunk's host read — bookkeeping that must
        mirror the device write cursors (every slot's cache length
        advanced by ``n``)."""

    def step(self) -> int:
        """One scheduling quantum: admit into free slots, run one
        decode chunk on device, drain its token block (the single
        device->host transfer), retire finished requests."""
        self._admit()
        if not any(r is not None for r in self.active):
            return 0
        self._before_chunk()
        n = self.decode_chunk
        (self.cache, self.tokens, self.done, self.remaining,
         self.slot_keys, toks) = self._chunk_jit(
            self.params, self.cache, self.tokens, self.done,
            self.remaining, self.temps, self.slot_keys, n=n)
        if self.overlap_admission:
            # the chunk above is dispatched but not yet read back:
            # admission prefills ride the gap
            self._prep_admissions()
        lps_np = None
        if self.capture_logprobs:
            toks, lps = toks
            lps_np = np.asarray(lps)   # same chunk-granular sync point
        toks_np = np.asarray(toks)              # ONE host sync per chunk
        self.stats["host_syncs"] += 1
        self.stats["decode_chunks"] += 1
        self.stats["decode_steps"] += n
        self.stats["total_slot_steps"] += n * self.slots
        self.stats["busy_slot_steps"] += int((toks_np >= 0).sum())
        self._after_chunk(n)
        self._drain(toks_np, lps_np)
        return sum(r is not None for r in self.active)


# legacy name: the wave engine was the original ServeEngine
ServeEngine = WaveEngine


def make_engine(kind: str, model, params, **kw):
    if kind == "wave":
        for k in ("decode_chunk", "top_k", "top_p", "seed",
                  "batch_admit", "overlap_admission"):
            kw.pop(k, None)
        return WaveEngine(model, params, **kw)
    if kind == "continuous":
        return ContinuousEngine(model, params, **kw)
    if kind == "paged":
        from repro.serving.paging import PagedEngine
        return PagedEngine(model, params, **kw)
    raise ValueError(f"unknown engine kind {kind!r}")
