"""Paged KV serving tier: global block pool, per-slot block tables,
copy-on-write shared prefixes.

The dense ``ContinuousEngine`` allocates one ``max_len``-wide KV cache
per slot, so device memory — not compute — caps concurrency, and every
co-batched request re-prefills its shared system prompt. This tier
replaces the per-slot cache with ONE physical block pool per layer
(``attention.PagedKVCache``) indexed through per-slot block tables:

* **BlockPool** (host): free-list alloc/release with refcounts over the
  physical block ids. Block 0 is the reserved trash block (writes from
  done/overflowing slots land there); every other block is owned by the
  requests whose tables map it. A request's worst-case block need is
  allocated AT ADMISSION, so pool pressure is a typed
  ``BlockPoolExhaustedError`` on admission — never a silent corruption
  or a mid-decode hang.

* **Content-addressed prefix sharing**: full prompt blocks are chain-
  hashed (sha256 over (parent digest, block tokens) — the chunk-store
  idiom from ``checkpointing/store.py``), so requests with a common
  system prompt (and GRPO groups with a common question) map the SAME
  physical blocks, refcounted. A full-prompt hit additionally reuses
  the registered last-token logits and admits with ZERO prefill
  FLOPs. The index holds no refs of its own: a block's index entries
  die with the block when its last user retires (refcount reaches zero
  exactly at retire).

* **Copy-on-write**: a partially-filled tail block adopted from the
  index is written at its first decode step, so admission reserves a
  fork target and ``_before_chunk`` copies the block just-in-time —
  only if it is still shared (a sole survivor adopts in place). Full
  blocks are never written after prefill, and appends past a sharer's
  prefix length are masked for every reader, so one appender + N
  readers per physical block is safe without a fork.

* **Chunked/paged prefill**: prompts longer than one dense bucket (or
  ``max_len`` itself, with ``capacity_blocks``) admit via
  ``model.prefill_extend`` segments that write straight into pool
  blocks.

The dense engine stays as the bit-identity foil: with the default pool
sizing, paged greedy output is asserted bitwise equal to
``ContinuousEngine`` across the model zoo (tests/test_paging.py).
"""
from __future__ import annotations

import hashlib
from collections import OrderedDict, deque

import jax
import jax.numpy as jnp
import numpy as np

from repro.models import attention as attn
from repro.serving.engine import (ContinuousEngine, Request,
                                  tree_insert_slot)

_HASH_ROOT = b"repro-paged-prefix-v1"


class BlockPoolExhaustedError(RuntimeError):
    """No free KV blocks for an allocation. Raised at ADMISSION (the
    failed request is re-queued at the front) — decode never allocates,
    so an admitted request can always run to its budget."""


class BlockPool:
    """Host-side free-list allocator with refcounts over the physical
    KV block pool. Block 0 (trash) is never handed out.

    ``on_pressure(pool, short)`` is the eviction hook: called before an
    allocation fails, it may release blocks (e.g. by evicting cold
    prefix blocks, or preempting a low-priority stream); allocation is
    re-checked after. ``on_free(bid, tags)`` fires when a block leaves
    the pool's ownership — the engine uses it to drop the block's
    prefix-index entries.

    With ``retain_tagged=True`` a tagged (prefix-indexed) block whose
    refcount reaches zero is PARKED on the ``cold`` LRU list instead of
    freed: its KV and index entries survive, so a later request with
    the same prefix revives it via :meth:`incref` at zero cost.
    ``evict``/``evict_cold`` turn cold blocks back into free ones (and
    only then fire ``on_free``)."""

    def __init__(self, n_blocks: int, *, on_pressure=None, on_free=None,
                 retain_tagged: bool = False):
        if n_blocks < 2:
            raise ValueError("pool needs >= 2 blocks (one is trash)")
        self.n_blocks = n_blocks
        self.free: deque[int] = deque(range(1, n_blocks))
        self.ref = np.zeros((n_blocks,), np.int32)
        self.tags: dict[int, list] = {}
        self.on_pressure = on_pressure
        self.on_free = on_free
        self.retain_tagged = retain_tagged
        # zero-ref tagged blocks, oldest-parked first (LRU eviction
        # order); values are unused
        self.cold: OrderedDict[int, None] = OrderedDict()
        self.stats = {"allocs": 0, "frees": 0, "peak_used": 0,
                      "exhausted": 0, "parked": 0, "revived": 0,
                      "evicted": 0}

    @property
    def used(self) -> int:
        return self.n_blocks - 1 - len(self.free)

    @property
    def cold_count(self) -> int:
        return len(self.cold)

    def alloc(self, n: int) -> list[int]:
        if n <= 0:
            return []
        if len(self.free) < n and self.on_pressure is not None:
            self.on_pressure(self, n - len(self.free))
        if len(self.free) < n:
            self.stats["exhausted"] += 1
            raise BlockPoolExhaustedError(
                f"need {n} KV blocks, {len(self.free)} free "
                f"(pool size {self.n_blocks - 1})")
        ids = [self.free.popleft() for _ in range(n)]
        for b in ids:
            self.ref[b] = 1
        self.stats["allocs"] += n
        self.stats["peak_used"] = max(self.stats["peak_used"], self.used)
        return ids

    def incref(self, bid: int) -> None:
        if not self.ref[bid] and bid in self.cold:
            # prefix match on a parked block: revive it from the cold
            # list — the whole point of retaining
            del self.cold[bid]
            self.ref[bid] = 1
            self.stats["revived"] += 1
            return
        assert self.ref[bid] > 0, f"incref on free block {bid}"
        self.ref[bid] += 1

    def decref(self, bid: int) -> bool:
        """Drop one ref. At zero the block frees (firing ``on_free``
        with its tags) — unless ``retain_tagged`` and it carries tags,
        in which case it parks on the cold LRU list with index entries
        intact. Returns True if freed."""
        assert self.ref[bid] > 0, f"decref on free block {bid}"
        self.ref[bid] -= 1
        if self.ref[bid]:
            return False
        if self.retain_tagged and self.tags.get(bid):
            self.cold[bid] = None        # most-recently-parked at end
            self.stats["parked"] += 1
            return False
        self._free_block(bid)
        return True

    def _free_block(self, bid: int) -> None:
        tags = self.tags.pop(bid, [])
        if self.on_free is not None:
            self.on_free(bid, tags)
        self.free.append(bid)
        self.stats["frees"] += 1

    def evict(self, bid: int) -> None:
        """Free one cold block: drops its index entries (``on_free``)
        and returns it to the free list."""
        assert bid in self.cold, f"evict on non-cold block {bid}"
        del self.cold[bid]
        self._free_block(bid)
        self.stats["evicted"] += 1

    def evict_cold(self, n: int) -> int:
        """Evict up to ``n`` cold blocks, oldest-parked first (LRU).
        Returns the number evicted — the standard ``on_pressure``
        policy when prefix retention is on."""
        evicted = 0
        while evicted < n and self.cold:
            bid, _ = self.cold.popitem(last=False)
            self._free_block(bid)
            self.stats["evicted"] += 1
            evicted += 1
        return evicted

    def tag(self, bid: int, item) -> None:
        self.tags.setdefault(bid, []).append(item)


class PrefixIndex:
    """Content-addressed registry of shared prefix blocks.

    ``blocks``: chain digest of prompt blocks [0, i] -> physical block
    id holding block i's KV. ``tails``: digest of (last full-block
    chain digest, tail tokens) -> (tail block id or None, cached
    last-token logits row) — the full-prompt entry that makes an exact
    repeat admit with zero prefill. Entries hold NO refs; they are
    dropped when their block is freed."""

    def __init__(self):
        self.blocks: dict[bytes, int] = {}
        self.tails: dict[bytes, tuple[int | None, object]] = {}

    def clear(self) -> None:
        self.blocks.clear()
        self.tails.clear()


def chain_digests(prompt: np.ndarray, blk: int) -> tuple[list[bytes],
                                                         bytes]:
    """sha256 chain over the prompt's full blocks, plus the tail
    digest. ``digests[i]`` commits to tokens [0, (i+1)*blk) — matching
    it guarantees the indexed block holds exactly the KV a fresh
    prefill of this prompt would write there (full-causal attention:
    block content depends only on its prefix)."""
    p = np.ascontiguousarray(np.asarray(prompt, np.int32))
    h = _HASH_ROOT
    digests = []
    f = len(p) // blk
    for i in range(f):
        h = hashlib.sha256(h + p[i * blk:(i + 1) * blk].tobytes()).digest()
        digests.append(h)
    tail = hashlib.sha256(h + b"|tail|" + p[f * blk:].tobytes()).digest()
    return digests, tail


def build_paged_cache(model, slots: int, shape, *, block_size: int,
                      n_blocks: int | None = None,
                      capacity_blocks: int | None = None,
                      rolling: bool = False):
    """Materialize the model's cache pytree with every ``KVCache`` leaf
    replaced by a ``PagedKVCache`` over a shared physical pool
    (``jax.eval_shape`` template — the dense cache is never allocated).
    Non-KV leaves (SSM states, conv rings) stay dense per-slot: they
    are O(1) in sequence length, paging buys nothing.

    Returns (cache, table_width, n_blocks). ``n_blocks=None`` sizes the
    pool to exactly the dense engine's capacity (slots * table_width
    blocks + trash) — the bit-identity-foil configuration."""
    template = jax.eval_shape(lambda: model.init_cache(slots, shape))
    return paged_cache_from_template(
        template, slots=slots, block_size=block_size,
        n_blocks=n_blocks, capacity_blocks=capacity_blocks,
        rolling=rolling)


def paged_cache_from_template(template, *, slots: int, block_size: int,
                              n_blocks: int | None = None,
                              capacity_blocks: int | None = None,
                              rolling: bool = False):
    """Core of :func:`build_paged_cache` over an abstract cache
    template (also used by the swarm stage servers, whose cache trees
    come from ``StageDef.init_cache`` rather than a ``ModelDef``)."""
    widths: list[int] = []

    def width(leaf):
        s_max = leaf.k.shape[-3]
        if s_max % block_size:
            raise ValueError(
                f"block_size {block_size} must divide the cache width "
                f"{s_max} (max_len / SWA ring)")
        nb = s_max // block_size
        if capacity_blocks is not None and not rolling:
            nb = max(nb, capacity_blocks)
        return nb

    is_kv = lambda x: isinstance(x, attn.KVCache)
    for leaf in jax.tree.leaves(template, is_leaf=is_kv):
        if isinstance(leaf, attn.KVCache):
            widths.append(width(leaf))
    if len(set(widths)) > 1:
        raise ValueError(f"non-uniform paged table widths {set(widths)}")
    nb = widths[0] if widths else 0
    if n_blocks is None:
        n_blocks = slots * nb + 1 if nb else 2

    def conv(leaf):
        if not isinstance(leaf, attn.KVCache):
            return jnp.zeros(leaf.shape, leaf.dtype)
        ks = leaf.k.shape
        hk, dh = ks[-2], ks[-1]
        if len(ks) == 5:            # stacked (L, B, S, Hk, dh)
            z = jnp.zeros((ks[0], n_blocks, block_size, hk, dh),
                          leaf.k.dtype)
            tbl = jnp.full((ks[0], slots, nb), -1, jnp.int32)
            ln = jnp.zeros((ks[0], slots), jnp.int32)
        else:                       # (B, S, Hk, dh)
            z = jnp.zeros((n_blocks, block_size, hk, dh), leaf.k.dtype)
            tbl = jnp.full((slots, nb), -1, jnp.int32)
            ln = jnp.zeros((slots,), jnp.int32)
        return attn.PagedKVCache(z, jnp.copy(z), tbl, ln)

    cache = jax.tree.map(conv, template, is_leaf=is_kv)
    return cache, nb, n_blocks


class PagedEngine(ContinuousEngine):
    """``ContinuousEngine`` with the per-slot dense cache swapped for
    the paged block pool. The decode loop is UNCHANGED (the paged
    ``cache_update`` / ``decode_attention`` dispatch inside the same
    jitted chunk); admission allocates blocks, matches content-
    addressed prefixes, and splices either a paginated scratch prefill,
    an extend-resumed suffix, or (full hit) nothing at all."""
    kind = "paged"

    def __init__(self, model, params, *, block_size: int = 16,
                 pool_blocks: int | None = None,
                 capacity_blocks: int | None = None,
                 share_prefix: bool = True,
                 cache_prefixes: bool = False,
                 prefill_chunk: int | None = None, **kw):
        kw.pop("overlap_admission", None)   # admission is host-stateful
        kw.pop("batch_admit", None)         # per-request (block alloc)
        super().__init__(model, params, batch_admit=False, **kw)
        family = getattr(self.cfg, "family", "")
        if family == "encdec":
            raise ValueError("paged serving unsupported for family "
                             "'encdec' (cross caches page per source, "
                             "not per token)")
        self.rolling = getattr(self.cfg, "sliding_window",
                               None) is not None
        self.blk = int(block_size)
        self.cache, self.nb, n_blocks = build_paged_cache(
            model, self.slots, self.shape, block_size=self.blk,
            n_blocks=pool_blocks, capacity_blocks=capacity_blocks,
            rolling=self.rolling)
        self.capacity = self.nb * self.blk if self.nb else self.max_len
        # cache_prefixes: keep zero-ref prefix-tagged blocks parked on
        # the pool's cold LRU list so repeat prompts hit even after
        # every sharer retired; admission under pressure evicts the
        # coldest instead of raising. Off by default: the dense foil
        # invariant is refcount-zero-frees-exactly-at-retire.
        self.cache_prefixes = bool(cache_prefixes)
        self.pool = BlockPool(
            n_blocks, on_free=self._on_block_free,
            retain_tagged=self.cache_prefixes,
            on_pressure=(self._on_pool_pressure if self.cache_prefixes
                         else None))
        self._extend = None
        if model.prefill_extend is not None and not self.rolling:
            self._extend = jax.jit(model.prefill_extend)
        self.prefix = PrefixIndex() if (
            share_prefix and not self.rolling and self.nb
            and family in ("dense", "moe", "vlm")) else None
        self.prefill_chunk = int(prefill_chunk or self.max_len)
        self._tables = np.full((self.slots, max(self.nb, 1)), -1,
                               np.int32)
        self._tbl_dirty = True
        self._slot_blocks: list[list[int]] = [[] for _ in
                                              range(self.slots)]
        # (table index to check, reserved fork target) per slot
        self._cow_pending: list[tuple[int, int] | None] = \
            [None] * self.slots
        self._paginate_jit = jax.jit(self._paginate_fn)
        self._paged_admit_jit = jax.jit(self._paged_admit_fn)
        self._admit_hit_jit = jax.jit(self._admit_hit_fn)
        self._set_len_jit = jax.jit(self._set_len_fn)
        self._settbl_jit = jax.jit(self._settbl_fn)
        self._fork_jit = jax.jit(self._fork_fn)
        self._extend_slot_jit = jax.jit(self._extend_slot_fn)
        self.stats.update(prefix_lookups=0, prefix_hits=0,
                          prefix_hit_tokens=0, prompt_tokens=0,
                          cow_forks=0, paged_extends=0,
                          admit_deferred=0)

    # -- device-side pieces ---------------------------------------------------

    @staticmethod
    def _is_paged(x) -> bool:
        return isinstance(x, attn.PagedKVCache)

    def _settbl_fn(self, cache, tbl):
        def leaf(c):
            if isinstance(c, attn.PagedKVCache):
                t = tbl.astype(jnp.int32)
                if c.table.ndim == 3:
                    t = jnp.broadcast_to(t[None], c.table.shape)
                return c._replace(table=t)
            return c
        return jax.tree.map(leaf, cache, is_leaf=self._is_paged)

    def _set_len_fn(self, cache, slot, plen):
        def leaf(c):
            if isinstance(c, attn.PagedKVCache):
                val = jnp.reshape(plen, (1,)).astype(jnp.int32)
                if c.length.ndim == 2:
                    v2 = jnp.broadcast_to(val[None],
                                          (c.length.shape[0], 1))
                    return c._replace(length=jax.lax.dynamic_update_slice(
                        c.length, v2, (0, slot)))
                return c._replace(length=jax.lax.dynamic_update_slice(
                    c.length, val, (slot,)))
            return c
        return jax.tree.map(leaf, cache, is_leaf=self._is_paged)

    def _paginate_leaf(self, bg, sb, row, slot):
        """Copy one dense scratch leaf (B=1, width S) into the pool
        blocks table row ``row`` maps; splice the slot's table/length
        rows. Cells whose row entry is -1 (scratch wider than the
        allocation) go to the trash block."""
        blk = self.blk
        nb = bg.table.shape[-1]
        s = sb.k.shape[-3]
        w = min(s, nb * blk)
        cells = jnp.arange(w)
        phys = row[cells // blk]
        phys = jnp.where(phys >= 0, phys, 0)
        off = cells % blk
        rown = row[None, :]
        if bg.k.ndim == 5:
            k = bg.k.at[:, phys, off].set(
                sb.k[:, 0, :w].astype(bg.k.dtype))
            v = bg.v.at[:, phys, off].set(
                sb.v[:, 0, :w].astype(bg.v.dtype))
            tbl = jax.lax.dynamic_update_slice(
                bg.table,
                jnp.broadcast_to(rown[None],
                                 (bg.table.shape[0], 1, nb)),
                (0, slot, 0))
            ln = jax.lax.dynamic_update_slice(
                bg.length, sb.length[:, :1].astype(jnp.int32), (0, slot))
        else:
            k = bg.k.at[phys, off].set(sb.k[0, :w].astype(bg.k.dtype))
            v = bg.v.at[phys, off].set(sb.v[0, :w].astype(bg.v.dtype))
            tbl = jax.lax.dynamic_update_slice(bg.table, rown, (slot, 0))
            ln = jax.lax.dynamic_update_slice(
                bg.length, sb.length.astype(jnp.int32), (slot,))
        return attn.PagedKVCache(k, v, tbl, ln)

    def _paginate_fn(self, cache, sub, row, slot):
        """Splice a dense B=1 scratch prefill into the paged slot:
        paged leaves scatter through the table, dense leaves (SSM
        state, conv rings) take the ordinary batch-axis insert."""
        is_cache = lambda x: isinstance(x, (attn.KVCache,
                                            attn.PagedKVCache))
        bl, bdef = jax.tree_util.tree_flatten(cache, is_leaf=is_cache)
        sl, _ = jax.tree_util.tree_flatten(sub, is_leaf=is_cache)
        out = []
        for bg, sb in zip(bl, sl):
            if isinstance(bg, attn.PagedKVCache):
                out.append(self._paginate_leaf(bg, sb, row, slot))
            else:
                out.append(tree_insert_slot(bg, sb, slot, self.slots))
        return jax.tree_util.tree_unflatten(bdef, out)

    def _paged_admit_fn(self, cache, tokens, done, remaining, temps,
                        slot_keys, sub_cache, logits, slot, budget,
                        temp, rid, row):
        cache = self._paginate_fn(cache, sub_cache, row, slot)
        return self._admit_state(cache, tokens, done, remaining, temps,
                                 slot_keys, logits, slot, budget, temp,
                                 rid)

    def _admit_hit_fn(self, cache, tokens, done, remaining, temps,
                      slot_keys, logits, slot, budget, temp, rid, plen):
        """Admission with no cache write at all (full prefix hit, or an
        extend path that already wrote through the table) — set the
        slot's lengths and splice the scheduler state."""
        cache = self._set_len_fn(cache, slot, plen)
        return self._admit_state(cache, tokens, done, remaining, temps,
                                 slot_keys, logits, slot, budget, temp,
                                 rid)

    def _fork_fn(self, cache, src, dst):
        def leaf(c):
            if isinstance(c, attn.PagedKVCache):
                axis = 1 if c.k.ndim == 5 else 0
                ks = jax.lax.dynamic_slice_in_dim(c.k, src, 1, axis=axis)
                vs = jax.lax.dynamic_slice_in_dim(c.v, src, 1, axis=axis)
                return c._replace(
                    k=jax.lax.dynamic_update_slice_in_dim(
                        c.k, ks, dst, axis=axis),
                    v=jax.lax.dynamic_update_slice_in_dim(
                        c.v, vs, dst, axis=axis))
            return c
        return jax.tree.map(leaf, cache, is_leaf=self._is_paged)

    def _extend_slot_fn(self, params, cache, tokens, slot, start,
                        seg_len):
        """One chunked-prefill segment for ``slot``: extract its B=1
        paged view (tables/lengths sliced, pool arrays shared), run
        ``prefill_extend`` (which writes the segment's KV through the
        table), merge the new pool arrays + the slot's length back."""
        def take(c):
            if isinstance(c, attn.PagedKVCache):
                ax = 1 if c.table.ndim == 3 else 0
                return c._replace(
                    table=jax.lax.dynamic_slice_in_dim(
                        c.table, slot, 1, axis=ax),
                    length=jax.lax.dynamic_slice_in_dim(
                        c.length, slot, 1, axis=ax))
            return c
        sub = jax.tree.map(take, cache, is_leaf=self._is_paged)
        logits, new_sub = self.model.prefill_extend(
            params, {"tokens": tokens, "start": start,
                     "seg_len": seg_len}, sub)

        def put(c, nc):
            if isinstance(c, attn.PagedKVCache):
                if c.length.ndim == 2:
                    ln = jax.lax.dynamic_update_slice(
                        c.length, nc.length.astype(jnp.int32), (0, slot))
                else:
                    ln = jax.lax.dynamic_update_slice(
                        c.length, nc.length.astype(jnp.int32), (slot,))
                return attn.PagedKVCache(nc.k, nc.v, c.table, ln)
            return c
        merged = jax.tree.map(put, cache, new_sub,
                              is_leaf=self._is_paged)
        return logits, merged

    # -- host-side admission --------------------------------------------------

    def _budget(self, req: Request) -> int:
        if self.rolling or not self.nb:
            return super()._budget(req)
        return max(1, min(req.max_new_tokens,
                          self.capacity - len(req.prompt)))

    def _row_dev(self, row: list[int]) -> jnp.ndarray:
        r = np.full((self.nb,), -1, np.int32)
        r[:len(row)] = row
        return jnp.asarray(r)

    def _admit(self) -> None:
        """Fill free slots one request at a time (block allocation is
        per-request). On pool exhaustion the request is back at the
        queue head: if anything is still decoding, its retire will free
        blocks — defer and retry at the next chunk boundary, keeping
        FIFO order. Only a request that cannot fit an EMPTY pool
        escalates the typed error to the caller."""
        free = [s for s in range(self.slots) if self.active[s] is None]
        while free and self.queue:
            req = self.queue.popleft()
            try:
                self._admit_one(req, free.pop(0))
            except BlockPoolExhaustedError:
                if not any(r is not None for r in self.active):
                    raise
                self.stats["admit_deferred"] += 1
                return

    def _match_prefix(self, prompt: np.ndarray):
        """Greedy longest content-addressed match: full blocks along
        the chain hash, then the full-prompt tail entry. Returns
        (shared block ids (ref'd), matched prefix length H, cached
        last-token logits or None, tail block adopted?, digests,
        tail digest)."""
        digests, tail_digest = chain_digests(prompt, self.blk)
        if self.prefix is None:
            return [], 0, None, False, digests, tail_digest
        self.stats["prefix_lookups"] += 1
        plen = len(prompt)
        ids: list[int] = []
        for d in digests:
            bid = self.prefix.blocks.get(d)
            if bid is None:
                break
            ids.append(bid)
        m = len(ids)
        H = m * self.blk
        hit_logits, tail_shared = None, False
        if m == len(digests):
            ent = self.prefix.tails.get(tail_digest)
            if ent is not None:
                tail_bid, hit_logits = ent
                if tail_bid is not None:
                    ids.append(tail_bid)
                    tail_shared = True
                H = plen
        if H >= plen and hit_logits is None and m:
            # whole-prompt block coverage but no cached logits: the
            # last block must be re-run, and a shared block can't be
            # the write target — drop it from the match
            ids.pop()
            m -= 1
            H = m * self.blk
        for bid in ids:
            self.pool.incref(bid)
        if H:
            self.stats["prefix_hits"] += 1
            self.stats["prefix_hit_tokens"] += H
        return ids, H, hit_logits, tail_shared, digests, tail_digest

    def _register_prefix(self, prompt: np.ndarray, row: list[int],
                         matched: int, digests: list[bytes],
                         tail_digest: bytes, logits) -> None:
        if self.prefix is None:
            return
        for i in range(matched, len(digests)):
            if digests[i] not in self.prefix.blocks:
                self.prefix.blocks[digests[i]] = row[i]
                self.pool.tag(row[i], ("block", digests[i]))
        if tail_digest not in self.prefix.tails:
            f = len(digests)
            tail_bid = row[f] if len(prompt) % self.blk else None
            self.prefix.tails[tail_digest] = (tail_bid, logits)
            if tail_bid is not None:
                self.pool.tag(tail_bid, ("tail", tail_digest))

    def _admit_one(self, req: Request, slot: int) -> None:
        plen = len(req.prompt)
        if not self.nb:
            # no KV leaves (pure SSM): paging degenerates to the dense
            # path — the "paged" cache IS the dense cache
            super()._admit_group([req], [slot])
            return
        if self.rolling:
            # ring semantics: the scratch prefill keeps the last window
            # regardless of prompt length, exactly like the dense foil
            limit = self.max_len
        elif self._extend is not None:
            limit = self.capacity
        else:
            limit = min(self.max_len, self.capacity)
        assert 1 <= plen <= limit, \
            f"prompt length {plen} vs paged capacity {limit}"
        prompt = np.asarray(req.prompt, np.int32)
        self.stats["prompt_tokens"] += plen
        budget = self._budget(req)
        if self.rolling:
            n_total = self.nb          # the whole ring, private
            hit, H, hit_lg, tail_shared = [], 0, None, False
            digests, tail_digest = [], b""
        else:
            cells = min(plen + budget, self.capacity)
            n_total = -(-cells // self.blk)
            (hit, H, hit_lg, tail_shared,
             digests, tail_digest) = self._match_prefix(prompt)
        need = (n_total - len(hit)) + \
            (1 if tail_shared and plen % self.blk else 0)
        try:
            fresh = self.pool.alloc(need)
        except BlockPoolExhaustedError:
            for bid in hit:
                self.pool.decref(bid)
            self.queue.appendleft(req)
            raise
        spare = fresh.pop() if tail_shared and plen % self.blk else None
        row = hit + fresh
        self._tables[slot, :] = -1
        self._tables[slot, :len(row)] = row
        self._tbl_dirty = True
        self._slot_blocks[slot] = row + ([spare] if spare is not None
                                         else [])
        self._cow_pending[slot] = (plen // self.blk, spare) \
            if spare is not None else None
        self._push_tables()
        matched = len(hit) - (1 if tail_shared else 0)

        if H == plen:                    # full hit: zero prefill
            logits = hit_lg
            out = self._admit_hit_jit(
                self.cache, self.tokens, self.done, self.remaining,
                self.temps, self.slot_keys, logits, jnp.int32(slot),
                budget - 1, float(req.temperature), jnp.int32(req.rid),
                jnp.int32(plen))
            self._finish_install(req, slot, out)
        elif H == 0:
            # dense scratch prefill of the leading window — the exact
            # bucketed call the dense foil makes — then paginate
            w0 = min(plen, self.max_len)
            padded = self._padded_len(w0)
            toks = np.full((1, padded), self.pad_id, np.int32)
            toks[0, :w0] = prompt[:w0]
            self.stats["prefill_widths"].add(padded)
            self.stats["prefills"] += 1
            logits, sub = self._prefill(
                self.params,
                {"tokens": jnp.asarray(toks),
                 "prompt_len": jnp.asarray([w0], np.int32)},
                self._pcache0)
            row_dev = self._row_dev(row)
            if w0 == plen:
                out = self._paged_admit_jit(
                    self.cache, self.tokens, self.done, self.remaining,
                    self.temps, self.slot_keys, sub, logits,
                    jnp.int32(slot), budget - 1,
                    float(req.temperature), jnp.int32(req.rid), row_dev)
                self._finish_install(req, slot, out)
            else:                        # prompt exceeds one bucket
                self.cache = self._paginate_jit(self.cache, sub,
                                                row_dev,
                                                jnp.int32(slot))
                logits = self._extend_to(slot, prompt, w0)
                out = self._admit_hit_jit(
                    self.cache, self.tokens, self.done, self.remaining,
                    self.temps, self.slot_keys, logits,
                    jnp.int32(slot), budget - 1,
                    float(req.temperature), jnp.int32(req.rid),
                    jnp.int32(plen))
                self._finish_install(req, slot, out)
        else:                            # partial hit: resume at H
            self.cache = self._set_len_jit(self.cache, jnp.int32(slot),
                                           jnp.int32(H))
            logits = self._extend_to(slot, prompt, H)
            out = self._admit_hit_jit(
                self.cache, self.tokens, self.done, self.remaining,
                self.temps, self.slot_keys, logits, jnp.int32(slot),
                budget - 1, float(req.temperature), jnp.int32(req.rid),
                jnp.int32(plen))
            self._finish_install(req, slot, out)
        if not self.rolling:
            self._register_prefix(prompt, row, matched, digests,
                                  tail_digest, logits)

    def _extend_to(self, slot: int, prompt: np.ndarray,
                   start: int) -> jnp.ndarray:
        """Run ``prefill_extend`` segments until the whole prompt is in
        the cache; returns the last-token logits."""
        assert self._extend is not None, \
            "prefix resume / long prompts need model.prefill_extend"
        plen = len(prompt)
        logits = None
        pos = start
        while pos < plen:
            w = min(self.prefill_chunk, plen - pos)
            padded = self._padded_len(w)
            toks = np.full((1, padded), self.pad_id, np.int32)
            toks[0, :w] = prompt[pos:pos + w]
            self.stats["prefill_widths"].add(padded)
            self.stats["paged_extends"] += 1
            logits, self.cache = self._extend_slot_jit(
                self.params, self.cache, jnp.asarray(toks),
                jnp.int32(slot), jnp.int32(pos), jnp.int32(w))
            pos += w
        return logits

    # -- scheduler seams ------------------------------------------------------

    def _push_tables(self) -> None:
        if not self._tbl_dirty or not self.nb:
            return
        self.cache = self._settbl_jit(self.cache,
                                      jnp.asarray(self._tables))
        self._tbl_dirty = False

    def _before_chunk(self) -> None:
        """Copy-on-write fork point: a slot that adopted a shared,
        partially-filled tail block appends to it on its first decode
        write — fork the physical block just-in-time if it is still
        shared, else adopt it in place."""
        for slot, req in enumerate(self.active):
            pend = self._cow_pending[slot]
            if req is None or pend is None:
                continue
            bi, spare = pend
            self._cow_pending[slot] = None
            bid = int(self._tables[slot, bi])
            if self.pool.ref[bid] > 1:
                self.cache = self._fork_jit(self.cache, jnp.int32(bid),
                                            jnp.int32(spare))
                self._tables[slot, bi] = spare
                self._tbl_dirty = True
                self._slot_blocks[slot].remove(bid)
                self.pool.decref(bid)
                self.stats["cow_forks"] += 1
            else:
                # every other sharer retired: sole owner, append in
                # place; the reserved fork target goes back
                self._slot_blocks[slot].remove(spare)
                self.pool.decref(spare)
        self._push_tables()

    def _release_slot(self, slot: int) -> None:
        """Retire: drop the slot's refs — blocks (and their index
        entries) free exactly when their LAST sharer retires."""
        for bid in self._slot_blocks[slot]:
            self.pool.decref(bid)
        self._slot_blocks[slot] = []
        self._cow_pending[slot] = None
        self._tables[slot, :] = -1
        self._tbl_dirty = True

    def _on_block_free(self, bid: int, tags: list) -> None:
        if self.prefix is None:
            return
        for kind, key in tags:
            if kind == "block":
                self.prefix.blocks.pop(key, None)
            else:
                self.prefix.tails.pop(key, None)

    def _on_pool_pressure(self, pool: BlockPool, short: int) -> None:
        pool.evict_cold(short)

    def flush_prefix_cache(self) -> None:
        """Invalidate all content-addressed prefix state. REQUIRED
        after a params swap (RL policy adoption): cached KV and logits
        are policy-dependent. Live requests keep their blocks; parked
        cold blocks (whose KV is now stale) free outright."""
        self.pool.evict_cold(len(self.pool.cold))
        if self.prefix is not None:
            self.prefix.clear()
        self.pool.tags.clear()

    def perf_summary(self) -> dict:
        s = super().perf_summary()
        prompt_toks = self.stats["prompt_tokens"]
        s.update(
            block_size=self.blk,
            pool_blocks=self.pool.n_blocks - 1,
            blocks_peak=self.pool.stats["peak_used"],
            prefix_hits=self.stats["prefix_hits"],
            prefix_hit_tokens=self.stats["prefix_hit_tokens"],
            prefix_hit_rate=(self.stats["prefix_hit_tokens"]
                             / prompt_toks if prompt_toks else 0.0),
            cow_forks=self.stats["cow_forks"],
            paged_extends=self.stats["paged_extends"],
            cold_blocks=self.pool.cold_count,
            blocks_revived=self.pool.stats["revived"],
            blocks_evicted=self.pool.stats["evicted"])
        return s
