"""Fault-tolerant swarm inference: pipeline-stage serving over
unreliable peers, with failover re-prefill (paper §2.4.2's failure
model applied to SERVING: peers are partial, unreliable replicas — a
request must survive any one of them dying mid-decode).

A model is split into K contiguous-layer stages
(``registry.make_stages``); each ``StageServer`` peer holds one or
more stages (params slice + per-request KV cache) and speaks the same
framed-TCP JSON-op protocol as ``ChunkPeer`` — it IS a ``ChunkPeer``
subclass, so checkpoint chunks, gossip polls and stage RPCs ride one
port, one connection pool and one typed-error family:

  * ``{"op": "stages"}`` -> ``{"stages": [...], "k_stages": K}``;
  * ``{"op": "prefill_stage", "sid", "rid", "install", "plen",
    "meta"}`` + one tensor frame (tokens (1, S) int32 on stage 0,
    activations (1, S, D) elsewhere) -> ``{"ok", "meta"}`` + one
    tensor frame (activations, or (1, V) logits on the last stage).
    ``plen`` is the true prompt length; the router right-pads prompts
    to the SAME power-of-two buckets the single-host engine uses, so
    a staged chain reproduces the engine's prefill widths — and its
    logits — bit for bit. ``install`` False runs the forward
    STATELESSLY (failover replay through healthy upstream stages);
    True (re)creates the request's stage cache;
  * ``{"op": "decode_stage", "sid", "rid", "seq", "meta"}`` + tensor
    frame ((1, 1) token / (1, 1, D) activation) -> appends exactly one
    position to the request's cache. ``seq`` is the stage's expected
    pre-decode cache length: a duplicate (``seq == len - 1``, e.g. a
    retry after the response was lost on a stale pooled conn) replays
    the saved output WITHOUT re-appending, so decode is idempotent on
    the wire even though the cache append is not;
  * ``{"op": "adopt_stage", "sid", "peers"}`` -> the server
    swarm-fetches the published stage weights (weight distribution is
    literally ``swarm_fetch``) into its own chunk store and starts
    serving the stage;
  * ``{"op": "release", "rid"}`` -> drops the request's state.

Stage possession is gossiped as synthetic inventory ids
(``stage:NNNN``) merged into the server's chunk digest/inventory, so
``ChunkGossip`` needs no changes and ``gossip.holders("stage:0002")``
answers "who can serve stage 2 right now".

The client-side ``SwarmRouter`` plans a chain of one holder per stage
from gossip possession and streams each request through it. Failure
handling (crash = ``PeerClosedError``/``ConnectionError``, stall =
``PeerTimeoutError``, corruption = ``ChecksumError`` — all typed, all
``FetchError``):

  * during PREFILL the router still holds the activations it was
    sending, so failover is: mark the peer dead, pick a surviving
    holder, resend. No replay.
  * during DECODE at stage j, stages 0..j-1 already committed the
    in-flight token (their caches are one position ahead) and the dead
    stage's KV state is gone. Recovery re-prefills from the request's
    token prefix (prompt + tokens emitted so far — BOUNDED replay,
    never the full generation history twice): stages 0..j-1 run
    ``prefill_stage(install=False)`` purely for activations, the new
    holder of stage j runs ``install=True`` (rebuilding its cache at
    the committed length), and stages j+1.. receive the last-position
    activation via one ordinary ``decode_stage`` (appending the exact
    position they were missing). The logits that come out are the ones
    the failed step was computing, so in-flight requests complete with
    greedy tokens bit-identical to an uninterrupted run.
  * a failure DURING recovery just moves the failure point (another
    holder dies -> it joins the install set / recovery recurses one
    stage further down); every failure consumes one unit of the
    per-request replay budget, so a flapping swarm fails typed
    (``ReplayBudgetError``) instead of looping.
  * a stage with no surviving holder raises ``StageUnservableError``
    (a ``FetchError``) — the chain fails typed, never hangs.

Fault injection reuses the ``ChunkPeer`` knobs (``crash_after``,
``stall_chunks``/``stall_s``, ``corrupt_after``), counted in
``served_chunks`` across chunk AND stage responses, so the
deterministic fault harness drives kill/stall/corrupt schedules over
serving exactly like it does over checkpoint recovery.
"""
from __future__ import annotations

import hashlib
import json
import struct
import threading
import time
from typing import Sequence

import jax
import jax.numpy as jnp
import numpy as np

from repro.checkpointing import checkpoint as _ckpt
from repro.checkpointing.p2p import (FetchError, PeerConn, PeerConnPool,
                                     PeerTimeoutError, RetryPolicy,
                                     _recv_frame, _send_frame)
from repro.checkpointing.store import ChunkStore
from repro.checkpointing.swarm import ChunkPeer, swarm_fetch
from repro.models import attention as attn
from repro.models import registry
from repro.serving.engine import bucket_len

Addr = tuple  # (host, port)


class StageUnservableError(FetchError):
    """No surviving holder can serve a required stage: the chain fails
    typed instead of hanging on a dead peer."""

    def __init__(self, msg: str, sid: int | None = None,
                 failures: dict | None = None):
        super().__init__(msg)
        self.sid = sid
        self.failures = failures or {}


class ReplayBudgetError(StageUnservableError):
    """A request burned its failover/replay budget (flapping swarm)."""


class StageRPCError(FetchError):
    """The peer answered, but with a protocol-level error (unknown
    stage, lost request state, seq mismatch). Treated like a peer
    failure by the router: fail over, re-prefill."""


def stage_chunk_id(sid: int) -> str:
    """The synthetic gossip-inventory id advertising stage possession."""
    return f"stage:{int(sid):04d}"


# -- tensor frames -------------------------------------------------------------


def _encode_arr(arr) -> tuple[bytes, dict]:
    arr = np.asarray(arr)
    buf, dtype = _ckpt.leaf_to_bytes(arr)
    return buf, {"shape": list(arr.shape), "dtype": dtype}


def _decode_arr(blob: bytes, meta: dict) -> np.ndarray:
    return _ckpt.leaf_from_bytes(blob, meta["dtype"],
                                 tuple(meta["shape"]))


# -- paged stage KV ------------------------------------------------------------


def _is_paged(x) -> bool:
    return isinstance(x, attn.PagedKVCache)


def _paged_view(pool_c, row, ln):
    """Assemble the B=1 paged cache one request sees: the stage's
    shared pool arrays plus the request's own table row / length."""
    if pool_c.k.ndim == 5:
        nl = pool_c.k.shape[0]
        table = jnp.broadcast_to(row[None, None], (nl, 1, row.shape[0]))
        length = jnp.broadcast_to(jnp.reshape(ln, (1, 1)), (nl, 1))
    else:
        table = row[None]
        length = jnp.reshape(ln, (1,))
    return pool_c._replace(table=table.astype(jnp.int32),
                           length=length.astype(jnp.int32))


def _paged_scatter(pool_c, dense_c, row, blk):
    """Copy a freshly prefilled dense B=1 stage cache leaf into the
    pool blocks ``row`` maps (cells past the allocation hit the trash
    block — they are pad positions beyond ``plen``)."""
    nb = row.shape[0]
    s = dense_c.k.shape[-3]
    w = min(s, nb * blk)
    cells = jnp.arange(w)
    phys = row[cells // blk]
    phys = jnp.where(phys >= 0, phys, 0)
    off = cells % blk
    if pool_c.k.ndim == 5:
        k = pool_c.k.at[:, phys, off].set(
            dense_c.k[:, 0, :w].astype(pool_c.k.dtype))
        v = pool_c.v.at[:, phys, off].set(
            dense_c.v[:, 0, :w].astype(pool_c.v.dtype))
    else:
        k = pool_c.k.at[phys, off].set(
            dense_c.k[0, :w].astype(pool_c.k.dtype))
        v = pool_c.v.at[phys, off].set(
            dense_c.v[0, :w].astype(pool_c.v.dtype))
    return pool_c._replace(k=k, v=v)


# -- weight distribution -------------------------------------------------------


def publish_stages(store: ChunkStore, cfg, params, k_stages: int,
                   *, stage_ids: Sequence[int] | None = None) -> list:
    """Chunk each stage's parameter slice into ``store`` under
    ``step == stage id``. Any ``ChunkPeer`` over that store can then
    hand the weights to a joining ``StageServer`` via plain
    ``swarm_fetch(step=sid)`` — weight distribution IS the checkpoint
    swarm path (striping, failover, content verification included)."""
    stages = registry.make_stages(cfg, k_stages)
    picked = stages if stage_ids is None else \
        [stages[i] for i in stage_ids]
    return [store.save_tree(s.index, s.slice_params(params),
                            extra_meta={"stage": s.index,
                                        "k_stages": k_stages},
                            kind="full")
            for s in picked]


def restore_stage_params(store: ChunkStore, cfg, k_stages: int,
                         sid: int):
    """Rebuild one stage's parameter tree from published chunks."""
    manifest = store.load_manifest(sid)
    like = registry.stage_param_specs(cfg, k_stages)[sid]
    flat = {k: store.read_leaf(e) for k, e in manifest["keys"].items()}
    return _ckpt.unflatten_like(like, flat)


# -- server --------------------------------------------------------------------


class StageServer(ChunkPeer):
    """One swarm-serving peer: a ``ChunkPeer`` (chunk store + gossip
    ops) that additionally serves pipeline stages. See module docstring
    for the wire protocol. Thread-safe: each client connection gets a
    session thread; stage tables and per-request state are lock-
    guarded."""

    def __init__(self, cfg, store: ChunkStore, *, k_stages: int,
                 host: str = "127.0.0.1", port: int = 0,
                 max_len: int = 256, kv_layout: str = "dense",
                 block_size: int = 16, pool_blocks: int | None = None,
                 **fault_knobs):
        self.cfg = cfg
        self.k_stages = int(k_stages)
        self.max_len = int(max_len)
        # kv_layout="paged": ONE physical block pool per served stage
        # instead of a max_len-wide cache per request — concurrent
        # requests share the pool, each holding only ceil(len/blk)
        # blocks (+1 lazily at each block boundary during decode).
        # Exhaustion is a typed "kv-exhausted" RPC error the router
        # treats like any peer failure: fail over to another holder.
        self.kv_layout = kv_layout
        self.block_size = int(block_size)
        self.pool_blocks = pool_blocks
        if kv_layout == "paged" and \
                getattr(cfg, "sliding_window", None) is not None:
            raise ValueError("paged kv_layout does not support SWA "
                             "ring caches in the stage tier")
        self._stage_defs = registry.make_stages(cfg, k_stages)
        self._stages: dict[int, object] = {}      # sid -> params
        self._reqs: dict[tuple, dict] = {}        # (rid, sid) -> state
        self._jits: dict[tuple, object] = {}
        self._pools: dict[int, dict] = {}         # sid -> paged pool
        self._slock = threading.Lock()
        self._plock = threading.Lock()   # serializes pool read-mod-write
        super().__init__(store, host, port, **fault_knobs)

    # -- stage lifecycle -----------------------------------------------------

    def serve_stage(self, sid: int, params) -> None:
        with self._slock:
            self._stages[int(sid)] = params

    def drop_stage(self, sid: int) -> None:
        with self._slock:
            self._stages.pop(int(sid), None)

    def stage_ids(self) -> list[int]:
        with self._slock:
            return sorted(self._stages)

    def adopt_stage(self, sid: int, peers: Sequence[Addr], *,
                    pool: PeerConnPool | None = None,
                    retry: RetryPolicy | None = None,
                    possession: dict | None = None,
                    timeout: float = 20.0) -> dict:
        """Fetch stage ``sid``'s published weights from the swarm into
        the local store (dedup means a rejoin only pulls what's
        missing), rebuild the params and start serving."""
        stats = swarm_fetch(peers, self.store, step=int(sid),
                            pool=pool, retry=retry,
                            possession=possession, timeout=timeout)
        params = restore_stage_params(self.store, self.cfg,
                                      self.k_stages, int(sid))
        self.serve_stage(int(sid), params)
        return stats

    # -- gossip possession (chunks + stage tokens) ---------------------------

    def _inventory(self) -> list[str]:
        with self._slock:
            stage_ids = [stage_chunk_id(s) for s in self._stages]
        return sorted(set(self.store.inventory()) | set(stage_ids))

    # -- request compute -----------------------------------------------------

    def _jit(self, kind: str, sid: int):
        key = (kind, sid)
        fn = self._jits.get(key)
        if fn is None:
            stage = self._stage_defs[sid]
            if kind == "prefill":
                fn = jax.jit(lambda p, x, c, pl, _f=stage.prefill:
                             _f(p, x, c, prompt_len=pl))
            else:
                fn = jax.jit(lambda p, x, c, _f=stage.decode:
                             _f(p, x, c))
            self._jits[key] = fn
        return fn

    def _respond_tensor(self, conn, arr) -> None:
        """Ship ``{"ok", "meta"}`` + one tensor frame, applying the
        inherited fault knobs (the response counts as one served
        chunk)."""
        if self.stall_chunks is not None and \
                self.served_chunks >= self.stall_chunks:
            time.sleep(self.stall_s)
        blob, meta = _encode_arr(arr)
        _send_frame(conn, json.dumps({"ok": True,
                                      "meta": meta}).encode())
        if self.corrupt_after is not None and \
                self.served_chunks >= self.corrupt_after:
            # in-transit corruption: a frame whose digest was computed
            # over the TRUE payload but whose bytes got flipped — the
            # receiver's frame check raises ChecksumError, typed
            digest = hashlib.sha256(blob).digest()
            bad = bytes(b ^ 0xFF for b in blob[:64]) + blob[64:]
            conn.sendall(struct.pack("!Q", len(blob)) + digest + bad)
        else:
            _send_frame(conn, blob)
        self.served_chunks += 1

    def _err(self, conn, **payload) -> bool:
        _send_frame(conn, json.dumps(payload).encode())
        return True

    def _paged_pool(self, sid: int) -> dict:
        """Lazily build stage ``sid``'s shared block pool (pool arrays
        + host allocator). Caller holds ``_plock``."""
        ent = self._pools.get(sid)
        if ent is None:
            from repro.serving.paging import (BlockPool,
                                              paged_cache_from_template)
            stage = self._stage_defs[sid]
            template = jax.eval_shape(
                lambda: stage.init_cache(1, self.max_len))
            # default: 4 requests' worth of blocks — the pool exists
            # to hold several concurrent requests, not one
            want = self.pool_blocks or \
                4 * (self.max_len // self.block_size) + 1
            cache, nb, n_blocks = paged_cache_from_template(
                template, slots=1, block_size=self.block_size,
                n_blocks=want)
            ent = {"cache": cache, "pool": BlockPool(n_blocks),
                   "nb": nb}
            self._pools[sid] = ent
        return ent

    def _row_arr(self, ent: dict, row: list) -> jnp.ndarray:
        r = np.full((ent["nb"],), -1, np.int32)
        r[:len(row)] = row
        return jnp.asarray(r)

    def _paged_install(self, conn, sid: int, rid, plen: int,
                       new_cache) -> bool:
        """Move a fresh dense stage prefill into pool blocks and record
        the request's (row, length). Returns False on pool exhaustion
        (error already sent)."""
        from repro.serving.paging import BlockPoolExhaustedError
        with self._plock:
            ent = self._paged_pool(sid)
            with self._slock:
                old = self._reqs.get((rid, sid))
            if old is not None:
                for b in old.get("row", ()):
                    ent["pool"].decref(b)
            try:
                row = ent["pool"].alloc(
                    max(1, -(-plen // self.block_size)))
            except BlockPoolExhaustedError as e:
                with self._slock:
                    self._reqs.pop((rid, sid), None)
                self._err(conn, error="kv-exhausted", sid=sid,
                          detail=str(e))
                return False
            rowd = self._row_arr(ent, row)
            ent["cache"] = jax.tree.map(
                lambda c, nc: _paged_scatter(c, nc, rowd,
                                             self.block_size),
                ent["cache"], new_cache, is_leaf=_is_paged)
            with self._slock:
                self._reqs[(rid, sid)] = {"row": row, "len": plen,
                                          "last_out": None}
        return True

    def _paged_decode(self, conn, params, sid: int, rid, x,
                      req: dict) -> bool:
        from repro.serving.paging import BlockPoolExhaustedError
        with self._plock:
            with self._slock:
                state = self._reqs.get((rid, sid))
            if state is None:
                return self._err(conn, error="no-such-request",
                                 rid=rid, sid=sid)
            seq = int(req.get("seq", state["len"]))
            if seq == state["len"] - 1 and \
                    state["last_out"] is not None:
                self._respond_tensor(conn, state["last_out"])
                return True
            if seq != state["len"]:
                return self._err(conn, error="seq-mismatch", rid=rid,
                                 sid=sid, expect=state["len"], got=seq)
            ent = self._paged_pool(sid)
            ln = state["len"]
            bi = ln // self.block_size
            if bi >= ent["nb"]:
                return self._err(conn, error="kv-exhausted", sid=sid,
                                 detail=f"request at capacity "
                                        f"{ent['nb'] * self.block_size}")
            if bi >= len(state["row"]):     # lazy growth at boundary
                try:
                    state["row"] += ent["pool"].alloc(1)
                except BlockPoolExhaustedError as e:
                    return self._err(conn, error="kv-exhausted",
                                     sid=sid, detail=str(e))
            rowd = self._row_arr(ent, state["row"])
            view = jax.tree.map(
                lambda c: _paged_view(c, rowd, jnp.int32(ln)),
                ent["cache"], is_leaf=_is_paged)
            out, new_view = self._jit("decode", sid)(params, x, view)
            ent["cache"] = jax.tree.map(
                lambda c, nc: c._replace(k=nc.k, v=nc.v),
                ent["cache"], new_view, is_leaf=_is_paged)
            out_np = np.asarray(out)
            with self._slock:
                self._reqs[(rid, sid)] = {"row": state["row"],
                                          "len": ln + 1,
                                          "last_out": out_np}
        self._respond_tensor(conn, out_np)
        return True

    def _handle_stage_op(self, conn, req: dict) -> bool:
        blob = _recv_frame(conn)
        sid, rid = int(req["sid"]), req["rid"]
        with self._slock:
            params = self._stages.get(sid)
        if params is None:
            return self._err(conn, error="no-such-stage", sid=sid)
        x = jax.numpy.asarray(_decode_arr(blob, req["meta"]))
        if req["op"] == "decode_stage" and self.kv_layout == "paged":
            return self._paged_decode(conn, params, sid, rid, x, req)
        if req["op"] == "prefill_stage":
            stage = self._stage_defs[sid]
            cache = stage.init_cache(1, self.max_len)
            plen = int(req.get("plen", x.shape[1]))
            out, new_cache = self._jit("prefill", sid)(
                params, x, cache,
                jax.numpy.asarray([plen], jax.numpy.int32))
            if req.get("install", True):
                if self.kv_layout == "paged":
                    # same dense prefill (bit-identical logits), then
                    # the KV moves into pool blocks
                    if not self._paged_install(conn, sid, rid, plen,
                                               new_cache):
                        return True         # kv-exhausted already sent
                else:
                    with self._slock:
                        self._reqs[(rid, sid)] = {
                            "cache": new_cache, "len": plen,
                            "last_out": None}
        else:                                       # decode_stage
            with self._slock:
                state = self._reqs.get((rid, sid))
            if state is None:
                return self._err(conn, error="no-such-request",
                                 rid=rid, sid=sid)
            seq = int(req.get("seq", state["len"]))
            if seq == state["len"] - 1 and state["last_out"] is not None:
                # duplicate delivery (retry after a lost response):
                # replay the saved output, do NOT re-append
                self._respond_tensor(conn, state["last_out"])
                return True
            if seq != state["len"]:
                return self._err(conn, error="seq-mismatch", rid=rid,
                                 sid=sid, expect=state["len"], got=seq)
            out, new_cache = self._jit("decode", sid)(
                params, x, state["cache"])
            out_np = np.asarray(out)
            with self._slock:
                self._reqs[(rid, sid)] = {"cache": new_cache,
                                          "len": state["len"] + 1,
                                          "last_out": out_np}
            self._respond_tensor(conn, out_np)
            return True
        self._respond_tensor(conn, out)
        return True

    def release(self, rid: str) -> int:
        with self._plock:
            with self._slock:
                gone = [k for k in self._reqs if k[0] == rid]
                states = [self._reqs.pop(k) for k in gone]
            if self.kv_layout == "paged":
                for (_, sid), st in zip(gone, states):
                    ent = self._pools.get(sid)
                    if ent is not None:
                        for b in st.get("row", ()):
                            ent["pool"].decref(b)
        return len(gone)

    # -- op dispatch ---------------------------------------------------------

    def _handle_op(self, conn, req: dict, pins: list) -> bool:
        op = req.get("op")
        if op in ("prefill_stage", "decode_stage"):
            if self.crash_after is not None and \
                    self.served_chunks >= self.crash_after:
                self.crash()
                return False
            return self._handle_stage_op(conn, req)
        if op == "stages":
            _send_frame(conn, json.dumps(
                {"stages": self.stage_ids(),
                 "k_stages": self.k_stages}).encode())
            return True
        if op == "release":
            _send_frame(conn, json.dumps(
                {"ok": True,
                 "released": self.release(req["rid"])}).encode())
            return True
        if op == "adopt_stage":
            try:
                stats = self.adopt_stage(
                    int(req["sid"]),
                    [tuple(a) for a in req["peers"]],
                    timeout=float(req.get("timeout", 20.0)))
            except (FetchError, OSError) as e:
                return self._err(conn, error="adopt-failed",
                                 detail=str(e))
            _send_frame(conn, json.dumps(
                {"ok": True, "stage": int(req["sid"]),
                 "chunks_fetched": stats["chunks_fetched"]}).encode())
            return True
        if op == "digest":
            # stage possession rides the chunk digest: adding/dropping
            # a stage changes the sha, so gossip pulls the inventory
            # (with its stage:NNNN tokens) exactly when it changed
            ids = self._inventory()
            sha = hashlib.sha256("\n".join(ids).encode()).hexdigest()
            _send_frame(conn, json.dumps(
                {"latest": self.store.latest_step(),
                 "n_chunks": len(ids), "sha": sha,
                 "version": self.store.version}).encode())
            return True
        if op == "inventory":
            _send_frame(conn, json.dumps(
                {"ids": self._inventory()}).encode())
            return True
        if op == "have":
            ids = set(self._inventory())
            _send_frame(conn, json.dumps(
                {"have": [int(d in ids) for d in req["ids"]]}).encode())
            return True
        return super()._handle_op(conn, req, pins)


# -- router --------------------------------------------------------------------


class _Request:
    __slots__ = ("rid", "prompt", "out", "chain", "lens", "replays")

    def __init__(self, rid, prompt, chain, k):
        self.rid = rid
        self.prompt = [int(t) for t in prompt]
        self.out: list[int] = []
        self.chain = chain             # sid -> Addr currently serving
        self.lens = [0] * k            # sid -> committed cache length
        self.replays = 0

    def prefix(self) -> list[int]:
        return self.prompt + self.out


class SwarmRouter:
    """Plans a stage chain from gossip possession and streams requests
    through it, failing over (with bounded-replay re-prefill) when a
    peer crashes, stalls past its deadline, or ships corrupt frames.
    See the module docstring for the recovery state machine."""

    def __init__(self, k_stages: int, gossip, *, timeout: float = 10.0,
                 pool: PeerConnPool | None = None,
                 max_replays: int = 8, max_len: int = 256,
                 bucket_prompts: bool = True, pad_id: int = 0):
        self.k = int(k_stages)
        self.gossip = gossip
        self.timeout = float(timeout)
        self.pool = pool
        self.max_replays = int(max_replays)
        self.max_len = int(max_len)
        self.bucket_prompts = bucket_prompts
        self.pad_id = int(pad_id)
        self.dead: set[Addr] = set()
        self.stats = {"requests": 0, "decode_steps": 0, "failovers": 0,
                      "replayed_tokens": 0, "recoveries": 0,
                      "recovery_s": 0.0, "fresh_retries": 0}

    # -- planning ------------------------------------------------------------

    def refresh(self) -> None:
        self.gossip.poll_once()

    def holders(self, sid: int) -> list[Addr]:
        return sorted(a for a in
                      self.gossip.holders(stage_chunk_id(sid))
                      if a not in self.dead)

    def _pick(self, sid: int, avoid: Sequence[Addr] = ()) -> Addr:
        hs = [a for a in self.holders(sid) if a not in avoid] \
            or self.holders(sid)
        if not hs:
            raise StageUnservableError(
                f"no surviving holder for stage {sid}", sid=sid)
        return hs[0]

    def plan_chain(self) -> list[Addr]:
        return [self._pick(s) for s in range(self.k)]

    def mark_dead(self, addr: Addr) -> None:
        self.dead.add(tuple(addr))
        if self.pool is not None:
            self.pool.discard_peer(addr)
        self.gossip.remove_peer(addr)

    def revive(self, addr: Addr) -> None:
        """A previously-dead peer rejoined (e.g. after adopt): make it
        plannable again."""
        self.dead.discard(tuple(addr))
        self.gossip.add_peer(addr)

    # -- wire ----------------------------------------------------------------

    def _roundtrip(self, conn: PeerConn, header: dict, arr):
        blob, meta = _encode_arr(arr)
        conn.send(dict(header, meta=meta))
        conn.send_bytes(blob)
        resp = conn.recv_json()
        if "error" in resp:
            raise StageRPCError(f"peer {conn.addr}: {resp}")
        return _decode_arr(conn.recv_frame(), resp["meta"])

    def _call(self, addr: Addr, header: dict, arr):
        """One stage RPC. A stalled peer (PeerTimeoutError) fails
        immediately — waiting out the deadline twice buys nothing. A
        closed/reset conn gets ONE fresh-socket retry when pooling is
        on (an idle pooled conn may have been reaped by the server
        between requests); the decode seq numbers make that retry safe
        even though the cache append is not idempotent."""
        try:
            if self.pool is not None:
                with self.pool.lease(addr) as conn:
                    return self._roundtrip(conn, header, arr)
            conn = PeerConn(addr, self.timeout)
            try:
                return self._roundtrip(conn, header, arr)
            finally:
                conn.close()
        except (PeerTimeoutError, StageRPCError):
            raise
        except (FetchError, OSError):
            if self.pool is None:
                raise
            self.stats["fresh_retries"] += 1
            conn = PeerConn(addr, self.timeout)
            try:
                out = self._roundtrip(conn, header, arr)
            except BaseException:
                conn.close()
                raise
            self.pool.release(conn)
            return out

    # -- failure accounting --------------------------------------------------

    def _fail(self, req: _Request, sid: int, addr: Addr, err) -> None:
        self.mark_dead(addr)
        self.stats["failovers"] += 1
        req.replays += 1
        if req.replays > self.max_replays:
            raise ReplayBudgetError(
                f"request {req.rid} exceeded {self.max_replays} "
                f"failovers (last: stage {sid} @ {addr}: {err})",
                sid=sid)
        req.chain[sid] = self._pick(sid, avoid=(addr,))

    # -- request flow --------------------------------------------------------

    def generate(self, prompt: Sequence[int], max_new_tokens: int,
                 *, rid: str | None = None,
                 eos_id: int | None = None) -> list[int]:
        """Greedy-decode up to ``max_new_tokens`` tokens through the
        chain (stopping at ``eos_id`` if given, matching the engine's
        retirement rule). Returns the emitted token ids; raises typed
        ``FetchError``s (never hangs) when the swarm cannot serve the
        request."""
        rid = rid or f"req{self.stats['requests']}"
        self.stats["requests"] += 1
        req = _Request(rid, prompt, self.plan_chain(), self.k)
        logits = self._prefill_chain(req)
        req.out.append(int(np.argmax(logits[0])))
        while len(req.out) < max_new_tokens and \
                req.out[-1] != eos_id:
            logits = self._decode_chain(req)
            self.stats["decode_steps"] += 1
            req.out.append(int(np.argmax(logits[0])))
        self._release(req)
        return req.out

    def _pad_prompt(self, toks: list) -> np.ndarray:
        """RIGHT-pad to the same power-of-two bucket the single-host
        engine uses, so the chain's prefill widths — and hence its
        logits — match the engine's bit for bit (``plen`` carries the
        true length for last-token gather / per-slot cache lengths)."""
        n = len(toks)
        padded = max(min(bucket_len(n), self.max_len), n) \
            if self.bucket_prompts else n
        row = np.full((1, padded), self.pad_id, np.int32)
        row[0, :n] = toks
        return row

    def _prefill_chain(self, req: _Request):
        """Initial prefill. On failure the router still holds the
        activations it was sending, so failover is resend-to-survivor:
        no replay needed."""
        x = self._pad_prompt(req.prompt)
        sid = 0
        while sid < self.k:
            addr = req.chain[sid]
            try:
                x = self._call(addr, {"op": "prefill_stage", "sid": sid,
                                      "rid": req.rid, "install": True,
                                      "plen": len(req.prompt)}, x)
            except (FetchError, OSError) as e:
                self._fail(req, sid, addr, e)
                continue
            req.lens[sid] = len(req.prompt)
            sid += 1
        return x

    def _decode_chain(self, req: _Request):
        token = np.asarray([[req.out[-1]]], np.int32)
        x = token
        for sid in range(self.k):
            addr = req.chain[sid]
            try:
                x = self._call(addr, {"op": "decode_stage", "sid": sid,
                                      "rid": req.rid,
                                      "seq": req.lens[sid]}, x)
            except (FetchError, OSError) as e:
                self._fail(req, sid, addr, e)
                return self._recover_decode(req, sid)
            req.lens[sid] += 1
        return x

    def _recover_decode(self, req: _Request, fail_sid: int):
        """Bounded-replay re-prefill after a mid-decode failure at
        ``fail_sid`` (its replacement holder is already planned).
        Invariant on entry: stages < fail_sid committed the in-flight
        token (length L = len(prefix)); stages >= fail_sid are at
        L - 1. Returns the logits the failed step was computing."""
        t0 = time.monotonic()
        self.stats["recoveries"] += 1
        toks = req.prefix()
        L = len(toks)
        prefix = self._pad_prompt(toks)
        self.stats["replayed_tokens"] += L
        install = {fail_sid}
        while True:
            x, sid, restart = prefix, 0, False
            while sid <= fail_sid:
                addr = req.chain[sid]
                try:
                    x = self._call(
                        addr, {"op": "prefill_stage", "sid": sid,
                               "rid": req.rid, "plen": L,
                               "install": sid in install}, x)
                except (FetchError, OSError) as e:
                    self._fail(req, sid, addr, e)
                    # the replacement lost its committed state too:
                    # it needs a full re-prefill, not a pass-through
                    install.add(sid)
                    restart = True
                    break
                if sid in install:
                    req.lens[sid] = L
                sid += 1
            if restart:
                continue
            self.stats["recovery_s"] += time.monotonic() - t0
            if fail_sid == self.k - 1:
                return x                       # (1, V) logits
            x_last = x[:, L - 1:L, :]          # true last position,
                                               # not the pad tail
            for sid in range(fail_sid + 1, self.k):
                addr = req.chain[sid]
                try:
                    x_last = self._call(
                        addr, {"op": "decode_stage", "sid": sid,
                               "rid": req.rid,
                               "seq": req.lens[sid]}, x_last)
                except (FetchError, OSError) as e:
                    # stages fail_sid+1 .. sid-1 committed the token
                    # during this pass, so the invariant holds with
                    # the failure point moved to sid: recurse
                    self._fail(req, sid, addr, e)
                    return self._recover_decode(req, sid)
                req.lens[sid] += 1
            return x_last

    def _release(self, req: _Request) -> None:
        for addr in set(req.chain):
            if addr in self.dead:
                continue
            try:
                self._call_simple(addr, {"op": "release",
                                         "rid": req.rid})
            except (FetchError, OSError):
                pass

    def _call_simple(self, addr: Addr, header: dict) -> dict:
        if self.pool is not None:
            with self.pool.lease(addr) as conn:
                return conn.request_json(header)
        conn = PeerConn(addr, self.timeout)
        try:
            return conn.request_json(header)
        finally:
            conn.close()
