"""Architecture + shape configuration system.

Every assigned architecture gets one file in this package instantiating
``ArchConfig`` with the published numbers; ``reduced()`` derives the
small same-family sibling used by the CPU smoke tests. The four
input-shape cells are global (``SHAPES``); applicability rules (e.g.
long_500k requires a sub-quadratic path) live on the config.
"""
from __future__ import annotations

import dataclasses

import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class MoEConfig:
    n_experts: int
    top_k: int
    d_expert: int
    n_shared: int = 0
    first_dense: int = 0          # leading dense-FFN layers (DeepSeek)
    capacity_factor: float = 1.25
    lb_weight: float = 0.01


@dataclasses.dataclass(frozen=True)
class SSMArchConfig:
    d_state: int
    head_dim: int = 64
    n_groups: int = 1
    conv_kernel: int = 4
    expand: int = 2
    # SSD chunk: the intra-chunk L-matrix scales with b*L*q while the
    # stacked inter-chunk states scale with b*(L/q)*p*n -> q ~ sqrt(p*n)
    chunk: int = 128


@dataclasses.dataclass(frozen=True)
class ShapeConfig:
    name: str
    kind: str          # 'train' | 'prefill' | 'decode'
    seq_len: int
    global_batch: int


SHAPES: dict[str, ShapeConfig] = {
    "train_4k": ShapeConfig("train_4k", "train", 4096, 256),
    "prefill_32k": ShapeConfig("prefill_32k", "prefill", 32768, 32),
    "decode_32k": ShapeConfig("decode_32k", "decode", 32768, 128),
    "long_500k": ShapeConfig("long_500k", "decode", 524288, 1),
}


@dataclasses.dataclass(frozen=True)
class ArchConfig:
    name: str
    family: str        # 'dense' | 'moe' | 'vlm' | 'encdec' | 'ssm' | 'hybrid'
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    d_ff: int
    vocab: int
    head_dim: int | None = None
    moe: MoEConfig | None = None
    ssm: SSMArchConfig | None = None
    sliding_window: int | None = None
    attn_every: int | None = None     # hybrid: shared attn period
    n_frontend: int = 0               # VLM/audio stub tokens
    rope_theta: float = 1e4
    norm_eps: float = 1e-5
    tie_embeddings: bool = False
    dtype: str = "bfloat16"
    max_z_weight: float = 2e-4        # paper: auxiliary max-z loss
    block_q: int = 512                # chunked-attention query block
    source: str = ""
    # per-arch parallelism hints (see sharding.plans)
    diloco_pref: str = "auto"         # 'auto' | 'pod_only' | 'none'
    fsdp_data: bool = False           # additionally shard params on 'data'
    # serving decode-attention backend: 'jnp' | 'pallas' (flash-decode
    # TPU kernel; interpret mode off-TPU — see kernels/flash_decode.py)
    decode_attn_impl: str = "jnp"

    @property
    def np_dtype(self):
        return {"bfloat16": jnp.bfloat16, "float32": jnp.float32}[
            self.dtype]

    @property
    def padded_vocab(self) -> int:
        """Vocab rounded up to a multiple of 256 so the embedding/head
        shard evenly over a 16-wide model axis (Megatron-style vocab
        padding — the published size stays the *logical* vocab)."""
        return -(-self.vocab // 256) * 256

    # -- applicability --------------------------------------------------------

    @property
    def sub_quadratic(self) -> bool:
        return (self.family in ("ssm", "hybrid")
                or self.sliding_window is not None)

    def supports(self, shape: ShapeConfig) -> bool:
        if shape.name == "long_500k" and not self.sub_quadratic:
            return False          # dense-attn 500k has no sub-quadratic path
        return True

    # -- analytic parameter counts -------------------------------------------

    def param_count(self) -> int:
        d, hd = self.d_model, self.head_dim or self.d_model // self.n_heads
        emb = self.padded_vocab * d * (1 if self.tie_embeddings else 2)
        if self.family in ("dense", "moe", "vlm"):
            att = d * hd * (self.n_heads * 2 + self.n_kv_heads * 2)
            if self.moe:
                n_moe = self.n_layers - self.moe.first_dense
                moe_l = (d * self.moe.n_experts
                         + 3 * d * self.moe.d_expert * self.moe.n_experts
                         + 3 * d * self.moe.d_expert * self.moe.n_shared)
                dense_l = 3 * d * self.d_ff
                return (emb + self.n_layers * att
                        + n_moe * moe_l + self.moe.first_dense * dense_l)
            return emb + self.n_layers * (att + 3 * d * self.d_ff)
        if self.family == "encdec":
            att = d * hd * (self.n_heads * 2 + self.n_kv_heads * 2)
            n_enc = self.n_layers // 2
            n_dec = self.n_layers - n_enc
            return (emb + n_enc * (att + 3 * d * self.d_ff)
                    + n_dec * (2 * att + 3 * d * self.d_ff))
        # ssm / hybrid
        s = self.ssm
        di = s.expand * d
        gn = s.n_groups * s.d_state
        h = di // s.head_dim
        mamba_l = (2 * d * di + 2 * d * gn + d * h     # projections
                   + s.conv_kernel * (di + 2 * gn)     # convs
                   + 3 * h + di + di * d)              # A/D/dt, norm, out
        total = emb + self.n_layers * mamba_l
        if self.attn_every:
            att = d * hd * (self.n_heads * 2 + self.n_kv_heads * 2)
            total += att + 3 * d * self.d_ff
        return total

    def active_param_count(self) -> int:
        """Active params per token (= total unless MoE)."""
        if not self.moe:
            return self.param_count()
        n_moe = self.n_layers - self.moe.first_dense
        routed = 3 * self.d_model * self.moe.d_expert * self.moe.n_experts
        active_routed = routed * self.moe.top_k / self.moe.n_experts
        return int(self.param_count() - n_moe * (routed - active_routed))

    # -- smoke-test sibling ----------------------------------------------------

    def reduced(self) -> "ArchConfig":
        kw = dict(
            name=self.name + "-reduced",
            n_layers=max(2, 4 if self.attn_every else 2),
            d_model=64, n_heads=4,
            n_kv_heads=min(self.n_kv_heads, 2) if
            self.n_kv_heads < self.n_heads else 4,
            d_ff=128, vocab=512, head_dim=16,
            dtype="float32", block_q=64,
        )
        if self.moe:
            kw["moe"] = dataclasses.replace(
                self.moe, n_experts=8, top_k=2, d_expert=32,
                n_shared=min(self.moe.n_shared, 1),
                first_dense=min(self.moe.first_dense, 1))
        if self.ssm:
            kw["ssm"] = dataclasses.replace(
                self.ssm, d_state=16, head_dim=16, chunk=16)
            kw["d_ff"] = 128 if self.d_ff else 0
        if self.attn_every:
            kw["attn_every"] = 2
        if self.sliding_window:
            kw["sliding_window"] = 32
        if self.n_frontend:
            kw["n_frontend"] = 8
        return dataclasses.replace(self, **kw)
