"""Config registry: ``get_config(arch_id)`` + the assigned-arch list."""
from repro.configs import (base, dbrx_132b, deepseek_moe_16b,
                           granite_3_2b, h2o_danube_1_8b, intellect_1,
                           internlm2_1_8b, mamba2_130m, minicpm_2b,
                           phi_3_vision_4_2b, seamless_m4t_medium,
                           zamba2_2_7b)
from repro.configs.base import SHAPES, ArchConfig, ShapeConfig

_MODULES = [seamless_m4t_medium, internlm2_1_8b, h2o_danube_1_8b,
            minicpm_2b, granite_3_2b, deepseek_moe_16b, dbrx_132b,
            phi_3_vision_4_2b, zamba2_2_7b, mamba2_130m, intellect_1]

CONFIGS: dict[str, ArchConfig] = {m.CONFIG.name: m.CONFIG
                                  for m in _MODULES}
ASSIGNED: tuple[str, ...] = tuple(m.CONFIG.name for m in _MODULES[:10])


def get_config(name: str) -> ArchConfig:
    if name not in CONFIGS:
        raise KeyError(f"unknown arch {name!r}; known: "
                       f"{sorted(CONFIGS)}")
    return CONFIGS[name]


__all__ = ["ArchConfig", "ShapeConfig", "SHAPES", "CONFIGS", "ASSIGNED",
           "get_config"]
