"""zamba2-2.7b — hybrid: Mamba2 backbone + shared attention block.
[arXiv:2411.15242; hf]
54L d_model=2560 32H (kv=32) d_ff=10240, ssm_state=64.
One shared attention+MLP block applied every 6 layers (9 applications)
— simplified from Zamba2's shared-block-with-LoRA (DESIGN.md
§Arch-applicability). Hybrid -> long_500k RUNS."""
from repro.configs.base import ArchConfig, SSMArchConfig

CONFIG = ArchConfig(
    name="zamba2-2.7b",
    family="hybrid",
    n_layers=54,
    d_model=2560,
    n_heads=32,
    n_kv_heads=32,
    d_ff=10240,
    vocab=32000,
    ssm=SSMArchConfig(d_state=64, head_dim=64),
    attn_every=6,
    source="arXiv:2411.15242; hf",
)
