"""INTELLECT-1 — the paper's own 10B model (Table 5): Llama-3
architecture, 42 layers (vs Llama3-8B's 32), d_model=4096, 32 heads,
GQA kv=8, d_ff=14336, vocab=128256, seq 8192, batch 128, max-z-loss
2e-4. Trained with DiLoCo H=100, inner AdamW lr 7.5e-5, outer Nesterov
lr 0.7 / momentum 0.9."""
from repro.configs.base import ArchConfig

CONFIG = ArchConfig(
    name="intellect-1",
    family="dense",
    n_layers=42,
    d_model=4096,
    n_heads=32,
    n_kv_heads=8,
    d_ff=14336,
    vocab=128256,
    rope_theta=5e5,
    source="INTELLECT-1 Technical Report, Appendix A",
)
