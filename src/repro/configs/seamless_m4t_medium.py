"""seamless-m4t-medium — enc-dec multimodal (speech) backbone.
[arXiv:2308.11596; hf]  12L (6 enc + 6 dec here; the assignment's "12L"
is split evenly), d_model=1024, 16H (GQA kv=16 == MHA), d_ff=4096,
vocab=256206. The speech frontend is a stub: input_specs() provides
precomputed frame embeddings. Shapes: src_len = tgt_len = seq_len // 2
so total processed positions == seq_len (documented in DESIGN.md)."""
from repro.configs.base import ArchConfig

CONFIG = ArchConfig(
    name="seamless-m4t-medium",
    family="encdec",
    n_layers=12,
    d_model=1024,
    n_heads=16,
    n_kv_heads=16,
    d_ff=4096,
    vocab=256206,
    source="arXiv:2308.11596; hf",
)
