"""minicpm-2b — dense llama-like, WSD schedule, tied embeddings.
[arXiv:2404.06395; hf]  40L d_model=2304 36H (kv=36, MHA) d_ff=5760
vocab=122753. MiniCPM popularized the WSD schedule the paper also uses
(optim/schedules.py)."""
from repro.configs.base import ArchConfig

CONFIG = ArchConfig(
    name="minicpm-2b",
    family="dense",
    n_layers=40,
    d_model=2304,
    n_heads=36,
    n_kv_heads=36,
    d_ff=5760,
    vocab=122753,
    tie_embeddings=True,
    source="arXiv:2404.06395; hf",
)
