"""dbrx-132b — large MoE: 16 experts top-4.
[hf:databricks/dbrx-base; unverified]
40L d_model=6144 48H (GQA kv=8) expert d_ff=10752 vocab=100352.

Memory plan: DiLoCo over the 'pod' axis ONLY (a full 132B replica per
DiLoCo worker needs ~16 bytes/param incl. Adam + anchor; 256 chips/pod
gives ~8.3 GB/chip) and params additionally FSDP-sharded over 'data'.
"""
from repro.configs.base import ArchConfig, MoEConfig

CONFIG = ArchConfig(
    name="dbrx-132b",
    family="moe",
    n_layers=40,
    d_model=6144,
    n_heads=48,
    n_kv_heads=8,
    d_ff=10752,
    vocab=100352,
    moe=MoEConfig(n_experts=16, top_k=4, d_expert=10752),
    diloco_pref="pod_only",
    fsdp_data=True,
    source="hf:databricks/dbrx-base; unverified",
)
