"""phi-3-vision-4.2b — phi3-mini backbone + CLIP vision frontend (STUB).
[hf:microsoft/Phi-3-vision-128k-instruct; hf]
32L d_model=3072 32H (kv=32, MHA) d_ff=8192 vocab=32064.
input_specs() provides 576 precomputed patch embeddings per image,
prepended to the text tokens; the loss is masked to text positions.
train_4k: 576 image + 3520 text positions = 4096 total."""
from repro.configs.base import ArchConfig

CONFIG = ArchConfig(
    name="phi-3-vision-4.2b",
    family="vlm",
    n_layers=32,
    d_model=3072,
    n_heads=32,
    n_kv_heads=32,
    d_ff=8192,
    vocab=32064,
    n_frontend=576,
    source="hf:microsoft/Phi-3-vision-128k-instruct; hf",
)
