"""mamba2-130m — pure SSM (SSD, state-space duality), attention-free.
[arXiv:2405.21060; unverified]
24L d_model=768 (attn-free, d_ff=0) vocab=50280, ssm_state=128,
tied embeddings. long_500k RUNS (O(1)-per-token recurrent decode)."""
from repro.configs.base import ArchConfig, SSMArchConfig

CONFIG = ArchConfig(
    name="mamba2-130m",
    family="ssm",
    n_layers=24,
    d_model=768,
    n_heads=24,            # d_inner / head_dim = 1536 / 64
    n_kv_heads=24,
    d_ff=0,
    vocab=50280,
    ssm=SSMArchConfig(d_state=128, head_dim=64),
    tie_embeddings=True,
    source="arXiv:2405.21060; unverified",
)
