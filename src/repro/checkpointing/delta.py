"""Quantized delta checkpoints over the chunk store.

The paper's 400x idea — ship int8-coded differences instead of fp32
state — applied to recovery traffic: after a full **base** snapshot,
each checkpoint persists only the int8/int4-coded difference against a
**reference** chain, reusing the sync engine's quantization codec
(``kernels.ops.quantize_pseudograd`` — the exact scale-aware 6-sigma /
bucket-mean scheme the ring uses on pseudo-gradients).

Exactness contract (the error-feedback trick, applied to storage):
the writer does NOT delta against the true previous state — it deltas
against its own *reconstruction* ``ref`` and then advances ``ref`` by
the dequantized delta it just stored:

    ref_0   = base                      (stored exactly)
    q_t     = quantize(theta_t - ref_{t-1})
    ref_t   = ref_{t-1} + dequantize(q_t)      # pure-numpy fp32 adds

A restorer replaying the chain computes bit-for-bit the same ``ref_t``
(the apply step is deterministic elementwise numpy, shared between
writer and reader, and every manifest records the sha256 of the
reconstruction it must produce). Quantization error therefore never
*compounds* across the chain — each step's reconstruction is within
one quantization step of the true value — and a periodic re-anchor
(``base_every``) bounds even that.

Wire/storage win: codes are 1 byte (int8) or a packed nibble (int4)
per element instead of 4, and update deltas are heavy-tailed, so the
6-sigma clip concentrates codes into few buckets; the store's deflate
layer then recovers most of the code-width/entropy gap. Post-sync
``params`` and ``anchor`` trees are bit-identical, so their code
chunks dedup to a single copy on top.
"""
from __future__ import annotations

import dataclasses
import hashlib
import threading
from typing import Any

import numpy as np

from repro.checkpointing import checkpoint as _ckpt
from repro.checkpointing.store import (ChunkMissingError, ChunkStore,
                                       chunk_ids)


class DeltaChainError(ValueError):
    """The stored chain does not reproduce the manifest's recorded
    reconstruction (corruption or writer/reader codec drift)."""


@dataclasses.dataclass(frozen=True)
class DeltaConfig:
    base_every: int = 8        # full re-anchor every N checkpoints
    codec: str = "int8"        # 'int8' | 'int4'
    quant_impl: str = "jnp"    # 'jnp' | 'pallas' (encoder only)


def _is_float(arr: np.ndarray) -> bool:
    return (arr.dtype.kind == "f" or str(arr.dtype) == "bfloat16") \
        and arr.size > 0


def _apply_delta(ref: np.ndarray, codes: np.ndarray,
                 codebook: np.ndarray) -> np.ndarray:
    """ref + codebook[codes] in plain fp32 numpy — the ONE apply path
    shared by writer and restorer, so the chain is bit-reproducible."""
    return ref + codebook[codes.astype(np.int32)]


def _unpack4(packed: np.ndarray, numel: int) -> np.ndarray:
    """Hi-nibble-first unpack matching ``compression.quantize4``'s
    packing — the ONE copy both writer and restorer go through (the
    chain's bit-exactness depends on the two sides agreeing)."""
    return np.stack([packed // 16, packed % 16],
                    axis=-1).reshape(-1)[:numel]


def _encode(new_f32: np.ndarray, ref: np.ndarray, cfg: DeltaConfig
            ) -> tuple[np.ndarray, np.ndarray, bytes]:
    """Quantize ``new - ref``; returns (codes for _apply_delta,
    fp32 codebook, wire bytes of the codes)."""
    import jax.numpy as jnp

    from repro.kernels import ops as qops
    if cfg.codec == "int8":
        q = qops.quantize_pseudograd(jnp.asarray(new_f32),
                                     jnp.asarray(ref),
                                     impl=cfg.quant_impl)
        codes = np.asarray(q.codes, np.uint8)
        return codes, np.asarray(q.codebook, np.float32), codes.tobytes()
    if cfg.codec == "int4":
        from repro.core import compression
        q4 = compression.quantize4(jnp.asarray(new_f32 - ref))
        packed = np.asarray(q4.packed, np.uint8)
        codes = _unpack4(packed, new_f32.size)
        return codes, np.asarray(q4.codebook, np.float32), packed.tobytes()
    raise ValueError(f"unknown delta codec {cfg.codec!r}")


def _decode_codes(buf: bytes, codec: str, numel: int) -> np.ndarray:
    raw = np.frombuffer(buf, np.uint8)
    if codec == "int8":
        return raw
    return _unpack4(raw, numel)


class DeltaCheckpointer:
    """Writer for a base + quantized-delta checkpoint chain."""

    def __init__(self, store: ChunkStore, cfg: DeltaConfig = DeltaConfig()):
        self.store = store
        self.cfg = cfg
        self._ref: dict[str, np.ndarray] | None = None   # flat fp32
        self._sig: dict[str, tuple] | None = None
        self._since_base = 0
        self._prev_step: int | None = None
        self._base_step: int | None = None

    def _signature(self, flat: dict[str, np.ndarray]) -> dict[str, tuple]:
        return {k: (tuple(a.shape), str(a.dtype)) for k, a in flat.items()}

    def save(self, step: int, tree: Any,
             extra_meta: dict | None = None) -> dict:
        flat = _ckpt._flatten(tree)
        sig = self._signature(flat)
        float_keys = [k for k, a in flat.items() if _is_float(a)]
        rebase = (self._ref is None or sig != self._sig
                  or not float_keys
                  or self.cfg.base_every <= 1
                  or self._since_base >= self.cfg.base_every)
        if rebase:
            manifest = self.store.save_tree(step, tree, extra_meta,
                                            kind="base")
            self._ref = {k: np.asarray(flat[k], np.float32)
                         .reshape(-1).copy() for k in float_keys}
            self._sig = sig
            self._since_base = 1
            self._base_step = step
        else:
            try:
                manifest = self._save_delta(step, flat, float_keys,
                                            extra_meta)
            except BaseException:
                # a partial write must not leave the in-memory ref
                # ahead of the persisted chain: force a re-anchor
                self._ref = None
                raise
            self._since_base += 1
        self._prev_step = step
        return manifest

    def _save_delta(self, step: int, flat: dict[str, np.ndarray],
                    float_keys: list[str],
                    extra_meta: dict | None) -> dict:
        keys: dict[str, dict] = {}
        ref_sha: dict[str, str] = {}
        new_refs: dict[str, np.ndarray] = {}   # staged; committed only
        #                                        after the manifest lands
        logical = new_bytes = codes_bytes = dedup = 0
        for key, arr in flat.items():
            buf_len = arr.size * arr.dtype.itemsize
            logical += buf_len
            if key not in float_keys:
                # non-float leaves (step counters...) ship full: tiny
                buf, dtype = _ckpt.leaf_to_bytes(arr)
                chunks, nb, dd = self.store._put_leaf(buf)
                keys[key] = {"shape": list(arr.shape), "dtype": dtype,
                             "chunks": chunks}
                new_bytes += nb
                dedup += dd
                continue
            new_f32 = np.asarray(arr, np.float32).reshape(-1)
            codes, codebook, wire = _encode(new_f32, self._ref[key],
                                            self.cfg)
            chunks, nb, dd = self.store._put_leaf(wire)
            book_id, book_nb = self.store.put(codebook.tobytes())
            new_refs[key] = _apply_delta(self._ref[key], codes,
                                         codebook)
            ref_sha[key] = hashlib.sha256(
                new_refs[key].tobytes()).hexdigest()
            keys[key] = {"shape": list(arr.shape),
                         "dtype": str(arr.dtype),
                         "delta": {"codec": self.cfg.codec,
                                   "numel": int(arr.size),
                                   "codes_chunks": chunks,
                                   "codebook_id": book_id}}
            new_bytes += nb + book_nb
            codes_bytes += len(wire)
            dedup += dd
        manifest = {"format": "chunked-v1", "step": int(step),
                    "kind": "delta", "meta": extra_meta or {},
                    "base_step": self._base_step,
                    "prev_step": self._prev_step,
                    "ref_sha": ref_sha, "keys": keys,
                    "stats": {"logical_bytes": logical,
                              "new_bytes": new_bytes,
                              "codes_bytes": codes_bytes,
                              "dedup_chunks": dedup}}
        self.store.write_manifest(manifest)
        self._ref.update(new_refs)
        return manifest

    def reference(self, like: Any) -> Any:
        """The writer-side reconstruction as a pytree shaped like
        ``like`` (what a chain restore must reproduce bit-exactly)."""
        assert self._ref is not None, "no checkpoint written yet"
        flat_like = _ckpt._flatten(like)
        out = {}
        for k, a in flat_like.items():
            if k in self._ref:
                out[k] = self._ref[k].reshape(a.shape).astype(a.dtype)
            else:
                out[k] = a
        return _ckpt.unflatten_like(like, out)


class ChainReplayer:
    """Incremental, streaming-safe delta-chain replay.

    Built from the manifest chain (base first), it tracks which chunk
    ids each step still lacks; ``on_chunk`` (called from the fetch
    worker threads as verified chunks land in the store) replays every
    consecutive chain step the moment its last chunk arrives — so by
    the time the final chunk lands, the whole reconstruction is already
    assembled and a joiner's restore is one ``finish`` call instead of
    a full chain replay at the outer boundary.

    Replay is the SAME elementwise-numpy apply path as ``restore``
    (``_apply_delta``), sha-verified per step against the writer's
    recorded reconstruction, so a streamed restore is bit-exact.
    Thread-safe: fetch workers race on ``on_chunk``; replay itself runs
    under the lock, strictly in chain order.
    """

    def __init__(self, store: ChunkStore, chain: list[dict]):
        assert chain, "empty manifest chain"
        assert chain[0]["kind"] != "delta", \
            "chain must start at a base/full manifest"
        self.store = store
        self.chain = chain
        self._lock = threading.Lock()
        self._applied = 0
        self._ref: dict[str, np.ndarray] = {}
        # per-step sets of chunk ids not yet locally present
        self._pending: list[set[str]] = [
            {d for d in chunk_ids(m) if not store.has(d)}
            for m in chain]
        self.stats = {"replayed_steps": 0, "replayed_on_stream": 0}

    # -- progress ------------------------------------------------------------

    @property
    def applied_steps(self) -> int:
        with self._lock:
            return self._applied

    @property
    def complete(self) -> bool:
        with self._lock:
            return self._applied == len(self.chain)

    def remaining_chunks(self) -> int:
        with self._lock:
            return len(set().union(*self._pending)) if self._pending \
                else 0

    def on_chunk(self, digest: str, n_bytes: int = 0) -> int:
        """A verified chunk landed in the store; replay whatever chain
        steps just became complete. Returns steps newly applied."""
        del n_bytes
        with self._lock:
            for pend in self._pending:
                pend.discard(digest)
            applied = self._advance_locked()
            self.stats["replayed_on_stream"] += applied
            return applied

    def advance(self) -> int:
        """Replay every consecutive step whose chunks are all local
        (recomputed from the store — the non-streaming entry point)."""
        with self._lock:
            for i, m in enumerate(self.chain[self._applied:],
                                  self._applied):
                self._pending[i] = {d for d in chunk_ids(m)
                                    if not self.store.has(d)}
            return self._advance_locked()

    # -- replay --------------------------------------------------------------

    def _advance_locked(self) -> int:
        applied = 0
        while self._applied < len(self.chain) and \
                not self._pending[self._applied]:
            self._apply_step(self.chain[self._applied])
            self._applied += 1
            applied += 1
            self.stats["replayed_steps"] += 1
        return applied

    def _apply_step(self, m: dict) -> None:
        if m["kind"] != "delta":       # the base: load float leaves
            for key, entry in m["keys"].items():
                arr = self.store.read_leaf(entry)
                if _is_float(arr):
                    self._ref[key] = np.asarray(
                        arr, np.float32).reshape(-1)
            return
        for key, entry in m["keys"].items():
            delta = entry.get("delta")
            if delta is None:
                continue
            wire = b"".join(self.store.get(c["id"])
                            for c in delta["codes_chunks"])
            codes = _decode_codes(wire, delta["codec"], delta["numel"])
            codebook = np.frombuffer(
                self.store.get(delta["codebook_id"]), np.float32)
            self._ref[key] = _apply_delta(self._ref[key], codes,
                                          codebook)
            got = hashlib.sha256(self._ref[key].tobytes()).hexdigest()
            if got != m["ref_sha"][key]:
                raise DeltaChainError(
                    f"chain replay diverged at step {m['step']} "
                    f"leaf {key!r}")

    def finish(self, like: Any) -> tuple[Any, dict]:
        """The fully-replayed tree shaped/dtyped like ``like`` plus the
        target step's meta. Raises ``ChunkMissingError`` if the chain
        has not fully streamed in yet."""
        with self._lock:
            if self._applied != len(self.chain):
                missing = set().union(
                    *self._pending[self._applied:])
                raise ChunkMissingError(
                    f"chain incomplete: {len(self.chain) - self._applied}"
                    f" steps unapplied, {len(missing)} chunks missing")
            target = self.chain[-1]
            out_flat: dict[str, np.ndarray] = {}
            for key, a in _ckpt._flatten(like).items():
                entry = target["keys"][key]
                if entry.get("delta") is not None:
                    out_flat[key] = self._ref[key].reshape(
                        a.shape).astype(a.dtype)
                else:
                    out_flat[key] = self.store.read_leaf(entry)
            return _ckpt.unflatten_like(like, out_flat), target["meta"]


def chain_steps(store: ChunkStore, step: int) -> list[int]:
    """Steps of the delta chain ending at ``step``: [base, ..., step].
    A base/full manifest is its own one-element chain."""
    chain = []
    m = store.load_manifest(step)
    while True:
        chain.append(m["step"])
        if m["kind"] != "delta":
            return chain[::-1]
        m = store.load_manifest(m["prev_step"])


def restore(store: ChunkStore, like: Any, step: int | None = None
            ) -> tuple[Any, dict]:
    """Replay base + deltas up to ``step``; bit-exact against the
    writer's reconstruction (verified via each manifest's ``ref_sha``).
    Returns (tree shaped/dtyped like ``like``, meta of ``step``).

    One replay path: this is ``ChainReplayer`` run to completion — the
    streaming fetcher assembles through the exact same code, so a
    streamed restore and a local restore are bit-identical by
    construction."""
    if step is None:
        step = store.latest_step()
        if step is None:
            raise FileNotFoundError(f"no manifests under {store.root}")
    chain = [store.load_manifest(s) for s in chain_steps(store, step)]
    replayer = ChainReplayer(store, chain)
    replayer.advance()
    return replayer.finish(like)
