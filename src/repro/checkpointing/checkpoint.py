"""Sharded checkpointing with manifest + async save (paper §2.4.2/§3.2:
checkpoint saving takes 60 s and live checkpoint recovery seeds
joiners).

Layout (one directory per step):
    step_000123/
      manifest.json            # tree structure, shapes, dtypes, meta
      arrays/<flat-key>.npy    # one file per leaf (process-local shards
                               # in a real multi-host run; full arrays
                               # in this single-process container)

Writes are atomic (tmp dir + rename) so a crash mid-save never corrupts
the latest checkpoint, and saves can run on a background thread (the
trainer overlaps them with the next inner phase, like the paper's
non-blocking flow).
"""
from __future__ import annotations

import json
import os
import pathlib
import shutil
import threading
from typing import Any

import jax
import numpy as np

_SEP = "::"

# raw-bits container per itemsize for dtypes numpy can't save natively
# (ml_dtypes: bf16 is 2 bytes, the fp8 family is 1 byte)
_RAW_UINT = {1: np.uint8, 2: np.uint16, 4: np.uint32, 8: np.uint64}


def resolve_dtype(name: str) -> np.dtype:
    """np.dtype for ``name``, falling back to ml_dtypes (bf16, fp8...)."""
    try:
        return np.dtype(name)
    except TypeError:
        import ml_dtypes
        return np.dtype(getattr(ml_dtypes, name))


def _needs_raw_bits(dtype: np.dtype) -> bool:
    return dtype.kind == "V" or str(dtype) == "bfloat16"


def leaf_to_bytes(arr: np.ndarray) -> tuple[bytes, str]:
    """C-order raw bytes + dtype name (round-trips any ml_dtype)."""
    arr = np.ascontiguousarray(arr)
    return arr.tobytes(), str(arr.dtype)


def leaf_from_bytes(buf: bytes, dtype: str, shape) -> np.ndarray:
    return np.frombuffer(buf, dtype=resolve_dtype(dtype)).reshape(
        tuple(shape))


def _path_key(path) -> str:
    return _SEP.join(
        str(getattr(p, "key", getattr(p, "idx", getattr(p, "name",
            p)))) for p in path)


def _flatten(tree: Any) -> dict[str, np.ndarray]:
    flat = {}
    for path, leaf in jax.tree_util.tree_flatten_with_path(tree)[0]:
        flat[_path_key(path)] = np.asarray(leaf)
    return flat


def unflatten_like(like: Any, out_flat: dict[str, Any]) -> Any:
    """Rebuild ``like``'s structure from a flat key->leaf dict (the
    inverse of ``_flatten``; shared by every restore path)."""
    leaves_like, _ = jax.tree_util.tree_flatten_with_path(like)
    keys_in_order = [_path_key(path) for path, _ in leaves_like]
    return jax.tree_util.tree_unflatten(
        jax.tree_util.tree_structure(like),
        [out_flat[k] for k in keys_in_order])


def save(ckpt_dir: str | pathlib.Path, step: int, tree: Any,
         extra_meta: dict | None = None) -> pathlib.Path:
    ckpt_dir = pathlib.Path(ckpt_dir)
    final = ckpt_dir / f"step_{step:08d}"
    tmp = ckpt_dir / f".tmp_step_{step:08d}"
    if tmp.exists():
        shutil.rmtree(tmp)
    (tmp / "arrays").mkdir(parents=True)
    flat = _flatten(tree)
    manifest = {"step": step, "keys": {}, "meta": extra_meta or {},
                # per-save nonce: two saves of the same step are never
                # byte-identical, so the server's consistency re-read
                # can detect a same-step replacement mid-serve
                "save_nonce": os.urandom(8).hex()}
    treedef = jax.tree_util.tree_structure(tree)
    manifest["treedef"] = str(treedef)
    for key, arr in flat.items():
        fname = key.replace("/", "_") + ".npy"
        dtype = str(arr.dtype)
        raw = _needs_raw_bits(arr.dtype)
        if raw:
            # numpy can't round-trip ml_dtypes (bf16/fp8...): store raw
            # bits in the unsigned container of the SAME itemsize (the
            # seed viewed everything as uint16, which corrupts 1-byte
            # fp8 leaves)
            np.save(tmp / "arrays" / fname,
                    arr.view(_RAW_UINT[arr.dtype.itemsize]))
        else:
            np.save(tmp / "arrays" / fname, arr)
        manifest["keys"][key] = {"file": fname, "shape": list(arr.shape),
                                 "dtype": dtype, "raw_bits": raw}
    (tmp / "manifest.json").write_text(json.dumps(manifest, indent=1))
    if final.exists():
        # swap via rename (tiny race window) instead of rmtree+rename
        # (window the length of the whole delete): a concurrent
        # CheckpointServer read sees either the old or the new dir
        doomed = ckpt_dir / f".old_step_{step:08d}"
        if doomed.exists():
            shutil.rmtree(doomed)
        final.rename(doomed)
        tmp.rename(final)
        shutil.rmtree(doomed)
    else:
        tmp.rename(final)
    return final


def save_async(ckpt_dir, step, tree, extra_meta=None) -> threading.Thread:
    """Paper-style non-blocking save: snapshot to host then write on a
    background thread while training continues."""
    host_tree = jax.tree.map(np.asarray, tree)  # device -> host snapshot
    t = threading.Thread(target=save,
                         args=(ckpt_dir, step, host_tree, extra_meta),
                         daemon=True)
    t.start()
    return t


def restore(ckpt_dir: str | pathlib.Path, like: Any,
            step: int | None = None) -> tuple[Any, dict]:
    """Restore into the structure of ``like`` (values replaced)."""
    ckpt_dir = pathlib.Path(ckpt_dir)
    if step is None:
        step = latest_step(ckpt_dir)
        if step is None:
            raise FileNotFoundError(f"no checkpoints under {ckpt_dir}")
    d = ckpt_dir / f"step_{step:08d}"
    manifest = json.loads((d / "manifest.json").read_text())
    flat_like = _flatten(like)
    out_flat = {}
    for key in flat_like:
        info = manifest["keys"][key]
        arr = np.load(d / "arrays" / info["file"])
        dtype = resolve_dtype(info["dtype"])
        # "raw_bits" marks leaves stored as unsigned bit containers;
        # older manifests lack the flag, so also re-view whenever the
        # recorded dtype doesn't match what np.load produced
        if info.get("raw_bits", False) or arr.dtype != dtype:
            arr = arr.view(dtype)
        out_flat[key] = arr
    return unflatten_like(like, out_flat), manifest["meta"]


def latest_step(ckpt_dir: str | pathlib.Path) -> int | None:
    ckpt_dir = pathlib.Path(ckpt_dir)
    if not ckpt_dir.exists():
        return None
    steps = [int(p.name.split("_")[1]) for p in ckpt_dir.iterdir()
             if p.name.startswith("step_")]
    return max(steps) if steps else None
