"""Sharded checkpointing with manifest + async save (paper §2.4.2/§3.2:
checkpoint saving takes 60 s and live checkpoint recovery seeds
joiners).

Layout (one directory per step):
    step_000123/
      manifest.json            # tree structure, shapes, dtypes, meta
      arrays/<flat-key>.npy    # one file per leaf (process-local shards
                               # in a real multi-host run; full arrays
                               # in this single-process container)

Writes are atomic (tmp dir + rename) so a crash mid-save never corrupts
the latest checkpoint, and saves can run on a background thread (the
trainer overlaps them with the next inner phase, like the paper's
non-blocking flow).
"""
from __future__ import annotations

import json
import pathlib
import shutil
import threading
from typing import Any

import jax
import numpy as np

_SEP = "::"


def _flatten(tree: Any) -> dict[str, np.ndarray]:
    flat = {}
    for path, leaf in jax.tree_util.tree_flatten_with_path(tree)[0]:
        key = _SEP.join(
            str(getattr(p, "key", getattr(p, "idx", getattr(p, "name",
                p)))) for p in path)
        flat[key] = np.asarray(leaf)
    return flat


def save(ckpt_dir: str | pathlib.Path, step: int, tree: Any,
         extra_meta: dict | None = None) -> pathlib.Path:
    ckpt_dir = pathlib.Path(ckpt_dir)
    final = ckpt_dir / f"step_{step:08d}"
    tmp = ckpt_dir / f".tmp_step_{step:08d}"
    if tmp.exists():
        shutil.rmtree(tmp)
    (tmp / "arrays").mkdir(parents=True)
    flat = _flatten(tree)
    manifest = {"step": step, "keys": {}, "meta": extra_meta or {}}
    treedef = jax.tree_util.tree_structure(tree)
    manifest["treedef"] = str(treedef)
    for key, arr in flat.items():
        fname = key.replace("/", "_") + ".npy"
        dtype = str(arr.dtype)
        if arr.dtype.kind == "V" or dtype == "bfloat16":
            # numpy can't round-trip ml_dtypes (bf16): store raw bits
            np.save(tmp / "arrays" / fname, arr.view(np.uint16))
        else:
            np.save(tmp / "arrays" / fname, arr)
        manifest["keys"][key] = {"file": fname, "shape": list(arr.shape),
                                 "dtype": dtype}
    (tmp / "manifest.json").write_text(json.dumps(manifest, indent=1))
    if final.exists():
        shutil.rmtree(final)
    tmp.rename(final)
    return final


def save_async(ckpt_dir, step, tree, extra_meta=None) -> threading.Thread:
    """Paper-style non-blocking save: snapshot to host then write on a
    background thread while training continues."""
    host_tree = jax.tree.map(np.asarray, tree)  # device -> host snapshot
    t = threading.Thread(target=save,
                         args=(ckpt_dir, step, host_tree, extra_meta),
                         daemon=True)
    t.start()
    return t


def restore(ckpt_dir: str | pathlib.Path, like: Any,
            step: int | None = None) -> tuple[Any, dict]:
    """Restore into the structure of ``like`` (values replaced)."""
    ckpt_dir = pathlib.Path(ckpt_dir)
    if step is None:
        step = latest_step(ckpt_dir)
        if step is None:
            raise FileNotFoundError(f"no checkpoints under {ckpt_dir}")
    d = ckpt_dir / f"step_{step:08d}"
    manifest = json.loads((d / "manifest.json").read_text())
    flat_like = _flatten(like)
    out_flat = {}
    for key in flat_like:
        info = manifest["keys"][key]
        arr = np.load(d / "arrays" / info["file"])
        if info["dtype"] == "bfloat16":
            import ml_dtypes
            arr = arr.view(ml_dtypes.bfloat16)
        out_flat[key] = arr
    leaves_like, treedef = jax.tree_util.tree_flatten_with_path(like)
    keys_in_order = [
        _SEP.join(str(getattr(p, "key", getattr(p, "idx", getattr(
            p, "name", p)))) for p in path)
        for path, _ in leaves_like]
    new_leaves = [out_flat[k] for k in keys_in_order]
    tree = jax.tree_util.tree_unflatten(
        jax.tree_util.tree_structure(like), new_leaves)
    return tree, manifest["meta"]


def latest_step(ckpt_dir: str | pathlib.Path) -> int | None:
    ckpt_dir = pathlib.Path(ckpt_dir)
    if not ckpt_dir.exists():
        return None
    steps = [int(p.name.split("_")[1]) for p in ckpt_dir.iterdir()
             if p.name.startswith("step_")]
    return max(steps) if steps else None
