from repro.checkpointing.checkpoint import (latest_step, restore, save,
                                            save_async)
from repro.checkpointing.delta import (ChainReplayer, DeltaCheckpointer,
                                       DeltaConfig, DeltaChainError)
from repro.checkpointing.gossip import (ChunkGossip, socket_transport,
                                        store_transport)
from repro.checkpointing.p2p import (CheckpointServer, ChecksumError,
                                     EmptyPeerError, FetchError,
                                     PeerClosedError, PeerConn,
                                     PeerConnPool, PeerTimeoutError,
                                     RetryDeadlineError, RetryPolicy,
                                     RetryableFetchError,
                                     fetch_checkpoint, retry_call)
from repro.checkpointing.snapshot import AsyncSnapshotter
from repro.checkpointing.store import (ChunkCorruptError,
                                       ChunkMissingError, ChunkStore)
from repro.checkpointing.streaming import StreamingFetcher
from repro.checkpointing.swarm import (ChunkPeer, NoPeersError,
                                       StepRetiredError, SwarmFetchError,
                                       recover, swarm_fetch)

__all__ = [
    "save", "save_async", "restore", "latest_step",
    "CheckpointServer", "fetch_checkpoint", "PeerConn", "PeerConnPool",
    "FetchError", "PeerClosedError", "ChecksumError", "EmptyPeerError",
    "RetryableFetchError", "PeerTimeoutError", "RetryDeadlineError",
    "RetryPolicy", "retry_call",
    "ChunkStore", "ChunkCorruptError", "ChunkMissingError",
    "DeltaCheckpointer", "DeltaConfig", "DeltaChainError",
    "ChainReplayer",
    "ChunkPeer", "swarm_fetch", "recover", "SwarmFetchError",
    "NoPeersError", "StepRetiredError",
    "ChunkGossip", "socket_transport", "store_transport",
    "StreamingFetcher",
    "AsyncSnapshotter",
]
