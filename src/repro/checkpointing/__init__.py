from repro.checkpointing.checkpoint import (latest_step, restore, save,
                                            save_async)
from repro.checkpointing.p2p import CheckpointServer, fetch_checkpoint

__all__ = ["save", "save_async", "restore", "latest_step",
           "CheckpointServer", "fetch_checkpoint"]
