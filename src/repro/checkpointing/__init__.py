from repro.checkpointing.checkpoint import (latest_step, restore, save,
                                            save_async)
from repro.checkpointing.delta import (DeltaCheckpointer, DeltaConfig,
                                       DeltaChainError)
from repro.checkpointing.p2p import (CheckpointServer, ChecksumError,
                                     EmptyPeerError, FetchError,
                                     PeerClosedError,
                                     RetryableFetchError,
                                     fetch_checkpoint)
from repro.checkpointing.snapshot import AsyncSnapshotter
from repro.checkpointing.store import (ChunkCorruptError,
                                       ChunkMissingError, ChunkStore)
from repro.checkpointing.swarm import (ChunkPeer, NoPeersError,
                                       SwarmFetchError, recover,
                                       swarm_fetch)

__all__ = [
    "save", "save_async", "restore", "latest_step",
    "CheckpointServer", "fetch_checkpoint",
    "FetchError", "PeerClosedError", "ChecksumError", "EmptyPeerError",
    "RetryableFetchError",
    "ChunkStore", "ChunkCorruptError", "ChunkMissingError",
    "DeltaCheckpointer", "DeltaConfig", "DeltaChainError",
    "ChunkPeer", "swarm_fetch", "recover", "SwarmFetchError",
    "NoPeersError",
    "AsyncSnapshotter",
]
