"""Peer-to-peer checkpoint transmission (paper §2.4.2).

A joining node downloads the checkpoint directly from any active peer
instead of central storage. Real TCP implementation (tested on
localhost): an active peer runs ``CheckpointServer`` next to training;
``fetch_checkpoint`` streams the manifest + arrays with length-prefixed
frames and sha256 integrity checks.

Both of the paper's onboarding modes are realized by the trainer:
  * blocking     — the trainer pauses at the outer boundary until the
                   fetch completes (the mode INTELLECT-1 actually used);
  * non-blocking — fetch on a thread while training continues; the
                   joiner enters at the NEXT outer step with zero
                   pseudo-gradient (weight 0 in the elastic ring).
"""
from __future__ import annotations

import hashlib
import io
import json
import pathlib
import socket
import struct
import threading


def _send_frame(sock: socket.socket, payload: bytes) -> None:
    digest = hashlib.sha256(payload).digest()
    sock.sendall(struct.pack("!Q", len(payload)) + digest + payload)


def _recv_exact(sock: socket.socket, n: int) -> bytes:
    buf = io.BytesIO()
    while buf.tell() < n:
        chunk = sock.recv(min(1 << 20, n - buf.tell()))
        if not chunk:
            raise ConnectionError("peer closed mid-frame")
        buf.write(chunk)
    return buf.getvalue()


def _recv_frame(sock: socket.socket) -> bytes:
    header = _recv_exact(sock, 8 + 32)
    (length,) = struct.unpack("!Q", header[:8])
    digest = header[8:40]
    payload = _recv_exact(sock, length)
    if hashlib.sha256(payload).digest() != digest:
        raise IOError("checksum mismatch in checkpoint frame")
    return payload


class CheckpointServer:
    """Serves the latest checkpoint directory to joining peers."""

    def __init__(self, ckpt_dir: str | pathlib.Path,
                 host: str = "127.0.0.1", port: int = 0):
        self.ckpt_dir = pathlib.Path(ckpt_dir)
        self._sock = socket.socket(socket.AF_INET, socket.SOCK_STREAM)
        self._sock.setsockopt(socket.SOL_SOCKET, socket.SO_REUSEADDR, 1)
        self._sock.bind((host, port))
        self._sock.listen(4)
        self.port = self._sock.getsockname()[1]
        self._stop = threading.Event()
        self._thread = threading.Thread(target=self._serve, daemon=True)
        self._thread.start()

    def _serve(self) -> None:
        self._sock.settimeout(0.2)
        while not self._stop.is_set():
            try:
                conn, _ = self._sock.accept()
            except socket.timeout:
                continue
            try:
                self._handle(conn)
            except OSError:
                pass
            finally:
                conn.close()

    def _handle(self, conn: socket.socket) -> None:
        from repro.checkpointing import checkpoint as ckpt
        step = ckpt.latest_step(self.ckpt_dir)
        if step is None:
            _send_frame(conn, json.dumps({"error": "empty"}).encode())
            return
        d = self.ckpt_dir / f"step_{step:08d}"
        manifest = (d / "manifest.json").read_bytes()
        _send_frame(conn, manifest)
        info = json.loads(manifest)
        for key in sorted(info["keys"]):
            _send_frame(conn,
                        (d / "arrays" / info["keys"][key]["file"])
                        .read_bytes())

    def close(self) -> None:
        self._stop.set()
        self._thread.join(timeout=2)
        self._sock.close()


def fetch_checkpoint(peer: tuple[str, int],
                     dest_dir: str | pathlib.Path,
                     timeout: float = 60.0) -> pathlib.Path:
    """Download the peer's latest checkpoint into ``dest_dir``; returns
    the local checkpoint path (same on-disk format as checkpoint.save)."""
    dest_dir = pathlib.Path(dest_dir)
    with socket.create_connection(peer, timeout=timeout) as sock:
        manifest_raw = _recv_frame(sock)
        manifest = json.loads(manifest_raw)
        if "error" in manifest:
            raise FileNotFoundError("peer has no checkpoint yet")
        step = manifest["step"]
        tmp = dest_dir / f".tmp_step_{step:08d}"
        if tmp.exists():
            import shutil
            shutil.rmtree(tmp)
        (tmp / "arrays").mkdir(parents=True)
        (tmp / "manifest.json").write_bytes(manifest_raw)
        for key in sorted(manifest["keys"]):
            payload = _recv_frame(sock)
            (tmp / "arrays" / manifest["keys"][key]["file"]).write_bytes(
                payload)
    final = dest_dir / f"step_{step:08d}"
    if final.exists():
        import shutil
        shutil.rmtree(final)
    tmp.rename(final)
    return final
