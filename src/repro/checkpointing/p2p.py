"""Peer-to-peer checkpoint transmission (paper §2.4.2).

A joining node downloads the checkpoint directly from any active peer
instead of central storage. Real TCP implementation (tested on
localhost): an active peer runs ``CheckpointServer`` next to training;
``fetch_checkpoint`` streams the manifest + arrays with length-prefixed
frames and sha256 integrity checks.

Failure semantics: every failure mode surfaces as a typed
``FetchError`` subclass (peer closed mid-frame, frame checksum
mismatch, peer has no checkpoint, checkpoint swapped out mid-serve) so
a caller can catch-and-retry without string matching. The server reads
the whole checkpoint into memory BEFORE the first byte goes on the
wire, so a concurrent ``save`` swapping the ``step_*`` directory can
never truncate a stream mid-transfer — at worst the snapshot read
fails and is retried against the new latest step.

Both of the paper's onboarding modes are realized by the trainer:
  * blocking     — the trainer pauses at the outer boundary until the
                   fetch completes (the mode INTELLECT-1 actually used);
  * non-blocking — fetch on a thread while training continues; the
                   joiner enters at the NEXT outer step with zero
                   pseudo-gradient (weight 0 in the elastic ring).

For the chunked content-addressed store and the multi-peer striped
fetch, see ``repro.checkpointing.swarm``.
"""
from __future__ import annotations

import collections
import contextlib
import dataclasses
import hashlib
import io
import json
import pathlib
import random
import socket
import struct
import threading
import time


class FetchError(Exception):
    """Base of all typed P2P checkpoint-transfer failures."""


class PeerClosedError(FetchError, ConnectionError):
    """Peer hung up mid-frame (crash or abrupt shutdown)."""


class ChecksumError(FetchError, IOError):
    """A frame's sha256 didn't match its payload (corruption in
    transit)."""


class EmptyPeerError(FetchError, FileNotFoundError):
    """The peer is healthy but has no checkpoint yet."""


class RetryableFetchError(FetchError, IOError):
    """The peer's checkpoint vanished mid-serve (concurrent save swap);
    the fetch is safe to retry immediately."""


class PeerTimeoutError(FetchError, TimeoutError):
    """A framed-TCP op exceeded its deadline (stalled peer or link).
    Raised instead of the raw ``socket.timeout`` so callers can treat a
    stall exactly like a crash — typed, catch-and-failover."""


class RetryDeadlineError(FetchError, TimeoutError):
    """A retry loop ran out of TOTAL wall-clock budget
    (``RetryPolicy.max_elapsed_s`` / ``StreamingFetcher``
    ``max_elapsed_s``): under churn, per-attempt backoff can stack
    unboundedly — the deadline caps the whole ladder. Chains the last
    underlying failure as ``__cause__``."""


# -- retry / backoff ----------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class RetryPolicy:
    """Shared retry/backoff schedule for framed-TCP ops.

    ``attempts`` total tries; between failures sleep
    ``min(max_delay, base_delay * 2**attempt)`` scaled by a uniform
    jitter in ``[1, 1 + jitter)`` so a fleet of retriers doesn't
    thundering-herd a recovering peer. ``retry_on`` lists the exception
    families worth retrying; ``no_retry`` carves out subclasses that
    are definitive answers, not transport noise (``EmptyPeerError`` is
    a FileNotFoundError and therefore an OSError — without the carve-
    out it would be retried pointlessly)."""

    attempts: int = 3
    base_delay: float = 0.05
    max_delay: float = 2.0
    jitter: float = 0.5
    retry_on: tuple = (PeerClosedError, ChecksumError,
                       RetryableFetchError, PeerTimeoutError, OSError)
    no_retry: tuple = (EmptyPeerError,)
    # total wall-clock budget across ALL attempts (None = unbounded):
    # once the elapsed time plus the next backoff would cross it, the
    # loop raises RetryDeadlineError instead of sleeping
    max_elapsed_s: float | None = None


def retry_call(fn, *, policy: RetryPolicy | None = None,
               describe: str = "", sleep=time.sleep, rng=None,
               clock=time.monotonic):
    """Run ``fn()`` under ``policy``; re-raises the last error once the
    attempts are exhausted. ``sleep``/``rng``/``clock`` are injectable
    for deterministic tests (``rng.random()`` in [0, 1) drives jitter).
    With ``policy.max_elapsed_s`` set, the TOTAL wall-clock across
    attempts (including the about-to-happen backoff sleep) is capped:
    crossing it raises :class:`RetryDeadlineError` from the last
    underlying failure."""
    policy = policy or RetryPolicy()
    roll = rng.random if rng is not None else random.random
    t0 = clock()
    last: BaseException | None = None
    for attempt in range(max(1, policy.attempts)):
        try:
            return fn()
        except policy.no_retry:
            raise
        except policy.retry_on as e:
            last = e
            if attempt + 1 >= max(1, policy.attempts):
                raise
            delay = min(policy.max_delay,
                        policy.base_delay * (2 ** attempt))
            delay *= 1.0 + policy.jitter * roll()
            if policy.max_elapsed_s is not None and \
                    (clock() - t0) + delay > policy.max_elapsed_s:
                raise RetryDeadlineError(
                    f"retry budget {policy.max_elapsed_s}s exhausted "
                    f"after {attempt + 1} attempts"
                    + (f" ({describe})" if describe else "")) from e
            sleep(delay)
    raise last  # pragma: no cover — loop always returns or raises


def _send_frame(sock: socket.socket, payload: bytes) -> None:
    digest = hashlib.sha256(payload).digest()
    sock.sendall(struct.pack("!Q", len(payload)) + digest + payload)


class PeerConn:
    """One framed TCP connection to a peer speaking the JSON-op
    protocol (``ChunkPeer``, the gossip layer and the swarm-serve stage
    RPCs ride on it): send a JSON request frame, read response frames.
    Shared by ``swarm_fetch``, ``ChunkGossip``, ``StreamingFetcher``
    and ``StageServer`` clients so every transport-level failure
    surfaces as the same typed ``FetchError`` family — a deadline blown
    anywhere becomes ``PeerTimeoutError``, never a raw socket.timeout."""

    def __init__(self, addr: tuple, timeout: float):
        self.addr = tuple(addr)
        with self._timeouts_typed():
            self.sock = socket.create_connection(addr, timeout=timeout)
        self.sock.settimeout(timeout)

    @contextlib.contextmanager
    def _timeouts_typed(self):
        try:
            yield
        except socket.timeout as e:
            raise PeerTimeoutError(
                f"peer {getattr(self, 'addr', '?')} timed out") from e

    def send(self, payload: dict) -> None:
        with self._timeouts_typed():
            _send_frame(self.sock, json.dumps(payload).encode())

    def send_bytes(self, blob: bytes) -> None:
        with self._timeouts_typed():
            _send_frame(self.sock, blob)

    def request(self, payload: dict) -> bytes:
        self.send(payload)
        return self.recv_frame()

    def request_json(self, payload: dict) -> dict:
        return json.loads(self.request(payload))

    def recv_frame(self) -> bytes:
        with self._timeouts_typed():
            return _recv_frame(self.sock)

    def recv_json(self) -> dict:
        return json.loads(self.recv_frame())

    def close(self) -> None:
        try:
            self.sock.close()
        except OSError:
            pass


class PeerConnPool:
    """Capped per-peer pool of reusable ``PeerConn``s.

    ``swarm_fetch`` rounds, gossip polls and stage RPCs used to open
    one fresh connection per peer per round — too chatty for 100-peer
    swarms. The pool keeps up to ``max_idle_per_peer`` healthy
    connections per address; ``lease`` hands one out (creating on
    miss) and returns it on clean exit, discarding it if the op
    raised (a conn that saw a transport error is never reused).
    Thread-safe; a connection is owned exclusively while leased."""

    def __init__(self, timeout: float = 20.0,
                 max_idle_per_peer: int = 2):
        self.timeout = timeout
        self.max_idle_per_peer = int(max_idle_per_peer)
        self._idle: dict[tuple, collections.deque] = {}
        self._lock = threading.Lock()
        self._closed = False
        self.stats = {"created": 0, "reused": 0, "discarded": 0}

    def acquire(self, addr: tuple) -> PeerConn:
        addr = tuple(addr)
        with self._lock:
            q = self._idle.get(addr)
            if q:
                self.stats["reused"] += 1
                return q.popleft()
        conn = PeerConn(addr, self.timeout)
        with self._lock:
            self.stats["created"] += 1
        return conn

    def release(self, conn: PeerConn, *, healthy: bool = True) -> None:
        with self._lock:
            q = self._idle.setdefault(conn.addr, collections.deque())
            if healthy and not self._closed and \
                    len(q) < self.max_idle_per_peer:
                q.append(conn)
                return
            self.stats["discarded"] += 1
        conn.close()

    @contextlib.contextmanager
    def lease(self, addr: tuple):
        conn = self.acquire(addr)
        try:
            yield conn
        except BaseException:
            self.release(conn, healthy=False)
            raise
        else:
            self.release(conn)

    def idle_count(self, addr: tuple | None = None) -> int:
        with self._lock:
            if addr is not None:
                return len(self._idle.get(tuple(addr), ()))
            return sum(len(q) for q in self._idle.values())

    def discard_peer(self, addr: tuple) -> None:
        """Drop every idle conn to a peer known dead."""
        with self._lock:
            q = self._idle.pop(tuple(addr), None)
        for conn in (q or ()):
            conn.close()

    def close(self) -> None:
        with self._lock:
            self._closed = True
            qs = list(self._idle.values())
            self._idle.clear()
        for q in qs:
            for conn in q:
                conn.close()


def _recv_exact(sock: socket.socket, n: int) -> bytes:
    buf = io.BytesIO()
    while buf.tell() < n:
        chunk = sock.recv(min(1 << 20, n - buf.tell()))
        if not chunk:
            raise PeerClosedError("peer closed mid-frame")
        buf.write(chunk)
    return buf.getvalue()


def _recv_frame(sock: socket.socket) -> bytes:
    header = _recv_exact(sock, 8 + 32)
    (length,) = struct.unpack("!Q", header[:8])
    digest = header[8:40]
    payload = _recv_exact(sock, length)
    if hashlib.sha256(payload).digest() != digest:
        raise ChecksumError("checksum mismatch in checkpoint frame")
    return payload


class CheckpointServer:
    """Serves the latest checkpoint directory to joining peers."""

    # bounded retries when a concurrent save swaps step_* mid-read
    SNAPSHOT_ATTEMPTS = 3

    def __init__(self, ckpt_dir: str | pathlib.Path,
                 host: str = "127.0.0.1", port: int = 0):
        self.ckpt_dir = pathlib.Path(ckpt_dir)
        self._sock = socket.socket(socket.AF_INET, socket.SOCK_STREAM)
        self._sock.setsockopt(socket.SOL_SOCKET, socket.SO_REUSEADDR, 1)
        self._sock.bind((host, port))
        self._sock.listen(4)
        self.port = self._sock.getsockname()[1]
        self._stop = threading.Event()
        self._thread = threading.Thread(target=self._serve, daemon=True)
        self._thread.start()

    def _serve(self) -> None:
        self._sock.settimeout(0.2)
        while not self._stop.is_set():
            try:
                conn, _ = self._sock.accept()
            except socket.timeout:
                continue
            except OSError:
                break
            try:
                self._handle(conn)
            except OSError:
                pass
            finally:
                conn.close()

    def _read_step_dir(self, d: pathlib.Path) -> list[bytes]:
        """One consistent snapshot of ``d``: manifest first, then the
        arrays in manifest-key order. Raises FileNotFoundError if a
        concurrent save swapped the directory away mid-read, and
        re-reads the manifest afterwards so a same-step REPLACEMENT
        mid-read (old manifest + new arrays, all checksums valid)
        can't be served as a checkpoint state that never existed —
        ``save`` stamps every manifest with a fresh nonce, so two
        saves of the same step are never byte-identical."""
        manifest = (d / "manifest.json").read_bytes()
        info = json.loads(manifest)
        frames = [manifest]
        for key in sorted(info["keys"]):
            frames.append(
                (d / "arrays" / info["keys"][key]["file"]).read_bytes())
        if (d / "manifest.json").read_bytes() != manifest:
            raise FileNotFoundError("step dir replaced mid-read")
        return frames

    def _snapshot_latest(self) -> list[bytes] | dict:
        """Read the whole latest checkpoint into memory before serving
        a single byte. The step dir path is resolved ONCE per attempt;
        a vanished/replaced file (save swap race) retries against the
        new latest instead of streaming a torn checkpoint."""
        import time

        from repro.checkpointing import checkpoint as ckpt
        saw_step = False
        for attempt in range(self.SNAPSHOT_ATTEMPTS):
            step = ckpt.latest_step(self.ckpt_dir)
            if step is None:
                # either truly empty, or we landed inside save()'s
                # rename swap of the only step — re-look before
                # declaring the peer empty
                time.sleep(0.01 * (attempt + 1))
                continue
            saw_step = True
            d = self.ckpt_dir / f"step_{step:08d}"
            try:
                return self._read_step_dir(d)
            except (FileNotFoundError, NotADirectoryError,
                    json.JSONDecodeError):
                continue
        # a peer that had a step at ANY point is retryable, not empty
        return {"error": "retry" if saw_step else "empty"}

    def _handle(self, conn: socket.socket) -> None:
        snap = self._snapshot_latest()
        if isinstance(snap, dict):
            _send_frame(conn, json.dumps(snap).encode())
            return
        for frame in snap:
            _send_frame(conn, frame)

    def close(self) -> None:
        self._stop.set()
        self._thread.join(timeout=2)
        self._sock.close()


def fetch_checkpoint(peer: tuple[str, int],
                     dest_dir: str | pathlib.Path,
                     timeout: float = 60.0) -> pathlib.Path:
    """Download the peer's latest checkpoint into ``dest_dir``; returns
    the local checkpoint path (same on-disk format as checkpoint.save).

    Raises ``EmptyPeerError`` / ``RetryableFetchError`` /
    ``PeerClosedError`` / ``ChecksumError`` (all ``FetchError``) so the
    caller can retry or fail over to another peer."""
    dest_dir = pathlib.Path(dest_dir)
    with socket.create_connection(peer, timeout=timeout) as sock:
        manifest_raw = _recv_frame(sock)
        manifest = json.loads(manifest_raw)
        if manifest.get("error") == "empty":
            raise EmptyPeerError("peer has no checkpoint yet")
        if manifest.get("error") == "retry":
            raise RetryableFetchError(
                "peer checkpoint swapped mid-serve; retry")
        step = manifest["step"]
        tmp = dest_dir / f".tmp_step_{step:08d}"
        if tmp.exists():
            import shutil
            shutil.rmtree(tmp)
        (tmp / "arrays").mkdir(parents=True)
        (tmp / "manifest.json").write_bytes(manifest_raw)
        for key in sorted(manifest["keys"]):
            payload = _recv_frame(sock)
            (tmp / "arrays" / manifest["keys"][key]["file"]).write_bytes(
                payload)
    final = dest_dir / f"step_{step:08d}"
    if final.exists():
        import shutil
        shutil.rmtree(final)
    tmp.rename(final)
    return final
