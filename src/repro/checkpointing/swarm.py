"""Swarm P2P checkpoint fetch over the chunk store (paper §2.4.2 +
SWARM Parallelism: stripe transfers across unreliable peers and
rebalance when one dies).

A joining node needs the latest checkpoint but no central storage
exists — only other training peers, each running a ``ChunkPeer`` next
to its ``ChunkStore``. ``swarm_fetch``:

  1. asks every peer for its latest step and targets the newest;
  2. pulls the manifest chain (base + deltas) from any holder;
  3. dedups against the local store (a rejoining node only fetches
     what changed since it left);
  4. splits the missing chunk ids into contiguous ranges on a shared
     work queue and downloads them from ALL live peers in parallel —
     each range is served by exactly one peer (disjoint striping);
  5. verifies every chunk by its content address on arrival;
  6. when a peer dies mid-transfer (connection drop, bad bytes,
     missing chunk), re-queues that peer's unfinished range so the
     survivors pick it up; the fetch fails only when NO peer is left.

Protocol: length-prefixed sha256-checked frames (same framing as
``p2p``). Requests are JSON; chunk payloads are the store's deflated
blobs, verified end-to-end by chunk id after inflation.
"""
from __future__ import annotations

import collections
import json
import pathlib
import socket
import threading
from typing import Sequence

from repro.checkpointing import delta as _delta
from repro.checkpointing.p2p import (FetchError, _recv_frame,
                                     _send_frame)
from repro.checkpointing.store import ChunkCorruptError, ChunkStore

Addr = tuple  # (host, port)


class SwarmFetchError(FetchError):
    """The swarm fetch could not complete; ``failures`` maps peer
    address -> reason."""

    def __init__(self, msg: str, failures: dict | None = None):
        super().__init__(msg)
        self.failures = failures or {}


class NoPeersError(SwarmFetchError):
    """No reachable peer holds a checkpoint."""


class ChunkPeer:
    """Serves a ``ChunkStore`` to joining peers.

    Request frames (JSON): ``{"op": "latest"}`` ->
    ``{"step": int|null}``; ``{"op": "manifest", "step": n}`` -> the
    manifest (or ``{"error": "no-such-step"}``); ``{"op": "chunks",
    "ids": [...]}`` -> one blob frame per id, in order (an empty frame
    means the peer doesn't hold that chunk).

    ``crash_after`` is the fault-injection hook used by the cluster
    simulator: the peer serves that many chunks, then drops every
    connection and stops accepting — a silent mid-transfer crash.
    """

    def __init__(self, store: ChunkStore, host: str = "127.0.0.1",
                 port: int = 0, crash_after: int | None = None):
        self.store = store
        self.crash_after = crash_after
        self.served_chunks = 0
        self._sock = socket.socket(socket.AF_INET, socket.SOCK_STREAM)
        self._sock.setsockopt(socket.SOL_SOCKET, socket.SO_REUSEADDR, 1)
        self._sock.bind((host, port))
        self._sock.listen(8)
        self.port = self._sock.getsockname()[1]
        self.addr = (host, self.port)
        self._stop = threading.Event()
        self._accept = threading.Thread(target=self._serve, daemon=True)
        self._accept.start()

    def _serve(self) -> None:
        self._sock.settimeout(0.2)
        while not self._stop.is_set():
            try:
                conn, _ = self._sock.accept()
            except socket.timeout:
                continue
            except OSError:
                break
            threading.Thread(target=self._session, args=(conn,),
                             daemon=True).start()

    def _session(self, conn: socket.socket) -> None:
        try:
            conn.settimeout(10.0)
            while not self._stop.is_set():
                req = json.loads(_recv_frame(conn))
                op = req.get("op")
                if op == "latest":
                    _send_frame(conn, json.dumps(
                        {"step": self.store.latest_step()}).encode())
                elif op == "manifest":
                    try:
                        m = self.store.load_manifest(req["step"])
                        _send_frame(conn, json.dumps(m).encode())
                    except FileNotFoundError:
                        _send_frame(conn, json.dumps(
                            {"error": "no-such-step"}).encode())
                elif op == "chunks":
                    for digest in req["ids"]:
                        if self.crash_after is not None and \
                                self.served_chunks >= self.crash_after:
                            self.crash()
                            return
                        try:
                            blob = self.store.get_blob(digest)
                        except KeyError:
                            blob = b""
                        _send_frame(conn, blob)
                        self.served_chunks += 1
                else:
                    return
        except (FetchError, OSError, json.JSONDecodeError):
            pass
        finally:
            conn.close()

    def crash(self) -> None:
        """Die silently mid-transfer (fault injection)."""
        self._stop.set()
        self._sock.close()

    def close(self) -> None:
        self._stop.set()
        self._accept.join(timeout=2)
        try:
            self._sock.close()
        except OSError:
            pass


class _PeerConn:
    def __init__(self, addr: Addr, timeout: float):
        self.addr = tuple(addr)
        self.sock = socket.create_connection(addr, timeout=timeout)
        self.sock.settimeout(timeout)

    def request(self, payload: dict) -> bytes:
        _send_frame(self.sock, json.dumps(payload).encode())
        return _recv_frame(self.sock)

    def close(self) -> None:
        try:
            self.sock.close()
        except OSError:
            pass


def _manifest_chain(conn: _PeerConn, step: int) -> list[dict]:
    """The full manifest chain for ``step`` (base first), fetched from
    one peer."""
    chain = []
    s = step
    while True:
        m = json.loads(conn.request({"op": "manifest", "step": s}))
        if "error" in m:
            raise SwarmFetchError(
                f"peer {conn.addr} lost step {s} mid-chain")
        chain.append(m)
        if m["kind"] != "delta":
            return chain[::-1]
        s = m["prev_step"]


def _manifest_chain_any(holders: list[_PeerConn], step: int,
                        failures: dict) -> list[dict]:
    """Chain fetch with failover: a bad first holder must not abort a
    recovery two healthy holders could serve."""
    last: Exception | None = None
    for c in list(holders):
        try:
            return _manifest_chain(c, step)
        except (FetchError, OSError) as e:
            failures[c.addr] = f"manifest chain: {e}"
            holders.remove(c)
            c.close()
            last = e
    raise SwarmFetchError(f"no peer could serve the manifest chain "
                          f"for step {step}: {last}", failures)


def swarm_fetch(peers: Sequence[Addr], store: ChunkStore | str,
                *, step: int | None = None, range_chunks: int = 8,
                timeout: float = 20.0) -> dict:
    """Fetch the newest checkpoint (manifest chain + all missing
    chunks) from ``peers`` into ``store``, striping disjoint chunk
    ranges across every live peer and reassigning on peer death.

    Returns stats: ``{"step", "chunks_fetched", "bytes_fetched",
    "per_peer", "reassigned_ranges", "dead_peers"}``.
    """
    if isinstance(store, (str, pathlib.Path)):
        store = ChunkStore(store)
    failures: dict[Addr, str] = {}
    conns: list[_PeerConn] = []
    for addr in peers:
        try:
            conns.append(_PeerConn(addr, timeout))
        except OSError as e:
            failures[tuple(addr)] = f"connect: {e}"
    try:
        # -- pick the newest step any peer holds -------------------------
        latest: dict[Addr, int] = {}
        for c in list(conns):
            try:
                got = json.loads(c.request({"op": "latest"}))["step"]
                if got is not None:
                    latest[c.addr] = got
            except (FetchError, OSError) as e:
                failures[c.addr] = f"latest: {e}"
                conns.remove(c)
                c.close()
        if step is None:
            if not latest:
                raise NoPeersError("no reachable peer holds a "
                                   "checkpoint", failures)
            step = max(latest.values())
        holders = [c for c in conns if latest.get(c.addr, -1) >= step]
        if not holders:
            raise NoPeersError(f"no peer holds step {step}", failures)
        chain = _manifest_chain_any(holders, step, failures)

        # -- dedup against local state, stripe the remainder -------------
        need: dict[str, None] = {}
        for m in chain:
            for d in store.missing(m):
                need.setdefault(d, None)
        ids = list(need)
        ranges = collections.deque(
            ids[i:i + range_chunks]
            for i in range(0, len(ids), range_chunks))
        cv = threading.Condition()
        inflight = [0]   # ranges popped but not yet finished/requeued
        stats = {"step": step, "chunks_fetched": 0, "bytes_fetched": 0,
                 "per_peer": {f"{a[0]}:{a[1]}": 0 for a in
                              (c.addr for c in holders)},
                 "reassigned_ranges": 0, "dead_peers": []}

        def worker(conn: _PeerConn) -> None:
            name = f"{conn.addr[0]}:{conn.addr[1]}"
            while True:
                with cv:
                    # another peer's in-flight batch may yet fail and
                    # be requeued — stay alive until nothing is left
                    # pending anywhere, not merely until the queue is
                    # momentarily empty
                    cv.wait_for(lambda: ranges or inflight[0] == 0)
                    if not ranges:
                        return
                    batch = ranges.popleft()
                    inflight[0] += 1
                done = 0
                try:
                    payload = conn.request({"op": "chunks",
                                            "ids": batch})
                    for i, digest in enumerate(batch):
                        blob = payload if i == 0 else _recv_frame(
                            conn.sock)
                        if not blob:
                            raise ChunkCorruptError(
                                f"peer missing chunk {digest[:12]}")
                        store.put_blob(digest, blob)
                        done += 1
                        with cv:
                            stats["chunks_fetched"] += 1
                            stats["bytes_fetched"] += len(blob)
                            stats["per_peer"][name] += 1
                    with cv:
                        inflight[0] -= 1
                        cv.notify_all()
                except (FetchError, ChunkCorruptError, OSError) as e:
                    with cv:
                        inflight[0] -= 1
                        rest = batch[done:]
                        if rest:
                            ranges.append(rest)
                            stats["reassigned_ranges"] += 1
                        failures[conn.addr] = str(e)
                        stats["dead_peers"].append(name)
                        cv.notify_all()
                    return

        threads = [threading.Thread(target=worker, args=(c,),
                                    daemon=True) for c in holders]
        for t in threads:
            t.start()
        for t in threads:
            t.join()

        still_missing = [d for d in ids if not store.has(d)]
        if still_missing:
            raise SwarmFetchError(
                f"{len(still_missing)} chunks unfetched after all "
                f"peers failed", failures)
        # chunks are all present and verified: publish the manifests
        # (base first) so a local restore sees a complete chain
        for m in chain:
            store.write_manifest(m)
        return stats
    finally:
        for c in conns:
            c.close()


def recover(peers: Sequence[Addr], store_root: str | pathlib.Path,
            like, *, step: int | None = None, timeout: float = 20.0):
    """One-call joiner recovery: swarm-fetch into a local store, then
    restore into the structure of ``like``. Returns
    (tree, meta, fetch_stats)."""
    store = ChunkStore(store_root)
    stats = swarm_fetch(peers, store, step=step, timeout=timeout)
    manifest = store.load_manifest(stats["step"])
    if manifest["kind"] == "delta":
        tree, meta = _delta.restore(store, like, step=stats["step"])
    else:
        tree, meta = store.restore_tree(like, step=stats["step"])
    return tree, meta, stats
