"""Swarm P2P checkpoint fetch over the chunk store (paper §2.4.2 +
SWARM Parallelism: stripe transfers across unreliable peers and
rebalance when one dies).

A joining node needs the latest checkpoint but no central storage
exists — only other training peers, each running a ``ChunkPeer`` next
to its ``ChunkStore``. ``swarm_fetch``:

  1. asks every peer for its latest step and targets the newest;
  2. pulls the manifest chain (base + deltas) from any holder;
  3. dedups against the local store (a rejoining node only fetches
     what changed since it left);
  4. splits the missing chunk ids into contiguous ranges on a shared
     work queue and downloads them from the live peers in parallel —
     each range is served by exactly one peer (disjoint striping).
     With a gossip ``possession`` map, a range is only ever handed to
     a peer that actually HOLDS all its chunks (peers are partial
     replicas, not full mirrors) and ranges are scheduled
     RAREST-FIRST (fewest holders lead the queue) so scarce chunks
     don't wait behind well-replicated ones and overlap-joins spread
     across the swarm; without one, the legacy every-peer-has-all
     assumption applies in manifest order;
  5. verifies every chunk by its content address on arrival;
  6. when a peer dies mid-transfer (connection drop, bad bytes,
     missing chunk), re-queues that peer's unfinished range so the
     surviving HOLDERS pick it up; the fetch fails only when no live
     peer can serve a still-missing range.

Protocol: length-prefixed sha256-checked frames (same framing as
``p2p``). Requests are JSON; chunk payloads are the store's deflated
blobs, verified end-to-end by chunk id after inflation. Gossip ops
(``digest`` / ``inventory`` / ``have``) ride the same connection — see
``repro.checkpointing.gossip``.
"""
from __future__ import annotations

import collections
import json
import pathlib
import socket
import threading
import time
from typing import Callable, Sequence

from repro.checkpointing import delta as _delta
from repro.checkpointing.p2p import (FetchError, PeerConn, PeerConnPool,
                                     RetryPolicy, _recv_frame,
                                     _send_frame, retry_call)
from repro.checkpointing.store import ChunkCorruptError, ChunkStore

Addr = tuple  # (host, port)

# kept importable under the old private name (tests, older callers)
_PeerConn = PeerConn


class SwarmFetchError(FetchError):
    """The swarm fetch could not complete; ``failures`` maps peer
    address -> reason."""

    def __init__(self, msg: str, failures: dict | None = None):
        super().__init__(msg)
        self.failures = failures or {}


class NoPeersError(SwarmFetchError):
    """No reachable peer holds a checkpoint."""


class StepRetiredError(SwarmFetchError):
    """The requested step was DELIBERATELY removed at the source
    (``ChunkStore.retire_step`` tombstone — e.g. a policy version the
    publisher force-expired). Unlike a missing step this is terminal:
    the consumer should move to a newer version, not retry."""


class ChunkPeer:
    """Serves a ``ChunkStore`` to joining peers.

    Request frames (JSON):
      * ``{"op": "latest"}`` -> ``{"step": int|null}``;
      * ``{"op": "manifest", "step": n}`` -> the manifest (or
        ``{"error": "no-such-step"}``); serving a manifest PINS its
        chain in the store until the session closes, so a concurrent
        retention gc can never truncate a checkpoint mid-stream;
      * ``{"op": "chunks", "ids": [...]}`` -> one blob frame per id, in
        order (an empty frame means the peer doesn't hold that chunk);
      * ``{"op": "digest"}`` -> ``{"latest", "n_chunks", "sha",
        "version"}`` — the compact possession summary gossip polls;
      * ``{"op": "inventory"}`` -> ``{"ids": [...]}`` full chunk-id
        list (pulled only when the digest sha changed);
      * ``{"op": "have", "ids": [...]}`` -> ``{"have": [0/1, ...]}``.

    Fault-injection knobs used by the cluster simulator and the
    deterministic fault harness:
      * ``crash_after`` — serve that many chunks, then drop every
        connection and stop accepting (silent mid-transfer crash);
      * ``corrupt_after`` — serve that many good chunks, then ship
        flipped bytes (checksum mismatch at the receiver);
      * ``stall_chunks`` / ``stall_s`` — after ``stall_chunks`` chunks
        sleep ``stall_s`` before EVERY subsequent chunk (a throttled /
        stalling WAN link; also what the overlap benchmark uses to give
        the fetch non-trivial wall time).
    """

    def __init__(self, store: ChunkStore, host: str = "127.0.0.1",
                 port: int = 0, crash_after: int | None = None,
                 corrupt_after: int | None = None,
                 stall_chunks: int | None = None,
                 stall_s: float = 0.0):
        self.store = store
        self.crash_after = crash_after
        self.corrupt_after = corrupt_after
        self.stall_chunks = stall_chunks
        self.stall_s = stall_s
        self.served_chunks = 0
        self._sock = socket.socket(socket.AF_INET, socket.SOCK_STREAM)
        self._sock.setsockopt(socket.SOL_SOCKET, socket.SO_REUSEADDR, 1)
        self._sock.bind((host, port))
        self._sock.listen(8)
        self.port = self._sock.getsockname()[1]
        self.addr = (host, self.port)
        self._stop = threading.Event()
        self._accept = threading.Thread(target=self._serve, daemon=True)
        self._accept.start()

    def _serve(self) -> None:
        self._sock.settimeout(0.2)
        while not self._stop.is_set():
            try:
                conn, _ = self._sock.accept()
            except socket.timeout:
                continue
            except OSError:
                break
            threading.Thread(target=self._session, args=(conn,),
                             daemon=True).start()

    def _send_chunk(self, conn: socket.socket, digest: str) -> None:
        if self.stall_chunks is not None and \
                self.served_chunks >= self.stall_chunks:
            time.sleep(self.stall_s)
        try:
            blob = self.store.get_blob(digest)
        except KeyError:
            blob = b""
        if self.corrupt_after is not None and \
                self.served_chunks >= self.corrupt_after and blob:
            blob = bytes(b ^ 0xFF for b in blob[:64]) + blob[64:]
        _send_frame(conn, blob)
        self.served_chunks += 1

    def _session(self, conn: socket.socket) -> None:
        pins: list[dict] = []
        try:
            conn.settimeout(10.0)
            while not self._stop.is_set():
                req = json.loads(_recv_frame(conn))
                if not self._handle_op(conn, req, pins):
                    return
        except (FetchError, OSError, json.JSONDecodeError):
            pass
        finally:
            for token in pins:
                self.store.unpin(token)
            conn.close()

    def _handle_op(self, conn: socket.socket, req: dict,
                   pins: list[dict]) -> bool:
        """Dispatch one request frame; returns False to end the
        session (unknown op or injected crash). Subclasses
        (``serving.swarm_serve.StageServer``) extend the op set by
        overriding and delegating unmatched ops here."""
        op = req.get("op")
        if op == "latest":
            _send_frame(conn, json.dumps(
                {"step": self.store.latest_step()}).encode())
        elif op == "manifest":
            # tombstone check FIRST: a retired step must answer
            # "retired" even while its manifest still exists on disk
            # (retire is announced before gc physically removes it)
            if self.store.is_retired(req["step"]):
                _send_frame(conn, json.dumps(
                    {"error": "retired", "step": req["step"]}).encode())
                return True
            try:
                m = self.store.load_manifest(req["step"])
                pins.append(self.store.pin_chain(req["step"]))
                _send_frame(conn, json.dumps(m).encode())
            except FileNotFoundError:
                _send_frame(conn, json.dumps(
                    {"error": "no-such-step"}).encode())
        elif op == "chunks":
            for digest in req["ids"]:
                if self.crash_after is not None and \
                        self.served_chunks >= self.crash_after:
                    self.crash()
                    return False
                self._send_chunk(conn, digest)
        elif op == "digest":
            n, sha = self.store.inventory_digest()
            _send_frame(conn, json.dumps(
                {"latest": self.store.latest_step(),
                 "n_chunks": n, "sha": sha,
                 "version": self.store.version}).encode())
        elif op == "inventory":
            _send_frame(conn, json.dumps(
                {"ids": self.store.inventory()}).encode())
        elif op == "have":
            _send_frame(conn, json.dumps(
                {"have": [int(self.store.has(d))
                          for d in req["ids"]]}).encode())
        else:
            return False
        return True

    def crash(self) -> None:
        """Die silently mid-transfer (fault injection)."""
        self._stop.set()
        self._sock.close()

    def close(self) -> None:
        self._stop.set()
        self._accept.join(timeout=2)
        try:
            self._sock.close()
        except OSError:
            pass


def _manifest_chain(conn: PeerConn, step: int) -> list[dict]:
    """The full manifest chain for ``step`` (base first), fetched from
    one peer."""
    chain = []
    s = step
    while True:
        m = json.loads(conn.request({"op": "manifest", "step": s}))
        if m.get("error") == "retired":
            raise StepRetiredError(
                f"peer {conn.addr} retired step {s}")
        if "error" in m:
            raise SwarmFetchError(
                f"peer {conn.addr} lost step {s} mid-chain")
        if m.get("step") != s:
            raise SwarmFetchError(
                f"peer {conn.addr} served a stale manifest "
                f"({m.get('step')} for requested step {s})")
        chain.append(m)
        if m["kind"] != "delta":
            return chain[::-1]
        s = m["prev_step"]


def _manifest_chain_any(holders: list[PeerConn], step: int,
                        failures: dict) -> list[dict]:
    """Chain fetch with failover: a bad first holder must not abort a
    recovery two healthy holders could serve."""
    last: Exception | None = None
    for c in list(holders):
        try:
            return _manifest_chain(c, step)
        except (FetchError, OSError) as e:
            failures[c.addr] = f"manifest chain: {e}"
            holders.remove(c)
            c.close()
            last = e
    if isinstance(last, StepRetiredError):
        # the step isn't lost, it was withdrawn — surface the typed
        # terminal error instead of a retryable-looking fetch failure
        raise StepRetiredError(str(last), failures)
    raise SwarmFetchError(f"no peer could serve the manifest chain "
                          f"for step {step}: {last}", failures)


def _schedule_ranges(ids: list[str], candidates, range_chunks: int,
                     possession_aware: bool) -> list[list[str]]:
    """Split the missing chunk ids into download ranges.

    Without a possession map: plain manifest-order ranges (legacy
    full-replica assumption). With one: group ids by holder set so
    ranges stay candidate-homogeneous (a partial holder gets ranges
    made ONLY of chunks it has), then schedule RAREST-FIRST — groups
    with the fewest holders lead the queue. Fetching scarce chunks
    first means (a) the single holder of a rare range starts on it
    immediately instead of burning its window on chunks everyone has,
    and (b) the well-replicated remainder is left for the drain phase,
    where every peer qualifies — so concurrent overlap-joins don't all
    pile onto the same (well-known) peer for the scarce tail. Manifest
    order is preserved inside each group (the chain replayer tolerates
    any order; in-order keeps its incremental replay warm).
    """
    if not possession_aware:
        return [ids[i:i + range_chunks]
                for i in range(0, len(ids), range_chunks)]
    groups: dict[frozenset, list[str]] = {}
    for d in ids:
        groups.setdefault(frozenset(candidates([d])), []).append(d)
    rarest = sorted(groups.items(), key=lambda kv: len(kv[0]))
    return [grp[i:i + range_chunks]
            for _, grp in rarest
            for i in range(0, len(grp), range_chunks)]


class _WorkQueue:
    """Shared range queue with per-range candidate tracking.

    Each range carries the set of peers believed (via gossip) to hold
    ALL its chunks; a worker only pops ranges it is a candidate for.
    When a peer dies it is struck from every range's candidate set —
    a range with no candidates left fails the fetch immediately
    instead of hanging (the caller may re-gossip and retry: the store
    keeps whatever already landed)."""

    def __init__(self, ranges: list[list[str]],
                 candidates: Callable[[list[str]], set[Addr]]):
        self.cv = threading.Condition()
        self.pending: collections.deque = collections.deque(
            (batch, candidates(batch)) for batch in ranges)
        self.inflight = 0
        self.dead: set[Addr] = set()
        self.unservable: list[list[str]] = []
        self.aborted = False

    def abort(self) -> None:
        """Fatal consumer-side error (e.g. the progress hook raised):
        wake every worker and make them drain out — the fetch must
        fail typed, never hang on a dead sibling's inflight count."""
        with self.cv:
            self.aborted = True
            self.cv.notify_all()

    def pop(self, addr: Addr):
        """Next range ``addr`` can serve, or None when the queue has
        fully drained (or this peer can serve nothing that's left).
        The scan preserves queue order (no rotation): the scheduler's
        rarest-first ordering survives peers skipping ranges they
        don't hold."""
        with self.cv:
            while True:
                if self.aborted:
                    return None
                i = 0
                while i < len(self.pending):
                    batch, cand = self.pending[i]
                    cand -= self.dead
                    if not cand:
                        del self.pending[i]
                        self.unservable.append(batch)
                        self.cv.notify_all()
                        continue
                    if addr in cand:
                        del self.pending[i]
                        self.inflight += 1
                        return batch
                    i += 1
                if addr in self.dead or self.unservable:
                    return None
                if not self.pending and self.inflight == 0:
                    return None
                # everything left is assigned to others or in flight;
                # an in-flight batch may yet fail and come back to us
                self.cv.wait()

    def done(self) -> None:
        with self.cv:
            self.inflight -= 1
            self.cv.notify_all()

    def requeue(self, batch: list[str], addr: Addr,
                candidates: set[Addr]) -> None:
        """Peer ``addr`` failed mid-range: mark it dead and hand the
        remainder to the surviving candidates."""
        with self.cv:
            self.inflight -= 1
            self.dead.add(addr)
            if batch:
                cand = candidates - self.dead
                if cand:
                    # front of the queue: losing a holder made this
                    # range RARER, so rarest-first puts it next
                    self.pending.appendleft((batch, cand))
                else:
                    self.unservable.append(batch)
            self.cv.notify_all()


def swarm_fetch(peers: Sequence[Addr], store: ChunkStore | str,
                *, step: int | None = None, range_chunks: int = 8,
                timeout: float = 20.0,
                possession: dict | None = None,
                progress: Callable[[str, int], None] | None = None,
                pool: PeerConnPool | None = None,
                retry: RetryPolicy | None = None
                ) -> dict:
    """Fetch the newest checkpoint (manifest chain + all missing
    chunks) from ``peers`` into ``store``, striping disjoint chunk
    ranges across every live peer and reassigning on peer death.

    ``possession`` (optional, from ``ChunkGossip.possession``) maps
    peer addr -> set of chunk ids that peer holds; ranges are then only
    assigned to actual holders instead of assuming full replicas. A
    peer absent from the map is assumed full (legacy behavior).
    ``progress(chunk_id, n_bytes)`` fires after each verified chunk
    lands (the streaming assembler's hook).

    ``pool`` (optional ``PeerConnPool``): connections are leased
    instead of opened fresh and returned healthy at the end, so
    repeated fetch rounds (streaming retries, multi-step catch-up)
    stop paying one TCP setup per peer per round. ``retry`` wraps the
    initial per-peer connect in the shared backoff schedule — the only
    idempotent spot worth retrying here (a mid-stream failure already
    reassigns to surviving holders, which IS the retry).

    Returns stats: ``{"step", "chunks_fetched", "bytes_fetched",
    "per_peer", "reassigned_ranges", "dead_peers"}``.
    """
    if isinstance(store, (str, pathlib.Path)):
        store = ChunkStore(store)
    failures: dict[Addr, str] = {}
    conns: list[PeerConn] = []

    def _connect(addr: Addr) -> PeerConn:
        if pool is not None:
            return pool.acquire(addr)
        return PeerConn(addr, timeout)

    for addr in peers:
        try:
            if retry is not None:
                conns.append(retry_call(
                    lambda a=addr: _connect(a), policy=retry))
            else:
                conns.append(_connect(addr))
        except (FetchError, OSError) as e:
            failures[tuple(addr)] = f"connect: {e}"
    try:
        # -- pick the newest step any peer holds -------------------------
        latest: dict[Addr, int] = {}
        for c in list(conns):
            try:
                got = json.loads(c.request({"op": "latest"}))["step"]
            except (FetchError, OSError) as e:
                conns.remove(c)
                c.close()
                if pool is not None:
                    # a pooled conn can be stale (peer restarted since
                    # the last round): one fresh-socket retry before
                    # declaring the peer dead
                    try:
                        c = PeerConn(c.addr, pool.timeout)
                        conns.append(c)
                        got = json.loads(
                            c.request({"op": "latest"}))["step"]
                    except (FetchError, OSError) as e2:
                        if c in conns:
                            conns.remove(c)
                        c.close()
                        failures[c.addr] = f"latest: {e2}"
                        continue
                else:
                    failures[c.addr] = f"latest: {e}"
                    continue
            if got is not None:
                latest[c.addr] = got
        if step is None:
            if not latest:
                raise NoPeersError("no reachable peer holds a "
                                   "checkpoint", failures)
            step = max(latest.values())
        holders = [c for c in conns if latest.get(c.addr, -1) >= step]
        if not holders:
            raise NoPeersError(f"no peer holds step {step}", failures)
        chain = _manifest_chain_any(holders, step, failures)

        # -- dedup against local state, stripe the remainder -------------
        need: dict[str, None] = {}
        for m in chain:
            for d in store.missing(m):
                need.setdefault(d, None)
        ids = list(need)

        # with a possession map, chunks a peer lacks never get routed
        # to it — and a lagging peer (latest < target, or no manifest
        # at all, e.g. a half-synced fellow joiner) still serves the
        # chunks gossip says it holds. A peer the map doesn't cover
        # falls back to the legacy assumption: full replica iff it
        # holds the target step.
        streamers = [c for c in conns
                     if c.addr in latest
                     or (possession is not None
                         and c.addr in possession)]

        def candidates(batch: list[str]) -> set[Addr]:
            out = set()
            for c in streamers:
                if possession is not None and c.addr in possession:
                    held = possession[c.addr]
                    if all(d in held for d in batch):
                        out.add(c.addr)
                elif latest.get(c.addr, -1) >= step:
                    out.add(c.addr)
            return out

        ranges = _schedule_ranges(ids, candidates, range_chunks,
                                  possession is not None)

        queue = _WorkQueue(ranges, candidates)
        lock = threading.Lock()
        stats = {"step": step, "chunks_fetched": 0, "bytes_fetched": 0,
                 "per_peer": {f"{a[0]}:{a[1]}": 0 for a in
                              (c.addr for c in streamers)},
                 "reassigned_ranges": 0, "dead_peers": []}

        fatal: list[BaseException] = []

        def worker(conn: PeerConn) -> None:
            name = f"{conn.addr[0]}:{conn.addr[1]}"
            while True:
                batch = queue.pop(conn.addr)
                if batch is None:
                    return
                done = 0
                try:
                    payload = conn.request({"op": "chunks",
                                            "ids": batch})
                    for i, digest in enumerate(batch):
                        blob = payload if i == 0 else conn.recv_frame()
                        if not blob:
                            raise ChunkCorruptError(
                                f"peer missing chunk {digest[:12]}")
                        store.put_blob(digest, blob)
                        done += 1
                        with lock:
                            stats["chunks_fetched"] += 1
                            stats["bytes_fetched"] += len(blob)
                            stats["per_peer"][name] += 1
                        if progress is not None:
                            # a consumer-side failure (e.g. the chain
                            # replayer rejecting a diverged chain) is
                            # fatal to the whole fetch, not this peer:
                            # abort every worker and re-raise after
                            # join — never leave siblings waiting on
                            # our inflight count
                            try:
                                progress(digest, len(blob))
                            except BaseException as e:
                                with lock:
                                    fatal.append(e)
                                queue.abort()
                                return
                    queue.done()
                except (FetchError, ChunkCorruptError, OSError) as e:
                    rest = batch[done:]
                    with lock:
                        if rest:
                            stats["reassigned_ranges"] += 1
                        failures[conn.addr] = str(e)
                        stats["dead_peers"].append(name)
                    queue.requeue(rest, conn.addr, candidates(rest))
                    return

        threads = [threading.Thread(target=worker, args=(c,),
                                    daemon=True) for c in streamers]
        for t in threads:
            t.start()
        for t in threads:
            t.join()

        if fatal:
            raise fatal[0]

        still_missing = [d for d in ids if not store.has(d)]
        if still_missing:
            raise SwarmFetchError(
                f"{len(still_missing)} chunks unfetched after all "
                f"candidate peers failed", failures)
        # chunks are all present and verified: publish the manifests
        # (base first) so a local restore sees a complete chain
        for m in chain:
            store.write_manifest(m)
        return stats
    finally:
        for c in conns:
            if pool is not None:
                # conns that saw a transport error are in ``failures``
                # — never put those back in rotation
                pool.release(c, healthy=c.addr not in failures)
            else:
                c.close()


def recover(peers: Sequence[Addr], store_root: str | pathlib.Path,
            like, *, step: int | None = None, timeout: float = 20.0):
    """One-call joiner recovery: swarm-fetch into a local store, then
    restore into the structure of ``like``. Returns
    (tree, meta, fetch_stats)."""
    store = ChunkStore(store_root)
    stats = swarm_fetch(peers, store, step=step, timeout=timeout)
    manifest = store.load_manifest(stats["step"])
    if manifest["kind"] == "delta":
        tree, meta = _delta.restore(store, like, step=stats["step"])
    else:
        tree, meta = store.restore_tree(like, step=stats["step"])
    return tree, meta, stats
