"""Double-buffered async snapshots: move the device->host copy and the
persist off the trainer's critical path (paper §3.2: checkpoint saving
takes 60 s; the trainer overlaps it with the next inner phase).

The seed's ``save_async`` spawned one fresh thread per checkpoint and
re-allocated a full host copy of the model every call — unbounded
threads and an allocator round-trip per save. ``AsyncSnapshotter``
instead owns

  * N (default 2) **reusable host buffers**: the device->host copy is
    a ``np.copyto`` into a preallocated pytree (on the CPU backend the
    jax-array view is zero-copy, so one memcpy total);
  * a single **writer thread** draining a FIFO of filled buffers, so
    persists never reorder and chained writers (the delta
    checkpointer's reference chain is stateful) stay correct;
  * **backpressure**: when every buffer is in flight, ``submit``
    blocks until the oldest persist finishes — bounded memory, never
    an unbounded queue of model copies.
"""
from __future__ import annotations

import threading
from typing import Any, Callable

import jax
import numpy as np


class _Slot:
    __slots__ = ("tree", "busy")

    def __init__(self):
        self.tree = None
        self.busy = False


class AsyncSnapshotter:
    """``submit(step, tree, meta)`` snapshots to a host buffer and
    queues ``write_fn(step, host_tree, meta)`` on the writer thread."""

    def __init__(self, write_fn: Callable[[int, Any, dict], Any],
                 buffers: int = 2,
                 on_persist: Callable[[int, Any], None] | None = None):
        assert buffers >= 1
        self.write_fn = write_fn
        # called on the writer thread with (step, write_fn's return)
        # after each successful persist — the trainer uses it to track
        # which steps are actually on disk (what a ChunkPeer may
        # advertise / retention may count), not merely submitted
        self.on_persist = on_persist
        self._slots = [_Slot() for _ in range(buffers)]
        self._queue: list[tuple[_Slot, int, dict]] = []
        self._cv = threading.Condition()
        self._closed = False
        self._error: BaseException | None = None
        self._tasks_inflight = 0
        self.stats = {"submits": 0, "blocked_waits": 0, "writes": 0,
                      "tasks": 0}
        self._writer = threading.Thread(target=self._drain, daemon=True)
        self._writer.start()

    # -- writer thread -------------------------------------------------------

    def _drain(self) -> None:
        while True:
            with self._cv:
                while not self._queue and not self._closed:
                    self._cv.wait()
                if not self._queue and self._closed:
                    return
                item = self._queue.pop(0)
                if item[0] == "task":
                    self._tasks_inflight += 1
            if item[0] == "task":
                _, fn = item
                try:
                    fn()
                except BaseException as e:
                    with self._cv:
                        self._error = e
                finally:
                    with self._cv:
                        self._tasks_inflight -= 1
                        self.stats["tasks"] += 1
                        self._cv.notify_all()
                continue
            _, slot, step, meta = item
            try:
                result = self.write_fn(step, slot.tree, meta)
                if self.on_persist is not None:
                    self.on_persist(step, result)
            except BaseException as e:  # surfaced on next submit/flush
                with self._cv:
                    self._error = e
            finally:
                with self._cv:
                    slot.busy = False
                    self.stats["writes"] += 1
                    self._cv.notify_all()

    # -- trainer side --------------------------------------------------------

    def _host_copy(self, slot: _Slot, tree: Any) -> None:
        """Device->host into the slot's reusable buffers."""
        def copy_leaf(buf, x):
            src = np.asarray(x)   # zero-copy view on the CPU backend
            if (buf is not None and buf.shape == src.shape
                    and buf.dtype == src.dtype):
                np.copyto(buf, src)
                return buf
            return np.array(src, copy=True)

        if slot.tree is None:
            slot.tree = jax.tree.map(
                lambda x: np.array(np.asarray(x), copy=True), tree)
        else:
            try:
                slot.tree = jax.tree.map(copy_leaf, slot.tree, tree)
            except ValueError:   # tree structure changed between steps
                slot.tree = jax.tree.map(
                    lambda x: np.array(np.asarray(x), copy=True), tree)

    def submit(self, step: int, tree: Any,
               extra_meta: dict | None = None) -> None:
        with self._cv:
            self._raise_pending()
            assert not self._closed, "snapshotter closed"
            slot = next((s for s in self._slots if not s.busy), None)
            if slot is None:
                self.stats["blocked_waits"] += 1
                while slot is None:
                    self._cv.wait()
                    slot = next((s for s in self._slots if not s.busy),
                                None)
            slot.busy = True
        try:
            self._host_copy(slot, tree)
        except BaseException:
            with self._cv:   # don't leak the slot: that deadlocks
                slot.busy = False
                self._cv.notify_all()
            raise
        with self._cv:
            self.stats["submits"] += 1
            self._queue.append(("write", slot, int(step),
                                extra_meta or {}))
            self._cv.notify_all()

    def submit_task(self, fn: Callable[[], Any]) -> None:
        """Queue an arbitrary maintenance callable (e.g. ChunkStore.gc)
        BEHIND all pending persists — FIFO with writes, so retention
        never deletes chunks of a checkpoint still being written."""
        with self._cv:
            self._raise_pending()
            assert not self._closed, "snapshotter closed"
            self._queue.append(("task", fn))
            self._cv.notify_all()

    def flush(self, timeout: float | None = None) -> None:
        """Block until every queued persist has finished. Raises
        ``TimeoutError`` if they haven't within ``timeout``."""
        with self._cv:
            done = self._cv.wait_for(
                lambda: not self._queue
                and not any(s.busy for s in self._slots)
                and self._tasks_inflight == 0,
                timeout=timeout)
            self._raise_pending()
            if not done:
                raise TimeoutError(
                    f"snapshot persists still pending after {timeout}s")

    def close(self, timeout: float | None = 30.0) -> None:
        self.flush(timeout=timeout)
        with self._cv:
            self._closed = True
            self._cv.notify_all()
        self._writer.join(timeout=5)

    def _raise_pending(self) -> None:
        if self._error is not None:
            err, self._error = self._error, None
            raise err
