"""Content-addressed chunk store for live checkpoint recovery
(paper §2.4.2: joiners P2P-fetch state from active peers).

Every pytree leaf is serialized to raw bytes and split into fixed-size
chunks addressed by the sha256 of their (uncompressed) contents:

    root/
      chunks/<aa>/<sha256-hex>        # zlib-deflated blob
      manifests/step_00000123.json    # tree structure -> chunk ids

Content addressing buys three things the flat npy-per-leaf layout
can't:

  * **dedup** — a chunk whose bytes didn't change between steps (or
    that appears twice inside one step: post-sync ``params`` and
    ``anchor`` are bit-identical trees) is stored and shipped once;
  * **verifiable transfer** — a chunk's id IS its checksum, so a swarm
    fetch validates every piece independently of which peer served it;
  * **resumable / striped fetch** — a joiner downloads disjoint chunk
    sets from several peers in parallel and re-requests only what's
    missing (see ``swarm.py``).

Chunk ids are computed on the uncompressed bytes; the on-disk blob is
zlib-deflated (quantized delta codes are low-entropy, so deflate
recovers most of the gap between the 8-bit code width and the code
entropy — see ``delta.py``).

All writes are atomic (tmp file + rename), so a crash mid-save never
corrupts the store and concurrent writers of the same chunk are
idempotent.
"""
from __future__ import annotations

import collections
import hashlib
import json
import os
import pathlib
import threading
import zlib
from typing import Any, Iterable

import numpy as np

from repro.checkpointing import checkpoint as _ckpt

DEFAULT_CHUNK_BYTES = 1 << 20


class ChunkCorruptError(IOError):
    """A blob's contents don't hash to its id (disk or peer
    corruption)."""


class ChunkMissingError(KeyError):
    """A chunk referenced by a manifest is not in the store."""


def chunk_ids(manifest: dict) -> list[str]:
    """Unique chunk ids referenced by ``manifest`` (first-appearance
    order, so consecutive ids usually belong to the same leaf)."""
    seen: dict[str, None] = {}
    for entry in manifest["keys"].values():
        for c in entry.get("chunks", ()):
            seen.setdefault(c["id"], None)
        delta = entry.get("delta")
        if delta:
            for c in delta["codes_chunks"]:
                seen.setdefault(c["id"], None)
            seen.setdefault(delta["codebook_id"], None)
    return list(seen)


class ChunkStore:
    """Chunked, deduplicating, content-addressed checkpoint store."""

    def __init__(self, root: str | pathlib.Path,
                 chunk_bytes: int = DEFAULT_CHUNK_BYTES,
                 compress_level: int = 6):
        self.root = pathlib.Path(root)
        self.chunk_bytes = int(chunk_bytes)
        self.compress_level = compress_level
        (self.root / "chunks").mkdir(parents=True, exist_ok=True)
        (self.root / "manifests").mkdir(parents=True, exist_ok=True)
        # bumped on every mutation through THIS instance; the gossip
        # digest caches its inventory sha against it (advisory only —
        # the sha itself is always recomputed when the version moved)
        self.version = 0
        self._lock = threading.Lock()
        self._digest_cache: tuple[int, tuple[int, str]] | None = None
        # refcounted pins: chunk ids / steps a ChunkPeer is actively
        # serving; gc() must not delete them out from under the wire
        self._pinned_chunks: collections.Counter = collections.Counter()
        self._pinned_steps: collections.Counter = collections.Counter()

    # -- blobs ---------------------------------------------------------------

    def _chunk_path(self, digest: str) -> pathlib.Path:
        return self.root / "chunks" / digest[:2] / digest

    def has(self, digest: str) -> bool:
        return self._chunk_path(digest).exists()

    def _write_blob(self, digest: str, blob: bytes) -> int:
        p = self._chunk_path(digest)
        p.parent.mkdir(parents=True, exist_ok=True)
        tmp = p.parent / f".{digest}.{os.getpid()}.{threading.get_ident()}"
        tmp.write_bytes(blob)
        tmp.rename(p)  # atomic; concurrent same-digest writers agree
        with self._lock:
            self.version += 1
        return len(blob)

    def put(self, data: bytes) -> tuple[str, int]:
        """Store ``data``; returns (digest, bytes newly written — 0 on
        a dedup hit)."""
        digest = hashlib.sha256(data).hexdigest()
        if self.has(digest):
            return digest, 0
        blob = zlib.compress(data, self.compress_level)
        return digest, self._write_blob(digest, blob)

    def put_blob(self, digest: str, blob: bytes) -> int:
        """Store an already-deflated blob as fetched from a peer,
        verifying it decompresses to bytes hashing to ``digest``."""
        if self.has(digest):
            return 0
        try:
            data = zlib.decompress(blob)
        except zlib.error as e:
            raise ChunkCorruptError(f"undecompressable blob for "
                                    f"{digest[:12]}: {e}") from e
        if hashlib.sha256(data).hexdigest() != digest:
            raise ChunkCorruptError(
                f"blob contents do not hash to {digest[:12]}")
        return self._write_blob(digest, blob)

    def get(self, digest: str) -> bytes:
        """Uncompressed chunk contents, integrity-checked."""
        try:
            data = zlib.decompress(self.get_blob(digest))
        except zlib.error as e:
            raise ChunkCorruptError(
                f"stored chunk {digest[:12]} is corrupt: {e}") from e
        if hashlib.sha256(data).hexdigest() != digest:
            raise ChunkCorruptError(
                f"stored chunk {digest[:12]} is corrupt")
        return data

    def get_blob(self, digest: str) -> bytes:
        """Raw deflated blob (what goes on the wire peer-to-peer)."""
        p = self._chunk_path(digest)
        if not p.exists():
            raise ChunkMissingError(digest)
        return p.read_bytes()

    def missing(self, manifest: dict) -> list[str]:
        return [d for d in chunk_ids(manifest) if not self.has(d)]

    # -- possession (gossip) -------------------------------------------------

    def inventory(self) -> list[str]:
        """Sorted ids of every chunk on disk — what this node can serve
        a streaming joiner (the gossip possession ground truth)."""
        out = []
        for sub in (self.root / "chunks").iterdir():
            out.extend(p.name for p in sub.iterdir()
                       if not p.name.startswith("."))
        return sorted(out)

    def inventory_digest(self) -> tuple[int, str]:
        """(n_chunks, sha256-hex over the sorted inventory): the compact
        possession summary a gossip round ships instead of the full id
        list. Cached against ``version`` so repeated polls between
        writes don't rescan the chunk tree."""
        with self._lock:
            cached = self._digest_cache
            version = self.version
        if cached is not None and cached[0] == version:
            return cached[1]
        ids = self.inventory()
        h = hashlib.sha256()
        for d in ids:
            h.update(d.encode())
        result = (len(ids), h.hexdigest())
        with self._lock:
            # only cache if no write raced the scan
            if self.version == version:
                self._digest_cache = (version, result)
        return result

    # -- pins ----------------------------------------------------------------

    def pin_chain(self, step: int) -> dict:
        """Pin the manifest chain ending at ``step`` (its steps and
        every referenced chunk) against gc while a peer streams it out.
        Returns an opaque token for :meth:`unpin`."""
        steps, ids = [], []
        s = step
        while True:
            m = self.load_manifest(s)
            steps.append(m["step"])
            ids.extend(chunk_ids(m))
            if m["kind"] != "delta":
                break
            s = m["prev_step"]
        with self._lock:
            self._pinned_steps.update(steps)
            self._pinned_chunks.update(ids)
        return {"steps": steps, "ids": ids}

    def pin_ids(self, ids) -> dict:
        """Pin loose chunk ids (no manifest required yet) against gc —
        a streaming joiner pins the chain it is assembling into a
        store that may concurrently run retention. Returns a token for
        :meth:`unpin`."""
        ids = list(ids)
        with self._lock:
            self._pinned_chunks.update(ids)
        return {"steps": [], "ids": ids}

    def unpin(self, token: dict) -> None:
        with self._lock:
            self._pinned_steps.subtract(token["steps"])
            self._pinned_chunks.subtract(token["ids"])
            self._pinned_steps += collections.Counter()  # drop <=0
            self._pinned_chunks += collections.Counter()

    # -- manifests -----------------------------------------------------------

    def _manifest_path(self, step: int) -> pathlib.Path:
        return self.root / "manifests" / f"step_{step:08d}.json"

    def write_manifest(self, manifest: dict) -> pathlib.Path:
        p = self._manifest_path(manifest["step"])
        tmp = p.with_name("." + p.name)
        tmp.write_text(json.dumps(manifest, indent=1, sort_keys=True))
        tmp.rename(p)
        with self._lock:
            self.version += 1
        return p

    def load_manifest(self, step: int) -> dict:
        return json.loads(self._manifest_path(step).read_text())

    # -- tombstones ----------------------------------------------------------
    # A retired step was removed ON PURPOSE (e.g. a policy version the
    # publisher force-expired). The tombstone lets the serving side
    # distinguish "deliberately gone" (typed StepRetiredError at the
    # fetcher) from "not written yet / wrong peer" (retryable), so a
    # lagging consumer fails fast instead of spinning on retries.

    def _retired_path(self) -> pathlib.Path:
        return self.root / "manifests" / "retired.json"

    def retired_steps(self) -> set[int]:
        p = self._retired_path()
        if not p.exists():
            return set()
        return set(json.loads(p.read_text()))

    def is_retired(self, step: int) -> bool:
        return int(step) in self.retired_steps()

    def retire_step(self, step: int) -> None:
        """Persist a tombstone for ``step`` (atomic, idempotent). Does
        not delete anything itself — run :meth:`gc` afterwards; the
        tombstone is what makes the deletion announceable."""
        steps = self.retired_steps()
        steps.add(int(step))
        p = self._retired_path()
        tmp = p.with_name("." + p.name)
        tmp.write_text(json.dumps(sorted(steps)))
        tmp.rename(p)
        with self._lock:
            self.version += 1

    def steps(self) -> list[int]:
        return sorted(int(p.stem.split("_")[1])
                      for p in (self.root / "manifests").iterdir()
                      if p.name.startswith("step_"))

    def latest_step(self) -> int | None:
        steps = self.steps()
        return steps[-1] if steps else None

    # -- pytrees -------------------------------------------------------------

    def _put_leaf(self, buf: bytes) -> tuple[list[dict], int, int]:
        """Chunk + store one leaf's bytes; returns (chunk list,
        new_bytes, dedup_hits)."""
        chunks, new_bytes, dedup = [], 0, 0
        for off in range(0, len(buf), self.chunk_bytes):
            piece = buf[off:off + self.chunk_bytes]
            digest, nb = self.put(piece)
            chunks.append({"id": digest, "n": len(piece)})
            new_bytes += nb
            dedup += nb == 0
        return chunks, new_bytes, dedup

    def save_tree(self, step: int, tree: Any,
                  extra_meta: dict | None = None,
                  kind: str = "full") -> dict:
        """Full snapshot of ``tree`` at ``step``; returns the manifest
        (also persisted). ``manifest['stats']`` reports logical vs
        newly-stored bytes so dedup is observable."""
        flat = _ckpt._flatten(tree)
        keys: dict[str, dict] = {}
        logical = new_bytes = dedup = 0
        for key, arr in flat.items():
            buf, dtype = _ckpt.leaf_to_bytes(arr)
            chunks, nb, dd = self._put_leaf(buf)
            keys[key] = {"shape": list(arr.shape), "dtype": dtype,
                         "chunks": chunks}
            logical += len(buf)
            new_bytes += nb
            dedup += dd
        manifest = {"format": "chunked-v1", "step": int(step),
                    "kind": kind, "meta": extra_meta or {},
                    "keys": keys,
                    "stats": {"logical_bytes": logical,
                              "new_bytes": new_bytes,
                              "dedup_chunks": dedup}}
        self.write_manifest(manifest)
        return manifest

    def read_leaf(self, entry: dict) -> np.ndarray:
        buf = b"".join(self.get(c["id"]) for c in entry["chunks"])
        return _ckpt.leaf_from_bytes(buf, entry["dtype"], entry["shape"])

    def restore_tree(self, like: Any, step: int | None = None
                     ) -> tuple[Any, dict]:
        """Restore a full/base snapshot into the structure of ``like``.
        Delta manifests are chains — use
        ``delta.DeltaCheckpointer.restore`` for those."""
        if step is None:
            step = self.latest_step()
            if step is None:
                raise FileNotFoundError(f"no manifests under {self.root}")
        manifest = self.load_manifest(step)
        if manifest["kind"] == "delta":
            from repro.checkpointing import delta
            return delta.restore(self, like, step=step)
        out_flat = {k: self.read_leaf(manifest["keys"][k])
                    for k in _ckpt._flatten(like)}
        return _ckpt.unflatten_like(like, out_flat), manifest["meta"]

    # -- maintenance ---------------------------------------------------------

    def gc(self, keep_steps: Iterable[int] | None = None) -> dict:
        """Drop manifests not in ``keep_steps`` (None keeps all) and
        every chunk no kept manifest references. Keeping a delta step
        implicitly keeps its whole chain back to the base — a kept
        checkpoint must stay restorable. Steps and chunks pinned by a
        serving ``ChunkPeer`` survive regardless (``pinned`` in the
        returned stats counts what gc wanted to drop but couldn't), so
        retention can never truncate a checkpoint mid-stream."""
        keep = set(self.steps() if keep_steps is None else keep_steps)
        for s in list(keep):
            m = self.load_manifest(s)
            while m["kind"] == "delta":
                m = self.load_manifest(m["prev_step"])
                keep.add(m["step"])
        # pin checks happen per item at DELETION time (not one
        # snapshot up front): a ChunkPeer/StreamingFetcher pins a
        # whole chain atomically BEFORE serving/consuming a byte, so
        # re-reading the counters right before each unlink closes the
        # window where a pin taken mid-gc would be ignored
        def step_pinned(s: int) -> bool:
            with self._lock:
                return self._pinned_steps.get(s, 0) > 0

        def chunk_pinned(d: str) -> bool:
            with self._lock:
                return self._pinned_chunks.get(d, 0) > 0

        pinned_saves = 0
        removed_manifests = 0
        for s in self.steps():
            if s not in keep:
                if step_pinned(s):
                    pinned_saves += 1
                    continue
                self._manifest_path(s).unlink()
                removed_manifests += 1
        live: set[str] = set()
        for s in self.steps():
            live.update(chunk_ids(self.load_manifest(s)))
        removed_chunks = 0
        for sub in (self.root / "chunks").iterdir():
            for p in sub.iterdir():
                if p.name.startswith(".") or p.name in live:
                    continue
                if chunk_pinned(p.name):
                    continue
                p.unlink()
                removed_chunks += 1
        with self._lock:
            self.version += 1
        return {"manifests": removed_manifests, "chunks": removed_chunks,
                "pinned": pinned_saves}
