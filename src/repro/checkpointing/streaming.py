"""Overlapped streaming checkpoint recovery (paper §2.4.2: joiners
recover WHILE the cluster trains, so elastic churn costs almost no
utilization; SWARM Parallelism overlaps communication with compute the
same way).

``StreamingFetcher`` runs the whole joiner recovery on a background
thread while the trainer's inner phase computes:

    INIT ──start()──▶ DISCOVER ──▶ STREAM ──▶ READY
                          │            │
                          ╰────────────┴─────▶ FAILED

* **DISCOVER** — gossip-poll the peers (``ChunkGossip``), pick the
  newest step any live peer holds (or the pinned ``step``), pull the
  manifest chain with holder failover;
* **STREAM** — possession-aware ``swarm_fetch`` rounds: ranges are
  assigned only to peers gossip says hold them, chunks arrive in
  manifest (chain) order and the ``ChainReplayer`` assembles the
  reconstruction incrementally as each chain step completes — delta
  replay is hidden under the transfer, not a lump at the end. Between
  rounds (a peer died / a range went unservable) gossip re-polls, so
  peers that joined or recovered mid-stream start serving immediately;
* **READY** — every chunk verified + replayed; ``result()`` hands the
  bit-exact tree to the trainer, which admits the joiner at the next
  outer boundary (``ElasticTrainer.poll_stream_join``).

Overlap accounting: ``stats()`` reports ``fetch_seconds`` (wall time
DISCOVER→READY) and the trainer records how much of it was hidden
under compute — the benchmark's overlap ratio.
"""
from __future__ import annotations

import pathlib
import threading
import time
from typing import Any, Sequence

from repro.checkpointing import delta as _delta
from repro.checkpointing.gossip import ChunkGossip
from repro.checkpointing.p2p import (FetchError, PeerConn,
                                     RetryDeadlineError)
from repro.checkpointing.store import ChunkStore
from repro.checkpointing.swarm import (NoPeersError, SwarmFetchError,
                                       _manifest_chain_any, swarm_fetch)

Addr = tuple


class StreamingFetcher:
    """Background joiner recovery: gossip + streamed chunks + chain
    assembly, overlapped with whatever the caller computes meanwhile."""

    def __init__(self, peers: Sequence[Addr],
                 store: ChunkStore | str | pathlib.Path, like: Any, *,
                 step: int | None = None, range_chunks: int = 8,
                 timeout: float = 20.0, max_rounds: int = 8,
                 round_wait: float = 0.05,
                 max_elapsed_s: float | None = None,
                 gossip: ChunkGossip | None = None):
        self.store = store if isinstance(store, ChunkStore) \
            else ChunkStore(store)
        self.like = like
        self.step = step
        self._step_pinned = step is not None   # caller chose the step
        self.range_chunks = range_chunks
        self.timeout = timeout
        self.max_rounds = max_rounds
        self.round_wait = round_wait
        # total wall-clock budget for the whole recovery (None =
        # unbounded): retry rounds under churn back off repeatedly, so
        # without a deadline a joiner can spin far past the point where
        # re-fetching from scratch would be cheaper
        self.max_elapsed_s = max_elapsed_s
        self._deadline: float | None = None
        self.gossip = gossip or ChunkGossip(peers, timeout=timeout)
        for addr in peers:
            self.gossip.add_peer(addr)
        self.state = "init"
        self.error: Exception | None = None
        self._ready = threading.Event()
        self._result: tuple[Any, dict] | None = None
        self._fetch_stats: dict = {}
        self._replayer: _delta.ChainReplayer | None = None
        self._thread: threading.Thread | None = None
        self._t0 = self._t_ready = None
        self._rounds = 0

    # -- lifecycle -----------------------------------------------------------

    def start(self) -> "StreamingFetcher":
        assert self._thread is None, "fetcher already started"
        self._thread = threading.Thread(target=self._run, daemon=True)
        self._thread.start()
        return self

    def _check_deadline(self, last: Exception | None = None) -> None:
        """Raise :class:`RetryDeadlineError` once the total recovery
        budget is spent (checked before every between-round backoff)."""
        if self._deadline is not None and \
                time.monotonic() > self._deadline:
            raise RetryDeadlineError(
                f"streaming recovery budget {self.max_elapsed_s}s "
                f"exhausted in state {self.state!r} "
                f"(round {self._rounds})") from last

    def _run(self) -> None:
        self._t0 = time.perf_counter()
        if self.max_elapsed_s is not None:
            self._deadline = time.monotonic() + self.max_elapsed_s
        try:
            chain = self._discover()
            self._stream(chain)
            self._t_ready = time.perf_counter()
            self.state = "ready"
        except Exception as e:   # surfaced via result()/wait_ready()
            self.error = e
            self.state = "failed"
        finally:
            self._ready.set()

    def _discover(self) -> list[dict]:
        self.state = "discover"
        step = self.step
        for attempt in range(self.max_rounds):
            self.gossip.poll_once()
            if step is None:
                step = self.gossip.latest_step()
            if step is not None:
                break
            self._check_deadline()
            time.sleep(self.round_wait * (attempt + 1))
        if step is None:
            raise NoPeersError("no live peer holds a checkpoint")
        self.step = step
        failures: dict = {}
        conns = []
        for addr in self.gossip.live_peers():
            try:
                conns.append(PeerConn(addr, self.timeout))
            except OSError as e:
                failures[tuple(addr)] = f"connect: {e}"
        try:
            holders = [c for c in conns]
            chain = _manifest_chain_any(holders, step, failures)
        finally:
            for c in conns:
                c.close()
        return chain

    def _set_chain(self, chain: list[dict], pin_token) -> dict:
        """(Re)build the replayer for ``chain`` and pin its chunk ids
        in the LOCAL store: when the joiner streams into its own live
        store (a trainer that is also checkpointing + running
        retention gc), in-flight streamed chunks must not be collected
        out from under the replay."""
        if pin_token is not None:
            self.store.unpin(pin_token)
        from repro.checkpointing.store import chunk_ids
        ids: dict[str, None] = {}
        for m in chain:
            for d in chunk_ids(m):
                ids.setdefault(d, None)
        token = self.store.pin_ids(list(ids))
        self._replayer = _delta.ChainReplayer(self.store, chain)
        # everything already local (rejoiner dedup) replays immediately
        self._replayer.advance()
        return token

    def _stream(self, chain: list[dict]) -> None:
        self.state = "stream"
        pin = self._set_chain(chain, None)
        last: Exception | None = None
        try:
            for rnd in range(self.max_rounds):
                self._rounds = rnd + 1
                peers = self.gossip.live_peers()
                if not peers:
                    raise SwarmFetchError(
                        f"no live peers left after round {rnd}: {last}")
                try:
                    st = swarm_fetch(
                        peers, self.store, step=self.step,
                        range_chunks=self.range_chunks,
                        timeout=self.timeout,
                        possession=self.gossip.possession,
                        progress=self._replayer.on_chunk)
                    self._merge_stats(st)
                    break
                except (FetchError, OSError) as e:
                    last = e
                    # the store kept everything that landed; re-gossip
                    # so recovered/new peers serve the remainder next
                    # round
                    if isinstance(e, SwarmFetchError) and e.failures:
                        self._merge_failures(e.failures)
                    self._check_deadline(last)
                    time.sleep(self.round_wait)
                    self.gossip.poll_once()
                    # if the caller didn't pin a step and ours
                    # vanished from the swarm (serving-side retention
                    # advanced during a slow fetch), re-target the
                    # newest step instead of failing all rounds on a
                    # checkpoint nobody can serve anymore — everything
                    # already streamed dedups into the new chain
                    if not self._step_pinned:
                        latest = self.gossip.latest_step()
                        if latest is not None and latest != self.step:
                            try:
                                self.step = latest
                                pin = self._set_chain(
                                    self._discover(), pin)
                            except (FetchError, OSError) as e2:
                                last = e2
                            finally:
                                self.state = "stream"
            else:
                raise SwarmFetchError(
                    f"streaming fetch failed after {self.max_rounds} "
                    f"rounds: {last}") from last
            # the replay ran under the transfer; anything left (e.g.
            # chunks that were already local mid-chain) completes here
            self._replayer.advance()
            self._result = self._replayer.finish(self.like)
        finally:
            self.store.unpin(pin)

    def _merge_stats(self, st: dict) -> None:
        f = self._fetch_stats
        f["step"] = st["step"]
        for k in ("chunks_fetched", "bytes_fetched",
                  "reassigned_ranges"):
            f[k] = f.get(k, 0) + st[k]
        per = f.setdefault("per_peer", {})
        for name, n in st["per_peer"].items():
            per[name] = per.get(name, 0) + n
        f.setdefault("dead_peers", []).extend(st["dead_peers"])

    def _merge_failures(self, failures: dict) -> None:
        dead = self._fetch_stats.setdefault("dead_peers", [])
        for addr in failures:
            name = f"{addr[0]}:{addr[1]}" if isinstance(addr, tuple) \
                else str(addr)
            if name not in dead:
                dead.append(name)

    # -- consumer side -------------------------------------------------------

    @property
    def ready(self) -> bool:
        return self.state == "ready"

    @property
    def failed(self) -> bool:
        return self.state == "failed"

    @property
    def done(self) -> bool:
        return self._ready.is_set()

    def wait_ready(self, timeout: float | None = None) -> dict:
        """Block until READY/FAILED; returns :meth:`stats`. Raises the
        recovery error on failure, ``TimeoutError`` on timeout."""
        if not self._ready.wait(timeout):
            raise TimeoutError(
                f"streaming recovery still {self.state} after "
                f"{timeout}s")
        if self.error is not None:
            raise self.error
        return self.stats()

    def result(self) -> tuple[Any, dict, dict]:
        """(tree, meta, stats) once READY (call after wait_ready /
        polling ``ready``)."""
        if self.error is not None:
            raise self.error
        assert self._result is not None, \
            f"recovery not ready (state={self.state})"
        tree, meta = self._result
        return tree, meta, self.stats()

    def stats(self) -> dict:
        rp = self._replayer
        out = dict(self._fetch_stats)
        out.update({
            "state": self.state,
            "rounds": self._rounds,
            # perf_counter anchors so a caller can intersect the fetch
            # window with its own compute window (overlap accounting)
            "t_start": self._t0,
            "t_ready": self._t_ready,
            "fetch_seconds": (
                (self._t_ready or time.perf_counter()) - self._t0
                if self._t0 is not None else 0.0),
            "gossip": dict(self.gossip.stats),
            "replayed_steps": rp.stats["replayed_steps"] if rp else 0,
            "replayed_on_stream":
                rp.stats["replayed_on_stream"] if rp else 0,
        })
        return out

    def close(self) -> None:
        self.gossip.stop()
