"""Chunk-availability gossip (paper §2.4.2 overlap + SWARM: assume
peers are partial, unreliable replicas — never that every peer has
every chunk).

``ChunkGossip`` tracks which peer holds which chunks by polling each
peer's **possession digest** — ``{"op": "digest"}`` on the existing
``ChunkPeer`` protocol returns ``(latest, n_chunks, sha)`` where sha is
the sha256 over the sorted chunk-id inventory. Only when the sha moved
does gossip pull the full id list (``{"op": "inventory"}``), so a
steady-state poll costs one ~100-byte frame per peer per round instead
of re-shipping O(chunks) ids.

The resulting possession map feeds ``swarm_fetch(possession=...)`` so
ranges are only ever assigned to peers that actually hold them, and
``StreamingFetcher`` re-polls between retry rounds so peers that
join/recover mid-stream start serving immediately.

Failure model: a peer that misses ``expire_polls`` consecutive polls is
marked dead and its possession dropped (no stale routing to a corpse);
a transient stall keeps the last-known map until expiry — stale-but-
harmless, since every chunk is content-verified on arrival anyway.

The transport is pluggable (``transport(addr, request_dict) -> dict``):
the default opens a short-lived framed TCP connection per poll; the
property tests drive the same state machine over in-memory stores.
"""
from __future__ import annotations

import dataclasses
import threading
import time
from typing import Callable, Iterable

from repro.checkpointing.p2p import (FetchError, PeerConn, PeerConnPool,
                                     RetryPolicy, retry_call)

Addr = tuple  # (host, port)


def socket_transport(timeout: float = 5.0, *,
                     pool: PeerConnPool | None = None,
                     policy: RetryPolicy | None = None
                     ) -> Callable[[Addr, dict], dict]:
    """One framed TCP round-trip per request.

    Without a ``pool``: a fresh connection per poll (a crashed peer
    costs one refused connect, not a wedged socket). With one: the
    connection is leased from the capped per-peer pool and reused
    across rounds; a conn that errored is discarded, so a stale pooled
    socket costs one retry, never a wedged round. ``policy`` wraps the
    round-trip in the shared retry/backoff schedule (each retry leases
    a fresh conn — gossip ops are read-only, hence idempotent)."""

    def once(addr: Addr, payload: dict) -> dict:
        if pool is not None:
            with pool.lease(addr) as conn:
                return conn.request_json(payload)
        conn = PeerConn(addr, timeout)
        try:
            return conn.request_json(payload)
        finally:
            conn.close()

    def send(addr: Addr, payload: dict) -> dict:
        if policy is None:
            return once(addr, payload)
        return retry_call(lambda: once(addr, payload), policy=policy)

    return send


@dataclasses.dataclass
class PeerView:
    """What gossip currently believes about one peer."""
    addr: Addr
    chunks: frozenset = frozenset()
    latest: int | None = None
    sha: str | None = None
    misses: int = 0          # consecutive failed polls
    alive: bool = False      # answered at least once, not expired
    polls: int = 0


class ChunkGossip:
    """Per-peer chunk-possession tracking via periodic digest polls."""

    def __init__(self, peers: Iterable[Addr], *,
                 transport: Callable[[Addr, dict], dict] | None = None,
                 timeout: float = 5.0, expire_polls: int = 3,
                 pool: "PeerConnPool | None" = None,
                 policy: RetryPolicy | None = None):
        self.transport = transport or socket_transport(
            timeout, pool=pool, policy=policy)
        self.expire_polls = int(expire_polls)
        self._views: dict[Addr, PeerView] = {}
        self._lock = threading.Lock()
        self._poller: threading.Thread | None = None
        self._stop = threading.Event()
        self.stats = {"polls": 0, "digests": 0, "inventories": 0,
                      "expired": 0}
        for addr in peers:
            self.add_peer(addr)

    # -- membership ----------------------------------------------------------

    def add_peer(self, addr: Addr) -> None:
        with self._lock:
            self._views.setdefault(tuple(addr), PeerView(tuple(addr)))

    def remove_peer(self, addr: Addr) -> None:
        """Drop a peer immediately (graceful leave / deathrattle — no
        need to wait out the expiry window)."""
        with self._lock:
            self._views.pop(tuple(addr), None)

    def peers(self) -> list[Addr]:
        with self._lock:
            return list(self._views)

    # -- polling -------------------------------------------------------------

    def _poll_peer(self, view: PeerView) -> None:
        try:
            digest = self.transport(view.addr, {"op": "digest"})
            self.stats["digests"] += 1
            new_sha = digest.get("sha")
            if new_sha != view.sha:
                inv = self.transport(view.addr, {"op": "inventory"})
                self.stats["inventories"] += 1
                chunks = frozenset(inv["ids"])
            else:
                chunks = view.chunks
            with self._lock:
                # peer may have been removed while we were polling
                live = self._views.get(view.addr)
                if live is not None:
                    live.chunks = chunks
                    live.latest = digest.get("latest")
                    live.sha = new_sha
                    live.misses = 0
                    live.alive = True
                    live.polls += 1
        except (FetchError, OSError, ValueError, KeyError):
            with self._lock:
                live = self._views.get(view.addr)
                if live is not None:
                    live.misses += 1
                    live.polls += 1
                    if live.alive and live.misses >= self.expire_polls:
                        live.alive = False
                        live.chunks = frozenset()
                        live.latest = None
                        live.sha = None
                        self.stats["expired"] += 1

    def poll_once(self) -> dict:
        """One synchronous gossip round over every tracked peer.
        Returns the updated possession map."""
        self.stats["polls"] += 1
        with self._lock:
            views = list(self._views.values())
        for v in views:
            self._poll_peer(v)
        return self.possession

    # -- views ---------------------------------------------------------------

    @property
    def possession(self) -> dict:
        """addr -> frozenset(chunk ids) for every live peer (what
        ``swarm_fetch(possession=...)`` consumes)."""
        with self._lock:
            return {a: v.chunks for a, v in self._views.items()
                    if v.alive}

    def latest_step(self) -> int | None:
        with self._lock:
            steps = [v.latest for v in self._views.values()
                     if v.alive and v.latest is not None]
        return max(steps) if steps else None

    def holders(self, chunk_id: str) -> list[Addr]:
        with self._lock:
            return [a for a, v in self._views.items()
                    if v.alive and chunk_id in v.chunks]

    def live_peers(self) -> list[Addr]:
        with self._lock:
            return [a for a, v in self._views.items() if v.alive]

    # -- background poller ---------------------------------------------------

    def start(self, interval: float = 1.0) -> None:
        """Poll every ``interval`` seconds on a daemon thread."""
        if self._poller is not None:
            return
        self._stop.clear()

        def loop():
            while not self._stop.wait(interval):
                self.poll_once()

        self._poller = threading.Thread(target=loop, daemon=True)
        self._poller.start()

    def stop(self) -> None:
        if self._poller is None:
            return
        self._stop.set()
        self._poller.join(timeout=2)
        self._poller = None


def store_transport(stores: dict) -> Callable[[Addr, dict], dict]:
    """In-memory transport over ``{addr: ChunkStore|None}`` — the
    deterministic harness / property tests drive the gossip state
    machine without sockets. ``None`` (or a missing addr) models a
    dead peer; a callable value is invoked first and may raise to model
    a stall."""

    def send(addr: Addr, payload: dict) -> dict:
        entry = stores.get(tuple(addr))
        if callable(entry):
            entry = entry()
        if entry is None:
            raise ConnectionError(f"peer {addr} unreachable")
        op = payload.get("op")
        if op == "digest":
            n, sha = entry.inventory_digest()
            return {"latest": entry.latest_step(), "n_chunks": n,
                    "sha": sha, "version": entry.version}
        if op == "inventory":
            return {"ids": entry.inventory()}
        if op == "have":
            return {"have": [int(entry.has(d))
                             for d in payload["ids"]]}
        raise ValueError(f"unknown op {op!r}")

    return send
