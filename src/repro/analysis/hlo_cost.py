"""Trip-count-aware cost analysis over post-SPMD HLO text.

``compiled.cost_analysis()`` counts every instruction ONCE — a while
body (lax.scan over layers / attention q-blocks / SSD chunks) is counted
for a single iteration, which silently undercounts a 40-layer model by
40x. This analyzer re-derives the three roofline inputs from the HLO
text with loop multipliers:

  * computations are classified (entry / while-body / fusion-body /
    scalar-applier) and a BFS from ENTRY propagates an execution
    multiplier: while bodies multiply by the loop trip count (recovered
    from the largest constant in the loop condition), fusion bodies
    inherit the caller's multiplier;
  * FLOPs: every ``dot`` contributes 2 * prod(result) * prod(lhs
    contracting dims) * multiplier (operand shapes resolved through a
    per-computation symbol table); convolutions analogous;
  * HBM bytes: operand+result bytes of every materializing instruction
    in non-fusion computations (the fusion boundary is the unit of HBM
    traffic, same convention as XLA's bytes-accessed);
  * collective wire bytes: per-op payload model (ring algorithms) —
    all-gather: result; all-reduce: 2x operand; reduce-scatter /
    all-to-all / collective-permute: operand.
"""
from __future__ import annotations

import dataclasses
import re

_DTYPE_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "s4": 1, "u4": 1, "s16": 2, "u16": 2,
    "bf16": 2, "f16": 2, "s32": 4, "u32": 4, "f32": 4, "s64": 8,
    "u64": 8, "f64": 8, "c64": 8, "c128": 16, "token": 0, "s2": 1,
    "u2": 1, "f8e4m3fn": 1, "f8e5m2": 1,
}

_SHAPE_RE = re.compile(r"(\w+)\[([\d,]*)\]")
_DEF_RE = re.compile(r"^\s*(?:ROOT\s+)?%([\w\.\-]+)\s*=\s*(.+?)\s+"
                     r"([\w\-]+)\(")
# computation headers sit at column 0, end with '{', and contain the
# '(params) -> type' arrow; params may hold nested tuple-type parens so
# the name is just the first token
_COMP_RE = re.compile(r"^(?:ENTRY\s+)?%?([\w\.\-]+)\s*\(.*->.*\{\s*$")

_SKIP_BYTES_OPS = {
    "parameter", "constant", "tuple", "get-tuple-element", "bitcast",
    "while", "conditional", "call", "after-all", "add-dependency",
    "iota", "partition-id", "replica-id", "custom-call",
}
_COLLECTIVE_OPS = {"all-gather", "all-reduce", "reduce-scatter",
                   "all-to-all", "collective-permute"}


def _dims(dim_str: str) -> list[int]:
    return [int(d) for d in dim_str.split(",") if d]


def _type_bytes_and_elems(type_str: str) -> tuple[int, int]:
    total_b = 0
    total_e = 0
    for dt, dims in _SHAPE_RE.findall(type_str):
        n = 1
        for d in _dims(dims):
            n *= d
        total_e += n
        total_b += n * _DTYPE_BYTES.get(dt, 4)
    return total_b, total_e


@dataclasses.dataclass
class Instruction:
    name: str
    type_str: str
    op: str
    line: str


@dataclasses.dataclass
class Computation:
    name: str
    instructions: list
    symbols: dict            # %name -> type_str


def parse_computations(hlo: str) -> dict[str, Computation]:
    comps: dict[str, Computation] = {}
    cur: Computation | None = None
    entry_name = None
    for line in hlo.splitlines():
        m = _COMP_RE.match(line) if not line[:1].isspace() else None
        if m:
            cur = Computation(m.group(1), [], {})
            comps[cur.name] = cur
            if line.startswith("ENTRY"):
                entry_name = cur.name
            continue
        if cur is None:
            continue
        if line.strip() == "}":
            cur = None
            continue
        dm = _DEF_RE.match(line)
        if dm:
            name, type_str, op = dm.groups()
            cur.symbols[name] = type_str
            cur.instructions.append(
                Instruction(name, type_str, op, line))
    if entry_name:
        comps["__entry__"] = comps[entry_name]
    return comps


def _loop_trip_count(cond: Computation) -> int:
    count = 1
    for ins in cond.instructions:
        for c in re.findall(r"constant\((\d+)\)", ins.line):
            count = max(count, int(c))
    return count


def _multipliers(comps: dict) -> tuple[dict, set]:
    """computation -> execution multiplier; + the set of fusion bodies."""
    entry = comps.get("__entry__")
    mult: dict[str, float] = {}
    fusion_bodies: set[str] = set()
    applier_bodies: set[str] = set()
    if entry is None:
        return {}, set()
    stack = [(entry.name, 1.0)]
    seen_pairs = set()
    while stack:
        cname, m = stack.pop()
        if (cname, m) in seen_pairs:
            continue
        seen_pairs.add((cname, m))
        mult[cname] = max(mult.get(cname, 0.0), m)
        comp = comps.get(cname)
        if comp is None:
            continue
        for ins in comp.instructions:
            if ins.op == "while":
                mb = re.search(r"body=%?([\w\.\-]+)", ins.line)
                mc = re.search(r"condition=%?([\w\.\-]+)", ins.line)
                if mb and mc and mc.group(1) in comps:
                    trips = _loop_trip_count(comps[mc.group(1)])
                    stack.append((mb.group(1), m * trips))
                    stack.append((mc.group(1), m * trips))
            for ref in re.findall(r"calls=%?([\w\.\-]+)", ins.line):
                fusion_bodies.add(ref)
                stack.append((ref, m))
            for ref in re.findall(r"to_apply=%?([\w\.\-]+)", ins.line):
                if ins.op == "call":
                    # real call (e.g. XLA:CPU's parallel-task fusion
                    # wrappers), not a reduce/scatter scalar applier
                    stack.append((ref, m))
                else:
                    applier_bodies.add(ref)
            for ref in re.findall(
                    r"(?:true_computation|false_computation|"
                    r"branch_computations)=.*?%?([\w\.\-]+)", ins.line):
                stack.append((ref, m))
    for a in applier_bodies:
        mult.pop(a, None)
    return mult, fusion_bodies


def _dot_flops(ins: Instruction, comp: Computation) -> float:
    res_b, res_e = _type_bytes_and_elems(ins.type_str)
    mo = re.search(r"\(([^)]*)\)", ins.line[ins.line.find(ins.op):])
    lhs_shape: list[int] = []
    if mo:
        seg = mo.group(1)
        # operands may be printed typed ('f32[a,b]{1,0} %x') or bare
        # ('%x' / 'x'); commas inside shape brackets break naive
        # splitting, so resolve the lhs via its %name first and fall
        # back to the first inline shape in the segment (lhs is first)
        syms = _operand_syms(ins)
        t = comp.symbols.get(syms[0]) if syms else None
        if t is None:
            tm = _SHAPE_RE.search(seg)
            t = tm.group(0) if tm else None
        if t:
            sm = _SHAPE_RE.search(t)
            if sm:
                lhs_shape = _dims(sm.group(2))
    contract = 1
    cm = re.search(r"lhs_contracting_dims=\{([\d,]*)\}", ins.line)
    if cm and lhs_shape:
        for d in _dims(cm.group(1)):
            if d < len(lhs_shape):
                contract *= lhs_shape[d]
    return 2.0 * res_e * contract


def _operand_syms(ins: Instruction) -> list[str]:
    mo = re.search(r"\((.*?)\)[,)]?", ins.line[ins.line.find(ins.op):])
    if not mo:
        return []
    seg = mo.group(1)
    # typed operand form ('f32[a,b]{1,0} %x') has commas inside the
    # shape brackets — pull the %names directly when present
    named = re.findall(r"%([\w\.\-]+)", seg)
    if named:
        return named
    out = []
    for operand in seg.split(","):
        operand = operand.strip()
        if operand:
            out.append(operand.split()[-1].lstrip("%"))
    return out


def _sliced_param_reads(comp: Computation) -> dict[int, float]:
    """For a fused computation: parameter index -> effective bytes read,
    when the parameter is consumed via dynamic-slice/gather (the scan-
    over-stacked-layers / FSDP pattern reads a slice, not the buffer)."""
    param_idx: dict[str, int] = {}
    for ins in comp.instructions:
        if ins.op == "parameter":
            m = re.search(r"parameter\((\d+)\)", ins.line)
            if m:
                param_idx[ins.name] = int(m.group(1))
    reads: dict[int, float] = {}
    for ins in comp.instructions:
        if ins.op in ("dynamic-slice", "gather"):
            syms = _operand_syms(ins)
            if syms and syms[0] in param_idx:
                rb, _ = _type_bytes_and_elems(ins.type_str)
                idx = param_idx[syms[0]]
                reads[idx] = reads.get(idx, 0.0) + rb
    return reads


def _instr_bytes(ins: Instruction, comp: Computation,
                 comps: dict | None = None) -> float:
    """Read+write bytes of one instruction, slice-aware:
      * dynamic-slice / gather read only the slice;
      * dynamic-update-slice writes only the update region (in-place);
      * fusion operands consumed via an internal dynamic-slice/gather
        count the slice, not the whole buffer."""
    res_b, _ = _type_bytes_and_elems(ins.type_str)
    if ins.op in ("dynamic-slice", "gather"):
        return 2.0 * res_b
    syms = _operand_syms(ins)

    def op_bytes(sym: str) -> float:
        t = comp.symbols.get(sym)
        if t is None:
            return 0.0
        ob, _ = _type_bytes_and_elems(t)
        return ob

    if ins.op in ("dynamic-update-slice", "scatter"):
        upd = op_bytes(syms[1]) if len(syms) > 1 else res_b
        return 2.0 * upd
    if ins.op == "fusion" and comps is not None:
        m = re.search(r"calls=%?([\w\.\-]+)", ins.line)
        sliced = _sliced_param_reads(comps[m.group(1)]) \
            if m and m.group(1) in comps else {}
        b = res_b
        for i, sym in enumerate(syms):
            b += sliced.get(i, op_bytes(sym))
        return b
    return res_b + sum(op_bytes(s) for s in syms)


def _collective_payload(ins: Instruction, comp: Computation) -> float:
    res_b, _ = _type_bytes_and_elems(ins.type_str)
    op_b = 0
    for sym in _operand_syms(ins):
        t = comp.symbols.get(sym)
        if t:
            ob, _ = _type_bytes_and_elems(t)
            op_b += ob
    kind = ins.op.replace("-start", "")
    if kind == "all-gather":
        return res_b
    if kind == "all-reduce":
        return 2.0 * (op_b or res_b)
    return op_b or res_b


@dataclasses.dataclass
class HloCost:
    flops: float
    hbm_bytes: float
    collective_bytes: dict
    n_dots: int
    unknown_flop_ops: int

    @property
    def wire_bytes(self) -> float:
        return sum(self.collective_bytes.values())


def analyze_hlo(hlo: str) -> HloCost:
    comps = parse_computations(hlo)
    mult, fusion_bodies = _multipliers(comps)
    flops = 0.0
    hbm = 0.0
    coll: dict[str, float] = {k: 0.0 for k in _COLLECTIVE_OPS}
    n_dots = 0
    unknown = 0
    for cname, m in mult.items():
        if cname == "__entry__":
            continue
        comp = comps.get(cname)
        if comp is None:
            continue
        in_fusion = cname in fusion_bodies
        for ins in comp.instructions:
            if ins.op == "dot":
                flops += m * _dot_flops(ins, comp)
                n_dots += 1
            elif ins.op == "convolution":
                unknown += 1
            kind = ins.op.replace("-start", "") \
                if ins.op.endswith("-start") else ins.op
            if kind in _COLLECTIVE_OPS and not ins.op.endswith("-done"):
                payload = _collective_payload(ins, comp)
                coll[kind] += m * payload
                hbm += m * payload
            if in_fusion or ins.op in _SKIP_BYTES_OPS \
                    or kind in _COLLECTIVE_OPS:
                continue
            hbm += m * _instr_bytes(ins, comp, comps)
    return HloCost(flops, hbm, coll, n_dots, unknown)


def top_bytes(hlo: str, n: int = 15):
    """Debug helper: heaviest (instruction x multiplier) byte movers."""
    comps = parse_computations(hlo)
    mult, fusion_bodies = _multipliers(comps)
    rows = []
    for cname, m in mult.items():
        if cname == "__entry__" or cname in fusion_bodies:
            continue
        comp = comps.get(cname)
        if comp is None:
            continue
        for ins in comp.instructions:
            kind = ins.op.replace("-start", "")
            if ins.op in _SKIP_BYTES_OPS or kind in _COLLECTIVE_OPS:
                continue
            b = m * _instr_bytes(ins, comp, comps)
            rows.append((b, m, ins.op, ins.line.strip()[:140]))
    rows.sort(reverse=True)
    return rows[:n]
