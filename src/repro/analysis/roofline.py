"""Roofline analysis from compiled dry-run artifacts (TPU v5e targets).

Three terms, all in seconds (per training/serve step, per device — the
SPMD module cost analysis is per-device):

    compute    = HLO_FLOPs / peak_FLOPs          (197 TFLOP/s bf16)
    memory     = HLO_bytes / HBM_bw              (819 GB/s)
    collective = wire_bytes / link_bw            (~50 GB/s ICI)

``wire_bytes`` is parsed from the post-SPMD HLO text: per-device payload
of every all-gather / all-reduce / reduce-scatter / all-to-all /
collective-permute, with collectives inside while-loop bodies (lax.scan
over layers / attention blocks) multiplied by the loop trip count
(recovered from the loop condition's comparison constant).

Byte model per op (ring algorithms):
    all-gather:          result_bytes            (receives n-1/n of out)
    reduce-scatter:      operand_bytes
    all-reduce:          2 x operand_bytes       (RS + AG phases)
    all-to-all:          operand_bytes
    collective-permute:  operand_bytes
"""
from __future__ import annotations

import dataclasses
import re

PEAK_FLOPS = 197e12       # bf16 / chip
HBM_BW = 819e9            # bytes/s per chip
ICI_BW = 50e9             # bytes/s per link (spec: ~50 GB/s/link)

_DTYPE_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2, "bf16": 2,
    "f16": 2, "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8,
    "f64": 8, "c64": 8, "c128": 16,
}

_SHAPE_RE = re.compile(r"(\w+)\[([\d,]*)\]")
_COLLECTIVES = ("all-gather", "all-reduce", "reduce-scatter",
                "all-to-all", "collective-permute")


def _shape_bytes(type_str: str) -> int:
    m = _SHAPE_RE.match(type_str.strip())
    if not m:
        return 0
    dt, dims = m.groups()
    n = 1
    for d in dims.split(","):
        if d:
            n *= int(d)
    return n * _DTYPE_BYTES.get(dt, 4)


@dataclasses.dataclass
class CollectiveStats:
    bytes_by_kind: dict
    total_bytes: float

    @property
    def breakdown(self) -> str:
        return ", ".join(f"{k}={v/1e6:.1f}MB"
                         for k, v in sorted(self.bytes_by_kind.items())
                         if v)


def _split_computations(hlo: str) -> dict[str, list[str]]:
    """computation name -> its instruction lines."""
    comps: dict[str, list[str]] = {}
    cur = None
    for line in hlo.splitlines():
        m = re.match(r"^(?:ENTRY\s+)?%?([\w\.\-]+)\s*\([^)]*\)\s*->", line)
        if m and line.rstrip().endswith("{"):
            cur = m.group(1)
            comps[cur] = []
        elif cur is not None:
            if line.strip() == "}":
                cur = None
            else:
                comps[cur].append(line)
    return comps


def _loop_trip_counts(hlo: str, comps: dict) -> dict[str, int]:
    """while-body computation name -> estimated trip count."""
    trips: dict[str, int] = {}
    for lines in comps.values():
        for line in lines:
            if " while(" not in line:
                continue
            mc = re.search(r"condition=%?([\w\.\-]+)", line)
            mb = re.search(r"body=%?([\w\.\-]+)", line)
            if not (mc and mb):
                continue
            cond, body = mc.group(1), mb.group(1)
            count = 1
            for cl in comps.get(cond, []):
                for c in re.findall(r"constant\((\d+)\)", cl):
                    count = max(count, int(c))
            trips[body] = count
    return trips


def collective_bytes(hlo: str) -> CollectiveStats:
    comps = _split_computations(hlo)
    trips = _loop_trip_counts(hlo, comps)
    by_kind: dict[str, float] = {k: 0.0 for k in _COLLECTIVES}

    for comp_name, lines in comps.items():
        mult = trips.get(comp_name, 1)
        for line in lines:
            m = re.search(
                r"=\s*(.*?)\s*"
                r"(all-gather|all-reduce|reduce-scatter|all-to-all|"
                r"collective-permute)(-start|-done)?\(", line)
            if not m:
                continue
            result_t, kind, suffix = m.groups()
            if suffix == "-done":
                continue          # payload counted at the -start op
            # result types: possibly a tuple "(bf16[..]{..}, ...)"
            res_bytes = sum(_shape_bytes(t) for t in
                            re.findall(r"\w+\[[\d,]*\]", result_t))
            # operand types appear inline in the call parens
            call = line[m.end():]
            op_bytes = sum(_shape_bytes(t) for t in
                           re.findall(r"\w+\[[\d,]*\]", call))
            if kind == "all-gather":
                b = res_bytes
            elif kind == "all-reduce":
                b = 2 * (op_bytes or res_bytes)
            elif kind == "reduce-scatter":
                b = op_bytes or res_bytes
            else:
                b = op_bytes or res_bytes
            by_kind[kind] += b * mult
    return CollectiveStats(by_kind, sum(by_kind.values()))


@dataclasses.dataclass
class Roofline:
    flops: float              # per device
    hbm_bytes: float          # per device
    wire_bytes: float         # per device
    compute_s: float
    memory_s: float
    collective_s: float
    bottleneck: str
    model_flops: float        # 6*N*D (or 2*N*D serve), GLOBAL
    useful_ratio: float       # model_flops / (flops * n_chips)
    step_s: float
    mfu: float

    def as_dict(self) -> dict:
        return dataclasses.asdict(self)


def analyze(compiled, *, n_chips: int, model_flops: float,
            hlo: str | None = None) -> Roofline:
    """Trip-count-aware roofline. XLA's cost_analysis() counts while
    bodies once (a 40-layer lax.scan would be 40x undercounted), so the
    numbers come from analysis.hlo_cost; the XLA aggregates are kept in
    the dry-run JSON for reference."""
    from repro.analysis import hlo_cost

    hlo = hlo if hlo is not None else compiled.as_text()
    cost = hlo_cost.analyze_hlo(hlo)
    flops = cost.flops
    hbm = cost.hbm_bytes
    wire = cost.wire_bytes
    c = flops / PEAK_FLOPS
    m = hbm / HBM_BW
    x = wire / ICI_BW
    terms = {"compute": c, "memory": m, "collective": x}
    bottleneck = max(terms, key=terms.get)
    step = max(c, m, x)
    useful = model_flops / max(flops * n_chips, 1.0)
    mfu = (model_flops / n_chips / max(step, 1e-30)) / PEAK_FLOPS
    return Roofline(flops, hbm, wire, c, m, x, bottleneck,
                    model_flops, useful, step, mfu)


def model_flops_for(cfg, shape) -> float:
    """MODEL_FLOPS: 6*N_active*tokens for training, 2*N_active*tokens
    for inference forward (decode counts one new token per sequence)."""
    n = cfg.active_param_count()
    if shape.kind == "train":
        return 6.0 * n * shape.global_batch * shape.seq_len
    if shape.kind == "prefill":
        return 2.0 * n * shape.global_batch * shape.seq_len
    return 2.0 * n * shape.global_batch      # decode: 1 token/seq
