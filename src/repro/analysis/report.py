"""Generate the EXPERIMENTS.md §Dry-run and §Roofline tables from the
JSON cells written by launch/dryrun.py.

    PYTHONPATH=src python -m repro.analysis.report > experiments/tables.md
"""
from __future__ import annotations

import json
import pathlib

from repro.analysis.roofline import HBM_BW, ICI_BW, PEAK_FLOPS
from repro.configs import ASSIGNED, SHAPES

OUT_DIR = pathlib.Path(__file__).resolve().parents[3] / "experiments" \
    / "dryrun"


def load_cells(mesh: str) -> dict:
    cells = {}
    for arch in ASSIGNED:
        for shape in SHAPES:
            p = OUT_DIR / mesh / arch / f"{shape}.json"
            if p.exists():
                cells[(arch, shape)] = json.loads(p.read_text())
    return cells


def _fmt_s(x: float) -> str:
    if x >= 1:
        return f"{x:.2f}s"
    if x >= 1e-3:
        return f"{x*1e3:.1f}ms"
    return f"{x*1e6:.0f}us"


def roofline_table(mesh: str = "single") -> str:
    cells = load_cells(mesh)
    lines = [
        f"### Roofline — {mesh}-pod mesh "
        f"({'512' if mesh == 'multi' else '256'} chips, v5e: "
        f"{PEAK_FLOPS/1e12:.0f} TF/s bf16, {HBM_BW/1e9:.0f} GB/s HBM, "
        f"{ICI_BW/1e9:.0f} GB/s link)",
        "",
        "| arch | shape | step | compute | memory | collective | "
        "bottleneck | MODEL/HLO flops | roofline MFU bound | "
        "peak GiB/dev | fits 16G |",
        "|---|---|---|---|---|---|---|---|---|---|---|",
    ]
    for (arch, shape), cell in sorted(cells.items()):
        if "skipped" in cell:
            lines.append(
                f"| {arch} | {shape} | — | — | — | — | skipped | — | — "
                f"| — | ({cell['skipped']}) |")
            continue
        for tag in ("train_step", "serve_step", "sync_step"):
            if tag not in cell:
                continue
            r = cell[tag]["roofline"]
            mem = cell[tag]["memory"].get("peak_device_bytes", 0)
            fits = "yes" if mem <= 16 * 2**30 else \
                f"NO ({mem/2**30:.0f}G)"
            lines.append(
                f"| {arch} | {shape} | {tag.split('_')[0]} | "
                f"{_fmt_s(r['compute_s'])} | {_fmt_s(r['memory_s'])} | "
                f"{_fmt_s(r['collective_s'])} | {r['bottleneck']} | "
                f"{r['useful_ratio']:.2f} | {r['mfu']:.3f} | "
                f"{mem/2**30:.2f} | {fits} |")
    return "\n".join(lines)


def summary(mesh: str) -> str:
    cells = load_cells(mesh)
    done = sum(1 for c in cells.values() if "skipped" not in c)
    skipped = sum(1 for c in cells.values() if "skipped" in c)
    over = [k for k, c in cells.items() if "skipped" not in c and any(
        c[t]["memory"].get("peak_device_bytes", 0) > 16 * 2**30
        for t in ("train_step", "serve_step", "sync_step") if t in c)]
    return (f"{mesh}: {done} compiled, {skipped} skipped "
            f"(documented), {len(over)} cells over 16 GiB/dev: {over}")


if __name__ == "__main__":
    for mesh in ("single", "multi"):
        print(summary(mesh))
        print()
        print(roofline_table(mesh))
        print()
