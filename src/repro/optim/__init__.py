from repro.optim.adamw import AdamW, AdamWState
from repro.optim.nesterov import NesterovSGD, NesterovState
from repro.optim import schedules

__all__ = ["AdamW", "AdamWState", "NesterovSGD", "NesterovState",
           "schedules"]
