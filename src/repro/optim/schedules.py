"""Learning-rate schedules. The paper (and MiniCPM, one of the assigned
archs) uses WSD — Warmup / Stable / Decay (Hägele et al., 2024): constant
lr after warmup, cool-down during the final fraction of training.
INTELLECT-1: 1000 warmup steps, anneal over the last 20%."""
from __future__ import annotations

import jax.numpy as jnp


def wsd(peak_lr: float, warmup_steps: int, total_steps: int,
        decay_fraction: float = 0.2, final_ratio: float = 0.0,
        decay_shape: str = "one_minus_sqrt"):
    """Warmup-Stable-Decay schedule: step -> lr."""
    decay_steps = max(1, int(total_steps * decay_fraction))
    decay_start = total_steps - decay_steps

    def schedule(step):
        step = jnp.asarray(step, jnp.float32)
        warm = peak_lr * jnp.minimum(step / max(1, warmup_steps), 1.0)
        frac = jnp.clip((step - decay_start) / decay_steps, 0.0, 1.0)
        if decay_shape == "linear":
            mult = 1.0 - (1.0 - final_ratio) * frac
        elif decay_shape == "cosine":
            mult = final_ratio + (1 - final_ratio) * 0.5 * (
                1 + jnp.cos(jnp.pi * frac))
        else:  # "one_minus_sqrt" (Hägele et al. recommended)
            mult = 1.0 - (1.0 - final_ratio) * jnp.sqrt(frac)
        return warm * jnp.where(step >= decay_start, mult, 1.0)

    return schedule


def constant(lr: float):
    return lambda step: jnp.full((), lr, jnp.float32)
