"""AdamW — the DiLoCo *inner* optimizer (paper: lr 7.5e-5, b1 0.9,
b2 0.95, weight decay 0.1). Pure-JAX pytree implementation; moments are
kept in fp32 regardless of parameter dtype (bf16-safe)."""
from __future__ import annotations

import dataclasses
from typing import Any, Callable, NamedTuple

import jax
import jax.numpy as jnp


class AdamWState(NamedTuple):
    step: jnp.ndarray
    m: Any
    v: Any


@dataclasses.dataclass(frozen=True)
class AdamW:
    lr: float | Callable = 1e-3      # float or schedule(step) -> lr
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1

    def init(self, params) -> AdamWState:
        zeros = jax.tree.map(
            lambda p: jnp.zeros(p.shape, jnp.float32), params)
        return AdamWState(jnp.zeros((), jnp.int32), zeros,
                          jax.tree.map(jnp.copy, zeros))

    def update(self, grads, state: AdamWState, params):
        step = state.step + 1
        lr = self.lr(step) if callable(self.lr) else self.lr
        b1, b2 = self.b1, self.b2

        def upd(g, m, v, p):
            g = g.astype(jnp.float32)
            m = b1 * m + (1 - b1) * g
            v = b2 * v + (1 - b2) * g * g
            mhat = m / (1 - b1 ** step.astype(jnp.float32))
            vhat = v / (1 - b2 ** step.astype(jnp.float32))
            delta = mhat / (jnp.sqrt(vhat) + self.eps)
            delta = delta + self.weight_decay * p.astype(jnp.float32)
            new_p = p.astype(jnp.float32) - lr * delta
            return new_p.astype(p.dtype), m, v

        out = jax.tree.map(upd, grads, state.m, state.v, params)
        new_params = jax.tree.map(lambda t: t[0], out,
                                  is_leaf=lambda t: isinstance(t, tuple))
        new_m = jax.tree.map(lambda t: t[1], out,
                             is_leaf=lambda t: isinstance(t, tuple))
        new_v = jax.tree.map(lambda t: t[2], out,
                             is_leaf=lambda t: isinstance(t, tuple))
        return new_params, AdamWState(step, new_m, new_v)
