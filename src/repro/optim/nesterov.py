"""Nesterov-momentum SGD — the DiLoCo *outer* optimizer (paper: outer lr
0.7, momentum 0.9). Operates on averaged pseudo-gradients
``delta = anchor - theta_i`` (Alg. 1 lines 10-12)."""
from __future__ import annotations

import dataclasses
from typing import Any, NamedTuple

import jax
import jax.numpy as jnp


class NesterovState(NamedTuple):
    momentum: Any  # fp32 pytree, same structure as params


@dataclasses.dataclass(frozen=True)
class NesterovSGD:
    lr: float = 0.7
    momentum: float = 0.9

    def init(self, params) -> NesterovState:
        return NesterovState(jax.tree.map(
            lambda p: jnp.zeros(p.shape, jnp.float32), params))

    def update(self, delta, state: NesterovState, params):
        """theta <- theta - lr * (mu * m_new + delta)  (Nesterov form),
        where m_new = mu * m + delta and delta is the averaged
        pseudo-gradient (already points from theta toward the anchor)."""
        mu = self.momentum

        def upd(d, m, p):
            d = d.astype(jnp.float32)
            m_new = mu * m + d
            step = mu * m_new + d  # Nesterov look-ahead
            new_p = p.astype(jnp.float32) - self.lr * step
            return new_p.astype(p.dtype), m_new

        out = jax.tree.map(upd, delta, state.momentum, params)
        is_pair = lambda t: isinstance(t, tuple)
        new_params = jax.tree.map(lambda t: t[0], out, is_leaf=is_pair)
        new_m = jax.tree.map(lambda t: t[1], out, is_leaf=is_pair)
        return new_params, NesterovState(new_m)

    def update_flat(self, delta_flat: jnp.ndarray, m_flat: jnp.ndarray,
                    p_flat: jnp.ndarray):
        """Flat-buffer mirror of ``update`` used by the SyncEngine's
        persistent fp32 anchor: same elementwise math (bit-identical to
        the per-leaf form), returns (new_p_flat, new_m_flat)."""
        d = delta_flat.astype(jnp.float32)
        m_new = self.momentum * m_flat + d
        step = self.momentum * m_new + d  # Nesterov look-ahead
        return p_flat - self.lr * step, m_new
