"""Activation-sharding hints.

Model code is mesh-agnostic; the step builder knows the plan. This
module bridges them: the builder activates a hint spec for the duration
of tracing and models call ``hint_residual`` on their (B, S, D) residual
stream at block boundaries. The canonical use is sequence parallelism on
multi-pod training where the per-pod batch (128) cannot cover
data x model (256): batch shards over 'data', the sequence dim over
'model', which divides the attention score tiles and their FLOPs by the
model-axis size.

No-ops outside a mesh context or when no hint is active (CPU trainer,
shard_map regions where the axis is manual).
"""
from __future__ import annotations

import contextlib

import jax

_SPEC = None


@contextlib.contextmanager
def activation_hints(spec):
    """Activate ``spec`` (a PartitionSpec for (B, S, D) activations)
    while tracing a step function."""
    global _SPEC
    prev = _SPEC
    _SPEC = spec
    try:
        yield
    finally:
        _SPEC = prev


def hint_residual(x):
    """Constrain a (B, S, D) activation to the active hint (no-op when
    unset/invalid in the current tracing context)."""
    if _SPEC is None or x.ndim != 3:
        return x
    try:
        return jax.lax.with_sharding_constraint(x, _SPEC)
    except Exception:
        return x
