"""ParallelismPlan: which mesh axis carries which form of parallelism
for a given (arch x shape x mesh) cell.

The paper's hybrid (§2.3): DiLoCo across the slow fabric, FSDP inside.
TPU mapping:
  * ``diloco_axis``  — 'pod' (multi-pod: inter-pod DCI is the "WAN") or
    'data' (single-pod: 16 DiLoCo workers of 16-chip FSDP groups, the
    paper's many-small-nodes regime), or None (huge models single-pod,
    or serving);
  * params shard over 'model' (TP/FSDP rules in ``partition.py``) and
    optionally also over 'data' (``fsdp_data``, for dbrx-class models);
  * activations/batch shard over the non-DiLoCo data axes;
  * decode caches shard KV-heads over 'model' when divisible, else the
    sequence dim (SP) for long contexts.
"""
from __future__ import annotations

import dataclasses

from repro.configs.base import ArchConfig, ShapeConfig


@dataclasses.dataclass(frozen=True)
class ParallelismPlan:
    diloco_axis: str | None
    rules: tuple[tuple[str, str | None], ...]  # logical -> mesh axis
    batch_axes: tuple[str, ...]                # activation batch sharding
    seq_axis: str | None                       # SP for long-context caches
    remat: bool
    n_workers: int                             # DiLoCo world size
    act_seq_axis: str | None = None            # SP for train activations
    microbatches: int = 1                      # gradient accumulation

    def rules_dict(self) -> dict:
        return dict(self.rules)


def make_plan(cfg: ArchConfig, shape: ShapeConfig,
              mesh_axes: dict[str, int]) -> ParallelismPlan:
    multi_pod = "pod" in mesh_axes
    diloco = None
    if shape.kind == "train":
        if cfg.diloco_pref == "none":
            diloco = None
        elif cfg.diloco_pref == "pod_only":
            diloco = "pod" if multi_pod else None
        else:  # auto: prefer the slow axis; else many workers in-pod
            diloco = "pod" if multi_pod else "data"

    fsdp_data = cfg.fsdp_data and diloco != "data"
    # tiny models: replicate params inside the DiLoCo worker and go pure
    # data-parallel over the 'model' axis too (TP shards would be
    # slivers and the SSD head count may not divide the axis)
    inner_dp = shape.kind == "train" and cfg.param_count() < 6e8
    if inner_dp:
        rules = (("vocab", None), ("heads", None), ("ff", None),
                 ("experts", None), ("embed", None), ("layers", None))
    elif fsdp_data and diloco is not None:
        # FSDP over data x model INSIDE a manual DiLoCo region: XLA's
        # SPMD partitioner CHECK-fails on manual subgroups + two
        # independently sharded dims, so shard ONE dim over the
        # combined ('data','model') axes (256-way) instead — same
        # per-chip memory, partitioner-safe.
        combo = ("data", "model")
        rules = (("vocab", combo), ("heads", combo),
                 ("ff", [combo, "data"]),       # expert FFN: 'model'
                 ("experts", "model"),          # is taken by E -> use
                 ("embed", None),               # 'data' for d_expert
                 ("layers", None))
    else:
        rules = (
            ("vocab", "model"),
            ("heads", "model"),
            ("ff", "model"),
            ("experts", "model"),
            ("embed", "data" if fsdp_data else None),
            ("layers", None),
        )
    batch_axes = tuple(a for a in ("pod", "data")
                       if a in mesh_axes and a != diloco)
    if shape.kind == "train":
        # FSDP-style activation sharding: also spread the per-worker
        # batch over 'model' when it divides (params stay 'model'-
        # sharded storage; XLA gathers weights per layer = FSDP)
        n_workers_est = mesh_axes.get(diloco, 1) if diloco else 1
        per_worker_batch = shape.global_batch // n_workers_est
        prod = 1
        for a in batch_axes + ("model",):
            prod *= mesh_axes[a]
        if per_worker_batch % prod == 0:
            batch_axes = batch_axes + ("model",)
    # SP: shard long decode caches over 'model' on the seq dim when the
    # batch is too small to cover the mesh and kv-heads don't divide
    # decode caches: sequence-parallel fallback over 'model' (used by
    # cache_pspec only when the KV-head count doesn't divide the axis)
    seq_axis = "model" if shape.kind in ("decode", "prefill") else None
    # training activations: when the batch can't cover data x model,
    # shard the SEQUENCE dim over 'model' (SP) for attention-family
    # archs — divides score tiles and their FLOPs by 16. (SSM/hybrid
    # scan over chunks sequentially; SP would serialize cross-device,
    # so those models ignore the hint.)
    act_seq_axis = None
    if (shape.kind == "train" and "model" not in batch_axes
            and not inner_dp and cfg.family not in ("ssm", "hybrid")
            and shape.seq_len % (mesh_axes["model"] * 32) == 0):
        act_seq_axis = "model"
    # activation checkpointing for every training shape (the paper's
    # FSDP training does the same; the SSD dual form in particular
    # saves O(L*Q) intra-chunk buffers without it)
    remat = shape.kind == "train"
    n_workers = mesh_axes.get(diloco, 1) if diloco else 1
    # gradient accumulation for the largest models: divides activation
    # peak by the microbatch count (params/optimizer unchanged)
    microbatches = 1
    if shape.kind == "train" and cfg.param_count() > 6e10:
        per_worker_batch = shape.global_batch // n_workers
        for cand in (4, 2):
            if per_worker_batch % cand == 0:
                microbatches = cand
                break
    return ParallelismPlan(diloco, rules, batch_axes, seq_axis, remat,
                           n_workers, act_seq_axis, microbatches)
