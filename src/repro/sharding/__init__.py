from repro.sharding.partition import (batch_pspec, cache_pspec,
                                      param_pspec, param_pspecs,
                                      to_named, with_leading)
from repro.sharding.plans import ParallelismPlan, make_plan

__all__ = ["ParallelismPlan", "make_plan", "param_pspec", "param_pspecs",
           "batch_pspec", "cache_pspec", "with_leading", "to_named"]
