"""Logical axes -> PartitionSpecs.

Every param's logical axis tuple (from the model's ParamBuilder) is
mapped through the plan's rules with conflict resolution: a mesh axis is
used at most once per param (first logical axis wins) and a dim is only
sharded when the mesh axis divides it (no padded shards on the memory-
critical parameters)."""
from __future__ import annotations

from typing import Any

import jax
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.sharding.plans import ParallelismPlan


def _is_axes(x) -> bool:
    return isinstance(x, tuple) and all(
        a is None or isinstance(a, str) for a in x)


def param_pspec(axes: tuple, shape: tuple, plan: ParallelismPlan,
                mesh_axes: dict[str, int]) -> P:
    rules = plan.rules_dict()
    used: set[str] = set()
    out = []
    for logical, dim in zip(axes, shape):
        rule = rules.get(logical)
        # rule: None | 'axis' | ('a','b') combined | ['pref1', 'pref2']
        prefs = rule if isinstance(rule, list) else [rule]
        chosen = None
        for cand in prefs:
            if cand is None:
                continue
            parts = (cand,) if isinstance(cand, str) else tuple(cand)
            if any(p in used or p not in mesh_axes for p in parts):
                continue
            size = 1
            for p in parts:
                size *= mesh_axes[p]
            if dim % size == 0:
                chosen = cand if isinstance(cand, str) else parts
                used.update(parts)
                break
        out.append(chosen)
    while out and out[-1] is None:
        out.pop()
    return P(*out)


def param_pspecs(axes_tree: Any, shapes_tree: Any,
                 plan: ParallelismPlan, mesh_axes: dict[str, int]) -> Any:
    return jax.tree.map(
        lambda a, s: param_pspec(a, s.shape, plan, mesh_axes),
        axes_tree, shapes_tree, is_leaf=_is_axes)


def with_leading(pspec_tree: Any, axis: str | None) -> Any:
    """Prepend the DiLoCo worker axis to every spec (stacked state)."""
    if axis is None:
        return pspec_tree
    return jax.tree.map(lambda s: P(axis, *s), pspec_tree,
                        is_leaf=lambda x: isinstance(x, P))


def wan_ring_specs(wan_axis: str,
                   local_axes: tuple[str, ...] = ()) -> tuple[P, P]:
    """Specs for the distributed outer-sync ring buffers.

    Returns ``(row_spec, acc_spec)``: per-worker flat rows — pseudo-
    gradients, thetas, weights — are sharded over the WAN (DiLoCo) axis
    only (``P(wan_axis)``); the in-flight ring accumulator/payload
    buffers additionally split their slice dim over the intra-node axes
    in hierarchical mode (``P(wan_axis, local_axes)`` — the paper's
    ElasticDeviceMesh split, see ``core.elastic_mesh.hierarchy``)."""
    row = P(wan_axis)
    acc = P(wan_axis, local_axes) if local_axes else row
    return row, acc


def batch_pspec(plan: ParallelismPlan,
                batch_size: int | None = None,
                mesh_axes: dict[str, int] | None = None) -> P:
    """Batch-leading activation/input sharding (dim 0 over batch axes).
    When ``batch_size`` is given, axes are dropped (outermost first)
    until the product divides it — argument shardings must divide."""
    ax = list(plan.batch_axes)
    if batch_size is not None and mesh_axes is not None:
        while ax:
            prod = 1
            for a in ax:
                prod *= mesh_axes[a]
            if batch_size % prod == 0 and batch_size >= prod:
                break
            ax.pop()
    if not ax:
        return P()
    lead = ax[0] if len(ax) == 1 else tuple(ax)
    return P(lead)


def cache_pspec(shape: tuple, plan: ParallelismPlan,
                mesh_axes: dict[str, int], *, batch_dim: int,
                heads_dim: int | None, seq_dim: int | None) -> P:
    """KV/SSM cache sharding: batch over data axes; heads over 'model'
    when divisible; else SP over the sequence dim for long contexts."""
    out: list = [None] * len(shape)
    bsz = shape[batch_dim]
    ax = plan.batch_axes
    if ax:
        n = 1
        for a in ax:
            n *= mesh_axes[a]
        if bsz % n == 0 and bsz >= n:
            out[batch_dim] = ax[0] if len(ax) == 1 else ax
    model = mesh_axes.get("model")
    if model:
        if (heads_dim is not None
                and shape[heads_dim] % model == 0):
            out[heads_dim] = "model"
        elif (plan.seq_axis and seq_dim is not None
              and shape[seq_dim] % model == 0):
            out[seq_dim] = plan.seq_axis
    while out and out[-1] is None:
        out.pop()
    return P(*out)


def to_named(tree: Any, mesh) -> Any:
    return jax.tree.map(
        lambda s: NamedSharding(mesh, s), tree,
        is_leaf=lambda x: isinstance(x, P))
