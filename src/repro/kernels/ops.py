"""Jit'd public wrappers over the int8 quantization kernels.

``impl`` selects the backend:
  * ``"pallas"`` — the TPU Pallas kernels (interpret mode off-TPU),
  * ``"jnp"``    — the pure-jnp oracle (used for dry-run lowering so the
                   quantization FLOPs/bytes stay visible/analyzable in HLO,
                   and on hosts where interpret mode would be too slow).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.kernels import int8_quant, ref

Quantized = ref.Quantized


def quantize(x: jnp.ndarray, *, impl: str = "pallas") -> Quantized:
    """Paper-faithful int8 quantization (6-sigma clip, bucket-mean codebook)."""
    if impl == "jnp":
        return ref.quantize(x)
    lo, width = ref.quant_params(x)
    codes, sums, counts = int8_quant.encode_hist(x, lo, width)
    return Quantized(codes, ref.make_codebook(sums, counts, lo, width))


@functools.partial(jax.jit, static_argnames=("impl",))
def _quantize_pseudograd(anchor, theta, scale, *, impl: str):
    af = anchor.astype(jnp.float32)
    tf = theta.astype(jnp.float32)
    if impl == "jnp":
        return ref.quantize_pseudograd(af, tf, scale=scale)
    # lo/width need stats of scale*(anchor - theta). Computed inside this
    # jit, XLA fuses the subtract/scale straight into the mean/std
    # reductions, so the pseudo-gradient is never materialized in HBM:
    # one stats trip over (anchor, theta), then the fused Pallas encode
    # reads (anchor, theta) once more and emits codes + histogram.
    pg = af - tf
    if scale is not None:
        pg = pg * scale
    lo, width = ref.quant_params(pg)
    codes, sums, counts = int8_quant.pseudograd_encode_hist(
        anchor, theta, lo, width, scale=scale)
    return Quantized(codes, ref.make_codebook(sums, counts, lo, width))


def quantize_pseudograd(anchor: jnp.ndarray, theta: jnp.ndarray, *,
                        scale=None, impl: str = "pallas") -> Quantized:
    """Fused ``scale * (anchor - theta)`` + quantize, single HBM trip per
    input — bit-identical to ``quantize(scale * (anchor - theta))``
    (``scale=None`` means unscaled; it is the elastic worker weight when
    the ring's transmit path calls this)."""
    return _quantize_pseudograd(anchor, theta, scale, impl=impl)


def dequantize(q: Quantized, *, dtype=jnp.float32,
               impl: str = "pallas") -> jnp.ndarray:
    if impl == "jnp":
        return ref.dequantize(q, dtype)
    return int8_quant.decode(q.codes, q.codebook).astype(dtype)


def dequantize_add(q: Quantized, acc: jnp.ndarray, *,
                   impl: str = "pallas") -> jnp.ndarray:
    """acc + dequantize(q) — fused on the Pallas path."""
    if impl == "jnp":
        return acc + ref.dequantize(q, acc.dtype)
    return int8_quant.decode_add(q.codes, q.codebook, acc)


def roundtrip_error(x: jnp.ndarray, *, impl: str = "jnp") -> jnp.ndarray:
    """Max |x - deq(q(x))| inside the clip range — test/bench helper."""
    q = quantize(x, impl=impl)
    return jnp.max(jnp.abs(x.astype(jnp.float32) - dequantize(q, impl=impl)))
