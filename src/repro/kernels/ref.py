"""Pure-jnp reference (oracle) for the PRIME int8 quantization scheme.

Paper (INTELLECT-1 §2.2): uniform quantization with clipping, following
Ryabinin et al. (2020):

  1. compute mean (mu) and std (sigma) of the tensor,
  2. quantization range = [mu - 6 sigma, mu + 6 sigma],
  3. range divided uniformly into 256 buckets,
  4. codebook value per bucket = average of the values falling in it
     (empty buckets fall back to the bucket midpoint),
  5. reduction is performed in fp32 -- only the *wire format* is int8
     (Q(a) + Q(b) != Q(a + b)).

Everything here is plain jnp and serves as the allclose oracle for the
Pallas kernels in ``int8_quant.py``.
"""
from __future__ import annotations

from typing import NamedTuple

import jax.numpy as jnp

NUM_BUCKETS = 256
CLIP_SIGMAS = 6.0
_EPS = 1e-12


class Quantized(NamedTuple):
    """Wire format of one quantized tensor (or tensor chunk)."""

    codes: jnp.ndarray      # uint8, same shape as the input
    codebook: jnp.ndarray   # (256,) fp32 dequantization table

    @property
    def wire_bytes(self) -> int:
        """Bytes on the wire: 1 byte/element + the fp32 codebook sideband."""
        return int(self.codes.size) + 4 * NUM_BUCKETS


def quant_params(x: jnp.ndarray) -> tuple[jnp.ndarray, jnp.ndarray]:
    """(lo, bucket_width) of the clipped uniform quantization range."""
    xf = x.astype(jnp.float32)
    mu = jnp.mean(xf)
    sigma = jnp.std(xf)
    half = CLIP_SIGMAS * sigma
    lo = mu - half
    width = jnp.maximum(2.0 * half / NUM_BUCKETS, _EPS)
    return lo, width


def encode(x: jnp.ndarray, lo: jnp.ndarray, width: jnp.ndarray) -> jnp.ndarray:
    """Bucket indices (uint8) for every element of ``x``."""
    xf = x.astype(jnp.float32)
    idx = jnp.floor((xf - lo) / width)
    return jnp.clip(idx, 0, NUM_BUCKETS - 1).astype(jnp.uint8)


def bucket_stats(
    x: jnp.ndarray, codes: jnp.ndarray
) -> tuple[jnp.ndarray, jnp.ndarray]:
    """Per-bucket (sum, count) of the values mapped to each bucket."""
    xf = x.astype(jnp.float32).reshape(-1)
    c = codes.reshape(-1).astype(jnp.int32)
    sums = jnp.zeros((NUM_BUCKETS,), jnp.float32).at[c].add(xf)
    counts = jnp.zeros((NUM_BUCKETS,), jnp.float32).at[c].add(1.0)
    return sums, counts


def make_codebook(
    sums: jnp.ndarray, counts: jnp.ndarray, lo: jnp.ndarray, width: jnp.ndarray
) -> jnp.ndarray:
    """Bucket means; empty buckets fall back to the bucket midpoint."""
    centers = lo + (jnp.arange(NUM_BUCKETS, dtype=jnp.float32) + 0.5) * width
    means = sums / jnp.maximum(counts, 1.0)
    return jnp.where(counts > 0, means, centers)


def quantize(x: jnp.ndarray) -> Quantized:
    """Full paper-faithful quantization: codes + bucket-mean codebook."""
    lo, width = quant_params(x)
    codes = encode(x, lo, width)
    sums, counts = bucket_stats(x, codes)
    return Quantized(codes, make_codebook(sums, counts, lo, width))


def dequantize(q: Quantized, dtype=jnp.float32) -> jnp.ndarray:
    return q.codebook[q.codes.astype(jnp.int32)].astype(dtype)


def quantize_pseudograd(anchor: jnp.ndarray, theta: jnp.ndarray,
                        scale=None) -> Quantized:
    """Fused pseudo-gradient ``scale * (anchor - theta)`` + quantize —
    oracle for the fused Pallas kernel. ``scale`` is the elastic worker
    weight folded into the transmit path (None = unweighted)."""
    pg = anchor.astype(jnp.float32) - theta.astype(jnp.float32)
    if scale is not None:
        pg = pg * jnp.float32(scale)
    return quantize(pg)
