"""Pallas TPU kernels for PRIME int8 pseudo-gradient quantization.

TPU adaptation of the paper's custom multithreaded C++ uint8 quantization
(INTELLECT-1 §2.2).  The GPU/CPU version scatter-adds into 256 histogram
bins; TPUs have no fast scatter, so the per-bucket statistics (needed for
the bucket-mean codebook) are computed as ``one_hot(codes) @ values`` —
an MXU matmul over (slab, 256) one-hot tiles.  Decode similarly uses
``one_hot(codes) @ codebook`` so nothing relies on vector gathers.

Layout: the flat tensor is padded and viewed as (rows, 128) with fp32
blocks of (BLOCK_ROWS, 128) staged through VMEM; per-block partial
histograms are accumulated across the (sequential) TPU grid into a single
(1, 256) output block.

Kernels:
  * ``encode_hist``      — codes + per-bucket (sum, count) in one pass
  * ``pseudograd_encode``— fused (anchor - theta) + encode (+hist); saves
                           one HBM round-trip for the DiLoCo outer step
  * ``decode``           — codebook[codes] via one-hot matmul
  * ``decode_add``       — fused dequantize-accumulate for the fp32 ring
                           accumulator (one pass instead of two)

All kernels are validated against ``ref.py`` in interpret mode (this
container is CPU-only; TPU is the deployment target).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels import ref

LANE = 128           # TPU lane width
BLOCK_ROWS = 512     # (512, 128) fp32 = 256 KiB / block in VMEM
SLAB_ROWS = 8        # histogram one-hot tile = (8*128, 256) fp32 = 1 MiB
NUM_BUCKETS = ref.NUM_BUCKETS


def _interpret_default() -> bool:
    return jax.default_backend() != "tpu"


# ---------------------------------------------------------------------------
# encode (+ fused pseudo-gradient) + histogram
# ---------------------------------------------------------------------------


def _encode_hist_body(scal_ref, x_ref, codes_ref, sums_ref, counts_ref, *,
                      block_rows: int, fused_sub: bool, anchor_ref=None):
    """One grid step: encode a (block_rows, 128) tile and accumulate the
    256-bin histogram via MXU one-hot matmuls."""
    pid = pl.program_id(0)
    lo = scal_ref[0]
    inv_width = scal_ref[1]
    nvalid = scal_ref[2]
    scale = scal_ref[3]

    x = x_ref[...].astype(jnp.float32)
    if fused_sub:
        x = (anchor_ref[...].astype(jnp.float32) - x) * scale

    # global element index of every lane, for masking the tail padding
    row0 = pid * block_rows
    rows = jax.lax.broadcasted_iota(jnp.float32, x.shape, 0) + row0
    cols = jax.lax.broadcasted_iota(jnp.float32, x.shape, 1)
    elem = rows * LANE + cols
    valid = elem < nvalid

    idx = jnp.floor((x - lo) * inv_width)
    idx = jnp.clip(idx, 0.0, float(NUM_BUCKETS - 1))
    codes = jnp.where(valid, idx, 0.0).astype(jnp.int32)
    codes_ref[...] = codes

    # zero the accumulators on the first grid step (TPU grid is sequential)
    @pl.when(pid == 0)
    def _init():
        sums_ref[...] = jnp.zeros_like(sums_ref)
        counts_ref[...] = jnp.zeros_like(counts_ref)

    buckets = jax.lax.broadcasted_iota(
        jnp.int32, (SLAB_ROWS * LANE, NUM_BUCKETS), 1)

    def slab(i, carry):
        s, c = carry
        xs = jax.lax.dynamic_slice(x, (i * SLAB_ROWS, 0), (SLAB_ROWS, LANE))
        cs = jax.lax.dynamic_slice(codes, (i * SLAB_ROWS, 0), (SLAB_ROWS, LANE))
        vs = jax.lax.dynamic_slice(
            valid, (i * SLAB_ROWS, 0), (SLAB_ROWS, LANE))
        oh = (cs.reshape(-1, 1) == buckets).astype(jnp.float32)
        oh = oh * vs.reshape(-1, 1).astype(jnp.float32)
        xf = jnp.where(vs, xs, 0.0).reshape(1, -1)
        s = s + jnp.dot(xf, oh, preferred_element_type=jnp.float32)
        c = c + jnp.sum(oh, axis=0, keepdims=True)
        return s, c

    s0 = jnp.zeros((1, NUM_BUCKETS), jnp.float32)
    c0 = jnp.zeros((1, NUM_BUCKETS), jnp.float32)
    s, c = jax.lax.fori_loop(0, block_rows // SLAB_ROWS, slab, (s0, c0))
    sums_ref[...] += s
    counts_ref[...] += c


def _pad_rows(flat: jnp.ndarray, block_rows: int) -> tuple[jnp.ndarray, int]:
    n = flat.size
    per_block = block_rows * LANE
    nblocks = max(1, -(-n // per_block))
    padded = nblocks * per_block
    flat = jnp.pad(flat, (0, padded - n))
    return flat.reshape(nblocks * block_rows, LANE), nblocks


@functools.partial(
    jax.jit, static_argnames=("block_rows", "fused_sub", "interpret"))
def _encode_hist_call(x_flat, anchor_flat, lo, width, nvalid, scale, *,
                      block_rows: int, fused_sub: bool, interpret: bool):
    x2d, nblocks = _pad_rows(x_flat, block_rows)
    scal = jnp.stack([lo, 1.0 / width, jnp.float32(nvalid), scale])

    in_specs = [
        pl.BlockSpec(memory_space=pltpu.SMEM),
        pl.BlockSpec((block_rows, LANE), lambda i: (i, 0)),
    ]
    args = [scal, x2d]
    kernel = functools.partial(
        _encode_hist_body, block_rows=block_rows, fused_sub=fused_sub)
    if fused_sub:
        a2d, _ = _pad_rows(anchor_flat, block_rows)
        in_specs.append(pl.BlockSpec((block_rows, LANE), lambda i: (i, 0)))
        args.append(a2d)

        def kernel(scal_ref, x_ref, anchor_ref, codes_ref, sums_ref,
                   counts_ref):
            _encode_hist_body(scal_ref, x_ref, codes_ref, sums_ref,
                              counts_ref, block_rows=block_rows,
                              fused_sub=True, anchor_ref=anchor_ref)

    codes2d, sums, counts = pl.pallas_call(
        kernel,
        grid=(nblocks,),
        in_specs=in_specs,
        out_specs=[
            pl.BlockSpec((block_rows, LANE), lambda i: (i, 0)),
            pl.BlockSpec((1, NUM_BUCKETS), lambda i: (0, 0)),
            pl.BlockSpec((1, NUM_BUCKETS), lambda i: (0, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct(x2d.shape, jnp.int32),
            jax.ShapeDtypeStruct((1, NUM_BUCKETS), jnp.float32),
            jax.ShapeDtypeStruct((1, NUM_BUCKETS), jnp.float32),
        ],
        interpret=interpret,
    )(*args)
    return codes2d, sums[0], counts[0]


def encode_hist(x: jnp.ndarray, lo, width, *, block_rows: int = BLOCK_ROWS,
                interpret: bool | None = None):
    """codes (uint8, x.shape) + per-bucket (sums, counts)."""
    if interpret is None:
        interpret = _interpret_default()
    flat = x.astype(jnp.float32).reshape(-1)
    codes2d, sums, counts = _encode_hist_call(
        flat, flat, jnp.float32(lo), jnp.float32(width), flat.size,
        jnp.float32(1.0), block_rows=block_rows, fused_sub=False,
        interpret=interpret)
    codes = codes2d.reshape(-1)[: flat.size].reshape(x.shape)
    return codes.astype(jnp.uint8), sums, counts


def pseudograd_encode_hist(anchor: jnp.ndarray, theta: jnp.ndarray, lo, width,
                           *, scale=None, block_rows: int = BLOCK_ROWS,
                           interpret: bool | None = None):
    """Fused ``scale * (anchor - theta)`` encode: codes + histogram in one
    HBM pass over (anchor, theta) — the pseudo-gradient never hits HBM."""
    if interpret is None:
        interpret = _interpret_default()
    tf = theta.astype(jnp.float32).reshape(-1)
    af = anchor.astype(jnp.float32).reshape(-1)
    codes2d, sums, counts = _encode_hist_call(
        tf, af, jnp.float32(lo), jnp.float32(width), tf.size,
        jnp.float32(1.0 if scale is None else scale),
        block_rows=block_rows, fused_sub=True, interpret=interpret)
    codes = codes2d.reshape(-1)[: tf.size].reshape(theta.shape)
    return codes.astype(jnp.uint8), sums, counts


# ---------------------------------------------------------------------------
# decode (+ fused accumulate)
# ---------------------------------------------------------------------------


def _decode_body(codes_ref, book_ref, out_ref, *, block_rows: int,
                 accumulate: bool, acc_ref=None):
    codes = codes_ref[...].astype(jnp.int32)
    book = book_ref[...].astype(jnp.float32)  # (1, 256)
    buckets = jax.lax.broadcasted_iota(
        jnp.int32, (SLAB_ROWS * LANE, NUM_BUCKETS), 1)

    def slab(i, out):
        cs = jax.lax.dynamic_slice(codes, (i * SLAB_ROWS, 0),
                                   (SLAB_ROWS, LANE))
        oh = (cs.reshape(-1, 1) == buckets).astype(jnp.float32)
        vals = jnp.dot(oh, book.reshape(-1, 1),
                       preferred_element_type=jnp.float32)
        return jax.lax.dynamic_update_slice(
            out, vals.reshape(SLAB_ROWS, LANE), (i * SLAB_ROWS, 0))

    out = jnp.zeros((block_rows, LANE), jnp.float32)
    out = jax.lax.fori_loop(0, block_rows // SLAB_ROWS, slab, out)
    if accumulate:
        out = out + acc_ref[...].astype(jnp.float32)
    out_ref[...] = out


@functools.partial(
    jax.jit, static_argnames=("block_rows", "accumulate", "interpret"))
def _decode_call(codes_flat, codebook, acc_flat, *, block_rows: int,
                 accumulate: bool, interpret: bool):
    c2d, nblocks = _pad_rows(codes_flat.astype(jnp.int32), block_rows)
    book = codebook.astype(jnp.float32).reshape(1, NUM_BUCKETS)
    in_specs = [
        pl.BlockSpec((block_rows, LANE), lambda i: (i, 0)),
        pl.BlockSpec((1, NUM_BUCKETS), lambda i: (0, 0)),
    ]
    args = [c2d, book]
    if accumulate:
        a2d, _ = _pad_rows(acc_flat.astype(jnp.float32), block_rows)
        in_specs.append(pl.BlockSpec((block_rows, LANE), lambda i: (i, 0)))
        args.append(a2d)

        def kernel(codes_ref, book_ref, acc_ref, out_ref):
            _decode_body(codes_ref, book_ref, out_ref,
                         block_rows=block_rows, accumulate=True,
                         acc_ref=acc_ref)
    else:
        kernel = functools.partial(
            _decode_body, block_rows=block_rows, accumulate=False)

    out = pl.pallas_call(
        kernel,
        grid=(nblocks,),
        in_specs=in_specs,
        out_specs=pl.BlockSpec((block_rows, LANE), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct(c2d.shape, jnp.float32),
        interpret=interpret,
    )(*args)
    return out


def decode(codes: jnp.ndarray, codebook: jnp.ndarray, *,
           block_rows: int = BLOCK_ROWS,
           interpret: bool | None = None) -> jnp.ndarray:
    """codebook[codes] as fp32 (one-hot matmul; no vector gather)."""
    if interpret is None:
        interpret = _interpret_default()
    flat = codes.reshape(-1)
    out = _decode_call(flat, codebook, flat, block_rows=block_rows,
                       accumulate=False, interpret=interpret)
    return out.reshape(-1)[: flat.size].reshape(codes.shape)


def decode_add(codes: jnp.ndarray, codebook: jnp.ndarray, acc: jnp.ndarray,
               *, block_rows: int = BLOCK_ROWS,
               interpret: bool | None = None) -> jnp.ndarray:
    """acc + codebook[codes] fused in one VMEM pass (ring accumulator)."""
    if interpret is None:
        interpret = _interpret_default()
    flat = codes.reshape(-1)
    out = _decode_call(flat, codebook, acc.reshape(-1),
                       block_rows=block_rows, accumulate=True,
                       interpret=interpret)
    return out.reshape(-1)[: flat.size].reshape(acc.shape).astype(acc.dtype)
