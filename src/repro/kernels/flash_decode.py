"""Pallas TPU flash-decode: grouped-query single-token attention
against the slotted KV cache.

The serve engine's hot loop is one decode step per live slot against a
(B, S_max, Hk, dh) cache with PER-SLOT lengths. The jnp path
materializes the full (B, Hk, G, 1, S_max) score tensor in HBM and
reads the cache twice (scores, then values). This kernel streams the
cache through VMEM once per (slot, kv-head) in S-blocks with an online
softmax (flash-decoding), carrying (m, l, acc) in VMEM scratch across
the sequential TPU grid — no score tensor ever hits HBM, and the
per-slot length/SWA-ring masking happens on the in-VMEM block.

Design notes:
  * grid = (B, Hk, S_blocks); the innermost S dimension revisits the
    same output block (constant index map), so the fp32 accumulator
    lives in the output ref itself — only m and l need scratch.
  * masks are ONE-HOT-FREE: live cells are found from a broadcasted
    iota of cell indices vs the slot's length (and, for SWA, the ring
    write-cursor arithmetic mirrored from
    ``attention.decode_valid_mask``), never by gathering.
  * q heads are blocked (1, 1, G, dh) and the cache (1, S_BLK, 1, dh):
    the two MXU contractions per block are (G, dh)x(dh, S_BLK) and
    (G, S_BLK)x(S_BLK, dh).
  * dh pads to the 128 lane width, G to the 8-row fp32 sublane tile,
    S to a whole number of blocks — padded cells are masked like any
    dead cell, padded q rows are sliced off on the way out.

Validated against the jnp ``decode_attention`` path in interpret mode
(this container is CPU-only; TPU is the deployment target) — see
tests/test_flash_decode.py. Selection follows the repo convention:
``impl="pallas" | "jnp"`` (ArchConfig.decode_attn_impl).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -1e30
LANE = 128          # TPU lane width: dh pads to a multiple of this
SUBLANE = 8         # fp32 sublane tile: G pads to a multiple of this
S_BLOCK = 256       # KV cells streamed through VMEM per grid step


def _interpret_default() -> bool:
    return jax.default_backend() != "tpu"


def _pad_to(n: int, m: int) -> int:
    return -(-n // m) * m


def _body(len_ref, q_ref, k_ref, v_ref, o_ref, m_ref, l_ref, *,
          s_blk: int, s_max: int, window: int | None, scale: float):
    b = pl.program_id(0)
    s_i = pl.program_id(2)
    ns = pl.num_programs(2)

    @pl.when(s_i == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)
        o_ref[...] = jnp.zeros_like(o_ref)

    q = q_ref[0, 0]              # (G_p, dh_p)
    k = k_ref[0, :, 0, :]        # (S_BLK, dh_p)
    v = v_ref[0, :, 0, :]
    length = len_ref[b]

    # which cells of this block are live for this slot (per-slot
    # length; SWA recovers absolute positions from the ring cursor —
    # same arithmetic as attention.decode_valid_mask)
    cell = s_i * s_blk + jax.lax.broadcasted_iota(
        jnp.int32, (1, s_blk), 1)
    if window is None:
        valid = (cell < length) & (cell < s_max)
    else:
        rem = length % s_max
        abs_pos = jnp.where(
            length > s_max,
            jnp.where(cell < rem, length - rem + cell,
                      length - rem - s_max + cell),
            cell)
        valid = ((abs_pos < length) & (abs_pos >= length - window)
                 & (cell < s_max))

    s = jax.lax.dot_general(
        q, k, dimension_numbers=(((1,), (1,)), ((), ())),
        preferred_element_type=jnp.float32) * scale      # (G_p, S_BLK)
    s = jnp.where(valid, s, NEG_INF)

    m_prev = m_ref[...]                                  # (G_p, 1)
    m_new = jnp.maximum(m_prev, jnp.max(s, axis=-1, keepdims=True))
    p = jnp.exp(s - m_new)
    p = jnp.where(valid, p, 0.0)
    alpha = jnp.exp(m_prev - m_new)                      # (G_p, 1)
    l_ref[...] = l_ref[...] * alpha + jnp.sum(p, axis=-1,
                                              keepdims=True)
    pv = jax.lax.dot_general(
        p.astype(v.dtype), v, dimension_numbers=(((1,), (0,)), ((), ())),
        preferred_element_type=jnp.float32)              # (G_p, dh_p)
    o_ref[0, 0] = o_ref[0, 0] * alpha + pv
    m_ref[...] = m_new

    @pl.when(s_i == ns - 1)
    def _finalize():
        o_ref[0, 0] = o_ref[0, 0] / jnp.maximum(l_ref[...], 1e-30)


@functools.partial(jax.jit, static_argnames=("window", "s_blk",
                                             "interpret"))
def _flash_decode_call(qg, k, v, length, *, window: int | None,
                       s_blk: int, interpret: bool):
    """qg: (B, Hk, G, dh); k/v: (B, S, Hk, dh); length: (B,) int32."""
    b, hk, g, dh = qg.shape
    s_max = k.shape[1]
    g_p = _pad_to(g, SUBLANE)
    dh_p = _pad_to(dh, LANE)
    s_p = _pad_to(s_max, s_blk)
    qg = jnp.pad(qg, ((0, 0), (0, 0), (0, g_p - g), (0, dh_p - dh)))
    k = jnp.pad(k, ((0, 0), (0, s_p - s_max), (0, 0), (0, dh_p - dh)))
    v = jnp.pad(v, ((0, 0), (0, s_p - s_max), (0, 0), (0, dh_p - dh)))

    kernel = functools.partial(_body, s_blk=s_blk, s_max=s_max,
                               window=window, scale=dh ** -0.5)
    out = pl.pallas_call(
        kernel,
        grid=(b, hk, s_p // s_blk),
        in_specs=[
            pl.BlockSpec(memory_space=pltpu.SMEM),
            pl.BlockSpec((1, 1, g_p, dh_p), lambda b, h, s: (b, h, 0, 0)),
            pl.BlockSpec((1, s_blk, 1, dh_p),
                         lambda b, h, s: (b, s, h, 0)),
            pl.BlockSpec((1, s_blk, 1, dh_p),
                         lambda b, h, s: (b, s, h, 0)),
        ],
        out_specs=pl.BlockSpec((1, 1, g_p, dh_p),
                               lambda b, h, s: (b, h, 0, 0)),
        out_shape=jax.ShapeDtypeStruct((b, hk, g_p, dh_p), jnp.float32),
        scratch_shapes=[
            pltpu.VMEM((g_p, 1), jnp.float32),   # running max m
            pltpu.VMEM((g_p, 1), jnp.float32),   # running denom l
        ],
        interpret=interpret,
    )(length.astype(jnp.int32), qg, k, v)
    return out[:, :, :g, :dh]


def _paged_body(tbl_ref, len_ref, q_ref, k_ref, v_ref, o_ref,
                m_ref, l_ref, *, blk: int, s_max: int,
                window: int | None, scale: float):
    """Same online-softmax math as `_body`, but the KV block streamed
    this grid step is whichever PHYSICAL pool block the slot's table
    maps for virtual block s — the gather happens in the BlockSpec
    index map (scalar-prefetched table), so the kernel body only ever
    sees contiguous (blk, dh) tiles. Virtual cell indices (for length /
    SWA-ring masking) are reconstructed from the grid position, which
    also masks trash-block reads (unmapped entries clamp to block 0 but
    their virtual cells are always >= the slot's length)."""
    b = pl.program_id(0)
    s_i = pl.program_id(2)
    ns = pl.num_programs(2)

    @pl.when(s_i == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)
        o_ref[...] = jnp.zeros_like(o_ref)

    q = q_ref[0, 0]              # (G_p, dh_p)
    k = k_ref[0, :, 0, :]        # (blk, dh_p)
    v = v_ref[0, :, 0, :]
    length = len_ref[b]

    cell = s_i * blk + jax.lax.broadcasted_iota(
        jnp.int32, (1, blk), 1)
    if window is None:
        valid = (cell < length) & (cell < s_max)
    else:
        rem = length % s_max
        abs_pos = jnp.where(
            length > s_max,
            jnp.where(cell < rem, length - rem + cell,
                      length - rem - s_max + cell),
            cell)
        valid = ((abs_pos < length) & (abs_pos >= length - window)
                 & (cell < s_max))

    s = jax.lax.dot_general(
        q, k, dimension_numbers=(((1,), (1,)), ((), ())),
        preferred_element_type=jnp.float32) * scale      # (G_p, blk)
    s = jnp.where(valid, s, NEG_INF)

    m_prev = m_ref[...]
    m_new = jnp.maximum(m_prev, jnp.max(s, axis=-1, keepdims=True))
    p = jnp.exp(s - m_new)
    p = jnp.where(valid, p, 0.0)
    alpha = jnp.exp(m_prev - m_new)
    l_ref[...] = l_ref[...] * alpha + jnp.sum(p, axis=-1,
                                              keepdims=True)
    pv = jax.lax.dot_general(
        p.astype(v.dtype), v, dimension_numbers=(((1,), (0,)), ((), ())),
        preferred_element_type=jnp.float32)
    o_ref[0, 0] = o_ref[0, 0] * alpha + pv
    m_ref[...] = m_new

    @pl.when(s_i == ns - 1)
    def _finalize():
        o_ref[0, 0] = o_ref[0, 0] / jnp.maximum(l_ref[...], 1e-30)


@functools.partial(jax.jit, static_argnames=("window", "interpret"))
def _flash_decode_paged_call(qg, k, v, table, length, *,
                             window: int | None, interpret: bool):
    """qg: (B, Hk, G, dh); k/v: (N_blocks, blk, Hk, dh) physical pool;
    table: (B, nb) int32 (-1 = unmapped); length: (B,)."""
    b, hk, g, dh = qg.shape
    blk = k.shape[1]
    nb = table.shape[1]
    s_max = nb * blk
    g_p = _pad_to(g, SUBLANE)
    dh_p = _pad_to(dh, LANE)
    qg = jnp.pad(qg, ((0, 0), (0, 0), (0, g_p - g), (0, dh_p - dh)))
    k = jnp.pad(k, ((0, 0), (0, 0), (0, 0), (0, dh_p - dh)))
    v = jnp.pad(v, ((0, 0), (0, 0), (0, 0), (0, dh_p - dh)))
    tbl = jnp.maximum(table, 0).astype(jnp.int32)   # clamp to trash blk

    kernel = functools.partial(_paged_body, blk=blk, s_max=s_max,
                               window=window, scale=dh ** -0.5)
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=2,
        grid=(b, hk, nb),
        in_specs=[
            pl.BlockSpec((1, 1, g_p, dh_p),
                         lambda b, h, s, tbl, ln: (b, h, 0, 0)),
            # the block-gather stage: virtual block s of slot b streams
            # physical pool block tbl[b, s] through VMEM
            pl.BlockSpec((1, blk, 1, dh_p),
                         lambda b, h, s, tbl, ln: (tbl[b, s], 0, h, 0)),
            pl.BlockSpec((1, blk, 1, dh_p),
                         lambda b, h, s, tbl, ln: (tbl[b, s], 0, h, 0)),
        ],
        out_specs=pl.BlockSpec((1, 1, g_p, dh_p),
                               lambda b, h, s, tbl, ln: (b, h, 0, 0)),
        scratch_shapes=[
            pltpu.VMEM((g_p, 1), jnp.float32),
            pltpu.VMEM((g_p, 1), jnp.float32),
        ],
    )
    out = pl.pallas_call(
        kernel,
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((b, hk, g_p, dh_p), jnp.float32),
        interpret=interpret,
    )(tbl, length.astype(jnp.int32), qg, k, v)
    return out[:, :, :g, :dh]


def flash_decode_paged(q: jnp.ndarray, k: jnp.ndarray, v: jnp.ndarray,
                       table: jnp.ndarray, length: jnp.ndarray, *,
                       window: int | None = None,
                       interpret: bool | None = None) -> jnp.ndarray:
    """Flash-decode against a paged (block-table) KV pool.

    q: (B, 1, Hq, dh); k/v: (N_blocks, blk, Hk, dh); table: (B, nb)
    block ids; length: (B,) per-slot lengths. Bitwise-equivalent to
    `flash_decode` with ``s_blk = blk`` on the dense gathered view
    (identical per-block accumulation order)."""
    if interpret is None:
        interpret = _interpret_default()
    b, t, hq, dh = q.shape
    hk = k.shape[2]
    qg = q.reshape(b, hk, hq // hk, dh)
    out = _flash_decode_paged_call(qg, k, v, table, length,
                                   window=window, interpret=interpret)
    return out.reshape(b, t, hq, dh).astype(q.dtype)


def flash_decode(q: jnp.ndarray, k: jnp.ndarray, v: jnp.ndarray,
                 length: jnp.ndarray, *, window: int | None = None,
                 s_blk: int = S_BLOCK,
                 interpret: bool | None = None) -> jnp.ndarray:
    """Drop-in for the jnp decode_attention body.

    q: (B, 1, Hq, dh); k/v: (B, S_max, Hk, dh); length: (B,) per-slot
    lengths. Returns (B, 1, Hq, dh) in q's dtype."""
    if interpret is None:
        interpret = _interpret_default()
    b, t, hq, dh = q.shape
    hk = k.shape[2]
    qg = q.reshape(b, hk, hq // hk, dh)   # head h = k_head * G + g
    s_blk = min(s_blk, _pad_to(k.shape[1], SUBLANE * 2))
    out = _flash_decode_call(qg, k, v, length, window=window,
                             s_blk=s_blk, interpret=interpret)
    return out.reshape(b, t, hq, dh).astype(q.dtype)
