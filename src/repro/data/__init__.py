from repro.data.pipeline import (INTELLECT1_MIX, DataConfig, SourceSpec,
                                 TokenPipeline)

__all__ = ["DataConfig", "SourceSpec", "TokenPipeline",
           "INTELLECT1_MIX"]
