"""Deterministic, shardable data pipeline with mixture weights and
annealing-phase re-weighting (paper §3.1/3.4, Table 1).

INTELLECT-1 trained on a five-source mixture (FineWeb-Edu 55%, FineWeb
10%, StackV1 20%, DCLM 10%, OpenWebMath 5%), re-weighted for the final
20% (annealing: 80/10/10/0/0). Every DiLoCo worker consumes a disjoint
shard (Alg. 1: data shards D_1..D_k).

This container is offline, so sources are synthetic-but-structured token
streams (per-source Zipf parameters + distinct marker prefixes so tests
can verify mixture ratios and shard disjointness). Everything is
counter-based (stateless RNG): ``batch_at(step)`` is pure, which makes
checkpoint/resume exact and *any* worker able to reproduce any other
worker's batch (needed for the elastic-join path: a joiner replays from
the outer-step boundary).
"""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np


@dataclasses.dataclass(frozen=True)
class SourceSpec:
    name: str
    weight: float              # stable-phase mixture weight
    anneal_weight: float       # annealing-phase weight
    zipf_a: float = 1.2        # token-distribution skew (synthetic)


INTELLECT1_MIX = (
    SourceSpec("fineweb-edu", 0.55, 0.80, 1.10),
    SourceSpec("fineweb", 0.10, 0.10, 1.15),
    SourceSpec("stack-v1", 0.20, 0.10, 1.30),
    SourceSpec("dclm-baseline", 0.10, 0.00, 1.20),
    SourceSpec("openwebmath", 0.05, 0.00, 1.25),
)


@dataclasses.dataclass(frozen=True)
class DataConfig:
    vocab: int
    seq_len: int
    batch_per_worker: int
    sources: tuple = INTELLECT1_MIX
    anneal_start_frac: float = 0.8     # paper: final 20% anneals
    total_steps: int = 10_000
    seed: int = 0


class TokenPipeline:
    """Counter-based synthetic pipeline; one instance per DiLoCo worker.

    ``batch_at(step)`` -> {"tokens", "targets", "mask"} for this
    worker's shard at that step, deterministically.
    """

    def __init__(self, cfg: DataConfig, worker: int, n_workers: int):
        self.cfg = cfg
        self.worker = worker
        self.n_workers = n_workers
        w = np.array([s.weight for s in cfg.sources], np.float64)
        self._w = w / w.sum()
        aw = np.array([s.anneal_weight for s in cfg.sources],
                      np.float64)
        self._aw = aw / max(aw.sum(), 1e-9)

    def mixture_at(self, step: int) -> np.ndarray:
        if step >= self.cfg.anneal_start_frac * self.cfg.total_steps:
            return self._aw
        return self._w

    def _fold(self, *ints) -> jax.Array:
        key = jax.random.PRNGKey(self.cfg.seed)
        for i in ints:
            key = jax.random.fold_in(key, i)
        return key

    def batch_at(self, step: int) -> dict:
        """Pure function of (seed, worker, step): exact resume + any
        worker can replay any shard."""
        cfg = self.cfg
        key = self._fold(self.worker, step)
        ks, kt = jax.random.split(key)
        mix = jnp.asarray(self.mixture_at(step))
        src = jax.random.choice(ks, len(cfg.sources),
                                (cfg.batch_per_worker,), p=mix)
        # per-source Zipf-ish token streams with a source-marker prefix
        zipf_a = jnp.asarray([s.zipf_a for s in cfg.sources])[src]
        u = jax.random.uniform(
            kt, (cfg.batch_per_worker, cfg.seq_len + 1),
            minval=1e-6, maxval=1.0)
        ranks = jnp.floor(u ** (-1.0 / zipf_a[:, None])) % (cfg.vocab - 8)
        tokens = (ranks + 8).astype(jnp.int32)
        tokens = tokens.at[:, 0].set(src.astype(jnp.int32))  # marker
        return {
            "tokens": tokens[:, :-1],
            "targets": tokens[:, 1:],
            "mask": jnp.ones((cfg.batch_per_worker, cfg.seq_len),
                             jnp.float32),
        }

    def state_dict(self) -> dict:
        return {"worker": self.worker, "n_workers": self.n_workers,
                "seed": self.cfg.seed}
