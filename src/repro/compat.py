"""JAX version compatibility shims.

The repo targets the modern public API (``jax.shard_map`` with
``check_vma``/``axis_names``, ``jax.make_mesh(..., axis_types=...)``);
older jax releases (<= 0.4.x, like the one baked into this container)
only ship ``jax.experimental.shard_map.shard_map`` with
``check_rep``/``auto`` and a ``jax.make_mesh`` without ``axis_types``.
Everything in-repo goes through these two wrappers so both API
generations lower to identical programs.
"""
from __future__ import annotations

import inspect

import numpy as np

import jax

_HAS_NEW_SHARD_MAP = hasattr(jax, "shard_map")


def make_mesh(axis_shapes, axis_names, *, devices=None):
    """``jax.make_mesh`` with Auto axis types when supported; manual
    ``Mesh`` construction on jax releases predating ``make_mesh``."""
    axis_shapes, axis_names = tuple(axis_shapes), tuple(axis_names)
    mk = getattr(jax, "make_mesh", None)
    if mk is None:
        devs = np.asarray(devices if devices is not None
                          else jax.devices())
        return jax.sharding.Mesh(
            devs.reshape(axis_shapes), axis_names)
    kwargs = {}
    if devices is not None:
        kwargs["devices"] = devices
    if "axis_types" in inspect.signature(mk).parameters:
        kwargs["axis_types"] = (
            jax.sharding.AxisType.Auto,) * len(axis_names)
    return mk(axis_shapes, axis_names, **kwargs)


def axis_size(axis_name) -> int:
    """Static size of a manual mesh axis (``jax.lax.axis_size`` on new
    jax; ``psum(1, axis)`` — which folds to a python int — on old)."""
    if hasattr(jax.lax, "axis_size"):
        return jax.lax.axis_size(axis_name)
    return jax.lax.psum(1, axis_name)


def shard_map(f, *, mesh, in_specs, out_specs, check_vma: bool = False,
              axis_names=None):
    """Version-portable ``shard_map``.

    ``axis_names`` is the set of MANUAL axes (new-API semantics); on old
    jax it is translated to the complementary ``auto`` set.  ``check_vma``
    maps to the old ``check_rep``.
    """
    if _HAS_NEW_SHARD_MAP:
        kwargs = {"mesh": mesh, "in_specs": in_specs,
                  "out_specs": out_specs, "check_vma": check_vma}
        if axis_names is not None:
            kwargs["axis_names"] = frozenset(axis_names)
        return jax.shard_map(f, **kwargs)
    from jax.experimental.shard_map import shard_map as _sm
    auto = frozenset()
    if axis_names is not None:
        auto = frozenset(mesh.axis_names) - frozenset(axis_names)
    return _sm(f, mesh=mesh, in_specs=in_specs, out_specs=out_specs,
               check_rep=check_vma, auto=auto)
