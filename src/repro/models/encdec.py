"""Encoder-decoder transformer (seamless-m4t-medium backbone).

The speech/text frontend is a STUB per the assignment: ``input_specs()``
provides precomputed frame embeddings (B, L_src, d_model) for the
encoder; the decoder is a standard causal transformer with per-layer
cross-attention into the encoder memory.

Serving: ``prefill`` = encoder forward + cross-K/V computation (done
once, cached); ``decode_step`` = one decoder token (self KV-cache +
static cross cache).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.models import attention as attn
from repro.models import common
from repro.models.common import ParamBuilder
from repro.sharding.act_hints import hint_residual


def _hd(cfg) -> int:
    return cfg.head_dim or cfg.d_model // cfg.n_heads


def _init_attn(b, prefix, cfg, n_kv):
    d, hd = cfg.d_model, _hd(cfg)
    b.add(f"{prefix}/wq", (d, cfg.n_heads * hd), ("embed", "heads"))
    b.add(f"{prefix}/wk", (d, n_kv * hd), ("embed", "heads"))
    b.add(f"{prefix}/wv", (d, n_kv * hd), ("embed", "heads"))
    b.add(f"{prefix}/wo", (cfg.n_heads * hd, d), ("heads", "embed"),
          scale=(cfg.n_heads * hd) ** -0.5)


def _init_mlp(b, prefix, cfg):
    d = cfg.d_model
    b.add(f"{prefix}/gate", (d, cfg.d_ff), ("embed", "ff"))
    b.add(f"{prefix}/up", (d, cfg.d_ff), ("embed", "ff"))
    b.add(f"{prefix}/down", (cfg.d_ff, d), ("ff", "embed"),
          scale=cfg.d_ff ** -0.5)


def _init_enc_layer(cfg, key):
    b = ParamBuilder(key, dtype=cfg.np_dtype)
    b.add("ln_attn", (cfg.d_model,), ("embed",), init="ones")
    _init_attn(b, "attn", cfg, cfg.n_kv_heads)
    b.add("ln_mlp", (cfg.d_model,), ("embed",), init="ones")
    _init_mlp(b, "mlp", cfg)
    return b.params, b.axes


def _init_dec_layer(cfg, key):
    b = ParamBuilder(key, dtype=cfg.np_dtype)
    b.add("ln_self", (cfg.d_model,), ("embed",), init="ones")
    _init_attn(b, "self", cfg, cfg.n_kv_heads)
    b.add("ln_cross", (cfg.d_model,), ("embed",), init="ones")
    _init_attn(b, "cross", cfg, cfg.n_kv_heads)
    b.add("ln_mlp", (cfg.d_model,), ("embed",), init="ones")
    _init_mlp(b, "mlp", cfg)
    return b.params, b.axes


def init_encdec(cfg, key):
    k0, k1, k2, k3 = jax.random.split(key, 4)
    b = ParamBuilder(k0, dtype=cfg.np_dtype)
    b.add("embed", (cfg.padded_vocab, cfg.d_model), ("vocab", "embed"),
          scale=0.02)
    b.add("ln_enc", (cfg.d_model,), ("embed",), init="ones")
    b.add("ln_dec", (cfg.d_model,), ("embed",), init="ones")
    b.add("lm_head", (cfg.d_model, cfg.padded_vocab),
          ("embed", "vocab"))
    params, axes = b.params, b.axes
    n_enc = cfg.n_layers // 2
    n_dec = cfg.n_layers - n_enc
    ek = jax.random.split(k1, n_enc)
    dk = jax.random.split(k2, n_dec)
    params["enc"] = jax.vmap(lambda k: _init_enc_layer(cfg, k)[0])(ek)
    params["dec"] = jax.vmap(lambda k: _init_dec_layer(cfg, k)[0])(dk)
    _, ea = common.eval_axes(functools.partial(_init_enc_layer, cfg), k3)
    _, da = common.eval_axes(functools.partial(_init_dec_layer, cfg), k3)
    axes["enc"] = common.stack_layer_axes(ea)
    axes["dec"] = common.stack_layer_axes(da)
    return params, axes


def _mha(cfg, p, xq, xkv, *, causal, positions_q=None, positions_kv=None):
    hd = _hd(cfg)
    b, sq, _ = xq.shape
    sk = xkv.shape[1]
    q = jnp.einsum("bsd,dh->bsh", xq, p["wq"]).reshape(
        b, sq, cfg.n_heads, hd)
    k = jnp.einsum("bsd,dh->bsh", xkv, p["wk"]).reshape(
        b, sk, cfg.n_kv_heads, hd)
    v = jnp.einsum("bsd,dh->bsh", xkv, p["wv"]).reshape(
        b, sk, cfg.n_kv_heads, hd)
    if positions_q is not None:
        q = common.apply_rope(q, positions_q, cfg.rope_theta)
        k = common.apply_rope(k, positions_kv, cfg.rope_theta)
    o = attn.attention(q, k, v, causal=causal, block_q=cfg.block_q)
    return jnp.einsum("bsh,hd->bsd", o.reshape(b, sq, -1), p["wo"])


def encode(cfg, params, src_embeds, *, remat: bool = False):
    x = src_embeds.astype(cfg.np_dtype)
    b, s, _ = x.shape
    pos = jnp.broadcast_to(jnp.arange(s)[None], (b, s))

    def block(x, p):
        x = hint_residual(x)
        x = x + _mha_self(cfg, p, x, pos, causal=False)
        f = common.swiglu(common.rms_norm(x, p["ln_mlp"], cfg.norm_eps),
                          p["mlp"]["gate"], p["mlp"]["up"],
                          p["mlp"]["down"])
        return x + f, None

    if remat:
        block = jax.checkpoint(block)
    x, _ = jax.lax.scan(block, x, params["enc"])
    return common.rms_norm(x, params["ln_enc"], cfg.norm_eps)


def _mha_self(cfg, p, x, pos, causal):
    h = common.rms_norm(x, p["ln_attn"], cfg.norm_eps)
    return _mha(cfg, p["attn"], h, h, causal=causal,
                positions_q=pos, positions_kv=pos)


def _dec_block(cfg, p, x, memory, pos, *, remat: bool = False):
    x = hint_residual(x)
    h = common.rms_norm(x, p["ln_self"], cfg.norm_eps)
    x = x + _mha(cfg, p["self"], h, h, causal=True,
                 positions_q=pos, positions_kv=pos)
    h = common.rms_norm(x, p["ln_cross"], cfg.norm_eps)
    x = x + _mha(cfg, p["cross"], h, memory, causal=False)
    h = common.rms_norm(x, p["ln_mlp"], cfg.norm_eps)
    return x + common.swiglu(h, p["mlp"]["gate"], p["mlp"]["up"],
                             p["mlp"]["down"])


def loss_fn(cfg, params, batch, *, remat: bool = False):
    memory = encode(cfg, params, batch["src_embeds"], remat=remat)
    x = common.embedding_lookup(params["embed"], batch["tokens"])
    b, s, _ = x.shape
    pos = jnp.broadcast_to(jnp.arange(s)[None], (b, s))

    def block(x, p):
        return _dec_block(cfg, p, x, memory, pos), None

    if remat:
        block = jax.checkpoint(block)
    x, _ = jax.lax.scan(block, x, params["dec"])
    x = common.rms_norm(x, params["ln_dec"], cfg.norm_eps)
    logits = jnp.einsum("bsd,dv->bsv", x, params["lm_head"])
    loss, metrics = common.cross_entropy_max_z(
        logits, batch["targets"], batch.get("mask"),
        z_weight=cfg.max_z_weight)
    return loss, metrics


# -- serving ------------------------------------------------------------------


def init_cache(cfg, batch_size: int, max_len: int, src_len: int):
    hd = _hd(cfg)
    n_dec = cfg.n_layers - cfg.n_layers // 2

    def stack(make):
        return jax.tree.map(lambda *xs: jnp.stack(xs),
                            *[make() for _ in range(n_dec)])

    return {
        "self": stack(lambda: attn.KVCache.init(
            batch_size, max_len, cfg.n_kv_heads, hd, cfg.np_dtype)),
        "cross_k": jnp.zeros((n_dec, batch_size, src_len,
                              cfg.n_kv_heads, hd), cfg.np_dtype),
        "cross_v": jnp.zeros((n_dec, batch_size, src_len,
                              cfg.n_kv_heads, hd), cfg.np_dtype),
        "src_len": jnp.zeros((batch_size,), jnp.int32),   # per slot
    }


def prefill(cfg, params, src_embeds, bos_token, cache):
    """Encode the source, precompute cross-K/V, run the BOS token."""
    memory = encode(cfg, params, src_embeds)
    hd = _hd(cfg)
    b, sl, _ = memory.shape

    def cross_kv(p):
        k = jnp.einsum("bsd,dh->bsh", memory, p["cross"]["wk"]).reshape(
            b, sl, cfg.n_kv_heads, hd)
        v = jnp.einsum("bsd,dh->bsh", memory, p["cross"]["wv"]).reshape(
            b, sl, cfg.n_kv_heads, hd)
        return k, v

    ck, cv = jax.vmap(cross_kv)(params["dec"])  # vmap over layer stack
    cache = dict(cache, cross_k=ck.astype(cfg.np_dtype),
                 cross_v=cv.astype(cfg.np_dtype),
                 src_len=jnp.full((b,), sl, jnp.int32))
    return decode_step(cfg, params, bos_token, cache)


def decode_step(cfg, params, token, cache):
    """One decoder token with self + cross caches."""
    x = common.embedding_lookup(params["embed"], token)
    b = x.shape[0]
    hd = _hd(cfg)
    length = cache["self"].length[0]                   # (B,)
    pos = length[:, None].astype(jnp.int32)

    def body(x, pc):
        p, sc, ck, cv = pc
        # self-attention (cached)
        h = common.rms_norm(x, p["ln_self"], cfg.norm_eps)
        q = jnp.einsum("bsd,dh->bsh", h, p["self"]["wq"]).reshape(
            b, 1, cfg.n_heads, hd)
        k = jnp.einsum("bsd,dh->bsh", h, p["self"]["wk"]).reshape(
            b, 1, cfg.n_kv_heads, hd)
        v = jnp.einsum("bsd,dh->bsh", h, p["self"]["wv"]).reshape(
            b, 1, cfg.n_kv_heads, hd)
        q = common.apply_rope(q, pos, cfg.rope_theta)
        k = common.apply_rope(k, pos, cfg.rope_theta)
        sc = attn.cache_update(sc, k, v)
        o = attn.decode_attention(q, sc, impl=cfg.decode_attn_impl)
        x = x + jnp.einsum("bsh,hd->bsd", o.reshape(b, 1, -1),
                           p["self"]["wo"])
        # cross-attention against the precomputed memory K/V
        h = common.rms_norm(x, p["ln_cross"], cfg.norm_eps)
        q = jnp.einsum("bsd,dh->bsh", h, p["cross"]["wq"]).reshape(
            b, 1, cfg.n_heads, hd)
        cross = attn.KVCache(ck, cv, cache["src_len"])
        o = attn.decode_attention(q, cross, impl=cfg.decode_attn_impl)
        x = x + jnp.einsum("bsh,hd->bsd", o.reshape(b, 1, -1),
                           p["cross"]["wo"])
        h = common.rms_norm(x, p["ln_mlp"], cfg.norm_eps)
        x = x + common.swiglu(h, p["mlp"]["gate"], p["mlp"]["up"],
                              p["mlp"]["down"])
        return x, sc

    x, new_self = jax.lax.scan(
        body, x, (params["dec"], cache["self"],
                  cache["cross_k"], cache["cross_v"]))
    x = common.rms_norm(x, params["ln_dec"], cfg.norm_eps)
    logits = jnp.einsum("bsd,dv->bsv", x, params["lm_head"])[:, 0]
    return logits, dict(cache, self=new_self)
