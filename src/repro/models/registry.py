"""Model registry: a family-uniform interface over the model zoo.

Every architecture exposes:
  * ``init(key) -> (params, logical_axes)``
  * ``loss(params, batch, remat=False) -> (loss, metrics)``
  * ``init_cache(batch_size, shape) -> cache``
  * ``prefill(params, batch, cache) -> (logits, cache)``
  * ``decode(params, token, cache) -> (logits, cache)``
  * ``input_specs(shape) -> batch of ShapeDtypeStructs`` (dry-run)

The dry-run lowers against ``jax.eval_shape`` of these — no allocation.
"""
from __future__ import annotations

import dataclasses
import functools
from typing import Any, Callable

import jax
import jax.numpy as jnp

from repro.configs.base import ArchConfig, ShapeConfig
from repro.models import encdec, hybrid, transformer

# enc-dec decode shapes: one decoder token, cross-attn KV over a source
# of seq_len frames, and a modest self cache (generated audio/text side)
ENCDEC_SELF_CACHE = 4096


@dataclasses.dataclass(frozen=True)
class ModelDef:
    cfg: ArchConfig
    init: Callable[[jax.Array], tuple[Any, Any]]
    loss: Callable[..., tuple[jnp.ndarray, dict]]
    init_cache: Callable[..., Any]
    prefill: Callable[..., tuple[jnp.ndarray, Any]]
    decode: Callable[..., tuple[jnp.ndarray, Any]]
    input_specs: Callable[[ShapeConfig], dict]
    # logits(params, tokens) -> (B, S, V): the bare training-mode
    # forward, for losses that need per-token log-probs instead of the
    # packaged cross-entropy (the RL/GRPO tier). None for families
    # whose forward needs more than tokens (enc-dec).
    logits: Callable[..., jnp.ndarray] | None = None
    # prefill_extend(params, batch, cache) -> (logits, cache): resume
    # prefill at batch["start"] with segment batch["tokens"] /
    # batch["seg_len"] — chunked/paged prefill and shared-prefix
    # resume. None for families without a stable resume offset
    # (SSM/hybrid state folds, enc-dec).
    prefill_extend: Callable[..., tuple[jnp.ndarray, Any]] | None = None

    def cache_pspecs(self, cache_shapes, plan, mesh_axes):
        """PartitionSpec tree for a cache pytree (path-aware: KV caches
        shard batch/heads or seq (SP); SSM states shard batch/heads;
        scalars replicate)."""
        from jax.sharding import PartitionSpec as P

        from repro.sharding.partition import cache_pspec

        def leaf_spec(path, leaf):
            names = [getattr(p, "name", getattr(p, "key", ""))
                     for p in path]
            name = names[-1] if names else ""
            rank = len(leaf.shape)
            if rank == 0 or name in ("length", "src_len"):
                return P()
            if name in ("k", "v") or name.startswith("cross"):
                if rank == 5:    # (L, B, S, Hk, dh)
                    return cache_pspec(leaf.shape, plan, mesh_axes,
                                       batch_dim=1, heads_dim=3,
                                       seq_dim=2)
                if rank == 4:    # (B, S, Hk, dh) unstacked
                    return cache_pspec(leaf.shape, plan, mesh_axes,
                                       batch_dim=0, heads_dim=2,
                                       seq_dim=1)
            if name == "state" and rank == 5:   # (L, B, H, P, N)
                return cache_pspec(leaf.shape, plan, mesh_axes,
                                   batch_dim=1, heads_dim=2,
                                   seq_dim=None)
            if name.startswith("conv") and rank == 4:  # (L, B, K-1, C)
                return cache_pspec(leaf.shape, plan, mesh_axes,
                                   batch_dim=1, heads_dim=3,
                                   seq_dim=None)
            # fallback: shard the batch dim if identifiable
            bdim = 1 if rank >= 3 else 0
            return cache_pspec(leaf.shape, plan, mesh_axes,
                               batch_dim=bdim, heads_dim=None,
                               seq_dim=None)

        return jax.tree_util.tree_map_with_path(leaf_spec, cache_shapes)


def _i32(*shape):
    return jax.ShapeDtypeStruct(shape, jnp.int32)


def _f(cfg, *shape):
    return jax.ShapeDtypeStruct(shape, cfg.np_dtype)


# -- decoder-only families (dense / moe / vlm) --------------------------------


def _lm_input_specs(cfg: ArchConfig, shape: ShapeConfig) -> dict:
    b, s = shape.global_batch, shape.seq_len
    nf = cfg.n_frontend
    if shape.kind == "train":
        specs = {"tokens": _i32(b, s - nf), "targets": _i32(b, s - nf),
                 "mask": jax.ShapeDtypeStruct((b, s - nf), jnp.float32)}
        if nf:
            specs["frontend"] = _f(cfg, b, nf, cfg.d_model)
        return specs
    if shape.kind == "prefill":
        specs = {"tokens": _i32(b, s - nf)}
        if nf:
            specs["frontend"] = _f(cfg, b, nf, cfg.d_model)
        return specs
    return {"token": _i32(b, 1)}     # decode


def _lm_def(cfg: ArchConfig) -> ModelDef:
    def loss(params, batch, remat=False):
        return transformer.loss_fn(cfg, params, batch, remat=remat)

    def init_cache(batch_size, shape: ShapeConfig):
        return transformer.init_cache(cfg, batch_size, shape.seq_len)

    def prefill(params, batch, cache):
        return transformer.prefill(cfg, params, batch["tokens"], cache,
                                   frontend=batch.get("frontend"),
                                   prompt_len=batch.get("prompt_len"))

    def decode(params, token, cache):
        return transformer.decode_step(cfg, params, token, cache)

    def logits(params, tokens, remat=False):
        return transformer.forward(cfg, params, tokens, remat=remat)[0]

    def prefill_extend(params, batch, cache):
        return transformer.prefill_extend(
            cfg, params, batch["tokens"], cache,
            start=batch["start"], seg_len=batch["seg_len"])

    return ModelDef(cfg, functools.partial(transformer.init_lm, cfg),
                    loss, init_cache, prefill, decode,
                    functools.partial(_lm_input_specs, cfg),
                    logits=logits,
                    prefill_extend=(None if cfg.sliding_window
                                    else prefill_extend))


# -- encoder-decoder -----------------------------------------------------------


def _encdec_input_specs(cfg: ArchConfig, shape: ShapeConfig) -> dict:
    b, s = shape.global_batch, shape.seq_len
    if shape.kind == "train":
        half = s // 2
        return {"src_embeds": _f(cfg, b, half, cfg.d_model),
                "tokens": _i32(b, half), "targets": _i32(b, half),
                "mask": jax.ShapeDtypeStruct((b, half), jnp.float32)}
    if shape.kind == "prefill":
        return {"src_embeds": _f(cfg, b, s, cfg.d_model),
                "bos": _i32(b, 1)}
    return {"token": _i32(b, 1)}


def _encdec_def(cfg: ArchConfig) -> ModelDef:
    def loss(params, batch, remat=False):
        return encdec.loss_fn(cfg, params, batch, remat=remat)

    def init_cache(batch_size, shape: ShapeConfig):
        src = shape.seq_len if shape.kind != "train" else \
            shape.seq_len // 2
        return encdec.init_cache(cfg, batch_size,
                                 min(ENCDEC_SELF_CACHE, shape.seq_len),
                                 src)

    def prefill(params, batch, cache):
        return encdec.prefill(cfg, params, batch["src_embeds"],
                              batch["bos"], cache)

    def decode(params, token, cache):
        return encdec.decode_step(cfg, params, token, cache)

    return ModelDef(cfg, functools.partial(encdec.init_encdec, cfg),
                    loss, init_cache, prefill, decode,
                    functools.partial(_encdec_input_specs, cfg))


# -- ssm / hybrid --------------------------------------------------------------


def _hybrid_def(cfg: ArchConfig) -> ModelDef:
    def loss(params, batch, remat=False):
        return hybrid.loss_fn(cfg, params, batch, remat=remat)

    def init_cache(batch_size, shape: ShapeConfig):
        # attention cache bounded by the window for SWA-style reuse;
        # hybrid shared-attn caches hold the full context
        return hybrid.init_cache(cfg, batch_size, shape.seq_len)

    def prefill(params, batch, cache):
        return hybrid.prefill(cfg, params, batch["tokens"], cache,
                              prompt_len=batch.get("prompt_len"))

    def decode(params, token, cache):
        return hybrid.decode_step(cfg, params, token, cache)

    def logits(params, tokens, remat=False):
        return hybrid.forward(cfg, params, tokens, remat=remat)[0]

    return ModelDef(cfg, functools.partial(hybrid.init_hybrid, cfg),
                    loss, init_cache, prefill, decode,
                    functools.partial(_lm_input_specs, cfg),
                    logits=logits)


# -- pipeline-stage partition (swarm serving) ---------------------------------


@dataclasses.dataclass(frozen=True)
class StageDef:
    """One contiguous-layer pipeline stage of a decoder-only LM.

    A stage owns layers ``[lo, hi)`` of the scan stack; the first stage
    additionally owns the embedding (+ any dense-prefix layers), the
    last owns the final norm + LM head. ``slice_params`` extracts the
    stage's parameter subtree from the full tree; ``init_cache``
    allocates the stage-local KV cache; ``prefill``/``decode`` run the
    stage forward (tokens in / logits out at the chain ends, (B, S, D)
    activations in between). Composing all stages in order is
    bit-identical to the monolithic ``ModelDef.prefill``/``decode`` —
    both are wrappers over the same ``stage_prefill``/``stage_decode``.
    """
    cfg: ArchConfig
    index: int
    n_stages: int
    lo: int
    hi: int
    slice_params: Callable[[Any], Any]
    init_cache: Callable[..., Any]
    prefill: Callable[..., tuple[jnp.ndarray, Any]]
    decode: Callable[..., tuple[jnp.ndarray, Any]]

    @property
    def first(self) -> bool:
        return self.index == 0

    @property
    def last(self) -> bool:
        return self.index == self.n_stages - 1


def make_stages(cfg: ArchConfig, k_stages: int) -> list[StageDef]:
    """Partition a decoder-only model into ``k_stages`` pipeline
    stages. Only the transformer families (dense / moe / vlm) have the
    stage seam; other families raise a typed error rather than serving
    garbage."""
    if cfg.family not in ("dense", "moe", "vlm"):
        raise ValueError(
            f"stage partition unsupported for family {cfg.family!r} "
            "(only dense/moe/vlm)")
    bounds = transformer.stage_bounds(cfg, k_stages)
    stages = []
    for i, (lo, hi) in enumerate(bounds):
        first, last = i == 0, i == k_stages - 1

        def slice_params(params, lo=lo, hi=hi, first=first, last=last):
            return transformer.slice_stage_params(
                cfg, params, lo, hi, first=first, last=last)

        def init_cache(batch_size, max_len, lo=lo, hi=hi, first=first):
            return transformer.init_stage_cache(
                cfg, batch_size, max_len, lo, hi, first=first)

        def prefill(params, inp, cache, prompt_len=None,
                    first=first, last=last):
            return transformer.stage_prefill(
                cfg, params, inp, cache, first=first, last=last,
                prompt_len=prompt_len)

        def decode(params, inp, cache, first=first, last=last):
            return transformer.stage_decode(
                cfg, params, inp, cache, first=first, last=last)

        stages.append(StageDef(cfg, i, k_stages, lo, hi, slice_params,
                               init_cache, prefill, decode))
    return stages


def stage_param_specs(cfg: ArchConfig, k_stages: int) -> list:
    """Abstract (ShapeDtypeStruct) parameter tree per stage — the
    ``like`` for restoring published stage weights from a chunk store
    without ever materializing the full model on the restoring host."""
    model = get_model(cfg)
    # init returns (params, logical_axes); the axes tree holds strings,
    # which eval_shape rejects as an output — trace params only
    specs = jax.eval_shape(lambda k: model.init(k)[0],
                           jax.random.PRNGKey(0))
    return [s.slice_params(specs) for s in make_stages(cfg, k_stages)]


def get_model(cfg: ArchConfig) -> ModelDef:
    if cfg.family in ("dense", "moe", "vlm"):
        return _lm_def(cfg)
    if cfg.family == "encdec":
        return _encdec_def(cfg)
    if cfg.family in ("ssm", "hybrid"):
        return _hybrid_def(cfg)
    raise ValueError(f"unknown family {cfg.family!r}")
