"""SSM and hybrid LMs.

* ``mamba_lm``  — pure Mamba2 stack (mamba2-130m): 24 SSD layers, tied
  embeddings, attention-free (long_500k runs with O(1)-per-token state).
* ``zamba_lm``  — Zamba2-style hybrid (zamba2-2.7b): a Mamba2 backbone
  with ONE shared attention+MLP transformer block applied every
  ``attn_every`` layers (9 applications at 54 layers). Simplification vs
  the real Zamba2 (which adds per-application LoRAs on the shared
  block): we share the block verbatim and give each application its own
  input layernorm gain, which is the part that matters for stability.
  Noted in DESIGN.md §Arch-applicability.

Both use stacked layers + lax.scan; the zamba scan is grouped
(outer scan over attention periods, inner scan over the mamba layers of
the group) so the shared block stays un-stacked.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.models import attention as attn
from repro.models import common, ssm
from repro.models.common import ParamBuilder


def _ssm_cfg(cfg) -> ssm.SSMConfig:
    return ssm.SSMConfig(d_model=cfg.d_model, d_state=cfg.ssm.d_state,
                         head_dim=cfg.ssm.head_dim,
                         n_groups=cfg.ssm.n_groups,
                         conv_kernel=cfg.ssm.conv_kernel,
                         expand=cfg.ssm.expand, chunk=cfg.ssm.chunk)


def _init_mamba_layer(cfg, key):
    b = ParamBuilder(key, dtype=cfg.np_dtype)
    b.add("ln", (cfg.d_model,), ("embed",), init="ones")
    ssm.init_mamba2(b, "mamba", _ssm_cfg(cfg))
    return b.params, b.axes


def _init_shared_block(cfg, key, n_apps: int):
    b = ParamBuilder(key, dtype=cfg.np_dtype)
    d, hd = cfg.d_model, cfg.d_model // cfg.n_heads
    b.add("ln_attn", (n_apps, d), (None, "embed"), init="ones")
    b.add("wq", (d, cfg.n_heads * hd), ("embed", "heads"))
    b.add("wk", (d, cfg.n_kv_heads * hd), ("embed", "heads"))
    b.add("wv", (d, cfg.n_kv_heads * hd), ("embed", "heads"))
    b.add("wo", (cfg.n_heads * hd, d), ("heads", "embed"),
          scale=(cfg.n_heads * hd) ** -0.5)
    b.add("ln_mlp", (n_apps, d), (None, "embed"), init="ones")
    b.add("mlp/gate", (d, cfg.d_ff), ("embed", "ff"))
    b.add("mlp/up", (d, cfg.d_ff), ("embed", "ff"))
    b.add("mlp/down", (cfg.d_ff, d), ("ff", "embed"),
          scale=cfg.d_ff ** -0.5)
    return b.params, b.axes


def init_hybrid(cfg, key):
    """Covers both families: cfg.attn_every=None -> pure SSM."""
    k0, k1, k2, k3 = jax.random.split(key, 4)
    b = ParamBuilder(k0, dtype=cfg.np_dtype)
    b.add("embed", (cfg.padded_vocab, cfg.d_model), ("vocab", "embed"),
          scale=0.02)
    b.add("ln_f", (cfg.d_model,), ("embed",), init="ones")
    if not cfg.tie_embeddings:
        b.add("lm_head", (cfg.d_model, cfg.padded_vocab),
              ("embed", "vocab"))
    params, axes = b.params, b.axes
    keys = jax.random.split(k1, cfg.n_layers)
    params["mamba"] = jax.vmap(
        lambda k: _init_mamba_layer(cfg, k)[0])(keys)
    _, ma = common.eval_axes(functools.partial(_init_mamba_layer, cfg), k2)
    axes["mamba"] = common.stack_layer_axes(ma)
    if cfg.attn_every:
        n_apps = cfg.n_layers // cfg.attn_every
        sp, sa = _init_shared_block(cfg, k3, n_apps)
        params["shared"] = sp
        axes["shared"] = sa
    return params, axes


def _shared_attn_apply(cfg, p, x, app_idx, *, positions,
                       layer_cache=None, return_kv=False):
    """One application of the shared transformer block."""
    hd = cfg.d_model // cfg.n_heads
    b, s, _ = x.shape
    h = common.rms_norm(x, p["ln_attn"][app_idx], cfg.norm_eps)
    q = jnp.einsum("bsd,dh->bsh", h, p["wq"]).reshape(
        b, s, cfg.n_heads, hd)
    k = jnp.einsum("bsd,dh->bsh", h, p["wk"]).reshape(
        b, s, cfg.n_kv_heads, hd)
    v = jnp.einsum("bsd,dh->bsh", h, p["wv"]).reshape(
        b, s, cfg.n_kv_heads, hd)
    q = common.apply_rope(q, positions, cfg.rope_theta)
    k = common.apply_rope(k, positions, cfg.rope_theta)
    new_cache = None
    if layer_cache is not None and s == 1:
        new_cache = attn.cache_update(layer_cache, k, v)
        o = attn.decode_attention(q, new_cache,
                                  impl=cfg.decode_attn_impl)
    else:
        o = attn.attention(q, k, v, causal=True, block_q=cfg.block_q)
        if return_kv:
            new_cache = (k, v)
    x = x + jnp.einsum("bsh,hd->bsd", o.reshape(b, s, -1), p["wo"])
    h = common.rms_norm(x, p["ln_mlp"][app_idx], cfg.norm_eps)
    x = x + common.swiglu(h, p["mlp"]["gate"], p["mlp"]["up"],
                          p["mlp"]["down"])
    return x, new_cache


def forward(cfg, params, tokens, *, remat: bool = False,
            collect_state: bool = False, states=None, kv_caches=None,
            prompt_len=None):
    """Training forward (and prefill when collect_state=True).

    ``prompt_len``: (B,) true lengths for right-padded serving prefill
    (threaded into the SSD mask — see ssm.apply_mamba2).
    Returns (logits, (ssm_states, kv_caches) or None)."""
    scfg = _ssm_cfg(cfg)
    x = common.embedding_lookup(params["embed"], tokens)
    b, s, _ = x.shape
    positions = jnp.broadcast_to(jnp.arange(s)[None], (b, s))

    def mamba_block(p, x, st):
        h = common.rms_norm(x, p["ln"], cfg.norm_eps)
        out, new_st = ssm.apply_mamba2(p["mamba"], h, scfg, state=st,
                                       return_state=collect_state,
                                       prompt_len=prompt_len)
        return x + out, new_st

    if remat:
        mamba_block = jax.checkpoint(mamba_block)

    with_state = collect_state or states is not None

    def scan_body(x, inp):
        if with_state:
            p, st = inp
        else:
            p, st = inp, None
        y, new_st = mamba_block(p, x, st)
        return y, new_st

    def scan_xs(p_group, st_group):
        return (p_group, st_group) if with_state else p_group

    if not cfg.attn_every:
        sts = states if states is not None else (
            _dummy_states(cfg, b) if with_state else None)
        x, new_states = jax.lax.scan(scan_body, x,
                                     scan_xs(params["mamba"], sts))
        x = common.rms_norm(x, params["ln_f"], cfg.norm_eps)
        logits = _head(cfg, params, x)
        return logits, (new_states, None)

    # hybrid: groups of `attn_every` mamba layers + one shared-attn app
    ae = cfg.attn_every
    n_apps = cfg.n_layers // ae
    grouped = jax.tree.map(
        lambda a: a.reshape((n_apps, ae) + a.shape[1:]), params["mamba"])
    sts = states if states is not None else (
        _dummy_states(cfg, b) if with_state else None)
    grouped_sts = jax.tree.map(
        lambda a: a.reshape((n_apps, ae) + a.shape[1:]), sts) \
        if with_state else None
    new_states_acc, new_kv_acc = [], []
    for g in range(n_apps):
        gp = jax.tree.map(lambda a: a[g], grouped)
        gs = jax.tree.map(lambda a: a[g], grouped_sts) \
            if with_state else None
        x, g_states = jax.lax.scan(scan_body, x, scan_xs(gp, gs))
        cache_g = None if kv_caches is None else jax.tree.map(
            lambda a: a[g], kv_caches)
        x, kv = _shared_attn_apply(cfg, params["shared"], x, g,
                                   positions=positions,
                                   layer_cache=cache_g,
                                   return_kv=collect_state)
        new_states_acc.append(g_states)
        new_kv_acc.append(kv)
    x = common.rms_norm(x, params["ln_f"], cfg.norm_eps)
    logits = _head(cfg, params, x)
    new_states = None
    if with_state and new_states_acc[0] is not None:
        new_states = jax.tree.map(lambda *xs: jnp.concatenate(xs),
                                  *new_states_acc)
    new_kv = None
    if collect_state and new_kv_acc[0] is not None:
        new_kv = jax.tree.map(lambda *xs: jnp.stack(xs), *new_kv_acc)
    return logits, (new_states, new_kv)


def _dummy_states(cfg, batch):
    """Per-layer zero SSMStates (scan xs); None fields not allowed in
    scan, so always materialize (they are small)."""
    scfg = _ssm_cfg(cfg)
    k = scfg.conv_kernel
    gn = scfg.n_groups * scfg.d_state

    def one():
        return ssm.SSMState(
            jnp.zeros((batch, scfg.n_heads, scfg.head_dim,
                       scfg.d_state), jnp.float32),
            jnp.zeros((batch, k - 1, scfg.d_inner), cfg.np_dtype),
            jnp.zeros((batch, k - 1, gn), cfg.np_dtype),
            jnp.zeros((batch, k - 1, gn), cfg.np_dtype))

    return jax.tree.map(lambda *xs: jnp.stack(xs),
                        *[one() for _ in range(cfg.n_layers)])


def _head(cfg, params, x):
    head = (params["embed"].T if cfg.tie_embeddings
            else params["lm_head"])
    return jnp.einsum("bsd,dv->bsv", x, head)


def loss_fn(cfg, params, batch, *, remat: bool = False):
    logits, _ = forward(cfg, params, batch["tokens"], remat=remat)
    loss, metrics = common.cross_entropy_max_z(
        logits, batch["targets"], batch.get("mask"),
        z_weight=cfg.max_z_weight)
    return loss, metrics


# -- serving ------------------------------------------------------------------


def init_cache(cfg, batch_size: int, max_len: int):
    cache = {"ssm": _dummy_states(cfg, batch_size), "kv": None}
    if cfg.attn_every:
        n_apps = cfg.n_layers // cfg.attn_every
        hd = cfg.d_model // cfg.n_heads
        cache["kv"] = jax.tree.map(
            lambda *xs: jnp.stack(xs),
            *[attn.KVCache.init(batch_size, max_len, cfg.n_kv_heads,
                                hd, cfg.np_dtype)
              for _ in range(n_apps)])
    return cache


def prefill(cfg, params, tokens, cache, *, prompt_len=None):
    logits, (states, kvs) = forward(cfg, params, tokens,
                                    collect_state=True,
                                    prompt_len=prompt_len)
    new_kv = cache["kv"]
    if kvs is not None:
        k_new, v_new = kvs  # stacked (n_apps, B, S, Hk, hd)

        def write(c, k, v):
            new = attn.cache_update(c, k, v)
            if prompt_len is not None:
                new = new._replace(length=jnp.broadcast_to(
                    prompt_len.astype(jnp.int32), new.length.shape))
            return new

        new_kv = jax.vmap(write, in_axes=(0, 0, 0))(cache["kv"], k_new,
                                                    v_new)
    if prompt_len is None:
        last = logits[:, -1]
    else:
        idx = (prompt_len.astype(jnp.int32) - 1)[:, None, None]
        last = jnp.take_along_axis(logits, idx, axis=1)[:, 0]
    return last, {"ssm": states, "kv": new_kv}


def decode_step(cfg, params, token, cache):
    """One-token step: recurrent SSM updates + cached shared attention.
    Positions come from the per-slot cache lengths."""
    scfg = _ssm_cfg(cfg)
    x = common.embedding_lookup(params["embed"], token)
    b = x.shape[0]

    def scan_body(x, inp):
        p, st = inp
        h = common.rms_norm(x, p["ln"], cfg.norm_eps)
        out, new_st = ssm.decode_mamba2(p["mamba"], h, scfg, st)
        return x + out, new_st

    if not cfg.attn_every:
        x, new_states = jax.lax.scan(scan_body, x,
                                     (params["mamba"], cache["ssm"]))
        x = common.rms_norm(x, params["ln_f"], cfg.norm_eps)
        return _head(cfg, params, x)[:, 0], dict(cache, ssm=new_states)

    ae = cfg.attn_every
    n_apps = cfg.n_layers // ae
    length = cache["kv"].length[0]                   # (B,)
    positions = length[:, None].astype(jnp.int32)
    grouped = jax.tree.map(
        lambda a: a.reshape((n_apps, ae) + a.shape[1:]), params["mamba"])
    grouped_sts = jax.tree.map(
        lambda a: a.reshape((n_apps, ae) + a.shape[1:]), cache["ssm"])
    new_states_acc, new_kv_acc = [], []
    for g in range(n_apps):
        gp = jax.tree.map(lambda a: a[g], grouped)
        gs = jax.tree.map(lambda a: a[g], grouped_sts)
        x, g_states = jax.lax.scan(scan_body, x, (gp, gs))
        cache_g = jax.tree.map(lambda a: a[g], cache["kv"])
        x, kv = _shared_attn_apply(cfg, params["shared"], x, g,
                                   positions=positions,
                                   layer_cache=cache_g)
        new_states_acc.append(g_states)
        new_kv_acc.append(kv)
    x = common.rms_norm(x, params["ln_f"], cfg.norm_eps)
    new_states = jax.tree.map(lambda *xs: jnp.concatenate(xs),
                              *new_states_acc)
    new_kv = jax.tree.map(lambda *xs: jnp.stack(xs), *new_kv_acc)
    return _head(cfg, params, x)[:, 0], {"ssm": new_states,
                                         "kv": new_kv}
