"""Mixture-of-Experts FFN (deepseek-moe-16b: 2 shared + 64 routed top-6;
dbrx-132b: 16 routed top-4).

Dispatch is capacity-based gather/scatter with static shapes (GShard-
style token dropping), which is the TPU-friendly formulation:

  1. router softmax -> top-k experts per token;
  2. per expert, take the top-C tokens by gate score (C = capacity);
  3. gather those tokens -> (E, C, d), run the expert SwiGLU as a
     batched einsum whose leading dim shards over the EP mesh axis;
  4. scatter-add weighted outputs back.

FLOPs scale with C*E = capacity_factor * (active tokens) — i.e. with the
ACTIVE parameter count, not the total (important for the roofline's
MODEL_FLOPS/HLO_FLOPs ratio). Shared experts (DeepSeek-MoE) are a plain
dense SwiGLU on every token.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.models import common


def _constrain_ep(x, *spec_attempts):
    """Best-effort sharding constraint with graceful fallback: specs
    are tried in order; axes that are manual in the enclosing shard_map
    region or missing from the ambient mesh make an attempt fail."""
    for spec in spec_attempts:
        try:
            return jax.lax.with_sharding_constraint(x, spec)
        except Exception:
            continue
    return x


def init_moe(b: common.ParamBuilder, prefix: str, d_model: int,
             d_expert: int, n_experts: int, n_shared: int) -> None:
    b.add(f"{prefix}/router", (d_model, n_experts), ("embed", None),
          scale=d_model ** -0.5)
    for nm in ("gate", "up"):
        b.add(f"{prefix}/experts/{nm}", (n_experts, d_model, d_expert),
              ("experts", "embed", "ff"))
    b.add(f"{prefix}/experts/down", (n_experts, d_expert, d_model),
          ("experts", "ff", "embed"), scale=d_expert ** -0.5)
    if n_shared:
        for nm in ("gate", "up"):
            b.add(f"{prefix}/shared/{nm}", (d_model, n_shared * d_expert),
                  ("embed", "ff"))
        b.add(f"{prefix}/shared/down", (n_shared * d_expert, d_model),
              ("ff", "embed"), scale=(n_shared * d_expert) ** -0.5)


TOKEN_BLOCK = 65536


def apply_moe(p, x: jnp.ndarray, *, top_k: int,
              capacity_factor: float = 1.25,
              full_capacity: bool = False):
    """x: (B, S, d) -> (B, S, d), aux metrics dict.

    Tokens are processed in blocks of <= TOKEN_BLOCK (GShard 'group'
    semantics: capacity applies per block). This bounds the peak memory
    of the dispatch structurally: XLA's SPMD strategy for the
    token-gather is an operand all-gather, which on a 0.5M-token pod
    batch would materialize the full (T, d) stream on every device —
    per-block it is a few hundred MB.

    ``full_capacity``: capacity = all tokens (no drops). The serving
    paths set this so capacity contention never couples slots: with
    fractional capacity a garbage token from a retired slot could evict
    a live slot's token, making outputs depend on batch composition."""
    bsz, seq, d = x.shape
    t = bsz * seq
    xf = x.reshape(t, d)
    n_experts = p["router"].shape[1]

    if t > TOKEN_BLOCK and t % TOKEN_BLOCK == 0:
        nb = t // TOKEN_BLOCK
        blocks = xf.reshape(nb, TOKEN_BLOCK, d)

        def body(lb_acc, xb):
            yb, aux_b = _moe_block(p, xb, top_k=top_k,
                                   capacity_factor=capacity_factor,
                                   full_capacity=full_capacity)
            return lb_acc + aux_b["lb_loss"], (yb, aux_b["dropped_frac"])

        lb, (ys, dropped) = jax.lax.scan(
            body, jnp.zeros((), jnp.float32), blocks)
        out = ys.reshape(bsz, seq, d)
        return out, {"lb_loss": lb / nb,
                     "dropped_frac": jnp.mean(dropped)}

    out, aux = _moe_block(p, xf, top_k=top_k,
                          capacity_factor=capacity_factor,
                          full_capacity=full_capacity)
    return out.reshape(bsz, seq, d), aux


def _moe_block(p, xf: jnp.ndarray, *, top_k: int,
               capacity_factor: float, full_capacity: bool = False):
    """One token block: (T, d) -> (T, d), aux."""
    t, d = xf.shape
    n_experts = p["router"].shape[1]

    logits = jnp.einsum("td,de->te", xf, p["router"]).astype(jnp.float32)
    probs = jax.nn.softmax(logits, axis=-1)
    gate_vals, gate_idx = jax.lax.top_k(probs, top_k)          # (T, k)
    gate_vals = gate_vals / jnp.sum(gate_vals, -1, keepdims=True)

    # score of each (token, expert): gate if selected else 0
    sel = jnp.zeros((t, n_experts), jnp.float32).at[
        jnp.arange(t)[:, None], gate_idx].set(gate_vals)

    if full_capacity or t <= 64:
        # serving / decode / tiny batches: full capacity (no drops) — a
        # fractional capacity would drop tokens based on what the OTHER
        # slots in the batch routed, breaking per-slot isolation
        capacity = t
    else:
        capacity = max(1, int(capacity_factor * top_k * t / n_experts))
        capacity = min(capacity, t)
    # per-expert top-C tokens by gate score  -> (E, C)
    scores_e = sel.T                                            # (E, T)
    top_scores, top_tokens = jax.lax.top_k(scores_e, capacity)  # (E, C)
    keep = top_scores > 0.0

    from jax.sharding import PartitionSpec as P

    xe_flat = jnp.take(xf, top_tokens.reshape(-1), axis=0)
    # constrain the (E*C, d) gather BEFORE the reshape — otherwise XLA
    # may materialize it replicated (E-major merged dim shards cleanly
    # over ('model','data'))
    xe_flat = _constrain_ep(xe_flat, P(("model", "data"), None),
                            P(("model",), None))
    xe = xe_flat.reshape(n_experts, capacity, d)
    # expert-parallel layout: experts over 'model', capacity over the
    # data axes (no-op when the mesh/axes are unavailable)
    xe = _constrain_ep(xe, P("model", "data", None),
                       P("model", None, None))
    g = jnp.einsum("ecd,edf->ecf", xe, p["experts"]["gate"])
    u = jnp.einsum("ecd,edf->ecf", xe, p["experts"]["up"])
    ye = jnp.einsum("ecf,efd->ecd", jax.nn.silu(g) * u,
                    p["experts"]["down"])
    ye = _constrain_ep(ye, P("model", "data", None),
                       P("model", None, None))
    w = (top_scores * keep).astype(ye.dtype)[..., None]         # (E, C, 1)
    upd = _constrain_ep((ye * w).reshape(-1, d),
                        P(("model", "data"), None),
                        P(("model",), None))
    out = jnp.zeros((t, d), ye.dtype).at[
        top_tokens.reshape(-1)].add(upd)
    # token dim = merged (batch x seq): keep the combined sharding when
    # the batch is data-sharded and the seq dim SP-sharded over 'model'
    out = _constrain_ep(out, P(("data", "model"), None),
                        P("data", None))

    if "shared" in p:
        out = out + common.swiglu(xf, p["shared"]["gate"],
                                  p["shared"]["up"],
                                  p["shared"]["down"]).astype(out.dtype)

    # load-balance auxiliaries (Switch-style)
    me = probs.mean(0)                                          # (E,)
    ce = (sel > 0).astype(jnp.float32).mean(0) * n_experts / top_k
    aux = {"lb_loss": n_experts * jnp.sum(me * ce),
           "dropped_frac": 1.0 - keep.sum() / (t * top_k)}
    return out.astype(xf.dtype), aux
