"""Shared model substrate: parameter builder with logical sharding axes,
norms, RoPE, SwiGLU. Pure JAX (no flax) — params are nested dicts of
arrays; every init has a parallel tree of *logical axis names* consumed
by ``sharding.partition`` to derive PartitionSpecs.

Logical axis vocabulary:
  'vocab'   — embedding rows            (TP: sharded over model axis)
  'embed'   — the d_model dim           (FSDP candidate)
  'heads'   — attention head-dim products (TP)
  'ff'      — MLP hidden                (TP)
  'experts' — MoE expert dim            (EP)
  'layers'  — stacked-layer leading dim (never sharded; lax.scan)
  None      — replicated
"""
from __future__ import annotations

import dataclasses
from typing import Any

import jax
import jax.numpy as jnp

Params = Any
Axes = Any


@dataclasses.dataclass
class ParamBuilder:
    """Builds a params tree and its logical-axes twin in lockstep."""

    key: jax.Array
    dtype: Any = jnp.float32
    params: dict = dataclasses.field(default_factory=dict)
    axes: dict = dataclasses.field(default_factory=dict)

    def _split(self) -> jax.Array:
        self.key, sub = jax.random.split(self.key)
        return sub

    def add(self, path: str, shape, axes, *, init: str = "normal",
            scale: float | None = None, dtype=None):
        """Register one parameter. ``path`` is '/'-separated."""
        dtype = dtype or self.dtype
        if init == "zeros":
            val = jnp.zeros(shape, dtype)
        elif init == "ones":
            val = jnp.ones(shape, dtype)
        else:
            fan_in = shape[0] if len(shape) > 1 else shape[-1]
            s = scale if scale is not None else fan_in ** -0.5
            val = (jax.random.normal(self._split(), shape, jnp.float32)
                   * s).astype(dtype)
        assert len(axes) == len(shape), (path, shape, axes)
        d_p, d_a = self.params, self.axes
        parts = path.split("/")
        for p in parts[:-1]:
            d_p = d_p.setdefault(p, {})
            d_a = d_a.setdefault(p, {})
        d_p[parts[-1]] = val
        d_a[parts[-1]] = tuple(axes)
        return val

def eval_axes(init_fn, key):
    """Logical-axes tree of an ``init_fn(key) -> (params, axes)`` without
    allocating: runs it under eval_shape and captures the axes side
    channel (axes are plain python, invisible to tracing)."""
    cell = {}

    def wrapper(k):
        p, a = init_fn(k)
        cell["axes"] = a
        return p

    shapes = jax.eval_shape(wrapper, key)
    return shapes, cell["axes"]


def stack_layer_params(per_layer: list[Params]) -> Params:
    """Stack identical per-layer trees along a leading 'layers' dim."""
    return jax.tree.map(lambda *xs: jnp.stack(xs), *per_layer)


def stack_layer_axes(axes: Axes) -> Axes:
    return jax.tree.map(lambda a: ("layers",) + tuple(a), axes,
                        is_leaf=lambda x: isinstance(x, tuple))


# -- layers -------------------------------------------------------------------


def rms_norm(x: jnp.ndarray, gamma: jnp.ndarray,
             eps: float = 1e-5) -> jnp.ndarray:
    dt = x.dtype
    xf = x.astype(jnp.float32)
    var = jnp.mean(xf * xf, axis=-1, keepdims=True)
    return (xf * jax.lax.rsqrt(var + eps) * gamma.astype(jnp.float32)
            ).astype(dt)


def swiglu(x: jnp.ndarray, w_gate: jnp.ndarray, w_up: jnp.ndarray,
           w_down: jnp.ndarray) -> jnp.ndarray:
    g = jnp.einsum("...d,df->...f", x, w_gate)
    u = jnp.einsum("...d,df->...f", x, w_up)
    return jnp.einsum("...f,fd->...d", jax.nn.silu(g) * u, w_down)


def rope_frequencies(head_dim: int, theta: float = 1e4) -> jnp.ndarray:
    return 1.0 / (theta ** (jnp.arange(0, head_dim, 2,
                                       dtype=jnp.float32) / head_dim))


def apply_rope(x: jnp.ndarray, positions: jnp.ndarray,
               theta: float = 1e4) -> jnp.ndarray:
    """x: (..., S, H, dh); positions: (..., S) int32."""
    dh = x.shape[-1]
    freqs = rope_frequencies(dh, theta)                      # (dh/2,)
    ang = positions[..., None].astype(jnp.float32) * freqs   # (..., S, dh/2)
    cos, sin = jnp.cos(ang)[..., None, :], jnp.sin(ang)[..., None, :]
    x1, x2 = x[..., : dh // 2], x[..., dh // 2:]
    xf1, xf2 = x1.astype(jnp.float32), x2.astype(jnp.float32)
    return jnp.concatenate(
        [xf1 * cos - xf2 * sin, xf2 * cos + xf1 * sin],
        axis=-1).astype(x.dtype)


def embedding_lookup(table: jnp.ndarray, ids: jnp.ndarray) -> jnp.ndarray:
    """One-hot-free gather; XLA shards it fine over a vocab-sharded table."""
    return jnp.take(table, ids, axis=0)


def cross_entropy_max_z(logits: jnp.ndarray, targets: jnp.ndarray,
                        mask: jnp.ndarray | None = None,
                        z_weight: float = 2e-4):
    """CE + auxiliary max-z loss (paper: Yang et al. 2023, weight 2e-4).

    logits: (..., V) fp32-upcast internally; targets int ids; mask 0/1.
    Returns (loss, metrics dict)."""
    lf = logits.astype(jnp.float32)
    lse = jax.nn.logsumexp(lf, axis=-1)
    ll = jnp.take_along_axis(lf, targets[..., None], axis=-1)[..., 0]
    ce = lse - ll
    z = z_weight * lse * lse
    tok = ce + z
    if mask is None:
        mask = jnp.ones(tok.shape, jnp.float32)
    mask = mask.astype(jnp.float32)
    denom = jnp.maximum(mask.sum(), 1.0)
    loss = (tok * mask).sum() / denom
    ce_mean = (ce * mask).sum() / denom
    return loss, {"ce": ce_mean, "z": (z * mask).sum() / denom,
                  "loss": loss}
