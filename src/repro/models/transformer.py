"""Decoder-only transformer LM (Llama-3-like, the paper's own family):
RMSNorm pre-norm blocks, GQA attention with RoPE, SwiGLU or MoE FFN,
optional sliding-window attention and multimodal prefix embeddings.

Layers are *stacked* (leading 'layers' dim) and applied with lax.scan —
essential to keep XLA compile time sane at 512 devices x 40+ layers.
Optional leading dense layers (DeepSeek-MoE's first-layer-dense) are
kept unstacked.
"""
from __future__ import annotations

import functools
from typing import Any

import jax
import jax.numpy as jnp

from repro.models import attention as attn
from repro.models import common, moe
from repro.models.common import ParamBuilder
from repro.sharding.act_hints import hint_residual


def _head_dim(cfg) -> int:
    return cfg.head_dim or cfg.d_model // cfg.n_heads


def init_layer(cfg, key, is_moe: bool):
    b = ParamBuilder(key, dtype=cfg.np_dtype)
    d, hd = cfg.d_model, _head_dim(cfg)
    b.add("ln_attn", (d,), ("embed",), init="ones")
    b.add("wq", (d, cfg.n_heads * hd), ("embed", "heads"))
    b.add("wk", (d, cfg.n_kv_heads * hd), ("embed", "heads"))
    b.add("wv", (d, cfg.n_kv_heads * hd), ("embed", "heads"))
    b.add("wo", (cfg.n_heads * hd, d), ("heads", "embed"),
          scale=(cfg.n_heads * hd) ** -0.5)
    b.add("ln_mlp", (d,), ("embed",), init="ones")
    if is_moe:
        moe.init_moe(b, "moe", d, cfg.moe.d_expert, cfg.moe.n_experts,
                     cfg.moe.n_shared)
    else:
        b.add("mlp/gate", (d, cfg.d_ff), ("embed", "ff"))
        b.add("mlp/up", (d, cfg.d_ff), ("embed", "ff"))
        b.add("mlp/down", (cfg.d_ff, d), ("ff", "embed"),
              scale=cfg.d_ff ** -0.5)
    return b.params, b.axes


def init_lm(cfg, key):
    """Returns (params, logical_axes)."""
    ke, kl, kh, kp = jax.random.split(key, 4)
    b = ParamBuilder(ke, dtype=cfg.np_dtype)
    b.add("embed", (cfg.padded_vocab, cfg.d_model), ("vocab", "embed"),
          scale=0.02)
    b.add("ln_f", (cfg.d_model,), ("embed",), init="ones")
    if not cfg.tie_embeddings:
        b.add("lm_head", (cfg.d_model, cfg.padded_vocab),
              ("embed", "vocab"))
    params, axes = b.params, b.axes

    n_dense_prefix = cfg.moe.first_dense if cfg.moe else 0
    n_scan = cfg.n_layers - n_dense_prefix
    keys = jax.random.split(kl, n_scan)
    layer_p = jax.vmap(
        lambda k: init_layer(cfg, k, is_moe=cfg.moe is not None)[0])(keys)
    _, layer_axes = common.eval_axes(
        lambda k: init_layer(cfg, k, is_moe=cfg.moe is not None), kh)
    params["layers"] = layer_p
    axes["layers"] = common.stack_layer_axes(layer_axes)
    if n_dense_prefix:
        pk = jax.random.split(kp, n_dense_prefix)
        for i in range(n_dense_prefix):
            pp, pa = init_layer(cfg, pk[i], is_moe=False)
            params[f"dense{i}"] = pp
            axes[f"dense{i}"] = pa
    return params, axes


# -- forward ------------------------------------------------------------------


def _attn_block(cfg, p, x, *, positions, layer_cache=None,
                cache_update_rolling=False, window, return_kv=False):
    """Self-attention sublayer. Returns (out, new_cache_or_kv)."""
    hd = _head_dim(cfg)
    h = common.rms_norm(x, p["ln_attn"], cfg.norm_eps)
    b, s, _ = h.shape
    q = jnp.einsum("bsd,dh->bsh", h, p["wq"]).reshape(
        b, s, cfg.n_heads, hd)
    k = jnp.einsum("bsd,dh->bsh", h, p["wk"]).reshape(
        b, s, cfg.n_kv_heads, hd)
    v = jnp.einsum("bsd,dh->bsh", h, p["wv"]).reshape(
        b, s, cfg.n_kv_heads, hd)
    q = common.apply_rope(q, positions, cfg.rope_theta)
    k = common.apply_rope(k, positions, cfg.rope_theta)

    if layer_cache is not None and s == 1:      # decode
        cache = attn.cache_update(layer_cache, k, v,
                                  rolling=cache_update_rolling)
        o = attn.decode_attention(q, cache, window=window,
                                  impl=cfg.decode_attn_impl)
        new = cache
    else:                                        # train / prefill
        o = attn.attention(q, k, v, causal=True, window=window,
                           block_q=cfg.block_q)
        new = (k, v) if return_kv else None
    o = o.reshape(b, s, cfg.n_heads * hd)
    return jnp.einsum("bsh,hd->bsd", o, p["wo"]), new


def _ffn_block(cfg, p, x, is_moe: bool, serving: bool = False):
    h = common.rms_norm(x, p["ln_mlp"], cfg.norm_eps)
    if is_moe:
        out, aux = moe.apply_moe(p["moe"], h, top_k=cfg.moe.top_k,
                                 capacity_factor=cfg.moe.capacity_factor,
                                 full_capacity=serving)
        return out, aux
    return common.swiglu(h, p["mlp"]["gate"], p["mlp"]["up"],
                         p["mlp"]["down"]), {}


def _layer(cfg, p, x, *, positions, is_moe, layer_cache=None,
           rolling=False, return_kv=False, serving=False):
    x = hint_residual(x)
    a, new_cache = _attn_block(
        cfg, p, x, positions=positions, layer_cache=layer_cache,
        cache_update_rolling=rolling, window=cfg.sliding_window,
        return_kv=return_kv)
    x = hint_residual(x + a)
    f, aux = _ffn_block(cfg, p, x, is_moe, serving=serving)
    return hint_residual(x + f), new_cache, aux


def forward(cfg, params, tokens, *, frontend=None, positions=None,
            remat: bool = False):
    """Training/scoring forward -> (logits, aux).

    ``frontend``: optional (B, F, d_model) stub embeddings (VLM/audio)
    prepended to the token embeddings."""
    x = common.embedding_lookup(params["embed"], tokens)
    if frontend is not None:
        x = jnp.concatenate([frontend.astype(x.dtype), x], axis=1)
    b, s, _ = x.shape
    if positions is None:
        positions = jnp.broadcast_to(jnp.arange(s)[None], (b, s))

    is_moe = cfg.moe is not None

    def dense_block(p, x):
        y, _, aux = _layer(cfg, p, x, positions=positions, is_moe=False)
        return y, aux

    def scan_block(p, x):
        y, _, aux = _layer(cfg, p, x, positions=positions, is_moe=is_moe)
        return y, aux

    if remat:
        dense_block = jax.checkpoint(dense_block)
        scan_block = jax.checkpoint(scan_block)

    aux_acc = {}
    n_dense_prefix = cfg.moe.first_dense if is_moe else 0
    for i in range(n_dense_prefix):
        x, _ = dense_block(params[f"dense{i}"], x)

    def body(x, p):
        y, aux = scan_block(p, x)
        return y, aux.get("lb_loss", jnp.zeros((), jnp.float32))

    x, lb = jax.lax.scan(body, x, params["layers"])
    aux_acc["lb_loss"] = jnp.sum(lb)

    x = common.rms_norm(x, params["ln_f"], cfg.norm_eps)
    head = (params["embed"].T if cfg.tie_embeddings
            else params["lm_head"])
    logits = jnp.einsum("bsd,dv->bsv", x, head)
    return logits, aux_acc


def loss_fn(cfg, params, batch, *, remat: bool = False):
    logits, aux = forward(cfg, params, batch["tokens"],
                          frontend=batch.get("frontend"), remat=remat)
    n_front = 0 if batch.get("frontend") is None \
        else batch["frontend"].shape[1]
    logits = logits[:, n_front:]
    loss, metrics = common.cross_entropy_max_z(
        logits, batch["targets"], batch.get("mask"),
        z_weight=cfg.max_z_weight)
    if cfg.moe is not None:
        loss = loss + cfg.moe.lb_weight * aux["lb_loss"]
        metrics["lb_loss"] = aux["lb_loss"]
    metrics["loss"] = loss
    return loss, metrics


# -- serving ------------------------------------------------------------------
#
# The serving forward is organized around a STAGE-PARTITION seam: the
# scan-stacked layer block splits into K contiguous-layer stages, each
# with its own params slice and KV-cache slice, so a model can be
# served by a chain of machines (see ``serving/swarm_serve.py``). The
# single-host path is the K=1 specialization — ``prefill`` /
# ``decode_step`` are thin wrappers over ``stage_prefill`` /
# ``stage_decode`` with ``first=last=True``, so staged and monolithic
# serving share every op (bit-identical by construction).


def n_scan_layers(cfg) -> int:
    return cfg.n_layers - (cfg.moe.first_dense if cfg.moe else 0)


def stage_bounds(cfg, k_stages: int) -> list[tuple[int, int]]:
    """Contiguous partition of the scan-stacked layers into
    ``k_stages`` near-equal [lo, hi) ranges (remainder spread over the
    leading stages). Dense-prefix layers (DeepSeek first-layer-dense)
    ride with stage 0."""
    n = n_scan_layers(cfg)
    if not 1 <= k_stages <= n:
        raise ValueError(f"k_stages {k_stages} not in [1, {n}]")
    base, rem = divmod(n, k_stages)
    bounds, lo = [], 0
    for i in range(k_stages):
        hi = lo + base + (1 if i < rem else 0)
        bounds.append((lo, hi))
        lo = hi
    return bounds


def _slice_rows(leaf, lo: int, hi: int):
    if isinstance(leaf, jax.ShapeDtypeStruct):
        # abstract trees (jax.eval_shape) slice too, so a stage's
        # parameter STRUCTURE is available without materializing the
        # full model (the `like` for restoring published stage weights)
        return jax.ShapeDtypeStruct((hi - lo,) + tuple(leaf.shape[1:]),
                                    leaf.dtype)
    return leaf[lo:hi]


def slice_stage_params(cfg, params, lo: int, hi: int, *, first: bool,
                       last: bool):
    """The parameter subtree one stage needs: its layer-stack rows,
    plus the embedding (+ dense prefix) on the first stage and the
    final norm + head on the last (tied embeddings put the embedding
    matrix on the last stage too)."""
    sp = {"layers": jax.tree.map(lambda l: _slice_rows(l, lo, hi),
                                 params["layers"])}
    n_dense_prefix = cfg.moe.first_dense if cfg.moe else 0
    if first:
        sp["embed"] = params["embed"]
        for i in range(n_dense_prefix):
            sp[f"dense{i}"] = params[f"dense{i}"]
    if last:
        sp["ln_f"] = params["ln_f"]
        if cfg.tie_embeddings:
            sp["embed"] = params["embed"]
        else:
            sp["lm_head"] = params["lm_head"]
    return sp


def _init_cache_range(cfg, batch_size: int, max_len: int, lo: int,
                      hi: int, *, first: bool):
    hd = _head_dim(cfg)
    s_max = min(max_len, cfg.sliding_window) if cfg.sliding_window \
        else max_len
    n_dense_prefix = (cfg.moe.first_dense if cfg.moe else 0) if first \
        else 0
    n_scan = hi - lo

    def one(_):
        return attn.KVCache.init(batch_size, s_max, cfg.n_kv_heads, hd,
                                 dtype=cfg.np_dtype)

    scan_cache = jax.tree.map(
        lambda *xs: jnp.stack(xs), *[one(i) for i in range(n_scan)]) \
        if n_scan else None
    prefix = [one(i) for i in range(n_dense_prefix)]
    return {"scan": scan_cache, "prefix": prefix}


def init_cache(cfg, batch_size: int, max_len: int):
    """Stacked per-layer KV cache (+ unstacked dense-prefix caches)."""
    return _init_cache_range(cfg, batch_size, max_len, 0,
                             n_scan_layers(cfg), first=True)


def init_stage_cache(cfg, batch_size: int, max_len: int, lo: int,
                     hi: int, *, first: bool):
    """Per-stage cache: KV stack for layers [lo, hi) (+ the dense
    prefix caches when this is the first stage)."""
    return _init_cache_range(cfg, batch_size, max_len, lo, hi,
                             first=first)


def _head_logits(cfg, params, x):
    """Final norm + LM head over (B, 1, D) -> (B, V)."""
    x = common.rms_norm(x, params["ln_f"], cfg.norm_eps)
    head = (params["embed"].T if cfg.tie_embeddings
            else params["lm_head"])
    return jnp.einsum("bsd,dv->bsv", x, head)[:, 0]


def stage_prefill(cfg, params, inp, cache, *, first: bool, last: bool,
                  frontend=None, prompt_len=None):
    """Prefill one stage's layers over the full (right-padded) prompt.

    ``inp``: (B, S) tokens when ``first`` else (B, S, D) activations
    from the previous stage. Returns ``(out, cache)`` where ``out`` is
    the (B, V) last-token logits when ``last`` (gathered at each
    slot's true ``prompt_len - 1``) else the full-width (B, S, D)
    activations to stream to the next stage.

    ``prompt_len``: optional (B,) true per-slot prompt lengths. Prompts
    are then expected RIGHT-padded to the (bucketed) common width —
    causal attention never lets a real position see the pad tail — so
    the cache lengths are set per slot. This is what lets admission pad
    to power-of-two buckets (capping recompiles) without changing
    outputs.
    """
    if first:
        x = common.embedding_lookup(params["embed"], inp)
        if frontend is not None:
            x = jnp.concatenate([frontend.astype(x.dtype), x], axis=1)
    else:
        x = inp
    b, s, _ = x.shape
    positions = jnp.broadcast_to(jnp.arange(s)[None], (b, s))
    is_moe = cfg.moe is not None
    rolling = cfg.sliding_window is not None
    scan_c = cache["scan"]
    if scan_c is None:
        s_max = 0
    elif isinstance(scan_c, attn.PagedKVCache):
        if rolling:
            raise ValueError(
                "direct paged SWA prefill unsupported — prefill dense "
                "scratch and paginate (serving.paging)")
        s_max = scan_c.s_max
    else:
        s_max = scan_c.k.shape[2]

    def write(cache_layer, kv):
        k, v = kv
        if rolling and (prompt_len is not None or s > s_max):
            # treat an un-annotated over-length prefill as full-width
            # prompts (the seed's slice-and-bump write clamped the
            # wrapped dynamic_update_slice to offset 0, scrambling
            # cell->position mapping — caught by teacher-forcing tests)
            eff_len = (prompt_len if prompt_len is not None
                       else jnp.full((b,), s, jnp.int32))
            # per-slot ring placement: cell c must hold the newest
            # prompt position p == c (mod s_max), i.e.
            # p = len-1 - ((len-1-c) mod s_max); cells a short slot
            # never wrote clamp to garbage rows that stay masked.
            # This is exact for ANY right-padded width — a batched
            # wave prefill can mix slots shorter and longer than the
            # ring.
            cell = jnp.arange(s_max)[None, :]
            plen = eff_len.astype(jnp.int32)[:, None]
            src = jnp.clip(plen - 1 - ((plen - 1 - cell) % s_max),
                           0, s - 1)[:, :, None, None]
            return cache_layer._replace(
                k=jnp.take_along_axis(k, src, axis=1).astype(
                    cache_layer.k.dtype),
                v=jnp.take_along_axis(v, src, axis=1).astype(
                    cache_layer.v.dtype),
                length=jnp.broadcast_to(eff_len.astype(jnp.int32),
                                        cache_layer.length.shape))
        new = attn.cache_update(cache_layer, k, v)
        if prompt_len is not None:
            # pad-tail cells stay garbage; masked by length and
            # overwritten as decode advances
            new = new._replace(
                length=jnp.broadcast_to(prompt_len.astype(jnp.int32),
                                        new.length.shape))
        return new

    new_prefix = []
    for i in range(len(cache["prefix"])):
        x, kv, _ = _layer(cfg, params[f"dense{i}"], x,
                          positions=positions, is_moe=False,
                          return_kv=True, serving=True)
        new_prefix.append(write(cache["prefix"][i], kv))

    def body(x, pc):
        p, c = pc
        y, kv, _ = _layer(cfg, p, x, positions=positions, is_moe=is_moe,
                          return_kv=True, serving=True)
        return y, write(c, kv)

    x, new_scan = jax.lax.scan(body, x, (params["layers"],
                                         cache["scan"]))
    new_cache = {"scan": new_scan, "prefix": new_prefix}
    if not last:
        return x, new_cache
    if prompt_len is None:
        x_last = x[:, -1:]
    else:
        idx = (prompt_len.astype(jnp.int32) - 1)[:, None, None]
        x_last = jnp.take_along_axis(x, idx, axis=1)
    return _head_logits(cfg, params, x_last), new_cache


def stage_decode(cfg, params, inp, cache, *, first: bool, last: bool):
    """One decode step through one stage's layers.

    ``inp``: (B, 1) token ids when ``first`` else (B, 1, D) activations.
    Returns ``(out, cache)``: (B, V) logits when ``last`` else (B, 1, D)
    activations. Positions come from the PER-SLOT cache lengths, so
    slots at different depths (continuous batching) each get the right
    RoPE phase — and every stage derives them independently from its
    own cache, which stays consistent across a chain because all
    stages advance in lockstep."""
    x = common.embedding_lookup(params["embed"], inp) if first else inp
    is_moe = cfg.moe is not None
    rolling = cfg.sliding_window is not None
    length = (cache["scan"].length[0] if cache["scan"] is not None
              else cache["prefix"][0].length)          # (B,)
    positions = length[:, None].astype(jnp.int32)

    new_prefix = []
    for i in range(len(cache["prefix"])):
        x2, c, _ = _layer(cfg, params[f"dense{i}"], x,
                          positions=positions, is_moe=False,
                          layer_cache=cache["prefix"][i], rolling=rolling,
                          serving=True)
        x = x2
        new_prefix.append(c)

    def body(x, pc):
        p, c = pc
        y, new_c, _ = _layer(cfg, p, x, positions=positions,
                             is_moe=is_moe, layer_cache=c,
                             rolling=rolling, serving=True)
        return y, new_c

    x, new_scan = jax.lax.scan(body, x, (params["layers"],
                                         cache["scan"]))
    new_cache = {"scan": new_scan, "prefix": new_prefix}
    if not last:
        return x, new_cache
    return _head_logits(cfg, params, x), new_cache


def prefill_extend(cfg, params, tokens, cache, *, start, seg_len):
    """Chunked prefill: run one (right-padded) prompt SEGMENT at
    absolute offset ``start`` against an already-partial cache.

    ``tokens``: (B, S) segment, right-padded; ``start``: scalar int32,
    absolute position of tokens[:, 0] — must equal the cache's current
    per-slot ``length`` (the write cursor); ``seg_len``: scalar int32,
    true segment length (<= S). Returns (last-token logits (B, V),
    cache with ``length = start + seg_len``).

    Every query row recomputes its FULL softmax over the whole live
    prefix (earlier segments read back from the cache + this segment's
    fresh K/V) — no online-softmax splitting — so chaining segments
    reproduces the single-shot ``prefill`` exactly, which is what lets
    prompts exceed one dense prefill bucket (paged caches: exceed
    ``max_len`` entirely) and lets shared-prefix admission resume after
    a content-addressed prefix hit. Full-causal only: an SWA ring has
    no stable absolute cells to resume into."""
    if cfg.sliding_window is not None:
        raise ValueError("prefill_extend is full-causal only (SWA "
                         "rings roll; resume offsets are undefined)")
    x = common.embedding_lookup(params["embed"], tokens)
    b, s, _ = x.shape
    start = jnp.asarray(start, jnp.int32)
    seg_len = jnp.asarray(seg_len, jnp.int32)
    positions = jnp.broadcast_to(start + jnp.arange(s)[None], (b, s))
    is_moe = cfg.moe is not None
    hd = _head_dim(cfg)

    def ext_layer(p, x, layer_cache, moe_layer: bool):
        x = hint_residual(x)
        h = common.rms_norm(x, p["ln_attn"], cfg.norm_eps)
        q = jnp.einsum("bsd,dh->bsh", h, p["wq"]).reshape(
            b, s, cfg.n_heads, hd)
        k = jnp.einsum("bsd,dh->bsh", h, p["wk"]).reshape(
            b, s, cfg.n_kv_heads, hd)
        v = jnp.einsum("bsd,dh->bsh", h, p["wv"]).reshape(
            b, s, cfg.n_kv_heads, hd)
        q = common.apply_rope(q, positions, cfg.rope_theta)
        k = common.apply_rope(k, positions, cfg.rope_theta)
        new = attn.cache_update(layer_cache, k, v)   # writes at length
        view = attn.paged_view(new) \
            if isinstance(new, attn.PagedKVCache) else new
        o = attn.attention(q, view.k, view.v, causal=True,
                           q_offset=start, block_q=cfg.block_q)
        a = jnp.einsum("bsh,hd->bsd",
                       o.reshape(b, s, cfg.n_heads * hd), p["wo"])
        x = hint_residual(x + a)
        f, _ = _ffn_block(cfg, p, x, moe_layer, serving=True)
        return hint_residual(x + f), new

    new_prefix = []
    for i in range(len(cache["prefix"])):
        x, c = ext_layer(params[f"dense{i}"], x, cache["prefix"][i],
                         False)
        new_prefix.append(c)

    def body(x, pc):
        p, c = pc
        y, new_c = ext_layer(p, x, c, is_moe)
        return y, new_c

    x, new_scan = jax.lax.scan(body, x, (params["layers"],
                                         cache["scan"]))

    def fix_len(c):
        # cache_update advanced length by the PADDED width; the true
        # cursor is start + seg_len
        return c._replace(length=jnp.broadcast_to(
            (start + seg_len).astype(jnp.int32), c.length.shape))

    is_cache = lambda c: isinstance(c, (attn.KVCache, attn.PagedKVCache))
    new_cache = jax.tree.map(fix_len,
                             {"scan": new_scan, "prefix": new_prefix},
                             is_leaf=is_cache)
    idx = jnp.broadcast_to(jnp.reshape(seg_len, (1, 1, 1)) - 1,
                           (b, 1, 1))
    x_last = jnp.take_along_axis(x, idx, axis=1)
    return _head_logits(cfg, params, x_last), new_cache


def prefill(cfg, params, tokens, cache, *, frontend=None,
            prompt_len=None):
    """Run the full prompt, fill the cache -> (last-token logits,
    cache). The K=1 stage specialization — see ``stage_prefill``."""
    return stage_prefill(cfg, params, tokens, cache, first=True,
                         last=True, frontend=frontend,
                         prompt_len=prompt_len)


def decode_step(cfg, params, token, cache):
    """One decode step. token: (B, 1) -> (logits (B, V), cache). The
    K=1 stage specialization — see ``stage_decode``."""
    return stage_decode(cfg, params, token, cache, first=True,
                        last=True)
