"""Attention: GQA/MHA, sliding-window (SWA), cross-attention, and
KV-cache decode.

Implementation notes (these are sharding-load-bearing):

* **Grouped-query einsums, never expanded KV.** K/V stay (B, S, Hk, dh)
  and Q is viewed as (B, T, Hk, G, dh); a `jnp.repeat` of KV to Hq heads
  lowers to broadcast_in_dim, which breaks XLA SPMD's partial-reduction
  path and forces a full cache all-gather per layer on seq-sharded
  decode caches (observed: 25 GB/layer/token). With the grouped form the
  score/value contractions reduce over the sharded seq dim locally and
  XLA inserts only tiny (B,Hk,G,T) all-reduces — cross-device
  flash-decoding for free.

* **Chunked prefill.** lax.scan over query blocks so the (S, S) score
  matrix never materializes (32k prefill would need terabytes). SWA
  additionally slices K/V to [block start - window, block end) making
  training truly sub-quadratic, which is what qualifies h2o-danube for
  long_500k.
"""
from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp

NEG_INF = -1e30


class KVCache(NamedTuple):
    k: jnp.ndarray        # (B, S_max, Hk, dh)
    v: jnp.ndarray        # (B, S_max, Hk, dh)
    length: jnp.ndarray   # (B,) int32 — tokens written PER SLOT (absolute)

    @classmethod
    def init(cls, batch: int, max_len: int, n_kv: int, head_dim: int,
             dtype=jnp.bfloat16) -> "KVCache":
        z = jnp.zeros((batch, max_len, n_kv, head_dim), dtype)
        return cls(z, jnp.copy(z), jnp.zeros((batch,), jnp.int32))


class PagedKVCache(NamedTuple):
    """Block-table KV cache: a global physical block pool shared by all
    slots, indexed per slot through a block table.

    Virtual cell ``c`` of slot ``b`` lives at physical cell
    ``(table[b, c // blk], c % blk)``. Physical block 0 is a reserved
    TRASH block — never mapped by any table — so writes past a slot's
    allocation (done slots padding out a decode chunk, pad tails of a
    bucketed prefill) land harmlessly instead of corrupting a neighbor;
    ``-1`` table entries mean "unmapped" and clamp to the trash block.
    Block allocation/refcounting is host-side (serving.paging.BlockPool);
    the device only ever sees the materialized table."""
    k: jnp.ndarray        # (N_blocks, blk, Hk, dh) physical pool
    v: jnp.ndarray        # (N_blocks, blk, Hk, dh)
    table: jnp.ndarray    # (B, nb) int32 block ids, -1 = unmapped
    length: jnp.ndarray   # (B,) int32 — tokens written PER SLOT (absolute)

    @classmethod
    def init(cls, n_blocks: int, block: int, n_kv: int, head_dim: int,
             batch: int, max_blocks: int,
             dtype=jnp.bfloat16) -> "PagedKVCache":
        z = jnp.zeros((n_blocks, block, n_kv, head_dim), dtype)
        return cls(z, jnp.copy(z),
                   jnp.full((batch, max_blocks), -1, jnp.int32),
                   jnp.zeros((batch,), jnp.int32))

    @property
    def block_size(self) -> int:
        return self.k.shape[-3]

    @property
    def s_max(self) -> int:
        """Virtual per-slot capacity in cells."""
        return self.table.shape[-1] * self.k.shape[-3]


def paged_view(cache: PagedKVCache) -> KVCache:
    """Gather the pool through the table into a dense per-slot view.

    Cell-for-cell identical to the dense cache a `KVCache` of the same
    virtual capacity would hold (unmapped blocks read the trash block;
    those cells are masked by ``length`` everywhere downstream), so any
    dense consumer is bitwise-correct on the view."""
    tbl = jnp.maximum(cache.table, 0)                 # (B, nb)
    b = tbl.shape[0]
    k = cache.k[tbl].reshape(b, -1, *cache.k.shape[2:])
    v = cache.v[tbl].reshape(b, -1, *cache.v.shape[2:])
    return KVCache(k, v, cache.length)


def paged_cache_update(cache: PagedKVCache, k_new: jnp.ndarray,
                       v_new: jnp.ndarray, *,
                       rolling: bool = False) -> PagedKVCache:
    """Append S_new tokens through the block table.

    Same per-slot write-cursor semantics as the dense `cache_update`
    (start at ``length``, SWA wraps mod the virtual ring size); the
    scatter routes each (slot, cell) to (table[slot, cell // blk],
    cell % blk). Cells past the virtual capacity or landing on an
    unmapped (-1) entry are redirected to the trash block — duplicate
    trash indices are the only scatter collisions, and their values are
    never read."""
    blk = cache.block_size
    nb = cache.table.shape[1]
    s_max = nb * blk
    s_new = k_new.shape[1]
    start = cache.length % s_max if rolling else cache.length    # (B,)
    cells = start[:, None] + jnp.arange(s_new)[None, :]          # (B, S)
    if rolling:
        cells = cells % s_max
    live = cells < s_max
    bi = jnp.clip(cells // blk, 0, nb - 1)
    phys = jnp.take_along_axis(cache.table, bi, axis=1)          # (B, S)
    phys = jnp.where(live & (phys >= 0), phys, 0)
    off = jnp.where(live, cells % blk, 0)
    k = cache.k.at[phys, off].set(k_new.astype(cache.k.dtype))
    v = cache.v.at[phys, off].set(v_new.astype(cache.v.dtype))
    return PagedKVCache(k, v, cache.table, cache.length + s_new)


def _grouped(q: jnp.ndarray, n_kv: int) -> jnp.ndarray:
    """(B, T, Hq, dh) -> (B, T, Hk, G, dh)."""
    b, t, hq, dh = q.shape
    return q.reshape(b, t, n_kv, hq // n_kv, dh)


def _sdpa_block(qg, k, v, mask):
    """One (q-block x kv-range) grouped attention, fp32 softmax.

    qg: (B, T, Hk, G, dh); k, v: (B, S, Hk, dh); mask: (T, S) bool.
    Returns (B, T, Hk, G, dh)."""
    scale = qg.shape[-1] ** -0.5
    s = jnp.einsum("btkgd,bskd->bkgts", qg, k).astype(jnp.float32)
    s = s * scale
    s = jnp.where(mask[None, None, None], s, NEG_INF)
    m = jnp.max(s, axis=-1, keepdims=True)
    p = jnp.exp(s - m)
    p = jnp.where(mask[None, None, None], p, 0.0)
    denom = jnp.sum(p, axis=-1, keepdims=True)
    p = p / jnp.maximum(denom, 1e-30)
    out = jnp.einsum("bkgts,bskd->btkgd", p.astype(v.dtype), v)
    return out


def attention(q: jnp.ndarray, k: jnp.ndarray, v: jnp.ndarray, *,
              causal: bool = True, window: int | None = None,
              q_offset: int = 0, block_q: int = 512) -> jnp.ndarray:
    """Chunked multi-head GQA attention.

    q: (B, Sq, Hq, dh); k/v: (B, Sk, Hk, dh) with Hq % Hk == 0.
    ``q_offset``: absolute position of q[0] relative to k[0].
    ``window``: SWA width (None = full causal)."""
    b, sq, hq, dh = q.shape
    sk = k.shape[1]
    hk = k.shape[2]
    qg = _grouped(q, hk)

    def finish(out):
        return out.reshape(b, -1, hq, dh).astype(q.dtype)

    if sq <= block_q:
        qpos = q_offset + jnp.arange(sq)[:, None]
        kpos = jnp.arange(sk)[None, :]
        mask = jnp.ones((sq, sk), bool)
        if causal:
            mask &= kpos <= qpos
        if window is not None:
            mask &= kpos > qpos - window
        return finish(_sdpa_block(qg, k, v, mask))

    nblk = -(-sq // block_q)
    pad = nblk * block_q - sq
    qp = jnp.pad(qg, ((0, 0), (0, pad), (0, 0), (0, 0), (0, 0)))
    qblocks = qp.reshape(b, nblk, block_q, hk, hq // hk, dh)
    qblocks = jnp.moveaxis(qblocks, 1, 0)

    # flash-semantics: checkpoint each q-block so the (block_q, S) score
    # tile is RECOMPUTED in backward instead of being stacked across the
    # scan (a 40L x 32k model would otherwise save terabytes of probs —
    # this is what fused flash kernels do on real hardware)
    if window is not None:
        # sub-quadratic: each q block sees [start - lookback, end)
        lookback = (-(-window // block_q)) * block_q
        span = lookback + block_q
        kpad = jnp.pad(k, ((0, 0), (lookback, pad), (0, 0), (0, 0)))
        vpad = jnp.pad(v, ((0, 0), (lookback, pad), (0, 0), (0, 0)))

        @jax.checkpoint
        def body(_, i):
            qb = qblocks[i]
            start = i * block_q
            kb = jax.lax.dynamic_slice_in_dim(kpad, start, span, axis=1)
            vb = jax.lax.dynamic_slice_in_dim(vpad, start, span, axis=1)
            qpos = q_offset + start + jnp.arange(block_q)[:, None]
            kpos = start - lookback + jnp.arange(span)[None, :] \
                + q_offset
            mask = (kpos >= q_offset) & (kpos <= qpos) & \
                (kpos > qpos - window)
            return None, _sdpa_block(qb, kb, vb, mask)

        _, outs = jax.lax.scan(body, None, jnp.arange(nblk))
    else:
        @jax.checkpoint
        def body(_, i):
            qb = qblocks[i]
            qpos = q_offset + i * block_q + jnp.arange(block_q)[:, None]
            kpos = jnp.arange(sk)[None, :]
            mask = kpos <= qpos if causal else \
                jnp.ones((block_q, sk), bool)
            return None, _sdpa_block(qb, k, v, mask)

        _, outs = jax.lax.scan(body, None, jnp.arange(nblk))

    out = jnp.moveaxis(outs, 0, 1)        # (B, nblk, block_q, ...)
    out = out.reshape(b, nblk * block_q, hk, hq // hk, dh)[:, :sq]
    return out.reshape(b, sq, hq, dh).astype(q.dtype)


def decode_valid_mask(length: jnp.ndarray, s_max: int,
                      window: int | None) -> jnp.ndarray:
    """(B,) per-slot lengths -> (B, S_max) bool mask of live cache cells.

    Full-causal: cell s is live while s < length. SWA: the cache is a
    rolling ring of size s_max; recover each cell's absolute position
    from the write cursor and keep the last ``window`` positions."""
    length = length[:, None].astype(jnp.int32)        # (B, 1)
    slot = jnp.arange(s_max)[None, :]                 # (1, S)
    if window is None:
        return slot < length
    wrap = length > s_max
    rem = length % s_max
    abs_pos = jnp.where(
        wrap,
        jnp.where(slot < rem, length - rem + slot,
                  length - rem - s_max + slot),
        slot)
    return (abs_pos < length) & (abs_pos >= length - window)


def decode_attention(q: jnp.ndarray, cache: KVCache, *,
                     window: int | None = None,
                     impl: str = "jnp") -> jnp.ndarray:
    """Single-token grouped attention against the per-slot cache.

    q: (B, 1, Hq, dh); ``cache.length`` is (B,) so every slot masks its
    own live prefix — slots at different sequence lengths decode
    together (continuous batching). With a seq-sharded cache the
    contractions reduce locally per shard and XLA merges partials
    (flash-decoding). For SWA the cache is a rolling buffer of size >=
    window. ``impl="pallas"`` selects the fused flash-decode TPU kernel
    (interpret mode off-TPU). Paged caches attend through the block
    table: the Pallas path gathers blocks inside the kernel via
    scalar-prefetched table lookups, the jnp path through a dense
    gathered view (bitwise identical to the dense cache by
    construction)."""
    if isinstance(cache, PagedKVCache):
        if impl == "pallas":
            from repro.kernels import flash_decode
            return flash_decode.flash_decode_paged(
                q, cache.k, cache.v, cache.table, cache.length,
                window=window)
        cache = paged_view(cache)
    if impl == "pallas":
        from repro.kernels import flash_decode
        return flash_decode.flash_decode(q, cache.k, cache.v,
                                         cache.length, window=window)
    b, t, hq, dh = q.shape
    s_max = cache.k.shape[1]
    hk = cache.k.shape[2]
    qg = _grouped(q, hk)
    scale = dh ** -0.5
    s = jnp.einsum("btkgd,bskd->bkgts", qg, cache.k).astype(
        jnp.float32) * scale

    valid = decode_valid_mask(cache.length, s_max, window)   # (B, S)
    s = jnp.where(valid[:, None, None, None], s, NEG_INF)
    m = jnp.max(s, axis=-1, keepdims=True)
    p = jnp.exp(s - m)
    p = jnp.where(valid[:, None, None, None], p, 0.0)
    denom = jnp.sum(p, axis=-1, keepdims=True)
    p = p / jnp.maximum(denom, 1e-30)
    out = jnp.einsum("bkgts,bskd->btkgd", p.astype(cache.v.dtype),
                     cache.v)
    return out.reshape(b, t, hq, dh).astype(q.dtype)


def cache_update(cache: KVCache, k_new: jnp.ndarray,
                 v_new: jnp.ndarray, *, rolling: bool = False) -> KVCache:
    """Append S_new tokens (prefill write or single decode step).

    Per-slot write offsets: each slot writes at its own ``length`` (a
    vmapped dynamic_update_slice, lowered to a batched scatter), so a
    freshly prefilled slot can sit next to slots deep into decode.
    Rolling mode wraps into a window-sized ring buffer; for prefill
    writes larger than the buffer, slice to the last s_max tokens and
    bump ``length`` before calling (see transformer.prefill). Paged
    caches dispatch to the block-table scatter."""
    if isinstance(cache, PagedKVCache):
        return paged_cache_update(cache, k_new, v_new, rolling=rolling)
    s_max = cache.k.shape[1]
    s_new = k_new.shape[1]
    start = cache.length % s_max if rolling else cache.length   # (B,)

    def write(buf, new, st):
        return jax.lax.dynamic_update_slice_in_dim(
            buf, new.astype(buf.dtype), st, axis=0)

    k = jax.vmap(write)(cache.k, k_new, start)
    v = jax.vmap(write)(cache.v, v_new, start)
    return KVCache(k, v, cache.length + s_new)
