"""Mamba2 — State Space Duality (SSD), arXiv:2405.21060.

Chunked SSD: the sequence is split into chunks of Q tokens; intra-chunk
interactions are computed as masked matmuls (the "attention-like" dual
form, MXU-friendly), inter-chunk via a lax.scan state recurrence —
O(L*Q + L*N*P) instead of O(L^2), which is what qualifies the SSM and
hybrid archs for the ``long_500k`` shape.

Projections are split per stream (z, x, B, C, dt) instead of one fused
in_proj so each output dim gets a clean sharding axis (x/z over 'ff').

Decode keeps a recurrent state (B, H, P, N) + a causal-conv ring of the
last K-1 inputs — O(1) per token.
"""
from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp

from repro.models import common


class SSMConfig(NamedTuple):
    d_model: int
    d_state: int = 64
    head_dim: int = 64
    n_groups: int = 1
    conv_kernel: int = 4
    expand: int = 2
    chunk: int = 256

    @property
    def d_inner(self) -> int:
        return self.expand * self.d_model

    @property
    def n_heads(self) -> int:
        return self.d_inner // self.head_dim


class SSMState(NamedTuple):
    state: jnp.ndarray       # (B, H, P, N) fp32
    conv_x: jnp.ndarray      # (B, K-1, d_inner)
    conv_b: jnp.ndarray      # (B, K-1, G*N)
    conv_c: jnp.ndarray      # (B, K-1, G*N)


def init_mamba2(b: common.ParamBuilder, prefix: str, cfg: SSMConfig):
    d, di, h = cfg.d_model, cfg.d_inner, cfg.n_heads
    gn = cfg.n_groups * cfg.d_state
    b.add(f"{prefix}/in_z", (d, di), ("embed", "ff"))
    b.add(f"{prefix}/in_x", (d, di), ("embed", "ff"))
    b.add(f"{prefix}/in_b", (d, gn), ("embed", None))
    b.add(f"{prefix}/in_c", (d, gn), ("embed", None))
    b.add(f"{prefix}/in_dt", (d, h), ("embed", None))
    b.add(f"{prefix}/conv_x", (cfg.conv_kernel, di), (None, "ff"),
          scale=cfg.conv_kernel ** -0.5)
    b.add(f"{prefix}/conv_b", (cfg.conv_kernel, gn), (None, None),
          scale=cfg.conv_kernel ** -0.5)
    b.add(f"{prefix}/conv_c", (cfg.conv_kernel, gn), (None, None),
          scale=cfg.conv_kernel ** -0.5)
    b.add(f"{prefix}/a_log", (h,), (None,), init="zeros")
    b.add(f"{prefix}/dt_bias", (h,), (None,), init="zeros")
    b.add(f"{prefix}/d_skip", (h,), (None,), init="ones")
    b.add(f"{prefix}/norm", (di,), ("ff",), init="ones")
    b.add(f"{prefix}/out", (di, d), ("ff", "embed"), scale=di ** -0.5)


def _causal_conv(x: jnp.ndarray, w: jnp.ndarray,
                 history: jnp.ndarray | None = None) -> jnp.ndarray:
    """Depthwise causal conv1d. x: (B, L, C), w: (K, C).
    ``history``: (B, K-1, C) left context (decode / continuation)."""
    k = w.shape[0]
    if history is None:
        history = jnp.zeros((x.shape[0], k - 1, x.shape[2]), x.dtype)
    xp = jnp.concatenate([history.astype(x.dtype), x], axis=1)
    out = sum(xp[:, i:i + x.shape[1]] * w[i] for i in range(k))
    return jax.nn.silu(out)


def _segsum(dta: jnp.ndarray) -> jnp.ndarray:
    """(..., Q) -> (..., Q, Q) with out[i,j] = sum_{j<k<=i} dta_k (i>=j),
    -inf above the diagonal."""
    q = dta.shape[-1]
    cs = jnp.cumsum(dta, axis=-1)
    diff = cs[..., :, None] - cs[..., None, :]
    ii = jnp.arange(q)
    mask = ii[:, None] >= ii[None, :]
    return jnp.where(mask, diff, -jnp.inf)


def ssd_chunked(x, dt, a, bmat, cmat, cfg: SSMConfig,
                init_state: jnp.ndarray | None = None):
    """Chunked SSD scan.

    x: (B, L, H, P); dt: (B, L, H) (post-softplus); a: (H,) negative;
    bmat/cmat: (B, L, G, N). Returns (y (B,L,H,P), final_state).
    """
    bsz, l, h, p = x.shape
    g, n = bmat.shape[2], bmat.shape[3]
    q = min(cfg.chunk, l)
    nc = -(-l // q)
    pad = nc * q - l
    if pad:
        x = jnp.pad(x, ((0, 0), (0, pad), (0, 0), (0, 0)))
        dt = jnp.pad(dt, ((0, 0), (0, pad), (0, 0)))
        bmat = jnp.pad(bmat, ((0, 0), (0, pad), (0, 0), (0, 0)))
        cmat = jnp.pad(cmat, ((0, 0), (0, pad), (0, 0), (0, 0)))

    hpg = h // g
    xc = x.reshape(bsz, nc, q, g, hpg, p)
    dtc = dt.reshape(bsz, nc, q, g, hpg)
    bc = bmat.reshape(bsz, nc, q, g, n)
    cc = cmat.reshape(bsz, nc, q, g, n)
    dta = dtc * a.reshape(g, hpg)                       # (b,c,q,g,hpg)
    xdt = xc * dtc[..., None]

    # intra-chunk (dual "attention" form)
    lmat = jnp.exp(_segsum(jnp.moveaxis(dta, 2, -1)))   # (b,c,g,hpg,q,q)
    scores = jnp.einsum("bcqgn,bckgn->bcgqk", cc, bc)
    y_diag = jnp.einsum("bcgqk,bcghqk,bckghp->bcqghp",
                        scores, lmat, xdt)

    # per-chunk boundary states
    cum = jnp.cumsum(dta, axis=2)                       # (b,c,q,g,hpg)
    total = cum[:, :, -1:]                              # (b,c,1,g,hpg)
    decay_to_end = jnp.exp(total - cum)                 # (b,c,q,g,hpg)
    chunk_states = jnp.einsum("bckgn,bckgh,bckghp->bcghpn",
                              bc, decay_to_end, xdt)

    # inter-chunk recurrence
    chunk_decay = jnp.exp(total[:, :, 0])               # (b,c,g,hpg)
    if init_state is None:
        init_state = jnp.zeros((bsz, g, hpg, p, n), jnp.float32)
    else:
        init_state = init_state.reshape(bsz, g, hpg, p, n)

    def step(s, inp):
        cs, dec = inp
        s_new = s * dec[..., None, None] + cs
        return s_new, s  # emit the state *entering* this chunk

    (final_state, prev_states) = jax.lax.scan(
        step, init_state.astype(jnp.float32),
        (jnp.moveaxis(chunk_states, 1, 0).astype(jnp.float32),
         jnp.moveaxis(chunk_decay, 1, 0).astype(jnp.float32)))
    prev_states = jnp.moveaxis(prev_states, 0, 1)       # (b,c,g,hpg,p,n)

    y_off = jnp.einsum("bcqgn,bcghpn,bcqgh->bcqghp",
                       cc, prev_states.astype(cc.dtype),
                       jnp.exp(cum).astype(cc.dtype))
    y = (y_diag + y_off).reshape(bsz, nc * q, h, p)[:, :l]
    return y, final_state.reshape(bsz, h, p, n)


def apply_mamba2(p, x: jnp.ndarray, cfg: SSMConfig,
                 state: SSMState | None = None,
                 return_state: bool = False,
                 prompt_len: jnp.ndarray | None = None):
    """Full Mamba2 block. x: (B, L, d_model).

    ``prompt_len``: optional (B,) true lengths for RIGHT-padded serving
    prefill. dt is zeroed at pad positions, which freezes the SSD
    recurrence exactly (decay exp(0)=1, input contribution x*dt=0), so
    the final state equals the unpadded run's; the conv tail is gathered
    per slot at the true last K-1 inputs. Outputs at pad positions are
    garbage — callers gather logits at ``prompt_len - 1``."""
    bsz, l, _ = x.shape
    h, pd, g, n = cfg.n_heads, cfg.head_dim, cfg.n_groups, cfg.d_state
    z = jnp.einsum("bld,df->blf", x, p["in_z"])
    xs = jnp.einsum("bld,df->blf", x, p["in_x"])
    bs = jnp.einsum("bld,df->blf", x, p["in_b"])
    cs = jnp.einsum("bld,df->blf", x, p["in_c"])
    dt = jnp.einsum("bld,dh->blh", x, p["in_dt"])

    hist = (state.conv_x, state.conv_b, state.conv_c) if state else (
        None, None, None)
    xs_in, bs_in, cs_in = xs, bs, cs
    xs = _causal_conv(xs, p["conv_x"], hist[0])
    bs = _causal_conv(bs, p["conv_b"], hist[1])
    cs = _causal_conv(cs, p["conv_c"], hist[2])

    dt = jax.nn.softplus(dt.astype(jnp.float32)
                         + p["dt_bias"].astype(jnp.float32))
    if prompt_len is not None:
        seq_mask = (jnp.arange(l)[None, :]
                    < prompt_len[:, None]).astype(jnp.float32)
        dt = dt * seq_mask[..., None]
    a = -jnp.exp(p["a_log"].astype(jnp.float32))
    xh = xs.reshape(bsz, l, h, pd)
    y, final = ssd_chunked(
        xh.astype(jnp.float32), dt, a,
        bs.reshape(bsz, l, g, n).astype(jnp.float32),
        cs.reshape(bsz, l, g, n).astype(jnp.float32), cfg,
        init_state=state.state if state else None)
    y = y + xh.astype(jnp.float32) * p["d_skip"].reshape(1, 1, h, 1)
    y = y.reshape(bsz, l, cfg.d_inner).astype(x.dtype)
    y = common.rms_norm(y * jax.nn.silu(z), p["norm"])
    out = jnp.einsum("blf,fd->bld", y, p["out"])
    if not return_state:
        return out, None
    k = cfg.conv_kernel

    def tail(seq, old):
        if prompt_len is not None:
            # last K-1 TRUE inputs per slot: the combined
            # (history, tokens) stream ends at position (k-1)+len, so
            # the tail is rows [len, len+k-1) of it
            hist = (old.astype(seq.dtype) if old is not None
                    else jnp.zeros((bsz, k - 1, seq.shape[-1]),
                                   seq.dtype))
            full = jnp.concatenate([hist, seq], axis=1)
            idx = (prompt_len.astype(jnp.int32)[:, None]
                   + jnp.arange(k - 1)[None, :])
            return jnp.take_along_axis(full, idx[:, :, None], axis=1)
        if l >= k - 1:
            return seq[:, l - (k - 1):]
        keep = old[:, l:] if old is not None else jnp.zeros(
            (bsz, k - 1 - l, seq.shape[-1]), seq.dtype)
        return jnp.concatenate([keep.astype(seq.dtype), seq], axis=1)

    new_state = SSMState(final,
                         tail(xs_in, hist[0] if state else None),
                         tail(bs_in, hist[1] if state else None),
                         tail(cs_in, hist[2] if state else None))
    return out, new_state


def decode_mamba2(p, x: jnp.ndarray, cfg: SSMConfig, state: SSMState):
    """One-token recurrent step. x: (B, 1, d_model)."""
    bsz = x.shape[0]
    h, pd, g, n = cfg.n_heads, cfg.head_dim, cfg.n_groups, cfg.d_state
    z = jnp.einsum("bld,df->blf", x, p["in_z"])
    xs = jnp.einsum("bld,df->blf", x, p["in_x"])
    bs = jnp.einsum("bld,df->blf", x, p["in_b"])
    cs = jnp.einsum("bld,df->blf", x, p["in_c"])
    dt = jnp.einsum("bld,dh->blh", x, p["in_dt"])

    new_conv = (jnp.concatenate([state.conv_x[:, 1:], xs.astype(
                    state.conv_x.dtype)], axis=1),
                jnp.concatenate([state.conv_b[:, 1:], bs.astype(
                    state.conv_b.dtype)], axis=1),
                jnp.concatenate([state.conv_c[:, 1:], cs.astype(
                    state.conv_c.dtype)], axis=1))
    xs = _causal_conv(xs, p["conv_x"], state.conv_x)[:, -1:]
    bs = _causal_conv(bs, p["conv_b"], state.conv_b)[:, -1:]
    cs = _causal_conv(cs, p["conv_c"], state.conv_c)[:, -1:]

    dt = jax.nn.softplus(dt.astype(jnp.float32)
                         + p["dt_bias"].astype(jnp.float32))[:, 0]  # (B,H)
    a = -jnp.exp(p["a_log"].astype(jnp.float32))
    da = jnp.exp(dt * a)                                           # (B,H)
    xh = xs.reshape(bsz, h, pd).astype(jnp.float32)
    hpg = h // g
    bh = jnp.repeat(bs.reshape(bsz, g, n), hpg, axis=1)            # (B,H,N)
    ch = jnp.repeat(cs.reshape(bsz, g, n), hpg, axis=1)
    xdt = xh * dt[..., None]
    s_new = (state.state * da[..., None, None]
             + xdt[..., :, None] * bh[:, :, None, :].astype(jnp.float32))
    y = jnp.einsum("bhpn,bhn->bhp", s_new, ch.astype(jnp.float32))
    y = y + xh * p["d_skip"].reshape(1, h, 1)
    y = y.reshape(bsz, 1, cfg.d_inner).astype(x.dtype)
    y = common.rms_norm(y * jax.nn.silu(z), p["norm"])
    out = jnp.einsum("blf,fd->bld", y, p["out"])
    return out, SSMState(s_new, *new_conv)
