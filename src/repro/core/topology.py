"""Bandwidth-aware ring topology optimization (INTELLECT-1 §2.5).

The paper continuously measures pairwise bandwidth and picks the ring
order that maximizes the minimum edge bandwidth along the cycle — a
max–min *bottleneck* variant of the Traveling Salesperson Problem:

    max_{C in HamiltonianCycles}  min_{(u,v) in C}  w(u, v)

Solvers:
  * ``solve_exact``  — binary search over the sorted edge weights with a
    Held–Karp-style Hamiltonicity DP on the thresholded graph.  O(2^n n^2)
    per check; exact for n <= ~16 (the paper ran up to 14 nodes).
  * ``solve_greedy`` — nearest-available-neighbor construction + 2-opt-
    style bottleneck improvement for larger fleets.
  * ``optimize_ring_order`` — dispatches on n.

The returned order is a tuple of node ids; edge (order[-1], order[0])
closes the cycle.  The DiLoCo ring all-reduce consumes it as the static
``ppermute`` permutation.
"""
from __future__ import annotations

import itertools

import numpy as np


def cycle_bottleneck(w: np.ndarray, order) -> float:
    """Minimum edge bandwidth along the closed cycle ``order``."""
    n = len(order)
    return float(min(w[order[i], order[(i + 1) % n]] for i in range(n)))


def _hamiltonian_cycle_at_least(w: np.ndarray, thresh: float):
    """Held–Karp reachability DP: find a Hamiltonian cycle using only
    edges with weight >= thresh. Returns the cycle or None."""
    n = w.shape[0]
    if n == 1:
        return (0,)
    if n == 2:
        return (0, 1) if w[0, 1] >= thresh else None
    adj = w >= thresh
    # dp[mask][v] = predecessor of v on a path 0->...->v covering `mask`
    full = 1 << n
    pred = [[-2] * n for _ in range(full)]
    pred[1][0] = -1
    for mask in range(1, full):
        if not mask & 1:
            continue
        for v in range(n):
            if pred[mask][v] == -2 or not (mask >> v) & 1:
                continue
            for u in range(1, n):
                if (mask >> u) & 1 or not adj[v, u]:
                    continue
                nm = mask | (1 << u)
                if pred[nm][u] == -2:
                    pred[nm][u] = v
    last = full - 1
    for v in range(1, n):
        if pred[last][v] != -2 and adj[v, 0]:
            path = []
            mask, cur = last, v
            while cur != -1:
                path.append(cur)
                p = pred[mask][cur]
                mask ^= 1 << cur
                cur = p
            return tuple(reversed(path))
    return None


def solve_exact(w: np.ndarray) -> tuple[int, ...]:
    """Exact max–min bottleneck cycle via binary search over edge weights."""
    n = w.shape[0]
    if n <= 2:
        return tuple(range(n))
    weights = sorted({float(w[i, j]) for i in range(n) for j in range(n)
                      if i != j})
    lo, hi = 0, len(weights) - 1
    best = None
    while lo <= hi:
        mid = (lo + hi) // 2
        cyc = _hamiltonian_cycle_at_least(w, weights[mid])
        if cyc is not None:
            best = cyc
            lo = mid + 1
        else:
            hi = mid - 1
    assert best is not None  # the complete graph always has a cycle
    return best


def solve_greedy(w: np.ndarray, restarts: int = 8,
                 seed: int = 0) -> tuple[int, ...]:
    """Greedy + pairwise-swap improvement; near-optimal for large n."""
    n = w.shape[0]
    if n <= 2:
        return tuple(range(n))
    rng = np.random.default_rng(seed)
    # NN construction is deterministic given the start node, so colliding
    # starts would duplicate work — draw distinct starts (0 first).
    starts = [0] + [int(s) for s in
                    rng.permutation(np.arange(1, n))[:max(0, restarts - 1)]]
    best, best_val = None, -np.inf
    for start in starts:
        order = [start]
        left = set(range(n)) - {start}
        while left:
            cur = order[-1]
            nxt = max(left, key=lambda v: w[cur, v])
            order.append(nxt)
            left.remove(nxt)
        improved = True
        while improved:
            improved = False
            val = cycle_bottleneck(w, order)
            for i, j in itertools.combinations(range(n), 2):
                order[i], order[j] = order[j], order[i]
                if cycle_bottleneck(w, order) > val:
                    improved = True
                    break
                order[i], order[j] = order[j], order[i]
        val = cycle_bottleneck(w, order)
        if val > best_val:
            best, best_val = tuple(order), val
    return best


def optimize_ring_order(bandwidth: np.ndarray,
                        exact_limit: int = 14) -> tuple[int, ...]:
    """Ring order maximizing the bottleneck bandwidth (paper's objective)."""
    w = np.asarray(bandwidth, dtype=np.float64)
    assert w.ndim == 2 and w.shape[0] == w.shape[1]
    w = (w + w.T) / 2.0  # links are symmetric for our purposes
    if w.shape[0] <= exact_limit:
        return solve_exact(w)
    return solve_greedy(w)


def exclude_slots(order, excluded) -> tuple[int, ...]:
    """Quarantine-aware ring order: keep the relative order of the
    retained slots and move ``excluded`` slots to the TAIL (in their
    original relative order).

    The result is still a permutation of ``order`` — excluded slots
    stay in the ring geometry (they contribute zero-weighted rows), but
    they no longer sit between healthy peers, so a wedged or
    quarantined contributor cannot stall a healthy-to-healthy wire
    edge. When the excluded slots already sit at the tail the order is
    unchanged — no recompile of the distributed hop programs."""
    excluded = set(excluded)
    kept = tuple(s for s in order if s not in excluded)
    tail = tuple(s for s in order if s in excluded)
    return kept + tail


class BandwidthMonitor:
    """Models the paper's background bandwidth-probing process.

    Keeps an EWMA of observed pairwise bandwidths and re-solves the ring
    order when the current ring's bottleneck drifts below ``reorder_ratio``
    of the achievable optimum (avoiding needless recompiles).
    """

    def __init__(self, n: int, ewma: float = 0.5, reorder_ratio: float = 0.8):
        self.n = n
        self.ewma = ewma
        self.reorder_ratio = reorder_ratio
        self.bandwidth = np.full((n, n), np.inf)
        np.fill_diagonal(self.bandwidth, 0.0)
        self.order: tuple[int, ...] = tuple(range(n))

    def observe(self, i: int, j: int, gbps: float) -> None:
        old = self.bandwidth[i, j]
        new = gbps if not np.isfinite(old) else (
            self.ewma * gbps + (1 - self.ewma) * old)
        self.bandwidth[i, j] = self.bandwidth[j, i] = new

    def observe_matrix(self, w) -> None:
        w = np.asarray(w, dtype=np.float64)
        for i in range(self.n):
            for j in range(i + 1, self.n):
                self.observe(i, j, float(w[i, j]))

    def ring_bottleneck(self, order=None) -> float | None:
        """Measured bottleneck bandwidth (Gb/s) of ``order`` (default: the
        current ring), or None while any edge on it is still unobserved."""
        order = self.order if order is None else tuple(order)
        n = len(order)
        if n <= 1:
            return None
        edges = [self.bandwidth[order[i], order[(i + 1) % n]]
                 for i in range(n)]
        if not all(np.isfinite(e) for e in edges):
            return None
        return float(min(edges))

    def maybe_reorder(self) -> tuple[bool, tuple[int, ...]]:
        """(changed, order). ``changed`` implies the caller must recompile
        the sync step with the new static ring permutation.

        Unobserved links (still ``inf``) are UNKNOWN, not zero: until every
        edge on the current ring has an observation we cannot score it, so
        we never reorder off a partially-observed matrix (a spurious
        reorder costs a recompile)."""
        cur_val = self.ring_bottleneck()
        if cur_val is None:
            return False, self.order
        # unobserved edges score 0 only as *candidates* — the solver will
        # route around them, and can never beat a fully-observed ring with
        # a cycle through an unmeasured link
        w = np.where(np.isfinite(self.bandwidth), self.bandwidth, 0.0)
        best = optimize_ring_order(w)
        best_val = cycle_bottleneck(w, best)
        if best_val > 0 and cur_val < self.reorder_ratio * best_val:
            self.order = best
            return True, best
        return False, self.order
