"""Beyond-paper gradient-compression extensions.

The paper ships int8 (4x vs fp32). Two extensions, both composable with
the ring:

  * **int4 packed quantization** (8x, -> ~800x total reduction at H=100):
    same 6-sigma uniform scheme with 16 buckets, two codes packed per
    uint8 byte on the wire.
  * **Error feedback (EF14-style)**: the residual ``pg - deq(q(pg))`` is
    kept locally and added to the next outer step's pseudo-gradient, so
    quantization bias cannot accumulate over outer steps. The paper
    argues pseudo-gradient quantization is robust; EF makes the claim
    unconditional at int4.
"""
from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp

NUM_BUCKETS4 = 16
CLIP_SIGMAS = 6.0
_EPS = 1e-12


class Quantized4(NamedTuple):
    packed: jnp.ndarray     # uint8, two 4-bit codes per byte
    codebook: jnp.ndarray   # (16,) fp32

    @property
    def wire_bytes(self) -> int:
        return int(self.packed.size) + 4 * NUM_BUCKETS4


def quantize4(x: jnp.ndarray) -> Quantized4:
    xf = x.astype(jnp.float32).reshape(-1)
    mu, sigma = jnp.mean(xf), jnp.std(xf)
    half = CLIP_SIGMAS * sigma
    lo = mu - half
    width = jnp.maximum(2 * half / NUM_BUCKETS4, _EPS)
    idx = jnp.clip(jnp.floor((xf - lo) / width), 0, NUM_BUCKETS4 - 1)
    codes = idx.astype(jnp.int32)
    sums = jnp.zeros((NUM_BUCKETS4,), jnp.float32).at[codes].add(xf)
    counts = jnp.zeros((NUM_BUCKETS4,), jnp.float32).at[codes].add(1.0)
    centers = lo + (jnp.arange(NUM_BUCKETS4, dtype=jnp.float32) + 0.5) * width
    book = jnp.where(counts > 0, sums / jnp.maximum(counts, 1.0), centers)
    # pack pairs: pad to even length
    n = codes.shape[0]
    codes = jnp.pad(codes, (0, n % 2))
    pair = codes.reshape(-1, 2)
    packed = (pair[:, 0] * 16 + pair[:, 1]).astype(jnp.uint8)
    return Quantized4(packed, book)


def dequantize4(q: Quantized4, shape, dtype=jnp.float32) -> jnp.ndarray:
    p = q.packed.astype(jnp.int32)
    hi, lo = p // 16, p % 16
    codes = jnp.stack([hi, lo], axis=-1).reshape(-1)
    n = 1
    for d in shape:
        n *= d
    return q.codebook[codes[:n]].reshape(shape).astype(dtype)


def ef_compress(pg_flat: jnp.ndarray, residual: jnp.ndarray,
                quantize_fn, dequantize_fn):
    """Error-feedback wrapper: compress (pg + residual), return the wire
    payload and the new residual."""
    corrected = pg_flat + residual
    q = quantize_fn(corrected)
    deq = dequantize_fn(q)
    return q, corrected - deq


def init_residual(pg_flat_shape) -> jnp.ndarray:
    return jnp.zeros(pg_flat_shape, jnp.float32)
