"""PRIME core: the paper's contribution as composable JAX modules."""
from repro.core.diloco import (DiLoCoConfig, OuterState, SyncAbortedError,
                               bandwidth_reduction_factor,
                               init_outer_state, init_outer_state_sim,
                               outer_sync, outer_sync_sim, sync_wire_bytes)
from repro.core.elastic_mesh import ElasticDeviceMesh, SlotAssignment
from repro.core.fault_tolerance import (ClusterSimulator, EventKind,
                                        HeartbeatMonitor, NodeEvent,
                                        NodeState, QuarantinePolicy,
                                        RetryPolicy)
from repro.core.ring_reduce import (RingConfig, chunk_norms,
                                    ring_all_reduce, ring_wire_bytes,
                                    simulate_ring_all_reduce)
from repro.core.sync_engine import SyncEngine
from repro.core.topology import (BandwidthMonitor, cycle_bottleneck,
                                 exclude_slots, optimize_ring_order)
from repro.core.validation import (AdmissionReport, AdmissionStats,
                                   ValidationConfig, poison_pseudograd,
                                   validate_pseudograds)

__all__ = [
    "DiLoCoConfig", "OuterState", "SyncAbortedError", "init_outer_state",
    "init_outer_state_sim", "outer_sync", "outer_sync_sim",
    "sync_wire_bytes", "bandwidth_reduction_factor",
    "ElasticDeviceMesh", "SlotAssignment",
    "ClusterSimulator", "EventKind", "HeartbeatMonitor", "NodeEvent",
    "NodeState", "QuarantinePolicy", "RetryPolicy",
    "RingConfig", "chunk_norms", "ring_all_reduce", "ring_wire_bytes",
    "simulate_ring_all_reduce", "SyncEngine",
    "BandwidthMonitor", "cycle_bottleneck", "exclude_slots",
    "optimize_ring_order",
    "AdmissionReport", "AdmissionStats", "ValidationConfig",
    "poison_pseudograd", "validate_pseudograds",
]
