"""Public quantization API (re-export).

The actual implementations live in ``repro.kernels``:
  * ``kernels.ref``        — pure-jnp oracle (paper's exact scheme),
  * ``kernels.int8_quant`` — Pallas TPU kernels,
  * ``kernels.ops``        — jit'd wrappers with impl selection.
"""
from repro.kernels.ops import (Quantized, dequantize, dequantize_add,
                               quantize, quantize_pseudograd,
                               roundtrip_error)
from repro.kernels.ref import CLIP_SIGMAS, NUM_BUCKETS

__all__ = ["Quantized", "quantize", "dequantize", "dequantize_add",
           "quantize_pseudograd", "roundtrip_error", "NUM_BUCKETS",
           "CLIP_SIGMAS"]
