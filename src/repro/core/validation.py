"""Contribution-admission checks for the DiLoCo outer step.

Every outer sync reduces one pseudo-gradient per contributor
(``pg = anchor_flat - theta_flat``).  A single corrupted contribution —
NaN'd buffers, a mis-scaled optimizer, a bit-flipped frame — poisons the
ring reduce and silently destroys the shared anchor for *everyone*.
This module computes cheap, host-side admission checks on the
already-materialized pseudo-gradient rows *before* any reduced value is
applied:

1. **Finite guard** — any non-finite element disqualifies the row
   outright (and the row must be sanitized before a re-reduce, because
   ``NaN * 0.0 == NaN``: zero-weighting is NOT sufficient).
2. **Per-bucket norm gate** — per-bucket log10-norms are compared
   against running median + MAD statistics accumulated across accepted
   outer steps (cross-step gate), and against the median + MAD of the
   current population (within-step gate, which covers step-0 attacks
   before history is armed).
3. **Leave-one-out cosine gate** — each candidate's cosine against the
   sum of the *other* candidates; a strongly anti-aligned row (e.g. a
   sign-flipped contribution) is flagged.

All arithmetic is plain numpy float64 on host so the simulator and the
distributed ``shard_map`` path — which materialize bit-identical
pseudo-gradients via the shared ``_sim_pseudograds`` — reach
bit-identical admission decisions.

The per-bucket norms double as the *chunk-norm sideband*: the same
``ring_reduce.chunk_norms`` layout rides the ring frames hop-by-hop, so
a corrupted chunk can be localized to the slot that injected it.
"""

from __future__ import annotations

import dataclasses
from collections import deque
from dataclasses import dataclass, field

import numpy as np

__all__ = [
    "ValidationConfig",
    "AdmissionStats",
    "AdmissionReport",
    "validate_pseudograds",
    "poison_pseudograd",
    "POISON_MODES",
]

# Norms at or below this are treated as exactly zero in log space.
ZERO_EPS = 1e-30
# A bucket whose median log-norm sits at the zero floor carries no
# signal (padding, frozen params, empty slots) — the norm gates skip it.
ARMED_FLOOR = -25.0


@dataclass(frozen=True)
class ValidationConfig:
    """Knobs for the contribution-admission layer.

    The defaults are deliberately loose: a false quarantine costs a
    healthy contributor's compute for ``probation_steps`` outer rounds,
    while a missed soft corruption costs one averaged-down outer step.
    """

    enabled: bool = True
    #: Outer steps of accepted per-bucket log-norms kept for the
    #: cross-step median/MAD gate.
    norm_window: int = 8
    #: Accepted steps required before the cross-step gate arms.
    min_history: int = 2
    #: Norm gate threshold: median + max(norm_nmads * MAD, min_decades),
    #: upper side only, in log10 space.
    norm_nmads: float = 6.0
    #: Absolute floor on the norm-gate margin (decades). Guards against
    #: a hair-trigger MAD when the population is nearly identical.
    min_decades: float = 1.0
    #: Leave-one-out cosine below this flags the row.  -0.4 catches a
    #: sign-flipped contribution (whose LOO cosine is minus the natural
    #: alignment) without tripping on ordinary gradient noise.
    cos_threshold: float = -0.4
    #: Minimum candidates for the cosine gate to run.
    min_workers_cos: int = 3
    #: Minimum candidates for the *within-step* norm gate to run.
    min_workers_cross: int = 4


def _log_norms(rows: np.ndarray, buckets: int) -> np.ndarray:
    """Per-bucket log10 L2 norms, shape (k, buckets), float64.

    Rows are padded (with zeros) to a multiple of ``buckets`` so every
    bucket covers the same number of columns.
    """
    rows = np.asarray(rows, dtype=np.float64)
    k, n = rows.shape
    bsize = -(-n // buckets) if buckets > 0 else n
    pad = bsize * buckets - n
    if pad:
        rows = np.concatenate([rows, np.zeros((k, pad))], axis=1)
    # Non-finite values would swallow whole-bucket info; the finite gate
    # runs first, but be defensive so log_norms stays reportable.
    safe = np.nan_to_num(rows, nan=0.0, posinf=0.0, neginf=0.0)
    sq = safe.reshape(k, buckets, bsize)
    norms = np.sqrt(np.sum(sq * sq, axis=2))
    return np.log10(norms + ZERO_EPS)


def _median_mad(x: np.ndarray, axis: int = 0) -> tuple[np.ndarray, np.ndarray]:
    med = np.median(x, axis=axis)
    mad = np.median(np.abs(x - np.expand_dims(med, axis)), axis=axis)
    return med, mad


class AdmissionStats:
    """Running cross-step statistics of *accepted* contributions.

    Keeps the last ``norm_window`` outer steps' accepted per-bucket
    log-norm rows.  Purely deterministic: both the simulator and the
    distributed backend update it with the same accepted rows, so
    thresholds stay bit-identical across paths.
    """

    def __init__(self, cfg: ValidationConfig):
        self.cfg = cfg
        self.window: deque[np.ndarray] = deque(maxlen=cfg.norm_window)

    def thresholds(self, ncols: int) -> tuple[np.ndarray, np.ndarray] | None:
        """(median, mad) per bucket over the window, or None if unarmed."""
        rows = [w for w in self.window if w.shape[1] == ncols]
        if len(rows) < self.cfg.min_history:
            return None
        stacked = np.concatenate(rows, axis=0)
        if stacked.shape[0] < self.cfg.min_history:
            return None
        return _median_mad(stacked, axis=0)

    def update(self, report: "AdmissionReport") -> None:
        if report.accepted:
            idx = np.array(sorted(report.accepted), dtype=np.int64)
            self.window.append(report.log_norms[idx])


@dataclass
class AdmissionReport:
    """Outcome of one admission pass over a pseudo-gradient population."""

    #: Slots with nonzero weight this step (the judged population).
    candidates: list[int]
    #: slot -> list of reason strings ("nonfinite", "norm", "cosine").
    flagged: dict[int, list[str]]
    #: slot -> bucket columns that tripped the norm gate (localization).
    bad_chunks: dict[int, list[int]]
    #: Candidate slots that passed every gate.
    accepted: list[int]
    #: ALL slots whose rows must be zeroed before any re-reduce
    #: (flagged candidates plus non-finite non-candidates — a weight-0
    #: NaN row still poisons the reduce).
    sanitize: list[int]
    #: slot -> leave-one-out cosine (only for slots the gate judged).
    cosines: dict[int, float]
    #: (k, buckets) per-bucket log10 norms of every row.
    log_norms: np.ndarray
    #: Filled in by the trainer after mapping slots to node ids.
    quarantined_nodes: list[int] = field(default_factory=list)

    @property
    def clean(self) -> bool:
        return not self.sanitize


def validate_pseudograds(
    pgs: np.ndarray,
    weights: np.ndarray,
    bucket_norms: np.ndarray | None,
    stats: AdmissionStats | None,
    cfg: ValidationConfig,
) -> AdmissionReport:
    """Run the admission gates over one population of pseudo-gradients.

    Args:
      pgs: (k, n) pseudo-gradient rows (host array; any float dtype).
      weights: (k,) contribution weights; only slots with weight > 0 are
        candidates, but *every* row is checked for finiteness (a NaN row
        with weight 0 still contaminates the staged accumulators).
      bucket_norms: optional (k, ncols) per-chunk norm sideband
        (``ring_reduce.chunk_norms``).  When given it is used for the
        norm gates directly (so sim and distributed judge the identical
        sideband values); otherwise norms are derived from ``pgs``.
      stats: running cross-step statistics, or None for stateless use.
      cfg: thresholds.

    Gates run in order — finite, cross-step norm, within-step norm,
    leave-one-out cosine — with the pending-candidate set recomputed
    between gates so an already-flagged row never distorts a later gate.
    """
    pgs = np.asarray(pgs, dtype=np.float64)
    weights = np.asarray(weights, dtype=np.float64)
    k = pgs.shape[0]

    if bucket_norms is not None:
        log_norms = np.log10(np.asarray(bucket_norms, dtype=np.float64) + ZERO_EPS)
    else:
        log_norms = _log_norms(pgs, 1)
    ncols = log_norms.shape[1]

    candidates = [i for i in range(k) if weights[i] > 0.0]
    flagged: dict[int, list[str]] = {}
    bad_chunks: dict[int, list[int]] = {}
    cosines: dict[int, float] = {}
    sanitize: set[int] = set()

    def _flag(slot: int, reason: str) -> None:
        flagged.setdefault(slot, []).append(reason)
        sanitize.add(slot)

    # --- gate 1: finite guard (every row, candidate or not) -----------
    finite = np.isfinite(pgs).all(axis=1)
    for i in range(k):
        if not finite[i]:
            sanitize.add(i)
            if i in candidates:
                _flag(i, "nonfinite")

    def _pending() -> list[int]:
        return [i for i in candidates if i not in flagged]

    def _norm_gate(rows_idx: list[int], med, mad, reason: str) -> None:
        margin = np.maximum(cfg.norm_nmads * mad, cfg.min_decades)
        armed = med > ARMED_FLOOR
        for i in rows_idx:
            over = armed & (log_norms[i] > med + margin)
            if over.any():
                _flag(i, reason)
                bad_chunks.setdefault(i, []).extend(
                    int(c) for c in np.nonzero(over)[0]
                )

    # --- gate 2: cross-step norm gate --------------------------------
    if stats is not None:
        th = stats.thresholds(ncols)
        if th is not None:
            _norm_gate(_pending(), th[0], th[1], "norm")

    # --- gate 3: within-step population norm gate --------------------
    pend = _pending()
    if len(pend) >= cfg.min_workers_cross:
        med, mad = _median_mad(log_norms[np.array(pend, dtype=np.int64)], axis=0)
        _norm_gate(pend, med, mad, "norm")

    # --- gate 4: leave-one-out cosine gate ---------------------------
    pend = _pending()
    if len(pend) >= cfg.min_workers_cos:
        idx = np.array(pend, dtype=np.int64)
        rows = pgs[idx]
        total = rows.sum(axis=0)
        norms = np.sqrt(np.sum(rows * rows, axis=1))
        for j, i in enumerate(pend):
            rest = total - rows[j]
            rest_n = float(np.sqrt(np.sum(rest * rest)))
            denom = float(norms[j]) * rest_n
            if denom <= ZERO_EPS:
                continue
            c = float(np.dot(rows[j], rest) / denom)
            cosines[i] = c
            if c < cfg.cos_threshold:
                _flag(i, "cosine")

    accepted = [i for i in candidates if i not in flagged]
    # Dedup bad-chunk columns while preserving order.
    bad_chunks = {s: sorted(set(cols)) for s, cols in bad_chunks.items()}
    return AdmissionReport(
        candidates=candidates,
        flagged=flagged,
        bad_chunks=bad_chunks,
        accepted=accepted,
        sanitize=sorted(sanitize),
        cosines=cosines,
        log_norms=log_norms,
    )


# ---------------------------------------------------------------------------
# Poison injection (fault harness / ClusterSimulator POISON events)
# ---------------------------------------------------------------------------

POISON_MODES = ("nan", "huge", "signflip", "bitflip")


def poison_pseudograd(pg: np.ndarray, mode: str, rng: np.random.Generator) -> np.ndarray:
    """Corrupt one pseudo-gradient row the way a faulty peer would.

    Modes mirror real open-run failure classes: NaN'd buffers from a
    diverged inner phase ("nan"), a mis-scaled optimizer or fp16
    overflow ("huge"), an adversarial anti-update ("signflip"), and a
    corrupted wire frame ("bitflip" — flips the float32 exponent MSB of
    scattered elements, the classic silent-corruption signature).
    """
    out = np.array(pg, dtype=np.float32, copy=True)
    n = out.size
    if mode == "nan":
        idx = rng.choice(n, size=max(1, n // 64), replace=False)
        out[idx] = np.nan
    elif mode == "huge":
        out *= np.float32(1e6)
    elif mode == "signflip":
        out = -out
    elif mode == "bitflip":
        idx = rng.choice(n, size=max(1, n // 128), replace=False)
        bits = out.view(np.uint32)
        bits[idx] ^= np.uint32(1 << 30)
    else:
        raise ValueError(f"unknown poison mode: {mode!r}")
    return out
