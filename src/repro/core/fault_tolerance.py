"""Fault tolerance and dynamic node management (INTELLECT-1 §2.4).

Deterministic (logical-clock) re-implementation of PRIME's mechanisms:

  * **HeartbeatMonitor** — each node heartbeats every ``interval`` (paper:
    2 s); nodes silent for ``timeout`` (paper: 6 s) are evicted. A
    *deathrattle* triggers immediate eviction (graceful exit).
  * **MembershipLog** — the master key-value store's view of the world;
    joins take effect only at outer-step boundaries (the paper admits
    joiners "at the next outer step with zero pseudo-gradients").
  * **RetryPolicy** — all-reduce retry excluding failed workers
    (paper §2.4.5), with bounded attempts.
  * **ClusterSimulator** — drives a schedule of join/leave/crash/
    straggler events against an elastic training loop; used by the
    resilience benchmark (paper Fig. 5: 4 -> 14 nodes) and the
    integration tests. With the overlapped outer sync (PR 5) it also
    tracks the sync IN FLIGHT across the phase boundary
    (``note_sync_begin``): a participant dying while its reduction is
    on the wire surfaces as ``plan["sync_torn"]`` so the trainer falls
    back to a synchronous re-reduction instead of applying a torn
    partial accumulator.
  * **CommOverlapLedger** — logical-time accounting of ring-hop
    transfers hidden under chunked inner compute (the paper's 83–96%
    compute-utilization claim): hops queue on a modeled WAN link and
    drain while compute windows advance the clock; whatever is still
    on the wire at ``finish_sync`` is exposed stall time.

Nothing here touches wall-clock time: time is an explicit float so tests
are deterministic.
"""
from __future__ import annotations

import dataclasses
import enum
import warnings
from typing import Callable, Iterable


class NodeState(enum.Enum):
    JOINING = "joining"      # downloading checkpoint (P2P), not yet live
    LIVE = "live"
    LEFT = "left"            # graceful (deathrattle)
    DEAD = "dead"            # evicted by heartbeat timeout
    QUARANTINED = "quarantined"  # admission violation: excluded from the
    #                              sync (zero weight, tail of the ring)
    #                              but still heartbeating; re-admitted on
    #                              probation after N clean outer steps


@dataclasses.dataclass
class Node:
    node_id: int
    state: NodeState = NodeState.JOINING
    last_heartbeat: float = -1.0
    joined_at: float = 0.0
    # -- contribution reputation (untrusted-contributor defense) -----------
    violations: int = 0        # admission checks failed, lifetime
    clean_credits: int = 0     # contributions accepted, lifetime
    quarantines: int = 0       # times quarantined (escalates probation)
    quarantine_steps: int = 0  # outer steps served in CURRENT quarantine

    @property
    def reputation(self) -> float:
        """Accepted fraction of judged contributions in [0, 1]
        (1.0 for a node never judged)."""
        judged = self.violations + self.clean_credits
        return self.clean_credits / judged if judged else 1.0


class HeartbeatMonitor:
    """Paper §2.4.3: 2 s heartbeats, 6 s eviction, deathrattle fast path."""

    def __init__(self, interval: float = 2.0, timeout: float = 6.0):
        assert timeout > interval
        self.interval = interval
        self.timeout = timeout
        self.nodes: dict[int, Node] = {}

    def register(self, node_id: int, now: float) -> Node:
        node = Node(node_id, NodeState.JOINING, last_heartbeat=now,
                    joined_at=now)
        self.nodes[node_id] = node
        return node

    def mark_live(self, node_id: int) -> None:
        self.nodes[node_id].state = NodeState.LIVE

    def heartbeat(self, node_id: int, now: float) -> None:
        n = self.nodes.get(node_id)
        if n is not None and n.state in (NodeState.LIVE, NodeState.JOINING,
                                         NodeState.QUARANTINED):
            n.last_heartbeat = now

    def deathrattle(self, node_id: int) -> None:
        n = self.nodes.get(node_id)
        if n is not None:
            n.state = NodeState.LEFT

    def sweep(self, now: float) -> list[int]:
        """Evict nodes whose heartbeat is older than ``timeout``;
        returns the newly evicted ids."""
        evicted = []
        for n in self.nodes.values():
            if n.state in (NodeState.LIVE, NodeState.JOINING,
                           NodeState.QUARANTINED) and \
                    now - n.last_heartbeat > self.timeout:
                n.state = NodeState.DEAD
                evicted.append(n.node_id)
        return evicted

    def live_ids(self) -> list[int]:
        return sorted(n.node_id for n in self.nodes.values()
                      if n.state == NodeState.LIVE)

    def quarantined_ids(self) -> list[int]:
        return sorted(n.node_id for n in self.nodes.values()
                      if n.state == NodeState.QUARANTINED)


@dataclasses.dataclass(frozen=True)
class RetryPolicy:
    max_attempts: int = 3

    def run_collective(self, attempt_fn: Callable[[frozenset], object],
                       participants: Iterable[int],
                       failures_by_attempt: Callable[[int, frozenset],
                                                     frozenset] = None):
        """Run ``attempt_fn(live_set)``, excluding nodes that fail
        mid-collective and retrying with the survivors (paper §2.4.5).

        ``failures_by_attempt(attempt, live)`` models which nodes die
        during a given attempt (empty set = success). Returns
        (result, final_live_set, attempts_used)."""
        live = frozenset(participants)
        for attempt in range(self.max_attempts):
            failed = (failures_by_attempt(attempt, live)
                      if failures_by_attempt else frozenset())
            failed = frozenset(failed) & live
            if not failed:
                return attempt_fn(live), live, attempt + 1
            live = live - failed
            if not live:
                break
        raise RuntimeError(
            f"collective failed after {self.max_attempts} attempts")


# -- event-driven cluster simulation ------------------------------------------


class EventKind(enum.Enum):
    JOIN = "join"                  # new node requests onboarding
    LEAVE = "leave"                # graceful deathrattle
    CRASH = "crash"                # heartbeats stop silently
    STRAGGLE = "straggle"          # node too slow for this outer sync
    ANNOUNCE = "announce"          # node intends to join soon: start
    #                                streaming its checkpoint NOW so the
    #                                fetch overlaps the inner phases
    #                                before its JOIN boundary
    STALL = "stall"                # a node's serving link stalls (its
    #                                ChunkPeer stops answering for a
    #                                while); membership is unaffected —
    #                                subscribers throttle/kill the peer
    POISON = "poison"              # node's contribution is corrupted
    #                                this outer step (arg = mode:
    #                                'nan' | 'huge' | 'signflip' |
    #                                'bitflip'); membership unchanged —
    #                                the admission layer must catch it


@dataclasses.dataclass(frozen=True)
class NodeEvent:
    outer_step: int
    kind: EventKind
    node_id: int
    arg: str = ""                  # kind-specific payload (POISON mode)


@dataclasses.dataclass(frozen=True)
class QuarantinePolicy:
    """Quarantine / probation knobs for the contribution-admission
    layer. A violating node is QUARANTINED immediately (zero sync
    weight, tail of the ring); after ``probation_steps`` outer steps of
    quarantine it is re-admitted as a joiner (anchor reset, zero-weight
    first round). Repeat offenders serve escalating probations:
    ``probation_steps * escalation**(quarantines - 1)``, capped at
    ``max_probation_steps``."""

    probation_steps: int = 2
    escalation: float = 2.0
    max_probation_steps: int = 16

    def required_steps(self, quarantines: int) -> int:
        n = self.probation_steps * self.escalation ** max(
            0, quarantines - 1)
        return min(int(n), self.max_probation_steps)


class ClusterSimulator:
    """Replays a membership schedule against an elastic DiLoCo loop.

    The trainer calls ``begin_outer_step``/``end_outer_step``; the
    simulator advances logical time, injects heartbeats for healthy
    nodes, applies scheduled events, and reports the live worker set the
    ring must use for this sync (stragglers excluded for one round)."""

    def __init__(self, initial_nodes: Iterable[int],
                 events: Iterable[NodeEvent] = (),
                 heartbeat: HeartbeatMonitor | None = None,
                 seconds_per_outer_step: float = 60.0,
                 quarantine: QuarantinePolicy | None = None):
        self.hb = heartbeat or HeartbeatMonitor()
        self.events = sorted(events, key=lambda e: e.outer_step)
        self.now = 0.0
        self.dt = seconds_per_outer_step
        self.crashed: set[int] = set()
        self.history: list[tuple[int, tuple[int, ...]]] = []
        # side-effect hooks fired as each event is applied — the
        # recovery tests use these to kill a node's ChunkPeer the
        # moment its CRASH event lands (so a swarm fetch in flight
        # loses that peer mid-transfer)
        self._subscribers: list[Callable[[NodeEvent], None]] = []
        self._inflight_sync: dict | None = None
        self.quarantine = quarantine or QuarantinePolicy()
        # (outer_step, node_id, reasons) of every recorded violation
        self.violations: list[tuple[int, int, tuple[str, ...]]] = []
        for nid in initial_nodes:
            self.hb.register(nid, self.now)
            self.hb.mark_live(nid)

    def subscribe(self, fn: Callable[[NodeEvent], None]) -> None:
        """Call ``fn(event)`` whenever an event is applied. A raising
        subscriber is DROPPED (and warned about) rather than wedging
        the event pump — one faulty observer must not take the
        membership machinery down with it."""
        self._subscribers.append(fn)

    def _notify(self, ev: NodeEvent) -> None:
        for fn in list(self._subscribers):
            try:
                fn(ev)
            except Exception as e:  # noqa: BLE001 — isolation boundary
                try:
                    self._subscribers.remove(fn)
                except ValueError:
                    pass
                warnings.warn(
                    f"ClusterSimulator subscriber {fn!r} raised "
                    f"{type(e).__name__}: {e} — dropped", RuntimeWarning,
                    stacklevel=2)

    # -- contribution admission / quarantine ---------------------------------

    def record_violation(self, node_id: int, outer_step: int,
                         reasons: Iterable[str] = ()) -> bool:
        """The admission layer rejected this node's contribution:
        quarantine it (LIVE nodes only). Returns True iff the node
        transitioned to QUARANTINED."""
        n = self.hb.nodes.get(node_id)
        self.violations.append((outer_step, node_id, tuple(reasons)))
        if n is None or n.state != NodeState.LIVE:
            return False
        n.violations += 1
        n.quarantines += 1
        n.quarantine_steps = 0
        n.state = NodeState.QUARANTINED
        return True

    def record_clean(self, node_ids: Iterable[int]) -> None:
        """The admission layer accepted these nodes' contributions."""
        for nid in node_ids:
            n = self.hb.nodes.get(nid)
            if n is not None and n.state == NodeState.LIVE:
                n.clean_credits += 1

    def quarantined_ids(self) -> list[int]:
        return self.hb.quarantined_ids()

    # -- in-flight overlapped sync -------------------------------------------

    def note_sync_begin(self, outer_step: int,
                        participants: Iterable[int]) -> None:
        """The trainer kicked off an overlapped outer sync at this
        boundary; its ring hops ride under the NEXT inner phase. Until
        ``note_sync_end``, any participant leaving the cluster tears
        the in-flight reduction (reported via ``plan['sync_torn']``)."""
        self._inflight_sync = {"outer_step": outer_step,
                               "nodes": frozenset(participants)}

    def note_sync_end(self) -> None:
        """The in-flight sync was applied (or abandoned)."""
        self._inflight_sync = None

    @property
    def inflight_sync(self) -> dict | None:
        return self._inflight_sync

    def begin_outer_step(self, outer_step: int) -> dict:
        """Apply events for this step; return the sync plan:
        {'live': [...], 'stragglers': [...], 'joined': [...],
        'left': [...], 'announced': [...], 'sync_torn': [...],
        'quarantined': [...], 'readmitted': [...], 'poison': {...}}.

        ``sync_torn`` lists in-flight-sync participants that left the
        cluster at this boundary (crash eviction or graceful leave
        while their pseudo-gradient reduction was still on the wire).
        ``quarantined`` lists nodes serving quarantine THIS step;
        ``readmitted`` lists nodes whose probation completed at this
        boundary (the trainer treats them exactly like joiners: anchor
        reset, zero-weight first round). ``poison`` maps node id ->
        corruption mode the harness injects into that node's
        contribution this step."""
        # -- probation: quarantined nodes serve one step per boundary;
        # completed probations re-admit as joiners
        readmitted = []
        for nid in self.hb.quarantined_ids():
            n = self.hb.nodes[nid]
            n.quarantine_steps += 1
            if n.quarantine_steps >= self.quarantine.required_steps(
                    n.quarantines):
                n.state = NodeState.LIVE
                n.quarantine_steps = 0
                readmitted.append(nid)

        joined, left, stragglers, announced = [], [], [], []
        poison: dict[int, str] = {}
        for ev in self.events:
            if ev.outer_step != outer_step:
                continue
            self._notify(ev)
            if ev.kind in (EventKind.ANNOUNCE, EventKind.STALL):
                # no membership change: ANNOUNCE kicks off a streaming
                # fetch via the subscriber hooks; STALL is a peer-level
                # fault the hooks inject into the serving ChunkPeer
                if ev.kind == EventKind.ANNOUNCE:
                    announced.append(ev.node_id)
            elif ev.kind == EventKind.JOIN:
                self.hb.register(ev.node_id, self.now)
                # joiner downloads a checkpoint P2P, becomes live at THIS
                # boundary with zero pseudo-gradient (paper non-blocking)
                self.hb.mark_live(ev.node_id)
                joined.append(ev.node_id)
            elif ev.kind == EventKind.LEAVE:
                self.hb.deathrattle(ev.node_id)
                left.append(ev.node_id)
            elif ev.kind == EventKind.CRASH:
                self.crashed.add(ev.node_id)
            elif ev.kind == EventKind.STRAGGLE:
                stragglers.append(ev.node_id)
            elif ev.kind == EventKind.POISON:
                poison[ev.node_id] = ev.arg or "nan"

        # advance logical time by one inner phase; crashed nodes stop
        # heartbeating and age out (6 s timeout << 38 min inner phase).
        # Quarantined nodes KEEP heartbeating: they are excluded from
        # the sync, not from the cluster.
        self.now += self.dt
        for nid in self.hb.live_ids() + self.hb.quarantined_ids():
            if nid not in self.crashed:
                self.hb.heartbeat(nid, self.now)
        evicted = self.hb.sweep(self.now)
        left.extend(evicted)

        live = self.hb.live_ids()
        self.history.append((outer_step, tuple(live)))
        torn: list[int] = []
        if self._inflight_sync is not None:
            torn = sorted(self._inflight_sync["nodes"] & set(left))
        return {"live": live,
                "stragglers": [s for s in stragglers if s in live],
                "joined": joined, "left": sorted(set(left)),
                "announced": announced, "sync_torn": torn,
                "quarantined": self.hb.quarantined_ids(),
                "readmitted": [r for r in readmitted
                               if r in live],
                "poison": poison}


# -- logical-time overlap accounting ------------------------------------------


class CommOverlapLedger:
    """Models ring-hop transfers on a WAN link running concurrently
    with (chunked) inner compute, in the simulator's logical time.

    The wire is a serial resource: a dispatched hop starts when the
    link frees up (``max(clock, busy)``) and occupies it for the hop's
    transfer time. Compute windows advance ``clock`` without touching
    the link, so transfers in flight during compute are HIDDEN; at
    ``finish_sync`` whatever the link still owes past the clock is
    EXPOSED stall time (the cluster waits at the boundary). This is the
    quantity the paper's 83–96% compute-utilization figures hide.
    """

    def __init__(self):
        self.clock = 0.0            # logical time consumed by compute
        self.busy_until = 0.0       # when the wire frees up
        self.records: list[dict] = []
        self._cur: dict | None = None

    def begin_sync(self, hop_seconds: float) -> None:
        """A new outer sync's comm window opens (at the boundary).
        ``hop_seconds`` is the default per-hop transfer time; individual
        hops may override it via ``dispatch_hop(seconds=...)``."""
        assert self._cur is None, "previous sync window still open"
        self._cur = {"hop_s": float(hop_seconds), "hops": 0,
                     "charged_s": 0.0, "t_open": self.clock}

    def dispatch_hop(self, n: int = 1, seconds: float | None = None) -> None:
        """``n`` ring hops handed to the wire at the current clock.
        ``seconds`` charges each of these hops its ACTUAL transfer time
        (hop payloads are uneven when bucket sub-chunks don't divide the
        shard, and each hop crosses a different link); None falls back to
        the window's uniform ``hop_seconds``."""
        assert self._cur is not None, "no sync window open"
        hop_s = self._cur["hop_s"] if seconds is None else float(seconds)
        for _ in range(n):
            self.busy_until = max(self.busy_until, self.clock) + hop_s
            self._cur["hops"] += 1
            self._cur["charged_s"] += hop_s

    def compute(self, seconds: float) -> None:
        """A compute window (inner-phase scan chunk) ran."""
        self.clock += float(seconds)

    def finish_sync(self) -> dict:
        """Close the window: the wire's remaining debt is exposed."""
        assert self._cur is not None, "no sync window open"
        cur, self._cur = self._cur, None
        total = cur["charged_s"]
        exposed = max(0.0, self.busy_until - self.clock)
        exposed = min(exposed, total)   # debt older than this window
        #                                 belongs to earlier records
        self.clock = max(self.clock, self.busy_until)
        rec = {"comm_total_s": total, "comm_exposed_s": exposed,
               "comm_hidden_s": total - exposed,
               "hidden_frac": (total - exposed) / total if total else 1.0,
               "hops": cur["hops"], "torn": False}
        self.records.append(rec)
        return rec

    def tear_sync(self, resync_hops: int) -> dict:
        """The in-flight sync was torn by a death: its partial comm is
        discarded and the synchronous re-reduction of ``resync_hops``
        hops runs fully exposed at the boundary."""
        assert self._cur is not None, "no sync window open"
        cur, self._cur = self._cur, None
        total = resync_hops * cur["hop_s"]
        self.busy_until = max(self.busy_until, self.clock)
        self.clock += total
        self.busy_until = self.clock
        rec = {"comm_total_s": total, "comm_exposed_s": total,
               "comm_hidden_s": 0.0, "hidden_frac": 0.0,
               "hops": resync_hops, "torn": True}
        self.records.append(rec)
        return rec

    @property
    def hidden_fraction(self) -> float:
        """Aggregate hidden fraction over every closed sync window."""
        total = sum(r["comm_total_s"] for r in self.records)
        hidden = sum(r["comm_hidden_s"] for r in self.records)
        return hidden / total if total else 1.0
