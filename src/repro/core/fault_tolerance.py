"""Fault tolerance and dynamic node management (INTELLECT-1 §2.4).

Deterministic (logical-clock) re-implementation of PRIME's mechanisms:

  * **HeartbeatMonitor** — each node heartbeats every ``interval`` (paper:
    2 s); nodes silent for ``timeout`` (paper: 6 s) are evicted. A
    *deathrattle* triggers immediate eviction (graceful exit).
  * **MembershipLog** — the master key-value store's view of the world;
    joins take effect only at outer-step boundaries (the paper admits
    joiners "at the next outer step with zero pseudo-gradients").
  * **RetryPolicy** — all-reduce retry excluding failed workers
    (paper §2.4.5), with bounded attempts.
  * **ClusterSimulator** — drives a schedule of join/leave/crash/
    straggler events against an elastic training loop; used by the
    resilience benchmark (paper Fig. 5: 4 -> 14 nodes) and the
    integration tests. With the overlapped outer sync (PR 5) it also
    tracks the sync IN FLIGHT across the phase boundary
    (``note_sync_begin``): a participant dying while its reduction is
    on the wire surfaces as ``plan["sync_torn"]`` so the trainer falls
    back to a synchronous re-reduction instead of applying a torn
    partial accumulator.
  * **CommOverlapLedger** — logical-time accounting of ring-hop
    transfers hidden under chunked inner compute (the paper's 83–96%
    compute-utilization claim): hops queue on a modeled WAN link and
    drain while compute windows advance the clock; whatever is still
    on the wire at ``finish_sync`` is exposed stall time.

Nothing here touches wall-clock time: time is an explicit float so tests
are deterministic.
"""
from __future__ import annotations

import dataclasses
import enum
from typing import Callable, Iterable


class NodeState(enum.Enum):
    JOINING = "joining"      # downloading checkpoint (P2P), not yet live
    LIVE = "live"
    LEFT = "left"            # graceful (deathrattle)
    DEAD = "dead"            # evicted by heartbeat timeout


@dataclasses.dataclass
class Node:
    node_id: int
    state: NodeState = NodeState.JOINING
    last_heartbeat: float = -1.0
    joined_at: float = 0.0


class HeartbeatMonitor:
    """Paper §2.4.3: 2 s heartbeats, 6 s eviction, deathrattle fast path."""

    def __init__(self, interval: float = 2.0, timeout: float = 6.0):
        assert timeout > interval
        self.interval = interval
        self.timeout = timeout
        self.nodes: dict[int, Node] = {}

    def register(self, node_id: int, now: float) -> Node:
        node = Node(node_id, NodeState.JOINING, last_heartbeat=now,
                    joined_at=now)
        self.nodes[node_id] = node
        return node

    def mark_live(self, node_id: int) -> None:
        self.nodes[node_id].state = NodeState.LIVE

    def heartbeat(self, node_id: int, now: float) -> None:
        n = self.nodes.get(node_id)
        if n is not None and n.state in (NodeState.LIVE, NodeState.JOINING):
            n.last_heartbeat = now

    def deathrattle(self, node_id: int) -> None:
        n = self.nodes.get(node_id)
        if n is not None:
            n.state = NodeState.LEFT

    def sweep(self, now: float) -> list[int]:
        """Evict nodes whose heartbeat is older than ``timeout``;
        returns the newly evicted ids."""
        evicted = []
        for n in self.nodes.values():
            if n.state in (NodeState.LIVE, NodeState.JOINING) and \
                    now - n.last_heartbeat > self.timeout:
                n.state = NodeState.DEAD
                evicted.append(n.node_id)
        return evicted

    def live_ids(self) -> list[int]:
        return sorted(n.node_id for n in self.nodes.values()
                      if n.state == NodeState.LIVE)


@dataclasses.dataclass(frozen=True)
class RetryPolicy:
    max_attempts: int = 3

    def run_collective(self, attempt_fn: Callable[[frozenset], object],
                       participants: Iterable[int],
                       failures_by_attempt: Callable[[int, frozenset],
                                                     frozenset] = None):
        """Run ``attempt_fn(live_set)``, excluding nodes that fail
        mid-collective and retrying with the survivors (paper §2.4.5).

        ``failures_by_attempt(attempt, live)`` models which nodes die
        during a given attempt (empty set = success). Returns
        (result, final_live_set, attempts_used)."""
        live = frozenset(participants)
        for attempt in range(self.max_attempts):
            failed = (failures_by_attempt(attempt, live)
                      if failures_by_attempt else frozenset())
            failed = frozenset(failed) & live
            if not failed:
                return attempt_fn(live), live, attempt + 1
            live = live - failed
            if not live:
                break
        raise RuntimeError(
            f"collective failed after {self.max_attempts} attempts")


# -- event-driven cluster simulation ------------------------------------------


class EventKind(enum.Enum):
    JOIN = "join"                  # new node requests onboarding
    LEAVE = "leave"                # graceful deathrattle
    CRASH = "crash"                # heartbeats stop silently
    STRAGGLE = "straggle"          # node too slow for this outer sync
    ANNOUNCE = "announce"          # node intends to join soon: start
    #                                streaming its checkpoint NOW so the
    #                                fetch overlaps the inner phases
    #                                before its JOIN boundary
    STALL = "stall"                # a node's serving link stalls (its
    #                                ChunkPeer stops answering for a
    #                                while); membership is unaffected —
    #                                subscribers throttle/kill the peer


@dataclasses.dataclass(frozen=True)
class NodeEvent:
    outer_step: int
    kind: EventKind
    node_id: int


class ClusterSimulator:
    """Replays a membership schedule against an elastic DiLoCo loop.

    The trainer calls ``begin_outer_step``/``end_outer_step``; the
    simulator advances logical time, injects heartbeats for healthy
    nodes, applies scheduled events, and reports the live worker set the
    ring must use for this sync (stragglers excluded for one round)."""

    def __init__(self, initial_nodes: Iterable[int],
                 events: Iterable[NodeEvent] = (),
                 heartbeat: HeartbeatMonitor | None = None,
                 seconds_per_outer_step: float = 60.0):
        self.hb = heartbeat or HeartbeatMonitor()
        self.events = sorted(events, key=lambda e: e.outer_step)
        self.now = 0.0
        self.dt = seconds_per_outer_step
        self.crashed: set[int] = set()
        self.history: list[tuple[int, tuple[int, ...]]] = []
        # side-effect hooks fired as each event is applied — the
        # recovery tests use these to kill a node's ChunkPeer the
        # moment its CRASH event lands (so a swarm fetch in flight
        # loses that peer mid-transfer)
        self._subscribers: list[Callable[[NodeEvent], None]] = []
        self._inflight_sync: dict | None = None
        for nid in initial_nodes:
            self.hb.register(nid, self.now)
            self.hb.mark_live(nid)

    def subscribe(self, fn: Callable[[NodeEvent], None]) -> None:
        """Call ``fn(event)`` whenever an event is applied."""
        self._subscribers.append(fn)

    # -- in-flight overlapped sync -------------------------------------------

    def note_sync_begin(self, outer_step: int,
                        participants: Iterable[int]) -> None:
        """The trainer kicked off an overlapped outer sync at this
        boundary; its ring hops ride under the NEXT inner phase. Until
        ``note_sync_end``, any participant leaving the cluster tears
        the in-flight reduction (reported via ``plan['sync_torn']``)."""
        self._inflight_sync = {"outer_step": outer_step,
                               "nodes": frozenset(participants)}

    def note_sync_end(self) -> None:
        """The in-flight sync was applied (or abandoned)."""
        self._inflight_sync = None

    @property
    def inflight_sync(self) -> dict | None:
        return self._inflight_sync

    def begin_outer_step(self, outer_step: int) -> dict:
        """Apply events for this step; return the sync plan:
        {'live': [...], 'stragglers': [...], 'joined': [...],
        'left': [...], 'announced': [...], 'sync_torn': [...]}.

        ``sync_torn`` lists in-flight-sync participants that left the
        cluster at this boundary (crash eviction or graceful leave
        while their pseudo-gradient reduction was still on the wire)."""
        joined, left, stragglers, announced = [], [], [], []
        for ev in self.events:
            if ev.outer_step != outer_step:
                continue
            for fn in self._subscribers:
                fn(ev)
            if ev.kind in (EventKind.ANNOUNCE, EventKind.STALL):
                # no membership change: ANNOUNCE kicks off a streaming
                # fetch via the subscriber hooks; STALL is a peer-level
                # fault the hooks inject into the serving ChunkPeer
                if ev.kind == EventKind.ANNOUNCE:
                    announced.append(ev.node_id)
            elif ev.kind == EventKind.JOIN:
                self.hb.register(ev.node_id, self.now)
                # joiner downloads a checkpoint P2P, becomes live at THIS
                # boundary with zero pseudo-gradient (paper non-blocking)
                self.hb.mark_live(ev.node_id)
                joined.append(ev.node_id)
            elif ev.kind == EventKind.LEAVE:
                self.hb.deathrattle(ev.node_id)
                left.append(ev.node_id)
            elif ev.kind == EventKind.CRASH:
                self.crashed.add(ev.node_id)
            elif ev.kind == EventKind.STRAGGLE:
                stragglers.append(ev.node_id)

        # advance logical time by one inner phase; crashed nodes stop
        # heartbeating and age out (6 s timeout << 38 min inner phase)
        self.now += self.dt
        for nid in self.hb.live_ids():
            if nid not in self.crashed:
                self.hb.heartbeat(nid, self.now)
        evicted = self.hb.sweep(self.now)
        left.extend(evicted)

        live = self.hb.live_ids()
        self.history.append((outer_step, tuple(live)))
        torn: list[int] = []
        if self._inflight_sync is not None:
            torn = sorted(self._inflight_sync["nodes"] & set(left))
        return {"live": live,
                "stragglers": [s for s in stragglers if s in live],
                "joined": joined, "left": sorted(set(left)),
                "announced": announced, "sync_torn": torn}


# -- logical-time overlap accounting ------------------------------------------


class CommOverlapLedger:
    """Models ring-hop transfers on a WAN link running concurrently
    with (chunked) inner compute, in the simulator's logical time.

    The wire is a serial resource: a dispatched hop starts when the
    link frees up (``max(clock, busy)``) and occupies it for the hop's
    transfer time. Compute windows advance ``clock`` without touching
    the link, so transfers in flight during compute are HIDDEN; at
    ``finish_sync`` whatever the link still owes past the clock is
    EXPOSED stall time (the cluster waits at the boundary). This is the
    quantity the paper's 83–96% compute-utilization figures hide.
    """

    def __init__(self):
        self.clock = 0.0            # logical time consumed by compute
        self.busy_until = 0.0       # when the wire frees up
        self.records: list[dict] = []
        self._cur: dict | None = None

    def begin_sync(self, hop_seconds: float) -> None:
        """A new outer sync's comm window opens (at the boundary).
        ``hop_seconds`` is the default per-hop transfer time; individual
        hops may override it via ``dispatch_hop(seconds=...)``."""
        assert self._cur is None, "previous sync window still open"
        self._cur = {"hop_s": float(hop_seconds), "hops": 0,
                     "charged_s": 0.0, "t_open": self.clock}

    def dispatch_hop(self, n: int = 1, seconds: float | None = None) -> None:
        """``n`` ring hops handed to the wire at the current clock.
        ``seconds`` charges each of these hops its ACTUAL transfer time
        (hop payloads are uneven when bucket sub-chunks don't divide the
        shard, and each hop crosses a different link); None falls back to
        the window's uniform ``hop_seconds``."""
        assert self._cur is not None, "no sync window open"
        hop_s = self._cur["hop_s"] if seconds is None else float(seconds)
        for _ in range(n):
            self.busy_until = max(self.busy_until, self.clock) + hop_s
            self._cur["hops"] += 1
            self._cur["charged_s"] += hop_s

    def compute(self, seconds: float) -> None:
        """A compute window (inner-phase scan chunk) ran."""
        self.clock += float(seconds)

    def finish_sync(self) -> dict:
        """Close the window: the wire's remaining debt is exposed."""
        assert self._cur is not None, "no sync window open"
        cur, self._cur = self._cur, None
        total = cur["charged_s"]
        exposed = max(0.0, self.busy_until - self.clock)
        exposed = min(exposed, total)   # debt older than this window
        #                                 belongs to earlier records
        self.clock = max(self.clock, self.busy_until)
        rec = {"comm_total_s": total, "comm_exposed_s": exposed,
               "comm_hidden_s": total - exposed,
               "hidden_frac": (total - exposed) / total if total else 1.0,
               "hops": cur["hops"], "torn": False}
        self.records.append(rec)
        return rec

    def tear_sync(self, resync_hops: int) -> dict:
        """The in-flight sync was torn by a death: its partial comm is
        discarded and the synchronous re-reduction of ``resync_hops``
        hops runs fully exposed at the boundary."""
        assert self._cur is not None, "no sync window open"
        cur, self._cur = self._cur, None
        total = resync_hops * cur["hop_s"]
        self.busy_until = max(self.busy_until, self.clock)
        self.clock += total
        self.busy_until = self.clock
        rec = {"comm_total_s": total, "comm_exposed_s": total,
               "comm_hidden_s": 0.0, "hidden_frac": 0.0,
               "hops": resync_hops, "torn": True}
        self.records.append(rec)
        return rec

    @property
    def hidden_fraction(self) -> float:
        """Aggregate hidden fraction over every closed sync window."""
        total = sum(r["comm_total_s"] for r in self.records)
        hidden = sum(r["comm_hidden_s"] for r in self.records)
        return hidden / total if total else 1.0
