"""ElasticDeviceMesh (INTELLECT-1 §2.4, Fig. 1).

The paper's ElasticDeviceMesh gives every process a *local* rank (FSDP
process group, fast intra-node fabric) and a *global* rank (fault-
tolerant DiLoCo data-parallel group over the internet). The TPU-native
analogue:

  * the **mesh axes** play the roles of the process groups: the DiLoCo
    axis ('pod' across pods / 'data' inside one) is the global group,
    the remaining axes ('data'/'model') are the local FSDP/TP groups;
  * JAX cannot resize a mesh inside a compiled program, so elasticity is
    realized two ways, both at outer-step boundaries (the only points
    the paper changes membership either):
      - **mask-and-renormalize** inside a fixed-capacity mesh: every
        DiLoCo slot has a weight in {0, 1}; dead/empty/joining slots
        contribute weight 0 and the ring average divides by the live
        weight sum (exactly the paper's "join with zero pseudo-
        gradient" + "exclude failed nodes" semantics);
      - **remesh**: build a smaller/larger mesh over the healthy
        hardware and recompile (the paper pays an analogous cost:
        process-group reinit + NCCL/Gloo re-rendezvous).
  * node ids (stable across the run, paper's global ranks) are mapped to
    mesh slots by ``SlotAssignment``; a node that dies frees its slot.
"""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np


@dataclasses.dataclass
class SlotAssignment:
    """Stable node-id -> DiLoCo-slot mapping with free-list reuse."""

    capacity: int
    slot_of: dict[int, int] = dataclasses.field(default_factory=dict)

    def assign(self, node_id: int) -> int:
        if node_id in self.slot_of:
            return self.slot_of[node_id]
        used = set(self.slot_of.values())
        for s in range(self.capacity):
            if s not in used:
                self.slot_of[node_id] = s
                return s
        raise RuntimeError("ElasticDeviceMesh at capacity; "
                           "remesh with a larger DiLoCo axis")

    def release(self, node_id: int) -> None:
        self.slot_of.pop(node_id, None)

    def live_mask(self, live_ids, zero_weight_ids=()) -> np.ndarray:
        mask = np.zeros((self.capacity,), np.float32)
        for nid in live_ids:
            if nid in self.slot_of and nid not in zero_weight_ids:
                mask[self.slot_of[nid]] = 1.0
        return mask


@dataclasses.dataclass(frozen=True)
class HierarchySpec:
    """The paper's ElasticDeviceMesh split of the outer sync: the WAN
    ring runs ONLY across the DiLoCo axis (one leader stream per site),
    while the remaining mesh axes form the fast intra-node group. The
    distributed sync (``train.step.DistSyncPrograms``) rings each
    device's 1/n_local slice over ``wan_axis`` and rebuilds the full
    vector with an intra-node all-gather — per-device WAN bytes drop by
    ``n_local``. ``local_rank`` ordering matches
    :meth:`ElasticDeviceMesh.local_rank` (row-major over the non-DiLoCo
    axes), which is also the order ``P(wan_axis, local_axes)`` shards
    and ``all_gather`` over ``local_axes`` re-concatenates."""

    wan_axis: str
    local_axes: tuple[str, ...]
    n_local: int

    @property
    def split(self) -> bool:
        """True when there is an intra-node group to split over."""
        return self.n_local > 1


def hierarchy(mesh: jax.sharding.Mesh,
              diloco_axis: str) -> HierarchySpec:
    """WAN/intra-node split of ``mesh`` around the DiLoCo axis."""
    local = tuple(a for a in mesh.axis_names if a != diloco_axis)
    n_local = int(np.prod([mesh.shape[a] for a in local],
                          dtype=np.int64)) if local else 1
    return HierarchySpec(diloco_axis, local, n_local)


class ElasticDeviceMesh:
    """Fixed-capacity mesh + slot assignment + weight computation."""

    def __init__(self, mesh: jax.sharding.Mesh, diloco_axis: str | None):
        self.mesh = mesh
        self.diloco_axis = diloco_axis
        cap = (mesh.shape[diloco_axis] if diloco_axis else 1)
        self.slots = SlotAssignment(cap)

    @property
    def capacity(self) -> int:
        return self.slots.capacity

    def admit(self, node_id: int) -> int:
        return self.slots.assign(node_id)

    def evict(self, node_id: int) -> None:
        self.slots.release(node_id)

    def weights(self, live_ids, joining_ids=(), straggler_ids=()):
        """Per-slot ring weights: 1 for contributing workers, 0 for
        joiners (zero pseudo-gradient), stragglers (excluded this
        round) and empty slots."""
        zero = set(joining_ids) | set(straggler_ids)
        return jnp.asarray(self.slots.live_mask(live_ids, zero))

    # -- rank bookkeeping (paper Fig. 1) -------------------------------------

    def global_rank(self, device_coords: dict[str, int]) -> int:
        """DiLoCo data-parallel rank of a device."""
        return device_coords.get(self.diloco_axis, 0)

    def local_rank(self, device_coords: dict[str, int]) -> int:
        """FSDP-group rank of a device (row-major over non-DiLoCo axes)."""
        rank, stride = 0, 1
        for name in reversed(list(self.mesh.shape.keys())):
            if name == self.diloco_axis:
                continue
            rank += device_coords.get(name, 0) * stride
            stride *= self.mesh.shape[name]
        return rank

    # -- remesh path ----------------------------------------------------------

    def remesh(self, new_diloco_size: int) -> "ElasticDeviceMesh":
        """Rebuild the mesh with a different DiLoCo-axis size over the
        currently healthy devices (recompile follows)."""
        shape = dict(self.mesh.shape)
        axes = list(shape.keys())
        assert self.diloco_axis is not None
        per_worker = np.prod(
            [s for a, s in shape.items() if a != self.diloco_axis],
            dtype=np.int64)
        need = int(per_worker) * new_diloco_size
        devices = np.asarray(self.mesh.devices).reshape(-1)[:need]
        new_shape = tuple(new_diloco_size if a == self.diloco_axis
                          else shape[a] for a in axes)
        from repro.compat import make_mesh
        mesh = make_mesh(new_shape, tuple(axes), devices=devices)
        out = ElasticDeviceMesh(mesh, self.diloco_axis)
        out.slots = SlotAssignment(new_diloco_size)
        for nid, slot in sorted(self.slots.slot_of.items(),
                                key=lambda kv: kv[1]):
            if slot < new_diloco_size:
                out.slots.slot_of[nid] = slot
        return out
