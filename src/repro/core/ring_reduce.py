"""Int8 ring-all-reduce with fp32 accumulation (INTELLECT-1 §2.2).

The paper's scheme, mapped to TPU collectives:

  * ring reduce-scatter then ring all-gather, built from static
    ``jax.lax.ppermute`` steps inside a ``shard_map`` manual region —
    the TPU analogue of the paper's IP-based Gloo ring;
  * every transmitted chunk is quantized to int8 (6-sigma clip +
    bucket-mean codebook, 1 KiB sideband per chunk-hop) while the running
    reduction stays fp32 — ``Q(a)+Q(b) != Q(a+b)``;
  * in the all-gather phase each reduced chunk is quantized ONCE by its
    owner and the codes are forwarded verbatim, so every worker
    (including the owner) dequantizes identical bytes -> all DiLoCo
    replicas apply bit-identical outer updates;
  * the ring order is a static permutation produced by the bandwidth-
    aware topology solver (``core.topology``); changing it recompiles,
    matching the paper's occasional ring re-ordering;
  * elastic weighting: each contribution is pre-scaled by a per-worker
    weight (0 for dead/joining workers) and the final average divides by
    the total live weight (paper §2.4: joiners enter with zero
    pseudo-gradient; failed workers are excluded from the average).

Fused + bucketed sync engine (see ``docs/sync_pipeline.md``):

  * each per-hop chunk is split into ``RingConfig.buckets`` sub-buckets
    with independent codebooks and independent ``ppermute``s, so the
    quantization of bucket ``i+1`` is data-independent of the transfer
    of bucket ``i`` and the compiler can overlap compress and
    communicate (the paper's pipelined all-reduce);
  * the reduce-scatter accumulation runs through the fused
    ``ops.dequantize_add`` (decode + accumulate in one memory pass);
  * when the caller provides ``fused_src=(anchor_flat, theta_flat)``
    the FIRST reduce-scatter hop quantizes straight off the model
    buffers via the fused ``ops.quantize_pseudograd`` (anchor - theta,
    scaled by the elastic weight, encoded in a single HBM trip) instead
    of re-reading the materialized pseudo-gradient.

Two implementations share all chunk/quant helpers and are tested for
exact equivalence:
  * ``ring_all_reduce``          — per-device, inside shard_map;
  * ``simulate_ring_all_reduce`` — stacked (k, D) single-process mirror
    (``vmap`` over workers, ``fori_loop`` over hops), used by the CPU
    cluster simulator and the unit tests.
"""
from __future__ import annotations

import dataclasses
from typing import Sequence

import jax
import jax.numpy as jnp
import numpy as np

from repro import compat
from repro.kernels import ops as qops
from repro.kernels.ref import NUM_BUCKETS


@dataclasses.dataclass(frozen=True)
class RingConfig:
    quant: str = "int8"          # 'int8' | 'fp32' (paper baseline) | 'int4'
    impl: str = "jnp"            # quant backend: 'jnp' | 'pallas'
    average: bool = True
    buckets: int = 1             # sub-buckets per chunk-hop (pipelining)
    fused: bool = True           # fused dequantize_add / pseudograd tx


def _bytes_per_elem(quant: str) -> float:
    return {"int8": 1.0, "int4": 0.5, "fp32": 4.0}[quant]


def ring_hop_bytes(numel: int, n_workers: int, quant: str = "int8",
                   buckets: int = 1) -> float:
    """Per-worker bytes of ONE wire hop (every hop carries one chunk of
    ``buckets`` sub-buckets plus their codebook sidebands; the chunk is
    rounded up so padding rides the wire too)."""
    if n_workers <= 1:
        return 0.0
    chunk = -(-numel // n_workers)
    chunk = -(-chunk // buckets) * buckets
    payload = chunk * _bytes_per_elem(quant)
    sideband = 0 if quant == "fp32" else 4 * NUM_BUCKETS * buckets
    return float(payload + sideband)


def ring_wire_bytes(numel: int, n_workers: int, quant: str = "int8",
                    buckets: int = 1) -> int:
    """Per-worker bytes on the wire for one all-reduce (both phases):
    2·(n−1) hops of :func:`ring_hop_bytes` each."""
    if n_workers <= 1:
        return 0
    return int(2 * (n_workers - 1)
               * ring_hop_bytes(numel, n_workers, quant, buckets))


# -- chunk/bucket helpers -----------------------------------------------------


def _pad_to_chunks(x: jnp.ndarray, n: int,
                   buckets: int = 1) -> tuple[jnp.ndarray, int, int]:
    """Pad the last dim so it splits into ``n`` chunks of ``buckets``
    equal sub-buckets. Returns (padded, chunk, bucket_size)."""
    size = x.shape[-1]
    chunk = -(-size // n)
    bsize = -(-chunk // buckets)
    chunk = bsize * buckets
    pad = n * chunk - size
    if pad:
        x = jnp.pad(x, [(0, 0)] * (x.ndim - 1) + [(0, pad)])
    return x, chunk, bsize


def _get_bucket(acc: jnp.ndarray, idx, b: int, chunk: int,
                bsize: int) -> jnp.ndarray:
    return jax.lax.dynamic_slice_in_dim(
        acc, idx * chunk + b * bsize, bsize, axis=-1)


def _set_bucket(acc: jnp.ndarray, idx, b: int, val: jnp.ndarray,
                chunk: int, bsize: int) -> jnp.ndarray:
    return jax.lax.dynamic_update_slice_in_dim(
        acc, val, idx * chunk + b * bsize, axis=-1)


def _tx_quant(val: jnp.ndarray, cfg: RingConfig):
    """Quantize a bucket for transmission -> (payload pytree, dequant fn)."""
    if cfg.quant == "fp32":
        return (val,), lambda p: p[0]
    if cfg.quant == "int4":
        from repro.core import compression
        q = compression.quantize4(val)
        return tuple(q), lambda p: compression.dequantize4(
            compression.Quantized4(*p), val.shape)
    q = qops.quantize(val, impl=cfg.impl)
    return tuple(q), lambda p: qops.dequantize(
        qops.Quantized(*p), impl=cfg.impl)


def _rx_add(payload, deq, acc_val: jnp.ndarray, cfg: RingConfig):
    """Reduce-scatter accumulate: fused decode+add on the int8 path."""
    if cfg.fused and cfg.quant == "int8":
        return qops.dequantize_add(qops.Quantized(*payload), acc_val,
                                   impl=cfg.impl)
    return acc_val + deq(payload)


def _int8_deq(cfg: RingConfig):
    return lambda p: qops.dequantize(qops.Quantized(*p), impl=cfg.impl)


# -- chunk-norm sideband (contribution admission / localization) -------------


def chunk_norms(xs, buckets: int = 1) -> np.ndarray:
    """Per-(chunk, bucket) L2 norms of stacked contributions.

    ``xs``: (k, D) per-worker rows. Returns (k, k * buckets) float64 —
    one column per wire sub-bucket, laid out exactly like the ring's
    chunk geometry (same ceil-div padding as :func:`_pad_to_chunks`), so
    an admission layer can localize WHICH chunk of WHICH slot carries
    garbage. Pure host-side numpy: the simulator and the distributed
    path compute bit-identical sidebands from their (bit-identical)
    retained pseudo-gradients.
    """
    rows = np.asarray(xs, dtype=np.float64)
    k, size = rows.shape
    nb = max(1, buckets)
    chunk = -(-size // k)
    bsize = -(-chunk // nb)
    chunk = bsize * nb
    pad = k * chunk - size
    if pad:
        rows = np.concatenate([rows, np.zeros((k, pad))], axis=1)
    safe = np.nan_to_num(rows, nan=0.0, posinf=0.0, neginf=0.0)
    sq = safe.reshape(k, k * nb, bsize)
    return np.sqrt(np.sum(sq * sq, axis=2))


# -- distributed ring (inside shard_map, manual over `axis_name`) ------------


def ring_all_reduce(x: jnp.ndarray, axis_name: str,
                    ring_order: Sequence[int] | None = None,
                    cfg: RingConfig = RingConfig(),
                    weight: jnp.ndarray | None = None,
                    fused_src=None) -> jnp.ndarray:
    """All-reduce (mean by default) of flat fp32 ``x`` over ``axis_name``.

    Must be called inside a shard_map region where ``axis_name`` is a
    manual axis. ``ring_order`` is the static bandwidth-optimized
    permutation of axis indices (defaults to the identity ring).
    ``fused_src=(anchor_flat, theta_flat)`` (both shaped like ``x``,
    with ``x == anchor_flat - theta_flat``) routes the first-hop
    transmit through the fused pseudo-gradient quantizer.
    """
    n = compat.axis_size(axis_name)
    orig_size = x.shape[-1]
    x = x.astype(jnp.float32)
    if weight is None:
        weight = jnp.float32(1.0)
    total_w = jax.lax.psum(weight, axis_name)
    if n == 1:
        out = x * weight / jnp.maximum(total_w, 1e-20) if cfg.average else x
        return out[..., :orig_size]

    order = tuple(ring_order) if ring_order is not None else tuple(range(n))
    assert sorted(order) == list(range(n)), "ring order must be a permutation"
    inv = np.argsort(np.asarray(order))  # axis index -> ring position
    perm_fwd = [(order[p], order[(p + 1) % n]) for p in range(n)]
    pos = jnp.asarray(inv)[jax.lax.axis_index(axis_name)]

    nb = max(1, cfg.buckets)
    acc, chunk, bsize = _pad_to_chunks(x * weight, n, nb)
    use_fused_tx = (fused_src is not None and cfg.fused
                    and cfg.quant == "int8")
    if use_fused_tx:
        a_flat, t_flat = fused_src
        pad = acc.shape[-1] - orig_size
        a_flat = jnp.pad(a_flat.astype(jnp.float32), (0, pad))
        t_flat = jnp.pad(t_flat.astype(jnp.float32), (0, pad))

    def shift(payload):
        return tuple(jax.lax.ppermute(p, axis_name, perm_fwd)
                     for p in payload)

    # Phase 1: reduce-scatter (n-1 hops, fp32 accumulation). All buckets
    # of a hop are quantized before any is shifted: bucket i+1's encode
    # has no data dependency on bucket i's ppermute, so the scheduler
    # overlaps compression with transmission (pipelined all-reduce).
    for s in range(n - 1):
        send_idx = (pos - s) % n
        recv_idx = (pos - s - 1) % n
        staged = []
        for b in range(nb):
            if s == 0 and use_fused_tx:
                start = send_idx * chunk + b * bsize
                a_c = jax.lax.dynamic_slice_in_dim(a_flat, start, bsize)
                t_c = jax.lax.dynamic_slice_in_dim(t_flat, start, bsize)
                q = qops.quantize_pseudograd(a_c, t_c, scale=weight,
                                             impl=cfg.impl)
                staged.append((tuple(q), _int8_deq(cfg)))
            else:
                staged.append(_tx_quant(
                    _get_bucket(acc, send_idx, b, chunk, bsize), cfg))
        for b, (payload, deq) in enumerate(staged):
            payload = shift(payload)
            acc_val = _get_bucket(acc, recv_idx, b, chunk, bsize)
            acc = _set_bucket(acc, recv_idx, b,
                              _rx_add(payload, deq, acc_val, cfg),
                              chunk, bsize)

    # Phase 2: all-gather. The owner quantizes its reduced chunk ONCE and
    # everyone (owner included) dequantizes the same forwarded codes.
    own_idx = (pos + 1) % n
    staged = []
    for b in range(nb):
        payload, deq = _tx_quant(
            _get_bucket(acc, own_idx, b, chunk, bsize), cfg)
        acc = _set_bucket(acc, own_idx, b, deq(payload), chunk, bsize)
        staged.append((payload, deq))
    for s in range(n - 1):
        recv_idx = (pos - s) % n
        staged = [(shift(payload), deq) for payload, deq in staged]
        for b, (payload, deq) in enumerate(staged):
            acc = _set_bucket(acc, recv_idx, b, deq(payload), chunk, bsize)

    out = acc[..., :orig_size]
    if cfg.average:
        out = out / jnp.maximum(total_w, 1e-20)
    return out


# -- single-process mirror (stacked workers) ---------------------------------


def _row_deq(cfg: RingConfig, bsize: int):
    """Row-wise dequant fn for (k, bsize) stacked payloads (static per
    (cfg, bsize) so the all-gather hops can rebuild it without carrying
    closures through jit boundaries)."""
    if cfg.quant == "fp32":
        return lambda p: p[0]
    if cfg.quant == "int4":
        from repro.core import compression
        return lambda p: jax.vmap(
            lambda pk, bk: compression.dequantize4(
                compression.Quantized4(pk, bk), (bsize,)))(*p)
    return lambda p: jax.vmap(
        lambda c, bk: qops.dequantize(qops.Quantized(c, bk),
                                      impl=cfg.impl))(*p)


def _quant_rows(vals: jnp.ndarray, cfg: RingConfig):
    """Row-wise transmit quantization of (k, bsize) stacked buckets ->
    (payload tuple of stacked arrays, row-wise dequant fn). vmap over
    workers is bit-identical to per-row calls on XLA:CPU (tested)."""
    bsize = vals.shape[-1]
    if cfg.quant == "fp32":
        return (vals,), _row_deq(cfg, bsize)
    if cfg.quant == "int4":
        from repro.core import compression
        q = jax.vmap(compression.quantize4)(vals)
        return tuple(q), _row_deq(cfg, bsize)
    q = jax.vmap(lambda v: qops.quantize(v, impl=cfg.impl))(vals)
    return tuple(q), _row_deq(cfg, bsize)


def _rx_add_rows(payload, deq, acc_vals: jnp.ndarray, cfg: RingConfig):
    if cfg.fused and cfg.quant == "int8":
        return jax.vmap(lambda c, bk, a: qops.dequantize_add(
            qops.Quantized(c, bk), a, impl=cfg.impl))(*payload, acc_vals)
    return acc_vals + deq(payload)


def _get_bucket_rows(accs, idxs, b: int, chunk: int, bsize: int):
    return jax.vmap(lambda a, i: jax.lax.dynamic_slice_in_dim(
        a, i * chunk + b * bsize, bsize, axis=-1))(accs, idxs)


def _set_bucket_rows(accs, idxs, b: int, vals, chunk: int, bsize: int):
    return jax.vmap(lambda a, i, v: jax.lax.dynamic_update_slice_in_dim(
        a, v, i * chunk + b * bsize, axis=-1))(accs, idxs, vals)


def _roll1(payload):
    """Position p receives from position p-1."""
    return tuple(jnp.roll(p, 1, axis=0) for p in payload)


# -- hop bodies (shared by the one-shot simulator, RingSyncOp, and the
#    distributed per-hop shard_map programs in train.step) -------------------
#
# ``k`` is always the RING size; the row count is ``positions.shape[0]``
# (all k positions in the simulator, ONE row per device inside a manual
# shard_map region, where ``positions = inv[axis_index][None]`` and
# ``shift`` is a ``ppermute`` along the ring instead of ``jnp.roll``).
# vmap over one row is bit-identical to the stacked vmap on XLA:CPU
# (tested), which is what makes the distributed path hop-for-hop
# bit-identical to the simulator.


def _rs_hop_rows(s, accs, k: int, chunk: int, bsize: int, nb: int,
                 cfg: RingConfig, fused_operands=None, *,
                 positions=None, shift=_roll1):
    """One reduce-scatter hop across the given ring positions/buckets.
    ``fused_operands=(a_flat, t_pos, w_pos)`` routes the transmit
    through the fused pseudo-gradient quantizer (hop 0 only)."""
    if positions is None:
        positions = jnp.arange(k)
    send_idx = (positions - s) % k
    recv_idx = (positions - s - 1) % k
    staged = []
    for b in range(nb):
        if fused_operands is not None:
            a_flat, t_pos, w_pos = fused_operands
            starts = send_idx * chunk + b * bsize
            a_rows = jax.vmap(lambda i: jax.lax.dynamic_slice_in_dim(
                a_flat, i, bsize, axis=-1))(starts)
            t_rows = jax.vmap(
                lambda t, i: jax.lax.dynamic_slice_in_dim(
                    t, i, bsize, axis=-1))(t_pos, starts)
            q = jax.vmap(lambda a, t, w: qops.quantize_pseudograd(
                a, t, scale=w, impl=cfg.impl))(a_rows, t_rows, w_pos)
            staged.append((tuple(q), _row_deq(cfg, bsize)))
        else:
            staged.append(_quant_rows(
                _get_bucket_rows(accs, send_idx, b, chunk, bsize), cfg))
    for b, (payload, deq) in enumerate(staged):
        payload = shift(payload)
        acc_vals = _get_bucket_rows(accs, recv_idx, b, chunk, bsize)
        accs = _set_bucket_rows(
            accs, recv_idx, b,
            _rx_add_rows(payload, deq, acc_vals, cfg),
            chunk, bsize)
    return accs


def _ag_init_rows(accs, k: int, chunk: int, bsize: int, nb: int,
                  cfg: RingConfig, *, positions=None):
    """All-gather prologue: every owner quantizes its reduced chunk ONCE
    (per bucket); the codes are then forwarded verbatim so every worker
    decodes identical bytes. Returns (accs, per-bucket payloads)."""
    if positions is None:
        positions = jnp.arange(k)
    own_idx = (positions + 1) % k
    payloads = []
    for b in range(nb):
        vals = _get_bucket_rows(accs, own_idx, b, chunk, bsize)
        payload, deq = _quant_rows(vals, cfg)
        accs = _set_bucket_rows(accs, own_idx, b, deq(payload),
                                chunk, bsize)
        payloads.append(payload)
    return accs, tuple(payloads)


def _ag_hop_rows(s, accs, payloads, k: int, chunk: int, bsize: int,
                 nb: int, cfg: RingConfig, *, positions=None, shift=_roll1):
    """One all-gather hop: shift every bucket's forwarded codes one
    position and decode in place. Buckets write disjoint regions, so
    hop-major order here equals the bucket-major order bit-for-bit."""
    if positions is None:
        positions = jnp.arange(k)
    recv_idx = (positions - s) % k
    deq = _row_deq(cfg, bsize)
    new_payloads = []
    for b in range(nb):
        payload = shift(payloads[b])
        accs = _set_bucket_rows(accs, recv_idx, b, deq(payload),
                                chunk, bsize)
        new_payloads.append(payload)
    return accs, tuple(new_payloads)


def simulate_ring_all_reduce(xs: jnp.ndarray,
                             ring_order: Sequence[int] | None = None,
                             cfg: RingConfig = RingConfig(),
                             weights: jnp.ndarray | None = None,
                             fused_src=None) -> jnp.ndarray:
    """Exact single-process mirror of ``ring_all_reduce``.

    ``xs``: (k, D) stacked per-worker vectors. Returns (k, D) results —
    identical across workers (and bit-identical to the distributed path,
    which the tests assert). Workers are handled by ``vmap`` and the
    hop loops by ``lax.fori_loop`` — no per-hop Python copies of the
    stacked accumulator. ``fused_src=(anchor_flat, thetas)`` mirrors the
    distributed fused first-hop transmit (``anchor_flat``: (D,) shared,
    ``thetas``: (k, D) per-worker).
    """
    k, orig_size = xs.shape
    xs = xs.astype(jnp.float32)
    if weights is None:
        weights = jnp.ones((k,), jnp.float32)
    total_w = jnp.sum(weights)
    if k == 1:
        out = xs * weights[:, None] / jnp.maximum(total_w, 1e-20) \
            if cfg.average else xs
        return out

    order = tuple(ring_order) if ring_order is not None else tuple(range(k))
    assert sorted(order) == list(range(k))
    perm = np.asarray(order)
    inv = np.argsort(perm)  # worker w sits at ring position inv[w]

    nb = max(1, cfg.buckets)
    # accs indexed by RING POSITION p: acc[p] belongs to worker order[p]
    w_pos = weights[jnp.asarray(perm)]
    accs = xs[perm] * w_pos[:, None]
    accs, chunk, bsize = _pad_to_chunks(accs, k, nb)

    use_fused_tx = (fused_src is not None and cfg.fused
                    and cfg.quant == "int8")
    if use_fused_tx:
        a_flat, thetas = fused_src
        pad = accs.shape[-1] - orig_size
        a_flat = jnp.pad(a_flat.astype(jnp.float32), (0, pad))
        t_pos = jnp.pad(thetas.astype(jnp.float32)[perm],
                        [(0, 0), (0, pad)])

    # Phase 1: reduce-scatter. Hop 0 is peeled so the fused
    # pseudo-gradient transmit (different payload source) stays out of
    # the uniform fori_loop body.
    fused_ops = (a_flat, t_pos, w_pos) if use_fused_tx else None
    accs = _rs_hop_rows(0, accs, k, chunk, bsize, nb, cfg, fused_ops)
    if k > 2:
        accs = jax.lax.fori_loop(
            1, k - 1,
            lambda s, a: _rs_hop_rows(s, a, k, chunk, bsize, nb, cfg),
            accs)

    # Phase 2: all-gather with forwarded codes; owners quantize once,
    # then one fori_loop over hops with every bucket's payload riding
    # the carry (hop-major == the per-bucket order bit-for-bit: buckets
    # write disjoint regions).
    accs, payloads = _ag_init_rows(accs, k, chunk, bsize, nb, cfg)
    accs, _ = jax.lax.fori_loop(
        0, k - 1,
        lambda s, c: _ag_hop_rows(s, c[0], c[1], k, chunk, bsize, nb,
                                  cfg),
        (accs, payloads))

    out_pos = accs[..., :orig_size]
    if cfg.average:
        out_pos = out_pos / jnp.maximum(total_w, 1e-20)
    # out[worker w] lives at ring position inv[w]
    return out_pos[jnp.asarray(inv)]


# -- hop-steppable simulation (overlapped outer sync) ------------------------


_HOP_JIT: dict = {}


def _hop_jit(kind: str, k: int, chunk: int, bsize: int, nb: int,
             cfg: RingConfig):
    """Per-hop jitted wrappers, cached on the static ring geometry so
    repeated outer steps reuse compilations. ``s`` rides as a traced
    scalar: one compilation serves every hop index."""
    key = (kind, k, chunk, bsize, nb, cfg)
    fn = _HOP_JIT.get(key)
    if fn is None:
        if kind == "rs":
            fn = jax.jit(lambda s, a: _rs_hop_rows(
                s, a, k, chunk, bsize, nb, cfg))
        elif kind == "rs_fused":
            fn = jax.jit(lambda s, a, af, tp, wp: _rs_hop_rows(
                s, a, k, chunk, bsize, nb, cfg, (af, tp, wp)))
        elif kind == "ag_init":
            fn = jax.jit(lambda a: _ag_init_rows(
                a, k, chunk, bsize, nb, cfg))
        elif kind == "ag":
            fn = jax.jit(lambda s, a, p: _ag_hop_rows(
                s, a, p, k, chunk, bsize, nb, cfg))
        else:
            raise ValueError(kind)
        _HOP_JIT[key] = fn
    return fn


class RingSyncOp:
    """Host-steppable mirror of :func:`simulate_ring_all_reduce`.

    The same reduce-scatter / all-gather hop math, split at WIRE-HOP
    granularity so a training loop can dispatch one hop between each
    inner-phase scan chunk and hide the ring under compute (the paper's
    overlapped outer sync). ``step()`` dispatches the next hop (async
    on device), ``finish()`` drains the remainder and returns the
    reduced (k, D) result — bit-identical to the one-shot simulator,
    which the tests assert.

    The op RETAINS its inputs (``xs``, ``weights``, ``fused_src``): a
    worker dying mid-overlap leaves the accumulator torn (it already
    absorbed hops that assumed the dead worker would keep forwarding),
    so recovery must re-reduce from the retained pseudo-gradients over
    the survivors — :meth:`restart` — never apply the partial state.
    """

    def __init__(self, xs: jnp.ndarray,
                 ring_order: Sequence[int] | None = None,
                 cfg: RingConfig = RingConfig(),
                 weights: jnp.ndarray | None = None,
                 fused_src=None):
        k, orig_size = xs.shape
        self.k, self.orig_size = k, orig_size
        self.cfg = cfg
        self.xs = xs.astype(jnp.float32)
        self.weights = (jnp.ones((k,), jnp.float32) if weights is None
                        else weights)
        self.ring_order = (tuple(ring_order) if ring_order is not None
                           else tuple(range(k)))
        self.fused_src = fused_src
        self.hops_done = 0
        self._out: jnp.ndarray | None = None
        self._total_w = jnp.sum(self.weights)
        if k == 1:
            self.hops_total = 0
            out = self.xs * self.weights[:, None] / jnp.maximum(
                self._total_w, 1e-20) if cfg.average else self.xs
            self._out = out
            return

        assert sorted(self.ring_order) == list(range(k)), \
            "ring order must be a permutation"
        perm = np.asarray(self.ring_order)
        self._inv = jnp.asarray(np.argsort(perm))
        nb = max(1, cfg.buckets)
        w_pos = self.weights[jnp.asarray(perm)]
        accs = self.xs[perm] * w_pos[:, None]
        accs, chunk, bsize = _pad_to_chunks(accs, k, nb)
        self._accs = accs
        self._chunk, self._bsize, self._nb = chunk, bsize, nb
        self._w_pos = w_pos
        self._fused0 = (fused_src is not None and cfg.fused
                        and cfg.quant == "int8")
        if self._fused0:
            a_flat, thetas = fused_src
            pad = accs.shape[-1] - orig_size
            self._a_flat = jnp.pad(a_flat.astype(jnp.float32), (0, pad))
            self._t_pos = jnp.pad(thetas.astype(jnp.float32)[perm],
                                  [(0, 0), (0, pad)])
        self._payloads = None
        # wire hops: (k-1) reduce-scatter + (k-1) all-gather forwards
        # (the owner-quantize prologue is compute-only and rides with
        # the first all-gather hop)
        self.hops_total = 2 * (k - 1)

    @property
    def pending(self) -> bool:
        return self.hops_done < self.hops_total

    def step(self) -> bool:
        """Dispatch ONE wire hop (async device work); returns True iff
        a hop was dispatched."""
        if self._out is not None or not self.pending:
            return False
        i, k = self.hops_done, self.k
        args = (self.k, self._chunk, self._bsize, self._nb, self.cfg)
        if i < k - 1:
            if i == 0 and self._fused0:
                self._accs = _hop_jit("rs_fused", *args)(
                    jnp.int32(0), self._accs, self._a_flat,
                    self._t_pos, self._w_pos)
            else:
                self._accs = _hop_jit("rs", *args)(
                    jnp.int32(i), self._accs)
        else:
            s = i - (k - 1)
            if s == 0:
                self._accs, self._payloads = _hop_jit(
                    "ag_init", *args)(self._accs)
            self._accs, self._payloads = _hop_jit("ag", *args)(
                jnp.int32(s), self._accs, self._payloads)
        self.hops_done += 1
        return True

    def finish(self) -> jnp.ndarray:
        """Drain any remaining hops and return the (k, D) reduced
        result (identical rows across workers)."""
        if self._out is None:
            while self.pending:
                self.step()
            out_pos = self._accs[..., :self.orig_size]
            if self.cfg.average:
                out_pos = out_pos / jnp.maximum(self._total_w, 1e-20)
            self._out = out_pos[self._inv]
            self._accs = self._payloads = None  # free the in-flight state
        return self._out

    def restart(self, weights: jnp.ndarray) -> jnp.ndarray:
        """Torn-reduction fallback: synchronously re-reduce the RETAINED
        inputs under ``weights`` (dead workers zeroed), discarding the
        partial accumulator. Returns the (k, D) reduced result."""
        return simulate_ring_all_reduce(
            self.xs, ring_order=self.ring_order, cfg=self.cfg,
            weights=weights, fused_src=self.fused_src)

    def norm_sideband(self) -> np.ndarray:
        """(k, k * buckets) per-chunk norm sideband of the retained
        inputs (:func:`chunk_norms`) for the admission layer."""
        return chunk_norms(self.xs, self.cfg.buckets)
