"""Int8 ring-all-reduce with fp32 accumulation (INTELLECT-1 §2.2).

The paper's scheme, mapped to TPU collectives:

  * ring reduce-scatter then ring all-gather, built from static
    ``jax.lax.ppermute`` steps inside a ``shard_map`` manual region —
    the TPU analogue of the paper's IP-based Gloo ring;
  * every transmitted chunk is quantized to int8 (6-sigma clip +
    bucket-mean codebook, 1 KiB sideband per chunk-hop) while the running
    reduction stays fp32 — ``Q(a)+Q(b) != Q(a+b)``;
  * in the all-gather phase each reduced chunk is quantized ONCE by its
    owner and the codes are forwarded verbatim, so every worker
    (including the owner) dequantizes identical bytes -> all DiLoCo
    replicas apply bit-identical outer updates;
  * the ring order is a static permutation produced by the bandwidth-
    aware topology solver (``core.topology``); changing it recompiles,
    matching the paper's occasional ring re-ordering;
  * elastic weighting: each contribution is pre-scaled by a per-worker
    weight (0 for dead/joining workers) and the final average divides by
    the total live weight (paper §2.4: joiners enter with zero
    pseudo-gradient; failed workers are excluded from the average).

Two implementations share all chunk/quant helpers and are tested for
exact equivalence:
  * ``ring_all_reduce``          — per-device, inside shard_map;
  * ``simulate_ring_all_reduce`` — stacked (k, D) single-process mirror,
    used by the CPU cluster simulator and the unit tests.
"""
from __future__ import annotations

import dataclasses
from typing import Sequence

import jax
import jax.numpy as jnp
import numpy as np

from repro.kernels import ops as qops
from repro.kernels.ref import NUM_BUCKETS


@dataclasses.dataclass(frozen=True)
class RingConfig:
    quant: str = "int8"          # 'int8' | 'fp32' (paper baseline) | 'int4'
    impl: str = "jnp"            # quant backend: 'jnp' | 'pallas'
    average: bool = True


def _bytes_per_elem(quant: str) -> float:
    return {"int8": 1.0, "int4": 0.5, "fp32": 4.0}[quant]


def ring_wire_bytes(numel: int, n_workers: int, quant: str = "int8") -> int:
    """Per-worker bytes on the wire for one all-reduce (both phases)."""
    if n_workers <= 1:
        return 0
    chunk = -(-numel // n_workers)
    payload = chunk * _bytes_per_elem(quant)
    sideband = 0 if quant == "fp32" else 4 * NUM_BUCKETS
    return int(2 * (n_workers - 1) * (payload + sideband))


# -- chunk helpers -----------------------------------------------------------


def _pad_to_chunks(x: jnp.ndarray, n: int) -> tuple[jnp.ndarray, int]:
    size = x.shape[-1]
    chunk = -(-size // n)
    pad = n * chunk - size
    if pad:
        x = jnp.pad(x, [(0, 0)] * (x.ndim - 1) + [(0, pad)])
    return x, chunk


def _get_chunk(acc: jnp.ndarray, idx, chunk: int) -> jnp.ndarray:
    return jax.lax.dynamic_slice_in_dim(acc, idx * chunk, chunk, axis=-1)


def _set_chunk(acc: jnp.ndarray, idx, val: jnp.ndarray, chunk: int):
    return jax.lax.dynamic_update_slice_in_dim(acc, val, idx * chunk, axis=-1)


def _tx_quant(val: jnp.ndarray, cfg: RingConfig):
    """Quantize a chunk for transmission -> (payload pytree, dequant fn)."""
    if cfg.quant == "fp32":
        return (val,), lambda p: p[0]
    if cfg.quant == "int4":
        from repro.core import compression
        q = compression.quantize4(val)
        return tuple(q), lambda p: compression.dequantize4(
            compression.Quantized4(*p), val.shape)
    q = qops.quantize(val, impl=cfg.impl)
    return tuple(q), lambda p: qops.dequantize(
        qops.Quantized(*p), impl=cfg.impl)


# -- distributed ring (inside shard_map, manual over `axis_name`) ------------


def ring_all_reduce(x: jnp.ndarray, axis_name: str,
                    ring_order: Sequence[int] | None = None,
                    cfg: RingConfig = RingConfig(),
                    weight: jnp.ndarray | None = None) -> jnp.ndarray:
    """All-reduce (mean by default) of flat fp32 ``x`` over ``axis_name``.

    Must be called inside a shard_map region where ``axis_name`` is a
    manual axis. ``ring_order`` is the static bandwidth-optimized
    permutation of axis indices (defaults to the identity ring).
    """
    n = jax.lax.axis_size(axis_name)
    orig_size = x.shape[-1]
    x = x.astype(jnp.float32)
    if weight is None:
        weight = jnp.float32(1.0)
    total_w = jax.lax.psum(weight, axis_name)
    if n == 1:
        out = x * weight / jnp.maximum(total_w, 1e-20) if cfg.average else x
        return out[..., :orig_size]

    order = tuple(ring_order) if ring_order is not None else tuple(range(n))
    assert sorted(order) == list(range(n)), "ring order must be a permutation"
    inv = np.argsort(np.asarray(order))  # axis index -> ring position
    perm_fwd = [(order[p], order[(p + 1) % n]) for p in range(n)]
    pos = jnp.asarray(inv)[jax.lax.axis_index(axis_name)]

    acc, chunk = _pad_to_chunks(x * weight, n)

    def shift(payload):
        return tuple(jax.lax.ppermute(p, axis_name, perm_fwd)
                     for p in payload)

    # Phase 1: reduce-scatter (n-1 quantized hops, fp32 accumulation)
    for s in range(n - 1):
        send_idx = (pos - s) % n
        payload, deq = _tx_quant(_get_chunk(acc, send_idx, chunk), cfg)
        payload = shift(payload)
        recv_idx = (pos - s - 1) % n
        recvd = deq(payload)
        acc = _set_chunk(acc, recv_idx,
                         _get_chunk(acc, recv_idx, chunk) + recvd, chunk)

    # Phase 2: all-gather. The owner quantizes its reduced chunk ONCE and
    # everyone (owner included) dequantizes the same codes.
    own_idx = (pos + 1) % n
    payload, deq = _tx_quant(_get_chunk(acc, own_idx, chunk), cfg)
    acc = _set_chunk(acc, own_idx, deq(payload), chunk)
    for s in range(n - 1):
        payload = shift(payload)
        recv_idx = (pos - s) % n
        acc = _set_chunk(acc, recv_idx, deq(payload), chunk)

    out = acc[..., :orig_size]
    if cfg.average:
        out = out / jnp.maximum(total_w, 1e-20)
    return out


# -- single-process mirror (stacked workers) ---------------------------------


def simulate_ring_all_reduce(xs: jnp.ndarray,
                             ring_order: Sequence[int] | None = None,
                             cfg: RingConfig = RingConfig(),
                             weights: jnp.ndarray | None = None
                             ) -> jnp.ndarray:
    """Exact single-process mirror of ``ring_all_reduce``.

    ``xs``: (k, D) stacked per-worker vectors. Returns (k, D) results —
    identical across workers (and bit-identical to the distributed path,
    which the tests assert).
    """
    k, orig_size = xs.shape
    xs = xs.astype(jnp.float32)
    if weights is None:
        weights = jnp.ones((k,), jnp.float32)
    total_w = jnp.sum(weights)
    if k == 1:
        out = xs * weights[:, None] / jnp.maximum(total_w, 1e-20) \
            if cfg.average else xs
        return out

    order = tuple(ring_order) if ring_order is not None else tuple(range(k))
    assert sorted(order) == list(range(k))
    # accs indexed by RING POSITION p: acc[p] belongs to worker order[p]
    accs_list = [xs[order[p]] * weights[order[p]] for p in range(k)]
    accs = jnp.stack(accs_list)
    accs, chunk = _pad_to_chunks(accs, k)

    def quant_chunks(vals):
        payloads, deqs = [], []
        for p in range(k):
            pay, deq = _tx_quant(vals[p], cfg)
            payloads.append(pay)
            deqs.append(deq)
        return payloads, deqs

    # Phase 1: reduce-scatter
    for s in range(k - 1):
        sends = [_get_chunk(accs[p], (p - s) % k, chunk) for p in range(k)]
        payloads, deqs = quant_chunks(sends)
        new = []
        for p in range(k):
            src = (p - 1) % k  # position p receives from position p-1
            recv_idx = (p - s - 1) % k
            val = _get_chunk(accs[p], recv_idx, chunk) + deqs[src](
                payloads[src])
            new.append(_set_chunk(accs[p], recv_idx, val, chunk))
        accs = jnp.stack(new)

    # Phase 2: all-gather with forwarded codes
    sends = [_get_chunk(accs[p], (p + 1) % k, chunk) for p in range(k)]
    payloads, deqs = quant_chunks(sends)
    accs = jnp.stack([
        _set_chunk(accs[p], (p + 1) % k, deqs[p](payloads[p]), chunk)
        for p in range(k)])
    bufs = payloads
    buf_deqs = deqs
    for s in range(k - 1):
        nbufs = [bufs[(p - 1) % k] for p in range(k)]
        ndeqs = [buf_deqs[(p - 1) % k] for p in range(k)]
        new = []
        for p in range(k):
            recv_idx = (p - s) % k
            new.append(_set_chunk(accs[p], recv_idx,
                                  ndeqs[p](nbufs[p]), chunk))
        accs = jnp.stack(new)
        bufs, buf_deqs = nbufs, ndeqs

    out_pos = accs[..., :orig_size]
    if cfg.average:
        out_pos = out_pos / jnp.maximum(total_w, 1e-20)
    # out[worker w] lives at ring position inv[w]
    inv = np.argsort(np.asarray(order))
    return out_pos[jnp.asarray(inv)]
