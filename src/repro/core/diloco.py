"""DiLoCo (Distributed Low-Communication) outer optimization — the heart
of PRIME (INTELLECT-1 §2.1, Alg. 1).

Each DiLoCo worker runs H inner AdamW steps, then all workers synchronize
*pseudo-gradients* ``delta_i = anchor - theta_i`` through the int8 ring
all-reduce and apply a shared Nesterov outer step:

    delta = (1/sum w) * sum_i  w_i (anchor - theta_i)      (elastic weights)
    anchor' = NesterovSGD(anchor, delta)
    theta_i <- anchor'                                      (all workers)

Two synchronization paths, sharing all math:
  * ``outer_sync``     — per-device, inside a shard_map region manual over
    the DiLoCo mesh axis ('pod' across pods, 'data' within one);
  * ``outer_sync_sim`` — stacked (k, ...) single-process mirror used by
    the CPU cluster simulator / examples / tests.

The outer step runs on the **SyncEngine** (``core.sync_engine``): the
anchor is kept as a persistent flat fp32 buffer (``OuterState.anchor_flat``)
so the pseudo-gradient is one subtract off the buffer instead of a
flatten of two pytrees, the outer Nesterov update runs in flat space,
and the flat (anchor, theta) pair feeds the ring's fused first-hop
transmit quantizer. See ``docs/sync_pipeline.md`` for the dataflow.

The anchor is kept in fp32 (it is the paper's CPU-offloaded master copy;
on TPU it can live in ``pinned_host`` memory — see
``sharding.plans.outer_state_sharding``).
"""
from __future__ import annotations

import dataclasses
from typing import Any, NamedTuple, Sequence

import jax
import jax.numpy as jnp

from repro.core import compression
from repro.core.ring_reduce import (RingConfig, RingSyncOp,
                                    ring_all_reduce, ring_wire_bytes,
                                    simulate_ring_all_reduce)
from repro.core.sync_engine import SyncEngine
from repro.kernels import ops as qops
from repro.optim.nesterov import NesterovSGD, NesterovState


class SyncAbortedError(RuntimeError):
    """An in-flight outer sync was aborted (trainer teardown or a
    rejected/discarded reduction); its result must never be applied."""


@dataclasses.dataclass(frozen=True)
class DiLoCoConfig:
    inner_steps: int = 100          # H (paper: 100; DiLoCo paper: up to 500)
    outer_lr: float = 0.7
    outer_momentum: float = 0.9
    quant: str = "int8"             # 'int8' | 'fp32' | 'int4'
    quant_impl: str = "jnp"         # 'jnp' | 'pallas'
    sync_buckets: int = 1           # sub-buckets per ring chunk-hop
    fused_sync: bool = True         # fused tx/rx kernels in the ring
    # 'none'    — synchronous outer step (the ring is a barrier between
    #             inner phases; the paper's fallback mode);
    # 'delayed' — the quantized ring runs UNDER the next inner phase
    #             (hops dispatched between scan chunks) and the reduced
    #             pseudo-gradient is applied one phase late (the
    #             paper's overlapped outer sync, §2.2 utilization).
    overlap: str = "none"
    error_feedback: bool = False    # beyond-paper (see core.compression)
    host_offload_outer: bool = False  # TPU-only placement flag
    # hierarchical reduce (paper's ElasticDeviceMesh split): each device
    # rings only its intra-node slice over the WAN (DiLoCo) axis and the
    # full vector is rebuilt intra-node — per-device WAN bytes / n_local.
    # Distributed backend only (train.step.DistSyncBackend); codebooks
    # become per-slice, so results are bit-identical to the PER-SLICE
    # simulator rather than the flat one (tested).
    hierarchical: bool = False

    @property
    def ring(self) -> RingConfig:
        return RingConfig(quant=self.quant, impl=self.quant_impl,
                          buckets=self.sync_buckets,
                          fused=self.fused_sync)

    @property
    def outer_opt(self) -> NesterovSGD:
        return NesterovSGD(lr=self.outer_lr, momentum=self.outer_momentum)


class OuterState(NamedTuple):
    anchor: Any                # fp32 pytree: theta at the last outer step
    opt: NesterovState         # fp32 outer momentum
    residual: Any              # fp32 flat EF residual (zeros if disabled)
    outer_step: jnp.ndarray
    anchor_flat: Any = None    # persistent flat fp32 anchor (SyncEngine);
    #                            None -> re-derived from ``anchor``.  Must
    #                            match the local view of ``anchor`` (i.e.
    #                            leave it None inside shard_map regions
    #                            where the anchor leaves are shards).


def init_outer_state(params, cfg: DiLoCoConfig) -> OuterState:
    anchor = jax.tree.map(lambda p: p.astype(jnp.float32), params)
    eng = SyncEngine.for_tree(anchor)
    opt = cfg.outer_opt.init(anchor)
    n = eng.numel if cfg.error_feedback else 0
    if cfg.error_feedback and cfg.overlap == "delayed":
        # two-slot residual: the delayed overlap interleaves two anchor
        # lineages (see finish_outer_sync_sim), so EF keeps one residual
        # per lineage — boundary t reads/writes slot t % 2 only
        residual = jnp.zeros((2, n), jnp.float32)
    else:
        residual = jnp.zeros((n,), jnp.float32)
    return OuterState(anchor, opt, residual, jnp.zeros((), jnp.int32),
                      eng.flatten(anchor))


def init_outer_state_sim(params_one_worker, cfg: DiLoCoConfig,
                         k: int) -> OuterState:
    """Outer state for the stacked single-process simulator: shared
    anchor/momentum, per-worker EF residuals ((2, k, n) under the
    delayed overlap — one slot per interleaved lineage)."""
    st = init_outer_state(params_one_worker, cfg)
    n = st.residual.shape[-1]
    if st.residual.ndim == 2:
        return st._replace(residual=jnp.zeros((2, k, n), jnp.float32))
    return st._replace(residual=jnp.zeros((k, n), jnp.float32))


def _ef_roundtrip(pg: jnp.ndarray, cfg: DiLoCoConfig) -> jnp.ndarray:
    """Quantize/dequantize roundtrip used by error feedback."""
    if cfg.quant == "int8":
        q = qops.quantize(pg, impl=cfg.quant_impl)
        return qops.dequantize(q, impl=cfg.quant_impl)
    q = compression.quantize4(pg)
    return compression.dequantize4(q, pg.shape)


def _pseudograd(params, state: OuterState, cfg: DiLoCoConfig):
    """Flat fp32 pseudo-gradient (+EF residual) off the persistent
    anchor buffer. Returns (pg, new_residual, theta_flat, anchor_flat)."""
    eng = SyncEngine.for_tree(params)
    p_flat = eng.flatten(params)
    a_flat = (state.anchor_flat if state.anchor_flat is not None
              else eng.flatten(state.anchor))
    pg = a_flat - p_flat
    new_residual = state.residual
    if cfg.error_feedback:
        if state.residual.ndim == 2:
            # two-slot (delayed cfg on the synchronous distributed
            # path): outer_step advances once per sync, so its parity
            # alternates slots — each lineage's residual round-trips
            # through its own slot
            slot = jnp.mod(state.outer_step, 2)
            res = jax.lax.dynamic_index_in_dim(
                state.residual, slot, 0, keepdims=False)
            pg = pg + res
            deq = _ef_roundtrip(pg, cfg)
            new_residual = jax.lax.dynamic_update_index_in_dim(
                state.residual, pg - deq, slot, 0)
            pg = deq
        else:
            pg = pg + state.residual
            deq = _ef_roundtrip(pg, cfg)
            new_residual = pg - deq
            pg = deq
    return pg, new_residual, p_flat, a_flat


def _apply_outer(reduced_pg_flat, params, state: OuterState,
                 cfg: DiLoCoConfig, new_residual, a_flat):
    """Flat-space outer Nesterov step + a single unflatten per output
    tree (bit-identical to the per-leaf formulation)."""
    eng = SyncEngine.for_tree(state.anchor)
    m_flat = eng.flatten(state.opt.momentum)
    new_a_flat, new_m_flat = cfg.outer_opt.update_flat(
        reduced_pg_flat, m_flat, a_flat)
    new_anchor = eng.unflatten(new_a_flat)
    new_opt = NesterovState(eng.unflatten(new_m_flat))
    new_params = eng.unflatten(new_a_flat, like=params)
    return new_params, OuterState(new_anchor, new_opt, new_residual,
                                  state.outer_step + 1, new_a_flat)


def _fused_src_ok(cfg: DiLoCoConfig) -> bool:
    """The fused first-hop transmit sends quantize(w*(anchor-theta))
    straight off the model buffers — only valid when the wire payload IS
    the raw pseudo-gradient (no EF rewrite) and the ring is int8."""
    return cfg.fused_sync and cfg.quant == "int8" and \
        not cfg.error_feedback


# -- distributed path (inside shard_map, manual over `axis_name`) ------------


def outer_sync(params, state: OuterState, cfg: DiLoCoConfig,
               axis_name: str, ring_order: Sequence[int] | None = None,
               weight: jnp.ndarray | None = None):
    """One DiLoCo outer step for this worker. Returns (params', state')."""
    pg, new_residual, p_flat, a_flat = _pseudograd(params, state, cfg)
    fused_src = (a_flat, p_flat) if _fused_src_ok(cfg) else None
    reduced = ring_all_reduce(pg, axis_name, ring_order=ring_order,
                              cfg=cfg.ring, weight=weight,
                              fused_src=fused_src)
    return _apply_outer(reduced, params, state, cfg, new_residual, a_flat)


# -- single-process simulation (stacked workers) ------------------------------


def _sim_pseudograds(stacked_params, state: OuterState,
                     cfg: DiLoCoConfig, ef_slot: int = 0):
    """Shared boundary front half of the sim outer step: stacked flat
    pseudo-gradients (+EF rewrite) off the persistent anchor buffer.
    Returns (k, any_params, a_flat, pgs, new_residuals, fused_src).

    With a two-slot residual buffer ((2, k, n): EF + delayed overlap)
    only row ``ef_slot`` is read, and ``new_residuals`` is that slot's
    (k, n) replacement — the caller commits it via
    :func:`_commit_residual` so the write lands on the CURRENT state.

    The anchor flatten is hoisted out of the worker dimension (the seed
    re-flattened the full anchor pytree once per worker inside a vmap);
    per-worker work is a single vmapped flatten + subtract.
    """
    k = jax.tree.leaves(stacked_params)[0].shape[0]
    any_params = jax.tree.map(lambda p: p[0], stacked_params)
    eng = SyncEngine.for_tree(any_params)

    a_flat = (state.anchor_flat if state.anchor_flat is not None
              else eng.flatten(state.anchor))
    p_flats = jax.vmap(eng.flatten)(stacked_params)
    pgs = a_flat[None, :] - p_flats
    new_residuals = state.residual
    if cfg.error_feedback:
        two_slot = state.residual.ndim == 3
        res = state.residual[ef_slot] if two_slot else state.residual
        pgs = pgs + res
        deqs = jax.vmap(lambda pg: _ef_roundtrip(pg, cfg))(pgs)
        new_residuals = pgs - deqs
        pgs = deqs

    fused_src = (a_flat, p_flats) if _fused_src_ok(cfg) else None
    return k, any_params, a_flat, pgs, new_residuals, fused_src


def _commit_residual(state: OuterState, new_residuals, ef_slot: int):
    """Merge a boundary's EF residual into the state's buffer. In
    two-slot mode only the boundary's OWN slot is written — and it is
    written against the residual buffer as it stands at commit time,
    not the begin-time snapshot, so an interleaved commit of the other
    lineage is never clobbered (this is what makes EF safe under the
    delayed overlap)."""
    if state.residual.ndim == 3:
        return state.residual.at[ef_slot].set(new_residuals)
    return new_residuals


def outer_sync_sim(stacked_params, state: OuterState, cfg: DiLoCoConfig,
                   ring_order: Sequence[int] | None = None,
                   weights: jnp.ndarray | None = None,
                   ef_slot: int = 0):
    """Mirror of ``outer_sync`` over stacked (k, ...) worker params with a
    SHARED outer state. Residuals are per-worker when EF is on."""
    k, any_params, a_flat, pgs, new_residuals, fused_src = \
        _sim_pseudograds(stacked_params, state, cfg, ef_slot=ef_slot)
    reduced = simulate_ring_all_reduce(pgs, ring_order=ring_order,
                                       cfg=cfg.ring, weights=weights,
                                       fused_src=fused_src)
    res = _commit_residual(state, new_residuals, ef_slot)
    # every worker's reduced copy is identical -> apply outer once
    new_params, new_state = _apply_outer(
        reduced[0], any_params, state._replace(residual=res),
        cfg, res, a_flat)
    stacked_new = jax.tree.map(
        lambda p: jnp.broadcast_to(p[None], (k,) + p.shape), new_params)
    return stacked_new, new_state


# -- overlapped outer sync (begin / finish pair, sim path) -------------------


class OuterSyncHandle:
    """One boundary's outer sync in flight (sim path).

    Created by :func:`begin_outer_sync_sim` at an outer boundary: the
    pseudo-gradients are computed and the first-hop quantization can be
    dispatched immediately; the remaining ring hops are dispatched by
    the trainer between inner-phase scan chunks (``step()``), and the
    reduced result is applied with a one-phase delay by
    :func:`finish_outer_sync_sim`. ``cfg.overlap == 'none'`` degenerates
    to begin+finish back-to-back at the same boundary, which is
    bit-identical to :func:`outer_sync_sim` (the ring op is bit-exact
    against the one-shot simulator and the apply path is shared).

    The handle retains the pseudo-gradient rows: when a participant
    dies mid-overlap the torn partial reduction is discarded and
    :func:`resync_outer_sim` re-reduces the retained rows over the
    survivors.
    """

    def __init__(self, op: RingSyncOp, cfg: DiLoCoConfig, a_flat,
                 new_residuals, weights, k: int, ef_slot: int = 0):
        self.op = op
        self.cfg = cfg
        # the anchor SNAPSHOT the pseudo-gradients are rooted at: the
        # delayed apply lands on this snapshot (see
        # finish_outer_sync_sim for why), so the handle must carry it
        # across the interleaved apply of the previous boundary
        self.a_flat = a_flat
        # EF residual produced at begin time. Two-slot mode: the (k, n)
        # replacement for residual slot ``ef_slot`` only — committed
        # into the commit-time state by _commit_residual, never as a
        # whole-buffer overwrite (a begin-time snapshot of the buffer
        # would resurrect the other lineage's stale residual)
        self.new_residuals = new_residuals
        self.ef_slot = ef_slot
        self.weights = weights
        self.k = k
        self.aborted = False

    def step(self) -> bool:
        """Dispatch the next ring hop; True iff one was dispatched."""
        if self.aborted:
            return False
        return self.op.step()

    @property
    def hops_total(self) -> int:
        return 0 if self.aborted else self.op.hops_total

    @property
    def hops_done(self) -> int:
        return 0 if self.aborted else self.op.hops_done

    def abort(self) -> None:
        """Discard this boundary's sync: drop the staged accumulators
        and retained inputs so nothing can be applied. Further
        ``finish``/``resync`` raises :class:`SyncAbortedError`."""
        self.aborted = True
        self.op = None

    def norm_sideband(self):
        """(k, k * buckets) per-chunk norm sideband of the retained
        pseudo-gradient rows (admission layer / localization)."""
        if self.aborted:
            raise SyncAbortedError("norm_sideband on an aborted sync")
        return self.op.norm_sideband()

    def sanitize(self, slots) -> None:
        """Zero the retained rows of ``slots`` so a subsequent
        ``restart`` re-reduces only clean contributions.

        Zero-WEIGHTING a corrupted row is NOT enough: ``NaN * 0 == NaN``
        and the op's staged accumulators were built from the raw rows,
        so after sanitizing the caller must RESTART the reduction (the
        staged partial state is contaminated and is discarded by
        ``restart``), never ``finish`` it.
        """
        if self.aborted:
            raise SyncAbortedError("sanitize on an aborted sync")
        if not slots:
            return
        idx = jnp.asarray(sorted(slots), dtype=jnp.int32)
        op = self.op
        op.xs = op.xs.at[idx].set(0.0)
        if op.fused_src is not None:
            # fused first-hop tx reads (anchor, thetas): a zero row in
            # pg-space means theta == anchor for that slot
            a_flat, thetas = op.fused_src
            thetas = thetas.at[idx].set(a_flat)
            op.fused_src = (a_flat, thetas)
        if self.cfg.error_feedback:
            # the EF rewrite folded the corrupted rows into the new
            # residuals — a poisoned residual would re-inject NaNs into
            # the NEXT boundary's pseudo-gradients
            self.new_residuals = self.new_residuals.at[idx].set(0.0)


def begin_outer_sync_sim(stacked_params, state: OuterState,
                         cfg: DiLoCoConfig,
                         ring_order: Sequence[int] | None = None,
                         weights: jnp.ndarray | None = None,
                         ef_slot: int = 0) -> OuterSyncHandle:
    """Boundary front half: compute + quantize the pseudo-gradients and
    stage the ring as a steppable op. Nothing is applied yet.

    ``ef_slot`` (two-slot EF under the delayed overlap): the residual
    lineage this boundary belongs to. The trainer alternates 0/1 per
    begin, so boundary t reads the residual written by boundary t-2 —
    whose sync has, with at most one handle in flight, always landed by
    then. (``state.outer_step`` parity is NOT usable as the slot: the
    first two begins both observe outer_step == 0.)"""
    k, _, a_flat, pgs, new_residuals, fused_src = _sim_pseudograds(
        stacked_params, state, cfg, ef_slot=ef_slot)
    if weights is None:
        weights = jnp.ones((k,), jnp.float32)
    op = RingSyncOp(pgs, ring_order=ring_order, cfg=cfg.ring,
                    weights=weights, fused_src=fused_src)
    return OuterSyncHandle(op, cfg, a_flat, new_residuals, weights, k,
                           ef_slot=ef_slot)


def _finish_apply(handle: OuterSyncHandle, reduced, stacked_params,
                  state: OuterState):
    if handle.aborted:
        raise SyncAbortedError("apply on an aborted sync")
    any_params = jax.tree.map(lambda p: p[0], stacked_params)
    res = _commit_residual(state, handle.new_residuals, handle.ef_slot)
    new_params, new_state = _apply_outer(
        reduced[0], any_params, state._replace(residual=res),
        handle.cfg, res, handle.a_flat)
    stacked_new = jax.tree.map(
        lambda p: jnp.broadcast_to(p[None], (handle.k,) + p.shape),
        new_params)
    return stacked_new, new_state


def finish_outer_sync_sim(handle: OuterSyncHandle, stacked_params,
                          state: OuterState):
    """Drain the remaining hops and apply the reduced pseudo-gradient
    to the anchor SNAPSHOT it was computed against (the handle's
    ``a_flat``, flat-space Nesterov), then reset every worker to the
    new tip.

    This is deliberate and NOT the "apply to the current anchor"
    stale-gradient convention. Under the trainer's boundary order
    (begin new -> finish old), the anchor at finish time has already
    absorbed the PREVIOUS boundary's delta, so tip t is built as
    ``T_t = Nesterov(T_{t-2}, Delta_{t-1})`` — two interleaved
    lineages, each advanced by exactly the synchronous DiLoCo rule
    (every delta applies to the very anchor its pseudo-gradients are
    rooted at, zero base-mismatch; workers hop to the newest tip each
    boundary, so the next pseudo-gradient re-derives from it and no
    signal is lost; the shared outer momentum threads sequentially
    through every apply and mixes the lineages). The alternative —
    applying Delta_{t-1} on top of tip T_{t-1} — compounds two
    same-rooted progress segments under the 0.7/0.9 outer Nesterov and
    measurably overshoots: 40–120% worse held-out anchor loss on the
    BENCH_sync overlap scenario, vs ~3% for this formulation
    (delayed-vs-synchronous, same data/steps)."""
    if handle.aborted:
        raise SyncAbortedError("finish on an aborted sync")
    return _finish_apply(handle, handle.op.finish(), stacked_params,
                         state)


def resync_outer_sim(handle: OuterSyncHandle, stacked_params,
                     state: OuterState, weights: jnp.ndarray):
    """Torn-overlap fallback: a participant died while the reduction
    was on the wire, so the partial accumulator can never be applied
    (it absorbed hops the dead worker will not forward). Re-reduce the
    RETAINED pseudo-gradients synchronously over the survivors
    (``weights`` with the dead workers zeroed) and apply — every
    survivor derives the identical result from identical retained
    inputs, so recovery is bit-consistent."""
    if handle.aborted:
        raise SyncAbortedError("resync on an aborted sync")
    return _finish_apply(handle, handle.op.restart(weights),
                         stacked_params, state)


def sync_wire_bytes(params, n_workers: int, cfg: DiLoCoConfig) -> int:
    """Per-worker wire bytes of ONE outer sync (benchmark helper)."""
    n = sum(l.size for l in jax.tree.leaves(params))
    return ring_wire_bytes(n, n_workers, cfg.quant,
                           buckets=cfg.sync_buckets)


def bandwidth_reduction_factor(cfg: DiLoCoConfig,
                               dp_bytes_per_step: float = 4.0) -> float:
    """Communication-volume reduction vs per-step fp32 data-parallel
    (paper: 400x at H=100/int8, ~2000x at H=500)."""
    bytes_per_elem = {"int8": 1.0, "int4": 0.5, "fp32": 4.0}[cfg.quant]
    return cfg.inner_steps * dp_bytes_per_step / bytes_per_elem
