"""DiLoCo (Distributed Low-Communication) outer optimization — the heart
of PRIME (INTELLECT-1 §2.1, Alg. 1).

Each DiLoCo worker runs H inner AdamW steps, then all workers synchronize
*pseudo-gradients* ``delta_i = anchor - theta_i`` through the int8 ring
all-reduce and apply a shared Nesterov outer step:

    delta = (1/sum w) * sum_i  w_i (anchor - theta_i)      (elastic weights)
    anchor' = NesterovSGD(anchor, delta)
    theta_i <- anchor'                                      (all workers)

Two synchronization paths, sharing all math:
  * ``outer_sync``     — per-device, inside a shard_map region manual over
    the DiLoCo mesh axis ('pod' across pods, 'data' within one);
  * ``outer_sync_sim`` — stacked (k, ...) single-process mirror used by
    the CPU cluster simulator / examples / tests.

The anchor is kept in fp32 (it is the paper's CPU-offloaded master copy;
on TPU it can live in ``pinned_host`` memory — see
``sharding.plans.outer_state_sharding``).
"""
from __future__ import annotations

import dataclasses
from typing import Any, NamedTuple, Sequence

import jax
import jax.numpy as jnp

from repro.core import compression
from repro.core.ring_reduce import (RingConfig, ring_all_reduce,
                                    ring_wire_bytes,
                                    simulate_ring_all_reduce)
from repro.kernels import ops as qops
from repro.optim.nesterov import NesterovSGD, NesterovState


@dataclasses.dataclass(frozen=True)
class DiLoCoConfig:
    inner_steps: int = 100          # H (paper: 100; DiLoCo paper: up to 500)
    outer_lr: float = 0.7
    outer_momentum: float = 0.9
    quant: str = "int8"             # 'int8' | 'fp32' | 'int4'
    quant_impl: str = "jnp"         # 'jnp' | 'pallas'
    error_feedback: bool = False    # beyond-paper (see core.compression)
    host_offload_outer: bool = False  # TPU-only placement flag

    @property
    def ring(self) -> RingConfig:
        return RingConfig(quant=self.quant, impl=self.quant_impl)

    @property
    def outer_opt(self) -> NesterovSGD:
        return NesterovSGD(lr=self.outer_lr, momentum=self.outer_momentum)


class OuterState(NamedTuple):
    anchor: Any                # fp32 pytree: theta at the last outer step
    opt: NesterovState         # fp32 outer momentum
    residual: Any              # fp32 flat EF residual (zeros if disabled)
    outer_step: jnp.ndarray


# -- flat <-> pytree helpers --------------------------------------------------


def flatten_pytree(tree):
    leaves, treedef = jax.tree.flatten(tree)
    shapes = [l.shape for l in leaves]
    sizes = [l.size for l in leaves]
    flat = jnp.concatenate(
        [l.reshape(-1).astype(jnp.float32) for l in leaves]) \
        if leaves else jnp.zeros((0,), jnp.float32)

    def unflatten(vec, like=None):
        out, off = [], 0
        ref_leaves = jax.tree.leaves(like) if like is not None else leaves
        for s, shp, ref in zip(sizes, shapes, ref_leaves):
            out.append(vec[off:off + s].reshape(shp).astype(ref.dtype))
            off += s
        return jax.tree.unflatten(treedef, out)

    return flat, unflatten


def init_outer_state(params, cfg: DiLoCoConfig) -> OuterState:
    anchor = jax.tree.map(lambda p: p.astype(jnp.float32), params)
    opt = cfg.outer_opt.init(anchor)
    n = sum(l.size for l in jax.tree.leaves(params))
    residual = jnp.zeros((n if cfg.error_feedback else 0,), jnp.float32)
    return OuterState(anchor, opt, residual, jnp.zeros((), jnp.int32))


def init_outer_state_sim(params_one_worker, cfg: DiLoCoConfig,
                         k: int) -> OuterState:
    """Outer state for the stacked single-process simulator: shared
    anchor/momentum, per-worker EF residuals."""
    st = init_outer_state(params_one_worker, cfg)
    n = st.residual.shape[0]
    return st._replace(residual=jnp.zeros((k, n), jnp.float32))


def _pseudograd(params, state: OuterState, cfg: DiLoCoConfig):
    """Flat fp32 pseudo-gradient (+EF residual), and the unflatten fn."""
    p_flat, unflatten = flatten_pytree(params)
    a_flat, _ = flatten_pytree(state.anchor)
    pg = a_flat - p_flat
    new_residual = state.residual
    if cfg.error_feedback:
        pg = pg + state.residual
        q = qops.quantize(pg, impl=cfg.quant_impl) if cfg.quant == "int8" \
            else compression.quantize4(pg)
        deq = (qops.dequantize(q, impl=cfg.quant_impl)
               if cfg.quant == "int8"
               else compression.dequantize4(q, pg.shape))
        new_residual = pg - deq
        pg = deq
    return pg, new_residual, unflatten


def _apply_outer(reduced_pg_flat, params, state: OuterState,
                 cfg: DiLoCoConfig, new_residual):
    delta = flatten_pytree(state.anchor)[1](
        reduced_pg_flat, like=state.anchor)
    new_anchor, new_opt = cfg.outer_opt.update(delta, state.opt,
                                               state.anchor)
    new_params = jax.tree.map(
        lambda a, p: a.astype(p.dtype), new_anchor, params)
    return new_params, OuterState(new_anchor, new_opt, new_residual,
                                  state.outer_step + 1)


# -- distributed path (inside shard_map, manual over `axis_name`) ------------


def outer_sync(params, state: OuterState, cfg: DiLoCoConfig,
               axis_name: str, ring_order: Sequence[int] | None = None,
               weight: jnp.ndarray | None = None):
    """One DiLoCo outer step for this worker. Returns (params', state')."""
    pg, new_residual, _ = _pseudograd(params, state, cfg)
    reduced = ring_all_reduce(pg, axis_name, ring_order=ring_order,
                              cfg=cfg.ring, weight=weight)
    return _apply_outer(reduced, params, state, cfg, new_residual)


# -- single-process simulation (stacked workers) ------------------------------


def outer_sync_sim(stacked_params, state: OuterState, cfg: DiLoCoConfig,
                   ring_order: Sequence[int] | None = None,
                   weights: jnp.ndarray | None = None):
    """Mirror of ``outer_sync`` over stacked (k, ...) worker params with a
    SHARED outer state. Residuals are per-worker when EF is on."""
    k = jax.tree.leaves(stacked_params)[0].shape[0]

    def per_worker(params_i, residual_i):
        st = state._replace(residual=residual_i)
        return _pseudograd(params_i, st, cfg)[:2]

    residuals = (state.residual if cfg.error_feedback
                 else jnp.zeros((k, 0), jnp.float32))
    pgs, new_residuals = jax.vmap(per_worker)(stacked_params, residuals)
    reduced = simulate_ring_all_reduce(pgs, ring_order=ring_order,
                                       cfg=cfg.ring, weights=weights)
    # every worker's reduced copy is identical -> apply outer once
    any_params = jax.tree.map(lambda p: p[0], stacked_params)
    new_params, new_state = _apply_outer(
        reduced[0], any_params, state._replace(residual=new_residuals),
        cfg, new_residuals)
    stacked_new = jax.tree.map(
        lambda p: jnp.broadcast_to(p[None], (k,) + p.shape), new_params)
    return stacked_new, new_state


def sync_wire_bytes(params, n_workers: int, cfg: DiLoCoConfig) -> int:
    """Per-worker wire bytes of ONE outer sync (benchmark helper)."""
    n = sum(l.size for l in jax.tree.leaves(params))
    return ring_wire_bytes(n, n_workers, cfg.quant)


def bandwidth_reduction_factor(cfg: DiLoCoConfig,
                               dp_bytes_per_step: float = 4.0) -> float:
    """Communication-volume reduction vs per-step fp32 data-parallel
    (paper: 400x at H=100/int8, ~2000x at H=500)."""
    bytes_per_elem = {"int8": 1.0, "int4": 0.5, "fp32": 4.0}[cfg.quant]
    return cfg.inner_steps * dp_bytes_per_step / bytes_per_elem
