"""SyncEngine: bucketed, fused DiLoCo outer-sync pipeline state.

The seed's outer step re-derived everything from pytrees on every call:
``_pseudograd`` flattened BOTH the worker params and the anchor (the
anchor twice — once for the pseudo-gradient, once more in
``_apply_outer`` just to rebuild the unflatten closure), and the outer
Nesterov update ran leaf-by-leaf on freshly unflattened trees.  Per
outer step per worker that is several full-model HBM round-trips that
have nothing to do with the actual math.

``SyncEngine`` hoists all of it to construction time:

  * the flatten **metadata** (treedef, shapes, sizes, offsets) is
    computed once per (treedef, shapes) key and cached — ``unflatten``
    never needs a reference flatten again;
  * the **anchor lives as a persistent flat fp32 buffer**
    (``OuterState.anchor_flat``, built once at ``init_outer_state``):
    the pseudo-gradient is one subtract off the persistent buffer, and
    the outer Nesterov step updates the buffer in place in flat space
    (elementwise, so bit-identical to the per-leaf formulation) before
    a single unflatten materializes the new anchor/param trees;
  * the flat (anchor, theta) pair doubles as the source for the ring's
    fused first-hop transmit (``ops.quantize_pseudograd``) so the
    quantizer reads model memory, not a materialized pseudo-gradient.

Engines are cheap static metadata — they hold no arrays — so the
module-level cache never pins device memory.
"""
from __future__ import annotations

from typing import Any

import jax
import jax.numpy as jnp
import numpy as np

_ENGINES: dict[Any, "SyncEngine"] = {}


class SyncEngine:
    """Static flatten/unflatten metadata for one pytree structure."""

    def __init__(self, treedef, shapes, dtypes):
        self.treedef = treedef
        self.shapes = tuple(tuple(s) for s in shapes)
        self.dtypes = tuple(dtypes)
        self.sizes = tuple(int(np.prod(s, dtype=np.int64)) if s else 1
                           for s in self.shapes)
        self.offsets = tuple(np.cumsum((0,) + self.sizes).tolist())
        self.numel = int(self.offsets[-1])

    @classmethod
    def for_tree(cls, tree) -> "SyncEngine":
        """Engine for ``tree``'s structure (cached on treedef+shapes)."""
        leaves, treedef = jax.tree.flatten(tree)
        shapes = tuple(tuple(l.shape) for l in leaves)
        dtypes = tuple(jnp.result_type(l) for l in leaves)
        key = (treedef, shapes, dtypes)
        eng = _ENGINES.get(key)
        if eng is None:
            eng = _ENGINES[key] = cls(treedef, shapes, dtypes)
        return eng

    # -- flat <-> tree -------------------------------------------------------

    def flatten(self, tree) -> jnp.ndarray:
        """Concat all leaves into one flat fp32 vector (vmap-safe)."""
        leaves = jax.tree.leaves(tree)
        if not leaves:
            return jnp.zeros((0,), jnp.float32)
        return jnp.concatenate(
            [l.reshape(-1).astype(jnp.float32) for l in leaves])

    def unflatten(self, vec: jnp.ndarray, like=None):
        """Rebuild the pytree from a flat vector using only static
        metadata. ``like`` supplies target dtypes (default: the
        template's dtypes)."""
        dtypes = ([jnp.result_type(l) for l in jax.tree.leaves(like)]
                  if like is not None else self.dtypes)
        out = []
        for i, (shape, size) in enumerate(zip(self.shapes, self.sizes)):
            out.append(vec[self.offsets[i]:self.offsets[i] + size]
                       .reshape(shape).astype(dtypes[i]))
        return jax.tree.unflatten(self.treedef, out)


# -- per-shard flat view ------------------------------------------------------


def shard_flat_size(shapes, specs, axis_sizes: dict) -> int:
    """Per-DEVICE flat length of the concat of local parameter shards.

    Inside a ``shard_map`` region manual over the whole mesh, each
    device sees its leaves as LOCAL shards; flattening those yields a
    per-shard flat anchor whose length is the sum of local shard sizes
    — ``prod(shape) / prod(mesh axes named in the leaf's spec)``. This
    is the static metadata the sharded-plan outer sync uses to size the
    buffer it threads through the region (the per-shard analogue of
    ``OuterState.anchor_flat``).

    ``shapes``/``specs`` are matching pytrees of leaf shapes (tuples or
    ShapeDtypeStructs) and PartitionSpecs; ``axis_sizes`` maps mesh
    axis name -> size.
    """
    import jax.sharding as _js

    def leaf_local(shape, spec) -> int:
        shape = tuple(getattr(shape, "shape", shape))
        size = int(np.prod(shape, dtype=np.int64)) if shape else 1
        div = 1
        for entry in tuple(spec):
            if entry is None:
                continue
            names = entry if isinstance(entry, tuple) else (entry,)
            for a in names:
                div *= int(axis_sizes.get(a, 1))
        assert size % div == 0, \
            f"shard spec {spec} does not divide leaf {shape}"
        return size // div

    leaves_s = jax.tree.leaves(
        shapes, is_leaf=lambda x: isinstance(x, (tuple, list))
        or hasattr(x, "shape"))
    leaves_p = jax.tree.leaves(
        specs, is_leaf=lambda x: isinstance(x, _js.PartitionSpec))
    assert len(leaves_s) == len(leaves_p), \
        "shapes/specs trees do not match"
    return sum(leaf_local(s, p) for s, p in zip(leaves_s, leaves_p))
