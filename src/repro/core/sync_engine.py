"""SyncEngine: bucketed, fused DiLoCo outer-sync pipeline state.

The seed's outer step re-derived everything from pytrees on every call:
``_pseudograd`` flattened BOTH the worker params and the anchor (the
anchor twice — once for the pseudo-gradient, once more in
``_apply_outer`` just to rebuild the unflatten closure), and the outer
Nesterov update ran leaf-by-leaf on freshly unflattened trees.  Per
outer step per worker that is several full-model HBM round-trips that
have nothing to do with the actual math.

``SyncEngine`` hoists all of it to construction time:

  * the flatten **metadata** (treedef, shapes, sizes, offsets) is
    computed once per (treedef, shapes) key and cached — ``unflatten``
    never needs a reference flatten again;
  * the **anchor lives as a persistent flat fp32 buffer**
    (``OuterState.anchor_flat``, built once at ``init_outer_state``):
    the pseudo-gradient is one subtract off the persistent buffer, and
    the outer Nesterov step updates the buffer in place in flat space
    (elementwise, so bit-identical to the per-leaf formulation) before
    a single unflatten materializes the new anchor/param trees;
  * the flat (anchor, theta) pair doubles as the source for the ring's
    fused first-hop transmit (``ops.quantize_pseudograd``) so the
    quantizer reads model memory, not a materialized pseudo-gradient.

Engines are cheap static metadata — they hold no arrays — so the
module-level cache never pins device memory.
"""
from __future__ import annotations

from typing import Any

import jax
import jax.numpy as jnp
import numpy as np

_ENGINES: dict[Any, "SyncEngine"] = {}


class SyncEngine:
    """Static flatten/unflatten metadata for one pytree structure."""

    def __init__(self, treedef, shapes, dtypes):
        self.treedef = treedef
        self.shapes = tuple(tuple(s) for s in shapes)
        self.dtypes = tuple(dtypes)
        self.sizes = tuple(int(np.prod(s, dtype=np.int64)) if s else 1
                           for s in self.shapes)
        self.offsets = tuple(np.cumsum((0,) + self.sizes).tolist())
        self.numel = int(self.offsets[-1])

    @classmethod
    def for_tree(cls, tree) -> "SyncEngine":
        """Engine for ``tree``'s structure (cached on treedef+shapes)."""
        leaves, treedef = jax.tree.flatten(tree)
        shapes = tuple(tuple(l.shape) for l in leaves)
        dtypes = tuple(jnp.result_type(l) for l in leaves)
        key = (treedef, shapes, dtypes)
        eng = _ENGINES.get(key)
        if eng is None:
            eng = _ENGINES[key] = cls(treedef, shapes, dtypes)
        return eng

    # -- flat <-> tree -------------------------------------------------------

    def flatten(self, tree) -> jnp.ndarray:
        """Concat all leaves into one flat fp32 vector (vmap-safe)."""
        leaves = jax.tree.leaves(tree)
        if not leaves:
            return jnp.zeros((0,), jnp.float32)
        return jnp.concatenate(
            [l.reshape(-1).astype(jnp.float32) for l in leaves])

    def unflatten(self, vec: jnp.ndarray, like=None):
        """Rebuild the pytree from a flat vector using only static
        metadata. ``like`` supplies target dtypes (default: the
        template's dtypes)."""
        dtypes = ([jnp.result_type(l) for l in jax.tree.leaves(like)]
                  if like is not None else self.dtypes)
        out = []
        for i, (shape, size) in enumerate(zip(self.shapes, self.sizes)):
            out.append(vec[self.offsets[i]:self.offsets[i] + size]
                       .reshape(shape).astype(dtypes[i]))
        return jax.tree.unflatten(self.treedef, out)
