"""ElasticTrainer: the full PRIME training loop.

Per outer step t (paper Alg. 1 + §2.4):
  1. ``ClusterSimulator.begin_outer_step`` applies membership events
     (join / graceful leave / crash / straggler) — heartbeat sweep
     evicts silent nodes; joiners are admitted at this boundary and
     P2P-fetch the latest checkpoint (blocking or non-blocking mode);
  2. every live worker runs H inner AdamW steps on its data shard;
  3. the bandwidth monitor re-solves the max-min ring order if links
     drifted (a changed order recompiles the sync step — same cost the
     paper pays re-rendezvousing process groups);
  4. the int8 ring all-reduce averages pseudo-gradients over live
     workers (weight 0 for joiners/stragglers) with the RetryPolicy
     excluding workers that die mid-collective;
  5. the shared Nesterov outer step updates the anchor; all workers
     reset to it; async checkpoint.

This class runs the *stacked single-process simulation* (k workers on
one device) so the complete protocol is testable on CPU; the
distributed path shares every component (see train/step.py builders +
launch/train.py) and the two are bit-equivalence-tested in
tests/test_distributed.py.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import diloco as dl
from repro.core import topology
from repro.core.elastic_mesh import SlotAssignment
from repro.core.fault_tolerance import ClusterSimulator, RetryPolicy
from repro.data.pipeline import DataConfig, TokenPipeline
from repro.optim.adamw import AdamW


@dataclasses.dataclass
class TrainerConfig:
    diloco: dl.DiLoCoConfig
    inner_lr: float | Callable = 7.5e-5
    ckpt_dir: str | None = None
    ckpt_every_outer: int = 1
    # 'flat'  — npy-per-leaf dirs (seed layout, CheckpointServer-served)
    # 'store' — content-addressed chunk store (dedup + swarm-fetchable)
    # 'delta' — chunk store + int8/int4 delta chain between base anchors
    ckpt_engine: str = "flat"
    ckpt_delta_base_every: int = 8
    ckpt_codec: str = "int8"       # delta codec: 'int8' | 'int4'
    ckpt_chunk_bytes: int = 1 << 20
    # retention: keep the newest N store/delta checkpoints, gc the rest
    # (ChunkStore.gc keeps delta chains restorable; runs FIFO behind
    # the async persists). None = keep everything (seed behavior).
    ckpt_keep: int | None = None
    max_workers: int = 16
    blocking_join: bool = True     # paper used blocking in production
    seconds_per_outer_step: float = 60.0


class ElasticTrainer:
    def __init__(self, model, cfg: TrainerConfig, data_cfg: DataConfig,
                 init_params, sim: ClusterSimulator):
        self.model = model
        self.cfg = cfg
        self.data_cfg = data_cfg
        self.sim = sim
        self.optimizer = AdamW(lr=cfg.inner_lr)
        self.retry = RetryPolicy()
        live = sim.hb.live_ids()
        self.slots = SlotAssignment(cfg.max_workers)
        for nid in live:
            self.slots.assign(nid)
        k = cfg.max_workers
        self.k = k
        # stacked worker state (slot-major)
        stack = lambda t: jax.tree.map(
            lambda x: jnp.broadcast_to(x[None], (k,) + x.shape), t)
        self.params = stack(init_params)
        self.opt_state = jax.vmap(self.optimizer.init)(self.params)
        self.outer = dl.init_outer_state_sim(init_params, cfg.diloco, k)
        self.bw = topology.BandwidthMonitor(k)
        self.ring_order = tuple(range(k))
        self.inner_phase_jit = jax.jit(self._inner_phase)
        self.history: list[dict] = []
        self._pipelines = {}
        self.ckpt_store = None
        self.snapshotter = None
        self._ckpt_steps: list[int] = []
        self.persisted_steps: list[int] = []   # on disk, not just queued
        self._stream_join = None               # in-flight StreamingFetcher
        if cfg.ckpt_dir and cfg.ckpt_engine != "flat":
            from repro.checkpointing import (AsyncSnapshotter, ChunkStore,
                                             DeltaCheckpointer,
                                             DeltaConfig)
            self.ckpt_store = ChunkStore(
                cfg.ckpt_dir, chunk_bytes=cfg.ckpt_chunk_bytes)
            if cfg.ckpt_engine == "delta":
                writer = DeltaCheckpointer(
                    self.ckpt_store,
                    DeltaConfig(base_every=cfg.ckpt_delta_base_every,
                                codec=cfg.ckpt_codec,
                                quant_impl=cfg.diloco.quant_impl))
                write_fn = writer.save
            elif cfg.ckpt_engine == "store":
                write_fn = self.ckpt_store.save_tree
            else:
                raise ValueError(
                    f"unknown ckpt_engine {cfg.ckpt_engine!r}")
            # double-buffered: persists overlap the next inner phase,
            # bounded memory, FIFO so the delta reference chain is
            # written in step order; on_persist tracks what is actually
            # on disk — the retention gc keep-set reads it at task
            # execution time, so gc can never count an in-flight save
            self.snapshotter = AsyncSnapshotter(
                write_fn,
                on_persist=lambda step, _m:
                    self.persisted_steps.append(step))

    # -- inner phase ----------------------------------------------------------

    def _inner_step(self, params, opt_state, batch, active):
        """One vmapped inner step; inactive slots are frozen."""
        def one(p, o, b):
            (_, metrics), g = jax.value_and_grad(
                self.model.loss, has_aux=True)(p, b)
            new_p, new_o = self.optimizer.update(g, o, p)
            return new_p, new_o, metrics

        new_p, new_o, metrics = jax.vmap(one)(params, opt_state, batch)
        keep = lambda new, old: jax.tree.map(
            lambda n, o: jnp.where(
                active.reshape((-1,) + (1,) * (n.ndim - 1)), n, o),
            new, old)
        return keep(new_p, params), keep(new_o, opt_state), metrics

    def _inner_phase(self, params, opt_state, batches, active):
        """All H inner steps as ONE ``lax.scan`` over pre-stacked
        (H, k, ...) batches: a single jit dispatch per outer step, and
        only the (H, k) loss trace is retained on device instead of H
        full metric pytrees."""
        def body(carry, batch):
            p, o = carry
            p, o, metrics = self._inner_step(p, o, batch, active)
            return (p, o), metrics["loss"]

        (params, opt_state), losses = jax.lax.scan(
            body, (params, opt_state), batches)
        return params, opt_state, losses

    def _pipeline(self, slot: int) -> TokenPipeline:
        if slot not in self._pipelines:
            self._pipelines[slot] = TokenPipeline(
                self.data_cfg, slot, self.k)
        return self._pipelines[slot]

    def _batches(self, step: int):
        bs = [self._pipeline(s).batch_at(step) for s in range(self.k)]
        return jax.tree.map(lambda *xs: jnp.stack(xs), *bs)

    # -- outer loop -----------------------------------------------------------

    def run(self, n_outer_steps: int, *, inner_steps: int | None = None,
            bandwidth_sampler=None) -> list[dict]:
        h = inner_steps or self.cfg.diloco.inner_steps
        global_step = int(self.outer.outer_step) * h
        for t in range(n_outer_steps):
            plan = self.sim.begin_outer_step(t)
            live_slots = self._sync_membership(plan)
            active = jnp.asarray(
                self.slots.live_mask(plan["live"]), jnp.float32)

            batches = jax.tree.map(
                lambda *xs: jnp.stack(xs),
                *[self._batches(global_step + i) for i in range(h)])
            self.params, self.opt_state, losses = self.inner_phase_jit(
                self.params, self.opt_state, batches, active)
            global_step += h

            # bandwidth-aware ring re-ordering (paper §2.5)
            if bandwidth_sampler is not None:
                self.bw.observe_matrix(bandwidth_sampler(t))
                changed, order = self.bw.maybe_reorder()
                if changed:
                    self.ring_order = order

            # elastic weighted sync with mid-collective retry
            weights = self.slots.live_mask(
                plan["live"],
                zero_weight_ids=plan["joined"] + plan["stragglers"])

            def attempt(live_set):
                w = np.array(weights)
                for nid, slot in self.slots.slot_of.items():
                    if nid not in live_set:
                        w[slot] = 0.0
                return self._outer_sync(jnp.asarray(w))

            (self.params, self.outer), _, attempts = \
                self.retry.run_collective(attempt, plan["live"])

            mean_loss = float(losses[-1][
                jnp.asarray(weights) > 0].mean()) if np.any(
                np.asarray(weights) > 0) else float("nan")
            rec = {"outer_step": t, "live": plan["live"],
                   "joined": plan["joined"], "left": plan["left"],
                   "loss": mean_loss, "ring_order": self.ring_order,
                   "attempts": attempts,
                   "wire_bytes": dl.sync_wire_bytes(
                       jax.tree.map(lambda p: p[0], self.params),
                       max(1, int(np.sum(np.asarray(weights) > 0))),
                       self.cfg.diloco)}
            # streamed recovery that completed during this inner phase
            # is adopted HERE — the paper's overlapped onboarding: the
            # fetch ran under compute, admission costs one restore
            join_rec = self.poll_stream_join()
            if join_rec is not None:
                rec["stream_join"] = join_rec
            self.history.append(rec)

            if self.cfg.ckpt_dir and \
                    (t + 1) % self.cfg.ckpt_every_outer == 0:
                tree = {"params": jax.tree.map(
                            lambda p: p[0], self.params),
                        "outer_momentum": self.outer.opt.momentum,
                        "anchor": self.outer.anchor}
                meta = {"outer_step": t + 1}
                if self.snapshotter is not None:
                    self.snapshotter.submit(global_step, tree, meta)
                    self._ckpt_steps.append(global_step)
                    if self.cfg.ckpt_keep and self.ckpt_store and \
                            len(self._ckpt_steps) > self.cfg.ckpt_keep:
                        # the keep set is computed when the task RUNS
                        # (FIFO behind every pending persist), from
                        # what is actually on disk by then — never
                        # from steps still in flight
                        keep = self.cfg.ckpt_keep
                        self.snapshotter.submit_task(
                            lambda k=keep: self.ckpt_store.gc(
                                keep_steps=tuple(
                                    self.persisted_steps[-k:])))
                else:
                    from repro.checkpointing import save_async
                    save_async(self.cfg.ckpt_dir, global_step, tree,
                               meta)
        if self.snapshotter is not None:
            self.snapshotter.flush()
        return self.history

    def begin_stream_join(self, peers, *, store_root=None,
                          step: int | None = None,
                          range_chunks: int = 8, timeout: float = 20.0):
        """Start an overlapped streaming recovery from ``peers`` on a
        background thread (paper §2.4.2: recovery overlaps the inner
        phase). The fetch gossips chunk availability, streams the
        manifest chain into this node's store and assembles the delta
        chain incrementally; ``run()`` adopts the result at the first
        outer boundary where it is ready. Returns the fetcher (callers
        outside ``run()`` can ``wait_ready()`` it themselves)."""
        assert self._stream_join is None or self._stream_join.done, \
            "a streaming join is already in flight"
        from repro.checkpointing import ChunkStore, StreamingFetcher
        # an explicit store_root wins (the single-process simulation
        # plays both cluster and joiner: the joiner must stream into
        # its OWN store, not dedup against the serving one); a real
        # joiner defaults to its configured chunk store
        if store_root is not None:
            store = ChunkStore(store_root)
        else:
            store = self.ckpt_store
            assert store is not None, \
                "streaming join needs a chunk store: configure " \
                "ckpt_engine store|delta or pass store_root"
        self._stream_join = StreamingFetcher(
            peers, store, self.checkpoint_like(), step=step,
            range_chunks=range_chunks, timeout=timeout).start()
        return self._stream_join

    def poll_stream_join(self) -> dict | None:
        """Non-blocking: adopt a finished streaming recovery (called at
        every outer boundary by ``run()``). Returns the admission
        record, a failure record, or None while still streaming."""
        f = self._stream_join
        if f is None or not f.done:
            return None
        self._stream_join = None
        if f.failed:
            f.close()
            return {"admitted": False, "error": str(f.error),
                    "stats": f.stats()}
        tree, meta, stats = f.result()
        self.adopt_checkpoint(tree, meta)
        f.close()
        return {"admitted": True, "step": stats["step"],
                "outer_step": meta.get("outer_step"), "stats": stats}

    def checkpoint_like(self):
        """Template pytree matching what run() checkpoints (for
        ``ChunkStore.restore_tree`` / ``delta.restore`` /
        ``swarm.recover``)."""
        return {"params": jax.tree.map(lambda p: p[0], self.params),
                "outer_momentum": self.outer.opt.momentum,
                "anchor": self.outer.anchor}

    def serve_checkpoints(self, port: int = 0):
        """Expose this node's chunk store to joining peers (the
        paper's live-recovery serving side)."""
        from repro.checkpointing import ChunkPeer
        assert self.ckpt_store is not None, \
            "serve_checkpoints requires ckpt_engine 'store' or 'delta'"
        return ChunkPeer(self.ckpt_store, port=port)

    def adopt_checkpoint(self, tree, meta: dict) -> None:
        """Enter at the next outer boundary from a recovered
        checkpoint: every slot resets to the recovered anchor and the
        outer state resumes its momentum (paper §2.4.2 onboarding)."""
        anchor = jax.tree.map(
            lambda a: jnp.asarray(a, jnp.float32), tree["anchor"])
        from repro.core.sync_engine import SyncEngine
        eng = SyncEngine.for_tree(anchor)
        self.outer = self.outer._replace(
            anchor=anchor,
            opt=self.outer.opt._replace(
                momentum=jax.tree.map(
                    lambda m: jnp.asarray(m, jnp.float32),
                    tree["outer_momentum"])),
            outer_step=jnp.asarray(meta.get("outer_step", 0),
                                   jnp.int32),
            anchor_flat=eng.flatten(anchor))
        self.params = jax.tree.map(
            lambda stacked, p: jnp.broadcast_to(
                jnp.asarray(p, stacked.dtype)[None],
                stacked.shape),
            self.params, tree["params"])
        self.opt_state = jax.vmap(self.optimizer.init)(self.params)

    def _outer_sync(self, weights):
        return dl.outer_sync_sim(self.params, self.outer,
                                 self.cfg.diloco,
                                 ring_order=self.ring_order[: self.k],
                                 weights=weights)

    def _sync_membership(self, plan) -> list[int]:
        for nid in plan["left"]:
            self.slots.release(nid)
        slots = []
        for nid in plan["live"]:
            slot = self.slots.assign(nid)
            slots.append(slot)
            if nid in plan["joined"]:
                # joiner adopts the anchor (P2P checkpoint in the
                # distributed path) and fresh optimizer state
                anchor = self.outer.anchor
                self.params = jax.tree.map(
                    lambda stacked, a: stacked.at[slot].set(
                        a.astype(stacked.dtype)),
                    self.params, anchor)
                fresh = self.optimizer.init(
                    jax.tree.map(lambda p: p[slot], self.params))
                self.opt_state = jax.tree.map(
                    lambda stacked, f: stacked.at[slot].set(f),
                    self.opt_state, fresh)
        return slots
