"""ElasticTrainer: the full PRIME training loop.

Per outer step t (paper Alg. 1 + §2.4):
  1. ``ClusterSimulator.begin_outer_step`` applies membership events
     (join / graceful leave / crash / straggler) — heartbeat sweep
     evicts silent nodes; joiners are admitted at this boundary and
     P2P-fetch the latest checkpoint (blocking or non-blocking mode);
  2. every live worker runs H inner AdamW steps on its data shard;
  3. the bandwidth monitor re-solves the max-min ring order if links
     drifted (a changed order recompiles the sync step — same cost the
     paper pays re-rendezvousing process groups);
  4. the int8 ring all-reduce averages pseudo-gradients over live
     workers (weight 0 for joiners/stragglers) with the RetryPolicy
     excluding workers that die mid-collective;
  5. the shared Nesterov outer step updates the anchor; all workers
     reset to it; async checkpoint.

This class runs the *stacked single-process simulation* (k workers on
one device) so the complete protocol is testable on CPU; the
distributed path shares every component (see train/step.py builders +
launch/train.py) and the two are bit-equivalence-tested in
tests/test_distributed.py.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import diloco as dl
from repro.core import ring_reduce as rr
from repro.core import topology
from repro.core import validation as vd
from repro.core.elastic_mesh import SlotAssignment
from repro.core.fault_tolerance import (ClusterSimulator,
                                        CommOverlapLedger, RetryPolicy)
from repro.data.pipeline import DataConfig, TokenPipeline
from repro.optim.adamw import AdamW


@dataclasses.dataclass
class TrainerConfig:
    diloco: dl.DiLoCoConfig
    inner_lr: float | Callable = 7.5e-5
    ckpt_dir: str | None = None
    ckpt_every_outer: int = 1
    # 'flat'  — npy-per-leaf dirs (seed layout, CheckpointServer-served)
    # 'store' — content-addressed chunk store (dedup + swarm-fetchable)
    # 'delta' — chunk store + int8/int4 delta chain between base anchors
    ckpt_engine: str = "flat"
    ckpt_delta_base_every: int = 8
    ckpt_codec: str = "int8"       # delta codec: 'int8' | 'int4'
    ckpt_chunk_bytes: int = 1 << 20
    # retention: keep the newest N store/delta checkpoints, gc the rest
    # (ChunkStore.gc keeps delta chains restorable; runs FIFO behind
    # the async persists). None = keep everything (seed behavior).
    ckpt_keep: int | None = None
    max_workers: int = 16
    blocking_join: bool = True     # paper used blocking in production
    seconds_per_outer_step: float = 60.0
    # inner phase as C jitted scan chunks instead of one monolithic
    # scan: the gaps between chunks are the host's interleave points
    # where in-flight ring hops are dispatched (diloco.overlap =
    # 'delayed'). <=2 distinct chunk lengths -> <=2 compilations.
    inner_chunks: int = 1
    # modeled WAN link for the CommOverlapLedger's logical-time
    # hidden/exposed accounting (paper: ~4 Gb/s internet links);
    # used only until the BandwidthMonitor has observed every edge of
    # the current ring — then the ring's actual bottleneck link rules
    sync_link_bytes_per_s: float = 500e6
    # unit conversion for BandwidthMonitor matrices (Gb/s -> bytes/s)
    link_bytes_per_gbps: float = 125e6
    # contribution-admission layer (untrusted-contributor defense):
    # None disables it; with a ValidationConfig every outer sync's
    # pseudo-gradients pass the admission gates BEFORE any reduced
    # value is applied, flagged contributors are sanitized out of the
    # reduce and quarantined via the ClusterSimulator's reputation
    # state machine (see core/validation.py, docs/sync_pipeline.md)
    validation: vd.ValidationConfig | None = None


class ElasticTrainer:
    def __init__(self, model, cfg: TrainerConfig, data_cfg: DataConfig,
                 init_params, sim: ClusterSimulator, *,
                 batch_provider: Callable | None = None,
                 boundary_hook: Callable | None = None,
                 sync_backend=None):
        self.model = model
        self.cfg = cfg
        self.data_cfg = data_cfg
        self.sim = sim
        # sync_backend (train.step.DistSyncBackend) stages the outer
        # sync as real per-hop shard_map collectives over a mesh's
        # DiLoCo axis instead of the single-device simulator ring;
        # bit-identical by construction, so everything downstream of
        # begin() is shared
        self.sync_backend = sync_backend
        # batch_provider(global_step, h, k) -> stacked (H, k, ...) batch
        # pytree: replaces the TokenPipeline feed (the RL tier's
        # rollout-buffer batcher plugs in here); boundary_hook(t, self)
        # runs after each outer boundary's sync + bookkeeping — the RL
        # PolicyPublisher ships the fresh anchor from it
        self.batch_provider = batch_provider
        self.boundary_hook = boundary_hook
        self.optimizer = AdamW(lr=cfg.inner_lr)
        self.retry = RetryPolicy()
        live = sim.hb.live_ids()
        self.slots = SlotAssignment(cfg.max_workers)
        for nid in live:
            self.slots.assign(nid)
        k = cfg.max_workers
        self.k = k
        # stacked worker state (slot-major)
        stack = lambda t: jax.tree.map(
            lambda x: jnp.broadcast_to(x[None], (k,) + x.shape), t)
        self.params = stack(init_params)
        self.opt_state = jax.vmap(self.optimizer.init)(self.params)
        self.outer = dl.init_outer_state_sim(init_params, cfg.diloco, k)
        self.bw = topology.BandwidthMonitor(k)
        self.ring_order = tuple(range(k))
        self.inner_phase_jit = jax.jit(self._inner_phase)
        # overlapped outer sync (diloco.overlap == 'delayed'): the
        # in-flight handle spans one inner phase; its ring hops are
        # dispatched between scan chunks and the reduced result is
        # applied at the NEXT boundary
        self.overlap = cfg.diloco.overlap == "delayed"
        if self.overlap and cfg.inner_chunks <= 1:
            import warnings
            warnings.warn(
                "overlap='delayed' with inner_chunks<=1: the inner "
                "phase has no interleave points, so all but the first "
                "ring hop drain EXPOSED at the boundary — you pay the "
                "delayed-application schedule without hiding the "
                "communication. Set TrainerConfig.inner_chunks >= "
                f"2*(k-1)+1 = {2 * (cfg.max_workers - 1) + 1} to hide "
                "the whole ring.", stacklevel=2)
        self._inflight: dl.OuterSyncHandle | None = None
        # two-slot EF lineage counter: alternates 0/1 per begin so each
        # overlapped boundary reads/writes its own residual slot (see
        # diloco.begin_outer_sync_sim; persists across run() calls)
        self._ef_begins = 0
        self.comm_ledger = CommOverlapLedger()
        # bandwidth-honest ledger window: the sim ring dispatches
        # 2*(k-1) hops, but only the live workers' 2*(n_live-1) carry
        # bytes on a real cluster — the rest are charged 0s
        self._live_hops = 0
        self._window_hop_i = 0
        self.reorders = 0            # accepted ring reorders (recompiles)
        # contribution admission: running cross-step norm statistics +
        # a log of every sanitize/quarantine decision
        self._adm_stats = (vd.AdmissionStats(cfg.validation)
                           if cfg.validation is not None else None)
        self.quarantine_events: list[dict] = []
        self.history: list[dict] = []
        self._pipelines = {}
        self.ckpt_store = None
        self.snapshotter = None
        self._ckpt_steps: list[int] = []
        self.persisted_steps: list[int] = []   # on disk, not just queued
        self._stream_join = None               # in-flight StreamingFetcher
        if cfg.ckpt_dir and cfg.ckpt_engine != "flat":
            from repro.checkpointing import (AsyncSnapshotter, ChunkStore,
                                             DeltaCheckpointer,
                                             DeltaConfig)
            self.ckpt_store = ChunkStore(
                cfg.ckpt_dir, chunk_bytes=cfg.ckpt_chunk_bytes)
            if cfg.ckpt_engine == "delta":
                writer = DeltaCheckpointer(
                    self.ckpt_store,
                    DeltaConfig(base_every=cfg.ckpt_delta_base_every,
                                codec=cfg.ckpt_codec,
                                quant_impl=cfg.diloco.quant_impl))
                write_fn = writer.save
            elif cfg.ckpt_engine == "store":
                write_fn = self.ckpt_store.save_tree
            else:
                raise ValueError(
                    f"unknown ckpt_engine {cfg.ckpt_engine!r}")
            # double-buffered: persists overlap the next inner phase,
            # bounded memory, FIFO so the delta reference chain is
            # written in step order; on_persist tracks what is actually
            # on disk — the retention gc keep-set reads it at task
            # execution time, so gc can never count an in-flight save
            self.snapshotter = AsyncSnapshotter(
                write_fn,
                on_persist=lambda step, _m:
                    self.persisted_steps.append(step))

    # -- inner phase ----------------------------------------------------------

    def _inner_step(self, params, opt_state, batch, active):
        """One vmapped inner step; inactive slots are frozen."""
        def one(p, o, b):
            (_, metrics), g = jax.value_and_grad(
                self.model.loss, has_aux=True)(p, b)
            new_p, new_o = self.optimizer.update(g, o, p)
            return new_p, new_o, metrics

        new_p, new_o, metrics = jax.vmap(one)(params, opt_state, batch)
        keep = lambda new, old: jax.tree.map(
            lambda n, o: jnp.where(
                active.reshape((-1,) + (1,) * (n.ndim - 1)), n, o),
            new, old)
        return keep(new_p, params), keep(new_o, opt_state), metrics

    def _inner_phase(self, params, opt_state, batches, active):
        """All H inner steps as ONE ``lax.scan`` over pre-stacked
        (H, k, ...) batches: a single jit dispatch per outer step, and
        only the (H, k) loss trace is retained on device instead of H
        full metric pytrees."""
        def body(carry, batch):
            p, o = carry
            p, o, metrics = self._inner_step(p, o, batch, active)
            return (p, o), metrics["loss"]

        (params, opt_state), losses = jax.lax.scan(
            body, (params, opt_state), batches)
        return params, opt_state, losses

    def _run_inner_phase(self, batches, active):
        """Run the inner phase as ``cfg.inner_chunks`` jitted scan
        chunks (near-equal lengths: at most 2 distinct shapes, so at
        most 2 compilations). The gap after each chunk is a host
        interleave point: one in-flight ring hop is dispatched there,
        hiding the outer sync's communication under compute. Chunking
        only moves the jit boundary — the per-step scan body is
        unchanged, so the loss trajectory is bit-identical to the
        monolithic scan (tested)."""
        h = jax.tree.leaves(batches)[0].shape[0]
        c = max(1, min(int(self.cfg.inner_chunks), h))
        sec_per_step = self.cfg.seconds_per_outer_step / max(1, h)
        if c == 1:
            self.params, self.opt_state, losses = self.inner_phase_jit(
                self.params, self.opt_state, batches, active)
            if self.overlap:
                self.comm_ledger.compute(h * sec_per_step)
            return losses
        bounds = np.linspace(0, h, c + 1).astype(int)
        losses = []
        for ci in range(c):
            lo, hi = int(bounds[ci]), int(bounds[ci + 1])
            if hi == lo:
                continue
            part = jax.tree.map(lambda x: x[lo:hi], batches)
            self.params, self.opt_state, l = self.inner_phase_jit(
                self.params, self.opt_state, part, active)
            losses.append(l)
            if self.overlap:
                self.comm_ledger.compute((hi - lo) * sec_per_step)
                if self._inflight is not None and self._inflight.step():
                    self._dispatch_ledger_hop()
        return jnp.concatenate(losses, axis=0)

    def _pipeline(self, slot: int) -> TokenPipeline:
        if slot not in self._pipelines:
            self._pipelines[slot] = TokenPipeline(
                self.data_cfg, slot, self.k)
        return self._pipelines[slot]

    def _batches(self, step: int):
        bs = [self._pipeline(s).batch_at(step) for s in range(self.k)]
        return jax.tree.map(lambda *xs: jnp.stack(xs), *bs)

    # -- outer loop -----------------------------------------------------------

    def run(self, n_outer_steps: int, *, inner_steps: int | None = None,
            bandwidth_sampler=None) -> list[dict]:
        h = inner_steps or self.cfg.diloco.inner_steps
        global_step = int(self.outer.outer_step) * h
        for t in range(n_outer_steps):
            plan = self.sim.begin_outer_step(t)
            # a participant of the in-flight overlapped sync left the
            # cluster: the partial reduction is torn — fall back to a
            # synchronous re-reduction over the survivors BEFORE the
            # dead node's slot is released (we need its slot to zero
            # its weight)
            fallback_rec = None
            if self._inflight is not None and plan.get("sync_torn"):
                fallback_rec = self._fallback_resync(plan)
            live_slots = self._sync_membership(plan)
            active = jnp.asarray(
                self.slots.live_mask(plan["live"]), jnp.float32)

            if self.batch_provider is not None:
                batches = self.batch_provider(global_step, h, self.k)
            else:
                batches = jax.tree.map(
                    lambda *xs: jnp.stack(xs),
                    *[self._batches(global_step + i) for i in range(h)])
            losses = self._run_inner_phase(batches, active)
            global_step += h

            # fault-harness POISON events corrupt the scheduled nodes'
            # contributions AFTER the inner phase, before the sync —
            # exactly what a faulty peer injects into the ring
            self._apply_poison(plan.get("poison", {}), t)

            # bandwidth-aware ring re-ordering (paper §2.5)
            if bandwidth_sampler is not None:
                self.bw.observe_matrix(bandwidth_sampler(t))
                changed, order = self.bw.maybe_reorder()
                if changed:
                    self.ring_order = order
                    self.reorders += 1

            # elastic weighted sync with mid-collective retry;
            # re-admitted (probation-complete) nodes re-enter like
            # joiners: zero weight for their first round
            weights = self.slots.live_mask(
                plan["live"],
                zero_weight_ids=plan["joined"] + plan["stragglers"]
                + list(plan.get("readmitted", ())))

            adm_report = None
            if self.overlap:
                overlap_rec = self._overlapped_boundary(t, weights)
                adm_report = overlap_rec.pop("_report", None)
                attempts = 1
            elif self._validation_on():
                overlap_rec = None
                adm_report = self._validated_outer_sync(t, weights)
                attempts = 1
            else:
                overlap_rec = None

                def attempt(live_set):
                    w = np.array(weights)
                    for nid, slot in self.slots.slot_of.items():
                        if nid not in live_set:
                            w[slot] = 0.0
                    return self._outer_sync(jnp.asarray(w))

                (self.params, self.outer), _, attempts = \
                    self.retry.run_collective(attempt, plan["live"])

            mean_loss = float(losses[-1][
                jnp.asarray(weights) > 0].mean()) if np.any(
                np.asarray(weights) > 0) else float("nan")
            rec = {"outer_step": t, "live": plan["live"],
                   "joined": plan["joined"], "left": plan["left"],
                   "loss": mean_loss, "ring_order": self.ring_order,
                   "attempts": attempts,
                   "wire_bytes": dl.sync_wire_bytes(
                       jax.tree.map(lambda p: p[0], self.params),
                       max(1, int(np.sum(np.asarray(weights) > 0))),
                       self.cfg.diloco)}
            if overlap_rec is not None:
                rec["overlap"] = overlap_rec
            if adm_report is not None:
                rec["admission"] = {
                    "accepted": adm_report.accepted,
                    "flagged": {s: list(r) for s, r in
                                adm_report.flagged.items()},
                    "quarantined": list(adm_report.quarantined_nodes)}
            if fallback_rec is not None:
                rec["sync_fallback"] = fallback_rec
            # streamed recovery that completed during this inner phase
            # is adopted HERE — the paper's overlapped onboarding: the
            # fetch ran under compute, admission costs one restore
            join_rec = self.poll_stream_join()
            if join_rec is not None:
                rec["stream_join"] = join_rec
            if self.boundary_hook is not None:
                hook_rec = self.boundary_hook(t, self)
                if hook_rec:
                    rec["boundary_hook"] = hook_rec
            self.history.append(rec)

            if self.cfg.ckpt_dir and \
                    (t + 1) % self.cfg.ckpt_every_outer == 0:
                tree = {"params": jax.tree.map(
                            lambda p: p[0], self.params),
                        "outer_momentum": self.outer.opt.momentum,
                        "anchor": self.outer.anchor}
                meta = {"outer_step": t + 1}
                if self.snapshotter is not None:
                    self.snapshotter.submit(global_step, tree, meta)
                    self._ckpt_steps.append(global_step)
                    if self.cfg.ckpt_keep and self.ckpt_store and \
                            len(self._ckpt_steps) > self.cfg.ckpt_keep:
                        # the keep set is computed when the task RUNS
                        # (FIFO behind every pending persist), from
                        # what is actually on disk by then — never
                        # from steps still in flight
                        keep = self.cfg.ckpt_keep
                        self.snapshotter.submit_task(
                            lambda k=keep: self.ckpt_store.gc(
                                keep_steps=tuple(
                                    self.persisted_steps[-k:])))
                else:
                    from repro.checkpointing import save_async
                    save_async(self.cfg.ckpt_dir, global_step, tree,
                               meta)
        # drain: the last boundary's sync is still in flight — apply it
        # so the returned anchor includes the final phase's progress
        if self._inflight is not None:
            self._drain_hops(self._inflight)
            self.history[-1].setdefault("overlap", {})["drain"] = \
                self.comm_ledger.finish_sync()
            self.params, self.outer = dl.finish_outer_sync_sim(
                self._inflight, self.params, self.outer)
            self._inflight = None
            self.sim.note_sync_end()
        if self.snapshotter is not None:
            self.snapshotter.flush()
        return self.history

    def begin_stream_join(self, peers, *, store_root=None,
                          step: int | None = None,
                          range_chunks: int = 8, timeout: float = 20.0):
        """Start an overlapped streaming recovery from ``peers`` on a
        background thread (paper §2.4.2: recovery overlaps the inner
        phase). The fetch gossips chunk availability, streams the
        manifest chain into this node's store and assembles the delta
        chain incrementally; ``run()`` adopts the result at the first
        outer boundary where it is ready. Returns the fetcher (callers
        outside ``run()`` can ``wait_ready()`` it themselves)."""
        assert self._stream_join is None or self._stream_join.done, \
            "a streaming join is already in flight"
        from repro.checkpointing import ChunkStore, StreamingFetcher
        # an explicit store_root wins (the single-process simulation
        # plays both cluster and joiner: the joiner must stream into
        # its OWN store, not dedup against the serving one); a real
        # joiner defaults to its configured chunk store
        if store_root is not None:
            store = ChunkStore(store_root)
        else:
            store = self.ckpt_store
            assert store is not None, \
                "streaming join needs a chunk store: configure " \
                "ckpt_engine store|delta or pass store_root"
        self._stream_join = StreamingFetcher(
            peers, store, self.checkpoint_like(), step=step,
            range_chunks=range_chunks, timeout=timeout).start()
        return self._stream_join

    def poll_stream_join(self) -> dict | None:
        """Non-blocking: adopt a finished streaming recovery (called at
        every outer boundary by ``run()``). Returns the admission
        record, a failure record, or None while still streaming."""
        f = self._stream_join
        if f is None or not f.done:
            return None
        self._stream_join = None
        if f.failed:
            f.close()
            return {"admitted": False, "error": str(f.error),
                    "stats": f.stats()}
        tree, meta, stats = f.result()
        self.adopt_checkpoint(tree, meta)
        f.close()
        return {"admitted": True, "step": stats["step"],
                "outer_step": meta.get("outer_step"), "stats": stats}

    def checkpoint_like(self):
        """Template pytree matching what run() checkpoints (for
        ``ChunkStore.restore_tree`` / ``delta.restore`` /
        ``swarm.recover``)."""
        return {"params": jax.tree.map(lambda p: p[0], self.params),
                "outer_momentum": self.outer.opt.momentum,
                "anchor": self.outer.anchor}

    def serve_checkpoints(self, port: int = 0):
        """Expose this node's chunk store to joining peers (the
        paper's live-recovery serving side)."""
        from repro.checkpointing import ChunkPeer
        assert self.ckpt_store is not None, \
            "serve_checkpoints requires ckpt_engine 'store' or 'delta'"
        return ChunkPeer(self.ckpt_store, port=port)

    def adopt_checkpoint(self, tree, meta: dict) -> None:
        """Enter at the next outer boundary from a recovered
        checkpoint: every slot resets to the recovered anchor and the
        outer state resumes its momentum (paper §2.4.2 onboarding)."""
        anchor = jax.tree.map(
            lambda a: jnp.asarray(a, jnp.float32), tree["anchor"])
        from repro.core.sync_engine import SyncEngine
        eng = SyncEngine.for_tree(anchor)
        self.outer = self.outer._replace(
            anchor=anchor,
            opt=self.outer.opt._replace(
                momentum=jax.tree.map(
                    lambda m: jnp.asarray(m, jnp.float32),
                    tree["outer_momentum"])),
            outer_step=jnp.asarray(meta.get("outer_step", 0),
                                   jnp.int32),
            anchor_flat=eng.flatten(anchor))
        self.params = jax.tree.map(
            lambda stacked, p: jnp.broadcast_to(
                jnp.asarray(p, stacked.dtype)[None],
                stacked.shape),
            self.params, tree["params"])
        self.opt_state = jax.vmap(self.optimizer.init)(self.params)

    def _quarantined_slots(self) -> list[int]:
        return sorted(
            self.slots.slot_of[nid]
            for nid in self.sim.quarantined_ids()
            if nid in self.slots.slot_of)

    def _ring_for_sync(self) -> tuple[int, ...]:
        """Quarantine-aware ring order: quarantined slots move to the
        tail (zero-weighted rows don't sit between healthy peers).
        When they already are at the tail the order — and therefore
        the distributed hop programs — is unchanged."""
        order = tuple(self.ring_order[: self.k])
        q = self._quarantined_slots()
        return topology.exclude_slots(order, q) if q else order

    def _begin_sync(self, weights, ef_slot: int) -> dl.OuterSyncHandle:
        """Stage the outer sync: through the distributed backend when
        one is plugged in, the simulator ring otherwise (same handle
        surface either way)."""
        if self.sync_backend is not None:
            return self.sync_backend.begin(
                self.params, self.outer, self.cfg.diloco,
                ring_order=self._ring_for_sync(), weights=weights,
                ef_slot=ef_slot)
        return dl.begin_outer_sync_sim(
            self.params, self.outer, self.cfg.diloco,
            ring_order=self._ring_for_sync(), weights=weights,
            ef_slot=ef_slot)

    def _outer_sync(self, weights):
        if self.sync_backend is not None:
            # non-overlapped path through the distributed collectives:
            # begin + immediate finish (EF residual is slot-free here)
            h = self._begin_sync(jnp.asarray(weights), ef_slot=0)
            return dl.finish_outer_sync_sim(h, self.params, self.outer)
        return dl.outer_sync_sim(self.params, self.outer,
                                 self.cfg.diloco,
                                 ring_order=self._ring_for_sync(),
                                 weights=weights)

    # -- contribution admission (untrusted-contributor defense) ---------------

    def _validation_on(self) -> bool:
        v = self.cfg.validation
        return v is not None and v.enabled

    def _apply_poison(self, poison: dict, t: int) -> None:
        """Corrupt the scheduled LIVE nodes' post-phase params in
        pseudo-gradient space (``p' = a - poison(a - p)``) so the
        contribution the next sync stages is exactly what a faulty
        peer would inject. Seeded per (node, step) — deterministic."""
        if not poison:
            return
        from repro.core.sync_engine import SyncEngine
        any_params = jax.tree.map(lambda p: p[0], self.params)
        eng = SyncEngine.for_tree(any_params)
        a_flat = (self.outer.anchor_flat
                  if self.outer.anchor_flat is not None
                  else eng.flatten(self.outer.anchor))
        a_np = np.asarray(a_flat, np.float32)
        live = set(self.sim.hb.live_ids())
        for nid in sorted(poison):
            if nid not in live:
                # quarantined/dead nodes have no contribution to spoil
                continue
            slot = self.slots.slot_of.get(nid)
            if slot is None:
                continue
            p_flat = np.asarray(eng.flatten(
                jax.tree.map(lambda p: p[slot], self.params)),
                np.float32)
            rng = np.random.default_rng([nid, t])
            bad = vd.poison_pseudograd(a_np - p_flat, poison[nid], rng)
            new_p = eng.unflatten(jnp.asarray(a_np - bad),
                                  like=any_params)
            self.params = jax.tree.map(
                lambda stacked, leaf: stacked.at[slot].set(
                    leaf.astype(stacked.dtype)),
                self.params, new_p)

    def _admission_check(self, handle: dl.OuterSyncHandle,
                         t: int) -> vd.AdmissionReport:
        """Judge the staged pseudo-gradients BEFORE any reduced value
        is applied; quarantine flagged contributors and feed the
        accepted rows back into the cross-step statistics. Pure
        host-side float64 on the retained rows + the chunk-norm
        sideband, so the simulator and the distributed backend reach
        bit-identical decisions."""
        report = vd.validate_pseudograds(
            np.asarray(handle.op.xs, np.float64),
            np.asarray(handle.weights, np.float64),
            handle.norm_sideband(), self._adm_stats,
            self.cfg.validation)
        slot_node = {slot: nid
                     for nid, slot in self.slots.slot_of.items()}
        for slot in sorted(report.flagged):
            nid = slot_node.get(slot)
            if nid is not None and self.sim.record_violation(
                    nid, t, report.flagged[slot]):
                report.quarantined_nodes.append(nid)
        self.sim.record_clean(
            [slot_node[s] for s in report.accepted if s in slot_node])
        self._adm_stats.update(report)
        if report.sanitize:
            self.quarantine_events.append({
                "outer_step": t,
                "flagged": {s: list(r)
                            for s, r in report.flagged.items()},
                "bad_chunks": {s: list(c)
                               for s, c in report.bad_chunks.items()},
                "quarantined": list(report.quarantined_nodes)})
        return report

    def _validated_outer_sync(self, t: int, weights) -> vd.AdmissionReport:
        """Non-overlapped outer sync behind the admission gates:
        begin -> judge -> (sanitize + restart over the clean survivors)
        or finish. The staged accumulators already absorbed the raw
        rows (and NaN * 0 == NaN), so a rejected population is never
        finished — the sanitized rows are RE-REDUCED from scratch via
        the torn-reduction restart path."""
        w = jnp.asarray(np.asarray(weights), jnp.float32)
        h = self._begin_sync(w, ef_slot=0)
        report = self._admission_check(h, t)
        if report.sanitize:
            h.sanitize(report.sanitize)
            w2 = np.asarray(w, np.float32).copy()
            for slot in report.sanitize:
                if slot < len(w2):
                    w2[slot] = 0.0
            self.params, self.outer = dl.resync_outer_sim(
                h, self.params, self.outer, jnp.asarray(w2))
        else:
            self.params, self.outer = dl.finish_outer_sync_sim(
                h, self.params, self.outer)
        return report

    # -- teardown -------------------------------------------------------------

    def close(self, discard: bool = False) -> dict | None:
        """Tear down any in-flight overlapped sync so an interrupted
        run can't leave hop buffers or a torn accumulator behind.
        ``discard=False`` drains and applies it (clean finish);
        ``discard=True`` aborts it — the partial reduction is dropped
        and the handle poisoned (``SyncAbortedError`` on any further
        use). Pending async snapshots are flushed either way."""
        h, self._inflight = self._inflight, None
        rec = None
        if h is not None and not h.aborted:
            if discard:
                h.abort()
                rec = {"discarded": True,
                       "ledger": self.comm_ledger.tear_sync(
                           resync_hops=0)}
            else:
                self._drain_hops(h)
                rec = {"discarded": False,
                       "ledger": self.comm_ledger.finish_sync()}
                self.params, self.outer = dl.finish_outer_sync_sim(
                    h, self.params, self.outer)
            self.sim.note_sync_end()
        if self.snapshotter is not None:
            self.snapshotter.flush()
        return rec

    def __enter__(self) -> "ElasticTrainer":
        return self

    def __exit__(self, exc_type, exc, tb) -> bool:
        # an exception mid-overlap leaves the reduction torn: discard
        # it (a partial accumulator must never be applied); on a clean
        # exit the in-flight sync drains and applies
        self.close(discard=exc_type is not None)
        return False

    # -- overlapped outer sync (diloco.overlap == 'delayed') ------------------

    def _link_rate(self) -> float:
        """Bytes/s of the slowest link on the CURRENT ring, from the
        BandwidthMonitor's EWMA matrix — the hop that paces a ring
        all-reduce. Falls back to the uniform modeled link until every
        ring edge has an observation."""
        bn = self.bw.ring_bottleneck(self.ring_order[: self.k])
        if bn is None or bn <= 0:
            return self.cfg.sync_link_bytes_per_s
        return bn * self.cfg.link_bytes_per_gbps

    def _hop_seconds(self, weights) -> float:
        """Modeled wire time of ONE live ring hop: the actual per-hop
        bytes of the n_live-worker ring (chunk payload + codebook
        sideband, ``ring_reduce.ring_hop_bytes``) over the ring's
        bottleneck-link rate."""
        n_live = max(1, int(np.sum(np.asarray(weights) > 0)))
        numel = sum(int(np.prod(l.shape[1:], dtype=np.int64))
                    for l in jax.tree.leaves(self.params))
        ring = self.cfg.diloco.ring
        per_hop = rr.ring_hop_bytes(numel, n_live, quant=ring.quant,
                                    buckets=ring.buckets)
        return per_hop / self._link_rate()

    def _dispatch_ledger_hop(self) -> None:
        """Charge one dispatched hop to the ledger. The sim ring always
        walks 2*(k-1) hops, but only 2*(n_live-1) of them carry bytes
        on the real cluster — the dead-slot remainder is charged 0s so
        the ledger reflects what the wire actually moves."""
        if self._window_hop_i < self._live_hops:
            self.comm_ledger.dispatch_hop()
        else:
            self.comm_ledger.dispatch_hop(seconds=0.0)
        self._window_hop_i += 1

    def _participants(self, weights) -> frozenset:
        w = np.asarray(weights)
        return frozenset(nid for nid, slot in self.slots.slot_of.items()
                         if slot < len(w) and w[slot] > 0)

    def _overlapped_boundary(self, t: int, weights) -> dict:
        """Boundary protocol for the delayed overlap (paper §2.2):

          1. compute + quantize THIS phase's pseudo-gradients against
             the current anchor (the one every worker started from) and
             stage the ring — ``begin`` before ``finish`` so the new
             pseudo-gradient never sees the about-to-land update;
          2. drain + apply the PREVIOUS boundary's reduction (one-phase
             delay) — every worker resets to the updated anchor;
          3. dispatch the new sync's first hop so its transfer hides
             under the next inner phase from the very start.
        """
        w = jnp.asarray(np.asarray(weights), jnp.float32)
        h_new = self._begin_sync(w, ef_slot=self._ef_begins % 2)
        self._ef_begins += 1
        # admission gates run on the STAGED rows before any hop is
        # dispatched — a flagged contribution never rides the wire
        report = (self._admission_check(h_new, t)
                  if self._validation_on() else None)
        rec: dict = {"hops": h_new.hops_total, "_report": report}
        prev = self._inflight
        if prev is not None:
            self._drain_hops(prev)
            rec["prev"] = self.comm_ledger.finish_sync()
            self.params, self.outer = dl.finish_outer_sync_sim(
                prev, self.params, self.outer)
        else:
            # first boundary: nothing in flight to apply — reset every
            # worker to the (unchanged) anchor; this phase's progress
            # arrives via the delayed application at the next boundary
            self._reset_to_anchor()
        if report is not None and report.sanitize:
            # rejected population: sanitize the retained rows and apply
            # this boundary's sync RIGHT NOW as a synchronous re-reduce
            # over the clean survivors (to its own anchor snapshot —
            # the same lineage the delayed apply would have used). The
            # whole re-reduction is exposed comm, charged like a torn
            # sync; nothing stays in flight.
            h_new.sanitize(report.sanitize)
            w2 = np.asarray(h_new.weights, np.float32).copy()
            for slot in report.sanitize:
                if slot < len(w2):
                    w2[slot] = 0.0
            self.params, self.outer = dl.resync_outer_sim(
                h_new, self.params, self.outer, jnp.asarray(w2))
            self.comm_ledger.begin_sync(self._hop_seconds(weights))
            rec["rejected"] = self.comm_ledger.tear_sync(
                resync_hops=h_new.hops_total)
            self._inflight = None
            return rec
        self.sim.note_sync_begin(t, self._participants(weights))
        self._inflight = h_new
        self.comm_ledger.begin_sync(self._hop_seconds(weights))
        n_live = max(1, int(np.sum(np.asarray(weights) > 0)))
        self._live_hops = 2 * (n_live - 1)
        self._window_hop_i = 0
        if h_new.step():
            self._dispatch_ledger_hop()
        return rec

    def _fallback_resync(self, plan) -> dict:
        """A participant of the in-flight sync left: discard the torn
        partial reduction and synchronously re-reduce the retained
        pseudo-gradients with the dead workers' weights zeroed
        (bit-consistent: every survivor re-derives the same result
        from the same retained inputs)."""
        h = self._inflight
        self._inflight = None
        self.sim.note_sync_end()
        w = np.asarray(h.weights, np.float32).copy()
        for nid in plan["sync_torn"]:
            slot = self.slots.slot_of.get(nid)
            if slot is not None and slot < len(w):
                w[slot] = 0.0
        self.params, self.outer = dl.resync_outer_sim(
            h, self.params, self.outer, jnp.asarray(w))
        led = self.comm_ledger.tear_sync(resync_hops=h.hops_total)
        return {"torn_by": list(plan["sync_torn"]),
                "resync_hops": h.hops_total, "ledger": led}

    def _drain_hops(self, handle: dl.OuterSyncHandle) -> None:
        """Dispatch every remaining hop of ``handle`` (exposed comm:
        the boundary is waiting on the wire)."""
        while handle.step():
            self._dispatch_ledger_hop()

    def _reset_to_anchor(self) -> None:
        for_slot = self.outer.anchor
        self.params = jax.tree.map(
            lambda stacked, a: jnp.broadcast_to(
                a.astype(stacked.dtype)[None], stacked.shape),
            self.params, for_slot)

    def _sync_membership(self, plan) -> list[int]:
        for nid in plan["left"]:
            self.slots.release(nid)
        slots = []
        for nid in plan["live"]:
            slot = self.slots.assign(nid)
            slots.append(slot)
            if nid in plan["joined"]:
                # joiner adopts the anchor (P2P checkpoint in the
                # distributed path) and fresh optimizer state
                anchor = self.outer.anchor
                self.params = jax.tree.map(
                    lambda stacked, a: stacked.at[slot].set(
                        a.astype(stacked.dtype)),
                    self.params, anchor)
                fresh = self.optimizer.init(
                    jax.tree.map(lambda p: p[slot], self.params))
                self.opt_state = jax.tree.map(
                    lambda stacked, f: stacked.at[slot].set(f),
                    self.opt_state, fresh)
        return slots
