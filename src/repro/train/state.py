"""Training state pytrees."""
from __future__ import annotations

from typing import Any, NamedTuple

from repro.core.diloco import OuterState
from repro.optim.adamw import AdamWState


class TrainState(NamedTuple):
    params: Any
    opt: AdamWState


class DiLoCoTrainState(NamedTuple):
    """Stacked (leading DiLoCo-worker dim) inner state + shared outer."""
    inner: TrainState
    outer: OuterState
