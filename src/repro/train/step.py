"""Step builders: jit/shard_map-wired train, sync and serve steps.

The inner train step runs as a plain pjit program over (data, model)
*inside* a shard_map region manual over the DiLoCo axis (paper §2.3:
FSDP inside, DiLoCo outside). The outer sync step runs the int8 ring
all-reduce over the same manual axis. When the plan has no DiLoCo axis
(huge models on one pod; serving) everything is plain pjit.

Each builder returns (fn, sharding spec pytrees) so the dry-run can
lower against ShapeDtypeStructs and the trainer can device_put real
state identically.
"""
from __future__ import annotations

from typing import Any

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import NamedSharding, PartitionSpec as P

from repro import compat
from repro.core import diloco as dl
from repro.core.sync_engine import SyncEngine, shard_flat_size
from repro.models import common
from repro.optim.adamw import AdamW, AdamWState
from repro.optim.nesterov import NesterovState
from repro.sharding import partition
from repro.train.state import TrainState


def mesh_axes(mesh) -> dict[str, int]:
    return dict(zip(mesh.axis_names, mesh.devices.shape))


def _constrain(mesh, tree, spec_tree):
    return jax.tree.map(
        lambda x, s: jax.lax.with_sharding_constraint(
            x, NamedSharding(mesh, s)),
        tree, spec_tree, is_leaf=lambda x: isinstance(x, P))


def param_specs(model, plan, mesh) -> Any:
    shapes, axes = common.eval_axes(model.init, jax.random.PRNGKey(0))
    return partition.param_pspecs(axes, shapes, plan, mesh_axes(mesh))


def _shard_flat_dims(shapes, pspecs, amap: dict,
                     diloco_axis: str | None) -> tuple[int, int]:
    """(padded_local_len, tile) of the per-shard flat anchor buffer a
    sharded plan threads through its sync region: the concat of each
    device's local anchor shards PLUS ONE SENTINEL element, tiled over
    the non-DiLoCo mesh axes. The sentinel makes the threaded layout's
    length provably distinct from a global flatten (numel) even when
    every leaf shards evenly — so ``sync()`` can always tell a
    global-layout buffer (e.g. ``init_outer_state``'s) from its own
    and rebuild instead of silently mis-reading it. Single source of
    truth for both ``build_outer_sync`` and ``flat_anchor_len``;
    callers pass their already-evaluated (shapes, pspecs)."""
    local = shard_flat_size(shapes, pspecs, amap) + 1
    tile = 1
    for a, n in amap.items():
        if a != diloco_axis:
            tile *= n
    return local, tile


def flat_anchor_len(model, plan, mesh) -> int:
    """GLOBAL length of the persistent flat anchor buffer the outer
    sync threads through its region (dry-run / device_put lockstep).

    Replicated-param plans thread the full flat anchor (numel).
    Sharded plans thread the PER-SHARD flat view: each device holds the
    concat of its local anchor shards plus a sentinel element (see
    ``_shard_flat_dims``), and the buffer's global shape is that local
    length tiled over the non-DiLoCo mesh axes (an opaque device-major
    concat, only ever interpreted inside the manual region)."""
    shapes, axes = common.eval_axes(model.init, jax.random.PRNGKey(0))
    amap = mesh_axes(mesh)
    pspecs = partition.param_pspecs(axes, shapes, plan, amap)
    sharded = any(s != P() for s in jax.tree.leaves(
        pspecs, is_leaf=lambda x: isinstance(x, P)))
    if not sharded:
        return sum(int(np.prod(s.shape, dtype=np.int64))
                   if s.shape else 1
                   for s in jax.tree.leaves(shapes))
    local, tile = _shard_flat_dims(shapes, pspecs, amap,
                                   plan.diloco_axis)
    return local * tile


def batch_pspecs(model, shape, plan, mesh, *, stacked: bool) -> Any:
    """Leading-batch-dim specs for every input leaf (+ worker dim)."""
    specs = model.input_specs(shape)
    per_worker = shape.global_batch // plan.n_workers
    bp = partition.batch_pspec(plan, per_worker, mesh_axes(mesh))
    if stacked and plan.diloco_axis:
        bp = P(plan.diloco_axis, *bp)
    return {k: bp for k in specs}


# -- train --------------------------------------------------------------------


def build_train_step(model, plan, mesh, optimizer: AdamW):
    """Returns (train_step, state_specs).

    state/batch carry a leading DiLoCo-worker dim iff plan.diloco_axis.
    train_step(state: TrainState, batch) -> (state, metrics)."""
    pspecs = param_specs(model, plan, mesh)

    bspec = partition.batch_pspec(plan)
    # (B, S, D) residual-stream spec: batch over the batch axes, seq
    # over the SP axis when the plan enables it
    batch_entry = bspec[0] if len(bspec) else None
    act_spec = P(batch_entry, plan.act_seq_axis) \
        if plan.act_seq_axis else None

    def _soft_constrain(tree):
        """Bare-spec grad constraints: work inside vmap (spmd_axis_name
        prepends the worker axis) and no-op without a mesh context."""
        def one(x, s):
            try:
                return jax.lax.with_sharding_constraint(x, s)
            except Exception:
                return x

        return jax.tree.map(one, tree, pspecs,
                            is_leaf=lambda x: isinstance(x, P))

    def grads_of(params, batch, hints: bool = True):
        """Microbatched (gradient-accumulation) value_and_grad with the
        activation hints active. ``hints=False`` traces the body with
        NO in-body sharding constraints at all — required inside plain
        vmap on data-sharded-params plans, where any constraint in the
        vmapped body lowers through XLA's manual-subgroup machinery and
        CHECK-crashes the compiler."""
        from repro.sharding.act_hints import activation_hints

        constrain = _soft_constrain if hints else (lambda t: t)
        with activation_hints(act_spec if hints else None):
            if plan.microbatches == 1:
                (_, metrics), grads = jax.value_and_grad(
                    model.loss, has_aux=True)(params, batch,
                                              remat=plan.remat)
                return constrain(grads), metrics
            nmb = plan.microbatches
            mb = jax.tree.map(
                lambda x: x.reshape((nmb, x.shape[0] // nmb)
                                    + x.shape[1:]), batch)

            def body(acc, b_i):
                (_, m), g = jax.value_and_grad(
                    model.loss, has_aux=True)(params, b_i,
                                              remat=plan.remat)
                g = constrain(g)
                acc = jax.tree.map(
                    lambda a, gg: a + gg.astype(jnp.float32), acc, g)
                return constrain(acc), m

            zeros = constrain(jax.tree.map(
                lambda p: jnp.zeros(p.shape, jnp.float32), params))
            grads, ms = jax.lax.scan(body, zeros, mb)
            grads = jax.tree.map(lambda g: g / nmb, grads)
            metrics = jax.tree.map(lambda m: m[-1], ms)
            return grads, metrics

    def inner(params, opt_state, batch):
        # anchor the activation batch sharding (FSDP-style: batch over
        # the data axes and, when divisible, 'model' too) + optional
        # sequence parallelism hint on the residual stream
        batch = jax.tree.map(
            lambda x: jax.lax.with_sharding_constraint(
                x, NamedSharding(mesh, bspec)), batch)
        grads, metrics = grads_of(params, batch)
        grads = _constrain(mesh, grads, pspecs)
        params, opt_state = optimizer.update(grads, opt_state, params)
        params = _constrain(mesh, params, pspecs)
        return params, opt_state, metrics

    dax = plan.diloco_axis
    if dax is None:
        def step(state: TrainState, batch):
            params, opt, metrics = inner(state.params, state.opt, batch)
            return TrainState(params, opt), metrics

        state_specs = TrainState(pspecs,
                                 AdamWState(P(), pspecs, pspecs))
        return step, state_specs

    lead = lambda t: partition.with_leading(t, dax)
    state_specs = TrainState(
        lead(pspecs), AdamWState(P(dax), lead(pspecs), lead(pspecs)))

    # XLA's SPMD partitioner CHECK-fails (`Check failed:
    # sharding.IsManualSubgroup()`) whenever a constraint meets a
    # manual subgroup: a shard_map region manual over the DiLoCo axis
    # whose body constrains leaves over the remaining mesh axes needs
    # manual-subgroup shardings this XLA cannot partition, and
    # `vmap(spmd_axis_name=dax)` lowers through the same machinery.
    # Partitioner-safe formulation with NO manual axes at all:
    # plain-vmap the per-worker step (traced hint-free — any in-body
    # constraint reintroduces the crash) over the stacked leading dim
    # and constrain the STACKED trees at the vmap boundary; sharding is
    # driven entirely by the boundary constraints and pjit propagation.
    def step(state: TrainState, batch):
        batch = jax.tree.map(
            lambda x: jax.lax.with_sharding_constraint(
                x, NamedSharding(mesh, P(dax, *bspec))), batch)

        grads, metrics = jax.vmap(
            lambda p, b: grads_of(p, b, hints=False))(
                state.params, batch)
        grads = _constrain(mesh, grads, lead(pspecs))
        params, opt = jax.vmap(optimizer.update)(
            grads, state.opt, state.params)
        params = _constrain(mesh, params, lead(pspecs))
        return TrainState(params, opt), metrics

    return step, state_specs


def build_outer_sync(model, plan, mesh, diloco_cfg: dl.DiLoCoConfig,
                     ring_order=None):
    """Returns (sync_step, outer_specs).

    sync_step(params_stacked, outer_state, weights)
        -> (params_stacked, outer_state).
    The outer state (fp32 anchor + Nesterov momentum) is SHARED
    (replicated over the DiLoCo axis, data/model-sharded like params —
    the paper's host-offloaded master copy; on TPU targets pass
    ``host_offload_outer=True`` to place it in pinned_host memory)."""
    pspecs = param_specs(model, plan, mesh)
    dax = plan.diloco_axis

    if dax is None:
        # degenerate DiLoCo (one worker): PER-LEAF pseudo-gradient +
        # outer update — flattening to one vector would concat sharded
        # leaves and force a full all-gather (observed: 1.8 TB/device
        # for dbrx)
        def sync_single(params, outer_state, weights):
            del weights
            delta = jax.tree.map(
                lambda a, p: a - p.astype(jnp.float32),
                outer_state.anchor, params)
            new_anchor, new_opt = diloco_cfg.outer_opt.update(
                delta, outer_state.opt, outer_state.anchor)
            new_params = jax.tree.map(
                lambda a, p: a.astype(p.dtype), new_anchor, params)
            return new_params, dl.OuterState(
                new_anchor, new_opt, outer_state.residual,
                outer_state.outer_step + 1)

        outer_specs = dl.OuterState(pspecs, NesterovState(pspecs),
                                    P(), P())
        return sync_single, outer_specs

    # Hybrid FSDP + DiLoCo (paper §2.3): "only ranks responsible for the
    # same shard communicate". The sync runs FULLY manual — every device
    # rings ITS OWN model-shard of the pseudo-gradient across the DiLoCo
    # axis; the 16 model columns run 16 parallel rings (the paper's
    # per-shard process groups / parallel TCP stores).
    sharded_params = any(
        s != P() for s in jax.tree.leaves(
            pspecs, is_leaf=lambda x: isinstance(x, P)))
    if diloco_cfg.error_feedback and sharded_params:
        raise NotImplementedError(
            "error feedback requires per-shard residual bookkeeping; "
            "supported with replicated-inner-params plans only")

    lead = lambda t: partition.with_leading(t, dax)

    if not sharded_params:
        # replicated-inner-params plans: thread the persistent flat
        # fp32 anchor THROUGH the shard_map region, so the
        # pseudo-gradient is one subtract off the buffer instead of a
        # per-sync anchor re-flatten, and the updated buffer flows back
        # out for the next outer step (sharded plans would need a
        # per-shard flat view first — the anchor leaves inside the
        # region are shards there).
        def per_worker(params, anchor, momentum, residual, outer_step,
                       a_flat, weights):
            p_i = jax.tree.map(lambda x: x[0], params)
            st = dl.OuterState(anchor, NesterovState(momentum),
                               residual[0], outer_step,
                               anchor_flat=a_flat)
            new_p, new_st = dl.outer_sync(
                p_i, st, diloco_cfg, dax, ring_order=ring_order,
                weight=weights[0])
            return (jax.tree.map(lambda x: x[None], new_p),
                    new_st.anchor, new_st.opt.momentum,
                    new_st.residual[None], new_st.outer_step,
                    new_st.anchor_flat)

        def sync(params_stacked, outer_state: dl.OuterState, weights):
            a_flat = outer_state.anchor_flat
            if a_flat is None:
                eng = SyncEngine.for_tree(outer_state.anchor)
                a_flat = eng.flatten(outer_state.anchor)
            new_p, anchor, momentum, residual, ostep, new_a_flat = \
                compat.shard_map(
                    per_worker, mesh=mesh,
                    in_specs=(lead(pspecs), pspecs, pspecs, P(dax),
                              P(), P(), P(dax)),
                    out_specs=(lead(pspecs), pspecs, pspecs, P(dax),
                               P(), P()),
                    check_vma=False)(
                        params_stacked, outer_state.anchor,
                        outer_state.opt.momentum, outer_state.residual,
                        outer_state.outer_step, a_flat, weights)
            return new_p, dl.OuterState(anchor, NesterovState(momentum),
                                        residual, ostep, new_a_flat)

        outer_specs = dl.OuterState(pspecs, NesterovState(pspecs),
                                    P(dax), P(), P())
        return sync, outer_specs

    # Sharded plans thread the PER-SHARD flat anchor view (the zero-
    # flatten fused path replicated plans got in PR 2): inside the
    # manual region every device's anchor leaves are LOCAL shards, so
    # the persistent buffer is the concat of those shards plus one
    # SENTINEL element (see _shard_flat_dims — it keeps the threaded
    # layout's length distinct from a global flatten, so a buffer from
    # init_outer_state can never be mis-read as per-shard). It rides
    # in/out of the region as an opaque device-major array whose first
    # dim is "sharded" over the non-DiLoCo mesh axes (and replicated
    # over the DiLoCo axis, like the anchor itself); sync() rebuilds
    # the view whenever the incoming buffer's length differs.
    nondax = tuple(a for a in mesh.axis_names if a != dax)
    flat_spec = P(nondax) if nondax else P()
    shapes, _ = common.eval_axes(model.init, jax.random.PRNGKey(0))
    padded_local, tile = _shard_flat_dims(shapes, pspecs,
                                          mesh_axes(mesh), dax)
    flat_global = padded_local * tile

    def _local_flatten(anchor):
        flat = SyncEngine.for_tree(anchor).flatten(anchor)
        return jnp.pad(flat, (0, 1))          # sentinel element

    flatten_local = compat.shard_map(
        _local_flatten, mesh=mesh, in_specs=(pspecs,),
        out_specs=flat_spec, check_vma=False)

    def per_worker(params, anchor, momentum, residual, outer_step,
                   a_flat, weights):
        p_i = jax.tree.map(lambda x: x[0], params)
        st = dl.OuterState(anchor, NesterovState(momentum),
                           residual[0], outer_step,
                           anchor_flat=a_flat[:-1])  # drop sentinel
        new_p, new_st = dl.outer_sync(
            p_i, st, diloco_cfg, dax, ring_order=ring_order,
            weight=weights[0])
        return (jax.tree.map(lambda x: x[None], new_p), new_st.anchor,
                new_st.opt.momentum, new_st.residual[None],
                new_st.outer_step, jnp.pad(new_st.anchor_flat, (0, 1)))

    def sync(params_stacked, outer_state: dl.OuterState, weights):
        a_flat = outer_state.anchor_flat
        if a_flat is None or tuple(a_flat.shape) != (flat_global,):
            # first sync (or a global-layout buffer from
            # init_outer_state): build the per-shard view once; the
            # updated buffer threads through every later sync
            a_flat = flatten_local(outer_state.anchor)
        new_p, anchor, momentum, residual, ostep, new_a_flat = \
            compat.shard_map(
                per_worker, mesh=mesh,
                in_specs=(lead(pspecs), pspecs, pspecs, P(dax), P(),
                          flat_spec, P(dax)),
                out_specs=(lead(pspecs), pspecs, pspecs, P(dax), P(),
                           flat_spec),
                check_vma=False)(
                    params_stacked, outer_state.anchor,
                    outer_state.opt.momentum, outer_state.residual,
                    outer_state.outer_step, a_flat, weights)
        return new_p, dl.OuterState(anchor, NesterovState(momentum),
                                    residual, ostep, new_a_flat)

    outer_specs = dl.OuterState(pspecs, NesterovState(pspecs),
                                P(dax), P(), flat_spec)
    return sync, outer_specs


# -- serve --------------------------------------------------------------------


def build_serve_step(model, plan, mesh, kind: str):
    """kind in {'prefill', 'decode'}. Returns (fn, param_specs)."""
    pspecs = param_specs(model, plan, mesh)
    axes = mesh_axes(mesh)

    # prefill SP: when KV heads don't divide the model axis (MHA
    # archs), shard the 32k sequence over 'model' for the prefill
    # activations — the attention q-block tiles divide accordingly
    hint = None
    if (kind == "prefill"
            and model.cfg.n_kv_heads % axes.get("model", 1) != 0
            and model.cfg.family not in ("ssm", "hybrid")):
        b_entry = plan.batch_axes[0] if plan.batch_axes else None
        hint = P(b_entry, "model")

    if kind == "prefill":
        def fn(params, batch, cache):
            from repro.sharding.act_hints import activation_hints
            with activation_hints(hint):
                return model.prefill(params, batch, cache)
    else:
        def fn(params, token, cache):
            return model.decode(params, token, cache)

    return fn, pspecs
