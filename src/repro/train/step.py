"""Step builders: jit/shard_map-wired train, sync and serve steps.

The inner train step runs as a plain pjit program over (data, model)
*inside* a shard_map region manual over the DiLoCo axis (paper §2.3:
FSDP inside, DiLoCo outside). The outer sync step runs the int8 ring
all-reduce over the same manual axis. When the plan has no DiLoCo axis
(huge models on one pod; serving) everything is plain pjit.

Each builder returns (fn, sharding spec pytrees) so the dry-run can
lower against ShapeDtypeStructs and the trainer can device_put real
state identically.
"""
from __future__ import annotations

from typing import Any

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import NamedSharding, PartitionSpec as P

from repro import compat
from repro.core import diloco as dl
from repro.core.sync_engine import SyncEngine, shard_flat_size
from repro.models import common
from repro.optim.adamw import AdamW, AdamWState
from repro.optim.nesterov import NesterovState
from repro.sharding import partition
from repro.train.state import TrainState


def mesh_axes(mesh) -> dict[str, int]:
    return dict(zip(mesh.axis_names, mesh.devices.shape))


def _constrain(mesh, tree, spec_tree):
    return jax.tree.map(
        lambda x, s: jax.lax.with_sharding_constraint(
            x, NamedSharding(mesh, s)),
        tree, spec_tree, is_leaf=lambda x: isinstance(x, P))


def param_specs(model, plan, mesh) -> Any:
    shapes, axes = common.eval_axes(model.init, jax.random.PRNGKey(0))
    return partition.param_pspecs(axes, shapes, plan, mesh_axes(mesh))


def _shard_flat_dims(shapes, pspecs, amap: dict,
                     diloco_axis: str | None) -> tuple[int, int]:
    """(padded_local_len, tile) of the per-shard flat anchor buffer a
    sharded plan threads through its sync region: the concat of each
    device's local anchor shards PLUS ONE SENTINEL element, tiled over
    the non-DiLoCo mesh axes. The sentinel makes the threaded layout's
    length provably distinct from a global flatten (numel) even when
    every leaf shards evenly — so ``sync()`` can always tell a
    global-layout buffer (e.g. ``init_outer_state``'s) from its own
    and rebuild instead of silently mis-reading it. Single source of
    truth for both ``build_outer_sync`` and ``flat_anchor_len``;
    callers pass their already-evaluated (shapes, pspecs)."""
    local = shard_flat_size(shapes, pspecs, amap) + 1
    tile = 1
    for a, n in amap.items():
        if a != diloco_axis:
            tile *= n
    return local, tile


def flat_anchor_len(model, plan, mesh) -> int:
    """GLOBAL length of the persistent flat anchor buffer the outer
    sync threads through its region (dry-run / device_put lockstep).

    Replicated-param plans thread the full flat anchor (numel).
    Sharded plans thread the PER-SHARD flat view: each device holds the
    concat of its local anchor shards plus a sentinel element (see
    ``_shard_flat_dims``), and the buffer's global shape is that local
    length tiled over the non-DiLoCo mesh axes (an opaque device-major
    concat, only ever interpreted inside the manual region)."""
    shapes, axes = common.eval_axes(model.init, jax.random.PRNGKey(0))
    amap = mesh_axes(mesh)
    pspecs = partition.param_pspecs(axes, shapes, plan, amap)
    sharded = any(s != P() for s in jax.tree.leaves(
        pspecs, is_leaf=lambda x: isinstance(x, P)))
    if not sharded:
        return sum(int(np.prod(s.shape, dtype=np.int64))
                   if s.shape else 1
                   for s in jax.tree.leaves(shapes))
    local, tile = _shard_flat_dims(shapes, pspecs, amap,
                                   plan.diloco_axis)
    return local * tile


def batch_pspecs(model, shape, plan, mesh, *, stacked: bool) -> Any:
    """Leading-batch-dim specs for every input leaf (+ worker dim)."""
    specs = model.input_specs(shape)
    per_worker = shape.global_batch // plan.n_workers
    bp = partition.batch_pspec(plan, per_worker, mesh_axes(mesh))
    if stacked and plan.diloco_axis:
        bp = P(plan.diloco_axis, *bp)
    return {k: bp for k in specs}


# -- train --------------------------------------------------------------------


def build_train_step(model, plan, mesh, optimizer: AdamW):
    """Returns (train_step, state_specs).

    state/batch carry a leading DiLoCo-worker dim iff plan.diloco_axis.
    train_step(state: TrainState, batch) -> (state, metrics)."""
    pspecs = param_specs(model, plan, mesh)

    bspec = partition.batch_pspec(plan)
    # (B, S, D) residual-stream spec: batch over the batch axes, seq
    # over the SP axis when the plan enables it
    batch_entry = bspec[0] if len(bspec) else None
    act_spec = P(batch_entry, plan.act_seq_axis) \
        if plan.act_seq_axis else None

    def _soft_constrain(tree):
        """Bare-spec grad constraints: work inside vmap (spmd_axis_name
        prepends the worker axis) and no-op without a mesh context."""
        def one(x, s):
            try:
                return jax.lax.with_sharding_constraint(x, s)
            except Exception:
                return x

        return jax.tree.map(one, tree, pspecs,
                            is_leaf=lambda x: isinstance(x, P))

    def grads_of(params, batch, hints: bool = True):
        """Microbatched (gradient-accumulation) value_and_grad with the
        activation hints active. ``hints=False`` traces the body with
        NO in-body sharding constraints at all — required inside plain
        vmap on data-sharded-params plans, where any constraint in the
        vmapped body lowers through XLA's manual-subgroup machinery and
        CHECK-crashes the compiler."""
        from repro.sharding.act_hints import activation_hints

        constrain = _soft_constrain if hints else (lambda t: t)
        with activation_hints(act_spec if hints else None):
            if plan.microbatches == 1:
                (_, metrics), grads = jax.value_and_grad(
                    model.loss, has_aux=True)(params, batch,
                                              remat=plan.remat)
                return constrain(grads), metrics
            nmb = plan.microbatches
            mb = jax.tree.map(
                lambda x: x.reshape((nmb, x.shape[0] // nmb)
                                    + x.shape[1:]), batch)

            def body(acc, b_i):
                (_, m), g = jax.value_and_grad(
                    model.loss, has_aux=True)(params, b_i,
                                              remat=plan.remat)
                g = constrain(g)
                acc = jax.tree.map(
                    lambda a, gg: a + gg.astype(jnp.float32), acc, g)
                return constrain(acc), m

            zeros = constrain(jax.tree.map(
                lambda p: jnp.zeros(p.shape, jnp.float32), params))
            grads, ms = jax.lax.scan(body, zeros, mb)
            grads = jax.tree.map(lambda g: g / nmb, grads)
            metrics = jax.tree.map(lambda m: m[-1], ms)
            return grads, metrics

    def inner(params, opt_state, batch):
        # anchor the activation batch sharding (FSDP-style: batch over
        # the data axes and, when divisible, 'model' too) + optional
        # sequence parallelism hint on the residual stream
        batch = jax.tree.map(
            lambda x: jax.lax.with_sharding_constraint(
                x, NamedSharding(mesh, bspec)), batch)
        grads, metrics = grads_of(params, batch)
        grads = _constrain(mesh, grads, pspecs)
        params, opt_state = optimizer.update(grads, opt_state, params)
        params = _constrain(mesh, params, pspecs)
        return params, opt_state, metrics

    dax = plan.diloco_axis
    if dax is None:
        def step(state: TrainState, batch):
            params, opt, metrics = inner(state.params, state.opt, batch)
            return TrainState(params, opt), metrics

        state_specs = TrainState(pspecs,
                                 AdamWState(P(), pspecs, pspecs))
        return step, state_specs

    lead = lambda t: partition.with_leading(t, dax)
    state_specs = TrainState(
        lead(pspecs), AdamWState(P(dax), lead(pspecs), lead(pspecs)))

    # XLA's SPMD partitioner CHECK-fails (`Check failed:
    # sharding.IsManualSubgroup()`) whenever a constraint meets a
    # manual subgroup: a shard_map region manual over the DiLoCo axis
    # whose body constrains leaves over the remaining mesh axes needs
    # manual-subgroup shardings this XLA cannot partition, and
    # `vmap(spmd_axis_name=dax)` lowers through the same machinery.
    # Partitioner-safe formulation with NO manual axes at all:
    # plain-vmap the per-worker step (traced hint-free — any in-body
    # constraint reintroduces the crash) over the stacked leading dim
    # and constrain the STACKED trees at the vmap boundary; sharding is
    # driven entirely by the boundary constraints and pjit propagation.
    def step(state: TrainState, batch):
        batch = jax.tree.map(
            lambda x: jax.lax.with_sharding_constraint(
                x, NamedSharding(mesh, P(dax, *bspec))), batch)

        grads, metrics = jax.vmap(
            lambda p, b: grads_of(p, b, hints=False))(
                state.params, batch)
        grads = _constrain(mesh, grads, lead(pspecs))
        params, opt = jax.vmap(optimizer.update)(
            grads, state.opt, state.params)
        params = _constrain(mesh, params, lead(pspecs))
        return TrainState(params, opt), metrics

    return step, state_specs


def build_outer_sync(model, plan, mesh, diloco_cfg: dl.DiLoCoConfig,
                     ring_order=None):
    """Returns (sync_step, outer_specs).

    sync_step(params_stacked, outer_state, weights)
        -> (params_stacked, outer_state).
    The outer state (fp32 anchor + Nesterov momentum) is SHARED
    (replicated over the DiLoCo axis, data/model-sharded like params —
    the paper's host-offloaded master copy; on TPU targets pass
    ``host_offload_outer=True`` to place it in pinned_host memory)."""
    pspecs = param_specs(model, plan, mesh)
    dax = plan.diloco_axis

    if dax is None:
        # degenerate DiLoCo (one worker): PER-LEAF pseudo-gradient +
        # outer update — flattening to one vector would concat sharded
        # leaves and force a full all-gather (observed: 1.8 TB/device
        # for dbrx)
        def sync_single(params, outer_state, weights):
            del weights
            delta = jax.tree.map(
                lambda a, p: a - p.astype(jnp.float32),
                outer_state.anchor, params)
            new_anchor, new_opt = diloco_cfg.outer_opt.update(
                delta, outer_state.opt, outer_state.anchor)
            new_params = jax.tree.map(
                lambda a, p: a.astype(p.dtype), new_anchor, params)
            return new_params, dl.OuterState(
                new_anchor, new_opt, outer_state.residual,
                outer_state.outer_step + 1)

        outer_specs = dl.OuterState(pspecs, NesterovState(pspecs),
                                    P(), P())
        return sync_single, outer_specs

    # Hybrid FSDP + DiLoCo (paper §2.3): "only ranks responsible for the
    # same shard communicate". The sync runs FULLY manual — every device
    # rings ITS OWN model-shard of the pseudo-gradient across the DiLoCo
    # axis; the 16 model columns run 16 parallel rings (the paper's
    # per-shard process groups / parallel TCP stores).
    sharded_params = any(
        s != P() for s in jax.tree.leaves(
            pspecs, is_leaf=lambda x: isinstance(x, P)))
    if diloco_cfg.error_feedback and sharded_params:
        raise NotImplementedError(
            "error feedback requires per-shard residual bookkeeping; "
            "supported with replicated-inner-params plans only")

    lead = lambda t: partition.with_leading(t, dax)

    if not sharded_params:
        # replicated-inner-params plans: thread the persistent flat
        # fp32 anchor THROUGH the shard_map region, so the
        # pseudo-gradient is one subtract off the buffer instead of a
        # per-sync anchor re-flatten, and the updated buffer flows back
        # out for the next outer step (sharded plans would need a
        # per-shard flat view first — the anchor leaves inside the
        # region are shards there).
        def per_worker(params, anchor, momentum, residual, outer_step,
                       a_flat, weights):
            p_i = jax.tree.map(lambda x: x[0], params)
            st = dl.OuterState(anchor, NesterovState(momentum),
                               residual[0], outer_step,
                               anchor_flat=a_flat)
            new_p, new_st = dl.outer_sync(
                p_i, st, diloco_cfg, dax, ring_order=ring_order,
                weight=weights[0])
            return (jax.tree.map(lambda x: x[None], new_p),
                    new_st.anchor, new_st.opt.momentum,
                    new_st.residual[None], new_st.outer_step,
                    new_st.anchor_flat)

        def sync(params_stacked, outer_state: dl.OuterState, weights):
            a_flat = outer_state.anchor_flat
            if a_flat is None:
                eng = SyncEngine.for_tree(outer_state.anchor)
                a_flat = eng.flatten(outer_state.anchor)
            new_p, anchor, momentum, residual, ostep, new_a_flat = \
                compat.shard_map(
                    per_worker, mesh=mesh,
                    in_specs=(lead(pspecs), pspecs, pspecs, P(dax),
                              P(), P(), P(dax)),
                    out_specs=(lead(pspecs), pspecs, pspecs, P(dax),
                               P(), P()),
                    check_vma=False)(
                        params_stacked, outer_state.anchor,
                        outer_state.opt.momentum, outer_state.residual,
                        outer_state.outer_step, a_flat, weights)
            return new_p, dl.OuterState(anchor, NesterovState(momentum),
                                        residual, ostep, new_a_flat)

        outer_specs = dl.OuterState(pspecs, NesterovState(pspecs),
                                    P(dax), P(), P())
        return sync, outer_specs

    # Sharded plans thread the PER-SHARD flat anchor view (the zero-
    # flatten fused path replicated plans got in PR 2): inside the
    # manual region every device's anchor leaves are LOCAL shards, so
    # the persistent buffer is the concat of those shards plus one
    # SENTINEL element (see _shard_flat_dims — it keeps the threaded
    # layout's length distinct from a global flatten, so a buffer from
    # init_outer_state can never be mis-read as per-shard). It rides
    # in/out of the region as an opaque device-major array whose first
    # dim is "sharded" over the non-DiLoCo mesh axes (and replicated
    # over the DiLoCo axis, like the anchor itself); sync() rebuilds
    # the view whenever the incoming buffer's length differs.
    nondax = tuple(a for a in mesh.axis_names if a != dax)
    flat_spec = P(nondax) if nondax else P()
    shapes, _ = common.eval_axes(model.init, jax.random.PRNGKey(0))
    padded_local, tile = _shard_flat_dims(shapes, pspecs,
                                          mesh_axes(mesh), dax)
    flat_global = padded_local * tile

    def _local_flatten(anchor):
        flat = SyncEngine.for_tree(anchor).flatten(anchor)
        return jnp.pad(flat, (0, 1))          # sentinel element

    flatten_local = compat.shard_map(
        _local_flatten, mesh=mesh, in_specs=(pspecs,),
        out_specs=flat_spec, check_vma=False)

    def per_worker(params, anchor, momentum, residual, outer_step,
                   a_flat, weights):
        p_i = jax.tree.map(lambda x: x[0], params)
        st = dl.OuterState(anchor, NesterovState(momentum),
                           residual[0], outer_step,
                           anchor_flat=a_flat[:-1])  # drop sentinel
        new_p, new_st = dl.outer_sync(
            p_i, st, diloco_cfg, dax, ring_order=ring_order,
            weight=weights[0])
        return (jax.tree.map(lambda x: x[None], new_p), new_st.anchor,
                new_st.opt.momentum, new_st.residual[None],
                new_st.outer_step, jnp.pad(new_st.anchor_flat, (0, 1)))

    def sync(params_stacked, outer_state: dl.OuterState, weights):
        a_flat = outer_state.anchor_flat
        if a_flat is None or tuple(a_flat.shape) != (flat_global,):
            # first sync (or a global-layout buffer from
            # init_outer_state): build the per-shard view once; the
            # updated buffer threads through every later sync
            a_flat = flatten_local(outer_state.anchor)
        new_p, anchor, momentum, residual, ostep, new_a_flat = \
            compat.shard_map(
                per_worker, mesh=mesh,
                in_specs=(lead(pspecs), pspecs, pspecs, P(dax), P(),
                          flat_spec, P(dax)),
                out_specs=(lead(pspecs), pspecs, pspecs, P(dax), P(),
                           flat_spec),
                check_vma=False)(
                    params_stacked, outer_state.anchor,
                    outer_state.opt.momentum, outer_state.residual,
                    outer_state.outer_step, a_flat, weights)
        return new_p, dl.OuterState(anchor, NesterovState(momentum),
                                    residual, ostep, new_a_flat)

    outer_specs = dl.OuterState(pspecs, NesterovState(pspecs),
                                P(dax), P(), flat_spec)
    return sync, outer_specs


# -- distributed overlapped outer sync (per-hop shard_map collectives) -------


class DistSyncPrograms:
    """Jitted per-hop ``shard_map`` collectives for the distributed
    outer-sync ring: one program per hop KIND (reduce-scatter, fused
    first hop, all-gather prologue, all-gather forward), the hop index
    riding traced so one compilation serves every hop.

    The hop BODIES are the simulator's (`ring_reduce._rs_hop_rows` /
    `_ag_hop_rows`), run at ONE ring position per device: inside the
    manual region ``positions = inv[axis_index(dax)][None]`` and the
    payload shift is the static ``ppermute`` along the bandwidth-
    ordered ring instead of ``jnp.roll``. Per-row math is identical and
    vmap over one row is bit-identical to the stacked vmap on XLA:CPU,
    so the distributed reduction is hop-for-hop bit-identical to the
    simulator (tested in tests/test_distributed.py). The in-flight
    accumulator and forwarded-code payloads thread BETWEEN programs as
    opaque flat shards (spec ``P(dax)`` / ``P(dax, local)``), like the
    PR 5 per-shard anchor buffer.

    Hierarchical mode (``core.elastic_mesh.HierarchySpec``, the paper's
    ElasticDeviceMesh split): each device rings only its intra-node
    slice (1/n_local of the vector) over the WAN axis, and the full
    vector is rebuilt with an intra-node ``all_gather`` at finalize —
    per-device WAN bytes drop by n_local. With replicated inner params
    every local copy of the pseudo-gradient is identical, so the slice
    by local rank IS the intra-node reduce-scatter (psum_scatter /
    n_local, exactly). Quantization codebooks become per-slice, so
    hierarchical results are bit-identical to the PER-SLICE simulator
    (concat of slice sims), not to the flat one.

    A changed ring order is a new static ``ppermute`` permutation:
    ``DistSyncBackend`` rebuilds these programs whenever
    ``BandwidthMonitor.maybe_reorder`` reports a change (the reorder ->
    recompile lifecycle; the paper pays the analogous process-group
    re-rendezvous cost).
    """

    def __init__(self, mesh, dax: str, size: int, cfg, ring_order=None,
                 hierarchy=None):
        from repro.core import ring_reduce as rr
        self.mesh, self.dax = mesh, dax
        self.cfg = cfg
        self.k = k = int(mesh.shape[dax])
        self.size = size
        order = (tuple(ring_order) if ring_order is not None
                 else tuple(range(k)))
        assert sorted(order) == list(range(k)), \
            "ring order must be a permutation of the DiLoCo slots"
        assert k > 1, "use RingSyncOp for the degenerate 1-worker ring"
        self.ring_order = order
        self.hier = hierarchy if (hierarchy is not None
                                  and hierarchy.split) else None
        lnames = self.hier.local_axes if self.hier else ()
        self.n_local = nl = self.hier.n_local if self.hier else 1
        self.slice_len = sl = -(-size // nl)
        nb = max(1, cfg.buckets)
        chunk = -(-sl // k)
        bsize = -(-chunk // nb)
        chunk = bsize * nb
        self.chunk, self.bsize, self.nb = chunk, bsize, nb
        self.padded = k * chunk

        inv = np.argsort(np.asarray(order))
        inv_dev = jnp.asarray(inv)
        perm_fwd = [(order[p], order[(p + 1) % k]) for p in range(k)]
        row_spec, acc_spec = partition.wan_ring_specs(dax, lnames)
        self._row_sharding = NamedSharding(mesh, row_spec)
        self._rep_sharding = NamedSharding(mesh, P())
        hier = self.hier is not None

        def _positions():
            # this device's ring position, as a 1-row batch for the
            # shared row-wise hop bodies
            return inv_dev[jax.lax.axis_index(dax)][None]

        def _shift(payload):
            # position p's payload moves to position p+1 — the ring's
            # static wire permutation (the sim's jnp.roll(+1) analogue)
            return tuple(jax.lax.ppermute(p, dax, perm_fwd)
                         for p in payload)

        # hierarchical buffers carry a local-slice dim the row-wise
        # bodies don't know about: squeeze/restore around each hop
        _sq = (lambda a: a[:, 0]) if hier else (lambda a: a)
        _usq = (lambda a: a[:, None]) if hier else (lambda a: a)
        _psq = (lambda p: jax.tree.map(lambda x: x[:, 0], p)) if hier \
            else (lambda p: p)
        _pusq = (lambda p: jax.tree.map(lambda x: x[:, None], p)) \
            if hier else (lambda p: p)
        geo = (k, chunk, bsize, nb, cfg)

        def rs_body(s, accs):
            return _usq(rr._rs_hop_rows(
                s, _sq(accs), *geo, positions=_positions(),
                shift=_shift))

        def rs_fused_body(s, accs, a_flat, t_row, w_row):
            return _usq(rr._rs_hop_rows(
                s, _sq(accs), *geo, (a_flat, t_row, w_row),
                positions=_positions(), shift=_shift))

        def ag_init_body(accs):
            a, p = rr._ag_init_rows(_sq(accs), *geo,
                                    positions=_positions())
            return _usq(a), _pusq(p)

        def ag_body(s, accs, payloads):
            a, p = rr._ag_hop_rows(s, _sq(accs), _psq(payloads), *geo,
                                   positions=_positions(), shift=_shift)
            return _usq(a), _pusq(p)

        def _local_rank():
            # row-major over the non-DiLoCo axes — must match
            # ElasticDeviceMesh.local_rank and the all_gather order
            r, stride = 0, 1
            for name in reversed(list(mesh.shape.keys())):
                if name == dax:
                    continue
                r = r + jax.lax.axis_index(name) * stride
                stride *= int(mesh.shape[name])
            return r

        def prep_hier_body(pg, w):
            # (1, size) worker row -> this device's weighted, ring-
            # padded intra-node slice (1, 1, padded)
            row = pg.astype(jnp.float32) * w[:, None]
            row = jnp.pad(row, ((0, 0), (0, nl * sl - size)))
            piece = jax.lax.dynamic_slice_in_dim(
                row, _local_rank() * sl, sl, axis=-1)
            piece = jnp.pad(piece, ((0, 0), (0, self.padded - sl)))
            return piece[:, None]

        def fin_hier_body(accs):
            # rebuild the full vector intra-node: gather every local
            # slice (valid region only) back into worker rows
            row = accs[:, 0, :sl]
            return jax.lax.all_gather(row, lnames, axis=1, tiled=True)

        def _sm(f, ins, outs):
            return jax.jit(compat.shard_map(
                f, mesh=mesh, in_specs=ins, out_specs=outs,
                check_vma=False))

        self.rs = _sm(rs_body, (P(), acc_spec), acc_spec)
        self.rs_fused = None if hier else _sm(
            rs_fused_body, (P(), acc_spec, P(), row_spec, row_spec),
            acc_spec)
        self.ag_init = _sm(ag_init_body, (acc_spec,),
                           (acc_spec, acc_spec))
        self.ag = _sm(ag_body, (P(), acc_spec, acc_spec),
                      (acc_spec, acc_spec))
        self._prep_hier = _sm(prep_hier_body, (row_spec, row_spec),
                              acc_spec) if hier else None
        self._fin_hier = _sm(fin_hier_body, (acc_spec,),
                             row_spec) if hier else None
        self._acc_sharding = NamedSharding(mesh, acc_spec)

    # -- buffer staging -------------------------------------------------------

    def prep(self, xs, weights):
        """Weighted, ring-padded accumulator rows, placed on the mesh
        (worker-major: row d = device d's position's accumulator)."""
        if self.hier:
            return self._prep_hier(
                jax.device_put(xs, self._row_sharding),
                jax.device_put(weights, self._row_sharding))
        accs = xs.astype(jnp.float32) * weights[:, None]
        accs = jnp.pad(accs, ((0, 0), (0, self.padded - self.size)))
        return jax.device_put(accs, self._acc_sharding)

    def prep_fused(self, a_flat, thetas, weights):
        """Ring-padded fused first-hop operands on the mesh (anchor
        replicated, theta/weight rows over the WAN axis)."""
        pad = self.padded - self.size
        a = jnp.pad(a_flat.astype(jnp.float32), (0, pad))
        t = jnp.pad(thetas.astype(jnp.float32), ((0, 0), (0, pad)))
        return (jax.device_put(a, self._rep_sharding),
                jax.device_put(t, self._row_sharding),
                jax.device_put(weights, self._row_sharding))

    def finalize(self, accs, total_w):
        """Post-all-gather accumulator -> (k, size) reduced rows on the
        default device (identical rows; same eager slice/divide as
        RingSyncOp.finish, so values are bit-identical to the sim)."""
        if self.hier:
            accs = self._fin_hier(accs)
        out = jnp.asarray(jax.device_get(accs))[:, : self.size]
        if self.cfg.average:
            out = out / jnp.maximum(total_w, 1e-20)
        return out


class DistRingSyncOp:
    """Distributed mirror of :class:`ring_reduce.RingSyncOp` with the
    same public surface (``step``/``finish``/``restart``/``pending``/
    ``hops_total``/``hops_done``), so ``diloco.OuterSyncHandle``,
    ``finish_outer_sync_sim`` and ``resync_outer_sim`` operate on it
    unchanged. Each ``step()`` dispatches ONE wire hop as a jitted
    shard_map collective and returns as soon as it is enqueued — no
    ``block_until_ready`` anywhere — so the transfer rides under the
    next inner-phase scan chunk. Like the sim op, it RETAINS its inputs
    for the torn-reduction fallback: ``restart`` re-reduces the
    retained rows over the survivors through the same distributed
    programs (bit-identical to the sim restart)."""

    def __init__(self, programs: DistSyncPrograms, xs,
                 weights=None, fused_src=None):
        pr = programs
        k, orig = xs.shape
        assert k == pr.k and orig == pr.size, \
            f"geometry mismatch: op ({k}, {orig}) vs programs " \
            f"({pr.k}, {pr.size})"
        self.programs = pr
        self.cfg = pr.cfg
        self.k, self.orig_size = k, orig
        self.ring_order = pr.ring_order
        self.xs = xs.astype(jnp.float32)
        self.weights = (jnp.ones((k,), jnp.float32) if weights is None
                        else weights)
        self.fused_src = fused_src
        self.hops_done = 0
        self._out = None
        self._total_w = jnp.sum(self.weights)
        self.hops_total = 2 * (k - 1)
        self._fused0 = (fused_src is not None and self.cfg.fused
                        and self.cfg.quant == "int8"
                        and pr.rs_fused is not None)
        self._accs = pr.prep(self.xs, self.weights)
        if self._fused0:
            a_flat, thetas = fused_src
            self._a_dev, self._t_dev, self._w_dev = pr.prep_fused(
                a_flat, thetas, self.weights)
        self._payloads = None

    @property
    def pending(self) -> bool:
        return self.hops_done < self.hops_total

    def step(self) -> bool:
        """Dispatch ONE wire hop (async collective); True iff a hop was
        dispatched."""
        if self._out is not None or not self.pending:
            return False
        i, k, pr = self.hops_done, self.k, self.programs
        if i < k - 1:
            if i == 0 and self._fused0:
                self._accs = pr.rs_fused(
                    jnp.int32(0), self._accs, self._a_dev,
                    self._t_dev, self._w_dev)
            else:
                self._accs = pr.rs(jnp.int32(i), self._accs)
        else:
            s = i - (k - 1)
            if s == 0:
                self._accs, self._payloads = pr.ag_init(self._accs)
            self._accs, self._payloads = pr.ag(
                jnp.int32(s), self._accs, self._payloads)
        self.hops_done += 1
        return True

    def finish(self):
        if self._out is None:
            while self.pending:
                self.step()
            self._out = self.programs.finalize(self._accs,
                                               self._total_w)
            self._accs = self._payloads = None   # free in-flight state
        return self._out

    def restart(self, weights):
        """Torn-reduction fallback: synchronously re-reduce the
        RETAINED inputs over the survivors through the same distributed
        programs (no recompile — weights ride traced)."""
        return DistRingSyncOp(self.programs, self.xs, weights=weights,
                              fused_src=self.fused_src).finish()

    def norm_sideband(self):
        """Per-chunk norm sideband of the retained rows — the SAME
        host-side ``ring_reduce.chunk_norms`` the simulator op uses, so
        both paths judge bit-identical values (the admission layer's
        bit-identity hinges on this)."""
        from repro.core import ring_reduce as rr
        return rr.chunk_norms(self.xs, self.cfg.buckets)


class DistSyncBackend:
    """Plugs the per-hop distributed collectives into ``ElasticTrainer``
    (pass ``sync_backend=DistSyncBackend(mesh, dax)`` to the trainer).

    ``begin`` mirrors ``diloco.begin_outer_sync_sim`` — literally the
    same pseudo-gradient front half (``_sim_pseudograds``), including
    the slot-parity two-slot error-feedback residual — but stages the
    ring as a :class:`DistRingSyncOp` over the mesh's DiLoCo axis, so
    distributed ``overlap='delayed'`` is bit-identical to the simulator
    path on the same plan. Hop programs are rebuilt whenever the ring
    order (or geometry) changes — ``recompiles`` counts builds, the
    first one included."""

    def __init__(self, mesh, dax: str, hierarchical: bool | None = None):
        from repro.core import elastic_mesh
        self.mesh, self.dax = mesh, dax
        self._split = elastic_mesh.hierarchy(mesh, dax)
        # None -> follow DiLoCoConfig.hierarchical per begin() call
        self.hierarchical = hierarchical
        self.recompiles = 0
        self._programs: DistSyncPrograms | None = None
        self._key = None

    def _want_hier(self, cfg) -> bool:
        use = (cfg.hierarchical if self.hierarchical is None
               else self.hierarchical)
        return bool(use) and self._split.split

    def begin(self, stacked_params, state, cfg, ring_order=None,
              weights=None, ef_slot: int = 0) -> dl.OuterSyncHandle:
        """Distributed analogue of ``diloco.begin_outer_sync_sim``."""
        from repro.core.ring_reduce import RingSyncOp
        k, _, a_flat, pgs, new_residuals, fused_src = \
            dl._sim_pseudograds(stacked_params, state, cfg,
                                ef_slot=ef_slot)
        assert k == int(self.mesh.shape[self.dax]), \
            f"trainer has {k} DiLoCo slots but mesh axis " \
            f"{self.dax!r} has {self.mesh.shape[self.dax]}"
        if weights is None:
            weights = jnp.ones((k,), jnp.float32)
        if k == 1:
            op = RingSyncOp(pgs, ring_order=ring_order, cfg=cfg.ring,
                            weights=weights, fused_src=fused_src)
            return dl.OuterSyncHandle(op, cfg, a_flat, new_residuals,
                                      weights, k, ef_slot=ef_slot)
        hier = self._want_hier(cfg)
        if hier:
            # per-slice codebooks make the fused whole-vector transmit
            # inapplicable; the materialized slice is quantized instead
            # (bit-identical values — quantize_pseudograd(a,t,w) ==
            # quantize(w*(a-t)) is a tested invariant)
            fused_src = None
        order = (tuple(ring_order) if ring_order is not None
                 else tuple(range(k)))
        key = (k, pgs.shape[-1], cfg.ring, order, hier)
        if key != self._key:
            self._programs = DistSyncPrograms(
                self.mesh, self.dax, pgs.shape[-1], cfg.ring,
                ring_order=order,
                hierarchy=self._split if hier else None)
            self._key = key
            self.recompiles += 1
        op = DistRingSyncOp(self._programs, pgs, weights=weights,
                            fused_src=fused_src)
        return dl.OuterSyncHandle(op, cfg, a_flat, new_residuals,
                                  weights, k, ef_slot=ef_slot)


# -- serve --------------------------------------------------------------------


def build_serve_step(model, plan, mesh, kind: str):
    """kind in {'prefill', 'decode'}. Returns (fn, param_specs)."""
    pspecs = param_specs(model, plan, mesh)
    axes = mesh_axes(mesh)

    # prefill SP: when KV heads don't divide the model axis (MHA
    # archs), shard the 32k sequence over 'model' for the prefill
    # activations — the attention q-block tiles divide accordingly
    hint = None
    if (kind == "prefill"
            and model.cfg.n_kv_heads % axes.get("model", 1) != 0
            and model.cfg.family not in ("ssm", "hybrid")):
        b_entry = plan.batch_axes[0] if plan.batch_axes else None
        hint = P(b_entry, "model")

    if kind == "prefill":
        def fn(params, batch, cache):
            from repro.sharding.act_hints import activation_hints
            with activation_hints(hint):
                return model.prefill(params, batch, cache)
    else:
        def fn(params, token, cache):
            return model.decode(params, token, cache)

    return fn, pspecs
