"""Live checkpoint recovery engine: content-addressed chunk store,
quantized delta chains (bit-exact restore + wire-byte reduction), and
the double-buffered async snapshot path (paper §2.4.2)."""
import hashlib
import threading
import time

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.checkpointing import (AsyncSnapshotter, ChunkCorruptError,
                                 ChunkStore, DeltaChainError,
                                 DeltaCheckpointer, DeltaConfig)
from repro.checkpointing import delta as delta_mod
from repro.checkpointing.store import chunk_ids


# -- chunk store --------------------------------------------------------------


def test_store_put_get_dedup(tmp_path):
    store = ChunkStore(tmp_path, chunk_bytes=64)
    data = b"x" * 1000
    d1, n1 = store.put(data)
    d2, n2 = store.put(data)
    assert d1 == d2 == hashlib.sha256(data).hexdigest()
    assert n1 > 0 and n2 == 0          # second put is a dedup hit
    assert store.get(d1) == data


def test_store_detects_corruption(tmp_path):
    store = ChunkStore(tmp_path)
    digest, _ = store.put(b"hello world")
    p = store._chunk_path(digest)
    p.write_bytes(p.read_bytes()[:-1] + b"\x00")
    with pytest.raises(ChunkCorruptError):
        store.get(digest)


def test_store_put_blob_verifies(tmp_path):
    import zlib
    store = ChunkStore(tmp_path)
    with pytest.raises(ChunkCorruptError):
        store.put_blob("0" * 64, zlib.compress(b"not those bytes"))
    with pytest.raises(ChunkCorruptError):
        store.put_blob("0" * 64, b"not even zlib")


def _tree(rng, n=1000):
    w = rng.normal(size=(n,)).astype(np.float32)
    return {"params": {"w": jnp.asarray(w)},
            "anchor": {"w": jnp.asarray(w)},      # post-sync identical
            "b": jnp.asarray(rng.normal(size=(8,)), jnp.bfloat16),
            "step": jnp.asarray(3, jnp.int32)}


def test_store_tree_roundtrip_and_intra_step_dedup(tmp_path, rng):
    store = ChunkStore(tmp_path, chunk_bytes=512)
    tree = _tree(rng)
    m = store.save_tree(7, tree, extra_meta={"outer_step": 2})
    # params == anchor bit-exactly -> the anchor's chunks dedup away
    assert m["stats"]["dedup_chunks"] >= len(
        m["keys"]["anchor::w"]["chunks"])
    restored, meta = store.restore_tree(tree, step=7)
    assert meta["outer_step"] == 2
    for a, b in zip(jax.tree.leaves(tree), jax.tree.leaves(restored)):
        np.testing.assert_array_equal(
            np.asarray(a, np.float32), np.asarray(b, np.float32))
    assert store.latest_step() == 7
    assert store.missing(m) == []


def test_store_gc_drops_unreferenced_chunks(tmp_path, rng):
    store = ChunkStore(tmp_path, chunk_bytes=256)
    t1 = {"w": jnp.asarray(rng.normal(size=(500,)), jnp.float32)}
    t2 = {"w": jnp.asarray(rng.normal(size=(500,)), jnp.float32)}
    m1 = store.save_tree(1, t1)
    store.save_tree(2, t2)
    removed = store.gc(keep_steps=[2])
    assert removed["manifests"] == 1
    assert removed["chunks"] == len(chunk_ids(m1))
    assert store.steps() == [2]
    restored, _ = store.restore_tree(t2, step=2)
    np.testing.assert_array_equal(np.asarray(t2["w"]),
                                  np.asarray(restored["w"]))


# -- delta chains -------------------------------------------------------------


def _heavy_tailed_chain(rng, n=60_000, steps=5):
    """Post-sync checkpoint trees with realistic heavy-tailed outer
    updates (params == anchor, smooth momentum)."""
    params = rng.normal(size=(n,)).astype(np.float32) * 0.02
    mom = np.zeros(n, np.float32)
    trees = []
    for t in range(steps):
        trees.append({"params": {"w": params.copy()},
                      "anchor": {"w": params.copy()},
                      "outer_momentum": {"w": mom.copy()},
                      "step": np.int32(t)})
        upd = rng.normal(size=(n,)).astype(np.float32) * 1e-3
        upd += ((rng.random(n) < 0.05)
                * rng.normal(size=(n,))).astype(np.float32) * 0.03
        params = params + upd
        mom = 0.9 * mom + upd
    return trees


def test_delta_chain_bit_exact_and_8x_wire_reduction(tmp_path):
    """The acceptance bar: the int8 delta chain restores BIT-EXACTLY
    to the writer's full-precision reference while shipping >= 8x
    fewer wire bytes than the flat fp32 snapshot it replaces."""
    rng = np.random.default_rng(7)   # fixed: thresholds are seed-tuned
    store = ChunkStore(tmp_path, chunk_bytes=1 << 14)
    ck = DeltaCheckpointer(store, DeltaConfig(base_every=16,
                                              codec="int8"))
    trees = _heavy_tailed_chain(rng)
    manifests = [ck.save(t, tree, extra_meta={"outer_step": t})
                 for t, tree in enumerate(trees)]
    assert manifests[0]["kind"] == "base"
    assert all(m["kind"] == "delta" for m in manifests[1:])

    like = trees[-1]
    restored, meta = delta_mod.restore(store, like)
    assert meta["outer_step"] == len(trees) - 1
    reference = ck.reference(like)
    for k in ("params", "anchor", "outer_momentum"):
        np.testing.assert_array_equal(restored[k]["w"],
                                      reference[k]["w"])
    # reconstruction tracks the truth: within one quantization bucket
    # for nearly all elements, within the 6-sigma clip for the tail
    err = np.abs(restored["params"]["w"] - trees[-1]["params"]["w"])
    assert np.quantile(err, 0.99) < 2e-3
    assert err.max() < 0.1

    flat_fp32 = sum(a.size * 4 for a in (
        trees[-1]["params"]["w"], trees[-1]["anchor"]["w"],
        trees[-1]["outer_momentum"]["w"])) + 4
    delta_bytes = manifests[-1]["stats"]["new_bytes"]
    assert flat_fp32 / delta_bytes >= 8.0, \
        f"only {flat_fp32 / delta_bytes:.2f}x"


def test_delta_int4_chain_bit_exact(tmp_path):
    rng = np.random.default_rng(7)   # fixed: thresholds are seed-tuned
    store = ChunkStore(tmp_path, chunk_bytes=1 << 14)
    ck = DeltaCheckpointer(store, DeltaConfig(base_every=16,
                                              codec="int4"))
    trees = _heavy_tailed_chain(rng, n=9_001, steps=4)  # odd: packing
    for t, tree in enumerate(trees):
        ck.save(t, tree)
    restored, _ = delta_mod.restore(store, trees[-1])
    reference = ck.reference(trees[-1])
    np.testing.assert_array_equal(restored["params"]["w"],
                                  reference["params"]["w"])
    err = np.abs(restored["params"]["w"] - trees[-1]["params"]["w"])
    assert np.quantile(err, 0.99) < 2e-2
    assert err.max() < 0.15


def test_delta_rebases_on_schedule_and_structure_change(tmp_path):
    rng = np.random.default_rng(7)
    store = ChunkStore(tmp_path)
    ck = DeltaCheckpointer(store, DeltaConfig(base_every=3))
    t0 = {"w": rng.normal(size=(100,)).astype(np.float32)}
    kinds = [ck.save(s, t0)["kind"] for s in range(6)]
    assert kinds == ["base", "delta", "delta", "base", "delta",
                     "delta"]
    # a shape change forces an immediate re-anchor
    t1 = {"w": rng.normal(size=(50,)).astype(np.float32)}
    assert ck.save(6, t1)["kind"] == "base"


def test_delta_restore_detects_tampered_chain(tmp_path):
    rng = np.random.default_rng(7)
    store = ChunkStore(tmp_path)
    ck = DeltaCheckpointer(store, DeltaConfig(base_every=8))
    trees = _heavy_tailed_chain(rng, n=2_000, steps=3)
    for t, tree in enumerate(trees):
        ck.save(t, tree)
    m = store.load_manifest(1)
    m["ref_sha"]["params::w"] = "0" * 64
    store.write_manifest(m)
    with pytest.raises(DeltaChainError):
        delta_mod.restore(store, trees[-1], step=2)


def test_delta_failed_save_rebases_instead_of_diverging(tmp_path,
                                                        monkeypatch):
    """An I/O error mid-delta-save must not advance the writer's
    reference past the persisted chain: the next save re-anchors and
    the chain stays restorable."""
    rng = np.random.default_rng(7)
    store = ChunkStore(tmp_path)
    ck = DeltaCheckpointer(store, DeltaConfig(base_every=8))
    trees = _heavy_tailed_chain(rng, n=2_000, steps=4)
    ck.save(0, trees[0])
    ck.save(1, trees[1])
    real_write = ChunkStore.write_manifest
    monkeypatch.setattr(
        ChunkStore, "write_manifest",
        lambda self, m: (_ for _ in ()).throw(OSError("disk full")))
    with pytest.raises(OSError):
        ck.save(2, trees[2])
    monkeypatch.setattr(ChunkStore, "write_manifest", real_write)
    m = ck.save(3, trees[3])
    assert m["kind"] == "base"   # forced re-anchor, not a broken delta
    restored, _ = delta_mod.restore(store, trees[3], step=3)
    np.testing.assert_array_equal(restored["params"]["w"],
                                  ck.reference(trees[3])["params"]["w"])


def test_snapshotter_flush_timeout_raises():
    gate = threading.Event()
    snap = AsyncSnapshotter(lambda s, t, m: gate.wait(10))
    snap.submit(0, {"x": np.zeros(4, np.float32)})
    with pytest.raises(TimeoutError):
        snap.flush(timeout=0.2)
    gate.set()
    snap.close()


def test_gc_keeps_delta_chain_dependencies(tmp_path):
    """Keeping only a delta step must keep its base + prev manifests
    and chunks — otherwise the 'kept' checkpoint is unrestorable."""
    rng = np.random.default_rng(7)
    store = ChunkStore(tmp_path, chunk_bytes=1 << 12)
    ck = DeltaCheckpointer(store, DeltaConfig(base_every=8))
    trees = _heavy_tailed_chain(rng, n=2_000, steps=4)
    refs = []
    for t, tree in enumerate(trees):
        ck.save(t, tree)
        refs.append(ck.reference(tree))
    store.gc(keep_steps=[3])
    assert set(store.steps()) == {0, 1, 2, 3}   # whole chain kept
    restored, _ = delta_mod.restore(store, trees[-1], step=3)
    np.testing.assert_array_equal(restored["params"]["w"],
                                  refs[3]["params"]["w"])


def test_delta_restore_mid_chain_step(tmp_path):
    rng = np.random.default_rng(7)
    store = ChunkStore(tmp_path)
    ck = DeltaCheckpointer(store, DeltaConfig(base_every=8))
    trees = _heavy_tailed_chain(rng, n=2_000, steps=4)
    refs = []
    for t, tree in enumerate(trees):
        ck.save(t, tree)
        refs.append(ck.reference(tree))
    restored, _ = delta_mod.restore(store, trees[1], step=1)
    np.testing.assert_array_equal(restored["params"]["w"],
                                  refs[1]["params"]["w"])


# -- async double-buffered snapshots ------------------------------------------


def test_snapshotter_fifo_and_backpressure():
    written, gate = [], threading.Event()

    def slow_write(step, tree, meta):
        gate.wait(5)
        written.append((step, float(tree["x"][0]), meta["m"]))

    snap = AsyncSnapshotter(slow_write, buffers=2)
    snap.submit(0, {"x": jnp.full((8,), 0.0)}, {"m": 0})
    snap.submit(1, {"x": jnp.full((8,), 1.0)}, {"m": 1})
    third_done = threading.Event()

    def third():
        snap.submit(2, {"x": jnp.full((8,), 2.0)}, {"m": 2})
        third_done.set()

    threading.Thread(target=third, daemon=True).start()
    # both buffers are in flight (writer is gated): submit #3 blocks
    assert not third_done.wait(0.3)
    gate.set()
    assert third_done.wait(5)
    snap.submit(3, {"x": jnp.full((8,), 3.0)}, {"m": 3})
    snap.flush(timeout=10)
    assert [w[0] for w in written] == [0, 1, 2, 3]     # FIFO order
    assert [w[1] for w in written] == [0.0, 1.0, 2.0, 3.0]
    assert snap.stats["blocked_waits"] >= 1            # backpressure
    snap.close()


def test_snapshotter_snapshot_is_stable_copy():
    """The host buffer must be a snapshot: mutating the source after
    submit cannot change what gets persisted."""
    seen = []
    snap = AsyncSnapshotter(lambda s, t, m: seen.append(t["x"].copy()))
    x = np.ones(16, np.float32)
    snap.submit(0, {"x": x})
    x[:] = -1.0
    snap.flush(timeout=10)
    np.testing.assert_array_equal(seen[0], np.ones(16, np.float32))
    snap.close()


def test_snapshotter_propagates_writer_errors():
    def bad_write(step, tree, meta):
        raise RuntimeError("disk full")

    snap = AsyncSnapshotter(bad_write)
    snap.submit(0, {"x": jnp.zeros(4)})
    for _ in range(100):
        if snap.stats["writes"]:
            break
        time.sleep(0.01)
    with pytest.raises(RuntimeError, match="disk full"):
        snap.flush(timeout=10)


# -- trainer integration ------------------------------------------------------


def _tiny_trainer(tmp_path, engine: str, **kw):
    from repro.configs import CONFIGS
    from repro.core.diloco import DiLoCoConfig
    from repro.core.fault_tolerance import ClusterSimulator
    from repro.data.pipeline import DataConfig
    from repro.models.registry import get_model
    from repro.train.loop import ElasticTrainer, TrainerConfig

    cfg = CONFIGS["mamba2-130m"].reduced()
    model = get_model(cfg)
    params, _ = model.init(jax.random.PRNGKey(0))
    dcfg = DataConfig(vocab=cfg.vocab, seq_len=32, batch_per_worker=2,
                      total_steps=50)
    tcfg = TrainerConfig(
        diloco=DiLoCoConfig(inner_steps=2, quant="fp32"),
        inner_lr=1e-3, max_workers=2, ckpt_dir=str(tmp_path),
        ckpt_engine=engine, **kw)
    return ElasticTrainer(model, tcfg, dcfg, params,
                          ClusterSimulator([0, 1]))


def test_trainer_delta_engine_restorable(tmp_path):
    tr = _tiny_trainer(tmp_path, "delta", ckpt_delta_base_every=2)
    tr.run(3)   # base, delta, base
    store = tr.ckpt_store
    assert store.latest_step() == 3 * 2
    kinds = [store.load_manifest(s)["kind"] for s in store.steps()]
    assert kinds == ["base", "delta", "base"]
    like = tr.checkpoint_like()
    restored, meta = store.restore_tree(like)   # auto-delegates
    assert meta["outer_step"] == 3
    np.testing.assert_array_equal(
        np.asarray(restored["anchor"]["embed"], np.float32),
        np.asarray(tr.outer.anchor["embed"], np.float32))


def test_trainer_store_engine_dedups_params_anchor(tmp_path):
    tr = _tiny_trainer(tmp_path, "store")
    tr.run(1)
    m = tr.ckpt_store.load_manifest(tr.ckpt_store.latest_step())
    # fp32 quant => post-sync params tree == anchor tree bit-exactly
    assert m["stats"]["dedup_chunks"] > 0
    restored, _ = tr.ckpt_store.restore_tree(tr.checkpoint_like())
    np.testing.assert_array_equal(
        np.asarray(restored["params"]["embed"], np.float32),
        np.asarray(jax.tree.map(lambda p: p[0],
                                tr.params)["embed"], np.float32))


def test_snapshotter_tasks_run_fifo_behind_writes():
    """submit_task callables are serialized AFTER pending persists."""
    order = []

    def slow_write(step, tree, meta):
        time.sleep(0.05)
        order.append(("write", step))

    snap = AsyncSnapshotter(slow_write, buffers=2)
    snap.submit(1, {"w": np.zeros(4, np.float32)})
    snap.submit_task(lambda: order.append(("task", 1)))
    snap.submit(2, {"w": np.ones(4, np.float32)})
    snap.flush()
    snap.close()
    assert order == [("write", 1), ("task", 1), ("write", 2)]
    assert snap.stats["tasks"] == 1


def test_snapshotter_task_error_surfaces():
    snap = AsyncSnapshotter(lambda *a: None)

    def boom():
        raise RuntimeError("gc failed")

    snap.submit_task(boom)
    with pytest.raises(RuntimeError, match="gc failed"):
        for _ in range(100):
            snap.flush()
            time.sleep(0.01)


def test_trainer_ckpt_keep_retention_gc(tmp_path):
    """ckpt_keep hooks ChunkStore.gc to the ckpt_every_outer cadence:
    only the newest N checkpoints (plus any delta-chain bases needed to
    restore them) survive, and the newest stays restorable."""
    tr = _tiny_trainer(tmp_path, "store", ckpt_keep=2)
    tr.run(5)
    tr.snapshotter.flush()
    steps = tr.ckpt_store.steps()
    assert steps == [4 * 2, 5 * 2]      # newest 2 of 5 (2 inner/outer)
    restored, meta = tr.ckpt_store.restore_tree(tr.checkpoint_like())
    assert meta["outer_step"] == 5

    # delta engine: retention must keep chain bases restorable
    tr2 = _tiny_trainer(tmp_path / "d", "delta", ckpt_keep=2,
                        ckpt_delta_base_every=4)
    tr2.run(6)    # base(2) d d d base(10) d
    tr2.snapshotter.flush()
    steps = tr2.ckpt_store.steps()
    assert 12 in steps and 10 in steps  # newest delta + its base
    restored, meta = tr2.ckpt_store.restore_tree(tr2.checkpoint_like())
    assert meta["outer_step"] == 6
