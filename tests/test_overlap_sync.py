"""Overlapped outer sync: hop-steppable ring vs the one-shot
simulator (bit-exact), begin/finish delayed application, torn-overlap
fallback, chunked inner phase, and the logical-time overlap ledger."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import diloco as dl
from repro.core import ring_reduce as rr
from repro.core.fault_tolerance import (ClusterSimulator,
                                        CommOverlapLedger, EventKind,
                                        NodeEvent)

_rng = np.random.default_rng(77)


# -- RingSyncOp == one-shot simulator -----------------------------------------


@pytest.mark.parametrize("k", [2, 4, 5])
@pytest.mark.parametrize("quant,buckets", [("fp32", 1), ("int8", 1),
                                           ("int8", 3), ("int4", 1)])
def test_stepped_ring_bit_matches_oneshot(k, quant, buckets):
    xs = jnp.asarray(_rng.normal(size=(k, 1027)), jnp.float32)
    order = tuple(np.random.default_rng(k).permutation(k).tolist())
    w = jnp.asarray(_rng.uniform(0.5, 1.5, size=(k,)), jnp.float32)
    cfg = rr.RingConfig(quant=quant, buckets=buckets)
    one = rr.simulate_ring_all_reduce(xs, ring_order=order, cfg=cfg,
                                      weights=w)
    op = rr.RingSyncOp(xs, ring_order=order, cfg=cfg, weights=w)
    assert op.hops_total == 2 * (k - 1)
    n = 0
    while op.step():
        n += 1
    assert n == op.hops_total
    assert not op.step()                      # idempotent once drained
    np.testing.assert_array_equal(np.asarray(one),
                                  np.asarray(op.finish()))


def test_stepped_ring_fused_src_bit_matches(rng):
    k, n = 4, 1500
    anchor = jnp.asarray(rng.normal(size=(n,)), jnp.float32)
    thetas = jnp.asarray(rng.normal(size=(k, n)), jnp.float32)
    pgs = anchor[None] - thetas
    w = jnp.asarray([1.0, 0.0, 1.0, 1.0], jnp.float32)
    cfg = rr.RingConfig(quant="int8", buckets=2)
    one = rr.simulate_ring_all_reduce(pgs, cfg=cfg, weights=w,
                                      fused_src=(anchor, thetas))
    op = rr.RingSyncOp(pgs, cfg=cfg, weights=w,
                       fused_src=(anchor, thetas))
    np.testing.assert_array_equal(np.asarray(one),
                                  np.asarray(op.finish()))


def test_stepped_ring_finish_drains_partial(rng):
    """finish() after a few step()s equals finish() with none."""
    xs = jnp.asarray(rng.normal(size=(4, 515)), jnp.float32)
    cfg = rr.RingConfig(quant="int8")
    a = rr.RingSyncOp(xs, cfg=cfg)
    for _ in range(3):
        a.step()
    b = rr.RingSyncOp(xs, cfg=cfg)
    np.testing.assert_array_equal(np.asarray(a.finish()),
                                  np.asarray(b.finish()))


def test_stepped_ring_restart_matches_fresh_weights(rng):
    """The torn-overlap fallback re-reduces the RETAINED inputs under
    new weights, bit-identical to a fresh synchronous reduction."""
    xs = jnp.asarray(rng.normal(size=(4, 700)), jnp.float32)
    cfg = rr.RingConfig(quant="int8")
    op = rr.RingSyncOp(xs, cfg=cfg)
    for _ in range(4):                 # partially reduced, then torn
        op.step()
    w = jnp.asarray([1.0, 1.0, 0.0, 1.0], jnp.float32)
    got = op.restart(w)
    want = rr.simulate_ring_all_reduce(xs, cfg=cfg, weights=w)
    np.testing.assert_array_equal(np.asarray(got), np.asarray(want))


def test_stepped_ring_k1_degenerate():
    xs = jnp.asarray([[1.0, 2.0, 3.0]], jnp.float32)
    op = rr.RingSyncOp(xs)
    assert op.hops_total == 0 and not op.step()
    np.testing.assert_array_equal(np.asarray(op.finish()),
                                  np.asarray(xs))


# -- begin / finish outer sync ------------------------------------------------


def _stacked(rng, k=4, n=515):
    p0 = {"w": jnp.asarray(rng.normal(size=(n,)), jnp.float32),
          "b": jnp.asarray(rng.normal(size=(9,)), jnp.float32)}
    stacked = jax.tree.map(
        lambda a: jnp.stack([a * (1 + 0.01 * i) for i in range(k)]), p0)
    return p0, stacked


@pytest.mark.parametrize("quant", ["fp32", "int8", "int4"])
def test_begin_finish_equals_outer_sync_sim(quant, rng):
    p0, stacked = _stacked(rng)
    cfg = dl.DiLoCoConfig(quant=quant, sync_buckets=2)
    st = dl.init_outer_state_sim(p0, cfg, 4)
    want_p, want_st = dl.outer_sync_sim(stacked, st, cfg)
    h = dl.begin_outer_sync_sim(stacked, st, cfg)
    while h.step():                    # interleave-style stepping
        pass
    got_p, got_st = dl.finish_outer_sync_sim(h, stacked, st)
    np.testing.assert_array_equal(np.asarray(want_p["w"]),
                                  np.asarray(got_p["w"]))
    np.testing.assert_array_equal(np.asarray(want_st.anchor_flat),
                                  np.asarray(got_st.anchor_flat))
    assert int(got_st.outer_step) == 1


def test_resync_equals_direct_weighted_sync(rng):
    """Fallback after a death == a synchronous sync with the dead
    worker's weight zeroed, bit-for-bit."""
    p0, stacked = _stacked(rng)
    cfg = dl.DiLoCoConfig(quant="int8")
    st = dl.init_outer_state_sim(p0, cfg, 4)
    h = dl.begin_outer_sync_sim(stacked, st, cfg)
    for _ in range(3):
        h.step()                       # mid-overlap when the death hits
    w = jnp.asarray([1.0, 0.0, 1.0, 1.0], jnp.float32)
    got_p, got_st = dl.resync_outer_sim(h, stacked, st, w)
    want_p, want_st = dl.outer_sync_sim(stacked, st, cfg, weights=w)
    np.testing.assert_array_equal(np.asarray(want_p["w"]),
                                  np.asarray(got_p["w"]))
    np.testing.assert_array_equal(np.asarray(want_st.anchor_flat),
                                  np.asarray(got_st.anchor_flat))


def test_delayed_apply_roots_at_begin_time_snapshot(rng):
    """The trainer's boundary order is begin-new -> finish-old, so a
    handle finishes AFTER the anchor absorbed the previous boundary's
    delta. The delayed apply deliberately lands each delta on the
    anchor SNAPSHOT its pseudo-gradients are rooted at (zero
    base-mismatch — the synchronous DiLoCo rule per lineage; applying
    to the moved tip instead compounds same-rooted progress under the
    outer momentum and measurably overshoots, see
    finish_outer_sync_sim). Momentum threads SEQUENTIALLY through
    every apply, mixing the two interleaved lineages."""
    p0, stacked_a = _stacked(rng)
    stacked_b = jax.tree.map(lambda x: x * 1.02, stacked_a)
    cfg = dl.DiLoCoConfig(quant="int8")
    st0 = dl.init_outer_state_sim(p0, cfg, 4)

    h0 = dl.begin_outer_sync_sim(stacked_a, st0, cfg)
    # next boundary: the NEW sync begins against the pre-apply anchor…
    h1 = dl.begin_outer_sync_sim(stacked_b, st0, cfg)
    # …then the old one finishes and the tip moves to T1
    _, st1 = dl.finish_outer_sync_sim(h0, stacked_b, st0)
    # final boundary: h1's delta lands on ITS root (A0), with the
    # momentum state as of the finish (threaded through T1's apply)
    _, st2 = dl.finish_outer_sync_sim(h1, stacked_b, st1)

    from repro.core.ring_reduce import simulate_ring_all_reduce
    from repro.core.sync_engine import SyncEngine
    eng = SyncEngine.for_tree(p0)
    p_flats = jax.vmap(eng.flatten)(stacked_b)
    pgs1 = st0.anchor_flat[None, :] - p_flats
    red1 = simulate_ring_all_reduce(
        pgs1, cfg=cfg.ring,
        fused_src=(st0.anchor_flat, p_flats))[0]
    want_a2, want_m2 = cfg.outer_opt.update_flat(
        red1, eng.flatten(st1.opt.momentum), st0.anchor_flat)
    np.testing.assert_array_equal(np.asarray(st2.anchor_flat),
                                  np.asarray(want_a2))
    np.testing.assert_array_equal(
        np.asarray(eng.flatten(st2.opt.momentum)), np.asarray(want_m2))
    # both lineages moved and the flat/tree anchor views agree
    assert not np.array_equal(np.asarray(st1.anchor_flat),
                              np.asarray(st0.anchor_flat))
    assert not np.array_equal(np.asarray(st2.anchor_flat),
                              np.asarray(st1.anchor_flat))
    np.testing.assert_array_equal(
        np.asarray(st2.anchor_flat),
        np.asarray(eng.flatten(st2.anchor)))


def test_delayed_ef_two_slot_shapes(rng):
    """EF + delayed overlap allocates one residual slot per interleaved
    anchor lineage: (2, n) distributed, (2, k, n) sim."""
    p0, _ = _stacked(rng)
    cfg = dl.DiLoCoConfig(quant="int8", error_feedback=True,
                          overlap="delayed")
    n = sum(l.size for l in jax.tree.leaves(p0))
    assert dl.init_outer_state(p0, cfg).residual.shape == (2, n)
    assert dl.init_outer_state_sim(p0, cfg, 4).residual.shape == \
        (2, 4, n)
    # overlap='none' keeps the single-slot layout bit-for-bit
    cfg0 = dl.DiLoCoConfig(quant="int8", error_feedback=True)
    assert dl.init_outer_state_sim(p0, cfg0, 4).residual.shape == (4, n)


def test_delayed_ef_commits_in_order(rng):
    """The PR-5 rejection, now the acceptance test: under the trainer's
    begin-new -> finish-old boundary order, every begin must read the
    residual committed by the SAME lineage's previous boundary (t-2) —
    and a finish must never clobber the other lineage's residual with
    its begin-time snapshot."""
    from repro.core.sync_engine import SyncEngine

    p0, stacked_a = _stacked(rng, k=3)
    stacked_b = jax.tree.map(lambda x: x * 1.03, stacked_a)
    stacked_c = jax.tree.map(lambda x: x * 0.97, stacked_a)
    stacked_d = jax.tree.map(lambda x: x * 1.01, stacked_a)
    cfg = dl.DiLoCoConfig(quant="int8", error_feedback=True,
                          overlap="delayed")
    st0 = dl.init_outer_state_sim(p0, cfg, 3)
    eng = SyncEngine.for_tree(p0)
    raw = lambda st, stacked: st.anchor_flat[None, :] - \
        jax.vmap(eng.flatten)(stacked)
    rt = jax.vmap(lambda x: dl._ef_roundtrip(x, cfg))

    def expect(raw_pgs, read_res):
        pre = raw_pgs + read_res
        return pre - rt(pre)

    # boundary 0: begin against zero residual
    h0 = dl.begin_outer_sync_sim(stacked_a, st0, cfg, ef_slot=0)
    r0 = expect(raw(st0, stacked_a), 0.0)
    np.testing.assert_array_equal(np.asarray(h0.new_residuals),
                                  np.asarray(r0))
    # boundary 1: begin BEFORE finish_0 lands (trainer order) — its
    # lineage (slot 1) is still zero
    h1 = dl.begin_outer_sync_sim(stacked_b, st0, cfg, ef_slot=1)
    r1 = expect(raw(st0, stacked_b), 0.0)
    _, st1 = dl.finish_outer_sync_sim(h0, stacked_b, st0)
    np.testing.assert_array_equal(np.asarray(st1.residual[0]),
                                  np.asarray(r0))
    # boundary 2: slot 0 must read r0 (committed by finish_0)
    h2 = dl.begin_outer_sync_sim(stacked_c, st1, cfg, ef_slot=0)
    r2 = expect(raw(st1, stacked_c), r0)
    np.testing.assert_array_equal(np.asarray(h2.new_residuals),
                                  np.asarray(r2))
    _, st2 = dl.finish_outer_sync_sim(h1, stacked_c, st1)
    # the in-order-commit property: finish_1 (whose begin snapshotted
    # st0, where slot 0 was zero) must NOT wipe slot 0's r0
    np.testing.assert_array_equal(np.asarray(st2.residual[0]),
                                  np.asarray(r0))
    np.testing.assert_array_equal(np.asarray(st2.residual[1]),
                                  np.asarray(r1))
    # boundary 3: slot 1 reads r1
    h3 = dl.begin_outer_sync_sim(stacked_d, st2, cfg, ef_slot=1)
    r3 = expect(raw(st2, stacked_d), r1)
    np.testing.assert_array_equal(np.asarray(h3.new_residuals),
                                  np.asarray(r3))
    _, st3 = dl.finish_outer_sync_sim(h2, stacked_d, st2)
    np.testing.assert_array_equal(np.asarray(st3.residual[0]),
                                  np.asarray(r2))
    np.testing.assert_array_equal(np.asarray(st3.residual[1]),
                                  np.asarray(r1))
    # torn-overlap fallback commits through the same slot merge: the
    # resync of a slot-0 handle must preserve slot 1's fresh r3
    h4 = dl.begin_outer_sync_sim(stacked_a, st3, cfg, ef_slot=0)
    _, st4 = dl.finish_outer_sync_sim(h3, stacked_a, st3)
    w = jnp.asarray([1.0, 0.0, 1.0], jnp.float32)
    _, st5 = dl.resync_outer_sim(h4, stacked_a, st4, w)
    np.testing.assert_array_equal(np.asarray(st5.residual[1]),
                                  np.asarray(r3))


# -- elastic trainer: chunked inner phase + delayed application ---------------


def _trainer(overlap, chunks, events=(), inner=3, workers=3,
             max_workers=4, ef=False):
    from repro.configs import CONFIGS
    from repro.data.pipeline import DataConfig
    from repro.models.registry import get_model
    from repro.train.loop import ElasticTrainer, TrainerConfig

    cfg = CONFIGS["mamba2-130m"].reduced()
    model = get_model(cfg)
    params, _ = model.init(jax.random.PRNGKey(0))
    dcfg = DataConfig(vocab=cfg.vocab, seq_len=32, batch_per_worker=2,
                      total_steps=inner * 16)
    tcfg = TrainerConfig(
        diloco=dl.DiLoCoConfig(inner_steps=inner, quant="int8",
                               overlap=overlap, error_feedback=ef),
        inner_lr=3e-3, max_workers=max_workers, inner_chunks=chunks)
    return ElasticTrainer(model, tcfg, dcfg, params,
                          ClusterSimulator(list(range(workers)),
                                           events=list(events)))


def test_chunked_inner_phase_bit_matches_monolithic():
    """Chunking only moves the jit boundary: the loss trajectory and
    the final anchor are bit-identical to the single-scan phase."""
    a = _trainer("none", 1)
    b = _trainer("none", 3)
    ha = a.run(3)
    hb = b.run(3)
    assert [h["loss"] for h in ha] == [h["loss"] for h in hb]
    np.testing.assert_array_equal(np.asarray(a.outer.anchor_flat),
                                  np.asarray(b.outer.anchor_flat))


def test_delayed_one_step_with_drain_equals_sync():
    """Run 1 outer step: the delayed schedule begins the sync at the
    boundary and the end-of-run drain applies it — the SAME reduction
    of the SAME phase-0 pseudo-gradients the synchronous schedule
    applies at that boundary. Anchors must match bit-for-bit."""
    a = _trainer("none", 1)
    b = _trainer("delayed", 3)
    a.run(1)
    b.run(1)
    np.testing.assert_array_equal(np.asarray(a.outer.anchor_flat),
                                  np.asarray(b.outer.anchor_flat))
    assert int(b.outer.outer_step) == 1


def test_delayed_trains_and_hides_comm():
    tr = _trainer("delayed", 8, inner=8)
    hist = tr.run(4)
    losses = [h["loss"] for h in hist]
    assert all(np.isfinite(l) for l in losses)
    assert losses[-1] < losses[0]
    # every boundary-closed window fully hid the ring (chunks >= hops);
    # only the end-of-run drain is exposed
    steady = tr.comm_ledger.records[:-1]
    assert steady and all(r["hidden_frac"] > 0.99 for r in steady)
    assert tr.comm_ledger.records[-1]["hidden_frac"] < 0.01
    assert all(h["overlap"]["hops"] == 2 * (tr.k - 1) for h in hist)


def test_delayed_ef_trainer_first_step_equals_sync_ef():
    """With zero initial residuals, one delayed outer step (+drain)
    reduces the same EF-rewritten phase-0 pseudo-gradients the
    synchronous EF schedule does — anchors match bit-for-bit."""
    a = _trainer("none", 1, ef=True)
    b = _trainer("delayed", 3, ef=True)
    a.run(1)
    b.run(1)
    np.testing.assert_array_equal(np.asarray(a.outer.anchor_flat),
                                  np.asarray(b.outer.anchor_flat))


def test_delayed_ef_trainer_alternates_slots_across_runs():
    """EF + delayed overlap trains end-to-end: the two residual
    lineages both accumulate, and the begin counter keeps alternating
    across run() calls (a second run must not re-read slot 0 twice)."""
    tr = _trainer("delayed", 4, ef=True, inner=4)
    hist = tr.run(3)
    assert all(np.isfinite(h["loss"]) for h in hist)
    res = np.asarray(tr.outer.residual)
    assert res.shape[0] == 2
    assert np.abs(res[0]).max() > 0 and np.abs(res[1]).max() > 0
    assert tr._ef_begins == 3
    hist = tr.run(2)
    assert tr._ef_begins == 5
    assert all(np.isfinite(h["loss"]) for h in hist)


def test_worker_death_mid_overlap_falls_back_bit_consistently():
    """A participant crashes while its reduction is on the wire: the
    trainer must discard the torn partial state, re-reduce the retained
    pseudo-gradients over the survivors, and keep training. Two
    identical runs land bit-identical anchors (deterministic
    recovery)."""
    ev = [NodeEvent(2, EventKind.CRASH, 1)]
    a = _trainer("delayed", 4, events=ev)
    ha = a.run(4)
    fallbacks = [h["sync_fallback"] for h in ha if "sync_fallback" in h]
    assert len(fallbacks) == 1
    assert fallbacks[0]["torn_by"] == [1]
    assert fallbacks[0]["ledger"]["torn"] is True
    assert all(np.isfinite(h["loss"]) for h in ha)
    b = _trainer("delayed", 4, events=ev)
    b.run(4)
    np.testing.assert_array_equal(np.asarray(a.outer.anchor_flat),
                                  np.asarray(b.outer.anchor_flat))


def test_nonparticipant_death_does_not_tear():
    """A node that joined AFTER the in-flight sync began (zero weight,
    not a participant) dying must not trigger the fallback."""
    ev = [NodeEvent(1, EventKind.JOIN, 9),
          NodeEvent(2, EventKind.CRASH, 9)]
    tr = _trainer("delayed", 4, events=ev)
    hist = tr.run(4)
    assert not any("sync_fallback" in h for h in hist)
    assert all(np.isfinite(h["loss"]) for h in hist)


# -- ClusterSimulator in-flight sync ------------------------------------------


def test_simulator_reports_torn_sync():
    sim = ClusterSimulator([0, 1, 2], events=[
        NodeEvent(1, EventKind.CRASH, 1),
        NodeEvent(2, EventKind.LEAVE, 2)])
    sim.begin_outer_step(0)
    sim.note_sync_begin(0, [0, 1])          # node 2 not a participant
    plan = sim.begin_outer_step(1)          # node 1 crashes -> evicted
    assert plan["sync_torn"] == [1]
    sim.note_sync_end()
    plan = sim.begin_outer_step(2)          # node 2 leaves, no sync
    assert plan["sync_torn"] == []


# -- CommOverlapLedger --------------------------------------------------------


def test_ledger_fully_hidden_when_compute_covers_comm():
    led = CommOverlapLedger()
    led.begin_sync(hop_seconds=1.0)
    for _ in range(4):
        led.dispatch_hop()
        led.compute(2.0)                   # each hop drains in-window
    rec = led.finish_sync()
    assert rec["comm_total_s"] == 4.0
    assert rec["comm_hidden_s"] == pytest.approx(4.0)
    assert led.hidden_fraction == pytest.approx(1.0)


def test_ledger_fully_exposed_without_compute():
    led = CommOverlapLedger()
    led.begin_sync(hop_seconds=1.0)
    led.dispatch_hop(3)
    rec = led.finish_sync()
    assert rec["comm_exposed_s"] == pytest.approx(3.0)
    assert rec["hidden_frac"] == pytest.approx(0.0)


def test_ledger_per_hop_seconds_override():
    """Bandwidth-honest charging: hops may carry different wire times
    (live hops at the bottleneck-link rate, dead-slot hops 0 s) — the
    window total is the SUM of what was actually charged, not
    hops * hop_seconds."""
    led = CommOverlapLedger()
    led.begin_sync(hop_seconds=1.0)
    led.dispatch_hop()                      # default: 1.0 s
    led.dispatch_hop(seconds=2.5)           # slow link
    led.compute(3.0)                        # hides what is in flight
    led.dispatch_hop(2, seconds=0.0)        # dead-slot hops: free
    rec = led.finish_sync()
    assert rec["hops"] == 4
    assert rec["comm_total_s"] == pytest.approx(3.5)
    assert rec["comm_hidden_s"] == pytest.approx(3.0)
    assert rec["comm_exposed_s"] == pytest.approx(0.5)


def test_ledger_uneven_bucket_charges():
    """Per-hop charges that don't divide the total evenly (the int8
    codebook sideband makes hop bytes a non-round number) must sum
    exactly — no residual from a uniform total/hops split."""
    led = CommOverlapLedger()
    charges = [0.7, 0.7, 0.7, 1.3, 1.3, 1.3]   # 6 hops, total 6.0
    led.begin_sync(hop_seconds=999.0)          # default must be unused
    for c in charges:
        led.dispatch_hop(seconds=c)
    rec = led.finish_sync()
    assert rec["hops"] == len(charges)
    assert rec["comm_total_s"] == pytest.approx(sum(charges))
    # tear_sync still prices the resync at the window's default rate
    led.begin_sync(hop_seconds=0.5)
    led.dispatch_hop(seconds=0.1)
    rec = led.tear_sync(resync_hops=4)
    assert rec["comm_total_s"] == pytest.approx(2.0)


def test_ledger_partial_and_tear():
    led = CommOverlapLedger()
    led.begin_sync(hop_seconds=2.0)
    led.dispatch_hop(2)                    # 4 s of comm
    led.compute(1.0)                       # only 1 s hidden
    rec = led.finish_sync()
    assert rec["comm_hidden_s"] == pytest.approx(1.0)
    assert rec["comm_exposed_s"] == pytest.approx(3.0)
    led.begin_sync(hop_seconds=0.5)
    led.dispatch_hop()
    rec = led.tear_sync(resync_hops=6)     # full ring re-run, exposed
    assert rec["torn"] and rec["comm_exposed_s"] == pytest.approx(3.0)
    assert rec["comm_hidden_s"] == 0.0
