"""Per-architecture smoke tests (REDUCED same-family configs, as
assigned): one forward/train step on CPU asserting output shapes and
finiteness, plus decode-vs-teacher-forcing consistency."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ASSIGNED, CONFIGS
from repro.configs.base import ShapeConfig
from repro.models.registry import get_model

KEY = jax.random.PRNGKey(0)


def _batch(model, cfg, shape, key=KEY):
    specs = model.input_specs(shape)
    out = {}
    for k, v in specs.items():
        if v.dtype == jnp.int32:
            out[k] = jax.random.randint(key, v.shape, 0, cfg.vocab)
        elif k == "mask":
            out[k] = jnp.ones(v.shape, v.dtype)
        else:
            out[k] = jax.random.normal(key, v.shape, jnp.float32
                                       ).astype(v.dtype) * 0.02
    return out


@pytest.mark.parametrize("arch", list(ASSIGNED) + ["intellect-1"])
def test_smoke_train_step(arch):
    cfg = CONFIGS[arch].reduced()
    model = get_model(cfg)
    params, axes = model.init(KEY)
    assert jax.tree.structure(params) == jax.tree.structure(
        axes, is_leaf=lambda x: isinstance(x, tuple))
    shape = ShapeConfig("t", "train", 64, 2)
    batch = _batch(model, cfg, shape)

    def step(p, b):
        loss, metrics = model.loss(p, b)
        g = jax.grad(lambda pp: model.loss(pp, b)[0])(p)
        return loss, metrics, g

    loss, metrics, g = jax.jit(step)(params, batch)
    assert np.isfinite(float(loss))
    assert float(loss) > 0
    # gradients exist, are finite, and at least most are nonzero
    leaves = jax.tree.leaves(g)
    assert all(np.isfinite(np.asarray(l, np.float32)).all()
               for l in leaves)
    nonzero = sum(float(jnp.abs(l).sum()) > 0 for l in leaves)
    assert nonzero >= 0.8 * len(leaves)


@pytest.mark.parametrize("arch", list(ASSIGNED))
def test_smoke_decode_shapes(arch):
    cfg = CONFIGS[arch].reduced()
    model = get_model(cfg)
    params, _ = model.init(KEY)
    pshape = ShapeConfig("p", "prefill", 32, 2)
    batch = _batch(model, cfg, pshape)
    cache = model.init_cache(2, pshape)
    logits, cache = jax.jit(
        lambda p, b, c: model.prefill(p, b, c))(params, batch, cache)
    assert logits.shape == (2, cfg.padded_vocab)
    tok = jnp.argmax(logits, -1)[:, None].astype(jnp.int32)
    logits2, cache = jax.jit(
        lambda p, t, c: model.decode(p, t, c))(params, tok, cache)
    assert logits2.shape == (2, cfg.padded_vocab)
    assert np.isfinite(np.asarray(logits2, np.float32)).all()


@pytest.mark.parametrize("arch", ["internlm2-1.8b", "granite-3-2b",
                                  "mamba2-130m", "zamba2-2.7b",
                                  "deepseek-moe-16b"])
def test_decode_matches_teacher_forcing(arch):
    """prefill(t_0..t_{n-1}) then decode(t_n) must equal the full
    forward at position n (KV-cache correctness)."""
    cfg = CONFIGS[arch].reduced()
    model = get_model(cfg)
    params, _ = model.init(KEY)
    n = 12
    tokens = jax.random.randint(jax.random.PRNGKey(1), (2, n + 1), 0,
                                cfg.vocab)
    shape = ShapeConfig("p", "prefill", 32, 2)
    cache = model.init_cache(2, shape)
    _, cache = model.prefill(params, {"tokens": tokens[:, :n]}, cache)
    logits_dec, _ = model.decode(params, tokens[:, n:n + 1], cache)

    full = {"tokens": tokens, "targets": tokens, "mask":
            jnp.ones((2, n + 1), jnp.float32)}
    if cfg.family in ("dense", "moe", "vlm"):
        from repro.models import transformer
        logits_full, _ = transformer.forward(cfg, params,
                                             tokens)
    else:
        from repro.models import hybrid
        logits_full, _ = hybrid.forward(cfg, params, tokens)
    np.testing.assert_allclose(
        np.asarray(logits_dec, np.float32),
        np.asarray(logits_full[:, n], np.float32), rtol=2e-2, atol=2e-2)


def test_swa_masks_long_range():
    """Sliding-window attention must ignore tokens beyond the window."""
    cfg = CONFIGS["h2o-danube-1.8b"].reduced()  # window 32
    model = get_model(cfg)
    params, _ = model.init(KEY)
    key = jax.random.PRNGKey(3)
    n = 80
    t1 = jax.random.randint(key, (1, n), 0, cfg.vocab)
    # change tokens far outside the window of the last position
    t2 = t1.at[:, :8].set((t1[:, :8] + 7) % cfg.vocab)
    from repro.models import transformer
    l1, _ = transformer.forward(cfg, params, t1)
    l2, _ = transformer.forward(cfg, params, t2)
    np.testing.assert_allclose(np.asarray(l1[:, -1], np.float32),
                               np.asarray(l2[:, -1], np.float32),
                               rtol=1e-4, atol=1e-4)


def test_vlm_frontend_changes_logits():
    cfg = CONFIGS["phi-3-vision-4.2b"].reduced()
    model = get_model(cfg)
    params, _ = model.init(KEY)
    shape = ShapeConfig("t", "train", 64, 2)
    b1 = _batch(model, cfg, shape)
    b2 = dict(b1, frontend=b1["frontend"] + 1.0)
    l1, _ = model.loss(params, b1)
    l2, _ = model.loss(params, b2)
    assert abs(float(l1) - float(l2)) > 1e-6


def test_moe_load_balance_aux_present():
    cfg = CONFIGS["deepseek-moe-16b"].reduced()
    model = get_model(cfg)
    params, _ = model.init(KEY)
    shape = ShapeConfig("t", "train", 64, 2)
    loss, metrics = model.loss(params, _batch(model, cfg, shape))
    assert "lb_loss" in metrics
    assert float(metrics["lb_loss"]) > 0


def test_max_z_loss_weight():
    """max-z aux (paper: weight 2e-4) contributes to the total loss."""
    from repro.models.common import cross_entropy_max_z
    logits = jnp.asarray(np.random.default_rng(0).normal(
        size=(4, 8, 32)) * 5, jnp.float32)
    targets = jnp.zeros((4, 8), jnp.int32)
    loss_z, m = cross_entropy_max_z(logits, targets, z_weight=2e-4)
    loss_0, _ = cross_entropy_max_z(logits, targets, z_weight=0.0)
    assert float(loss_z) > float(loss_0)
    assert float(m["z"]) > 0


@pytest.mark.parametrize("arch", list(ASSIGNED))
def test_param_counts_match_analytics(arch):
    from repro.models import common
    cfg = CONFIGS[arch]
    model = get_model(cfg)
    shapes, _ = common.eval_axes(model.init, KEY)
    actual = sum(l.size for l in jax.tree.leaves(shapes))
    assert abs(actual - cfg.param_count()) / actual < 1e-3


def test_long_500k_applicability():
    from repro.configs import SHAPES
    long = SHAPES["long_500k"]
    runs = {a for a in ASSIGNED if CONFIGS[a].supports(long)}
    assert runs == {"h2o-danube-1.8b", "zamba2-2.7b", "mamba2-130m"}
