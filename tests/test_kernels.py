"""Pallas int8 quantization kernels vs the pure-jnp oracle:
shape/dtype sweeps + hypothesis property tests of the paper's scheme."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypo_compat import given, settings, st

from repro.kernels import int8_quant, ops, ref

SHAPES = [(16,), (1000,), (128, 128), (257, 130), (8, 4, 33),
          (3, 5, 7, 11), (65537,)]
DTYPES = [jnp.float32, jnp.bfloat16]


@pytest.mark.parametrize("shape", SHAPES)
@pytest.mark.parametrize("dtype", DTYPES)
def test_encode_matches_ref(shape, dtype, rng):
    x = jnp.asarray(rng.normal(1.5, 2.0, size=shape), dtype)
    qr = ref.quantize(x)
    qp = ops.quantize(x, impl="pallas")
    np.testing.assert_array_equal(np.asarray(qr.codes),
                                  np.asarray(qp.codes))
    np.testing.assert_allclose(np.asarray(qr.codebook),
                               np.asarray(qp.codebook),
                               rtol=2e-5, atol=2e-5)


@pytest.mark.parametrize("shape", SHAPES)
def test_decode_matches_ref(shape, rng):
    x = jnp.asarray(rng.normal(size=shape), jnp.float32)
    q = ref.quantize(x)
    dr = ref.dequantize(q)
    dp = ops.dequantize(q, impl="pallas")
    np.testing.assert_allclose(np.asarray(dr), np.asarray(dp),
                               rtol=1e-5, atol=1e-5)


def test_fused_pseudograd(rng):
    a = jnp.asarray(rng.normal(size=(300, 40)), jnp.float32)
    t = jnp.asarray(rng.normal(size=(300, 40)), jnp.float32)
    qf = ops.quantize_pseudograd(a, t, impl="pallas")
    qr = ref.quantize_pseudograd(a, t)
    np.testing.assert_array_equal(np.asarray(qf.codes),
                                  np.asarray(qr.codes))


def test_decode_add_fused(rng):
    x = jnp.asarray(rng.normal(size=(1000,)), jnp.float32)
    acc = jnp.asarray(rng.normal(size=(1000,)), jnp.float32)
    q = ref.quantize(x)
    fused = ops.dequantize_add(q, acc, impl="pallas")
    np.testing.assert_allclose(np.asarray(fused),
                               np.asarray(acc + ref.dequantize(q)),
                               rtol=1e-5, atol=1e-5)


# -- paper-scheme properties ---------------------------------------------------


@settings(deadline=None, max_examples=30)
@given(st.integers(10, 4000), st.floats(-5, 5), st.floats(0.01, 10),
       st.integers(0, 2**31 - 1))
def test_roundtrip_error_bounded_by_bucket_width(n, mu, sigma, seed):
    """Inside the 6-sigma clip range, |x - deq(q(x))| <= bucket width
    (bucket means can sit anywhere inside the bucket)."""
    r = np.random.default_rng(seed)
    x = jnp.asarray(r.normal(mu, sigma, size=n), jnp.float32)
    lo, width = ref.quant_params(x)
    q = ref.quantize(x)
    deq = ref.dequantize(q)
    hi = lo + ref.NUM_BUCKETS * width
    inside = (x >= lo) & (x < hi)
    err = jnp.abs(deq - x)
    assert float(jnp.max(jnp.where(inside, err, 0.0))) <= \
        float(width) + 1e-6


@settings(deadline=None, max_examples=30)
@given(st.integers(2, 500), st.integers(0, 2**31 - 1))
def test_codebook_values_inside_buckets(n, seed):
    r = np.random.default_rng(seed)
    x = jnp.asarray(r.normal(size=n) * r.uniform(0.1, 4), jnp.float32)
    lo, width = ref.quant_params(x)
    q = ref.quantize(x)
    edges_lo = lo + jnp.arange(ref.NUM_BUCKETS) * width
    # each codebook entry lies within (or at the edge of) its bucket:
    # bucket means for non-empty buckets, midpoints for empty ones.
    # clipped values can drag edge-bucket means outside -> allow the
    # clip overflow there.
    inner = slice(1, ref.NUM_BUCKETS - 1)
    cb = q.codebook[inner]
    assert bool(jnp.all(cb >= edges_lo[inner] - 1e-5))
    assert bool(jnp.all(cb <= edges_lo[inner] + width + 1e-5))


@settings(deadline=None, max_examples=20)
@given(st.integers(2, 300), st.integers(0, 2**31 - 1))
def test_quantize_is_deterministic_and_uint8(n, seed):
    r = np.random.default_rng(seed)
    x = jnp.asarray(r.normal(size=n), jnp.float32)
    q1, q2 = ref.quantize(x), ref.quantize(x)
    assert q1.codes.dtype == jnp.uint8
    np.testing.assert_array_equal(np.asarray(q1.codes),
                                  np.asarray(q2.codes))


def test_constant_tensor_roundtrips_exactly():
    x = jnp.full((100,), 3.25, jnp.float32)
    q = ref.quantize(x)
    np.testing.assert_allclose(np.asarray(ref.dequantize(q)),
                               np.asarray(x), atol=1e-6)


def test_wire_bytes_accounting():
    x = jnp.zeros((1000,), jnp.float32)
    q = ref.quantize(x)
    assert q.wire_bytes == 1000 + 4 * 256  # 1 B/elem + codebook
