"""Optional-`hypothesis` shim for the property tests.

The container this repo is developed in does not ship `hypothesis`
(and the no-new-deps rule forbids installing it). Property tests
import `given`/`settings`/`st` from here: with hypothesis installed
(e.g. in CI) they run as real property tests; without it they are
skipped instead of breaking collection for the whole module.
"""
from __future__ import annotations

try:
    import hypothesis  # noqa: F401
    import hypothesis.strategies as st
    from hypothesis import given, settings

    HAVE_HYPOTHESIS = True
except ImportError:  # pragma: no cover - depends on environment
    import pytest

    HAVE_HYPOTHESIS = False

    def given(*_args, **_kwargs):
        def deco(fn):
            return pytest.mark.skip(
                reason="hypothesis not installed")(fn)

        return deco

    def settings(*_args, **_kwargs):
        def deco(fn):
            return fn

        return deco

    class _Strategies:
        """Stand-in for hypothesis.strategies: every strategy factory
        returns None (the tests are skipped before it matters)."""

        def __getattr__(self, _name):
            return lambda *a, **k: None

    st = _Strategies()
