"""Wave-batched serving engine over the model zoo."""
import jax
import numpy as np
import pytest

from repro.configs import CONFIGS
from repro.models.registry import get_model
from repro.serving.engine import Request, ServeEngine


@pytest.fixture(scope="module")
def setup():
    cfg = CONFIGS["mamba2-130m"].reduced()
    model = get_model(cfg)
    params, _ = model.init(jax.random.PRNGKey(0))
    return cfg, model, params


def test_engine_drains_all_requests(setup):
    cfg, model, params = setup
    engine = ServeEngine(model, params, batch_slots=3, max_len=128)
    rng = np.random.default_rng(0)
    reqs = [Request(i, rng.integers(2, cfg.vocab, size=8).astype(
        np.int32), max_new_tokens=5) for i in range(7)]
    for r in reqs:
        engine.submit(r)
    engine.run_until_drained()
    assert all(r.done for r in reqs)
    assert all(1 <= len(r.out_tokens) <= 5 for r in reqs)
    assert engine.stats["waves"] >= 3     # 7 requests / 3 slots


def test_engine_greedy_matches_manual_decode(setup):
    """Engine output == manual prefill+decode loop (same greedy path)."""
    cfg, model, params = setup
    from repro.configs.base import ShapeConfig
    prompt = np.arange(2, 10).astype(np.int32)
    engine = ServeEngine(model, params, batch_slots=1, max_len=64)
    req = Request(0, prompt, max_new_tokens=4)
    engine.submit(req)
    engine.run_until_drained()

    shape = ShapeConfig("m", "decode", 64, 1)
    cache = model.init_cache(1, shape)
    logits, cache = model.prefill(params, {"tokens": prompt[None]},
                                  cache)
    toks = [int(np.argmax(np.asarray(logits[0])))]
    for _ in range(3):
        logits, cache = model.decode(
            params, np.asarray([[toks[-1]]], np.int32), cache)
        toks.append(int(np.argmax(np.asarray(logits[0]))))
    assert req.out_tokens == toks


def test_varied_prompt_lengths_left_padded(setup):
    cfg, model, params = setup
    engine = ServeEngine(model, params, batch_slots=2, max_len=64)
    rng = np.random.default_rng(1)
    a = Request(0, rng.integers(2, cfg.vocab, size=4).astype(np.int32),
                max_new_tokens=3)
    b = Request(1, rng.integers(2, cfg.vocab, size=9).astype(np.int32),
                max_new_tokens=3)
    engine.submit(a)
    engine.submit(b)
    engine.run_until_drained()
    assert a.done and b.done
