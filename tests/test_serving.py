"""Serving engines: continuous-batching correctness (slot insert /
retire, bucketed exact prefill, on-device sampling loop) and wave-vs-
continuous greedy bit-equivalence, plus the SWA rolling-cache wrap
boundary in decode_attention."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import CONFIGS
from repro.configs.base import ShapeConfig
from repro.models import attention as attn
from repro.models.registry import get_model
from repro.serving.engine import (ContinuousEngine, Request, WaveEngine,
                                  bucket_len)


@pytest.fixture(scope="module")
def setup():
    cfg = CONFIGS["mamba2-130m"].reduced()
    model = get_model(cfg)
    params, _ = model.init(jax.random.PRNGKey(0))
    return cfg, model, params


def _mixed_requests(cfg, n, seed=0, long_new=17):
    rng = np.random.default_rng(seed)
    reqs = []
    for i in range(n):
        plen = int(rng.integers(20, 45)) if i % 3 == 2 else \
            int(rng.integers(3, 20))
        reqs.append(Request(
            i, (rng.integers(2, cfg.vocab, size=plen)).astype(np.int32),
            max_new_tokens=long_new if i % 3 == 2 else 4))
    return reqs


# -- wave engine (legacy behavior preserved) ----------------------------------


def test_wave_engine_drains_all_requests(setup):
    cfg, model, params = setup
    engine = WaveEngine(model, params, batch_slots=3, max_len=128)
    rng = np.random.default_rng(0)
    reqs = [Request(i, rng.integers(2, cfg.vocab, size=8).astype(
        np.int32), max_new_tokens=5) for i in range(7)]
    for r in reqs:
        engine.submit(r)
    engine.run_until_drained()
    assert all(r.done for r in reqs)
    assert all(1 <= len(r.out_tokens) <= 5 for r in reqs)
    assert engine.stats["waves"] >= 3     # 7 requests / 3 slots


def test_wave_greedy_matches_manual_decode(setup):
    """Engine output == manual prefill+decode loop (same greedy path)."""
    cfg, model, params = setup
    prompt = np.arange(2, 10).astype(np.int32)
    engine = WaveEngine(model, params, batch_slots=1, max_len=64)
    req = Request(0, prompt, max_new_tokens=4)
    engine.submit(req)
    engine.run_until_drained()

    shape = ShapeConfig("m", "decode", 64, 1)
    cache = model.init_cache(1, shape)
    logits, cache = model.prefill(params, {"tokens": prompt[None]},
                                  cache)
    toks = [int(np.argmax(np.asarray(logits[0])))]
    for _ in range(3):
        logits, cache = model.decode(
            params, np.asarray([[toks[-1]]], np.int32), cache)
        toks.append(int(np.argmax(np.asarray(logits[0]))))
    assert req.out_tokens == toks


# -- continuous engine --------------------------------------------------------


def test_continuous_drains_and_reuses_slots(setup):
    cfg, model, params = setup
    engine = ContinuousEngine(model, params, batch_slots=2,
                              max_len=128, decode_chunk=4)
    reqs = _mixed_requests(cfg, 9)
    for r in reqs:
        engine.submit(r)
    engine.run_until_drained()
    assert all(r.done for r in reqs)
    assert all(1 <= len(r.out_tokens) <= r.max_new_tokens
               for r in reqs)
    assert engine.stats["admitted"] == 9        # 9 requests, 2 slots
    # one host sync per CHUNK, not per token
    assert engine.stats["host_syncs"] == engine.stats["decode_chunks"]
    assert engine.stats["tokens_out"] > engine.stats["host_syncs"]


def test_continuous_greedy_matches_manual_decode(setup):
    cfg, model, params = setup
    prompt = np.arange(2, 13).astype(np.int32)
    engine = ContinuousEngine(model, params, batch_slots=3,
                              max_len=64, decode_chunk=5)
    req = Request(0, prompt, max_new_tokens=7)
    engine.submit(req)
    engine.run_until_drained()

    shape = ShapeConfig("m", "decode", 64, 1)
    cache = model.init_cache(1, shape)
    logits, cache = model.prefill(params, {"tokens": prompt[None]},
                                  cache)
    toks = [int(np.argmax(np.asarray(logits[0])))]
    for _ in range(6):
        logits, cache = model.decode(
            params, np.asarray([[toks[-1]]], np.int32), cache)
        toks.append(int(np.argmax(np.asarray(logits[0]))))
    assert req.out_tokens == toks


@pytest.mark.parametrize("arch", ["mamba2-130m", "internlm2-1.8b",
                                  "h2o-danube-1.8b"])
def test_continuous_bit_identical_to_wave_greedy(arch):
    """Acceptance: greedy outputs bit-identical between engines on a
    mixed-length trace (SSM, dense GQA, SWA families)."""
    cfg = CONFIGS[arch].reduced()
    model = get_model(cfg)
    params, _ = model.init(jax.random.PRNGKey(0))
    a = _mixed_requests(cfg, 8)
    b = _mixed_requests(cfg, 8)
    w = WaveEngine(model, params, batch_slots=3, max_len=128)
    c = ContinuousEngine(model, params, batch_slots=3, max_len=128,
                         decode_chunk=5)
    for r in a:
        w.submit(r)
    for r in b:
        c.submit(r)
    w.run_until_drained()
    c.run_until_drained()
    for x, y in zip(a, b):
        assert x.out_tokens == y.out_tokens, x.rid


def test_prefill_widths_are_bucketed(setup):
    cfg, model, params = setup
    engine = ContinuousEngine(model, params, batch_slots=2,
                              max_len=128, decode_chunk=4)
    for r in _mixed_requests(cfg, 12, seed=3):
        engine.submit(r)
    engine.run_until_drained()
    widths = engine.stats["prefill_widths"]
    assert all(w == bucket_len(w) for w in widths)    # powers of two
    assert len(widths) <= 4       # capped recompiles on 3..45 prompts


def test_sampling_deterministic_and_top1_is_greedy(setup):
    cfg, model, params = setup

    def run(seed, temperature, top_k):
        engine = ContinuousEngine(model, params, batch_slots=2,
                                  max_len=64, decode_chunk=4,
                                  top_k=top_k, seed=seed)
        reqs = [Request(i, np.arange(2, 8 + i).astype(np.int32),
                        max_new_tokens=6, temperature=temperature)
                for i in range(4)]
        for r in reqs:
            engine.submit(r)
        engine.run_until_drained()
        return [r.out_tokens for r in reqs]

    assert run(0, 1.0, 0) == run(0, 1.0, 0)       # same rng -> same
    assert run(0, 1.0, 0) != run(1, 1.0, 0)       # different rng
    assert all(0 <= t < cfg.padded_vocab
               for out in run(0, 1.0, 0) for t in out)
    # top_k=1 collapses sampling to argmax == greedy
    assert run(0, 5.0, 1) == run(0, 0.0, 0)


def test_per_slot_seed_reproducible_across_slot_placement(setup):
    """A sampled request's token stream is seeded from (engine seed,
    rid): the SAME request must produce the SAME tokens whether it is
    served alone in slot 0 or admitted mid-stream into a busy engine's
    last free slot next to other sampled traffic."""
    cfg, model, params = setup
    prompt = np.arange(2, 9).astype(np.int32)

    solo_req = Request(7, prompt.copy(), max_new_tokens=6,
                       temperature=0.9)
    solo = ContinuousEngine(model, params, batch_slots=1, max_len=64,
                            decode_chunk=4, seed=3)
    solo.submit(solo_req)
    solo.run_until_drained()

    busy = ContinuousEngine(model, params, batch_slots=3, max_len=64,
                            decode_chunk=4, seed=3)
    for i, t in ((100, 1.3), (101, 0.7)):     # different rids/temps
        busy.submit(Request(i, np.arange(3, 12).astype(np.int32),
                            max_new_tokens=20, temperature=t))
    busy.step()                                # both decode a chunk
    late = Request(7, prompt.copy(), max_new_tokens=6,
                   temperature=0.9)
    busy.submit(late)                          # lands in slot 2
    busy.run_until_drained()
    assert late.out_tokens == solo_req.out_tokens

    # different engine seed -> different stream for the same rid
    other = ContinuousEngine(model, params, batch_slots=1, max_len=64,
                             decode_chunk=4, seed=4)
    req2 = Request(7, prompt.copy(), max_new_tokens=6, temperature=0.9)
    other.submit(req2)
    other.run_until_drained()
    assert req2.out_tokens != solo_req.out_tokens


def test_mid_stream_admission_uses_per_slot_positions(setup):
    """A request admitted while another slot is deep into decode must
    produce the same tokens as when served alone."""
    cfg, model, params = setup
    long_req = Request(0, np.arange(2, 10).astype(np.int32),
                       max_new_tokens=24)
    late_req = Request(1, np.arange(3, 9).astype(np.int32),
                       max_new_tokens=5)

    solo = Request(9, late_req.prompt.copy(), max_new_tokens=5)
    e1 = ContinuousEngine(model, params, batch_slots=1, max_len=64,
                          decode_chunk=4)
    e1.submit(solo)
    e1.run_until_drained()

    e2 = ContinuousEngine(model, params, batch_slots=2, max_len=64,
                          decode_chunk=4)
    e2.submit(long_req)
    e2.step()                      # long_req decodes a chunk alone
    e2.submit(late_req)            # admitted mid-stream
    e2.run_until_drained()
    assert late_req.out_tokens == solo.out_tokens
    assert long_req.done and late_req.done


# -- SWA rolling-cache wrap boundary ------------------------------------------


def _brute_swa_reference(q, written, window, dtype=jnp.float32):
    """Dense attention over the chronological last-`window` tokens."""
    ks = jnp.stack([k for k, _ in written[-window:]], axis=1)
    vs = jnp.stack([v for _, v in written[-window:]], axis=1)
    b = q.shape[0]
    lengths = jnp.full((b,), ks.shape[1], jnp.int32)
    flat = attn.KVCache(ks, vs, lengths)
    return attn.decode_attention(q, flat)


@pytest.mark.parametrize("length", [31, 32, 33, 40])
def test_swa_rolling_wrap_boundary(length):
    """decode_attention on the ring at length == s_max and s_max + 1
    (the wrap boundary) must equal dense attention over the
    chronological window."""
    s_max, window, b, hk, g, dh = 32, 24, 2, 2, 2, 16
    rng = np.random.default_rng(length)
    cache = attn.KVCache.init(b, s_max, hk, dh, jnp.float32)
    written = []
    for _ in range(length):
        k = jnp.asarray(rng.normal(size=(b, 1, hk, dh)), jnp.float32)
        v = jnp.asarray(rng.normal(size=(b, 1, hk, dh)), jnp.float32)
        cache = attn.cache_update(cache, k, v, rolling=True)
        written.append((k[:, 0], v[:, 0]))
    assert int(cache.length[0]) == length
    q = jnp.asarray(rng.normal(size=(b, 1, hk * g, dh)), jnp.float32)
    out = attn.decode_attention(q, cache, window=window)
    ref = _brute_swa_reference(q, written, window)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                               rtol=1e-5, atol=1e-5)


def test_per_slot_lengths_mask_independently():
    """Slots at different lengths in ONE cache must each match their
    own single-slot computation."""
    s_max, b, hk, g, dh = 16, 3, 2, 2, 8
    rng = np.random.default_rng(0)
    k = jnp.asarray(rng.normal(size=(b, s_max, hk, dh)), jnp.float32)
    v = jnp.asarray(rng.normal(size=(b, s_max, hk, dh)), jnp.float32)
    lengths = jnp.asarray([3, 9, 16], jnp.int32)
    q = jnp.asarray(rng.normal(size=(b, 1, hk * g, dh)), jnp.float32)
    out = attn.decode_attention(q, attn.KVCache(k, v, lengths))
    for i in range(b):
        solo = attn.decode_attention(
            q[i:i + 1], attn.KVCache(k[i:i + 1], v[i:i + 1],
                                     lengths[i:i + 1]))
        np.testing.assert_allclose(np.asarray(out[i]),
                                   np.asarray(solo[0]),
                                   rtol=1e-6, atol=1e-6)
