"""AdamW vs a numpy reference; WSD schedule shape (paper: warmup 1000,
stable, anneal final 20%)."""
import jax
import jax.numpy as jnp
import numpy as np

from repro.optim import AdamW, schedules


def test_adamw_matches_numpy_reference(rng):
    opt = AdamW(lr=1e-2, b1=0.9, b2=0.95, eps=1e-8, weight_decay=0.1)
    p = {"w": jnp.asarray(rng.normal(size=(16,)), jnp.float32)}
    st = opt.init(p)
    pw = np.asarray(p["w"], np.float64)
    m = np.zeros(16)
    v = np.zeros(16)
    for t in range(1, 4):
        g = rng.normal(size=(16,)).astype(np.float32)
        m = 0.9 * m + 0.1 * g
        v = 0.95 * v + 0.05 * g * g
        mh = m / (1 - 0.9 ** t)
        vh = v / (1 - 0.95 ** t)
        pw = pw - 1e-2 * (mh / (np.sqrt(vh) + 1e-8) + 0.1 * pw)
        p, st = opt.update({"w": jnp.asarray(g)}, st, p)
    np.testing.assert_allclose(np.asarray(p["w"]), pw, rtol=1e-4,
                               atol=1e-6)


def test_adamw_schedule_callable():
    sched = schedules.wsd(1e-3, warmup_steps=10, total_steps=100)
    opt = AdamW(lr=sched)
    p = {"w": jnp.ones((4,))}
    st = opt.init(p)
    p2, st = opt.update({"w": jnp.ones((4,))}, st, p)
    assert not np.allclose(np.asarray(p2["w"]), np.asarray(p["w"]))


def test_wsd_schedule_phases():
    s = schedules.wsd(1.0, warmup_steps=100, total_steps=1000,
                      decay_fraction=0.2)
    assert float(s(0)) == 0.0
    assert float(s(50)) == 0.5          # linear warmup
    assert float(s(100)) == 1.0
    assert float(s(500)) == 1.0          # stable phase
    assert float(s(799)) == 1.0
    assert float(s(900)) < 1.0           # annealing
    assert float(s(1000)) <= 0.05        # fully decayed
    # monotone decay in the anneal phase
    vals = [float(s(t)) for t in range(800, 1001, 25)]
    assert all(a >= b for a, b in zip(vals, vals[1:]))
