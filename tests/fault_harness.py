"""Deterministic fault-injection harness for the streaming recovery
subsystem.

Everything is derived from one integer seed: the membership schedule
(`NodeEvent`s driving `ClusterSimulator`), which chunks each serving
peer holds (partial replicas), and the peer-level faults (kill N chunks
into a transfer, stall the link, corrupt a frame). Tests replay the
same seed and get the same world, every run.

Pieces:
  * ``seeded_events(seed, ...)`` — a reproducible kill/join/stall
    schedule for ``ClusterSimulator``;
  * ``PeerFleet`` — builds per-node ``ChunkStore``s holding seeded
    subsets of a source store's chunks (union guaranteed complete),
    serves them with ``ChunkPeer``s, and applies fault events
    (CRASH -> ``crash_after`` mid-transfer, STALL -> per-chunk sleep,
    plus direct ``corrupt``/``kill`` knobs for scenario tests);
  * ``FakeStore`` — in-memory stand-in for ``ChunkStore``'s gossip
    surface (inventory/digest/has/latest), for socket-free property
    tests via ``gossip.store_transport``.
"""
from __future__ import annotations

import hashlib
import pathlib

import numpy as np

from repro.checkpointing import ChunkPeer, ChunkStore
from repro.core.fault_tolerance import EventKind, NodeEvent


def seeded_events(seed: int, n_outer: int, joiner_ids,
                  crash_ids, stall_ids, *, poison_ids=(),
                  announce_lead: int = 1) -> list[NodeEvent]:
    """A reproducible membership schedule: every joiner gets an
    ANNOUNCE ``announce_lead`` steps before its JOIN; crashes and
    stalls land at seeded steps. ``poison_ids`` nodes turn adversarial
    at a seeded step and STAY adversarial: a POISON event every step
    from then on, cycling through the corruption modes."""
    from repro.core.validation import POISON_MODES

    rng = np.random.default_rng(seed)
    events: list[NodeEvent] = []
    for nid in joiner_ids:
        join_at = int(rng.integers(announce_lead + 1, n_outer))
        events.append(NodeEvent(join_at - announce_lead,
                                EventKind.ANNOUNCE, nid))
        events.append(NodeEvent(join_at, EventKind.JOIN, nid))
    for nid in crash_ids:
        events.append(NodeEvent(int(rng.integers(1, n_outer)),
                                EventKind.CRASH, nid))
    for nid in stall_ids:
        events.append(NodeEvent(int(rng.integers(1, n_outer)),
                                EventKind.STALL, nid))
    for nid in poison_ids:
        start = int(rng.integers(0, max(1, n_outer - 1)))
        mode0 = int(rng.integers(len(POISON_MODES)))
        for i, t in enumerate(range(start, n_outer)):
            mode = POISON_MODES[(mode0 + i) % len(POISON_MODES)]
            events.append(NodeEvent(t, EventKind.POISON, nid, arg=mode))
    return sorted(events, key=lambda e: e.outer_step)


class PeerFleet:
    """Seeded fleet of partial-replica serving peers over one source
    store, wired to ``ClusterSimulator`` fault events."""

    def __init__(self, src: ChunkStore, node_ids, root: pathlib.Path,
                 seed: int = 0, *, hold_fraction: float = 0.6,
                 chunk_bytes: int | None = None):
        self.src = src
        self.rng = np.random.default_rng(seed)
        self.stores: dict[int, ChunkStore] = {}
        self.peers: dict[int, ChunkPeer] = {}
        cb = chunk_bytes or src.chunk_bytes
        ids = src.inventory()
        node_ids = list(node_ids)
        for i, nid in enumerate(node_ids):
            if i == 0 or hold_fraction >= 1.0:
                # the first peer is a full replica: the union must
                # cover every chunk no matter what the rng drops
                self.stores[nid] = src
            else:
                st = ChunkStore(root / f"node_{nid}", chunk_bytes=cb)
                held = self.rng.random(len(ids)) < hold_fraction
                # partial replicas carry chunks but NO manifests —
                # they model mid-sync joiners; gossip is what
                # advertises their possession to the fetch
                for d, h in zip(ids, held):
                    if h:
                        st.put_blob(d, src.get_blob(d))
                self.stores[nid] = st
            self.peers[nid] = ChunkPeer(self.stores[nid])

    @property
    def addrs(self) -> list[tuple]:
        return [p.addr for p in self.peers.values()]

    def addr_of(self, nid: int) -> tuple:
        return self.peers[nid].addr

    def kill(self, nid: int, after_chunks: int = 0) -> None:
        """Crash ``nid``'s peer ``after_chunks`` more served chunks
        (0 = immediately)."""
        p = self.peers[nid]
        if after_chunks <= 0:
            p.crash()
        else:
            p.crash_after = p.served_chunks + after_chunks

    def stall(self, nid: int, seconds: float) -> None:
        p = self.peers[nid]
        p.stall_chunks = p.served_chunks
        p.stall_s = seconds

    def corrupt(self, nid: int, after_chunks: int = 0) -> None:
        p = self.peers[nid]
        p.corrupt_after = p.served_chunks + after_chunks

    def on_event(self, ev: NodeEvent) -> None:
        """``ClusterSimulator.subscribe`` hook: apply peer-level
        faults as membership events land."""
        if ev.node_id not in self.peers:
            return
        if ev.kind == EventKind.CRASH:
            self.kill(ev.node_id, after_chunks=2)
        elif ev.kind == EventKind.STALL:
            self.stall(ev.node_id, 0.05)

    def close(self) -> None:
        for p in self.peers.values():
            p.close()


class StageFleet:
    """K-stage x R-replica swarm-serving fleet for deterministic
    failover tests.

    Publishes each stage's parameter slice into a seed ``ChunkStore``
    (weight distribution = ``swarm_fetch``), then brings up
    ``k_stages * replicas`` ``StageServer``s — server ``(sid, r)``
    serves stage ``sid`` — plus a ``ChunkPeer`` over the seed store so
    late joiners can adopt. ``kill``/``stall``/``corrupt`` apply the
    shared peer fault knobs to one stage replica; ``router()`` wires a
    gossip + pool + ``SwarmRouter`` over the live fleet."""

    def __init__(self, cfg, params, root: pathlib.Path, *,
                 k_stages: int, replicas: int = 2, max_len: int = 128,
                 serve_seed_peer: bool = True, **server_kw):
        from repro.models import registry
        from repro.serving import swarm_serve as sw

        self.cfg = cfg
        self.k = k_stages
        self.replicas = replicas
        self.max_len = max_len
        self.seed_store = ChunkStore(root / "seed")
        sw.publish_stages(self.seed_store, cfg, params, k_stages)
        self.seed_peer = ChunkPeer(self.seed_store) \
            if serve_seed_peer else None
        stages = registry.make_stages(cfg, k_stages)
        self.servers: dict[tuple, object] = {}   # (sid, r) -> server
        for sid in range(k_stages):
            sp = stages[sid].slice_params(params)
            for r in range(replicas):
                store = ChunkStore(root / f"srv_{sid}_{r}")
                srv = sw.StageServer(cfg, store, k_stages=k_stages,
                                     max_len=max_len, **server_kw)
                srv.serve_stage(sid, sp)
                self.servers[(sid, r)] = srv
        self._pools: list = []
        self._gossips: list = []

    def server(self, sid: int, r: int = 0):
        return self.servers[(sid, r)]

    def addr_of(self, sid: int, r: int = 0) -> tuple:
        return self.servers[(sid, r)].addr

    @property
    def addrs(self) -> list[tuple]:
        return [s.addr for s in self.servers.values()]

    def kill(self, sid: int, r: int = 0, after_ops: int = 0) -> None:
        """Crash one stage replica ``after_ops`` more served
        responses (0 = immediately)."""
        s = self.servers[(sid, r)]
        if after_ops <= 0:
            s.crash()
        else:
            s.crash_after = s.served_chunks + after_ops

    def stall(self, sid: int, r: int = 0, seconds: float = 30.0,
              after_ops: int = 0) -> None:
        s = self.servers[(sid, r)]
        s.stall_chunks = s.served_chunks + after_ops
        s.stall_s = seconds

    def corrupt(self, sid: int, r: int = 0, after_ops: int = 0) -> None:
        s = self.servers[(sid, r)]
        s.corrupt_after = s.served_chunks + after_ops

    def router(self, *, timeout: float = 3.0, max_replays: int = 8,
               pooled: bool = True):
        from repro.checkpointing import ChunkGossip, PeerConnPool
        from repro.serving.swarm_serve import SwarmRouter

        pool = PeerConnPool(timeout=timeout) if pooled else None
        gossip = ChunkGossip(self.addrs, timeout=timeout, pool=pool)
        gossip.poll_once()
        router = SwarmRouter(self.k, gossip, timeout=timeout,
                             pool=pool, max_replays=max_replays,
                             max_len=self.max_len)
        self._pools.append(pool)
        self._gossips.append(gossip)
        return router

    def close(self) -> None:
        for g in self._gossips:
            g.stop()
        for p in self._pools:
            if p is not None:
                p.close()
        for s in self.servers.values():
            s.close()
        if self.seed_peer is not None:
            self.seed_peer.close()


class FakeStore:
    """In-memory gossip surface (what ``store_transport`` needs):
    chunk-id set + latest step, no disk, no sockets."""

    def __init__(self, ids=(), latest=None):
        self.ids = set(ids)
        self.latest = latest
        self.version = 0

    def add(self, *ids) -> None:
        self.ids.update(ids)
        self.version += 1

    def drop(self, *ids) -> None:
        self.ids.difference_update(ids)
        self.version += 1

    def inventory(self):
        return sorted(self.ids)

    def inventory_digest(self):
        h = hashlib.sha256()
        for d in self.inventory():
            h.update(d.encode())
        return len(self.ids), h.hexdigest()

    def latest_step(self):
        return self.latest

    def has(self, d):
        return d in self.ids
