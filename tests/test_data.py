"""Data pipeline: counter-based determinism (exact resume), shard
disjointness across DiLoCo workers, mixture ratios, annealing switch."""
import numpy as np
import pytest

from repro.data.pipeline import DataConfig, TokenPipeline


@pytest.fixture
def cfg():
    return DataConfig(vocab=1000, seq_len=32, batch_per_worker=16,
                      total_steps=100)


def test_batch_at_is_pure(cfg):
    p = TokenPipeline(cfg, worker=0, n_workers=4)
    b1 = p.batch_at(17)
    b2 = p.batch_at(17)
    np.testing.assert_array_equal(np.asarray(b1["tokens"]),
                                  np.asarray(b2["tokens"]))
    # and a fresh instance reproduces it (checkpoint-free resume)
    p2 = TokenPipeline(cfg, worker=0, n_workers=4)
    np.testing.assert_array_equal(np.asarray(b1["tokens"]),
                                  np.asarray(p2.batch_at(17)["tokens"]))


def test_workers_get_disjoint_shards(cfg):
    b0 = TokenPipeline(cfg, 0, 4).batch_at(0)
    b1 = TokenPipeline(cfg, 1, 4).batch_at(0)
    assert not np.array_equal(np.asarray(b0["tokens"]),
                              np.asarray(b1["tokens"]))


def test_targets_are_shifted_tokens(cfg):
    b = TokenPipeline(cfg, 0, 4).batch_at(3)
    np.testing.assert_array_equal(np.asarray(b["tokens"][:, 1:]),
                                  np.asarray(b["targets"][:, :-1]))


def test_mixture_ratios_match_weights():
    cfg = DataConfig(vocab=1000, seq_len=8, batch_per_worker=512,
                     total_steps=100)
    p = TokenPipeline(cfg, 0, 1)
    markers = np.concatenate([
        np.asarray(p.batch_at(s)["tokens"][:, 0]) for s in range(10)])
    frac = np.bincount(markers, minlength=5)[:5] / markers.size
    weights = p.mixture_at(0)
    np.testing.assert_allclose(frac, weights, atol=0.03)


def test_annealing_reweights_mixture():
    cfg = DataConfig(vocab=1000, seq_len=8, batch_per_worker=512,
                     total_steps=100, anneal_start_frac=0.8)
    p = TokenPipeline(cfg, 0, 1)
    stable = p.mixture_at(0)
    anneal = p.mixture_at(90)
    # paper Table 1: FineWeb-Edu 55 -> 80, DCLM/OpenWebMath -> 0
    assert anneal[0] > stable[0]
    assert anneal[3] == 0.0 and anneal[4] == 0.0
    markers = np.asarray(p.batch_at(90)["tokens"][:, 0])
    assert set(np.unique(markers)) <= {0, 1, 2}


def test_tokens_in_vocab_range(cfg):
    b = TokenPipeline(cfg, 2, 4).batch_at(5)
    t = np.asarray(b["tokens"])
    assert t.min() >= 0 and t.max() < cfg.vocab
