"""Distributed-path equivalence tests, run in subprocesses with 8 forced
host devices (the main test process must keep seeing ONE device)."""
import json
import pathlib
import subprocess
import sys
import textwrap

import pytest

_SRC = str(pathlib.Path(__file__).resolve().parents[1] / "src")


def _run(body: str) -> str:
    prog = textwrap.dedent("""
        import os
        os.environ["XLA_FLAGS"] = \
            "--xla_force_host_platform_device_count=8"
        import sys
        sys.path.insert(0, {src!r})
        import jax, numpy as np, jax.numpy as jnp
        from jax.sharding import PartitionSpec as P
        from repro import compat
    """).format(src=_SRC) + textwrap.dedent(body)
    out = subprocess.run([sys.executable, "-c", prog],
                         capture_output=True, text=True, timeout=600)
    assert out.returncode == 0, out.stderr[-3000:]
    return out.stdout


def test_distributed_ring_bit_matches_simulation():
    print(_run("""
        from repro.core import ring_reduce
        rng = np.random.default_rng(2)
        full = jnp.asarray(rng.normal(size=(8, 515)), jnp.float32)
        orders = {1: (0,), 2: (1, 0), 4: (2, 0, 3, 1),
                  8: (3, 1, 4, 0, 7, 5, 2, 6)}
        for k in [1, 2, 4, 8]:
            mesh = jax.sharding.Mesh(
                np.asarray(jax.devices()[:k]), ("dp",))
            xs = full[:k]
            order = orders[k]
            for quant in ["fp32", "int8", "int4"]:
                for buckets in ([1, 3] if quant == "int8" else [1]):
                    cfg = ring_reduce.RingConfig(quant=quant,
                                                 buckets=buckets)
                    def f(x):
                        return ring_reduce.ring_all_reduce(
                            x[0], "dp", ring_order=order, cfg=cfg)[None]
                    dist = jax.jit(compat.shard_map(
                        f, mesh=mesh, in_specs=P("dp"),
                        out_specs=P("dp"), check_vma=False))(xs)
                    sim = ring_reduce.simulate_ring_all_reduce(
                        xs, ring_order=order, cfg=cfg)
                    np.testing.assert_array_equal(
                        np.asarray(dist), np.asarray(sim),
                        err_msg=f"k={k} quant={quant} B={buckets}")
        print("RING-EQUIV-OK")
    """))


def test_distributed_outer_sync_matches_simulation():
    out = _run("""
        from repro.core import diloco
        mesh = compat.make_mesh((8,), ("dp",))
        rng = np.random.default_rng(3)
        params = {"a": jnp.asarray(rng.normal(size=(8, 6, 7)),
                                   jnp.float32),
                  "b": jnp.asarray(rng.normal(size=(8, 11)),
                                   jnp.float32)}
        dcfg = diloco.DiLoCoConfig(quant="int8")
        p0 = jax.tree.map(lambda p: p[0], params)
        st = diloco.init_outer_state(p0, dcfg)
        def sync(p, anchor, mom):
            pi = jax.tree.map(lambda x: x[0], p)
            sti = diloco.OuterState(
                anchor, type(st.opt)(mom),
                jnp.zeros((0,), jnp.float32),
                jnp.zeros((), jnp.int32))
            np_, _ = diloco.outer_sync(pi, sti, dcfg, "dp")
            return jax.tree.map(lambda x: x[None], np_)
        dist_p = jax.jit(compat.shard_map(
            sync, mesh=mesh, in_specs=(P("dp"), P(), P()),
            out_specs=P("dp"), check_vma=False))(
                params, st.anchor, st.opt.momentum)
        st_sim = diloco.init_outer_state_sim(p0, dcfg, 8)
        sim_p, _ = diloco.outer_sync_sim(params, st_sim, dcfg)
        for k in ("a", "b"):
            np.testing.assert_allclose(
                np.asarray(dist_p[k]), np.asarray(sim_p[k]),
                rtol=3e-6, atol=3e-7)
        print("SYNC-EQUIV-OK")
    """)
    assert "SYNC-EQUIV-OK" in out


def test_shard_map_train_step_runs_and_reduces_loss():
    out = _run("""
        from repro.configs import CONFIGS
        from repro.models.registry import get_model
        from repro.optim.adamw import AdamW
        from repro.sharding import make_plan
        from repro.train import step as step_lib
        from repro.train.state import TrainState
        from repro.configs.base import ShapeConfig
        import dataclasses

        mesh = compat.make_mesh((4, 2), ("data", "model"))
        cfg = CONFIGS["internlm2-1.8b"].reduced()
        shape = ShapeConfig("t", "train", 32, 8)
        plan = make_plan(cfg, shape, {"data": 4, "model": 2})
        assert plan.diloco_axis == "data"
        model = get_model(cfg)
        params, _ = model.init(jax.random.PRNGKey(0))
        k = plan.n_workers
        stack = lambda t: jax.tree.map(
            lambda x: jnp.broadcast_to(x[None], (k,) + x.shape), t)
        opt = AdamW(lr=1e-3)
        with mesh:
            step, specs = step_lib.build_train_step(model, plan, mesh,
                                                    opt)
            sp = stack(params)
            so = jax.vmap(opt.init)(sp)
            state = TrainState(sp, so)
            key = jax.random.PRNGKey(1)
            tokens = jax.random.randint(key, (k, 2, 33), 0, cfg.vocab)
            batch = {"tokens": tokens[..., :-1],
                     "targets": tokens[..., 1:],
                     "mask": jnp.ones((k, 2, 32), jnp.float32)}
            jitted = jax.jit(step)
            losses = []
            for i in range(8):
                state, metrics = jitted(state, batch)
                losses.append(float(metrics["loss"].mean()))
        assert losses[-1] < losses[0], losses
        print("TRAIN-STEP-OK", losses[0], losses[-1])
    """)
    assert "TRAIN-STEP-OK" in out


def test_replicated_plan_sync_threads_anchor_flat():
    """Replicated-inner-params plans thread the PERSISTENT flat fp32
    anchor through the shard_map sync (ROADMAP follow-up from PR 1):
    the returned state carries the updated buffer, it matches a fresh
    flatten of the anchor, and chaining two syncs off it matches the
    simulation."""
    out = _run("""
        from repro.core import diloco
        from repro.core.sync_engine import SyncEngine
        from repro.models.registry import get_model
        from repro.configs import CONFIGS
        from repro.configs.base import ShapeConfig
        from repro.sharding import make_plan
        from repro.train import step as step_lib

        mesh = compat.make_mesh((4, 2), ("data", "model"))
        cfg = CONFIGS["mamba2-130m"].reduced()
        shape = ShapeConfig("t", "train", 32, 8)
        plan = make_plan(cfg, shape, {"data": 4, "model": 2})
        assert plan.diloco_axis == "data"
        model = get_model(cfg)
        params, _ = model.init(jax.random.PRNGKey(0))
        k = plan.n_workers
        stacked = jax.tree.map(
            lambda x: jnp.stack([x + 0.01 * i for i in range(k)]),
            params)
        dcfg = diloco.DiLoCoConfig(quant="fp32")
        st = diloco.init_outer_state(params, dcfg)
        st = st._replace(residual=jnp.zeros((k, 0), jnp.float32))
        with mesh:
            sync, outer_specs = step_lib.build_outer_sync(
                model, plan, mesh, dcfg)
            # replicated plan => the flat anchor IS threaded
            assert outer_specs.anchor_flat is not None
            w = jnp.ones((k,), jnp.float32)
            jsync = jax.jit(sync)
            new_p, new_st = jsync(stacked, st, w)
            assert new_st.anchor_flat is not None
            # the threaded buffer equals a fresh flatten of the anchor
            eng = SyncEngine.for_tree(new_st.anchor)
            np.testing.assert_array_equal(
                np.asarray(new_st.anchor_flat),
                np.asarray(eng.flatten(new_st.anchor)))
            # chain a second sync off the returned buffer
            new_p2, new_st2 = jsync(new_p, new_st, w)
        sim_st = diloco.init_outer_state_sim(params, dcfg, k)
        sim_p, sim_st = diloco.outer_sync_sim(stacked, sim_st, dcfg)
        sim_p2, _ = diloco.outer_sync_sim(sim_p, sim_st, dcfg)
        for got, want in (((new_p), (sim_p)), ((new_p2), (sim_p2))):
            np.testing.assert_allclose(
                np.asarray(got["embed"], np.float32),
                np.asarray(want["embed"], np.float32),
                rtol=1e-5, atol=1e-6)
        print("ANCHOR-FLAT-OK")
    """)
    assert "ANCHOR-FLAT-OK" in out


def test_sharded_plan_threads_per_shard_anchor_flat():
    """Sharded plans thread the PER-SHARD flat anchor view through the
    manual sync region (PR 5): the concat of each device's local anchor
    shards rides in/out as an opaque buffer, so the pseudo-gradient is
    one subtract off the persistent buffer — and the result is
    BIT-EXACT against the tree-path sync that re-flattens the local
    anchor every call."""
    out = _run("""
        from repro.core import diloco
        from repro.configs import CONFIGS
        from repro.configs.base import ShapeConfig
        from repro.sharding.plans import ParallelismPlan
        from repro.train import step as step_lib
        from repro.models.registry import get_model
        from jax.sharding import PartitionSpec as P

        mesh = compat.make_mesh((4, 2), ("data", "model"))
        cfg = CONFIGS["internlm2-1.8b"].reduced()
        # reduced() configs all take the inner-DP (replicated) rules;
        # force real TP sharding so the per-shard path is exercised
        plan = ParallelismPlan(
            diloco_axis="data",
            rules=(("vocab", "model"), ("heads", "model"),
                   ("ff", "model"), ("experts", "model"),
                   ("embed", None), ("layers", None)),
            batch_axes=(), seq_axis=None, remat=False, n_workers=4)
        model = get_model(cfg)
        pspecs = step_lib.param_specs(model, plan, mesh)
        specs = jax.tree.leaves(
            pspecs, is_leaf=lambda x: isinstance(x, P))
        assert any(s != P() for s in specs), "plan must shard params"
        params, _ = model.init(jax.random.PRNGKey(0))
        k = 4
        stacked = jax.tree.map(
            lambda x: jnp.stack([x + 0.01 * i for i in range(k)]),
            params)
        dcfg = diloco.DiLoCoConfig(quant="fp32")
        st = diloco.init_outer_state(params, dcfg)
        st = st._replace(residual=jnp.zeros((k, 0), jnp.float32),
                         anchor_flat=None)
        numel = sum(l.size for l in jax.tree.leaves(params))
        with mesh:
            sync, outer_specs = step_lib.build_outer_sync(
                model, plan, mesh, dcfg)
            # sharded plan => a per-shard flat spec is threaded
            assert outer_specs.anchor_flat is not None
            flat_len = step_lib.flat_anchor_len(model, plan, mesh)
            assert flat_len > numel  # replicated leaves concat per dev
            w = jnp.ones((k,), jnp.float32)
            jsync = jax.jit(sync)
            p1, st1 = jsync(stacked, st, w)
            assert st1.anchor_flat.shape == (flat_len,)
            # chained: threaded buffer vs tree-path rebuild, bit-exact
            p2a, st2a = jsync(p1, st1, w)
            p2b, st2b = jsync(p1, st1._replace(anchor_flat=None), w)
            for a, b in zip(jax.tree.leaves(p2a), jax.tree.leaves(p2b)):
                np.testing.assert_array_equal(np.asarray(a),
                                              np.asarray(b))
            np.testing.assert_array_equal(
                np.asarray(st2a.anchor_flat),
                np.asarray(st2b.anchor_flat))
        # and the sharded sync still equals the unsharded simulation
        sim_st = diloco.init_outer_state_sim(params, dcfg, k)
        sim_p, sim_st = diloco.outer_sync_sim(stacked, sim_st, dcfg)
        sim_p2, _ = diloco.outer_sync_sim(p1, sim_st, dcfg)
        np.testing.assert_allclose(
            np.asarray(p1["embed"], np.float32),
            np.asarray(sim_p["embed"], np.float32),
            rtol=1e-4, atol=1e-5)
        np.testing.assert_allclose(
            np.asarray(p2a["embed"], np.float32),
            np.asarray(sim_p2["embed"], np.float32),
            rtol=1e-4, atol=1e-5)
        print("SHARD-ANCHOR-FLAT-OK")
    """)
    assert "SHARD-ANCHOR-FLAT-OK" in out


def test_full_manual_sync_with_sharded_params():
    """Hybrid FSDP+DiLoCo: per-shard rings on a 2x2 mesh equal the
    unsharded simulation."""
    out = _run("""
        from repro.core import diloco
        from repro.sharding import partition
        from repro.sharding.plans import ParallelismPlan
        from repro.train import step as step_lib
        from repro.models.registry import get_model
        from repro.configs import CONFIGS
        from repro.configs.base import ShapeConfig
        from repro.sharding import make_plan

        mesh = compat.make_mesh((4, 2), ("data", "model"))
        cfg = CONFIGS["internlm2-1.8b"].reduced()
        shape = ShapeConfig("t", "train", 32, 8)
        plan = make_plan(cfg, shape, {"data": 4, "model": 2})
        model = get_model(cfg)
        params, _ = model.init(jax.random.PRNGKey(0))
        k = plan.n_workers
        rng = np.random.default_rng(0)
        stacked = jax.tree.map(
            lambda x: jnp.stack([x + 0.01 * i for i in range(k)]),
            params)
        # fp32 ring -> exact equivalence (int8 per-SHARD stats
        # legitimately differ from the sim's per-worker chunk stats)
        dcfg = diloco.DiLoCoConfig(quant="fp32")
        st = diloco.init_outer_state(params, dcfg)
        # the distributed sync expects a stacked per-worker residual
        st = st._replace(residual=jnp.zeros((k, 0), jnp.float32))
        with mesh:
            sync, outer_specs = step_lib.build_outer_sync(
                model, plan, mesh, dcfg)
            w = jnp.ones((k,), jnp.float32)
            new_p, new_st = jax.jit(sync)(stacked, st, w)
        sim_st = diloco.init_outer_state_sim(params, dcfg, k)
        sim_p, _ = diloco.outer_sync_sim(stacked, sim_st, dcfg)
        a = np.asarray(new_p["embed"], np.float32)
        b = np.asarray(sim_p["embed"], np.float32)
        np.testing.assert_allclose(a, b, rtol=1e-4, atol=1e-5)
        print("FULL-MANUAL-SYNC-OK")
    """)
    assert "FULL-MANUAL-SYNC-OK" in out


def test_dist_ring_op_bit_matches_sim_op():
    """Per-hop distributed ring (DistRingSyncOp over jitted shard_map
    hop programs) is bit-identical to the simulator ring — plain,
    fused first hop, non-identity ring order, partial weights, and the
    torn-reduction restart path."""
    out = _run("""
        from repro.core import ring_reduce as rr
        from repro.train import step as ts
        k, size = 4, 37
        mesh = compat.make_mesh((k,), ("data",),
                                devices=np.asarray(jax.devices())[:k])
        cfg = rr.RingConfig(quant="int8", buckets=3)
        rng = np.random.default_rng(0)
        w = jnp.asarray([1.0, 0.0, 1.0, 1.0], jnp.float32)
        order = (2, 0, 3, 1)
        pr = ts.DistSyncPrograms(mesh, "data", size, cfg,
                                 ring_order=order)
        a = jnp.asarray(rng.normal(size=(size,)), jnp.float32)
        thetas = jnp.asarray(rng.normal(size=(k, size)), jnp.float32)
        pgs = a[None] - thetas
        # plain
        ref = rr.simulate_ring_all_reduce(pgs, cfg=cfg,
                                          ring_order=order, weights=w)
        op = ts.DistRingSyncOp(pr, pgs, weights=w)
        hops = 0
        while op.step():
            hops += 1
        assert hops == op.hops_total == 2 * (k - 1)
        np.testing.assert_array_equal(np.asarray(ref),
                                      np.asarray(op.finish()))
        # fused first-hop transmit
        ref2 = rr.simulate_ring_all_reduce(
            pgs, cfg=cfg, ring_order=order, weights=w,
            fused_src=(a, thetas))
        op2 = ts.DistRingSyncOp(pr, pgs, weights=w,
                                fused_src=(a, thetas))
        np.testing.assert_array_equal(np.asarray(ref2),
                                      np.asarray(op2.finish()))
        # restart (torn reduction): re-reduce retained inputs over the
        # survivors, mid-flight state discarded
        op3 = ts.DistRingSyncOp(pr, pgs, weights=w,
                                fused_src=(a, thetas))
        op3.step(); op3.step()
        w2 = jnp.asarray([1.0, 0.0, 1.0, 0.0], jnp.float32)
        ref3 = rr.simulate_ring_all_reduce(
            pgs, cfg=cfg, ring_order=order, weights=w2,
            fused_src=(a, thetas))
        np.testing.assert_array_equal(np.asarray(ref3),
                                      np.asarray(op3.restart(w2)))
        print("DIST-OP-OK")
    """)
    assert "DIST-OP-OK" in out


def test_hierarchical_ring_matches_per_slice_sim():
    """Hierarchical mode ((4, 2) mesh: WAN ring over 'data', intra-node
    split over 'model') is bit-identical to the PER-SLICE simulator:
    each 1/n_local slice ringed independently (its own codebooks), then
    concatenated — the documented equivalence class for the paper's
    ElasticDeviceMesh split."""
    out = _run("""
        from repro.core import elastic_mesh as em
        from repro.core import ring_reduce as rr
        from repro.train import step as ts
        k, size = 4, 37
        mesh = compat.make_mesh((k, 2), ("data", "model"),
                                devices=np.asarray(jax.devices())[:8])
        hier = em.hierarchy(mesh, "data")
        assert hier.split and hier.n_local == 2
        cfg = rr.RingConfig(quant="int8", buckets=3)
        rng = np.random.default_rng(1)
        pgs = jnp.asarray(rng.normal(size=(k, size)), jnp.float32)
        w = jnp.asarray([1.0, 0.0, 1.0, 1.0], jnp.float32)
        order = (2, 0, 3, 1)
        pr = ts.DistSyncPrograms(mesh, "data", size, cfg,
                                 ring_order=order, hierarchy=hier)
        out_h = ts.DistRingSyncOp(pr, pgs, weights=w).finish()
        sl = pr.slice_len
        pad = jnp.pad(pgs, ((0, 0), (0, hier.n_local * sl - size)))
        parts = [rr.simulate_ring_all_reduce(
                     pad[:, i * sl:(i + 1) * sl], cfg=cfg,
                     ring_order=order, weights=w)
                 for i in range(hier.n_local)]
        ref = jnp.concatenate(parts, axis=1)[:, :size]
        np.testing.assert_array_equal(np.asarray(ref),
                                      np.asarray(out_h))
        print("HIER-OK")
    """)
    assert "HIER-OK" in out


def test_dist_backend_trainer_bit_identical_to_sim():
    """The acceptance test: an ElasticTrainer running overlap='delayed'
    through DistSyncBackend (real per-hop shard_map collectives over a
    4-way mesh) is bit-identical to the simulator trainer over 4 outer
    steps — including a worker CRASHING mid-overlap at step 2, which
    takes the torn-reduction fallback on both paths."""
    out = _run("""
        from repro.configs import CONFIGS
        from repro.core import diloco as dl
        from repro.core.fault_tolerance import (ClusterSimulator,
                                                EventKind, NodeEvent)
        from repro.data.pipeline import DataConfig
        from repro.models.registry import get_model
        from repro.train import step as ts
        from repro.train.loop import ElasticTrainer, TrainerConfig

        K, INNER, CHUNKS, STEPS = 4, 5, 7, 4

        def make_trainer(backend=None):
            cfg = CONFIGS["mamba2-130m"].reduced()
            model = get_model(cfg)
            params, _ = model.init(jax.random.PRNGKey(0))
            dcfg = DataConfig(vocab=cfg.vocab, seq_len=32,
                              batch_per_worker=2,
                              total_steps=INNER * 32)
            tcfg = TrainerConfig(
                diloco=dl.DiLoCoConfig(inner_steps=INNER, quant="int8",
                                       overlap="delayed",
                                       error_feedback=True),
                inner_lr=3e-3, max_workers=K, inner_chunks=CHUNKS)
            ev = [NodeEvent(2, EventKind.CRASH, 1)]
            return ElasticTrainer(
                model, tcfg, dcfg, params,
                ClusterSimulator(list(range(K)), events=ev),
                sync_backend=backend)

        t_sim = make_trainer()
        hist_sim = t_sim.run(STEPS)
        mesh = compat.make_mesh((K,), ("data",),
                                devices=np.asarray(jax.devices())[:K])
        backend = ts.DistSyncBackend(mesh, "data")
        t_dist = make_trainer(backend=backend)
        hist_dist = t_dist.run(STEPS)

        torn = [("sync_fallback" in r) for r in hist_dist]
        assert torn == [("sync_fallback" in r) for r in hist_sim]
        assert any(torn), "crash at step 2 must tear the in-flight sync"
        for ls, ld in zip(jax.tree.leaves(t_sim.params),
                          jax.tree.leaves(t_dist.params)):
            np.testing.assert_array_equal(np.asarray(ls),
                                          np.asarray(ld))
        np.testing.assert_array_equal(
            np.asarray(t_sim.outer.anchor_flat),
            np.asarray(t_dist.outer.anchor_flat))
        assert all(r["loss"] == s["loss"]
                   for r, s in zip(hist_dist, hist_sim))
        assert backend.recompiles == 1   # stable ring order: one build
        print("TRAINER-EQUIV-OK")
    """)
    assert "TRAINER-EQUIV-OK" in out
