"""Property tests (hypothesis, optional via tests/hypo_compat.py) for
the streaming-recovery tentpole:

  * gossip possession maps converge to ground truth under ARBITRARY
    join/leave/stall schedules — whatever churn happened historically,
    once the world holds still for the expiry window the map equals
    exactly what the live peers hold;
  * streamed delta-chain restores are bit-exact for ANY chunk arrival
    order, chain length and codec — the incremental ChainReplayer and
    the one-shot restore produce identical bytes.
"""
import tempfile

import numpy as np

from repro.checkpointing import (ChainReplayer, ChunkGossip,
                                 ChunkStore, DeltaCheckpointer,
                                 DeltaConfig, store_transport)
from repro.checkpointing import delta as delta_mod

from tests.fault_harness import FakeStore
from tests.hypo_compat import given, settings, st

PEERS = [("p", 0), ("p", 1), ("p", 2)]
UNIVERSE = [f"{i:02x}" * 32 for i in range(12)]

# one churn action: (peer index, op, chunk index)
_action = st.tuples(st.integers(0, 2),
                    st.sampled_from(["up", "down", "gain", "lose"]),
                    st.integers(0, 11))
_schedule = st.lists(st.lists(_action, max_size=4), max_size=8)


@given(schedule=_schedule, expire=st.integers(1, 3))
@settings(max_examples=30, deadline=None)
def test_gossip_possession_converges_to_ground_truth(schedule, expire):
    stores = {addr: FakeStore() for addr in PEERS}
    world: dict = dict(stores)           # None = down / stalled
    g = ChunkGossip(PEERS, expire_polls=expire,
                    transport=store_transport(world))
    for round_actions in schedule:
        for pi, op, ci in round_actions:
            addr = PEERS[pi]
            if op == "up":
                world[addr] = stores[addr]
            elif op == "down":
                world[addr] = None
            elif op == "gain":
                stores[addr].add(UNIVERSE[ci])
            elif op == "lose":
                stores[addr].drop(UNIVERSE[ci])
        g.poll_once()   # gossip runs concurrently with the churn

    # the world holds still: everything converges within the expiry
    # window plus one clean round
    for _ in range(expire + 1):
        g.poll_once()
    pos = g.possession
    for addr in PEERS:
        if world[addr] is None:
            assert addr not in pos, \
                f"dead peer {addr} still in the map"
        else:
            assert pos.get(addr) == frozenset(world[addr].ids), \
                f"possession diverged for {addr}"


@given(seed=st.integers(0, 2**31 - 1), steps=st.integers(1, 4),
       codec=st.sampled_from(["int8", "int4"]),
       order_seed=st.integers(0, 2**31 - 1))
@settings(max_examples=15, deadline=None)
def test_streamed_chain_restore_bit_exact_any_order(seed, steps,
                                                    codec, order_seed):
    rng = np.random.default_rng(seed)
    with tempfile.TemporaryDirectory() as td:
        src = ChunkStore(f"{td}/src", chunk_bytes=1 << 10)
        ck = DeltaCheckpointer(src, DeltaConfig(base_every=steps + 1,
                                                codec=codec))
        w = rng.normal(size=(4_000,)).astype(np.float32)
        tree = None
        for t in range(steps):
            tree = {"w": w.copy(), "step": np.int32(t)}
            ck.save(t, tree, extra_meta={"t": t})
            w = (w + rng.normal(size=w.shape).astype(np.float32)
                 * 1e-3).astype(np.float32)

        chain = [src.load_manifest(s) for s in src.steps()]
        dst = ChunkStore(f"{td}/dst", chunk_bytes=1 << 10)
        rp = ChainReplayer(dst, chain)
        ids = src.inventory()
        order = np.random.default_rng(order_seed).permutation(len(ids))
        for i in order:
            dst.put_blob(ids[i], src.get_blob(ids[i]))
            rp.on_chunk(ids[i])
        assert rp.complete
        streamed, meta = rp.finish(tree)
        assert meta["t"] == steps - 1

        # bit-exact vs the writer's reconstruction AND the one-shot
        # restore from the source store
        np.testing.assert_array_equal(streamed["w"],
                                      ck.reference(tree)["w"])
        direct, _ = delta_mod.restore(src, tree)
        np.testing.assert_array_equal(streamed["w"], direct["w"])
