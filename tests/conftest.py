import os

# Tests must see ONE device (the dry-run sets its own 512-device flag in
# a subprocess); keep any user XLA_FLAGS out of the way.
os.environ.pop("XLA_FLAGS", None)

import numpy as np
import pytest


@pytest.fixture(scope="session")
def rng():
    return np.random.default_rng(0)
