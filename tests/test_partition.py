"""Sharding plans and partition rules."""
import jax
from jax.sharding import PartitionSpec as P

from repro.configs import CONFIGS, SHAPES
from repro.sharding import make_plan, partition

MESH_S = {"data": 16, "model": 16}
MESH_M = {"pod": 2, "data": 16, "model": 16}


def test_plan_diloco_axis_selection():
    cfg = CONFIGS["granite-3-2b"]
    assert make_plan(cfg, SHAPES["train_4k"], MESH_S).diloco_axis == \
        "data"
    assert make_plan(cfg, SHAPES["train_4k"], MESH_M).diloco_axis == \
        "pod"
    # dbrx: a 132B replica per worker only fits one per pod
    dbrx = CONFIGS["dbrx-132b"]
    assert make_plan(dbrx, SHAPES["train_4k"], MESH_S).diloco_axis \
        is None
    assert make_plan(dbrx, SHAPES["train_4k"], MESH_M).diloco_axis == \
        "pod"
    # serving never uses DiLoCo
    assert make_plan(cfg, SHAPES["decode_32k"], MESH_M).diloco_axis \
        is None


def test_plan_tiny_model_inner_dp():
    cfg = CONFIGS["mamba2-130m"]
    plan = make_plan(cfg, SHAPES["train_4k"], MESH_S)
    assert all(ax is None for _, ax in plan.rules)
    assert "model" in plan.batch_axes


def test_param_pspec_rules_and_conflicts():
    plan = make_plan(CONFIGS["granite-3-2b"], SHAPES["train_4k"],
                     MESH_S)
    # vocab-sharded embedding
    s = partition.param_pspec(("vocab", "embed"), (49408, 2048), plan,
                              MESH_S)
    assert s == P("model")
    # ff sharded
    s = partition.param_pspec(("embed", "ff"), (2048, 8192), plan,
                              MESH_S)
    assert s == P(None, "model")
    # conflict: two logical axes both wanting 'model' -> first wins
    s = partition.param_pspec(("experts", "embed", "ff"),
                              (64, 2048, 1408), plan, MESH_S)
    assert s == P("model")


def test_param_pspec_divisibility_guard():
    plan = make_plan(CONFIGS["granite-3-2b"], SHAPES["train_4k"],
                     MESH_S)
    # 24 heads don't divide 16 -> replicated
    s = partition.param_pspec(("heads",), (24,), plan, MESH_S)
    assert s == P()


def test_batch_pspec_divisibility_fallback():
    plan = make_plan(CONFIGS["granite-3-2b"], SHAPES["decode_32k"],
                     MESH_M)
    assert partition.batch_pspec(plan, 128, MESH_M) != P()
    # batch=1 (long_500k) can't shard
    assert partition.batch_pspec(plan, 1, MESH_M) == P()


def test_cache_pspec_heads_vs_seq():
    plan = make_plan(CONFIGS["internlm2-1.8b"], SHAPES["decode_32k"],
                     MESH_S)
    # kv heads 8 don't divide 16 -> fall to sequence parallelism
    s = partition.cache_pspec((24, 128, 32768, 8, 128), plan, MESH_S,
                              batch_dim=1, heads_dim=3, seq_dim=2)
    assert s == P(None, "data", "model")
    # 32 kv heads divide -> heads sharding preferred
    plan2 = make_plan(CONFIGS["phi-3-vision-4.2b"],
                      SHAPES["decode_32k"], MESH_S)
    s2 = partition.cache_pspec((32, 128, 32768, 32, 96), plan2, MESH_S,
                               batch_dim=1, heads_dim=3, seq_dim=2)
    assert s2 == P(None, "data", None, "model")


def test_remat_on_for_all_train_shapes():
    for arch in ("mamba2-130m", "dbrx-132b"):
        plan = make_plan(CONFIGS[arch], SHAPES["train_4k"], MESH_S)
        assert plan.remat
        plan = make_plan(CONFIGS[arch], SHAPES["decode_32k"], MESH_S)
        assert not plan.remat


def test_vocab_padding_divisible():
    for name, cfg in CONFIGS.items():
        assert cfg.padded_vocab % 256 == 0
        assert cfg.padded_vocab >= cfg.vocab
        assert cfg.padded_vocab - cfg.vocab < 256
