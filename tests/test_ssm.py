"""Mamba2 SSD: the chunked dual-form scan must match the naive O(L)
recurrence, and decode must continue prefill exactly."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.models import ssm
from repro.models.common import ParamBuilder

CFG = ssm.SSMConfig(d_model=16, d_state=8, head_dim=4, n_groups=1,
                    conv_kernel=4, expand=2, chunk=4)


def _naive_ssd(x, dt, a, bmat, cmat):
    """Reference: token-by-token recurrence."""
    bsz, l, h, p = x.shape
    g, n = bmat.shape[2], bmat.shape[3]
    hpg = h // g
    bh = np.repeat(np.asarray(bmat), hpg, axis=2)      # (B, L, H, N)
    ch = np.repeat(np.asarray(cmat), hpg, axis=2)
    s = np.zeros((bsz, h, p, n))
    ys = np.zeros((bsz, l, h, p))
    xn, dtn, an = map(np.asarray, (x, dt, a))
    for t in range(l):
        da = np.exp(dtn[:, t] * an)                     # (B, H)
        s = s * da[..., None, None] + (
            dtn[:, t][..., None] * xn[:, t])[..., None] * \
            bh[:, t][:, :, None, :]
        ys[:, t] = np.einsum("bhpn,bhn->bhp", s, ch[:, t])
    return ys, s


@pytest.mark.parametrize("l", [4, 7, 16, 33])
def test_chunked_ssd_matches_naive(l, rng):
    bsz, h, p, g, n = 2, CFG.n_heads, CFG.head_dim, 1, CFG.d_state
    x = jnp.asarray(rng.normal(size=(bsz, l, h, p)), jnp.float32)
    dt = jnp.asarray(rng.uniform(0.01, 0.2, size=(bsz, l, h)),
                     jnp.float32)
    a = -jnp.asarray(rng.uniform(0.5, 2.0, size=(h,)), jnp.float32)
    bm = jnp.asarray(rng.normal(size=(bsz, l, g, n)), jnp.float32)
    cm = jnp.asarray(rng.normal(size=(bsz, l, g, n)), jnp.float32)
    y, final = ssm.ssd_chunked(x, dt, a, bm, cm, CFG)
    y_ref, s_ref = _naive_ssd(x, dt, a, bm, cm)
    np.testing.assert_allclose(np.asarray(y), y_ref, rtol=2e-4,
                               atol=2e-4)
    np.testing.assert_allclose(np.asarray(final), s_ref, rtol=2e-4,
                               atol=2e-4)


def test_ssd_chunk_size_invariance(rng):
    bsz, l, h, p, g, n = 1, 24, CFG.n_heads, CFG.head_dim, 1, CFG.d_state
    x = jnp.asarray(rng.normal(size=(bsz, l, h, p)), jnp.float32)
    dt = jnp.asarray(rng.uniform(0.01, 0.2, size=(bsz, l, h)),
                     jnp.float32)
    a = -jnp.asarray(rng.uniform(0.5, 2.0, size=(h,)), jnp.float32)
    bm = jnp.asarray(rng.normal(size=(bsz, l, g, n)), jnp.float32)
    cm = jnp.asarray(rng.normal(size=(bsz, l, g, n)), jnp.float32)
    y1, _ = ssm.ssd_chunked(x, dt, a, bm, cm, CFG._replace(chunk=4))
    y2, _ = ssm.ssd_chunked(x, dt, a, bm, cm, CFG._replace(chunk=8))
    y3, _ = ssm.ssd_chunked(x, dt, a, bm, cm, CFG._replace(chunk=24))
    np.testing.assert_allclose(np.asarray(y1), np.asarray(y2),
                               rtol=2e-4, atol=2e-4)
    np.testing.assert_allclose(np.asarray(y1), np.asarray(y3),
                               rtol=2e-4, atol=2e-4)


def test_mamba2_decode_continues_prefill(rng):
    b = ParamBuilder(jax.random.PRNGKey(0), dtype=jnp.float32)
    ssm.init_mamba2(b, "m", CFG)
    p = b.params["m"]
    bsz, l = 2, 12
    x = jnp.asarray(rng.normal(size=(bsz, l + 1, CFG.d_model)) * 0.3,
                    jnp.float32)
    # full pass over l+1 tokens
    y_full, _ = ssm.apply_mamba2(p, x, CFG, return_state=False)
    # prefill l tokens, then decode token l+1
    y_pre, state = ssm.apply_mamba2(p, x[:, :l], CFG,
                                    return_state=True)
    y_dec, _ = ssm.decode_mamba2(p, x[:, l:], CFG, state)
    np.testing.assert_allclose(np.asarray(y_dec[:, 0]),
                               np.asarray(y_full[:, l]), rtol=2e-3,
                               atol=2e-3)
    np.testing.assert_allclose(np.asarray(y_pre),
                               np.asarray(y_full[:, :l]), rtol=2e-3,
                               atol=2e-3)


def test_chunked_prefill_continuation(rng):
    """apply_mamba2 with a carried state == one long prefill."""
    b = ParamBuilder(jax.random.PRNGKey(1), dtype=jnp.float32)
    ssm.init_mamba2(b, "m", CFG)
    p = b.params["m"]
    bsz = 1
    x = jnp.asarray(rng.normal(size=(bsz, 16, CFG.d_model)) * 0.3,
                    jnp.float32)
    y_full, st_full = ssm.apply_mamba2(p, x, CFG, return_state=True)
    y1, st1 = ssm.apply_mamba2(p, x[:, :9], CFG, return_state=True)
    y2, st2 = ssm.apply_mamba2(p, x[:, 9:], CFG, state=st1,
                               return_state=True)
    np.testing.assert_allclose(np.asarray(y2), np.asarray(y_full[:, 9:]),
                               rtol=2e-3, atol=2e-3)
    np.testing.assert_allclose(np.asarray(st2.state),
                               np.asarray(st_full.state), rtol=2e-3,
                               atol=2e-3)
